"""AOT lowering driver: JAX → HLO text + manifest, consumed by Rust.

Python runs ONCE here (``make artifacts``); the rust binary is
self-contained afterwards. The interchange format is HLO *text*, not a
serialized HloModuleProto: jax ≥ 0.5 emits protos with 64-bit
instruction ids that the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--models mlp,convnet,...]
"""

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_registry

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(fn, input_specs):
    """Lower a python function to XLA HLO text with tupled outputs."""
    shaped = [
        jax.ShapeDtypeStruct(tuple(shape), DTYPES[dt]) for _, shape, dt in input_specs
    ]
    lowered = jax.jit(fn).lower(*shaped)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def manifest_text(spec):
    """Render the manifest format parsed by rust/src/runtime/manifest.rs."""
    lines = [f"artifact {spec.name}"]
    for name, shape, dt in spec.inputs:
        sh = ",".join(str(d) for d in shape) if shape else "-"
        lines.append(f"input {name} {dt} {sh}")
    for name, shape, dt in spec.outputs:
        sh = ",".join(str(d) for d in shape) if shape else "-"
        lines.append(f"output {name} {dt} {sh}")
    for p in spec.params:
        init = spec.param_inits.get(p, "zero")
        lines.append(f"param {p} {init}")
    for k, v in sorted(spec.meta.items()):
        lines.append(f"meta {k} {v}")
    return "\n".join(lines) + "\n"


def build(spec, out_dir, force=False):
    hlo_path = os.path.join(out_dir, f"{spec.name}.hlo.txt")
    man_path = os.path.join(out_dir, f"{spec.name}.manifest")
    if not force and os.path.exists(hlo_path) and os.path.exists(man_path):
        print(f"  [cached] {spec.name}")
        return
    t0 = time.time()
    text = to_hlo_text(spec.fn, spec.inputs)
    with open(hlo_path, "w") as f:
        f.write(text)
    with open(man_path, "w") as f:
        f.write(manifest_text(spec))
    print(
        f"  [built]  {spec.name}: {len(text) / 1e3:.0f} KB HLO in {time.time() - t0:.1f}s"
    )


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default=",".join(model_registry.DEFAULT_MODELS),
        help="comma-separated registry keys; 'all' for everything",
    )
    ap.add_argument("--force", action="store_true", help="rebuild cached artifacts")
    ap.add_argument("--list", action="store_true", help="list registry keys and exit")
    args = ap.parse_args(argv)

    reg = model_registry.registry()
    if args.list:
        for k in sorted(reg):
            print(k)
        return 0

    keys = sorted(reg) if args.models == "all" else args.models.split(",")
    os.makedirs(args.out_dir, exist_ok=True)
    for key in keys:
        key = key.strip()
        if key not in reg:
            print(f"unknown model {key!r}; available: {sorted(reg)}", file=sys.stderr)
            return 1
        print(f"{key}:")
        for spec in reg[key]():
            build(spec, args.out_dir, force=args.force)
    return 0


if __name__ == "__main__":
    sys.exit(main())
