"""L2 artifact assembly: turn model definitions into the artifact set
that `aot.py` lowers and the Rust coordinator loads.

Artifact calling convention (mirrored by `rust/src/runtime/manifest.rs`):

- ``<model>_train``: inputs = params ++ data, outputs = (loss, *grads).
- ``<model>_eval`` : inputs = params ++ data, outputs = (loss, correct)
  for classifiers, (loss,) for language models.
- ``powersgd_*``   : the L1 Pallas compression kernels exported as
  standalone artifacts for the XLA compression path
  (`--compress-exec xla`) and the Rust↔JAX differential tests.
"""

import jax.numpy as jnp

from .kernels import powersgd as pk
from .models.convnet import ConvNet
from .models.lstm import LstmLm
from .models.mlp import Mlp
from .models.transformer import PRESETS, TransformerLm
from .models import common


class ArtifactSpec:
    """Everything aot.py needs to lower + describe one artifact."""

    def __init__(self, name, fn, inputs, outputs, params=(), meta=None,
                 param_inits=None):
        self.name = name
        self.fn = fn
        self.inputs = inputs      # list[(name, shape, dtype_str)]
        self.outputs = outputs    # list[(name, shape, dtype_str)]
        self.params = list(params)
        self.param_inits = dict(param_inits or {})
        self.meta = dict(meta or {})


def _param_inputs(model):
    out = []
    for name, shape, _init in model.param_specs():
        out.append((name, shape, "f32"))
    return out


def _param_inits(model):
    """Concrete per-parameter init directives for the manifest: 'zero',
    'one', or 'normal:<sigma>'. The Rust trainer replays these exactly."""
    out = {}
    for n, _s, i in model.param_specs():
        out[n] = i if isinstance(i, str) else f"normal:{i:.6g}"
    return out


def model_artifacts(model, kind):
    """Train + eval artifacts for one model instance.

    kind: 'classifier' (eval → loss+correct) or 'lm' (eval → loss).
    """
    pspecs = model.param_specs()
    n_params = len(pspecs)
    param_inputs = _param_inputs(model)
    data_inputs = list(model.data_specs())
    eval_data_inputs = list(model.data_specs(eval=True))
    grads_out = [(f"grad.{n}", s, "f32") for n, s, _ in pspecs]

    train = ArtifactSpec(
        name=f"{model.name}_train",
        fn=common.train_step_fn(model.loss, n_params),
        inputs=param_inputs + data_inputs,
        outputs=[("loss", (), "f32")] + grads_out,
        params=[n for n, _, _ in pspecs],
        param_inits=_param_inits(model),
        meta={"model": model.name},
    )
    if kind == "classifier":
        eval_fn = common.eval_step_fn(model.loss, model.logits, n_params)
        eval_outputs = [("loss", (), "f32"), ("correct", (), "f32")]
    else:
        eval_fn = common.lm_eval_step_fn(model.loss, n_params)
        eval_outputs = [("loss", (), "f32")]
    evala = ArtifactSpec(
        name=f"{model.name}_eval",
        fn=eval_fn,
        inputs=param_inputs + eval_data_inputs,
        outputs=eval_outputs,
        params=[n for n, _, _ in pspecs],
        meta={"model": model.name},
    )
    return [train, evala]


def powersgd_kernel_artifacts(shapes=((64, 576), (512, 4608), (2600, 650)), ranks=(2, 4)):
    """Standalone compression artifacts over representative layer shapes
    from the paper's Tables 10/11 (plus a small one for tests)."""
    arts = []
    for (n, m) in shapes:
        for r in ranks:
            tag = f"{n}x{m}_r{r}"
            arts.append(
                ArtifactSpec(
                    name=f"powersgd_stage1_{tag}",
                    fn=lambda M, Q: (pk.matmul_mq(M, Q),),
                    inputs=[("m", (n, m), "f32"), ("q", (m, r), "f32")],
                    outputs=[("p", (n, r), "f32")],
                )
            )
            arts.append(
                ArtifactSpec(
                    name=f"powersgd_stage2_{tag}",
                    fn=lambda M, P: pk.powersgd_stage2(M, P),
                    inputs=[("m", (n, m), "f32"), ("p_mean", (n, r), "f32")],
                    outputs=[("p_hat", (n, r), "f32"), ("q", (m, r), "f32")],
                )
            )
            arts.append(
                ArtifactSpec(
                    name=f"powersgd_decompress_{tag}",
                    fn=lambda P, Q, D: pk.powersgd_decompress(P, Q, D),
                    inputs=[
                        ("p_hat", (n, r), "f32"),
                        ("q", (m, r), "f32"),
                        ("delta", (n, m), "f32"),
                    ],
                    outputs=[("m_hat", (n, m), "f32"), ("error", (n, m), "f32")],
                )
            )
    return arts


# ---------------------------------------------------------------------
# The artifact registry: name → builder. `aot.py --models a,b,c`.
# ---------------------------------------------------------------------

def registry():
    reg = {}

    reg["mlp"] = lambda: model_artifacts(Mlp(), "classifier")
    reg["convnet"] = lambda: model_artifacts(ConvNet(), "classifier")
    reg["lstm"] = lambda: model_artifacts(LstmLm(), "lm")
    for preset in PRESETS:
        reg[f"transformer_{preset}"] = (
            lambda p=preset: model_artifacts(_named_transformer(p), "lm")
        )
    reg["powersgd_kernels"] = powersgd_kernel_artifacts
    # small-shape kernel artifacts for fast integration tests
    reg["powersgd_kernels_small"] = lambda: powersgd_kernel_artifacts(
        shapes=((16, 10),), ranks=(2,)
    )
    return reg


def _named_transformer(preset):
    m = TransformerLm.preset(preset)
    m.name = f"transformer_{preset}"
    return m


DEFAULT_MODELS = ["mlp", "convnet", "lstm", "transformer_tiny", "powersgd_kernels_small"]
