"""L1 Pallas kernels for the PowerSGD compression hot-spot.

The paper's insight is that compression must cost no more than a couple
of *skinny GEMMs* (never an SVD). On TPU that maps to MXU work over
VMEM-resident tiles (DESIGN.md §Hardware-Adaptation):

- ``matmul_mq``   : P = M·Q.   M is streamed HBM→VMEM in row tiles via
  BlockSpec; Q (m×r, r ≤ 32 ⇒ ≤ a few hundred KiB) is pinned whole in
  VMEM for the duration of the kernel.
- ``matmul_mtp``  : Q = Mᵀ·P̂. Same streaming of M; accumulates the m×r
  result across row tiles through a VMEM accumulator (sequential grid).
- ``gram_schmidt``: orthonormalization of the n×r tall-skinny P — VPU
  work, single VMEM-resident block (n·r·4 ≤ 3.7 MiB for every layer in
  the paper).
- ``decompress_ef``: M̂ = P̂·Qᵀ fused with the error-feedback residual
  Δ − M̂, one pass over the output tile.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so the lowering path is interpret-mode
Pallas → plain HLO → ``artifacts/*.hlo.txt`` → Rust. Correctness is
pinned against ``ref.py`` by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height for streaming M. 128 matches the MXU systolic dimension
# and keeps a (128 × m) f32 tile ≤ 2.4 MiB for the paper's widest layer
# (m = 4608), comfortably inside a 16 MiB VMEM budget together with Q.
BLOCK_N = 128


def _row_grid(n):
    return (max(1, pl.cdiv(n, BLOCK_N)),)


def matmul_mq(m_mat, q):
    """P = M @ Q with M streamed in row tiles and Q VMEM-resident."""
    n, m = m_mat.shape
    m2, r = q.shape
    assert m == m2, f"inner dim mismatch {m} vs {m2}"
    bn = min(BLOCK_N, n)

    def kernel(m_ref, q_ref, o_ref):
        o_ref[...] = m_ref[...] @ q_ref[...]

    return pl.pallas_call(
        kernel,
        grid=_row_grid(n),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((m2, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), m_mat.dtype),
        interpret=True,
    )(m_mat, q)


def matmul_mtp(m_mat, p_hat):
    """Q = Mᵀ @ P̂ without materializing Mᵀ.

    The grid walks row tiles of M sequentially; each step accumulates its
    (m × r) partial product into the output block (revisited every step —
    Pallas guarantees sequential grid execution, so the accumulation is
    well-defined; this is the standard reduction-via-revisiting pattern).
    """
    n, m = m_mat.shape
    n2, r = p_hat.shape
    assert n == n2, f"inner dim mismatch {n} vs {n2}"
    bn = min(BLOCK_N, n)

    def kernel(m_ref, p_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        # Partial final tiles are padded by Pallas; mask the padded rows
        # out of the reduction (they would otherwise poison the sum).
        row = jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0) + pl.program_id(0) * bn
        mask = row < n
        mseg = jnp.where(mask, m_ref[...], 0.0)
        pseg = jnp.where(mask, p_ref[...], 0.0)
        o_ref[...] += mseg.T @ pseg

    return pl.pallas_call(
        kernel,
        grid=_row_grid(n),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((bn, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, r), m_mat.dtype),
        interpret=True,
    )(m_mat, p_hat)


def gram_schmidt(p, eps=1e-8):
    """Modified Gram–Schmidt over the columns of a VMEM-resident block.

    r is static and small (1–32), so the column loop is unrolled at trace
    time; each iteration is a VPU reduction + broadcast.
    """
    n, r = p.shape

    def kernel(p_ref, o_ref):
        cols = []
        for c in range(r):
            v = p_ref[:, c]
            for u in cols:
                v = v - jnp.dot(u, v) * u
            v = v / jnp.maximum(jnp.sqrt(jnp.sum(v * v)), eps)
            cols.append(v)
        o_ref[...] = jnp.stack(cols, axis=1)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, r), p.dtype),
        interpret=True,
    )(p)


def decompress(p_hat, q):
    """M̂ = P̂ @ Qᵀ, streaming output row tiles (P̂ rows ↔ M̂ rows)."""
    n, r = p_hat.shape
    m, r2 = q.shape
    assert r == r2
    bn = min(BLOCK_N, n)

    def kernel(p_ref, q_ref, o_ref):
        o_ref[...] = p_ref[...] @ q_ref[...].T

    return pl.pallas_call(
        kernel,
        grid=_row_grid(n),
        in_specs=[
            pl.BlockSpec((bn, r), lambda i: (i, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), p_hat.dtype),
        interpret=True,
    )(p_hat, q)


def decompress_ef(p_hat, q, delta):
    """Fused M̂ = P̂Qᵀ and error residual e = Δ − M̂ (one output pass)."""
    n, r = p_hat.shape
    m, _ = q.shape
    bn = min(BLOCK_N, n)

    def kernel(p_ref, q_ref, d_ref, mhat_ref, err_ref):
        mhat = p_ref[...] @ q_ref[...].T
        mhat_ref[...] = mhat
        err_ref[...] = d_ref[...] - mhat

    return pl.pallas_call(
        kernel,
        grid=_row_grid(n),
        in_specs=[
            pl.BlockSpec((bn, r), lambda i: (i, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), p_hat.dtype),
            jax.ShapeDtypeStruct((n, m), p_hat.dtype),
        ],
        interpret=True,
    )(p_hat, q, delta)


@functools.partial(jax.jit, static_argnames=())
def powersgd_stage1(m_mat, q):
    """Artifact body: P = M·Q (before the P all-reduce)."""
    return (matmul_mq(m_mat, q),)


@jax.jit
def powersgd_stage2(m_mat, p_mean):
    """Artifact body: P̂ = GS(P̄); Q = Mᵀ·P̂ (before the Q all-reduce)."""
    p_hat = gram_schmidt(p_mean)
    return p_hat, matmul_mtp(m_mat, p_hat)


@jax.jit
def powersgd_decompress(p_hat, q, delta):
    """Artifact body: M̂ = P̂Qᵀ and EF residual."""
    m_hat, err = decompress_ef(p_hat, q, delta)
    return m_hat, err


def vmem_footprint_bytes(n, m, r, dtype_bytes=4):
    """Estimated VMEM footprint of one ``matmul_mq`` grid step on TPU:
    M row tile + resident Q + output tile (DESIGN.md §Hardware-Adaptation;
    reported in EXPERIMENTS.md §Perf)."""
    bn = min(BLOCK_N, n)
    return dtype_bytes * (bn * m + m * r + bn * r)


def mxu_utilization_estimate(r):
    """Fraction of the 128-wide MXU tile the skinny GEMM keeps busy: the
    r output columns of a 128×128 systolic tile. Compression is
    HBM-bandwidth-bound by design, so this is expected to be low."""
    return min(r, 128) / 128.0
