"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every Pallas kernel in `powersgd.py` has an exact counterpart here; the
pytest suite asserts allclose between the two over a randomized sweep of
shapes, ranks and dtypes. These references are also what the L2 model
tests use to validate compression semantics end-to-end.
"""

import jax.numpy as jnp


def matmul_mq(m, q):
    """P = M @ Q  (PowerSGD stage 1: project onto the current subspace)."""
    return m @ q


def matmul_mtp(m, p_hat):
    """Q = M^T @ P_hat (PowerSGD stage 2: refresh the subspace)."""
    return m.T @ p_hat


def gram_schmidt(p, eps=1e-8):
    """Orthonormalize the columns of p (modified Gram-Schmidt).

    Matches the paper's ORTHOGONALIZE step. Columns with vanishing
    residual norm are left normalized-by-eps (the Rust side substitutes a
    random direction; for test inputs we avoid rank deficiency).
    """
    n, r = p.shape
    cols = []
    for c in range(r):
        v = p[:, c]
        for u in cols:
            v = v - jnp.dot(u, v) * u
        v = v / jnp.maximum(jnp.linalg.norm(v), eps)
        cols.append(v)
    return jnp.stack(cols, axis=1)


def decompress(p_hat, q):
    """M_hat = P_hat @ Q^T."""
    return p_hat @ q.T


def decompress_ef(p_hat, q, delta):
    """Reconstruct and compute the error-feedback residual.

    Returns (M_hat, delta - M_hat): the decompressed update and the error
    memory for the next step (Algorithm 2, line 9).
    """
    m_hat = p_hat @ q.T
    return m_hat, delta - m_hat


def powersgd_step(m, q):
    """One full (single-worker) PowerSGD compression round.

    Returns (m_hat, p_hat, q_new) — used by the differential tests
    against the Rust native implementation.
    """
    p = matmul_mq(m, q)
    p_hat = gram_schmidt(p)
    q_new = matmul_mtp(m, p_hat)
    return decompress(p_hat, q_new), p_hat, q_new
