"""LSTM language model — the WikiText-2 proxy (paper Table 7 / 11).

Mirrors the paper's architecture at reduced width: tied-free embedding,
stacked LSTM layers via `lax.scan`, linear decoder. The gradient
matricization produces the same shape family as Table 11 (a huge
`vocab×embed` encoder matrix dominating the communication volume, plus
`4h×h`-style recurrent matrices).
"""

import jax
import jax.numpy as jnp

from . import common


class LstmLm:
    name = "lstm"

    def __init__(self, vocab=1000, embed=64, hidden=128, layers=2, seq=32, batch=8):
        self.vocab, self.embed, self.hidden = vocab, embed, hidden
        self.layers, self.seq, self.batch = layers, seq, batch
        self.eval_batch = 16

    def param_specs(self):
        v, e, h = self.vocab, self.embed, self.hidden
        specs = [("encoder", (v, e), 0.05)]
        for l in range(self.layers):
            inp = e if l == 0 else h
            specs.append((f"rnn-ih-l{l}", (4 * h, inp), (1.0 / inp) ** 0.5))
            specs.append((f"rnn-hh-l{l}", (4 * h, h), (1.0 / h) ** 0.5))
            specs.append((f"rnn-b-l{l}", (4 * h,), "zero"))
        specs.append(("decoder", (h, v), (1.0 / h) ** 0.5))
        specs.append(("decoder-b", (v,), "zero"))
        return specs

    def data_specs(self, eval=False):
        b = self.eval_batch if eval else self.batch
        return [
            ("tokens", (b, self.seq), "i32"),
            ("targets", (b, self.seq), "i32"),
        ]

    def _unpack(self, params):
        encoder = params[0]
        layers = []
        for l in range(self.layers):
            layers.append(tuple(params[1 + 3 * l : 4 + 3 * l]))
        decoder, decoder_b = params[-2], params[-1]
        return encoder, layers, decoder, decoder_b

    def _lstm_layer(self, wih, whh, b, xs):
        """xs: [T, B, in] → [T, B, h] via lax.scan."""
        h = self.hidden
        b_sz = xs.shape[1]
        h0 = jnp.zeros((b_sz, h), xs.dtype)
        c0 = jnp.zeros((b_sz, h), xs.dtype)

        def cell(carry, x_t):
            h_prev, c_prev = carry
            gates = x_t @ wih.T + h_prev @ whh.T + b
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
            g = jnp.tanh(g)
            c = f * c_prev + i * g
            h_new = o * jnp.tanh(c)
            return (h_new, c), h_new

        _, ys = jax.lax.scan(cell, (h0, c0), xs)
        return ys

    def logits(self, params, tokens, targets=None):
        encoder, layers, decoder, decoder_b = self._unpack(params)
        x = encoder[tokens]  # [B, T, e]
        h = jnp.transpose(x, (1, 0, 2))  # [T, B, e]
        for wih, whh, b in layers:
            h = self._lstm_layer(wih, whh, b, h)
        h = jnp.transpose(h, (1, 0, 2))  # [B, T, h]
        return h @ decoder + decoder_b

    def loss(self, params, tokens, targets):
        return common.cross_entropy(self.logits(params, tokens), targets)
