"""Shared model plumbing for the L2 JAX model zoo.

Every model exposes:

- ``param_specs() -> list[(name, shape, init)]`` — ordered trainable
  parameters; ``init`` is 'he' (normal, sqrt(2/fan_in)), 'zero', or a
  float scale for plain normal.
- ``loss_fn(params: list[jnp.ndarray], *data) -> scalar`` — mean loss.
- ``data_specs(batch) -> list[(name, shape, dtype)]`` — per-step inputs.
- optionally ``eval_outputs(params, *data)`` — (loss, correct_count).

``train_step_fn`` wires loss + grads into the artifact calling
convention consumed by the Rust trainer: inputs = params ++ data,
outputs = (loss, *grads) in parameter order.
"""

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels):
    """Mean softmax cross-entropy; labels are int class ids."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def accuracy_count(logits, labels):
    """Number of correct argmax predictions, as f32."""
    pred = jnp.argmax(logits, axis=-1)
    return jnp.sum((pred == labels).astype(jnp.float32))


def train_step_fn(loss_fn, n_params):
    """Build f(*params, *data) -> (loss, *grads)."""

    def step(*args):
        params = list(args[:n_params])
        data = args[n_params:]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(ps, *data)
        )(params)
        return (loss, *grads)

    return step


def eval_step_fn(loss_fn, logits_fn, n_params):
    """Build f(*params, *data) -> (loss, correct_count)."""

    def step(*args):
        params = list(args[:n_params])
        data = args[n_params:]
        loss = loss_fn(params, *data)
        logits = logits_fn(params, *data)
        return (loss, accuracy_count(logits, data[-1].reshape(logits.shape[:-1])))

    return step


def lm_eval_step_fn(loss_fn, n_params):
    """Build f(*params, *data) -> (loss,) for perplexity reporting."""

    def step(*args):
        params = list(args[:n_params])
        data = args[n_params:]
        return (loss_fn(params, *data),)

    return step
