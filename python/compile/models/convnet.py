"""ResNet-style CNN classifier — the CIFAR10 proxy (paper Tables 1–6).

A scaled-down residual network with the same structural elements as the
paper's ResNet18 (3×3 convs, identity shortcuts, stride-2 stage
transitions with 1×1 projection shortcuts, global average pooling), so
its gradients matricize exactly like Table 10's rows. Sized for CPU
training on 3×16×16 Gaussian-mixture images.
"""

import jax
import jax.numpy as jnp

from . import common


def conv(x, w, stride=1):
    """NCHW 3×3/1×1 convolution with SAME padding."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


class ConvNet:
    """conv3×3(c) → block(c) → block(2c, stride 2) → pool → linear."""

    name = "convnet"

    def __init__(self, channels=16, classes=10, image=16, batch=32):
        self.c, self.classes, self.image, self.batch = channels, classes, image, batch
        self.eval_batch = 256

    def param_specs(self):
        c = self.c

        def he(shape):
            fan_in = 1
            for d in shape[1:]:
                fan_in *= d
            return (2.0 / fan_in) ** 0.5

        conv_shapes = {
            "conv1": (c, 3, 3, 3),
            "b1.conv1": (c, c, 3, 3),
            "b1.conv2": (c, c, 3, 3),
            "b2.conv1": (2 * c, c, 3, 3),
            "b2.conv2": (2 * c, 2 * c, 3, 3),
            "b2.shortcut": (2 * c, c, 1, 1),
        }
        return [
            ("conv1", (c, 3, 3, 3), he((c, 3, 3, 3))),
            # residual block 1 (c → c)
            ("b1.conv1", (c, c, 3, 3), he((c, c, 3, 3))),
            ("b1.conv2", (c, c, 3, 3), he((c, c, 3, 3))),
            # residual block 2 (c → 2c, stride 2, projection shortcut)
            ("b2.conv1", (2 * c, c, 3, 3), he((2 * c, c, 3, 3))),
            ("b2.conv2", (2 * c, 2 * c, 3, 3), he((2 * c, 2 * c, 3, 3))),
            ("b2.shortcut", (2 * c, c, 1, 1), he((2 * c, c, 1, 1))),
            ("linear", (2 * c, self.classes), (1.0 / (2 * c)) ** 0.5),
            ("bias", (self.classes,), "zero"),
        ]

    def data_specs(self, eval=False):
        b = self.eval_batch if eval else self.batch
        # Flat image vectors: the Rust data pipeline ships [B, 3·H·W] and
        # the model restores NCHW internally.
        return [
            ("x", (b, 3 * self.image * self.image), "f32"),
            ("y", (b,), "i32"),
        ]

    def logits(self, params, x, y=None):
        x = x.reshape(x.shape[0], 3, self.image, self.image)
        conv1, b1c1, b1c2, b2c1, b2c2, b2s, lin, bias = params
        h = jax.nn.relu(conv(x, conv1))
        # block 1
        r = jax.nn.relu(conv(h, b1c1))
        r = conv(r, b1c2)
        h = jax.nn.relu(h + r)
        # block 2 (downsample)
        r = jax.nn.relu(conv(h, b2c1, stride=2))
        r = conv(r, b2c2)
        s = conv(h, b2s, stride=2)
        h = jax.nn.relu(s + r)
        # global average pool → linear
        h = jnp.mean(h, axis=(2, 3))
        return h @ lin + bias

    def loss(self, params, x, y):
        return common.cross_entropy(self.logits(params, x), y)
