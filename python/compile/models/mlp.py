"""Two-layer MLP classifier — the quickstart workload.

Small enough to train in seconds on CPU; used by examples/quickstart.rs
and the trainer integration tests.
"""

import jax.numpy as jnp

from . import common


class Mlp:
    """dim → hidden (tanh) → classes."""

    name = "mlp"

    def __init__(self, dim=64, hidden=128, classes=10, batch=32):
        self.dim, self.hidden, self.classes, self.batch = dim, hidden, classes, batch
        self.eval_batch = 256

    def param_specs(self):
        return [
            ("w1", (self.dim, self.hidden), 1.0 / self.dim**0.5),
            ("b1", (self.hidden,), "zero"),
            ("w2", (self.hidden, self.classes), 1.0 / self.hidden**0.5),
            ("b2", (self.classes,), "zero"),
        ]

    def data_specs(self, eval=False):
        b = self.eval_batch if eval else self.batch
        return [
            ("x", (b, self.dim), "f32"),
            ("y", (b,), "i32"),
        ]

    def logits(self, params, x, y=None):
        w1, b1, w2, b2 = params
        h = jnp.tanh(x @ w1 + b1)
        return h @ w2 + b2

    def loss(self, params, x, y):
        return common.cross_entropy(self.logits(params, x), y)
