"""Decoder-only transformer LM — the Appendix-D workload and the
end-to-end validation driver (examples/train_transformer.rs).

Pre-norm GPT-style blocks: causal multi-head attention + GELU MLP, with
learned positional embeddings and an untied output projection. Presets
scale from CPU-friendly smoke sizes up to the ~100M-parameter
configuration the e2e driver can select with `--preset 100m`.
"""

import jax
import jax.numpy as jnp

from . import common

PRESETS = {
    # name: (vocab, d_model, heads, layers, ffn_mult, seq, batch/worker)
    "tiny": dict(vocab=2000, d=128, heads=4, layers=2, ffn=4, seq=64, batch=8),
    "small": dict(vocab=4000, d=256, heads=8, layers=4, ffn=4, seq=128, batch=8),
    "25m": dict(vocab=8000, d=512, heads=8, layers=6, ffn=4, seq=128, batch=4),
    "100m": dict(vocab=16000, d=768, heads=12, layers=12, ffn=4, seq=256, batch=2),
}


class TransformerLm:
    name = "transformer"

    def __init__(self, vocab=2000, d=128, heads=4, layers=2, ffn=4, seq=64, batch=8):
        assert d % heads == 0
        self.vocab, self.d, self.heads = vocab, d, heads
        self.layers, self.ffn, self.seq, self.batch = layers, ffn, seq, batch
        self.eval_batch = 16

    @classmethod
    def preset(cls, name):
        return cls(**PRESETS[name])

    def n_params(self):
        d, f = self.d, self.ffn * self.d
        per_layer = 4 * d * d + 2 * d * f + 2 * d + 2 * d + d + f
        return self.vocab * d * 2 + self.seq * d + self.layers * per_layer

    def param_specs(self):
        d, f = self.d, self.ffn * self.d
        specs = [
            ("embed", (self.vocab, d), 0.02),
            ("pos", (self.seq, d), 0.02),
        ]
        for l in range(self.layers):
            specs += [
                (f"l{l}.ln1", (d,), "one"),
                (f"l{l}.qkv", (d, 3 * d), (1.0 / d) ** 0.5),
                (f"l{l}.attn_out", (d, d), (1.0 / d) ** 0.5 / (2.0 * self.layers) ** 0.5),
                (f"l{l}.ln2", (d,), "one"),
                (f"l{l}.ffn_w1", (d, f), (2.0 / d) ** 0.5),
                (f"l{l}.ffn_b1", (f,), "zero"),
                (f"l{l}.ffn_w2", (f, d), (1.0 / f) ** 0.5 / (2.0 * self.layers) ** 0.5),
                (f"l{l}.ffn_b2", (d,), "zero"),
            ]
        specs += [
            ("ln_f", (d,), "one"),
            ("unembed", (d, self.vocab), (1.0 / d) ** 0.5),
        ]
        return specs

    def data_specs(self, eval=False):
        b = self.eval_batch if eval else self.batch
        return [
            ("tokens", (b, self.seq), "i32"),
            ("targets", (b, self.seq), "i32"),
        ]

    @staticmethod
    def _layernorm(x, scale):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + 1e-5) * scale

    def _block(self, x, p, mask):
        ln1, qkv, attn_out, ln2, w1, b1, w2, b2 = p
        b_sz, t, d = x.shape
        h = self.heads
        hd = d // h
        # attention
        y = self._layernorm(x, ln1)
        qkv_out = y @ qkv  # [B,T,3d]
        q, k, v = jnp.split(qkv_out, 3, axis=-1)
        q = q.reshape(b_sz, t, h, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b_sz, t, h, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b_sz, t, h, hd).transpose(0, 2, 1, 3)
        att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
        att = jnp.where(mask, att, jnp.float32(-1e9))
        att = jax.nn.softmax(att, axis=-1)
        y = (att @ v).transpose(0, 2, 1, 3).reshape(b_sz, t, d)
        x = x + y @ attn_out
        # mlp
        y = self._layernorm(x, ln2)
        y = jax.nn.gelu(y @ w1 + b1)
        x = x + (y @ w2 + b2)
        return x

    def logits(self, params, tokens, targets=None):
        embed, pos = params[0], params[1]
        per_layer = 8
        blocks = [
            tuple(params[2 + l * per_layer : 2 + (l + 1) * per_layer])
            for l in range(self.layers)
        ]
        ln_f, unembed = params[-2], params[-1]
        b_sz, t = tokens.shape
        x = embed[tokens] + pos[None, :t, :]
        mask = jnp.tril(jnp.ones((t, t), bool))[None, None, :, :]
        for p in blocks:
            x = self._block(x, p, mask)
        x = self._layernorm(x, ln_f)
        return x @ unembed

    def loss(self, params, tokens, targets):
        return common.cross_entropy(self.logits(params, tokens), targets)
