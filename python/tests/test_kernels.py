"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

The shape/rank sweep is randomized but seeded (hypothesis-style property
coverage without the dependency): shapes include non-divisible-by-block
sizes, rank-1 edges and the paper's real layer shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import powersgd as K
from compile.kernels import ref as R

# (n, m) sweep: tiny, non-divisible, block-aligned, paper Table 10/11 rows
SHAPES = [
    (1, 1),
    (3, 7),
    (16, 10),
    (64, 576),
    (128, 64),
    (300, 200),
    (513, 131),
    (2600, 650),
]
RANKS = [1, 2, 4, 7]


def _rand(shape, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.mark.parametrize("n,m", SHAPES)
@pytest.mark.parametrize("r", RANKS)
def test_matmul_mq_matches_ref(n, m, r):
    if r > min(n, m):
        pytest.skip("rank exceeds dims")
    M = _rand((n, m), seed=n * 1000 + m)
    Q = _rand((m, r), seed=r)
    np.testing.assert_allclose(
        K.matmul_mq(M, Q), R.matmul_mq(M, Q), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("n,m", SHAPES)
@pytest.mark.parametrize("r", RANKS)
def test_matmul_mtp_matches_ref(n, m, r):
    if r > min(n, m):
        pytest.skip("rank exceeds dims")
    M = _rand((n, m), seed=n + m)
    P = _rand((n, r), seed=r + 1)
    np.testing.assert_allclose(
        K.matmul_mtp(M, P), R.matmul_mtp(M, P), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("n", [4, 33, 128, 513, 2600])
@pytest.mark.parametrize("r", RANKS)
def test_gram_schmidt_matches_ref_and_is_orthonormal(n, r):
    if r > n:
        pytest.skip("rank exceeds dims")
    P = _rand((n, r), seed=n * 7 + r)
    got = K.gram_schmidt(P)
    np.testing.assert_allclose(got, R.gram_schmidt(P), rtol=2e-4, atol=2e-4)
    gram = np.asarray(got.T @ got)
    np.testing.assert_allclose(gram, np.eye(r), atol=2e-4)


@pytest.mark.parametrize("n,m", [(16, 10), (300, 200), (513, 131)])
@pytest.mark.parametrize("r", [1, 2, 4])
def test_decompress_ef_matches_ref(n, m, r):
    P = _rand((n, r), seed=1)
    Q = _rand((m, r), seed=2)
    D = _rand((n, m), seed=3)
    mh, err = K.decompress_ef(P, Q, D)
    rm, re = R.decompress_ef(P, Q, D)
    np.testing.assert_allclose(mh, rm, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(err, re, rtol=2e-5, atol=2e-5)
    # EF identity: reconstruction + error == delta
    np.testing.assert_allclose(np.asarray(mh) + np.asarray(err), D, rtol=1e-4, atol=1e-4)


def test_full_powersgd_step_low_rank_and_convergence():
    """Warm-started repeated steps on a fixed matrix approach the best
    rank-r approximation (paper Theorem I)."""
    M = _rand((40, 25), seed=11)
    r = 2
    Q = _rand((25, r), seed=12)
    for _ in range(40):
        m_hat, p_hat, Q = R.powersgd_step(M, Q)
    # compare against SVD truncation
    u, s, vt = np.linalg.svd(np.asarray(M), full_matrices=False)
    best = (u[:, :r] * s[:r]) @ vt[:r]
    err_power = np.linalg.norm(np.asarray(M) - np.asarray(m_hat))
    err_best = np.linalg.norm(np.asarray(M) - best)
    assert abs(err_power - err_best) / err_best < 0.02


def test_kernel_powersgd_step_matches_ref_step():
    """The Pallas kernels compose to the same step as the jnp reference."""
    M = _rand((64, 40), seed=21)
    Q0 = _rand((40, 2), seed=22)
    p = K.matmul_mq(M, Q0)
    p_hat = K.gram_schmidt(p)
    q = K.matmul_mtp(M, p_hat)
    m_hat, _err = K.decompress_ef(p_hat, q, M)
    ref_m_hat, _, _ = R.powersgd_step(M, Q0)
    np.testing.assert_allclose(m_hat, ref_m_hat, rtol=2e-3, atol=2e-3)


def test_randomized_property_sweep():
    """Seeded random shapes (hypothesis-style): M·Q then decompress must
    equal the rank-r projection of M onto span(Q̂) columns."""
    rng = np.random.default_rng(99)
    for _ in range(25):
        n = int(rng.integers(2, 200))
        m = int(rng.integers(2, 200))
        r = int(rng.integers(1, min(n, m, 8) + 1))
        M = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
        Q = jnp.asarray(rng.normal(size=(m, r)), jnp.float32)
        np.testing.assert_allclose(
            K.matmul_mq(M, Q), np.asarray(M) @ np.asarray(Q), rtol=1e-3, atol=1e-3
        )


def test_vmem_and_mxu_estimates():
    """Hardware-adaptation bookkeeping stays within the TPU budget for
    every layer shape in the paper (DESIGN.md §Hardware-Adaptation)."""
    VMEM = 16 * 1024 * 1024
    for n, m in SHAPES:
        for r in (1, 2, 4, 32):
            assert K.vmem_footprint_bytes(n, m, r) < VMEM
    assert K.mxu_utilization_estimate(4) == 4 / 128
    assert K.mxu_utilization_estimate(256) == 1.0
