"""AOT pipeline tests: manifest rendering, HLO lowering, registry."""

import os

import pytest

from compile import aot
from compile import model as model_registry
from compile.model import ArtifactSpec, model_artifacts, powersgd_kernel_artifacts
from compile.models.mlp import Mlp


def test_manifest_text_format():
    spec = ArtifactSpec(
        name="demo",
        fn=lambda x: (x,),
        inputs=[("x", (2, 3), "f32"), ("y", (4,), "i32"), ("s", (), "f32")],
        outputs=[("loss", (), "f32")],
        params=["x"],
        param_inits={"x": "normal:0.1"},
        meta={"k": "v"},
    )
    text = aot.manifest_text(spec)
    lines = text.strip().splitlines()
    assert lines[0] == "artifact demo"
    assert "input x f32 2,3" in lines
    assert "input y i32 4" in lines
    assert "input s f32 -" in lines
    assert "output loss f32 -" in lines
    assert "param x normal:0.1" in lines
    assert "meta k v" in lines


def test_model_artifacts_cover_all_params():
    arts = model_artifacts(Mlp(), "classifier")
    assert [a.name for a in arts] == ["mlp_train", "mlp_eval"]
    train = arts[0]
    # outputs = loss + one grad per param, shapes matching
    pspecs = Mlp().param_specs()
    assert len(train.outputs) == 1 + len(pspecs)
    for (gname, gshape, _), (pname, pshape, _) in zip(train.outputs[1:], pspecs):
        assert gname == f"grad.{pname}"
        assert tuple(gshape) == tuple(pshape)
    # every param has an init directive
    assert set(train.params) == set(train.param_inits)


def test_lowering_produces_hlo_text():
    arts = model_artifacts(Mlp(), "classifier")
    text = aot.to_hlo_text(arts[0].fn, arts[0].inputs)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_kernel_artifacts_shapes():
    arts = powersgd_kernel_artifacts(shapes=((8, 5),), ranks=(2,))
    names = [a.name for a in arts]
    assert names == [
        "powersgd_stage1_8x5_r2",
        "powersgd_stage2_8x5_r2",
        "powersgd_decompress_8x5_r2",
    ]
    s2 = arts[1]
    assert s2.outputs[0][1] == (8, 2)   # p_hat
    assert s2.outputs[1][1] == (5, 2)   # q


def test_registry_keys():
    reg = model_registry.registry()
    for key in model_registry.DEFAULT_MODELS:
        assert key in reg
    assert "transformer_100m" in reg


def test_build_writes_and_caches(tmp_path):
    arts = powersgd_kernel_artifacts(shapes=((4, 3),), ranks=(1,))
    aot.build(arts[0], str(tmp_path))
    hlo = tmp_path / f"{arts[0].name}.hlo.txt"
    man = tmp_path / f"{arts[0].name}.manifest"
    assert hlo.exists() and man.exists()
    mtime = os.path.getmtime(hlo)
    aot.build(arts[0], str(tmp_path))  # cached: no rewrite
    assert os.path.getmtime(hlo) == mtime
    aot.build(arts[0], str(tmp_path), force=True)
    assert os.path.getmtime(hlo) >= mtime


def test_default_artifacts_exist_after_make():
    """If `make artifacts` has run, the default set must be complete."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(art_dir):
        pytest.skip("artifacts/ not built yet")
    for model in ["mlp", "convnet", "lstm", "transformer_tiny"]:
        for suffix in ["train", "eval"]:
            for ext in ["hlo.txt", "manifest"]:
                path = os.path.join(art_dir, f"{model}_{suffix}.{ext}")
                assert os.path.exists(path), path
