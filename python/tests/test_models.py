"""L2 model sanity: shapes, losses, gradients for every model in the zoo."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models.common import train_step_fn, eval_step_fn, cross_entropy
from compile.models.convnet import ConvNet
from compile.models.lstm import LstmLm
from compile.models.mlp import Mlp
from compile.models.transformer import TransformerLm

INITS = {"zero": lambda s: jnp.zeros(s), "one": lambda s: jnp.ones(s)}


def init_params(model, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _name, shape, init in model.param_specs():
        if isinstance(init, str):
            out.append(INITS[init](shape))
        else:
            out.append(jnp.asarray(rng.normal(size=shape) * init, jnp.float32))
    return out


def make_data(model, seed=0):
    rng = np.random.default_rng(seed)
    data = []
    for _name, shape, dt in model.data_specs():
        if dt == "f32":
            data.append(jnp.asarray(rng.normal(size=shape), jnp.float32))
        else:
            hi = getattr(model, "vocab", getattr(model, "classes", 2))
            data.append(jnp.asarray(rng.integers(0, hi, size=shape), jnp.int32))
    return data


MODELS = [
    Mlp(),
    ConvNet(),
    LstmLm(vocab=200, embed=16, hidden=24, layers=1, seq=8, batch=2),
    TransformerLm(vocab=100, d=32, heads=2, layers=1, seq=8, batch=2),
]


@pytest.mark.parametrize("model", MODELS, ids=lambda m: m.name)
def test_loss_is_finite_and_near_uniform_at_init(model):
    params = init_params(model)
    data = make_data(model)
    loss = model.loss(params, *data)
    assert np.isfinite(float(loss))
    n_out = getattr(model, "vocab", getattr(model, "classes", None))
    # at (near-)random init, loss ≈ ln(n_classes or vocab)
    assert float(loss) < np.log(n_out) * 2.0 + 1.0


@pytest.mark.parametrize("model", MODELS, ids=lambda m: m.name)
def test_train_step_emits_loss_plus_all_grads(model):
    params = init_params(model)
    data = make_data(model)
    step = train_step_fn(model.loss, len(params))
    outs = step(*params, *data)
    assert len(outs) == 1 + len(params)
    for g, p in zip(outs[1:], params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()
    # at least one gradient strictly nonzero
    assert any(float(jnp.abs(g).max()) > 0 for g in outs[1:])


@pytest.mark.parametrize("model", MODELS, ids=lambda m: m.name)
def test_sgd_descends(model):
    params = init_params(model)
    data = make_data(model)
    step = train_step_fn(model.loss, len(params))
    loss0 = float(model.loss(params, *data))
    lr = 0.1
    for _ in range(20):
        outs = step(*params, *data)
        params = [p - lr * g for p, g in zip(params, outs[1:])]
    loss1 = float(model.loss(params, *data))
    assert loss1 < loss0, f"{model.name}: {loss0} -> {loss1}"


def test_eval_step_counts_correct():
    model = Mlp()
    params = init_params(model)
    data = make_data(model, seed=1)
    ev = eval_step_fn(model.loss, model.logits, len(params))
    loss, correct = ev(*params, *data)
    b = model.eval_batch
    assert 0 <= float(correct) <= b
    assert np.isfinite(float(loss))


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0], [0.0, 2.0]])
    labels = jnp.asarray([0, 0])
    got = float(cross_entropy(logits, labels))
    p0 = np.exp(2.0) / (np.exp(2.0) + 1.0)
    want = -(np.log(p0) + np.log(1 - p0)) / 2
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_transformer_causality():
    """Changing a future token must not affect earlier logits."""
    model = TransformerLm(vocab=50, d=32, heads=2, layers=1, seq=8, batch=1)
    params = init_params(model, seed=3)
    rng = np.random.default_rng(4)
    toks = rng.integers(0, 50, size=(1, 8))
    a = np.asarray(model.logits(params, jnp.asarray(toks, jnp.int32)))
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 7) % 50
    b = np.asarray(model.logits(params, jnp.asarray(toks2, jnp.int32)))
    np.testing.assert_allclose(a[0, :-1], b[0, :-1], atol=1e-5)
    assert np.abs(a[0, -1] - b[0, -1]).max() > 1e-6


def test_transformer_param_count_presets():
    t = TransformerLm.preset("100m")
    n = sum(int(np.prod(s)) for _, s, _ in t.param_specs())
    assert 80e6 < n < 130e6, f"100m preset has {n/1e6:.1f}M params"
    tiny = TransformerLm.preset("tiny")
    n = sum(int(np.prod(s)) for _, s, _ in tiny.param_specs())
    assert n < 2e6
