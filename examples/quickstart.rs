//! Quickstart: train a small classifier on 2 simulated workers with
//! rank-2 PowerSGD and compare the bytes on the wire against plain SGD.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! For the *multi-process* quickstart — W real OS processes over a
//! localhost TCP ring, verified bitwise against the in-process oracle
//! (DESIGN.md §10) — no artifacts are needed:
//!
//! ```text
//! cargo run --release -- launch --workers 4 --transport tcp --compressor powersgd --rank 2
//! ```
//!
//! Add `--threads N` (or set `POWERSGD_THREADS`) to any subcommand to
//! fan the compression kernels (GEMMs + Gram–Schmidt) out over the
//! kernel pool (DESIGN.md §11). Results are bitwise identical at every
//! thread count, so this is purely a wall-clock knob — and it composes
//! with `--engine threaded` / `launch`: W workers × N kernel threads.

use anyhow::Result;
use powersgd::compress::PowerSgd;
use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::Classification;
use powersgd::optim::{EfSgd, LrSchedule};
use powersgd::runtime::Runtime;

fn main() -> Result<()> {
    // 1. Load the AOT-compiled model (lowered once by `make artifacts`;
    //    no Python anywhere in this process).
    let mut rt = Runtime::cpu("artifacts")?;
    let train = rt.load("mlp_train")?;
    let eval = rt.load("mlp_eval")?;

    // 2. PowerSGD rank-2 compression inside error-feedback SGD
    //    (Algorithms 1 + 2 of the paper).
    let compressor = Box::new(PowerSgd::new(2, /*seed=*/ 1));
    let opt = Box::new(EfSgd::new(compressor, LrSchedule::constant(0.05), 0.9));

    // 3. Two simulated workers, NCCL-like network model.
    let cfg = TrainerConfig {
        workers: 2,
        eval_every: 50,
        eval_kind: EvalKind::Accuracy,
        log_every: 25,
        ..Default::default()
    };
    let mut data = Classification::new(64, 10, 32, 2, 42);
    let mut trainer = Trainer::new(train, Some(eval), opt, cfg)?;

    trainer.train(&mut data, 200)?;

    let full = trainer.registry().total_bytes();
    let sent = trainer.metrics.total_bytes() / 200;
    println!("\n--- quickstart summary ---");
    println!("test accuracy:        {:.1}%", trainer.evaluate(&mut data)?);
    println!("gradient size:        {full} bytes/step");
    println!("transmitted:          {sent} bytes/step ({:.0}x compression)", full as f64 / sent as f64);
    println!("loss (mean last 10):  {:.4}", trainer.metrics.mean_loss_last(10));
    Ok(())
}
