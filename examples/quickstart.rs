//! Quickstart: a narrated walkthrough of the three ways to drive this
//! reproduction, smallest first. Linked from `powersgd --help`.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Part 1 needs nothing but the crate: it runs a miniature
//! `scheme-compare` experiment scenario on the calibrated simulator and
//! prints the paper-style table (the full version is
//! `powersgd experiment --suite scheme-compare`, which also writes
//! `EXPERIMENTS_scheme-compare.json` and the deterministic `REPORT.md`).
//!
//! Part 2 runs a *real* threaded-engine round: per-worker compression
//! over a metered in-process ring, measured wire bytes cross-checked
//! against the analytic model and the final parameters verified bitwise
//! against the centralized lockstep oracle — the in-process twin of
//!
//! ```text
//! cargo run --release -- launch --workers 4 --transport tcp --compressor powersgd --rank 2
//! ```
//!
//! Part 3 trains a small classifier on 2 simulated workers with rank-2
//! PowerSGD; it needs the AOT-compiled artifacts (`make artifacts`) and
//! is skipped with a note when they are absent, so this example always
//! runs to completion.
//!
//! Add `--threads N` (or set `POWERSGD_THREADS`) to any subcommand to
//! fan the compression kernels (GEMMs + Gram–Schmidt) out over the
//! kernel pool (DESIGN.md §11). Results are bitwise identical at every
//! thread count, so this is purely a wall-clock knob — and it composes
//! with `--engine threaded` / `launch`: W workers × N kernel threads.
//! The kernels are the blocked SIMD backend by default; setting
//! `POWERSGD_KERNEL_BACKEND=reference` swaps in the naive reference
//! kernels (for differential testing — much slower, same invariance).
//!
//! Add `--pipeline overlap` to `train`/`launch` to post the vector
//! all-reduce early and drain it behind the factor collectives
//! (DESIGN.md §14) — traffic is reordered, bits are not, so results
//! stay bitwise identical to `--pipeline off`. `--pipeline delayed`
//! applies the previous step's aggregate instead (the PyTorch DDP
//! PowerSGD-hook trick); it trades one step of staleness for a fully
//! hidden collective and is verified against a delayed oracle.
//!
//! Add `--trace TRACE.json` to any subcommand to record the run with
//! the span recorder (DESIGN.md §13) and open the file at
//! <https://ui.perfetto.dev>: one track per worker and ring thread,
//! phase-tagged spans from gradient to decompress. `launch` writes
//! per-rank `TRACE_r<k>.json` parts and merges them into one timeline.
//! Tracing never changes computed values — traced runs stay bitwise
//! identical to untraced ones.
//!
//! Add `--metrics METRICS.json` to any subcommand to snapshot the
//! crate-wide run-health registry (DESIGN.md §15): counters, quality
//! gauges (EF residual, low-rank approximation error, compression
//! ratio, delayed staleness) and fixed-bucket histograms — one relaxed
//! atomic load per site when off, and like tracing it never changes
//! computed values. `launch --metrics` additionally streams per-step
//! frames from every worker over the control connection, writes
//! per-rank `METRICS_r<k>.jsonl`, and merges a cluster-health summary
//! (median/p95 step times, straggler flags, dead-peer tolerant) whose
//! wire bytes reconcile *exactly* with the metered transport. And
//! `powersgd bench-diff OLD.json NEW.json` compares two `BENCH_*.json`
//! documents with tolerance thresholds and a markdown delta table —
//! the CI bench regression gate.
//!
//! Add `--elastic` to `launch` for epoch-based elastic membership
//! (DESIGN.md §16): workers heartbeat at every step boundary, a
//! crashed or hung worker is detected (control-socket EOF or
//! `--heartbeat-ms` timeout), and the survivors re-form the ring at
//! W−1 and keep training — their own error-feedback residuals intact,
//! the departed rank's dropped. `--join-at-step K` admits one extra
//! worker mid-run. A stable-membership elastic run is bitwise
//! identical to the plain lockstep oracle; churned runs verify against
//! a composed per-epoch oracle (or member-consistency where replay
//! does not apply — see the §16 table). Try the whole failure path in
//! one line with deterministic fault injection:
//!
//! ```text
//! cargo run --release -- launch --workers 4 --elastic --fail-rank 2 --fail-at-step 1
//! ```

use anyhow::Result;
use powersgd::compress::PowerSgd;
use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::Classification;
use powersgd::experiments::{
    measured_metrics_check, measured_wire_check, measured_wire_check_pipelined, run_scenario,
    scenarios_for,
};
use powersgd::obs::metrics::{Counter, Gauge};
use powersgd::obs::Phase;
use powersgd::optim::{EfSgd, LrSchedule};
use powersgd::runtime::Runtime;
use powersgd::transport::PipelineMode;
use powersgd::util::Table;

fn main() -> Result<()> {
    // ------------------------------------------------------------------
    // Part 1 — a miniature scheme-compare scenario (pure simulator).
    //
    // The experiment registry names every scenario `powersgd experiment`
    // can run; here we evaluate just its quick tier for ResNet18 and
    // print the Table 4-style rows ourselves.
    // ------------------------------------------------------------------
    let mut table = Table::new(
        "Miniature scheme-compare (ResNet18, 16 workers, NCCL)",
        &["Scenario", "Msg bytes/step", "Data/epoch", "Time/batch", "Speedup vs 1x SGD"],
    );
    for spec in scenarios_for("scheme-compare", /*quick=*/ true) {
        if spec.profile != "resnet18" {
            continue;
        }
        let record = run_scenario(&spec)?;
        let metric = |key: &str| {
            record.metrics.iter().find(|(k, _)| *k == key).map(|(_, v)| *v).unwrap_or(f64::NAN)
        };
        table.row(&[
            record.name.clone(),
            format!("{}", metric("msg_bytes") as u64),
            format!("{:.1} MiB", metric("data_epoch_mb")),
            format!("{:.0} ms", metric("total_ms")),
            format!("{:.1}x", metric("speedup_vs_single_sgd")),
        ]);
    }
    table.print();
    println!();

    // ------------------------------------------------------------------
    // Part 2 — one real threaded-engine run with measured wire bytes.
    // ------------------------------------------------------------------
    let wire = measured_wire_check("powersgd", 2, /*workers=*/ 2, /*steps=*/ 2, /*seed=*/ 42)?;
    for r in &wire.per_rank {
        println!(
            "rank {}: measured {} wire bytes == analytic {} (logical {}, bitwise vs oracle)",
            r.rank, r.measured, r.analytic, r.logical
        );
    }
    // The same run was captured by the span recorder (DESIGN.md §13):
    // per-phase counts are deterministic for the workload. Add
    // `--trace TRACE.json` to any CLI run for the Perfetto timeline.
    println!(
        "spans: {} compress, {} collective, {} ring sends on tracks {:?}",
        wire.spans.count(Phase::Compress),
        wire.spans.count(Phase::Collective),
        wire.spans.count(Phase::RingSend),
        wire.spans.tracks
    );
    // The same workload under `--pipeline overlap`: identical bytes and
    // bits (the check verifies both), but collectives are posted early —
    // the in-flight spans are the communication the schedule hides.
    let overlapped =
        measured_wire_check_pipelined("powersgd", 2, 2, 2, 42, PipelineMode::Overlap)?;
    println!(
        "overlap: same {} wire bytes, {} in-flight collectives posted",
        overlapped.per_rank.iter().map(|r| r.measured).sum::<u64>(),
        overlapped.spans.count(Phase::InFlight),
    );
    // The same engine with the run-health registry on (DESIGN.md §15):
    // the wire-byte counter covers the metered traffic and the quality
    // gauges carry the last compression round. `--metrics METRICS.json`
    // snapshots this on any CLI run; `launch --metrics` merges per-rank
    // streams into the cluster-health summary instead.
    let health = measured_metrics_check(42, /*quick=*/ true)?;
    println!(
        "metrics: {} wire bytes counted across {} compress rounds, approx error {:.3}",
        health.delta.counter(Counter::WireSentBytes),
        health.delta.counter(Counter::CompressRounds),
        health.delta.gauge(Gauge::ApproxError),
    );
    println!();

    // ------------------------------------------------------------------
    // Part 3 — train a small classifier end-to-end (needs artifacts).
    // ------------------------------------------------------------------
    let mut rt = match Runtime::cpu("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping the training walkthrough (no PJRT runtime: {e})");
            println!("run `make artifacts` first to enable it");
            return Ok(());
        }
    };
    let (train, eval) = match (rt.load("mlp_train"), rt.load("mlp_eval")) {
        (Ok(t), Ok(e)) => (t, e),
        _ => {
            println!("skipping the training walkthrough (mlp artifacts not found)");
            println!("run `make artifacts` first to enable it");
            return Ok(());
        }
    };

    // PowerSGD rank-2 compression inside error-feedback SGD
    // (Algorithms 1 + 2 of the paper), two simulated workers.
    let compressor = Box::new(PowerSgd::new(2, /*seed=*/ 1));
    let opt = Box::new(EfSgd::new(compressor, LrSchedule::constant(0.05), 0.9));
    let cfg = TrainerConfig {
        workers: 2,
        eval_every: 50,
        eval_kind: EvalKind::Accuracy,
        log_every: 25,
        ..Default::default()
    };
    let mut data = Classification::new(64, 10, 32, 2, 42);
    let mut trainer = Trainer::new(train, Some(eval), opt, cfg)?;

    trainer.train(&mut data, 200)?;

    let full = trainer.registry().total_bytes();
    let sent = trainer.metrics.total_bytes() / 200;
    println!("\n--- quickstart summary ---");
    println!("test accuracy:        {:.1}%", trainer.evaluate(&mut data)?);
    println!("gradient size:        {full} bytes/step");
    println!(
        "transmitted:          {sent} bytes/step ({:.0}x compression)",
        full as f64 / sent as f64
    );
    println!("loss (mean last 10):  {:.4}", trainer.metrics.mean_loss_last(10));
    Ok(())
}
