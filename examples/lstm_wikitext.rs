//! WikiText-proxy language modeling (paper Table 7): an LSTM LM over a
//! Zipf corpus on 4 simulated workers — SGD vs Signum vs rank-4
//! PowerSGD, reporting perplexity and communication volume, plus the
//! paper-scale LSTM timing simulation.
//!
//! ```text
//! make artifacts && cargo run --release --example lstm_wikitext
//! ```

use anyhow::Result;
use powersgd::compress::PowerSgd;
use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::LmCorpus;
use powersgd::net::NCCL;
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule, Sgd, SignumOpt};
use powersgd::profiles::lstm_wikitext2;
use powersgd::runtime::Runtime;
use powersgd::simulate::{data_per_epoch_mb, simulate_step, Scheme};
use powersgd::util::Table;

const STEPS: usize = 150;
const WORKERS: usize = 4;

fn run(opt: Box<dyn DistOptimizer>) -> Result<(f64, u64)> {
    let mut rt = Runtime::cpu("artifacts")?;
    let train = rt.load("lstm_train")?;
    let eval = rt.load("lstm_eval")?;
    let cfg = TrainerConfig {
        workers: WORKERS,
        eval_kind: EvalKind::Perplexity,
        ..Default::default()
    };
    let mut data = LmCorpus::new(1000, 8, 32, WORKERS, 42);
    let mut trainer = Trainer::new(train, Some(eval), opt, cfg)?;
    trainer.train(&mut data, STEPS)?;
    let ppl = trainer.evaluate(&mut data)?;
    Ok((ppl, trainer.metrics.total_bytes() / STEPS as u64))
}

fn main() -> Result<()> {
    let mut table = Table::new(
        "LSTM / WikiText-proxy — 4 workers, 150 steps (cf. paper Table 7)",
        &["Algorithm", "Test perplexity", "Bytes/step", "Compression"],
    );
    // Signum needs its own (much smaller) LR — paper Appendix I.
    let cases: Vec<(String, Box<dyn DistOptimizer>)> = vec![
        ("SGD".into(), Box::new(Sgd::new(LrSchedule::constant(0.5), 0.9))),
        ("Signum".into(), Box::new(SignumOpt::new(LrSchedule::constant(0.005), 0.9))),
        (
            "Rank 4".into(),
            Box::new(EfSgd::new(Box::new(PowerSgd::new(4, 1)), LrSchedule::constant(0.5), 0.9)),
        ),
    ];
    let mut full_bytes = 0u64;
    for (name, opt) in cases {
        let (ppl, bytes) = run(opt)?;
        if name == "SGD" {
            full_bytes = bytes;
        }
        table.row(&[
            name,
            format!("{ppl:.1}"),
            format!("{bytes}"),
            format!("{:.0}x", full_bytes as f64 / bytes as f64),
        ]);
    }
    table.print();

    // Paper-scale timing over the exact Table 11 shapes.
    let p = lstm_wikitext2();
    let mut sim = Table::new(
        "Simulated paper-scale LSTM/WikiText-2 — 16 workers, NCCL",
        &["Algorithm", "Data/epoch", "Time/batch", "vs SGD"],
    );
    let sgd_total = simulate_step(&p, Scheme::Sgd, 16, &NCCL).total();
    for scheme in [Scheme::Sgd, Scheme::Signum, Scheme::PowerSgd { rank: 4 }] {
        let b = simulate_step(&p, scheme, 16, &NCCL);
        sim.row(&[
            scheme.name(),
            format!("{:.0} MB", data_per_epoch_mb(&p, scheme)),
            format!("{:.0} ms", b.total() * 1e3),
            format!("{:+.0}%", (b.total() / sgd_total - 1.0) * 100.0),
        ]);
    }
    sim.print();
    Ok(())
}
