//! CIFAR10-proxy workload (paper Tables 3/6): a residual CNN trained on
//! synthetic Gaussian-mixture images by 4 simulated workers, comparing
//! SGD against PowerSGD ranks 1/2/4 on accuracy and communication, and
//! printing the paper-scale timing simulation for the real ResNet18.
//!
//! ```text
//! make artifacts && cargo run --release --example cifar_resnet
//! ```

use anyhow::Result;
use powersgd::compress::PowerSgd;
use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::Classification;
use powersgd::net::NCCL;
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule, Sgd};
use powersgd::profiles::resnet18;
use powersgd::runtime::Runtime;
use powersgd::simulate::{data_per_epoch_mb, simulate_step, Scheme};
use powersgd::util::Table;

const STEPS: usize = 250;
const WORKERS: usize = 4;

fn run(opt: Box<dyn DistOptimizer>) -> Result<(f64, u64)> {
    let mut rt = Runtime::cpu("artifacts")?;
    let train = rt.load("convnet_train")?;
    let eval = rt.load("convnet_eval")?;
    let cfg = TrainerConfig {
        workers: WORKERS,
        eval_kind: EvalKind::Accuracy,
        ..Default::default()
    };
    let mut data = Classification::new(3 * 16 * 16, 10, 32, WORKERS, 42);
    let mut trainer = Trainer::new(train, Some(eval), opt, cfg)?;
    trainer.train(&mut data, STEPS)?;
    let acc = trainer.evaluate(&mut data)?;
    Ok((acc, trainer.metrics.total_bytes() / STEPS as u64))
}

fn main() -> Result<()> {
    let lr = LrSchedule::constant(0.02);
    let mut table = Table::new(
        "ConvNet / CIFAR-proxy — 4 workers, 250 steps (cf. paper Table 3)",
        &["Algorithm", "Test accuracy", "Bytes/step", "Compression"],
    );
    let cases: Vec<(String, Box<dyn DistOptimizer>)> = vec![
        ("SGD".into(), Box::new(Sgd::new(lr.clone(), 0.9))),
        ("Rank 1".into(), Box::new(EfSgd::new(Box::new(PowerSgd::new(1, 1)), lr.clone(), 0.9))),
        ("Rank 2".into(), Box::new(EfSgd::new(Box::new(PowerSgd::new(2, 1)), lr.clone(), 0.9))),
        ("Rank 4".into(), Box::new(EfSgd::new(Box::new(PowerSgd::new(4, 1)), lr.clone(), 0.9))),
    ];
    let mut full_bytes = 0u64;
    for (name, opt) in cases {
        let (acc, bytes) = run(opt)?;
        if name == "SGD" {
            full_bytes = bytes;
        }
        table.row(&[
            name,
            format!("{acc:.1}%"),
            format!("{bytes}"),
            format!("{:.0}x", full_bytes as f64 / bytes as f64),
        ]);
    }
    table.print();

    // Paper-scale timing: the exact ResNet18 shape profile over the
    // calibrated 16-worker NCCL model (regenerates Table 3's right side).
    let p = resnet18();
    let mut sim = Table::new(
        "Simulated paper-scale ResNet18/CIFAR10 — 16 workers, NCCL",
        &["Algorithm", "Data/epoch", "Time/batch", "vs SGD"],
    );
    let sgd_total = simulate_step(&p, Scheme::Sgd, 16, &NCCL).total();
    for scheme in [
        Scheme::Sgd,
        Scheme::PowerSgd { rank: 1 },
        Scheme::PowerSgd { rank: 2 },
        Scheme::PowerSgd { rank: 4 },
    ] {
        let b = simulate_step(&p, scheme, 16, &NCCL);
        sim.row(&[
            scheme.name(),
            format!("{:.0} MB", data_per_epoch_mb(&p, scheme)),
            format!("{:.0} ms", b.total() * 1e3),
            format!("{:+.0}%", (b.total() / sgd_total - 1.0) * 100.0),
        ]);
    }
    sim.print();
    Ok(())
}
