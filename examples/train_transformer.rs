//! End-to-end validation driver (DESIGN.md §8): train a decoder-only
//! transformer LM for a few hundred steps over simulated workers with
//! PowerSGD, log the loss curve, and report the full time/byte breakdown.
//!
//! ```text
//! # build the artifact for the chosen preset first, e.g.:
//! cd python && python -m compile.aot --out-dir ../artifacts --models transformer_small
//! cargo run --release --example train_transformer -- --preset small --steps 300
//! # paper-scale config (slow on CPU — lower step count accordingly):
//! cargo run --release --example train_transformer -- --preset 100m --steps 20
//! ```
//!
//! The recorded run for EXPERIMENTS.md §E2E uses `--preset small
//! --steps 300 --workers 4` and compares PowerSGD rank 4 vs SGD.

use anyhow::{Context, Result};
use powersgd::compress::PowerSgd;
use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::LmCorpus;
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule, Sgd};
use powersgd::runtime::Runtime;
use powersgd::util::{Args, Table};

struct PresetCfg {
    vocab: usize,
    batch: usize,
    seq: usize,
}

fn preset_cfg(name: &str) -> PresetCfg {
    match name {
        "tiny" => PresetCfg { vocab: 2000, batch: 8, seq: 64 },
        "small" => PresetCfg { vocab: 4000, batch: 8, seq: 128 },
        "25m" => PresetCfg { vocab: 8000, batch: 4, seq: 128 },
        "100m" => PresetCfg { vocab: 16000, batch: 2, seq: 256 },
        other => panic!("unknown preset {other:?} (tiny|small|25m|100m)"),
    }
}

fn main() -> Result<()> {
    let args = Args::parse();
    let preset = args.get_or("preset", "small").to_string();
    let steps = args.get_parsed_or("steps", 300usize);
    let workers = args.get_parsed_or("workers", 4usize);
    let rank = args.get_parsed_or("rank", 4usize);
    let lr = args.get_parsed_or("lr", 0.05f64);
    let seed = args.get_parsed_or("seed", 42u64);
    let compare_sgd = !args.flag("skip-sgd");
    let pc = preset_cfg(&preset);
    let model = format!("transformer_{preset}");

    let mut rt = Runtime::cpu("artifacts")?;
    let train = rt
        .load(&format!("{model}_train"))
        .with_context(|| format!("artifact for preset {preset} missing — run `cd python && python -m compile.aot --out-dir ../artifacts --models {model}`"))?;
    let eval = rt.load(&format!("{model}_eval"))?;

    let run = |name: &str, opt: Box<dyn DistOptimizer>| -> Result<(f64, f64, u64, String)> {
        let cfg = TrainerConfig {
            workers,
            seed,
            eval_every: (steps / 6).max(1),
            eval_kind: EvalKind::Perplexity,
            log_every: (steps / 15).max(1),
            ..Default::default()
        };
        let mut data = LmCorpus::new(pc.vocab, pc.batch, pc.seq, workers, seed);
        let mut trainer = Trainer::new(train.clone(), Some(eval.clone()), opt, cfg)?;
        eprintln!(
            "=== {name}: {} params, {} workers, {} steps ===",
            trainer.registry().numel(),
            workers,
            steps
        );
        let t0 = std::time::Instant::now();
        trainer.train(&mut data, steps)?;
        let wall = t0.elapsed().as_secs_f64();
        let ppl = trainer.evaluate(&mut data)?;
        let bytes = trainer.metrics.total_bytes() / steps as u64;
        let (grad_s, comp_s) = trainer.metrics.mean_times();
        eprintln!(
            "{name}: final ppl {ppl:.1}, {wall:.0}s wall, grad {:.0} ms/worker/step, compress {:.1} ms, sim-comm {:.2} ms",
            grad_s * 1e3,
            comp_s * 1e3,
            trainer.metrics.mean_sim_comm() * 1e3
        );
        Ok((ppl, wall, bytes, trainer.metrics.loss_curve_csv((steps / 30).max(1))))
    };

    let mut table = Table::new(
        &format!("Transformer ({preset}) — {workers} workers, {steps} steps"),
        &["Algorithm", "Final ppl", "Bytes/step", "Wall time"],
    );

    let powersgd = Box::new(EfSgd::new(
        Box::new(PowerSgd::new(rank, seed)),
        LrSchedule::constant(lr),
        0.9,
    ));
    let (ppl_p, wall_p, bytes_p, curve) = run(&format!("PowerSGD rank {rank}"), powersgd)?;
    table.row(&[
        format!("Rank {rank}"),
        format!("{ppl_p:.1}"),
        format!("{bytes_p}"),
        format!("{wall_p:.0} s"),
    ]);

    if compare_sgd {
        let sgd = Box::new(Sgd::new(LrSchedule::constant(lr), 0.9));
        let (ppl_s, wall_s, bytes_s, _) = run("SGD", sgd)?;
        table.row(&[
            "SGD".into(),
            format!("{ppl_s:.1}"),
            format!("{bytes_s}"),
            format!("{wall_s:.0} s"),
        ]);
        println!(
            "\ncompression: {:.0}x less data than SGD",
            bytes_s as f64 / bytes_p as f64
        );
    }
    table.print();
    println!("\nloss curve (PowerSGD):\n{curve}");
    Ok(())
}
