//! `powersgd` — the L3 coordinator binary.
//!
//! Subcommands:
//! - `train`    — distributed training of an AOT-compiled model with a
//!   chosen compressor over W simulated workers.
//! - `simulate` — shape-profile timing simulator (paper Tables 3–7,
//!   Figure 3) without running a model.
//! - `launch`   — quickstart for the multi-process TCP ring
//!   (DESIGN.md §10): spawn W `powersgd worker` OS processes on
//!   localhost, rendezvous them into a ring, run a deterministic
//!   PowerSGD EF-SGD trajectory over real sockets, and verify it
//!   **bitwise** against the in-process lockstep oracle — including
//!   measured-wire-bytes vs. the analytic `message_bytes` model.
//! - `worker`   — one rank of a launch (spawned by `launch`; can also
//!   be started by hand against a known coordinator address).
//! - `experiment` — run a registered suite of the paper's §5 sweeps
//!   (DESIGN.md §12): emits a versioned `EXPERIMENTS_<suite>.json`
//!   artifact per suite plus a deterministic `REPORT.md` with
//!   paper-style tables, including measured wire bytes from a real
//!   threaded-engine run.
//! - `artifacts`— list available compiled artifacts.
//!
//! Examples:
//! ```text
//! powersgd train --model mlp --compressor powersgd --rank 2 --workers 4 --steps 200
//! powersgd train --model mlp --engine threaded --bucket-mb 4 --straggler 1.5
//! powersgd train --model mlp --engine threaded --threads 4
//! powersgd simulate --profile resnet18 --scheme rank2 --workers 16 --backend nccl
//! powersgd simulate --profile resnet18 --bucket-mb 4 --overlap
//! powersgd simulate --profile resnet18 --scheme rank2 --engine threaded
//! powersgd launch --workers 4 --transport tcp --compressor powersgd --rank 2 --steps 3
//! powersgd launch --workers 2 --compressor sign-norm --steps 5 --threads 4
//! powersgd launch --workers 2 --steps 3 --trace TRACE.json
//! powersgd launch --workers 2 --steps 3 --metrics METRICS.json
//! powersgd bench-diff bench-trajectory/BENCH_kernel_hotpath.json BENCH_kernel_hotpath.json
//! powersgd experiment --suite scheme-compare
//! powersgd experiment --all --out-dir target/experiments
//! ```
//!
//! `--threads N` (default `$POWERSGD_THREADS`, else 1) sizes the
//! kernel pool (DESIGN.md §11) that parallelizes the compression
//! GEMMs and Gram–Schmidt. Kernel results are **bitwise identical at
//! every thread count**, so `--threads` only changes wall-clock. It
//! composes with `--engine threaded`: W worker threads each dispatch
//! onto the shared pool (W workers × N kernel threads). The kernels
//! themselves are the blocked SIMD implementations;
//! `POWERSGD_KERNEL_BACKEND=reference` swaps in the naive reference
//! backend (slow — for differential testing and the blocked-vs-naive
//! bench duel only; the thread-count invariance holds on both).
//!
//! `--trace PATH` records the span timeline (step phases, compression
//! kernels, ring collectives, wire codec; DESIGN.md §13) and writes
//! Chrome-trace-event JSON openable at <https://ui.perfetto.dev>. On
//! `launch` each worker process writes a rank-suffixed part
//! (`TRACE_r<k>.json`) and the coordinator merges the parts into one
//! file with a track per worker and kernel-pool thread. Tracing only
//! reads clocks — computed values stay bitwise identical.
//!
//! With `--engine threaded`, `train` runs compression decentralized
//! (per-worker `WorkerCompressor` instances over the `InProcRing`) for
//! schemes that support it, and `simulate` executes one real
//! decentralized round per scheme, checked bitwise against the
//! centralized lockstep oracle. `launch` takes the same per-worker path
//! across real process boundaries: each worker compresses its own
//! gradient and aggregates over a `TcpRing`.

use anyhow::{bail, Context, Result};
use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::{Classification, DataSource, LmCorpus};
use powersgd::net::backend_by_name;
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule, Sgd, SignumOpt};
use powersgd::runtime::Runtime;
use powersgd::simulate::{
    data_per_epoch_mb, scheme_by_name, simulate_step, simulate_step_overlapped, Scheme,
};
use powersgd::transport::{
    bytes_from_mb, engine_by_name, pipeline_by_name, Cluster, EngineKind, PipelineMode,
};
use powersgd::util::{Args, Table};

fn main() -> Result<()> {
    let args = Args::parse();
    if args.flag("help") || args.subcommand() == Some("help") {
        print_help();
        return Ok(());
    }
    // Kernel pool size, before any subcommand touches a kernel. The
    // env default (POWERSGD_THREADS) is resolved lazily by the pool;
    // an explicit --threads wins.
    if let Some(t) = args.get("threads") {
        let n: usize = t.parse().context("--threads must be a positive integer")?;
        if n == 0 {
            bail!("--threads must be >= 1");
        }
        powersgd::runtime::pool::set_threads(n);
    }
    // `--trace PATH` turns the span recorder fully on (timing + track
    // capture) before any subcommand runs. Tracing only reads clocks —
    // computed values stay bitwise identical (DESIGN.md §13).
    let trace = args.get("trace").map(std::path::PathBuf::from);
    if trace.is_some() {
        powersgd::obs::enable_timing(true);
        powersgd::obs::enable_trace(true);
    }
    // `--metrics PATH` turns the run-health registry on (DESIGN.md §15).
    // Like tracing, metrics only read clocks and counters — computed
    // values stay bitwise identical with the flag on or off.
    let metrics = args.get("metrics").map(std::path::PathBuf::from);
    if metrics.is_some() {
        powersgd::obs::enable_metrics(true);
    }
    let sub = args.subcommand();
    let result = match sub {
        Some("train") => cmd_train(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("launch") => cmd_launch(&args),
        Some("worker") => cmd_worker(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    // `worker` writes its own rank-suffixed part and `launch` merges the
    // per-rank parts itself; every other subcommand is a single process
    // whose whole timeline is written here.
    if let (Some(path), Ok(())) = (&trace, &result) {
        match sub {
            Some("launch") | Some("worker") => {}
            // The experiment runner's scoped captures consume the span
            // buffers as they record, so a whole-process trace here
            // would be empty — refuse rather than write a misleading
            // file.
            Some("experiment") => eprintln!(
                "warning: --trace is a no-op for `experiment` (its scoped captures consume \
                 the spans); see the time-attribution section of REPORT.md instead"
            ),
            _ => write_trace(path, 0, &format!("powersgd {}", sub.unwrap_or("")))?,
        }
    }
    // `worker` writes its own rank-suffixed METRICS part and `launch`
    // writes the merged cluster-health summary itself; every other
    // subcommand dumps this process's whole-run snapshot here.
    if let (Some(path), Ok(())) = (&metrics, &result) {
        match sub {
            Some("launch") | Some("worker") => {}
            // The experiment runner scopes the registry around each
            // measured run and reconciles the deltas into REPORT.md
            // itself, so a whole-process snapshot here would lump every
            // suite and config into one undifferentiated blob — refuse
            // rather than write a misleading file.
            Some("experiment") => eprintln!(
                "warning: --metrics is a no-op for `experiment` (the runner scopes the \
                 registry per measured run); see the \"Run health\" section of REPORT.md instead"
            ),
            _ => {
                let doc = powersgd::obs::metrics::snapshot().to_json();
                std::fs::write(path, doc)
                    .with_context(|| format!("writing metrics {}", path.display()))?;
                eprintln!("wrote metrics {}", path.display());
            }
        }
    }
    result
}

/// Drain the recorded span tracks into one Chrome-trace-event JSON file
/// (openable at <https://ui.perfetto.dev>).
fn write_trace(path: &std::path::Path, pid: u32, process_name: &str) -> Result<()> {
    let tracks = powersgd::obs::drain_tracks();
    let doc = powersgd::obs::chrome::chrome_trace_json(pid, process_name, &tracks);
    std::fs::write(path, doc).with_context(|| format!("writing trace {}", path.display()))?;
    eprintln!("wrote trace {} (open at https://ui.perfetto.dev)", path.display());
    Ok(())
}

/// `powersgd --help` / bare invocation: subcommands and shared options.
fn print_help() {
    eprintln!(
        "powersgd — PowerSGD distributed-training coordinator\n\
         \n\
         usage: powersgd <train|simulate|launch|worker|artifacts> [options]\n\
         \n\
         subcommands:\n\
         \x20 train      train an AOT-compiled model over W simulated workers\n\
         \x20 simulate   shape-profile timing simulator (paper Tables 3-7)\n\
         \x20 launch     spawn W worker processes on a localhost TCP ring\n\
         \x20 worker     one rank of a launch (normally spawned by `launch`)\n\
         \x20 experiment run a registered suite of the paper's sweeps and\n\
         \x20            generate EXPERIMENTS_<suite>.json + REPORT.md\n\
         \x20            (--suite NAME | --all | --list; --quick; --out-dir D)\n\
         \x20 bench-diff compare two BENCH_<name>.json artifacts: markdown\n\
         \x20            delta table; non-zero exit when a *_ms metric slows\n\
         \x20            beyond --tolerance R (default 0.25), a *_gflops\n\
         \x20            metric drops beyond it, or a *_bytes metric drifts\n\
         \x20            at all; --report-only warns instead (for\n\
         \x20            cross-machine baselines)\n\
         \x20 artifacts  list available compiled artifacts\n\
         \n\
         shared options:\n\
         \x20 --threads N      kernel-pool threads for the compression GEMMs\n\
         \x20                  and Gram-Schmidt (default: $POWERSGD_THREADS,\n\
         \x20                  else 1). Results are bitwise identical at every\n\
         \x20                  thread count. Composes with --engine threaded:\n\
         \x20                  W worker threads x N kernel threads. Kernels\n\
         \x20                  run the blocked SIMD backend; set\n\
         \x20                  POWERSGD_KERNEL_BACKEND=reference to force the\n\
         \x20                  naive reference kernels (differential testing\n\
         \x20                  and bench duels only -- much slower).\n\
         \x20 --engine E       collective engine: lockstep | threaded\n\
         \x20 --pipeline P     collective scheduling: off | overlap | delayed\n\
         \x20                  (default off). overlap posts collectives early\n\
         \x20                  and drains late -- bitwise identical to off;\n\
         \x20                  delayed applies step t-1's aggregate at step t\n\
         \x20                  (the DDP PowerSGD-hook trick; new trajectory).\n\
         \x20 --compressor C   powersgd | powersgd-cold | unbiased-rank |\n\
         \x20                  sign-norm | top-k | none | ... (see DESIGN.md)\n\
         \x20 --rank R         compression rank (default 2)\n\
         \x20 --workers W      simulated/spawned worker count\n\
         \x20 --seed S         deterministic seed\n\
         \x20 --trace PATH     write a Chrome-trace (Perfetto) span timeline\n\
         \x20                  to PATH; open it at https://ui.perfetto.dev.\n\
         \x20                  `launch` forwards the flag and merges the\n\
         \x20                  per-rank worker parts (PATH -> TRACE_r<k>\n\
         \x20                  naming) into one file. Tracing never changes\n\
         \x20                  computed values (see DESIGN.md).\n\
         \x20 --metrics PATH   record the run-health registry (DESIGN.md\n\
         \x20                  §15): counters, compression-quality gauges,\n\
         \x20                  deterministic histograms. `train`/`simulate`\n\
         \x20                  write one snapshot to PATH; `launch` forwards\n\
         \x20                  the flag — each worker writes per-step\n\
         \x20                  METRICS_r<k>.jsonl and the coordinator writes\n\
         \x20                  the merged cluster-health summary (median/p95\n\
         \x20                  step times, straggler flags, wire-byte\n\
         \x20                  reconciliation) to PATH. Metrics never change\n\
         \x20                  computed values.\n\
         \x20 --straggle-rank K / --straggle-ms MS\n\
         \x20                  (launch/worker) inject a deterministic sleep\n\
         \x20                  before every step on rank K — exercises the\n\
         \x20                  straggler detector in tests and CI\n\
         \x20 --comm-timeout-ms MS\n\
         \x20                  (launch/worker) ring socket read/write timeout,\n\
         \x20                  overriding the run timeout (--timeout-s). Must\n\
         \x20                  exceed --straggle-ms, or the injected sleep\n\
         \x20                  reads as a dead peer (DESIGN.md §16)\n\
         \x20 --elastic        (launch/worker) epoch-based elastic membership\n\
         \x20                  (DESIGN.md §16): a crashed or joining worker\n\
         \x20                  triggers ring re-formation at the next step\n\
         \x20                  boundary and the run continues at W-1 / W+1\n\
         \x20 --heartbeat-ms MS\n\
         \x20                  elastic step-boundary heartbeat timeout: a\n\
         \x20                  member silent past MS at a boundary is declared\n\
         \x20                  dead (default 5000; must exceed --straggle-ms)\n\
         \x20 --reconnect-retries N\n\
         \x20                  connect attempts per ring edge with jittered\n\
         \x20                  exponential backoff (default 4); attempts are\n\
         \x20                  counted in the reconnect_attempts metric\n\
         \x20 --join-at-step S (launch, elastic) spawn one extra worker and\n\
         \x20                  admit it into the ring at step boundary S\n\
         \x20                  (joins into a churned run are out of scope, so\n\
         \x20                  this cannot combine with --fail-rank)\n\
         \x20 --fail-rank R / --fail-at-step S / --fail-midstep\n\
         \x20                  (launch/worker, elastic) deterministic fault\n\
         \x20                  injection: rank R crashes at step S — at the\n\
         \x20                  step boundary, or mid-collective with\n\
         \x20                  --fail-midstep — and the survivors re-form\n\
         \n\
         see DESIGN.md for the full option list, and\n\
         examples/quickstart.rs for a narrated walkthrough (it runs a\n\
         miniature scheme-compare scenario and prints the table):\n\
         \x20 cargo run --release --example quickstart"
    );
}

/// Build the optimizer selected by `--compressor` (+ `--rank`). Under
/// the threaded engine, schemes with a per-worker implementation run
/// decentralized — each worker thread compresses its own gradient and
/// aggregates over the `InProcRing`, bitwise-identical to the oracle —
/// while the rest fall back to the centralized path (whose collectives
/// still run on the threaded ring via the engine switch). Either way
/// the compression GEMMs and Gram–Schmidt dispatch onto the kernel
/// pool sized by `--threads` / `POWERSGD_THREADS` (set by `main`
/// before this runs); kernel results are bitwise identical at every
/// thread count.
pub fn build_optimizer(
    name: &str,
    rank: usize,
    schedule: LrSchedule,
    momentum: f32,
    seed: u64,
    error_feedback: bool,
    engine: EngineKind,
    pipeline: PipelineMode,
) -> Result<Box<dyn DistOptimizer>> {
    use powersgd::compress::{decentralized_by_name, Compressor};
    let boxed: Box<dyn Compressor> = match name {
        "none" | "sgd" => return Ok(Box::new(Sgd::new(schedule, momentum))),
        "signum" => return Ok(Box::new(SignumOpt::new(schedule, momentum))),
        _ => match (engine, decentralized_by_name(name, rank, seed)) {
            // Pipelined scheduling needs the per-worker path; the
            // centralized oracle has no collectives to overlap, so the
            // mode only reaches compressors through the fleet.
            (EngineKind::Threaded, Some(dec)) => Box::new(dec.with_pipeline(pipeline)),
            _ => centralized_compressor(name, rank, seed)?,
        },
    };
    let mut ef = EfSgd::new(boxed, schedule, momentum);
    if pipeline == PipelineMode::Delayed {
        ef = ef.with_delayed_aggregate();
    }
    Ok(Box::new(if error_feedback { ef } else { ef.without_error_feedback() }))
}

/// The centralized oracle compressor for a CLI name.
fn centralized_compressor(
    name: &str,
    rank: usize,
    seed: u64,
) -> Result<Box<dyn powersgd::compress::Compressor>> {
    use powersgd::compress::*;
    Ok(match name {
        "powersgd" => Box::new(PowerSgd::new(rank, seed)),
        "powersgd-adaptive" => Box::new(AdaptivePowerSgd::new(rank, 1, 32, seed)),
        "powersgd-cold" => Box::new(PowerSgd::new(rank, seed).without_warm_start()),
        "best-rank" => Box::new(BestRankR::new(rank, seed)),
        "unbiased-rank" => Box::new(UnbiasedRank::new(rank, seed)),
        "random-block" => Box::new(RandomBlock::new(rank, seed)),
        "random-k" => Box::new(RandomK::new(rank, seed)),
        "top-k" => Box::new(TopK::new(rank)),
        "sign-norm" => Box::new(SignNorm::new()),
        "atomo" => Box::new(Atomo::new(rank, seed)),
        other => bail!("unknown compressor {other:?}"),
    })
}

/// Construct the data source matching a model artifact name.
pub fn build_data(model: &str, workers: usize, seed: u64) -> Result<Box<dyn DataSource>> {
    Ok(match model {
        "mlp" => Box::new(Classification::new(64, 10, 32, workers, seed)),
        "convnet" => Box::new(Classification::new(3 * 16 * 16, 10, 32, workers, seed)),
        "lstm" => Box::new(LmCorpus::new(1000, 8, 32, workers, seed)),
        m if m.starts_with("transformer_tiny") => {
            Box::new(LmCorpus::new(2000, 8, 64, workers, seed))
        }
        m if m.starts_with("transformer_small") => {
            Box::new(LmCorpus::new(4000, 8, 128, workers, seed))
        }
        m if m.starts_with("transformer_25m") => {
            Box::new(LmCorpus::new(8000, 4, 128, workers, seed))
        }
        m if m.starts_with("transformer_100m") => {
            Box::new(LmCorpus::new(16000, 2, 256, workers, seed))
        }
        other => bail!("no data source for model {other:?}"),
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mlp").to_string();
    let compressor = args.get_or("compressor", "powersgd").to_string();
    let rank = args.get_parsed_or("rank", 2usize);
    let workers = args.get_parsed_or("workers", 4usize);
    let steps = args.get_parsed_or("steps", 100usize);
    let lr = args.get_parsed_or("lr", 0.05f64);
    let momentum = args.get_parsed_or("momentum", 0.9f64) as f32;
    let seed = args.get_parsed_or("seed", 42u64);
    let warmup = args.get_parsed_or("warmup", 0usize);
    let eval_every = args.get_parsed_or("eval-every", steps / 4);
    let backend = backend_by_name(args.get_or("backend", "nccl"))
        .context("unknown backend (nccl|gloo)")?;
    let artifacts_dir = args.get_or("artifacts", "artifacts").to_string();
    let no_ef = args.flag("no-error-feedback");
    let engine = engine_by_name(args.get_or("engine", "lockstep"))
        .context("unknown engine (lockstep|threaded)")?;
    let pipeline = pipeline_by_name(args.get_or("pipeline", "off"))
        .context("unknown pipeline mode (off|overlap|delayed)")?;
    let bucket_mb = args.get_parsed_or("bucket-mb", 0.0f64);
    let straggler = args.get_parsed_or("straggler", 1.0f64);

    let mut rt = Runtime::cpu(&artifacts_dir)?;
    let train = rt.load(&format!("{model}_train"))?;
    let eval = rt.load(&format!("{model}_eval")).ok();

    let is_lm = model.starts_with("lstm") || model.starts_with("transformer");
    let schedule = LrSchedule::paper_step(lr, workers, warmup, vec![]);
    let opt =
        build_optimizer(&compressor, rank, schedule, momentum, seed, !no_ef, engine, pipeline)?;
    let cfg = TrainerConfig {
        workers,
        backend,
        seed,
        eval_every,
        eval_kind: if is_lm { EvalKind::Perplexity } else { EvalKind::Accuracy },
        log_every: args.get_parsed_or("log-every", 10usize),
        engine,
        pipeline,
        bucket_bytes: bytes_from_mb(bucket_mb),
        straggler,
    };
    let mut data = build_data(&model, workers, seed)?;
    let mut trainer = Trainer::new(train, eval, opt, cfg)?;

    eprintln!(
        "training {model} with {} on {workers} workers ({} params, {} bytes/step uncompressed)",
        trainer.optimizer_name(),
        trainer.registry().numel(),
        trainer.registry().total_bytes(),
    );
    trainer.train(data.as_mut(), steps)?;

    let (grad_s, comp_s, coll_s, dec_s) = trainer.metrics.mean_times();
    println!("final loss (mean last 10): {:.4}", trainer.metrics.mean_loss_last(10));
    if let Some(e) = trainer.metrics.last_eval() {
        println!("final eval: {:.3}", e);
    }
    println!(
        "bytes/step: {}   grad: {:.1} ms   compress: {:.1} ms   collective: {:.1} ms   \
         decompress: {:.1} ms   sim-comm: {:.2} ms   sim-step: {:.2} ms",
        trainer.metrics.total_bytes() / steps as u64,
        grad_s * 1e3,
        comp_s * 1e3,
        coll_s * 1e3,
        dec_s * 1e3,
        trainer.metrics.mean_sim_comm() * 1e3,
        trainer.metrics.mean_sim_step() * 1e3,
    );
    if args.flag("loss-curve") {
        println!("{}", trainer.metrics.loss_curve_csv(5));
    }
    Ok(())
}

fn parse_scheme(s: &str, rank: usize) -> Result<Scheme> {
    scheme_by_name(s, rank).with_context(|| format!("unknown scheme {s:?}"))
}

fn profile_by_name(name: &str) -> Result<powersgd::profiles::ModelProfile> {
    powersgd::profiles::by_name(name)
        .with_context(|| format!("unknown profile {name:?} (resnet18|lstm|transformer)"))
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let profile = profile_by_name(args.get_or("profile", "resnet18"))?;
    let workers = args.get_parsed_or("workers", 16usize);
    let backend = backend_by_name(args.get_or("backend", "nccl"))
        .context("unknown backend (nccl|gloo)")?;
    let rank = args.get_parsed_or("rank", 2usize);
    let schemes: Vec<Scheme> = match args.get("scheme") {
        Some(s) => vec![parse_scheme(s, rank)?],
        None => vec![
            Scheme::Sgd,
            Scheme::PowerSgd { rank: 1 },
            Scheme::PowerSgd { rank: 2 },
            Scheme::PowerSgd { rank: 4 },
            Scheme::Signum,
            Scheme::Atomo { rank: 2 },
        ],
    };
    let mut table = Table::new(
        &format!("{} — {} workers, {}", profile.name, workers, backend.name),
        &["Algorithm", "Data/epoch", "fwd", "bwd", "encode", "comm", "decode", "Time/batch"],
    );
    for s in &schemes {
        let b = simulate_step(&profile, *s, workers, &backend);
        table.row(&[
            s.name(),
            format!("{:.0} MB", data_per_epoch_mb(&profile, *s)),
            format!("{:.0} ms", b.fwd * 1e3),
            format!("{:.0} ms", b.bwd * 1e3),
            format!("{:.1} ms", b.encode * 1e3),
            format!("{:.1} ms", b.comm * 1e3),
            format!("{:.1} ms", b.decode * 1e3),
            format!("{:.0} ms", b.total() * 1e3),
        ]);
    }
    table.print();

    // `--bucket-mb N` (with optional `--overlap` / `--straggler S`) adds
    // the threaded engine's bucketed comm/compute-overlap projection.
    let bucket_mb = args.get_parsed_or("bucket-mb", 0.0f64);
    if bucket_mb > 0.0 || args.flag("overlap") {
        let straggler = args.get_parsed_or("straggler", 1.0f64);
        let cluster = Cluster::with_straggler(workers, &backend, straggler);
        let bucket_bytes = bytes_from_mb(bucket_mb);
        let mut table = Table::new(
            &format!(
                "Overlap projection — {:.1} MB buckets, straggler ×{straggler:.2}",
                bucket_mb
            ),
            &["Algorithm", "Buckets", "No overlap", "Overlapped", "Comm exposed", "Saved"],
        );
        for s in &schemes {
            let seq = simulate_step_overlapped(&profile, *s, &cluster, bucket_bytes, false);
            let ovl = simulate_step_overlapped(&profile, *s, &cluster, bucket_bytes, true);
            table.row(&[
                s.name(),
                format!("{}", ovl.buckets),
                format!("{:.0} ms", seq.total * 1e3),
                format!("{:.0} ms", ovl.total * 1e3),
                format!("{:.1} ms", ovl.exposed_comm * 1e3),
                format!("{:.0}%", 100.0 * (1.0 - ovl.total / seq.total)),
            ]);
        }
        table.print();
    }

    // `--engine threaded` additionally executes one *real* decentralized
    // compression round per scheme — per-worker WorkerCompressor
    // instances over the InProcRing — and verifies it reproduces the
    // centralized lockstep oracle bitwise on the profile's layer shapes.
    if let Some(engine_name) = args.get("engine") {
        let engine = engine_by_name(engine_name).context("unknown engine (lockstep|threaded)")?;
        if engine == EngineKind::Threaded {
            let seed = args.get_parsed_or("seed", 42u64);
            run_decentralized_check(&profile, &schemes, workers, seed)?;
        }
    }
    Ok(())
}

/// Execute one real decentralized compression round per scheme over the
/// profile's layer shapes and check it against the centralized lockstep
/// oracle bitwise — the equivalence `tests/integration_decentralized.rs`
/// pins, demonstrated here on the paper's real shapes.
fn run_decentralized_check(
    profile: &powersgd::profiles::ModelProfile,
    schemes: &[Scheme],
    workers: usize,
    seed: u64,
) -> Result<()> {
    use powersgd::collectives::CommLog;
    use powersgd::compress::Compressor as _;
    use powersgd::simulate::{centralized_for_scheme, decentralized_for_scheme};
    use powersgd::tensor::Tensor;
    use powersgd::util::Rng;

    // Cap the world size so the check stays in memory. All-reduce
    // schemes hold ~W full gradients plus one shared mean per path;
    // gather schemes (sign/top-K) additionally materialize a full-model
    // mean and per-worker locals on both paths, so budget them 4× lower.
    let numel = profile.registry.numel().max(1);
    let budget: usize = if schemes.iter().all(|s| s.all_reduce()) {
        200_000_000
    } else {
        50_000_000
    };
    let w = workers.min((budget / numel).max(2));
    if w < workers {
        eprintln!("note: capping the decentralized check at {w} workers ({numel} params each)");
    }

    let mut rng = Rng::new(seed ^ 0x9e37);
    let updates: Vec<Vec<Tensor>> = (0..w)
        .map(|_| {
            profile
                .registry
                .specs
                .iter()
                .map(|s| {
                    let shape: Vec<usize> = match s.matrix_dims() {
                        Some((n, m)) => vec![n, m],
                        None => vec![s.numel()],
                    };
                    let mut t = Tensor::zeros(&shape);
                    rng.fill_normal(t.data_mut(), 1.0);
                    t
                })
                .collect()
        })
        .collect();

    let mut table = Table::new(
        &format!(
            "Decentralized per-worker compression — {} over InProcRing, {w} workers",
            profile.name
        ),
        &["Algorithm", "Per-worker step", "Oracle step", "Bytes/worker", "Bitwise"],
    );
    for &scheme in schemes {
        let (Some(mut dec), Some(mut oracle)) =
            (decentralized_for_scheme(scheme, seed), centralized_for_scheme(scheme, seed))
        else {
            eprintln!("note: {} has no per-worker implementation; skipped", scheme.name());
            continue;
        };
        let mut dlog = CommLog::default();
        let t0 = std::time::Instant::now();
        let dec_out = dec.compress_aggregate(&updates, &mut dlog);
        let dec_s = t0.elapsed().as_secs_f64();
        let mut olog = CommLog::default();
        let t1 = std::time::Instant::now();
        let oracle_out = oracle.compress_aggregate(&updates, &mut olog);
        let oracle_s = t1.elapsed().as_secs_f64();
        let mut bitwise = dlog.bytes_sent() == olog.bytes_sent();
        for (a, b) in dec_out.mean.iter().zip(oracle_out.mean.iter()) {
            bitwise &= a.data() == b.data();
        }
        if !bitwise {
            bail!("{}: decentralized path diverged from the lockstep oracle", scheme.name());
        }
        table.row(&[
            scheme.name(),
            format!("{:.1} ms", dec_s * 1e3),
            format!("{:.1} ms", oracle_s * 1e3),
            format!("{}", dlog.bytes_sent()),
            "ok".into(),
        ]);
    }
    table.print();
    Ok(())
}

/// Shared `launch`/`worker` options → the TCP harness config. The
/// momentum parses as f32 directly (not via f64) so the coordinator's
/// value and the string-forwarded worker values are bit-identical.
fn harness_config(args: &Args) -> Result<powersgd::transport::tcp::HarnessConfig> {
    Ok(powersgd::transport::tcp::HarnessConfig {
        compressor: args.get_or("compressor", "powersgd").to_string(),
        rank: args.get_parsed_or("rank", 2usize),
        seed: args.get_parsed_or("seed", 42u64),
        steps: args.get_parsed_or("steps", 3usize),
        lr: args.get_parsed_or("lr", 0.05f64),
        momentum: args.get_parsed_or("momentum", 0.9f32),
        pipeline: pipeline_by_name(args.get_or("pipeline", "off"))
            .context("unknown pipeline mode (off|overlap|delayed)")?,
        metrics: args.get("metrics").is_some(),
        straggle_rank: args.get_parsed_or("straggle-rank", 0usize),
        straggle_ms: args.get_parsed_or("straggle-ms", 0u64),
        elastic: args.flag("elastic"),
        heartbeat_ms: args.get_parsed_or("heartbeat-ms", 5000u64),
        reconnect_retries: args.get_parsed_or(
            "reconnect-retries",
            powersgd::transport::tcp::DEFAULT_CONNECT_RETRIES,
        ),
        comm_timeout_ms: args
            .get("comm-timeout-ms")
            .map(|v| v.parse::<u64>())
            .transpose()
            .context("--comm-timeout-ms must be an integer (milliseconds)")?,
        fail_rank: args
            .get("fail-rank")
            .map(|v| v.parse::<usize>())
            .transpose()
            .context("--fail-rank must be a rank index")?,
        fail_at_step: args.get_parsed_or("fail-at-step", 0u64),
        fail_midstep: args.flag("fail-midstep"),
    })
}

fn harness_timeout(args: &Args) -> std::time::Duration {
    std::time::Duration::from_secs_f64(args.get_parsed_or("timeout-s", 30.0f64))
}

/// `powersgd launch`: spawn W worker processes, rendezvous them into a
/// TCP ring on localhost, and verify the run against the lockstep
/// oracle (bitwise parameters + exact byte accounting). Exits non-zero
/// on any mismatch or dead worker.
fn cmd_launch(args: &Args) -> Result<()> {
    use powersgd::transport::tcp::{coordinate, coordinate_elastic, Rendezvous};
    use std::process::Command;

    let workers = args.get_parsed_or("workers", 4usize);
    let transport = args.get_or("transport", "tcp");
    if transport != "tcp" {
        bail!("unknown transport {transport:?} (tcp)");
    }
    let cfg = harness_config(args)?;
    let timeout = harness_timeout(args);
    let join_at_step: Option<u64> = args
        .get("join-at-step")
        .map(|v| v.parse::<u64>())
        .transpose()
        .context("--join-at-step must be a step index")?;
    if (join_at_step.is_some() || cfg.fail_rank.is_some()) && !cfg.elastic {
        bail!("--join-at-step / --fail-rank need --elastic (DESIGN.md §16)");
    }
    if join_at_step.is_some() && cfg.fail_rank.is_some() {
        bail!(
            "--join-at-step cannot be combined with --fail-rank: a joiner cannot replay a \
             churned prefix, so joining a churned run is out of scope (DESIGN.md §16)"
        );
    }
    if let Some(k) = join_at_step {
        if k >= cfg.steps as u64 {
            bail!(
                "--join-at-step {k} out of range for --steps {}: the join boundary must be a \
                 step the run still executes",
                cfg.steps
            );
        }
    }
    if let Some(r) = cfg.fail_rank {
        if r >= workers {
            bail!("--fail-rank {r} out of range for --workers {workers}");
        }
    }

    let rendezvous = Rendezvous::bind(args.get_or("bind", "127.0.0.1:0"))?;
    let addr = rendezvous.addr()?;
    let exe = std::env::current_exe().context("cannot locate the powersgd binary")?;
    // A late joiner is one extra identical worker process: the
    // coordinator admits exactly `workers` at rendezvous and leaves the
    // extra Hello in the listener backlog until the join boundary.
    let spawn_count = workers + usize::from(join_at_step.is_some());
    eprintln!(
        "launching {spawn_count} worker processes (rendezvous {addr}, {} rank {}, {} steps, \
         pipeline {}{})",
        cfg.compressor, cfg.rank, cfg.steps,
        cfg.pipeline.cli_name(),
        if cfg.elastic { ", elastic" } else { "" }
    );
    let mut children = Vec::with_capacity(spawn_count);
    for _ in 0..spawn_count {
        let mut cmd = Command::new(&exe);
        cmd.arg("worker")
            .arg("--coordinator")
            .arg(&addr)
            .arg("--compressor")
            .arg(&cfg.compressor)
            .arg("--rank")
            .arg(cfg.rank.to_string())
            .arg("--seed")
            .arg(cfg.seed.to_string())
            .arg("--steps")
            .arg(cfg.steps.to_string())
            .arg("--lr")
            .arg(cfg.lr.to_string())
            .arg("--momentum")
            .arg(cfg.momentum.to_string())
            .arg("--pipeline")
            .arg(cfg.pipeline.cli_name())
            .arg("--timeout-s")
            .arg(timeout.as_secs_f64().to_string());
        // Kernel threads compose across processes too: every worker
        // process gets the coordinator's --threads (kernels are bitwise
        // thread-count invariant, so this only changes wall-clock).
        if let Some(t) = args.get("threads") {
            cmd.arg("--threads").arg(t);
        }
        // Workers inherit the coordinator's cwd, so a relative --trace
        // base resolves to the same per-rank part paths merged below.
        if let Some(trace) = args.get("trace") {
            cmd.arg("--trace").arg(trace);
        }
        // Same for --metrics: workers write rank-suffixed JSONL parts
        // next to the merged summary path, and push their per-step
        // frames back over the control connection for aggregation.
        if let Some(metrics) = args.get("metrics") {
            cmd.arg("--metrics").arg(metrics);
        }
        // Deterministic straggler injection (integration tests and the
        // metrics CI smoke): one chosen rank sleeps before every step.
        if cfg.straggle_ms > 0 {
            cmd.arg("--straggle-rank")
                .arg(cfg.straggle_rank.to_string())
                .arg("--straggle-ms")
                .arg(cfg.straggle_ms.to_string());
        }
        // Elastic-membership options (DESIGN.md §16). Ranks are assigned
        // by rendezvous arrival order, so every worker gets the same
        // flags — including the fault injection, which each worker
        // checks against its own assigned rank.
        if cfg.elastic {
            cmd.arg("--heartbeat-ms")
                .arg(cfg.heartbeat_ms.to_string())
                .arg("--reconnect-retries")
                .arg(cfg.reconnect_retries.to_string())
                .arg("--elastic");
            if let Some(r) = cfg.fail_rank {
                cmd.arg("--fail-rank")
                    .arg(r.to_string())
                    .arg("--fail-at-step")
                    .arg(cfg.fail_at_step.to_string());
                if cfg.fail_midstep {
                    cmd.arg("--fail-midstep");
                }
            }
        }
        if let Some(ms) = cfg.comm_timeout_ms {
            cmd.arg("--comm-timeout-ms").arg(ms.to_string());
        }
        let child = cmd.spawn().context("spawning a worker process")?;
        children.push(child);
    }

    let outcome = if cfg.elastic {
        coordinate_elastic(&rendezvous, workers, &cfg, timeout, join_at_step)
    } else {
        coordinate(&rendezvous, workers, &cfg, timeout)
    };
    if outcome.is_err() {
        // Don't leave orphan workers behind a failed launch.
        for child in &mut children {
            let _ = child.kill();
        }
    }
    let mut injected_exit_seen = false;
    for (idx, mut child) in children.into_iter().enumerate() {
        let status = child.wait().context("waiting for a worker process")?;
        if outcome.is_ok() && !status.success() {
            // The deliberately crashed rank of an elastic fault
            // injection exits non-zero by design — but only that one:
            // a second failed process is a genuine bug the injection
            // must not mask.
            if cfg.elastic && cfg.fail_rank.is_some() && !injected_exit_seen {
                injected_exit_seen = true;
                eprintln!("note: worker process #{idx} exited with {status} (fault injection)");
                continue;
            }
            bail!("worker process #{idx} exited with {status}");
        }
    }
    let outcome = outcome?;

    // Elastic runs verify against the composed multi-epoch oracle when
    // the scheme's worker state survives the membership change bitwise
    // (DESIGN.md §16); otherwise they verify member-consistency (every
    // survivor bitwise-equal to every other) plus per-member logical
    // byte accounting. The coordinator records which check it actually
    // ran, so the printed verdict cannot drift from the verification.
    let verdict = if outcome.oracle_verified { "bitwise" } else { "consistent" };
    let mut table = Table::new(
        &format!(
            "TCP ring — {} workers × {} steps, {} (rank {}){}",
            outcome.world,
            outcome.steps,
            cfg.compressor,
            cfg.rank,
            if cfg.elastic {
                format!(", elastic ({} epochs)", outcome.epochs.len())
            } else {
                String::new()
            }
        ),
        &["Rank", "Wire bytes", "Logical bytes", "Model bytes/step", "vs oracle"],
    );
    for report in &outcome.reports {
        table.row(&[
            format!("{}", report.rank),
            format!("{}", report.wire_bytes),
            format!("{}", report.logical_bytes),
            format!("{}", outcome.model_bytes_per_step),
            verdict.into(),
        ]);
    }
    table.print();
    if cfg.elastic {
        for e in &outcome.epochs {
            eprintln!(
                "epoch {}: world {} from step {} (departed ranks {:?}, joined {})",
                e.epoch, e.world, e.start_step, e.missing_ranks, e.joined
            );
        }
        println!(
            "ok: {} members finished ({} epochs, {} reconnect attempts); final parameters {}",
            outcome.reports.len(),
            outcome.epochs.len(),
            outcome.reconnect_attempts_total,
            if outcome.oracle_verified {
                "bitwise-identical to the composed elastic oracle"
            } else {
                "bitwise-consistent across members (oracle replay not applicable \
                 to this scheme under this churn — see DESIGN.md §16)"
            }
        );
    } else {
        println!(
            "ok: {} workers bitwise-identical to the lockstep oracle; measured wire bytes match \
             the analytic message_bytes model",
            outcome.world
        );
    }
    if let Some(base) = args.get("trace") {
        // Worker parts are named by origin (epoch-0) rank, so a late
        // joiner writes the part after the initial world's.
        merge_launch_traces(std::path::Path::new(base), spawn_count)?;
    }
    // The merged cluster-health summary: per-step frames pushed by every
    // worker over the control connection, aggregated into medians/p95s
    // and straggler flags, reconciled against the metered transport.
    if let Some(base) = args.get("metrics") {
        use powersgd::obs::metrics::{aggregate, STRAGGLER_FACTOR, STRAGGLER_MIN_EXCESS_S};
        let mut health =
            aggregate(&outcome.metrics_by_rank, STRAGGLER_FACTOR, STRAGGLER_MIN_EXCESS_S);
        // Epoch history and reconnect counts come from the coordinator's
        // membership log, not the per-step frames, so the aggregate
        // cannot derive them — fill before rendering (DESIGN.md §16).
        health.epochs = outcome.epochs.clone();
        health.reconnect_attempts_total = outcome.reconnect_attempts_total;
        let reconciles = outcome.metrics_reconcile();
        if reconciles == Some(false) {
            eprintln!("warning: per-step metrics frames do not sum to the metered wire bytes");
        }
        let path = std::path::Path::new(base);
        std::fs::write(path, health.to_json(reconciles))
            .with_context(|| format!("writing merged metrics {}", path.display()))?;
        eprintln!(
            "wrote merged metrics {} ({} steps, stragglers: {:?})",
            path.display(),
            health.steps.len(),
            health.straggler_ranks()
        );
    }
    Ok(())
}

/// Merge the per-rank worker traces (written by `cmd_worker` under
/// rank-suffixed names) with the coordinator's own tracks into one
/// Chrome-trace file at `base`. A rank whose part is missing or
/// unreadable (dead peer) is skipped with a warning — the merge still
/// succeeds on the surviving parts.
fn merge_launch_traces(base: &std::path::Path, workers: usize) -> Result<()> {
    use powersgd::obs::chrome::{chrome_trace_json, merge_chrome_traces, rank_trace_path};
    let mut parts = Vec::with_capacity(workers + 1);
    for rank in 0..workers {
        let path = rank_trace_path(base, rank);
        match std::fs::read_to_string(&path) {
            Ok(doc) => parts.push(doc),
            Err(e) => eprintln!("warning: skipping trace part {} ({e})", path.display()),
        }
    }
    // The coordinator's own timeline (rendezvous + report collection)
    // gets the pid after the last worker rank.
    parts.push(chrome_trace_json(workers as u32, "coordinator", &powersgd::obs::drain_tracks()));
    match merge_chrome_traces(&parts) {
        Some(doc) => {
            std::fs::write(base, doc)
                .with_context(|| format!("writing merged trace {}", base.display()))?;
            eprintln!(
                "wrote merged trace {} (open at https://ui.perfetto.dev)",
                base.display()
            );
        }
        None => {
            eprintln!("warning: no valid trace parts; {} not written", base.display());
        }
    }
    Ok(())
}

/// `powersgd worker`: one rank of a `launch` — rendezvous, run the
/// trajectory over the metered TCP ring, report back.
fn cmd_worker(args: &Args) -> Result<()> {
    let coordinator = args
        .get("coordinator")
        .context("worker needs --coordinator host:port (normally passed by `launch`)")?;
    let (rank, step_metrics) = powersgd::transport::tcp::run_worker_with_metrics(
        coordinator,
        &harness_config(args)?,
        harness_timeout(args),
    )?;
    // Each worker process writes its own rank-suffixed trace part
    // (TRACE.json -> TRACE_r<k>.json); the launching coordinator merges
    // the parts into the base path.
    if let Some(base) = args.get("trace") {
        let path = powersgd::obs::chrome::rank_trace_path(std::path::Path::new(base), rank);
        write_trace(&path, rank as u32, &format!("worker rank {rank}"))?;
    }
    // And its own rank-suffixed metrics part (METRICS.json ->
    // METRICS_r<k>.jsonl, one JSON object per step); the coordinator
    // aggregates the same frames — received over the control
    // connection — into the merged summary at the base path.
    if let Some(base) = args.get("metrics") {
        let path =
            powersgd::obs::metrics::rank_metrics_path(std::path::Path::new(base), rank);
        let mut doc = String::new();
        for m in &step_metrics {
            doc.push_str(&m.jsonl_line());
            doc.push('\n');
        }
        std::fs::write(&path, doc)
            .with_context(|| format!("writing metrics part {}", path.display()))?;
        eprintln!("wrote metrics part {} ({} steps)", path.display(), step_metrics.len());
    }
    Ok(())
}

/// `powersgd experiment`: run a registered suite (or `--all`) of the
/// paper's §5 sweeps and write the artifacts — one
/// `EXPERIMENTS_<suite>.json` per suite plus the deterministic
/// `REPORT.md` (DESIGN.md §12). `--quick` shrinks every axis for the CI
/// smoke tier (also triggered by `BENCH_QUICK=1`); `--list` prints the
/// registry.
fn cmd_experiment(args: &Args) -> Result<()> {
    use powersgd::experiments::{registry, run_suite, suite_by_name, write_report};

    let seed = args.get_parsed_or("seed", 42u64);
    let quick = args.flag("quick") || powersgd::util::quick_mode();
    let out_dir = std::path::PathBuf::from(args.get_or("out-dir", "."));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating --out-dir {}", out_dir.display()))?;

    if args.flag("list") {
        for s in registry() {
            println!("{:<16} {} ({})", s.name, s.title, s.paper_ref);
        }
        return Ok(());
    }

    let suites: Vec<&str> = if args.flag("all") {
        registry().iter().map(|s| s.name).collect()
    } else {
        let name = args.get_or("suite", "scheme-compare");
        vec![
            suite_by_name(name)
                .with_context(|| {
                    format!("unknown suite {name:?}; `powersgd experiment --list` shows all")
                })?
                .name,
        ]
    };

    for name in suites {
        let run = run_suite(name, seed, quick)?;
        run.table().print();
        let path = run.write_json(&out_dir).context("writing the experiments JSON artifact")?;
        println!("wrote {} ({} records)", path.display(), run.records.len());
    }

    // The report always covers the full registry (the analytic tables
    // are cheap) plus the measured threaded-engine section, so any
    // single-suite invocation still yields the complete document;
    // `quick` only shrinks the measured configs. When the wire-check
    // suite itself was selected above, its measured runs execute a
    // second time here — the harness model is tiny (141 params, ≤ 3
    // steps), so re-running beats threading outcomes through the API.
    let report = write_report(&out_dir, seed, quick)?;
    println!("wrote {}", report.display());
    Ok(())
}

/// `powersgd bench-diff <old.json> <new.json>`: compare two
/// `BENCH_<name>.json` artifacts and print the markdown delta table.
/// `--tolerance R` sets the relative `*_ms` slowdown allowed (default
/// 0.25 = +25%; `*_bytes` metrics must match exactly); exits non-zero
/// on any regression. `--report-only` downgrades every failure to a
/// warning and exits 0 — the CI mode against baselines committed from a
/// different machine, where absolute timings are not comparable.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    use powersgd::util::benchdiff::{diff, parse_bench_json, DEFAULT_TOLERANCE};
    let [_, old_path, new_path] = args.positional() else {
        bail!("usage: powersgd bench-diff <old.json> <new.json> [--tolerance R] [--report-only]");
    };
    let read = |p: &str| -> Result<_> {
        let doc = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
        parse_bench_json(&doc).with_context(|| format!("parsing {p}"))
    };
    let (old, new) = (read(old_path)?, read(new_path)?);
    let tolerance = args.get_parsed_or("tolerance", DEFAULT_TOLERANCE);
    let report_only = args.flag("report-only");
    let report = diff(&old, &new, tolerance, report_only)?;
    println!("## Bench diff: {} ({old_path} → {new_path})\n", new.bench);
    print!("{}", report.to_markdown());
    if report.regressions > 0 {
        bail!(
            "{} metric(s) regressed beyond the {:.0}% tolerance",
            report.regressions,
            tolerance * 100.0
        );
    }
    println!("\nok: no regressions ({} metrics compared)", report.lines.len());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let rt = Runtime::cpu(dir)?;
    println!("platform: {}", rt.platform());
    for name in rt.available() {
        println!("  {name}");
    }
    Ok(())
}
