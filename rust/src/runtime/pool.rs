//! Persistent kernel thread pool: deterministic parallelism for the
//! compute hot path (DESIGN.md §11).
//!
//! PowerSGD's pitch (§4.2) is that compression is cheap enough to win
//! wall-clock; that only holds if the encode/decode kernels run as fast
//! as the hardware allows (Agarwal et al., Zhang et al. — PAPERS.md).
//! This module is the execution layer under `tensor::matmul` and
//! `linalg::gram_schmidt`: a process-wide pool of worker threads,
//! spawned once and reused for every kernel dispatch, with **bitwise
//! determinism across thread counts** as the hard invariant.
//!
//! The determinism contract, kernel by kernel:
//!
//! - Output-sharded kernels (`matmul_into`, `matmul_nt_into` over rows;
//!   `matmul_tn_into` over accumulator columns) partition *disjoint*
//!   output ranges. Every output element is produced by exactly one
//!   task with exactly the serial loop's per-element operation order,
//!   so the partition — and therefore the thread count — can never
//!   change a bit.
//! - Reductions ([`deterministic_sum`]) use a **fixed** chunk size
//!   ([`REDUCE_CHUNK`], never derived from the thread count): partials
//!   are exact serial sums over fixed element ranges, combined in a
//!   pairwise tree whose shape depends only on the input length.
//!   Inputs of ≤ `REDUCE_CHUNK` elements reduce in one chunk and are
//!   bit-identical to a plain serial sum.
//!
//! Thread count comes from `--threads` / `POWERSGD_THREADS`
//! ([`set_threads`] / [`threads`]); the default of 1 keeps every
//! kernel on the calling thread (and `run` short-circuits without
//! touching the pool at all). Worker threads are spawned lazily up to
//! the highest count ever requested and then live for the process
//! lifetime; concurrent dispatches from multiple caller threads (the
//! decentralized engine runs one compressor per worker thread) simply
//! queue on the same workers.
//!
//! Chunk tasks must be pure compute: a task that itself dispatched
//! pool work could deadlock two workers against each other. All
//! kernels in this crate dispatch only from caller threads.
//!
//! # Worked example
//!
//! The determinism contract, demonstrated: a multi-chunk reduction is
//! **bitwise identical** at every thread count (doctests run in their
//! own process, so flipping the global count here races nothing):
//!
//! ```
//! use powersgd::runtime::pool::{deterministic_sum, set_threads, REDUCE_CHUNK};
//!
//! let n = 3 * REDUCE_CHUNK + 17;
//! let xs: Vec<f64> = (0..n).map(|i| ((i * 13 + 7) as f64).cos()).collect();
//! set_threads(1);
//! let serial = deterministic_sum(n, |i| xs[i]);
//! set_threads(4);
//! let parallel = deterministic_sum(n, |i| xs[i]);
//! assert_eq!(serial.to_bits(), parallel.to_bits());
//! ```

use std::cell::RefCell;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Mutex, OnceLock};

/// Fixed element-chunk size of every deterministic reduction. Never
/// derived from the thread count, so the reduction tree is identical
/// at every thread count — and identical to the plain serial f64 sum
/// for inputs of at most this many elements.
pub const REDUCE_CHUNK: usize = 4096;

static THREADS: AtomicUsize = AtomicUsize::new(0);

/// The kernel thread count: `--threads` / [`set_threads`] if set,
/// otherwise `POWERSGD_THREADS`, otherwise 1 (serial).
pub fn threads() -> usize {
    match THREADS.load(Ordering::SeqCst) {
        0 => {
            let n = std::env::var("POWERSGD_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&n| n >= 1)
                .unwrap_or(1);
            THREADS.store(n, Ordering::SeqCst);
            n
        }
        n => n,
    }
}

/// Select the process-wide kernel thread count (clamped to ≥ 1).
/// Kernel results are bitwise-identical at every count, so this only
/// changes wall-clock, never training trajectories.
pub fn set_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::SeqCst);
}

/// Which GEMM / Gram–Schmidt implementations the crate dispatches to.
///
/// The two backends are *numerically* interchangeable (DESIGN.md §11
/// spells out, per kernel, whether they are bitwise-equal or
/// ULP-bounded), but only [`KernelBackend::Blocked`] is built for
/// speed. The reference backend exists so the differential harness in
/// `tests/integration_kernel_equiv.rs` has an executable specification
/// to compare against, and so the kernel benches can report an honest
/// blocked-vs-naive speedup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// Cache-blocked, register-tiled, explicitly vectorized kernels
    /// with packed panels in per-thread scratch (the default).
    Blocked,
    /// Naive textbook loops: serial per-element accumulation, no
    /// packing, no lane splitting. Slow, obviously correct.
    Reference,
}

/// 0 = unresolved, 1 = blocked, 2 = reference.
static BACKEND: AtomicUsize = AtomicUsize::new(0);

/// The active kernel backend: [`set_kernel_backend`] if called,
/// otherwise `POWERSGD_KERNEL_BACKEND=reference|blocked`, otherwise
/// [`KernelBackend::Blocked`].
pub fn kernel_backend() -> KernelBackend {
    match BACKEND.load(Ordering::SeqCst) {
        0 => {
            let b = match std::env::var("POWERSGD_KERNEL_BACKEND").as_deref() {
                Ok("reference") => KernelBackend::Reference,
                Ok("blocked") | Err(_) => KernelBackend::Blocked,
                Ok(other) => panic!(
                    "POWERSGD_KERNEL_BACKEND must be `reference` or `blocked`, got `{other}` \
                     (refusing to guess: a silent fallback would make a differential run vacuous)"
                ),
            };
            set_kernel_backend(b);
            b
        }
        2 => KernelBackend::Reference,
        _ => KernelBackend::Blocked,
    }
}

/// Select the process-wide kernel backend (tests and benches; the
/// training CLI always runs blocked).
pub fn set_kernel_backend(b: KernelBackend) {
    let v = match b {
        KernelBackend::Blocked => 1,
        KernelBackend::Reference => 2,
    };
    BACKEND.store(v, Ordering::SeqCst);
}

/// Times any per-thread kernel scratch slot below had to (re)grow.
/// After the first step warms every participating thread, this must
/// stay flat — the zero-alloc-steady-state leg of DESIGN.md §11,
/// asserted by
/// `proptest_invariants::prop_kernel_scratch_zero_alloc_after_first_step`.
static SCRATCH_GROWS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Packed-panel scratch (`matmul_into`'s Bᵀ, `matmul_nt_into`'s Qᵀ).
    static PANEL: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Accumulator-tile scratch (`matmul_tn_into`'s r×jb f32 tile).
    static TILE: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// f64 reduction partials (fused Gram–Schmidt norm/dot sweeps).
    static PARTIALS: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

fn with_slot<T: Copy + Default, R>(
    cell: &RefCell<Vec<T>>,
    len: usize,
    f: impl FnOnce(&mut [T]) -> R,
) -> R {
    let mut buf = cell.borrow_mut();
    if buf.len() < len {
        SCRATCH_GROWS.fetch_add(1, Ordering::Relaxed);
        buf.resize(len, T::default());
    }
    f(&mut buf[..len])
}

/// Hand `f` this thread's packed-panel scratch, grown to at least
/// `len` f32s (contents stale — the caller overwrites what it reads).
/// Calls must not nest on one thread: the slot is a single buffer.
pub fn with_panel<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    PANEL.with(|c| with_slot(c, len, f))
}

/// Hand `f` this thread's accumulator-tile scratch (`len` f32s, stale
/// contents). Separate from [`with_panel`] so a kernel that packs a
/// panel on the caller thread can still tile inside pool tasks that
/// happen to run on that same thread.
pub fn with_tile<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    TILE.with(|c| with_slot(c, len, f))
}

/// Hand `f` this thread's f64 reduction-partial scratch (`len` f64s,
/// stale contents).
pub fn with_partials<R>(len: usize, f: impl FnOnce(&mut [f64]) -> R) -> R {
    PARTIALS.with(|c| with_slot(c, len, f))
}

/// Cumulative process-wide count of kernel-scratch growth events
/// (monotone; diff two reads around a steady-state region to assert
/// zero allocation).
pub fn kernel_scratch_grows() -> u64 {
    SCRATCH_GROWS.load(Ordering::Relaxed)
}

/// Lifetime-erased shared task: the pool waits for every chunk's ack
/// before `run` returns, so the erased borrow never outlives the
/// caller's closure.
struct Task(&'static (dyn Fn(usize) + Sync));

struct Job {
    task: Task,
    start: usize,
    end: usize,
    /// `true` = all chunks ran to completion; `false` = a chunk panicked.
    ack: Sender<bool>,
}

/// The persistent pool. One per process ([`pool`]); worker threads are
/// spawned on first demand and reused for every later dispatch.
pub struct KernelPool {
    senders: Mutex<Vec<Sender<Job>>>,
}

static POOL: OnceLock<KernelPool> = OnceLock::new();

/// The process-wide kernel pool.
pub fn pool() -> &'static KernelPool {
    POOL.get_or_init(|| KernelPool { senders: Mutex::new(Vec::new()) })
}

impl KernelPool {
    /// Run `f(chunk)` for every `chunk ∈ [0, chunks)`, split over at
    /// most [`threads`] participants (the caller is one of them). Every
    /// chunk runs exactly once; the call returns only after all chunks
    /// finished, so `f` may borrow locals. Panics inside `f` propagate
    /// to the caller after every other chunk completed.
    pub fn run<F: Fn(usize) + Sync>(&self, chunks: usize, f: F) {
        self.run_dyn(chunks, &f)
    }

    fn run_dyn(&self, chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        let k = threads().min(chunks);
        if k <= 1 {
            for c in 0..chunks {
                f(c);
            }
            return;
        }
        // SAFETY: the erased reference is only used by jobs whose acks
        // are drained below before this frame returns (even when the
        // caller's own share panics), so it never outlives `f`.
        let raw: *const (dyn Fn(usize) + Sync) = f;
        let helpers = self.helper_senders(k - 1);
        let (ack, ack_rx) = mpsc::channel();
        let mut sent = 0usize;
        let mut send_failed = false;
        for (j, s) in helpers.iter().enumerate() {
            let job = Job {
                task: Task(unsafe { &*raw }),
                start: (j + 1) * chunks / k,
                end: (j + 2) * chunks / k,
                ack: ack.clone(),
            };
            if s.send(job).is_ok() {
                sent += 1;
            } else {
                send_failed = true;
            }
        }
        // The caller takes the first range; its panic (if any) must not
        // unwind past the outstanding borrows, so it is deferred until
        // every helper acked.
        let mine = std::panic::catch_unwind(AssertUnwindSafe(|| {
            for c in 0..chunks / k {
                f(c);
            }
        }));
        let mut ok = true;
        for _ in 0..sent {
            ok &= ack_rx.recv().expect("kernel pool worker thread died");
        }
        if let Err(p) = mine {
            std::panic::resume_unwind(p);
        }
        assert!(!send_failed, "kernel pool worker thread died");
        assert!(ok, "a kernel pool task panicked");
    }

    /// Clones of the first `n` worker senders, spawning missing workers.
    fn helper_senders(&self, n: usize) -> Vec<Sender<Job>> {
        let mut senders = self.senders.lock().expect("kernel pool poisoned");
        while senders.len() < n {
            let (tx, rx) = mpsc::channel();
            let id = senders.len();
            std::thread::Builder::new()
                .name(format!("powersgd-kernel-{id}"))
                .spawn(move || worker_loop(rx))
                .expect("spawning a kernel pool thread");
            senders.push(tx);
        }
        senders[..n].to_vec()
    }
}

fn worker_loop(rx: Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        let ok = std::panic::catch_unwind(AssertUnwindSafe(|| {
            // One span per dispatched job slice: pool threads show up
            // as their own `powersgd-kernel-{id}` tracks in a trace
            // (DESIGN.md §13); spans never touch the chunk data, so
            // the bitwise-determinism contract above is unaffected.
            let _span = crate::obs::span(crate::obs::Phase::PoolChunk);
            for c in job.start..job.end {
                (job.task.0)(c);
            }
        }))
        .is_ok();
        let _ = job.ack.send(ok);
    }
}

/// Run `f(start, end)` over a partition of `[0, total)` into contiguous
/// ranges — at most [`threads`] of them, each covering at least
/// `min_per` items (so tiny inputs stay on the calling thread). The
/// partition decides only *who* computes, never *what*: callers whose
/// per-element work is partition-independent are bitwise deterministic
/// at every thread count.
pub fn parallel_ranges<F: Fn(usize, usize) + Sync>(total: usize, min_per: usize, f: F) {
    if total == 0 {
        return;
    }
    let parts = total.div_ceil(min_per.max(1)).min(threads()).max(1);
    if parts <= 1 {
        f(0, total);
        return;
    }
    pool().run(parts, |j| {
        let start = j * total / parts;
        let end = (j + 1) * total / parts;
        if start < end {
            f(start, end);
        }
    });
}

/// Deterministic parallel sum of `value(i)` for `i ∈ [0, n)`:
/// fixed chunks of [`REDUCE_CHUNK`] elements, each summed serially in
/// f64, partials combined pairwise. The tree shape depends only on `n`
/// — bitwise identical at every thread count, and equal to a plain
/// serial f64 sum whenever `n ≤ REDUCE_CHUNK`.
pub fn deterministic_sum<F: Fn(usize) -> f64 + Sync>(n: usize, value: F) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let chunks = n.div_ceil(REDUCE_CHUNK);
    if chunks == 1 {
        let mut acc = 0.0;
        for i in 0..n {
            acc += value(i);
        }
        return acc;
    }
    // Partials live on the stack for every realistic n (the largest
    // paper layer has 28 869 rows → 8 chunks); huge inputs spill.
    let mut stack = [0.0f64; 64];
    let mut heap = Vec::new();
    let partials: &mut [f64] = if chunks <= stack.len() {
        &mut stack[..chunks]
    } else {
        heap.resize(chunks, 0.0);
        &mut heap[..]
    };
    {
        let slots = DisjointSlice::new(partials);
        let value = &value;
        parallel_ranges(chunks, 1, move |c0, c1| {
            // SAFETY: parallel_ranges hands out disjoint chunk ranges.
            let out = unsafe { slots.range_mut(c0, c1) };
            for (slot, c) in out.iter_mut().zip(c0..c1) {
                let start = c * REDUCE_CHUNK;
                let end = ((c + 1) * REDUCE_CHUNK).min(n);
                let mut acc = 0.0;
                for i in start..end {
                    acc += value(i);
                }
                *slot = acc;
            }
        });
    }
    pairwise_sum(partials)
}

/// Pairwise (tree) combination; the shape depends only on the length.
fn pairwise_sum(xs: &[f64]) -> f64 {
    match xs.len() {
        0 => 0.0,
        1 => xs[0],
        n => {
            let mid = n / 2;
            pairwise_sum(&xs[..mid]) + pairwise_sum(&xs[mid..])
        }
    }
}

/// Shared handle over a mutable slice for writers that own disjoint
/// ranges — the sharding pattern of every parallel kernel. The borrow
/// of the underlying slice lives as long as the handle, so the usual
/// aliasing guarantees hold *between* concurrent `range_mut` calls
/// only if their ranges do not overlap (the caller's obligation).
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _borrow: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: access is only through `range_mut`, whose contract requires
// disjoint ranges across concurrent users; T crosses threads by &mut.
unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}
unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    /// Wrap a mutable slice for disjoint-range concurrent writes.
    pub fn new(slice: &'a mut [T]) -> DisjointSlice<'a, T> {
        DisjointSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _borrow: std::marker::PhantomData,
        }
    }

    /// Mutable subslice `[start, end)` of the underlying slice.
    ///
    /// # Safety
    /// Concurrent callers must request non-overlapping ranges.
    #[allow(clippy::mut_from_ref)] // the whole point: disjoint &mut views
    pub unsafe fn range_mut(&self, start: usize, end: usize) -> &mut [T] {
        assert!(start <= end && end <= self.len, "disjoint range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), end - start)
    }
}

/// Serializes tests that assert on the process-wide thread count (the
/// kernels themselves are thread-count invariant, so everything else
/// can race freely) and restores the ambient count on drop — so a
/// `POWERSGD_THREADS=4` CI run keeps the rest of the suite at 4
/// threads after a sweep finishes.
#[cfg(test)]
pub(crate) struct TestGuard {
    _lock: std::sync::MutexGuard<'static, ()>,
    ambient: usize,
}

#[cfg(test)]
impl Drop for TestGuard {
    fn drop(&mut self) {
        set_threads(self.ambient);
    }
}

#[cfg(test)]
pub(crate) fn test_guard() -> TestGuard {
    static LOCK: Mutex<()> = Mutex::new(());
    let lock = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    TestGuard { _lock: lock, ambient: threads() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_count_resolves_and_clamps() {
        let _g = test_guard();
        assert!(threads() >= 1);
        set_threads(0);
        assert_eq!(threads(), 1);
        set_threads(3);
        assert_eq!(threads(), 3);
    }

    #[test]
    fn run_covers_every_chunk_exactly_once() {
        let _g = test_guard();
        set_threads(4);
        let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
        pool().run(23, |c| {
            hits[c].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_ranges_partition_is_disjoint_and_complete() {
        let _g = test_guard();
        set_threads(8);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_ranges(1000, 16, |s, e| {
            for h in &hits[s..e] {
                h.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        // min_per keeps small totals inline: one covering range.
        let mut calls = Vec::new();
        {
            let calls = Mutex::new(&mut calls);
            parallel_ranges(10, 100, |s, e| calls.lock().unwrap().push((s, e)));
        }
        assert_eq!(calls, vec![(0, 10)]);
    }

    #[test]
    fn deterministic_sum_matches_serial_below_one_chunk() {
        let _g = test_guard();
        set_threads(4);
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37 + 11) as f64).sin()).collect();
        let serial: f64 = xs.iter().sum();
        let got = deterministic_sum(xs.len(), |i| xs[i]);
        assert_eq!(got.to_bits(), serial.to_bits());
    }

    #[test]
    fn deterministic_sum_is_thread_count_invariant() {
        let _g = test_guard();
        let n = 3 * REDUCE_CHUNK + 17;
        let xs: Vec<f64> = (0..n).map(|i| ((i * 13 + 7) as f64).cos()).collect();
        set_threads(1);
        let want = deterministic_sum(n, |i| xs[i]);
        for t in [2usize, 4, 8] {
            set_threads(t);
            let got = deterministic_sum(n, |i| xs[i]);
            assert_eq!(got.to_bits(), want.to_bits(), "t={t}");
        }
    }

    #[test]
    fn disjoint_slice_writes_land() {
        let mut data = vec![0u32; 100];
        let s = DisjointSlice::new(&mut data);
        unsafe { s.range_mut(0, 50) }.fill(1);
        unsafe { s.range_mut(50, 100) }.fill(2);
        drop(s);
        assert!(data[..50].iter().all(|&v| v == 1));
        assert!(data[50..].iter().all(|&v| v == 2));
    }

    #[test]
    fn kernel_backend_resolves_and_is_stable() {
        // Only resolution is tested here: actually flipping the global
        // backend would race the run-vs-run bitwise tests elsewhere in
        // this binary. Set/get and cross-backend dispatch are exercised
        // in tests/integration_kernel_equiv.rs, which owns its process
        // and serializes every test.
        let first = kernel_backend(); // forces env resolution
        assert_eq!(kernel_backend(), first);
        assert!(matches!(first, KernelBackend::Blocked | KernelBackend::Reference));
    }

    #[test]
    fn scratch_slots_grow_once_then_reuse() {
        // The grow counter is process-global and other unit tests run
        // kernels concurrently, so this test only makes assertions that
        // concurrent growth cannot falsify: growth strictly increases
        // when a *fresh* thread warms its slots, and a slot's storage
        // persists across calls on one thread (the reuse leg proper is
        // pinned, under a lock, in proptest_invariants.rs).
        let before = kernel_scratch_grows();
        std::thread::spawn(|| {
            with_panel(256, |b| b[255] = 1.5);
            with_tile(256, |b| b[0] = 2.5);
            with_partials(256, |b| b[0] = 3.5);
            // Same thread, same-or-smaller requests: contents persist.
            with_panel(16, |b| assert_eq!(b.len(), 16));
            with_panel(256, |b| assert_eq!(b[255], 1.5));
            with_partials(256, |b| assert_eq!(b[0], 3.5));
        })
        .join()
        .expect("scratch warm thread");
        assert!(kernel_scratch_grows() >= before + 3, "fresh thread must grow all slots");
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let _g = test_guard();
        set_threads(4);
        let r = std::panic::catch_unwind(|| {
            pool().run(8, |c| {
                assert!(c != 7, "boom");
            });
        });
        assert!(r.is_err(), "panic in a chunk must propagate");
        // The pool keeps working after a task panicked.
        let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        pool().run(8, |c| {
            hits[c].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }
}
