//! Artifact manifest: the I/O contract emitted by `python/compile/aot.py`
//! next to every HLO text file.
//!
//! Line-based format (one artifact per file):
//!
//! ```text
//! artifact mlp_train_step
//! input  w1 f32 64,128
//! input  x  f32 32,64
//! input  y  i32 32
//! output loss    f32 -
//! output grad.w1 f32 64,128
//! param  w1
//! meta   batch_per_worker 32
//! ```
//!
//! `-` denotes a scalar (rank-0) shape. `param` lines mark which inputs
//! are trainable parameters, in optimizer order; remaining inputs are
//! per-step data. `meta` lines are free-form key/value pairs.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// Element type of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer (labels, token ids).
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?} (expected f32/i32)"),
        }
    }
}

/// One input or output tensor description.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    /// Tensor name as the manifest declares it.
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Empty = scalar.
    pub shape: Vec<usize>,
}

impl IoSpec {
    /// Element count (1 for scalars).
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parameter initialization directive (emitted by aot.py so the Rust
/// trainer replays exactly what the model author intended).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Init {
    /// All zeros (biases).
    Zero,
    /// All ones (norm scales).
    One,
    /// N(0, sigma²) i.i.d.
    Normal(f32),
}

impl Init {
    fn parse(s: &str) -> Result<Init> {
        if s == "zero" {
            Ok(Init::Zero)
        } else if s == "one" {
            Ok(Init::One)
        } else if let Some(sig) = s.strip_prefix("normal:") {
            Ok(Init::Normal(sig.parse::<f32>().map_err(|e| anyhow!("bad sigma {sig:?}: {e}"))?))
        } else {
            bail!("unknown init {s:?} (zero|one|normal:<sigma>)")
        }
    }
}

/// Parsed manifest for one artifact.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    /// Artifact name (the `artifact` line).
    pub name: String,
    /// Declared inputs, in call order.
    pub inputs: Vec<IoSpec>,
    /// Declared outputs, in return order.
    pub outputs: Vec<IoSpec>,
    /// Names of inputs that are trainable parameters, in order.
    pub params: Vec<String>,
    /// Per-parameter init directives, same order as `params`.
    pub inits: Vec<Init>,
    /// Free-form key/value metadata (`meta` lines).
    pub meta: HashMap<String, String>,
}

impl ArtifactManifest {
    /// Parse from the text format above.
    pub fn parse(text: &str) -> Result<ArtifactManifest> {
        let mut m = ArtifactManifest::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let tag = parts.next().unwrap();
            let ctx = || format!("manifest line {}: {raw:?}", lineno + 1);
            match tag {
                "artifact" => {
                    m.name = parts.next().ok_or_else(|| anyhow!("missing name")).with_context(ctx)?.to_string();
                }
                "input" | "output" => {
                    let name = parts.next().ok_or_else(|| anyhow!("missing io name")).with_context(ctx)?;
                    let dtype = DType::parse(parts.next().ok_or_else(|| anyhow!("missing dtype")).with_context(ctx)?)?;
                    let shape_s = parts.next().ok_or_else(|| anyhow!("missing shape")).with_context(ctx)?;
                    let shape: Vec<usize> = if shape_s == "-" {
                        vec![]
                    } else {
                        shape_s
                            .split(',')
                            .map(|d| d.parse::<usize>().map_err(|e| anyhow!("bad dim {d:?}: {e}")))
                            .collect::<Result<_>>()
                            .with_context(ctx)?
                    };
                    let spec = IoSpec { name: name.to_string(), dtype, shape };
                    if tag == "input" {
                        m.inputs.push(spec);
                    } else {
                        m.outputs.push(spec);
                    }
                }
                "param" => {
                    m.params.push(
                        parts.next().ok_or_else(|| anyhow!("missing param name")).with_context(ctx)?.to_string(),
                    );
                    m.inits.push(match parts.next() {
                        Some(tok) => Init::parse(tok).with_context(ctx)?,
                        None => Init::Zero,
                    });
                }
                "meta" => {
                    let k = parts.next().ok_or_else(|| anyhow!("missing meta key")).with_context(ctx)?;
                    let v = parts.collect::<Vec<_>>().join(" ");
                    m.meta.insert(k.to_string(), v);
                }
                other => bail!("unknown manifest tag {other:?} at line {}", lineno + 1),
            }
        }
        if m.name.is_empty() {
            bail!("manifest has no `artifact` line");
        }
        // Every declared param must exist among inputs.
        for p in &m.params {
            if !m.inputs.iter().any(|i| &i.name == p) {
                bail!("param {p:?} not among inputs");
            }
        }
        Ok(m)
    }

    /// Read and parse a manifest file.
    pub fn load(path: &std::path::Path) -> Result<ArtifactManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    /// Input specs for the trainable parameters, in `params` order.
    pub fn param_specs(&self) -> Vec<&IoSpec> {
        self.params
            .iter()
            .map(|p| self.inputs.iter().find(|i| &i.name == p).unwrap())
            .collect()
    }

    /// Input specs that are NOT parameters (per-step data), in input order.
    pub fn data_specs(&self) -> Vec<&IoSpec> {
        self.inputs
            .iter()
            .filter(|i| !self.params.contains(&i.name))
            .collect()
    }

    /// Build a [`crate::grad::ParamRegistry`] over the parameter inputs.
    pub fn param_registry(&self) -> crate::grad::ParamRegistry {
        let named: Vec<(&str, Vec<usize>)> = self
            .param_specs()
            .iter()
            .map(|s| (s.name.as_str(), if s.shape.is_empty() { vec![1] } else { s.shape.clone() }))
            .collect();
        crate::grad::ParamRegistry::from_shapes(&named)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
artifact mlp_train_step
input w1 f32 64,128
input b1 f32 128
input x f32 32,64
input y i32 32
output loss f32 -
output grad.w1 f32 64,128
output grad.b1 f32 128
param w1 normal:0.125
param b1 zero
meta batch_per_worker 32
";

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "mlp_train_step");
        assert_eq!(m.inputs.len(), 4);
        assert_eq!(m.outputs.len(), 3);
        assert_eq!(m.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(m.inputs[3].dtype, DType::I32);
        assert_eq!(m.params, vec!["w1", "b1"]);
        assert_eq!(m.inits, vec![Init::Normal(0.125), Init::Zero]);
        assert_eq!(m.meta["batch_per_worker"], "32");
    }

    #[test]
    fn param_and_data_split() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        let ps = m.param_specs();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].shape, vec![64, 128]);
        let ds = m.data_specs();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0].name, "x");
    }

    #[test]
    fn registry_from_manifest() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        let reg = m.param_registry();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.numel(), 64 * 128 + 128);
    }

    #[test]
    fn rejects_unknown_param() {
        let bad = "artifact a\ninput x f32 2\nparam nope\n";
        assert!(ArtifactManifest::parse(bad).is_err());
    }

    #[test]
    fn init_parsing() {
        let m = ArtifactManifest::parse(
            "artifact a\ninput x f32 2\ninput s f32 2\nparam x one\nparam s\n",
        )
        .unwrap();
        assert_eq!(m.inits, vec![Init::One, Init::Zero]);
        assert!(ArtifactManifest::parse("artifact a\ninput x f32 2\nparam x banana\n").is_err());
    }

    #[test]
    fn rejects_missing_name_and_bad_dtype() {
        assert!(ArtifactManifest::parse("input x f32 2\n").is_err());
        assert!(ArtifactManifest::parse("artifact a\ninput x f64 2\n").is_err());
    }
}
