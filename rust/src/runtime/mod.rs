//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! the coordinator's hot path.
//!
//! `python/compile/aot.py` lowers every L2 JAX computation **once** to
//! HLO *text* (`artifacts/<name>.hlo.txt`) plus a manifest
//! (`artifacts/<name>.manifest`). This module loads the text, compiles it
//! on the PJRT CPU client (one compile per artifact per process, cached),
//! and exposes typed `execute` over [`crate::tensor::Tensor`]s.
//!
//! HLO text — not serialized `HloModuleProto` — is the interchange
//! format: jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).

mod manifest;
pub mod pool;
pub use manifest::{ArtifactManifest, DType, Init, IoSpec};

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// An input value for artifact execution.
#[derive(Debug, Clone)]
pub enum Value {
    /// An f32 tensor (parameters, features).
    F32(Tensor),
    /// An i32 tensor as `(shape, data)` (labels, token ids).
    I32(Vec<usize>, Vec<i32>),
}

impl Value {
    /// The value's shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(s, _) => s,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
            Value::I32(_, v) => xla::Literal::vec1(v).reshape(&dims)?,
        };
        Ok(lit)
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

/// One compiled artifact: PJRT executable + manifest.
pub struct Artifact {
    /// The artifact's parsed I/O contract.
    pub manifest: ArtifactManifest,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with positional inputs matching `manifest.inputs` order.
    /// Returns f32 outputs as [`Tensor`]s (scalars become shape `[1]`).
    pub fn execute(&self, inputs: &[Value]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "artifact {}: got {} inputs, manifest declares {}",
                self.manifest.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        for (v, spec) in inputs.iter().zip(self.manifest.inputs.iter()) {
            let expect: &[usize] = &spec.shape;
            if v.shape() != expect {
                bail!(
                    "artifact {}: input {} shape {:?} != manifest {:?}",
                    self.manifest.name,
                    spec.name,
                    v.shape(),
                    expect
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result.to_tuple()?;
        if parts.len() != self.manifest.outputs.len() {
            bail!(
                "artifact {}: got {} outputs, manifest declares {}",
                self.manifest.name,
                parts.len(),
                self.manifest.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(self.manifest.outputs.iter()) {
            let data = lit
                .to_vec::<f32>()
                .with_context(|| format!("output {} to_vec", spec.name))?;
            let shape: Vec<usize> = if spec.shape.is_empty() { vec![1] } else { spec.shape.clone() };
            out.push(Tensor::from_vec(&shape, data));
        }
        Ok(out)
    }
}

/// PJRT runtime with an artifact registry: each artifact is compiled at
/// most once per process and cached by name.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, std::sync::Arc<Artifact>>,
}

impl Runtime {
    /// CPU-backed runtime rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir: artifacts_dir.as_ref().to_path_buf(), cache: HashMap::new() })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (or fetch from cache) the named artifact.
    pub fn load(&mut self, name: &str) -> Result<std::sync::Arc<Artifact>> {
        if let Some(a) = self.cache.get(name) {
            return Ok(a.clone());
        }
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let man_path = self.dir.join(format!("{name}.manifest"));
        let manifest = ArtifactManifest::load(&man_path)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .with_context(|| format!("non-utf8 path {}", hlo_path.display()))?,
        )
        .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let artifact = std::sync::Arc::new(Artifact { manifest, exe });
        self.cache.insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Names of all artifacts present in the directory (by `.manifest`).
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        let n = e.file_name().to_string_lossy().to_string();
                        n.strip_suffix(".manifest").map(|s| s.to_string())
                    })
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/integration_runtime.rs
    // (they need `make artifacts` to have run). Here: Value conversions.

    #[test]
    fn value_shapes() {
        let v = Value::F32(Tensor::zeros(&[2, 3]));
        assert_eq!(v.shape(), &[2, 3]);
        let i = Value::I32(vec![4], vec![1, 2, 3, 4]);
        assert_eq!(i.shape(), &[4]);
    }

    #[test]
    fn tensor_into_value() {
        let v: Value = Tensor::zeros(&[5]).into();
        assert!(matches!(v, Value::F32(_)));
    }
}
