//! Bounded exponential backoff with deterministic jitter.
//!
//! Every connect path in the TCP transport — rendezvous control
//! connections, initial ring-edge dials, and elastic re-formation
//! reconnects (DESIGN.md §16) — retries through one of these policies
//! instead of making a single timed-out attempt. The delay for attempt
//! `k` grows as `base · 2^k`, capped at `cap`, with ±50% jitter drawn
//! from a seeded [`Rng`] so two ranks hammering the same listener
//! desynchronize without making test runs timing-dependent.
//!
//! Each retry (every attempt after the first) bumps the policy's own
//! [`Backoff::attempts`] tally — workers sum their policies' tallies
//! into the `reconnect_attempts` field of their end-of-run `Report`,
//! which the coordinator reconciles cluster-wide — and additionally
//! increments the process-global
//! [`Counter::ReconnectAttempts`](crate::obs::metrics::Counter)
//! metrics counter for `--metrics` snapshots.

use crate::obs::metrics::{self, Counter};
use crate::util::Rng;
use std::time::{Duration, Instant};

/// A bounded exponential backoff policy. Construct once per connect
/// site and drive it with [`Backoff::run`].
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    max_retries: u32,
    rng: Rng,
    attempts: u64,
}

impl Backoff {
    /// Policy with explicit base delay, delay cap, and retry budget.
    /// `seed` only perturbs the jitter; it never changes the bounds.
    pub fn new(base: Duration, cap: Duration, max_retries: u32, seed: u64) -> Backoff {
        Backoff { base, cap, max_retries, rng: Rng::new(seed ^ 0xB0FF), attempts: 0 }
    }

    /// The standard connect policy: 10 ms base, 500 ms cap.
    pub fn standard(max_retries: u32, seed: u64) -> Backoff {
        Backoff::new(Duration::from_millis(10), Duration::from_millis(500), max_retries, seed)
    }

    /// Retry budget (attempts beyond the first).
    pub fn max_retries(&self) -> u32 {
        self.max_retries
    }

    /// Retries this policy has performed so far, summed across every
    /// [`Backoff::run`] call (each call's first attempt is free). This
    /// is the per-worker count that ends up in the `Report` frame — a
    /// local tally, so concurrent in-process workers never see each
    /// other's retries.
    pub fn attempts(&self) -> u64 {
        self.attempts
    }

    /// Jittered delay before retry number `attempt` (0-based): the
    /// capped exponential `base · 2^attempt`, scaled into `[50%, 100%]`
    /// by the seeded jitter draw.
    pub fn delay(&mut self, attempt: u32) -> Duration {
        let exp = self.base.saturating_mul(1u32 << attempt.min(16)).min(self.cap);
        let nanos = exp.as_nanos() as u64;
        let half = nanos / 2;
        let jittered = half + self.rng.below(half.max(1));
        Duration::from_nanos(jittered)
    }

    /// Run `f` until it succeeds, the retry budget is spent, or the
    /// next sleep would cross `deadline`. Returns the last error when
    /// giving up. Every retry bumps [`Backoff::attempts`] and the
    /// `reconnect_attempts` metrics counter.
    pub fn run<T, E>(
        &mut self,
        deadline: Instant,
        mut f: impl FnMut() -> Result<T, E>,
    ) -> Result<T, E> {
        let mut attempt = 0u32;
        loop {
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    let delay = self.delay(attempt);
                    let now = Instant::now();
                    if attempt >= self.max_retries || now + delay >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(delay);
                    self.attempts += 1;
                    metrics::add(Counter::ReconnectAttempts, 1);
                    attempt += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_grow_and_cap() {
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_millis(80), 8, 1);
        // Jitter keeps each delay within [50%, 100%] of the exponential.
        for (attempt, cap_ms) in [(0u32, 10u64), (1, 20), (2, 40), (3, 80), (4, 80), (10, 80)] {
            let d = b.delay(attempt);
            assert!(d <= Duration::from_millis(cap_ms), "attempt {attempt}: {d:?}");
            assert!(d >= Duration::from_millis(cap_ms / 2), "attempt {attempt}: {d:?}");
        }
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let mut a = Backoff::standard(3, 42);
        let mut b = Backoff::standard(3, 42);
        for attempt in 0..5 {
            assert_eq!(a.delay(attempt), b.delay(attempt));
        }
    }

    #[test]
    fn run_stops_at_retry_budget() {
        let mut b = Backoff::new(Duration::from_micros(10), Duration::from_micros(10), 2, 7);
        let mut calls = 0;
        let r: Result<(), &str> = b.run(Instant::now() + Duration::from_secs(5), || {
            calls += 1;
            Err("nope")
        });
        assert_eq!(r.unwrap_err(), "nope");
        assert_eq!(calls, 3); // first attempt + 2 retries
    }

    #[test]
    fn run_respects_deadline() {
        let mut b = Backoff::new(Duration::from_secs(10), Duration::from_secs(10), 100, 7);
        let mut calls = 0;
        // Next sleep (≥5 s) would blow the 10 ms deadline: exactly one attempt.
        let r: Result<(), &str> = b.run(Instant::now() + Duration::from_millis(10), || {
            calls += 1;
            Err("down")
        });
        assert!(r.is_err());
        assert_eq!(calls, 1);
    }

    #[test]
    fn attempts_tally_accumulates_across_runs() {
        let mut b = Backoff::new(Duration::from_micros(10), Duration::from_micros(10), 2, 7);
        assert_eq!(b.attempts(), 0);
        let r: Result<(), &str> =
            b.run(Instant::now() + Duration::from_secs(5), || Err("nope"));
        assert!(r.is_err());
        assert_eq!(b.attempts(), 2); // budget of 2 retries after the first try
        let mut calls = 0;
        let r: Result<(), &str> = b.run(Instant::now() + Duration::from_secs(5), || {
            calls += 1;
            if calls < 2 {
                Err("again")
            } else {
                Ok(())
            }
        });
        assert!(r.is_ok());
        assert_eq!(b.attempts(), 3); // one more retry, summed with the first run's
    }

    #[test]
    fn run_returns_first_success() {
        let mut b = Backoff::new(Duration::from_micros(10), Duration::from_micros(50), 10, 7);
        let mut calls = 0;
        let r: Result<u32, &str> = b.run(Instant::now() + Duration::from_secs(5), || {
            calls += 1;
            if calls < 3 {
                Err("not yet")
            } else {
                Ok(99)
            }
        });
        assert_eq!(r.unwrap(), 99);
        assert_eq!(calls, 3);
    }
}
