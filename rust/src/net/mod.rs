//! α–β network cost model for the simulated cluster.
//!
//! The paper's testbed (Appendix B): 8 nodes × 2 GPUs, 10 Gbit/s
//! Ethernet, PyTorch NCCL and GLOO backends. We price each collective
//! with the standard latency–bandwidth model and calibrate the constants
//! so the end-to-end per-batch times of Tables 3–7 are reproduced (see
//! the `calibration` tests below and the generated `REPORT.md` from
//! `powersgd experiment`, DESIGN.md §12):
//!
//! - ring all-reduce: `t = 2(W−1)·α + 2·(W−1)/W · S/β`
//! - all-gather:      `t = (W−1)·α + (W−1) · S/β`  (S = per-worker msg)
//! - reduce+broadcast (parameter server): `t = 2(W−1)·(α + S/β)`
//!
//! Decode cost after an all-gather scales with W (each worker unpacks
//! W messages) — that is modeled in the simulator, not here.

pub mod backoff;

use crate::collectives::{CollKind, CollOp};

/// A communication backend profile.
// `Eq` is intentionally not derived: the f64 fields make equality only
// partial (NaN). `simulate::Scheme`, whose fields are integers, does
// derive it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Backend {
    /// Display name ("NCCL" / "GLOO").
    pub name: &'static str,
    /// Per-hop latency, seconds.
    pub alpha: f64,
    /// Effective bandwidth, bytes/second.
    pub beta: f64,
}

/// NCCL on 10 Gbit/s Ethernet: near line-rate for large messages,
/// ~30 µs hop latency. Calibrated so an 83 MB ResNet18 all-reduce over
/// 16 workers costs ≈ 73 ms, matching Table 3's SGD row (312 ms total
/// with fwd+bwd ≈ 235 ms).
pub const NCCL: Backend = Backend { name: "NCCL", alpha: 30e-6, beta: 1.10e9 };

/// GLOO: the slower CPU-mediated backend — higher latency, lower
/// effective bandwidth (Appendix B's measurements show ≈2–3× slower
/// collectives at these message sizes).
pub const GLOO: Backend = Backend { name: "GLOO", alpha: 200e-6, beta: 0.40e9 };

impl Backend {
    /// Time (seconds) for one collective op with per-worker message size
    /// `bytes` across `w` workers.
    pub fn time(&self, kind: CollKind, bytes: u64, w: usize) -> f64 {
        if w <= 1 {
            return 0.0;
        }
        let s = bytes as f64;
        let wf = w as f64;
        match kind {
            CollKind::AllReduce => {
                2.0 * (wf - 1.0) * self.alpha + 2.0 * (wf - 1.0) / wf * s / self.beta
            }
            CollKind::AllGather => (wf - 1.0) * self.alpha + (wf - 1.0) * s / self.beta,
            CollKind::ReduceBroadcast => 2.0 * (wf - 1.0) * (self.alpha + s / self.beta),
        }
    }

    /// Total time for a logged sequence of ops.
    pub fn time_ops(&self, ops: &[CollOp], w: usize) -> f64 {
        ops.iter().map(|o| self.time(o.kind, o.bytes, w)).sum()
    }
}

/// Look up a backend profile by (case-insensitive) name.
pub fn backend_by_name(name: &str) -> Option<Backend> {
    match name.to_ascii_lowercase().as_str() {
        "nccl" => Some(NCCL),
        "gloo" => Some(GLOO),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_scaling_is_sublinear_in_w() {
        // Ring all-reduce bandwidth term saturates at 2·S/β: doubling W
        // from 8 to 16 must barely change the time for large S.
        let s = 43_000_000u64;
        let t8 = NCCL.time(CollKind::AllReduce, s, 8);
        let t16 = NCCL.time(CollKind::AllReduce, s, 16);
        assert!(t16 < t8 * 1.15, "{t8} -> {t16}");
    }

    #[test]
    fn allgather_scales_linearly_in_w() {
        let s = 1_000_000u64;
        let t4 = NCCL.time(CollKind::AllGather, s, 4);
        let t16 = NCCL.time(CollKind::AllGather, s, 16);
        assert!(t16 > 3.0 * t4, "{t4} -> {t16}");
    }

    #[test]
    fn gloo_slower_than_nccl() {
        for &(kind, s) in &[
            (CollKind::AllReduce, 43_000_000u64),
            (CollKind::AllGather, 1_000_000),
            (CollKind::ReduceBroadcast, 10_000_000),
        ] {
            assert!(GLOO.time(kind, s, 16) > NCCL.time(kind, s, 16));
        }
    }

    #[test]
    fn single_worker_is_free() {
        assert_eq!(NCCL.time(CollKind::AllReduce, 1 << 20, 1), 0.0);
    }

    #[test]
    fn calibration_resnet18_sgd_comm() {
        // Table 3: SGD on ResNet18, 16 workers — total 312 ms with
        // fwd+bwd ≈ 235 ms ⇒ comm ≈ 75 ms for the 43 MB gradient.
        let t = NCCL.time(CollKind::AllReduce, 43_000_000, 16) * 1e3;
        assert!((60.0..95.0).contains(&t), "ResNet comm {t} ms");
    }

    #[test]
    fn calibration_lstm_sgd_comm() {
        // Table 7: SGD on the LSTM — total 300 ms with fwd+bwd ≈ 125 ms
        // ⇒ comm ≈ 175 ms for the 110 MB gradient.
        let t = NCCL.time(CollKind::AllReduce, 110_000_000, 16) * 1e3;
        assert!((150.0..220.0).contains(&t), "LSTM comm {t} ms");
    }

    #[test]
    fn powersgd_rank2_comm_is_negligible() {
        // Rank-2 ResNet18 message ≈ 0.33 MB ⇒ well under 5 ms.
        let t = NCCL.time(CollKind::AllReduce, 330_000, 16) * 1e3;
        assert!(t < 5.0, "rank-2 comm {t} ms");
    }

    #[test]
    fn lookup() {
        assert_eq!(backend_by_name("nccl").unwrap().name, "NCCL");
        assert_eq!(backend_by_name("GLOO").unwrap().name, "GLOO");
        assert!(backend_by_name("mpi").is_none());
    }
}
