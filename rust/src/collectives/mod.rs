//! In-process collective communication over simulated workers.
//!
//! The algorithmic semantics are *exact* — ring all-reduce really moves
//! chunks between per-worker buffers in W−1 reduce-scatter steps plus
//! W−1 all-gather steps, so associativity/ordering effects and byte
//! counts are faithful. Only wall-clock *network* time is simulated (the
//! α–β cost model lives in [`crate::net`]; this module records what was
//! communicated in a [`CommLog`]).
//!
//! Three aggregation strategies from the paper (§3 "Efficient
//! aggregation"):
//! - [`all_reduce_mean`] — ring all-reduce; requires *linear* compressors.
//! - [`all_gather`] — every worker receives every worker's message;
//!   required by sign/top-K/Atomo (decode cost scales with W, Table 5).
//! - parameter-server (reduce + broadcast) is priced by the cost model
//!   for comparison (Appendix B) but all algorithms in the paper's main
//!   experiments use one of the two above.
//!
//! Every collective here dispatches on the engine carried by the
//! [`CommLog`] it records into ([`CommLog::on`] selects it;
//! `CommLog::default()` is the lockstep oracle): `Lockstep` runs the
//! sequential reference implementation on the caller's thread,
//! `Threaded` runs the channel-based ring in [`crate::transport`] with
//! one OS thread per worker. The engine is per-run configuration, not
//! process state — two logs with different engines coexist in one
//! process. Both engines produce bitwise-identical results (the
//! lockstep path is the oracle the threaded engine is tested against),
//! so the switch never changes training trajectories.
//!
//! These entry points take *all* workers' buffers at once — the
//! centralized view the oracle compressors use. The decentralized
//! per-worker path ([`crate::compress::WorkerCompressor`]) instead
//! calls the per-worker collective halves in
//! [`crate::transport::ring`] directly from each worker thread, with
//! identical chunk schedules and identical [`CommLog`] accounting.
//!
//! # Worked example
//!
//! Average three workers' buffers with a real chunked ring all-reduce
//! and read the traffic off the log:
//!
//! ```
//! use powersgd::collectives::{all_reduce_mean, CollKind, CommLog};
//!
//! let mut bufs = vec![vec![1.0f32, 3.0], vec![2.0, 4.0], vec![3.0, 5.0]];
//! let mut log = CommLog::default();
//! all_reduce_mean(&mut bufs, &mut log);
//! // Every worker holds the identical mean afterwards.
//! assert_eq!(bufs[0], vec![2.0, 4.0]);
//! assert_eq!(bufs[1], bufs[0]);
//! // The log records the *logical* per-worker message (the paper's
//! // data-volume unit): one all-reduce of two f32s.
//! assert_eq!(log.ops[0].kind, CollKind::AllReduce);
//! assert_eq!(log.bytes_sent(), 2 * 4);
//! ```

use crate::transport::EngineKind;
use std::sync::Arc;

/// What kind of collective an operation used.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollKind {
    /// Ring all-reduce (linear compressors, uncompressed vectors).
    AllReduce,
    /// Ring all-gather (sign/top-K/Atomo messages).
    AllGather,
    /// Parameter-server style reduce + broadcast (priced, not executed).
    ReduceBroadcast,
}

/// One logged collective operation. `bytes` is the per-worker message
/// size (the paper's "data sent per epoch" accounting unit).
#[derive(Debug, Clone, Copy)]
pub struct CollOp {
    /// Which collective ran.
    pub kind: CollKind,
    /// Per-worker message bytes (logical, not the ring expansion).
    pub bytes: u64,
}

/// Log of collective traffic for one step (or one epoch), plus the
/// engine its collectives execute on. `CommLog::default()` runs the
/// lockstep oracle; [`CommLog::on`] selects explicitly. The engine
/// rides on the log — the one value already threaded through every
/// collective call — so engine choice is per-run configuration and two
/// engines can coexist in one process.
#[derive(Debug, Clone, Default)]
pub struct CommLog {
    /// Logged operations, in execution order.
    pub ops: Vec<CollOp>,
    /// Execution substrate for collectives recorded into this log.
    pub engine: EngineKind,
}

impl CommLog {
    /// An empty log whose collectives run on `engine`.
    pub fn on(engine: EngineKind) -> CommLog {
        CommLog { ops: Vec::new(), engine }
    }

    /// Append one collective operation.
    pub fn record(&mut self, kind: CollKind, bytes: u64) {
        self.ops.push(CollOp { kind, bytes });
    }

    /// Total per-worker bytes sent (paper's data-volume metric).
    pub fn bytes_sent(&self) -> u64 {
        self.ops.iter().map(|o| o.bytes).sum()
    }

    /// Forget every logged operation.
    pub fn clear(&mut self) {
        self.ops.clear();
    }
}

/// Exact payload bytes a single worker puts on the wire for one ring
/// collective carrying a `msg_bytes`-byte per-worker message — the
/// *measured* counterpart of the logical [`CommLog`] unit (which
/// records the message size once, not the ring expansion). A metered
/// transport must observe exactly this many sent bytes per collective;
/// `transport::tcp` cross-checks it on every multi-process run.
///
/// - **All-reduce** (f32 payload): the two-phase ring sends `2(W−1)`
///   chunks; chunk `c` covers values `[c·n/W, (c+1)·n/W)`, so when `W`
///   does not divide `n` the total depends on which chunks this
///   worker's `rank` touches. Summed over all ranks this is the
///   classic `2·(W−1)/W · N` bandwidth term.
/// - **All-gather**: the worker forwards `W−1` messages; the schemes
///   that gather (sign, top-K) send equal-length messages from every
///   rank, so the expansion is `(W−1)·msg_bytes`.
/// - **Reduce+broadcast** is only priced by the α–β model, never
///   executed on a transport; its sent-side share is the message
///   itself.
pub fn ring_wire_bytes(kind: CollKind, msg_bytes: u64, world: usize, rank: usize) -> u64 {
    if world <= 1 {
        return 0;
    }
    match kind {
        CollKind::AllReduce => {
            debug_assert_eq!(msg_bytes % 4, 0, "all-reduce payloads are f32");
            let n = (msg_bytes / 4) as usize;
            let starts: Vec<usize> = (0..=world).map(|c| c * n / world).collect();
            let chunk = |c: usize| (starts[c + 1] - starts[c]) as u64;
            let mut values = 0u64;
            for s in 0..world - 1 {
                values += chunk((rank + world - s) % world); // reduce-scatter send
                values += chunk((rank + 1 + world - s) % world); // all-gather send
            }
            values * 4
        }
        CollKind::AllGather => (world as u64 - 1) * msg_bytes,
        CollKind::ReduceBroadcast => msg_bytes,
    }
}

/// Ring all-reduce (sum) across per-worker buffers, in place: after the
/// call every worker's buffer holds the elementwise sum.
///
/// Implemented as the standard two-phase ring: W−1 reduce-scatter steps
/// (each worker owns one chunk at the end) followed by W−1 all-gather
/// steps. Real chunked data movement; O(2·(W−1)/W · N) values moved per
/// worker — the ring's bandwidth term.
///
/// This entry point is the *sequential reference* (the lockstep
/// oracle). Engine-dispatching callers go through [`all_reduce_mean`]
/// with a [`CommLog::on`] log, or call
/// [`crate::transport::ring_all_reduce_sum_threaded`] directly.
pub fn ring_all_reduce_sum(buffers: &mut [Vec<f32>]) {
    ring_all_reduce_sum_lockstep(buffers);
}

/// The sequential reference implementation of [`ring_all_reduce_sum`] —
/// the correctness oracle for the threaded engine.
pub(crate) fn ring_all_reduce_sum_lockstep(buffers: &mut [Vec<f32>]) {
    let w = buffers.len();
    if w == 0 {
        return;
    }
    let n = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == n), "buffer length mismatch");
    if w == 1 || n == 0 {
        return;
    }
    // Chunk boundaries: chunk c covers [starts[c], starts[c+1]).
    let starts: Vec<usize> = (0..=w).map(|c| c * n / w).collect();

    // Phase 1: reduce-scatter. In step s, worker i sends chunk
    // (i - s) mod w to worker (i + 1) mod w, which accumulates it.
    for s in 0..w - 1 {
        // Compute all transfers for this step against the pre-step state:
        // in a real ring these happen concurrently. Buffer the sends.
        let sends: Vec<(usize, usize, Vec<f32>)> = (0..w)
            .map(|i| {
                let c = (i + w - s) % w;
                let chunk = buffers[i][starts[c]..starts[c + 1]].to_vec();
                ((i + 1) % w, c, chunk)
            })
            .collect();
        for (dst, c, chunk) in sends {
            let dstbuf = &mut buffers[dst][starts[c]..starts[c + 1]];
            for (d, v) in dstbuf.iter_mut().zip(chunk.iter()) {
                *d += v;
            }
        }
    }
    // After reduce-scatter, worker i owns the fully-reduced chunk
    // (i + 1) mod w.
    // Phase 2: all-gather. In step s, worker i sends its owned-or-received
    // chunk (i + 1 - s) mod w to worker (i + 1) mod w, which overwrites.
    for s in 0..w - 1 {
        let sends: Vec<(usize, usize, Vec<f32>)> = (0..w)
            .map(|i| {
                let c = (i + 1 + w - s) % w;
                let chunk = buffers[i][starts[c]..starts[c + 1]].to_vec();
                ((i + 1) % w, c, chunk)
            })
            .collect();
        for (dst, c, chunk) in sends {
            buffers[dst][starts[c]..starts[c + 1]].copy_from_slice(&chunk);
        }
    }
}

/// All-reduce **mean** across per-worker buffers, recording the traffic.
/// A no-op on an empty worker set (no traffic logged).
pub fn all_reduce_mean(buffers: &mut [Vec<f32>], log: &mut CommLog) {
    if buffers.is_empty() {
        return;
    }
    let _span = crate::obs::span(crate::obs::Phase::Collective);
    let w = buffers.len() as f32;
    let bytes = (buffers[0].len() * 4) as u64;
    match log.engine {
        EngineKind::Threaded => crate::transport::ring_all_reduce_sum_threaded(buffers),
        EngineKind::Lockstep => ring_all_reduce_sum_lockstep(buffers),
    }
    for b in buffers.iter_mut() {
        for v in b.iter_mut() {
            *v /= w;
        }
    }
    log.record(CollKind::AllReduce, bytes);
}

/// Materialize the gathered view on the log's engine. On the lockstep
/// engine this is a straight copy of the message list; on the threaded
/// engine the messages really travel the channel ring.
fn gathered_view<M: Clone + Send + Sync + Default>(messages: &[M], engine: EngineKind) -> Vec<M> {
    match engine {
        EngineKind::Threaded => crate::transport::ring_all_gather_threaded(messages),
        EngineKind::Lockstep => messages.to_vec(),
    }
}

/// All-gather: returns, for each worker, every worker's message (the
/// flattened list, indexable by source worker). All workers receive
/// identical views, so one gathered view is built and shared via `Arc` —
/// decode paths only read it, and this avoids the O(W²) clone of a
/// per-worker deep copy. `CommLog` accounting is unchanged (the wire
/// still carries one message per worker). Empty input gathers nothing
/// and logs nothing.
pub fn all_gather(messages: &[Vec<f32>], log: &mut CommLog) -> Vec<Arc<Vec<Vec<f32>>>> {
    if messages.is_empty() {
        return Vec::new();
    }
    let _span = crate::obs::span(crate::obs::Phase::Collective);
    let bytes = (messages[0].len() * 4) as u64;
    log.record(CollKind::AllGather, bytes);
    let view = Arc::new(gathered_view(messages, log.engine));
    messages.iter().map(|_| Arc::clone(&view)).collect()
}

/// All-gather for byte-packed messages (sign compression sends bitmaps).
/// Same `Arc` sharing and empty-input behavior as [`all_gather`].
pub fn all_gather_bytes(messages: &[Vec<u8>], log: &mut CommLog) -> Vec<Arc<Vec<Vec<u8>>>> {
    if messages.is_empty() {
        return Vec::new();
    }
    let _span = crate::obs::span(crate::obs::Phase::Collective);
    let bytes = messages[0].len() as u64;
    log.record(CollKind::AllGather, bytes);
    let view = Arc::new(gathered_view(messages, log.engine));
    messages.iter().map(|_| Arc::clone(&view)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_buffers(w: usize, n: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
        (0..w)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn ring_matches_naive_sum() {
        let mut rng = Rng::new(51);
        for &w in &[1usize, 2, 3, 4, 7, 16] {
            for &n in &[1usize, 2, 5, 16, 1000, 1003] {
                let bufs = random_buffers(w, n, &mut rng);
                let mut expect = vec![0.0f32; n];
                for b in &bufs {
                    for (e, v) in expect.iter_mut().zip(b) {
                        *e += v;
                    }
                }
                let mut got = bufs.clone();
                ring_all_reduce_sum(&mut got);
                for b in &got {
                    for (g, e) in b.iter().zip(&expect) {
                        assert!(
                            (g - e).abs() <= 1e-4 * e.abs().max(1.0),
                            "w={w} n={n}: {g} vs {e}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_workers_identical_after_allreduce() {
        let mut rng = Rng::new(52);
        let mut bufs = random_buffers(8, 257, &mut rng);
        let mut log = CommLog::default();
        all_reduce_mean(&mut bufs, &mut log);
        for b in &bufs[1..] {
            assert_eq!(b, &bufs[0]);
        }
        assert_eq!(log.bytes_sent(), 257 * 4);
        assert_eq!(log.ops[0].kind, CollKind::AllReduce);
    }

    #[test]
    fn mean_is_correct() {
        let mut bufs = vec![vec![1.0f32, 2.0], vec![3.0, 6.0]];
        let mut log = CommLog::default();
        all_reduce_mean(&mut bufs, &mut log);
        assert_eq!(bufs[0], vec![2.0, 4.0]);
    }

    #[test]
    fn all_gather_delivers_everything() {
        let msgs = vec![vec![1.0f32], vec![2.0], vec![3.0]];
        let mut log = CommLog::default();
        let got = all_gather(&msgs, &mut log);
        assert_eq!(got.len(), 3);
        for per_worker in &got {
            assert_eq!(per_worker.len(), 3);
            assert_eq!(per_worker[1], vec![2.0]);
        }
        assert_eq!(log.bytes_sent(), 4);
    }

    #[test]
    fn single_worker_noop() {
        let mut bufs = vec![vec![5.0f32, -1.0]];
        ring_all_reduce_sum(&mut bufs);
        assert_eq!(bufs[0], vec![5.0, -1.0]);
    }

    #[test]
    fn empty_worker_set_is_a_noop() {
        // Regression: `buffers[0]` indexing used to panic on empty input.
        let mut log = CommLog::default();
        let mut bufs: Vec<Vec<f32>> = Vec::new();
        all_reduce_mean(&mut bufs, &mut log);
        ring_all_reduce_sum(&mut bufs);
        let gathered = all_gather(&[], &mut log);
        assert!(gathered.is_empty());
        let gathered_b = all_gather_bytes(&[], &mut log);
        assert!(gathered_b.is_empty());
        assert!(log.ops.is_empty(), "empty collectives must not log traffic");
    }

    #[test]
    fn all_gather_shares_one_view() {
        let msgs = vec![vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let mut log = CommLog::default();
        let got = all_gather(&msgs, &mut log);
        assert_eq!(got.len(), 3);
        // One gathered view, shared: no O(W²) deep copies.
        assert!(std::sync::Arc::ptr_eq(&got[0], &got[1]));
        assert!(std::sync::Arc::ptr_eq(&got[1], &got[2]));
        assert_eq!(got[2][0], vec![1.0, 2.0]);
        // Byte accounting unchanged: one per-worker message.
        assert_eq!(log.bytes_sent(), 8);
    }

    #[test]
    fn ring_wire_bytes_sums_to_bandwidth_term() {
        // Σ over ranks of the per-rank expansion = 2·(W−1)·N·4 for
        // all-reduce (every step moves every chunk exactly once per
        // phase), and W·(W−1)·B for all-gather.
        for &(w, n) in &[(2usize, 8usize), (3, 10), (4, 1003), (5, 7), (7, 0)] {
            let msg = (n * 4) as u64;
            let total: u64 =
                (0..w).map(|r| ring_wire_bytes(CollKind::AllReduce, msg, w, r)).sum();
            assert_eq!(total, 2 * (w as u64 - 1) * (n as u64) * 4, "w={w} n={n}");
            let gather: u64 =
                (0..w).map(|r| ring_wire_bytes(CollKind::AllGather, 10, w, r)).sum();
            assert_eq!(gather, (w as u64) * (w as u64 - 1) * 10);
        }
    }

    #[test]
    fn ring_wire_bytes_even_split_is_rank_independent() {
        // When W | n every rank sends the same 2(W−1)·(n/W) values.
        let (w, n) = (4usize, 64usize);
        for r in 0..w {
            assert_eq!(
                ring_wire_bytes(CollKind::AllReduce, (n * 4) as u64, w, r),
                (2 * (w as u64 - 1)) * ((n / w) as u64) * 4
            );
        }
        // Single worker: nothing crosses a wire.
        assert_eq!(ring_wire_bytes(CollKind::AllReduce, 400, 1, 0), 0);
        assert_eq!(ring_wire_bytes(CollKind::AllGather, 400, 1, 0), 0);
    }

    /// The engine rides on the log, so two engines run side by side in
    /// one process (no global switch) and agree bitwise.
    #[test]
    fn engines_coexist_per_log() {
        let mut rng = Rng::new(53);
        let bufs = random_buffers(3, 37, &mut rng);
        let mut on_lockstep = bufs.clone();
        let mut on_threaded = bufs;
        let mut lock_log = CommLog::default();
        let mut thread_log = CommLog::on(EngineKind::Threaded);
        all_reduce_mean(&mut on_lockstep, &mut lock_log);
        all_reduce_mean(&mut on_threaded, &mut thread_log);
        for (a, b) in on_lockstep.iter().zip(on_threaded.iter()) {
            let a_bits: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
            let b_bits: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a_bits, b_bits);
        }
        assert_eq!(lock_log.bytes_sent(), thread_log.bytes_sent());
    }

    #[test]
    fn commlog_accumulates() {
        let mut log = CommLog::default();
        log.record(CollKind::AllReduce, 100);
        log.record(CollKind::AllGather, 50);
        assert_eq!(log.bytes_sent(), 150);
        log.clear();
        assert_eq!(log.bytes_sent(), 0);
    }
}
