//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we implement
//! xoshiro256++ (Blackman & Vigna) plus the samplers the library needs:
//! uniform floats, standard normals (Box–Muller), integer ranges,
//! shuffles and weighted sampling.
//!
//! All randomness in the library flows through [`Rng`] so that every
//! experiment is reproducible from a single `u64` seed. Workers derive
//! their own streams via [`Rng::split`] (splitmix-style), mirroring how
//! JAX splits PRNG keys.

/// xoshiro256++ PRNG with convenience samplers.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the most recent Box–Muller transform.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid: the state is
    /// expanded through splitmix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (e.g. one per worker).
    pub fn split(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64-bit output (xoshiro256++ core).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish method
    /// with a widening multiply; bias is negligible for n << 2^64 but we
    /// reject to be exact.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal sample (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fill a slice with i.i.d. N(0, sigma^2) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates on an
    /// index map — O(k) memory via a sparse swap table).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k > n");
        use std::collections::HashMap;
        let mut swaps: HashMap<usize, usize> = HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            let vi = *swaps.get(&i).unwrap_or(&i);
            let vj = *swaps.get(&j).unwrap_or(&j);
            out.push(vj);
            swaps.insert(j, vi);
        }
        out
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: all-zero weights");
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            if u < w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(7);
        let mut w0 = root.split(0);
        let mut w1 = root.split(1);
        let a: Vec<u64> = (0..8).map(|_| w0.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| w1.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let ix = rng.sample_indices(100, 30);
            let mut sorted = ix.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 30);
            assert!(ix.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut rng = Rng::new(6);
        let w = [0.0, 0.0, 10.0, 0.1];
        let mut counts = [0usize; 4];
        for _ in 0..1000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 900);
    }
}
