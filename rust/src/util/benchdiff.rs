//! `powersgd bench-diff` — compare two `BENCH_<name>.json` documents.
//!
//! The bench binaries emit flat-record JSON artifacts
//! ([`crate::util::bench::BenchJson`]); CI uploads them and
//! `rust/bench-trajectory/` keeps committed baselines. This module
//! parses two such documents (hand-rolled reader — serde is unavailable
//! offline, and the writer's layout is fixed), matches records by name,
//! and renders a markdown delta table:
//!
//! - `*_ms` timing metrics compare with a **relative tolerance**
//!   (default +25%): only a slowdown beyond the threshold is a
//!   regression — speedups and noise-level drift pass.
//! - `*_bytes` traffic metrics compare **exactly**: wire and logical
//!   byte counts are deterministic, so any drift is a regression until
//!   the baseline is deliberately regenerated.
//! - `*_gflops` throughput metrics compare with the same relative
//!   tolerance **direction-reversed**: higher is better, so only a
//!   *drop* beyond the threshold regresses — the gate that keeps the
//!   blocked kernels' GFLOP/s records from silently decaying.
//! - Everything else (`n`, `threads` tags, …) is context, not compared.
//!
//! Context axes (`bench`, `engine`, `transport`, `pipeline`, `threads`,
//! `quick`) must match between the documents — diffing a lockstep run
//! against a threaded one is an error, not a regression. With
//! `report_only` every failure (context mismatch, removed record,
//! regression) downgrades to a warning and the diff always "passes":
//! that's the CI mode for comparing against a baseline committed from a
//! different machine, where absolute timings are not comparable but the
//! table is still worth printing.

use anyhow::{bail, Context, Result};

/// Relative slowdown on a `*_ms` metric tolerated before it counts as a
/// regression (0.25 = +25%).
pub const DEFAULT_TOLERANCE: f64 = 0.25;

/// One parsed bench record: a case name plus its named metrics in
/// document order.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// Case name (`"powersgd_step/metrics/on"`, …).
    pub name: String,
    /// Metric key/value pairs (`mean_ms`, `wire_bytes`, …).
    pub metrics: Vec<(String, f64)>,
}

impl BenchRecord {
    /// Look up a metric by key.
    pub fn metric(&self, key: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }
}

/// One parsed `BENCH_<name>.json` document.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchDoc {
    /// Document layout version; absent in pre-versioning artifacts,
    /// which parse as version 1.
    pub schema_version: u64,
    /// Bench binary name.
    pub bench: String,
    /// Collective engine context (`lockstep` | `threaded`).
    pub engine: String,
    /// Transport context (`inproc` | `tcp`).
    pub transport: String,
    /// Pipeline context (`off` | `overlap` | `delayed`).
    pub pipeline: String,
    /// Document-level kernel-pool thread count.
    pub threads: u64,
    /// Whether the run used the shrunken `BENCH_QUICK=1` budgets.
    pub quick: bool,
    /// Flat records, in document order.
    pub records: Vec<BenchRecord>,
}

impl BenchDoc {
    /// Look up a record by case name.
    pub fn record(&self, name: &str) -> Option<&BenchRecord> {
        self.records.iter().find(|r| r.name == name)
    }
}

/// Unescape the JSON string starting at `s[0] == '"'`; returns the
/// string and the rest of the input after the closing quote.
fn parse_string(s: &str) -> Result<(String, &str)> {
    let mut chars = s.char_indices();
    match chars.next() {
        Some((_, '"')) => {}
        _ => bail!("expected a JSON string at {s:.40?}"),
    }
    let mut out = String::new();
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Ok((out, &s[i + 1..])),
            '\\' => match chars.next() {
                Some((_, '"')) => out.push('"'),
                Some((_, '\\')) => out.push('\\'),
                Some((_, 'n')) => out.push('\n'),
                Some((_, 't')) => out.push('\t'),
                Some((_, 'u')) => {
                    let hex: String = (0..4).filter_map(|_| chars.next().map(|(_, c)| c)).collect();
                    let code = u32::from_str_radix(&hex, 16)
                        .with_context(|| format!("bad \\u escape {hex:?}"))?;
                    out.push(char::from_u32(code).context("bad \\u code point")?);
                }
                other => bail!("unsupported escape {other:?}"),
            },
            c => out.push(c),
        }
    }
    bail!("unterminated JSON string at {s:.40?}")
}

/// Parse the number (or `null`, `true`, `false`) at the head of `s`;
/// returns the value and the rest. `null` maps to NaN (the writer emits
/// it for non-finite measurements), booleans to 0/1.
fn parse_number(s: &str) -> Result<(f64, &str)> {
    for (lit, v) in [("null", f64::NAN), ("true", 1.0), ("false", 0.0)] {
        if let Some(rest) = s.strip_prefix(lit) {
            return Ok((v, rest));
        }
    }
    let end = s
        .char_indices()
        .find(|&(_, c)| !matches!(c, '0'..='9' | '-' | '+' | '.' | 'e' | 'E'))
        .map_or(s.len(), |(i, _)| i);
    let v: f64 = s[..end].parse().with_context(|| format!("bad JSON number at {s:.40?}"))?;
    Ok((v, &s[end..]))
}

/// Parse one record line of the writer's layout:
/// `{"name": "...", "mean_ms": 1.5, ...}` (trailing comma tolerated).
fn parse_record(line: &str) -> Result<BenchRecord> {
    let mut rest = line
        .trim()
        .trim_end_matches(',')
        .strip_prefix('{')
        .with_context(|| format!("record line must start with '{{': {line:.60?}"))?
        .trim_end_matches('}');
    let mut name = None;
    let mut metrics = Vec::new();
    loop {
        rest = rest.trim_start().trim_start_matches(',').trim_start();
        if rest.is_empty() {
            break;
        }
        let (key, after) = parse_string(rest)?;
        let after = after
            .trim_start()
            .strip_prefix(':')
            .with_context(|| format!("expected ':' after key {key:?}"))?
            .trim_start();
        if key == "name" {
            let (value, after) = parse_string(after)?;
            name = Some(value);
            rest = after;
        } else {
            let (value, after) = parse_number(after)?;
            metrics.push((key, value));
            rest = after;
        }
    }
    Ok(BenchRecord { name: name.context("record without a \"name\" key")?, metrics })
}

/// Parse a `BENCH_<name>.json` document produced by
/// [`crate::util::bench::BenchJson::to_json`]. Line-oriented: header
/// keys and one record per line, exactly as the writer emits them.
pub fn parse_bench_json(doc: &str) -> Result<BenchDoc> {
    let mut out = BenchDoc {
        schema_version: 1,
        bench: String::new(),
        engine: String::new(),
        transport: String::new(),
        pipeline: String::new(),
        threads: 0,
        quick: false,
        records: Vec::new(),
    };
    let mut in_records = false;
    for line in doc.lines() {
        let t = line.trim();
        if t == "{" || t == "}" {
            continue;
        }
        if in_records {
            if t == "]" || t == "]," {
                in_records = false;
            } else {
                out.records.push(parse_record(t)?);
            }
            continue;
        }
        if t.starts_with("\"records\"") {
            in_records = true;
            continue;
        }
        let Some((key, after)) = parse_string(t).ok() else {
            bail!("unrecognized line {t:.60?}");
        };
        let value = after
            .trim_start()
            .strip_prefix(':')
            .with_context(|| format!("expected ':' after header key {key:?}"))?
            .trim();
        match key.as_str() {
            "bench" | "engine" | "transport" | "pipeline" => {
                let (s, _) = parse_string(value)?;
                match key.as_str() {
                    "bench" => out.bench = s,
                    "engine" => out.engine = s,
                    "transport" => out.transport = s,
                    _ => out.pipeline = s,
                }
            }
            "schema_version" | "threads" => {
                let (v, _) = parse_number(value)?;
                if key == "threads" {
                    out.threads = v as u64;
                } else {
                    out.schema_version = v as u64;
                }
            }
            "quick" => {
                let (v, _) = parse_number(value)?;
                out.quick = v != 0.0;
            }
            other => bail!("unknown header key {other:?}"),
        }
    }
    if out.bench.is_empty() {
        bail!("not a bench document (no \"bench\" header)");
    }
    Ok(out)
}

/// The verdict for one compared metric.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffLine {
    /// Case name.
    pub name: String,
    /// Metric key.
    pub metric: String,
    /// Baseline value.
    pub old: f64,
    /// New value.
    pub new: f64,
    /// Relative change `(new - old) / old` (NaN when `old == 0`).
    pub rel: f64,
    /// True when this line violates its tolerance.
    pub regressed: bool,
}

/// The outcome of a bench-diff: the rendered table plus the machine
/// verdicts CI gates on.
#[derive(Debug, Clone, Default)]
pub struct DiffReport {
    /// Every compared metric, in document order.
    pub lines: Vec<DiffLine>,
    /// Non-fatal notes: records added/removed, context drift under
    /// `report_only`, skipped metrics.
    pub warnings: Vec<String>,
    /// Number of regressed lines (0 = pass).
    pub regressions: usize,
}

impl DiffReport {
    /// Render the markdown delta table (plus the warning list).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("| Case | Metric | Baseline | New | Δ | Verdict |\n");
        out.push_str("|---|---|---:|---:|---:|---|\n");
        for l in &self.lines {
            let delta = if l.rel.is_finite() {
                format!("{:+.1}%", l.rel * 100.0)
            } else {
                "n/a".into()
            };
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} |\n",
                l.name,
                l.metric,
                fmt_value(&l.metric, l.old),
                fmt_value(&l.metric, l.new),
                delta,
                if l.regressed { "**regressed**" } else { "ok" },
            ));
        }
        for w in &self.warnings {
            out.push_str(&format!("\n> warning: {w}\n"));
        }
        out
    }
}

fn fmt_value(metric: &str, v: f64) -> String {
    if metric.ends_with("_bytes") || metric == "n" {
        format!("{v:.0}")
    } else {
        format!("{v:.4}")
    }
}

/// Compare `new` against the `old` baseline.
///
/// `tolerance` is the relative slowdown allowed on `*_ms` metrics;
/// `*_bytes` metrics must match exactly. With `report_only`, context
/// mismatches and regressions become warnings and `regressions` stays 0
/// — the caller always exits 0 but still gets the table.
pub fn diff(old: &BenchDoc, new: &BenchDoc, tolerance: f64, report_only: bool) -> Result<DiffReport> {
    let mut report = DiffReport::default();
    for (axis, a, b) in [
        ("bench", &old.bench, &new.bench),
        ("engine", &old.engine, &new.engine),
        ("transport", &old.transport, &new.transport),
        ("pipeline", &old.pipeline, &new.pipeline),
    ] {
        if a != b {
            let msg = format!("context mismatch on {axis}: baseline {a:?} vs new {b:?}");
            if report_only {
                report.warnings.push(msg);
            } else {
                bail!("{msg} — these documents are not comparable");
            }
        }
    }
    for (axis, a, b) in
        [("threads", old.threads, new.threads), ("quick", old.quick as u64, new.quick as u64)]
    {
        if a != b {
            report.warnings.push(format!("context drift on {axis}: baseline {a} vs new {b}"));
        }
    }

    for rec in &old.records {
        let Some(new_rec) = new.record(&rec.name) else {
            report.warnings.push(format!("record {:?} missing from the new run", rec.name));
            continue;
        };
        for (key, old_v) in &rec.metrics {
            let timing = key.ends_with("_ms");
            let traffic = key.ends_with("_bytes");
            let throughput = key.ends_with("_gflops");
            if !timing && !traffic && !throughput {
                continue;
            }
            let Some(new_v) = new_rec.metric(key) else {
                report.warnings.push(format!("metric {key:?} missing from record {:?}", rec.name));
                continue;
            };
            let rel = if *old_v != 0.0 { (new_v - old_v) / old_v } else { f64::NAN };
            let regressed = if traffic {
                // Deterministic byte counts: bitwise drift is the bug.
                new_v != *old_v
            } else if throughput {
                // Higher is better: only a drop beyond tolerance fails.
                rel.is_finite() && rel < -tolerance
            } else {
                rel.is_finite() && rel > tolerance
            };
            report.lines.push(DiffLine {
                name: rec.name.clone(),
                metric: key.clone(),
                old: *old_v,
                new: new_v,
                rel,
                regressed: regressed && !report_only,
            });
            if regressed && report_only {
                report
                    .warnings
                    .push(format!("{} {key}: would regress outside report-only mode", rec.name));
            }
        }
    }
    for rec in &new.records {
        if old.record(&rec.name).is_none() {
            report.warnings.push(format!("record {:?} is new (no baseline)", rec.name));
        }
    }
    report.regressions = report.lines.iter().filter(|l| l.regressed).count();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bench::BenchJson;

    fn doc(mean: f64, wire: u64) -> String {
        let mut j = BenchJson::new("unit");
        j.set_context("threaded", "tcp");
        j.record("case/a", &[("mean_ms", mean), ("n", 5.0)]);
        j.record_wire("case/wire", wire, 1024);
        j.to_json()
    }

    #[test]
    fn parses_the_writers_own_output() {
        let d = parse_bench_json(&doc(1.5, 2048)).unwrap();
        assert_eq!(d.schema_version, 2);
        assert_eq!(d.bench, "unit");
        assert_eq!(d.engine, "threaded");
        assert_eq!(d.transport, "tcp");
        assert_eq!(d.pipeline, "off");
        assert_eq!(d.records.len(), 2);
        assert_eq!(d.record("case/a").unwrap().metric("mean_ms"), Some(1.5));
        assert_eq!(d.record("case/wire").unwrap().metric("wire_bytes"), Some(2048.0));
    }

    #[test]
    fn parses_escapes_and_null() {
        let mut j = BenchJson::new("esc");
        j.record("case \"q\"", &[("mean_ms", f64::NAN)]);
        let d = parse_bench_json(&j.to_json()).unwrap();
        let r = d.record("case \"q\"").unwrap();
        assert!(r.metric("mean_ms").unwrap().is_nan());
    }

    #[test]
    fn pre_versioning_documents_parse_as_v1() {
        let legacy = doc(1.0, 1024).replace("  \"schema_version\": 2,\n", "");
        let d = parse_bench_json(&legacy).unwrap();
        assert_eq!(d.schema_version, 1);
    }

    #[test]
    fn within_tolerance_passes() {
        let old = parse_bench_json(&doc(1.0, 2048)).unwrap();
        let new = parse_bench_json(&doc(1.2, 2048)).unwrap();
        let r = diff(&old, &new, DEFAULT_TOLERANCE, false).unwrap();
        assert_eq!(r.regressions, 0, "{:?}", r.lines);
        assert!(r.to_markdown().contains("| case/a | mean_ms |"));
    }

    #[test]
    fn timing_regression_is_flagged() {
        let old = parse_bench_json(&doc(1.0, 2048)).unwrap();
        let new = parse_bench_json(&doc(1.6, 2048)).unwrap();
        let r = diff(&old, &new, DEFAULT_TOLERANCE, false).unwrap();
        assert_eq!(r.regressions, 1);
        assert!(r.to_markdown().contains("**regressed**"));
        // A speedup of the same magnitude is not a regression.
        let r = diff(&new, &old, DEFAULT_TOLERANCE, false).unwrap();
        assert_eq!(r.regressions, 0);
    }

    #[test]
    fn gflops_compare_direction_reversed() {
        let mk = |gf: f64| {
            let mut j = BenchJson::new("unit");
            j.set_context("threaded", "tcp");
            j.record("kernel/nn/blocked", &[("throughput_gflops", gf), ("speedup_x", 2.0)]);
            parse_bench_json(&j.to_json()).unwrap()
        };
        // A throughput drop beyond tolerance regresses…
        let r = diff(&mk(10.0), &mk(6.0), DEFAULT_TOLERANCE, false).unwrap();
        assert_eq!(r.regressions, 1, "{:?}", r.lines);
        // …an equal-magnitude improvement passes…
        let r = diff(&mk(6.0), &mk(10.0), DEFAULT_TOLERANCE, false).unwrap();
        assert_eq!(r.regressions, 0, "{:?}", r.lines);
        // …noise-level drift passes…
        let r = diff(&mk(10.0), &mk(9.0), DEFAULT_TOLERANCE, false).unwrap();
        assert_eq!(r.regressions, 0, "{:?}", r.lines);
        // …and the unsuffixed ratio metric is context, never compared.
        assert!(r.lines.iter().all(|l| l.metric != "speedup_x"));
    }

    #[test]
    fn byte_drift_is_exact() {
        let old = parse_bench_json(&doc(1.0, 2048)).unwrap();
        let new = parse_bench_json(&doc(1.0, 2049)).unwrap();
        let r = diff(&old, &new, DEFAULT_TOLERANCE, false).unwrap();
        assert_eq!(r.regressions, 1);
    }

    #[test]
    fn context_mismatch_is_an_error_unless_report_only() {
        let old = parse_bench_json(&doc(1.0, 2048)).unwrap();
        let mut other = BenchJson::new("unit");
        other.set_context("lockstep", "inproc");
        other.record("case/a", &[("mean_ms", 1.0)]);
        let new = parse_bench_json(&other.to_json()).unwrap();
        assert!(diff(&old, &new, DEFAULT_TOLERANCE, false).is_err());
        let r = diff(&old, &new, DEFAULT_TOLERANCE, true).unwrap();
        assert_eq!(r.regressions, 0);
        assert!(r.warnings.iter().any(|w| w.contains("context mismatch")));
    }

    #[test]
    fn report_only_downgrades_regressions() {
        let old = parse_bench_json(&doc(1.0, 2048)).unwrap();
        let new = parse_bench_json(&doc(10.0, 4096)).unwrap();
        let r = diff(&old, &new, DEFAULT_TOLERANCE, true).unwrap();
        assert_eq!(r.regressions, 0);
        assert!(r.warnings.iter().any(|w| w.contains("would regress")));
    }

    #[test]
    fn removed_and_added_records_warn() {
        let old = parse_bench_json(&doc(1.0, 2048)).unwrap();
        let mut j = BenchJson::new("unit");
        j.set_context("threaded", "tcp");
        j.record("case/a", &[("mean_ms", 1.0)]);
        j.record("case/brand-new", &[("mean_ms", 1.0)]);
        let new = parse_bench_json(&j.to_json()).unwrap();
        let r = diff(&old, &new, DEFAULT_TOLERANCE, false).unwrap();
        assert!(r.warnings.iter().any(|w| w.contains("missing from the new run")));
        assert!(r.warnings.iter().any(|w| w.contains("no baseline")));
    }
}
