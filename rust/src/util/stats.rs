//! Small statistics helpers used by metrics and the bench harness.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Minimum (NaN-ignoring); 0.0 for empty input.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::INFINITY, f64::min).min(f64::INFINITY)
}

/// Maximum (NaN-ignoring); 0.0 for empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Summary of a set of timing samples, in the unit of the samples.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median (50th percentile, interpolated).
    pub p50: f64,
    /// 95th percentile (interpolated).
    pub p95: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample set (all-zero summary for empty input).
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std_dev(xs),
            min: if xs.is_empty() { 0.0 } else { min(xs) },
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            max: max(xs).max(f64::NEG_INFINITY),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }
}
