//! Shared utilities: RNG, CLI parsing, statistics, bench harness, tables.
//!
//! Everything here is hand-rolled because the offline build environment
//! only vendors the `xla` crate's dependency closure (no rand / clap /
//! criterion). See DESIGN.md §2 "Offline-environment deviations".

pub mod bench;
pub mod benchdiff;
pub mod cli;
pub mod rng;
pub mod stats;
pub mod table;

pub use bench::{black_box, quick_mode, BenchJson, BenchRunner};
pub use cli::Args;
pub use rng::Rng;
pub use table::Table;

/// Format a byte count the way the paper does (MB with 0 or 1 decimals).
pub fn fmt_mb(bytes: f64) -> String {
    let mb = bytes / 1e6;
    if mb >= 100.0 {
        format!("{mb:.0} MB")
    } else {
        format!("{mb:.1} MB")
    }
}

/// Format milliseconds like the paper's "time per batch" column.
pub fn fmt_ms(ms: f64) -> String {
    format!("{ms:.0} ms")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formatting() {
        assert_eq!(fmt_mb(1023.0e6), "1023 MB");
        assert_eq!(fmt_mb(8.0e6), "8.0 MB");
        assert_eq!(fmt_ms(312.4), "312 ms");
    }
}
