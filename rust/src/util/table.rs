//! Paper-style table printer used by the bench harness and the
//! experiment report generator to emit rows matching the layout of the
//! tables in the PowerSGD paper.
//!
//! Two renderings of the same rows: [`Table::render`] produces the
//! column-aligned ASCII form printed to terminals, [`Table::markdown`]
//! the GitHub-flavored pipe table embedded in the generated `REPORT.md`
//! (`powersgd experiment`, DESIGN.md §12).

/// Column-aligned table with a title, built row by row.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; the cell count must match the header width.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from &str slices.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render the column-aligned ASCII form (`== title ==`, padded
    /// columns, a dashed rule under the header).
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", cell, w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavored-markdown pipe table: a bold title
    /// line, a blank line, then `| header |`, the `|---|` separator,
    /// and one `| cell |` line per row. This is the building block of
    /// the generated `REPORT.md` — byte-deterministic given the rows.
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("**{}**\n\n", self.title));
        out.push('|');
        for h in &self.header {
            out.push_str(&format!(" {h} |"));
        }
        out.push_str("\n|");
        for _ in &self.header {
            out.push_str("---|");
        }
        out.push('\n');
        for row in &self.rows {
            out.push('|');
            for cell in row {
                out.push_str(&format!(" {cell} |"));
            }
            out.push('\n');
        }
        out
    }

    /// Print the ASCII rendering to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Algorithm", "Acc"]);
        t.row_str(&["SGD", "94.3%"]);
        t.row_str(&["Rank 2 PowerSGD", "94.4%"]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("Rank 2 PowerSGD  94.4%"));
        // header padded to widest cell
        assert!(s.contains("Algorithm        Acc"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn markdown_pipe_table() {
        let mut t = Table::new("Demo", &["Algorithm", "Acc"]);
        t.row_str(&["SGD", "94.3%"]);
        t.row_str(&["Rank 2", "94.4%"]);
        let md = t.markdown();
        assert_eq!(
            md,
            "**Demo**\n\n| Algorithm | Acc |\n|---|---|\n| SGD | 94.3% |\n| Rank 2 | 94.4% |\n"
        );
    }
}
