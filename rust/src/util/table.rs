//! Paper-style ASCII table printer used by the bench harness to emit
//! rows matching the layout of the tables in the PowerSGD paper.

/// Column-aligned table with a title, built row by row.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from &str slices.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", cell, w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Algorithm", "Acc"]);
        t.row_str(&["SGD", "94.3%"]);
        t.row_str(&["Rank 2 PowerSGD", "94.4%"]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("Rank 2 PowerSGD  94.4%"));
        // header padded to widest cell
        assert!(s.contains("Algorithm        Acc"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_str(&["only-one"]);
    }
}
