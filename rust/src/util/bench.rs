//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! Benches are plain binaries (`harness = false`) that call
//! [`BenchRunner::bench`] per case and print a criterion-style summary.
//! Warmup iterations are run first, then the measured phase is repeated
//! until both a minimum iteration count and minimum elapsed time are hit,
//! so fast and slow cases are both measured meaningfully.

use super::stats::Summary;
use std::time::{Duration, Instant};

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Configuration for one benchmark runner.
pub struct BenchRunner {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
    results: Vec<(String, Summary)>,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            min_time: Duration::from_millis(300),
            results: Vec::new(),
        }
    }
}

impl BenchRunner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Runner that measures each case exactly `n` times (for very heavy
    /// one-shot cases like a full SVD).
    pub fn once(n: usize) -> Self {
        BenchRunner {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: n.max(1),
            min_time: Duration::from_millis(0),
            ..Default::default()
        }
    }

    /// Quick-mode runner for heavy end-to-end cases.
    pub fn heavy() -> Self {
        BenchRunner {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            min_time: Duration::from_millis(200),
            ..Default::default()
        }
    }

    /// Measure `f`, print a summary line, and record it.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3); // ms
            let enough_iters = samples.len() >= self.min_iters;
            let enough_time = start.elapsed() >= self.min_time;
            if (enough_iters && enough_time) || samples.len() >= self.max_iters {
                break;
            }
        }
        let s = Summary::of(&samples);
        println!(
            "bench {name:<44} {:>10.4} ms/iter  (±{:.4}, n={}, p95={:.4})",
            s.mean, s.std, s.n, s.p95
        );
        self.results.push((name.to_string(), s));
        s
    }

    /// All recorded results, in execution order.
    pub fn results(&self) -> &[(String, Summary)] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut r = BenchRunner {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            min_time: Duration::from_millis(0),
            results: Vec::new(),
        };
        let mut count = 0usize;
        let s = r.bench("noop", || {
            count += 1;
            black_box(count);
        });
        assert!(s.n >= 3);
        assert_eq!(r.results().len(), 1);
        // warmup + measured
        assert!(count >= 4);
    }

    #[test]
    fn max_iters_caps_fast_cases() {
        let mut r = BenchRunner {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 7,
            min_time: Duration::from_secs(3600),
            results: Vec::new(),
        };
        let s = r.bench("fast", || {
            black_box(1 + 1);
        });
        assert_eq!(s.n, 7);
    }
}
