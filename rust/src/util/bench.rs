//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! Benches are plain binaries (`harness = false`) that call
//! [`BenchRunner::bench`] per case and print a criterion-style summary.
//! Warmup iterations are run first, then the measured phase is repeated
//! until both a minimum iteration count and minimum elapsed time are hit,
//! so fast and slow cases are both measured meaningfully.
//!
//! Two CI hooks:
//! - `BENCH_QUICK=1` ([`quick_mode`]) shrinks case lists and iteration
//!   budgets so the `bench-smoke` job finishes in seconds;
//! - [`BenchJson`] emits one `BENCH_<name>.json` per bench binary
//!   (hand-rolled writer; serde is unavailable offline), uploaded as a
//!   workflow artifact — the bench regression trajectory.

use super::stats::Summary;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Configuration for one benchmark runner.
pub struct BenchRunner {
    /// Unmeasured iterations run before sampling starts.
    pub warmup_iters: usize,
    /// Minimum measured iterations per case.
    pub min_iters: usize,
    /// Hard cap on measured iterations per case.
    pub max_iters: usize,
    /// Minimum measured wall-clock per case (with `min_iters`, whichever
    /// is hit later — unless `max_iters` caps first).
    pub min_time: Duration,
    results: Vec<(String, Summary)>,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            min_time: Duration::from_millis(300),
            results: Vec::new(),
        }
    }
}

impl BenchRunner {
    /// Default runner (full measurement budget).
    pub fn new() -> Self {
        Self::default()
    }

    /// Runner honoring [`quick_mode`]: in the CI smoke job each case
    /// runs a handful of iterations — enough for a trend point in the
    /// JSON artifact, not a stable measurement.
    pub fn from_env() -> Self {
        if quick_mode() {
            BenchRunner {
                warmup_iters: 1,
                min_iters: 2,
                max_iters: 5,
                min_time: Duration::from_millis(0),
                results: Vec::new(),
            }
        } else {
            Self::default()
        }
    }

    /// Runner that measures each case exactly `n` times (for very heavy
    /// one-shot cases like a full SVD).
    pub fn once(n: usize) -> Self {
        BenchRunner {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: n.max(1),
            min_time: Duration::from_millis(0),
            ..Default::default()
        }
    }

    /// Quick-mode runner for heavy end-to-end cases.
    pub fn heavy() -> Self {
        BenchRunner {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            min_time: Duration::from_millis(200),
            ..Default::default()
        }
    }

    /// Measure `f`, print a summary line, and record it.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3); // ms
            let enough_iters = samples.len() >= self.min_iters;
            let enough_time = start.elapsed() >= self.min_time;
            if (enough_iters && enough_time) || samples.len() >= self.max_iters {
                break;
            }
        }
        let s = Summary::of(&samples);
        println!(
            "bench {name:<44} {:>10.4} ms/iter  (±{:.4}, n={}, p95={:.4})",
            s.mean, s.std, s.n, s.p95
        );
        self.results.push((name.to_string(), s));
        s
    }

    /// All recorded results, in execution order.
    pub fn results(&self) -> &[(String, Summary)] {
        &self.results
    }
}

/// True when `BENCH_QUICK=1` (the CI `bench-smoke` job): benches shrink
/// their case lists and iteration budgets but still emit JSON.
pub fn quick_mode() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// Machine-readable bench output: one flat-record JSON document per
/// bench binary, written as `BENCH_<name>.json` so CI can upload the
/// files as artifacts and later runs can diff them.
///
/// Every document carries an `engine` and `transport` context (which
/// execution substrate produced the numbers), so bench trajectories
/// stay comparable across lockstep / threaded / tcp runs; wire-level
/// traffic goes into per-record `wire_bytes`/`logical_bytes` metrics
/// via [`BenchJson::record_wire`].
pub struct BenchJson {
    bench: String,
    engine: String,
    transport: String,
    /// Collective scheduling the measured cases model or drive
    /// (`off` | `overlap` | `delayed`, the `--pipeline` axis).
    pipeline: String,
    /// Ambient kernel-pool thread count
    /// ([`crate::runtime::pool::threads`]) at construction; sweeps that
    /// vary the count per case additionally tag each record with a
    /// `threads` metric ([`BenchJson::record_runner_tagged`]).
    threads: usize,
    records: Vec<(String, Vec<(String, f64)>)>,
}

impl BenchJson {
    /// Empty document for the named bench binary.
    pub fn new(bench: &str) -> BenchJson {
        BenchJson {
            bench: bench.to_string(),
            engine: "lockstep".into(),
            transport: "inproc".into(),
            pipeline: "off".into(),
            threads: crate::runtime::pool::threads(),
            records: Vec::new(),
        }
    }

    /// Tag the document with the execution substrate it measured
    /// (engine: `lockstep` / `threaded`; transport: `inproc` / `tcp`).
    pub fn set_context(&mut self, engine: &str, transport: &str) {
        self.engine = engine.to_string();
        self.transport = transport.to_string();
    }

    /// Tag the document with the collective schedule it measured
    /// (`off` | `overlap` | `delayed` — the CLI `--pipeline` spelling).
    pub fn set_pipeline(&mut self, pipeline: &str) {
        self.pipeline = pipeline.to_string();
    }

    /// Override the document-level kernel thread count (benches that
    /// sweep thread counts record per-row `threads` metrics instead).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
    }

    /// Append one record of named metrics.
    pub fn record(&mut self, name: &str, metrics: &[(&str, f64)]) {
        self.records
            .push((name.to_string(), metrics.iter().map(|(k, v)| (k.to_string(), *v)).collect()));
    }

    /// Append one measured-traffic record: `wire_bytes` is what a
    /// metered transport counted on the ring, `logical_bytes` the
    /// per-worker `CommLog`/`message_bytes` unit.
    pub fn record_wire(&mut self, name: &str, wire_bytes: u64, logical_bytes: u64) {
        self.record(
            name,
            &[("wire_bytes", wire_bytes as f64), ("logical_bytes", logical_bytes as f64)],
        );
    }

    /// Append every result of a runner as mean/p50/p95 records.
    pub fn record_runner(&mut self, runner: &BenchRunner) {
        self.record_runner_tagged(runner, &[]);
    }

    /// Like [`BenchJson::record_runner`], with extra metrics appended
    /// to every record — how thread-count sweeps tag their per-count
    /// rows (`("threads", t)`).
    pub fn record_runner_tagged(&mut self, runner: &BenchRunner, extra: &[(&str, f64)]) {
        for (name, s) in runner.results() {
            let mut metrics: Vec<(String, f64)> = vec![
                ("mean_ms".into(), s.mean),
                ("p50_ms".into(), s.p50),
                ("p95_ms".into(), s.p95),
                ("n".into(), s.n as f64),
            ];
            metrics.extend(extra.iter().map(|(k, v)| (k.to_string(), *v)));
            self.records.push((name.clone(), metrics));
        }
    }

    /// Serialize the document (stable key order, valid JSON).
    ///
    /// `schema_version` history: 1 = original flat-record layout;
    /// 2 = adds the version field itself so `powersgd bench-diff` and
    /// the committed `rust/bench-trajectory/` baselines can detect
    /// layout drift (records and context keys are unchanged).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str("  \"schema_version\": 2,\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.bench)));
        out.push_str(&format!("  \"engine\": \"{}\",\n", json_escape(&self.engine)));
        out.push_str(&format!("  \"transport\": \"{}\",\n", json_escape(&self.transport)));
        out.push_str(&format!("  \"pipeline\": \"{}\",\n", json_escape(&self.pipeline)));
        out.push_str(&format!("  \"threads\": {},\n", self.threads));
        out.push_str(&format!("  \"quick\": {},\n", quick_mode()));
        out.push_str("  \"records\": [\n");
        for (i, (name, metrics)) in self.records.iter().enumerate() {
            out.push_str(&format!("    {{\"name\": \"{}\"", json_escape(name)));
            for (k, v) in metrics {
                out.push_str(&format!(", \"{}\": {}", json_escape(k), json_num(*v)));
            }
            out.push_str(if i + 1 < self.records.len() { "},\n" } else { "}\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write `BENCH_<bench>.json` into `$BENCH_JSON_DIR` (default: the
    /// working directory); returns the path written.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
        let path = PathBuf::from(dir).join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, self.to_json())?;
        println!("wrote {}", path.display());
        Ok(path)
    }
}

/// JSON string escaping shared by the hand-rolled writers ([`BenchJson`]
/// and the experiment artifact writer in [`crate::experiments`]).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON number: f64 `Display` never uses exponent notation; non-finite
/// values (which JSON cannot carry) become null.
pub(crate) fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut r = BenchRunner {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            min_time: Duration::from_millis(0),
            results: Vec::new(),
        };
        let mut count = 0usize;
        let s = r.bench("noop", || {
            count += 1;
            black_box(count);
        });
        assert!(s.n >= 3);
        assert_eq!(r.results().len(), 1);
        // warmup + measured
        assert!(count >= 4);
    }

    #[test]
    fn json_document_is_well_formed() {
        let mut j = BenchJson::new("unit");
        j.record("case \"a\"", &[("mean_ms", 1.5), ("n", 3.0)]);
        j.record("case_b", &[("mean_ms", f64::NAN)]);
        let doc = j.to_json();
        assert!(doc.contains("\"schema_version\": 2"));
        assert!(doc.contains("\"bench\": \"unit\""));
        // Context defaults: comparable across engine/transport runs.
        assert!(doc.contains("\"engine\": \"lockstep\""));
        assert!(doc.contains("\"transport\": \"inproc\""));
        assert!(doc.contains("\"pipeline\": \"off\""));
        // Kernel thread count always lands in the document (ambient
        // value; don't pin it — CI runs the suite at several counts).
        assert!(doc.contains("\"threads\": "));
        assert!(doc.contains("\"case \\\"a\\\"\", \"mean_ms\": 1.5, \"n\": 3"));
        assert!(doc.contains("\"case_b\", \"mean_ms\": null"));
        // Balanced braces/brackets — a cheap structural validity check.
        for (open, close) in [('{', '}'), ('[', ']')] {
            let opens = doc.matches(open).count();
            let closes = doc.matches(close).count();
            assert_eq!(opens, closes, "unbalanced {open}{close}");
        }
    }

    #[test]
    fn json_runner_results_round_trip() {
        let mut r = BenchRunner {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 2,
            min_time: Duration::from_millis(0),
            results: Vec::new(),
        };
        r.bench("tiny", || {
            black_box(1 + 1);
        });
        let mut j = BenchJson::new("runner");
        j.record_runner(&r);
        assert!(j.to_json().contains("\"tiny\""));
    }

    #[test]
    fn context_and_wire_records_land_in_the_document() {
        let mut j = BenchJson::new("wire");
        j.set_context("threaded", "tcp");
        j.set_pipeline("overlap");
        j.record_wire("all_reduce/w4", 1536, 1024);
        let doc = j.to_json();
        assert!(doc.contains("\"engine\": \"threaded\""));
        assert!(doc.contains("\"transport\": \"tcp\""));
        assert!(doc.contains("\"pipeline\": \"overlap\""));
        assert!(doc.contains("\"wire_bytes\": 1536"));
        assert!(doc.contains("\"logical_bytes\": 1024"));
    }

    #[test]
    fn tagged_runner_records_carry_extra_metrics() {
        let mut r = BenchRunner {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 2,
            min_time: Duration::from_millis(0),
            results: Vec::new(),
        };
        r.bench("case", || {
            black_box(1 + 1);
        });
        let mut j = BenchJson::new("tagged");
        j.set_threads(4);
        j.record_runner_tagged(&r, &[("threads", 4.0)]);
        let doc = j.to_json();
        assert!(doc.contains("\"threads\": 4,"), "document-level threads:\n{doc}");
        // The per-record tag lands at the end of the record line — this
        // is what the kernel_hotpath sweep relies on to distinguish
        // thread counts, so pin it independently of the header.
        assert!(doc.contains(", \"threads\": 4}"), "record-level threads tag:\n{doc}");
        assert!(doc.contains("\"mean_ms\":"), "{doc}");
    }

    #[test]
    fn max_iters_caps_fast_cases() {
        let mut r = BenchRunner {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 7,
            min_time: Duration::from_secs(3600),
            results: Vec::new(),
        };
        let s = r.bench("fast", || {
            black_box(1 + 1);
        });
        assert_eq!(s.n, 7);
    }
}
