//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments. Used by the `powersgd` binary and by every example.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    options: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (argv[0] must already be stripped).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    /// True when `--name` was given as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name value` / `--name=value`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Like [`Args::get`] with a default for absent options.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed lookup with default; panics with a readable message on a
    /// malformed value (CLI misuse should fail loudly).
    pub fn get_parsed_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{name}={v}: invalid value ({e:?})")),
        }
    }

    /// All positional (non-`--`) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// The first positional argument, by convention the subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["--rank", "4", "--workers=16"]);
        assert_eq!(a.get("rank"), Some("4"));
        assert_eq!(a.get("workers"), Some("16"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["train", "--verbose", "--rank", "2", "extra"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.positional(), &["train".to_string(), "extra".to_string()]);
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["--rank", "4"]);
        assert_eq!(a.get_parsed_or("rank", 1usize), 4);
        assert_eq!(a.get_parsed_or("workers", 16usize), 16);
        assert!((a.get_parsed_or("lr", 0.1f64) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse(&["--verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("verbose"), None);
    }

    #[test]
    #[should_panic]
    fn malformed_typed_value_panics() {
        let a = parse(&["--rank", "banana"]);
        let _: usize = a.get_parsed_or("rank", 1);
    }
}
