//! Gradient/parameter registry: how raw model tensors become the
//! matrices the compressors operate on.
//!
//! Following §3 of the paper:
//! - vector-shaped parameters (biases, norm scales) are aggregated
//!   **uncompressed**;
//! - convolution kernels `[out, in, kh, kw]` are flattened to
//!   `[out, in·kh·kw]` ("flattening input and kernel dimensions");
//! - everything else of rank ≥ 2 becomes `[shape[0], ∏ rest]`.

use crate::tensor::Tensor;

/// Bytes per transmitted element: every wire format in this crate is
/// **f32** (the paper's setup — no fp16/bf16 path exists). This is the
/// single home of that assumption on the *model* side: all analytic
/// byte accounting derives from it — [`ParamSpec::bytes`], the
/// per-scheme message models
/// ([`crate::simulate::Scheme::spec_message_bytes`] and the per-worker
/// [`crate::compress::WorkerCompressor::message_bytes`] implementations),
/// and everything downstream of them
/// ([`crate::simulate::Scheme::layer_timings`],
/// [`crate::simulate::data_per_epoch_mb`]).
///
/// The *transport* side frames f32 payloads independently (the ring
/// chunk arithmetic in [`crate::collectives::ring_wire_bytes`], the
/// packed all-reduce buffers, the `WireSized` impls), and the
/// measured-vs-analytic cross-checks pin the two sides to each other
/// on every metered run. A future mixed-precision wire format must
/// therefore replace this constant with a per-spec element size *and*
/// revisit those framing sites — the cross-checks will fail loudly
/// until both sides agree.
pub const ELEM_BYTES: u64 = 4;

/// How a parameter participates in compression.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressKind {
    /// Rank-≥2 tensor reshaped to a matrix and low-rank compressed.
    Matrix { rows: usize, cols: usize },
    /// Rank-1 (or scalar) tensor, sent uncompressed.
    Vector { len: usize },
}

/// One model parameter: name, original tensor shape, compression view.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    /// Parameter name (e.g. `layer4.1.conv2`), as the profile declares it.
    pub name: String,
    /// Original tensor shape, before matricization.
    pub shape: Vec<usize>,
    /// How the parameter participates in compression.
    pub kind: CompressKind,
}

impl ParamSpec {
    /// Build a spec applying the paper's matricization rule.
    pub fn new(name: &str, shape: &[usize]) -> ParamSpec {
        let numel: usize = shape.iter().product();
        let kind = if shape.len() >= 2 {
            CompressKind::Matrix { rows: shape[0], cols: numel / shape[0] }
        } else {
            CompressKind::Vector { len: numel }
        };
        ParamSpec { name: name.to_string(), shape: shape.to_vec(), kind }
    }

    /// Element count of the original tensor.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Uncompressed size in bytes ([`ELEM_BYTES`] per element — f32).
    pub fn bytes(&self) -> u64 {
        self.numel() as u64 * ELEM_BYTES
    }

    /// Matrix view dims, if compressed.
    pub fn matrix_dims(&self) -> Option<(usize, usize)> {
        match self.kind {
            CompressKind::Matrix { rows, cols } => Some((rows, cols)),
            CompressKind::Vector { .. } => None,
        }
    }

    /// Compressed message size (bytes) for a rank-`r` low-rank scheme:
    /// `(n + m)·r·4` for matrices, full size for vectors. This is the
    /// per-tensor "Compression" column of paper Tables 10/11. Capped at
    /// the uncompressed size (reporting convention).
    pub fn rank_r_bytes(&self, r: usize) -> u64 {
        self.rank_r_bytes_uncapped(r).min(self.bytes())
    }

    /// Like [`rank_r_bytes`](Self::rank_r_bytes) but without the cap:
    /// what PowerSGD actually transmits (`P` then `Q`) regardless of the
    /// matrix size.
    pub fn rank_r_bytes_uncapped(&self, r: usize) -> u64 {
        match self.kind {
            CompressKind::Matrix { rows, cols } => ((rows + cols) * r) as u64 * ELEM_BYTES,
            CompressKind::Vector { len } => len as u64 * ELEM_BYTES,
        }
    }
}

/// Ordered set of parameters for one model.
#[derive(Debug, Clone, Default)]
pub struct ParamRegistry {
    /// Per-parameter specs, in declaration (optimizer) order.
    pub specs: Vec<ParamSpec>,
}

impl ParamRegistry {
    /// Registry over pre-built specs.
    pub fn new(specs: Vec<ParamSpec>) -> ParamRegistry {
        ParamRegistry { specs }
    }

    /// Registry from `(name, shape)` pairs, applying the paper's
    /// matricization rule to each ([`ParamSpec::new`]).
    pub fn from_shapes(named_shapes: &[(&str, Vec<usize>)]) -> ParamRegistry {
        ParamRegistry {
            specs: named_shapes
                .iter()
                .map(|(n, s)| ParamSpec::new(n, s))
                .collect(),
        }
    }

    /// Number of parameters.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// True when the registry declares no parameters.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Total element count over all parameters.
    pub fn numel(&self) -> usize {
        self.specs.iter().map(|s| s.numel()).sum()
    }

    /// Total uncompressed gradient bytes per step (per worker message).
    pub fn total_bytes(&self) -> u64 {
        self.specs.iter().map(|s| s.bytes()).sum()
    }

    /// Total rank-`r` compressed bytes per step.
    pub fn total_rank_r_bytes(&self, r: usize) -> u64 {
        self.specs.iter().map(|s| s.rank_r_bytes(r)).sum()
    }

    /// Total rank-`r` transmitted bytes per step, uncapped (see
    /// [`ParamSpec::rank_r_bytes_uncapped`]).
    pub fn total_rank_r_bytes_uncapped(&self, r: usize) -> u64 {
        self.specs.iter().map(|s| s.rank_r_bytes_uncapped(r)).sum()
    }

    /// Overall compression ratio at rank `r` (paper Table 10: "243/r ×").
    pub fn compression_ratio(&self, r: usize) -> f64 {
        self.total_bytes() as f64 / self.total_rank_r_bytes(r) as f64
    }

    /// View raw gradient tensors as compression-shaped tensors
    /// (matrices reshaped, vectors untouched). Cheap: reshape is metadata.
    pub fn matricize(&self, grads: Vec<Tensor>) -> Vec<Tensor> {
        assert_eq!(grads.len(), self.specs.len(), "grad count mismatch");
        grads
            .into_iter()
            .zip(self.specs.iter())
            .map(|(g, spec)| {
                assert_eq!(g.len(), spec.numel(), "grad numel mismatch for {}", spec.name);
                match spec.kind {
                    CompressKind::Matrix { rows, cols } => g.reshape(&[rows, cols]),
                    CompressKind::Vector { len } => g.reshape(&[len]),
                }
            })
            .collect()
    }

    /// Undo [`Self::matricize`]: restore original tensor shapes.
    pub fn dematricize(&self, grads: Vec<Tensor>) -> Vec<Tensor> {
        assert_eq!(grads.len(), self.specs.len());
        grads
            .into_iter()
            .zip(self.specs.iter())
            .map(|(g, spec)| g.reshape(&spec.shape))
            .collect()
    }

    /// Allocate a zeroed update buffer set in compression shapes.
    pub fn zeros_like(&self) -> Vec<Tensor> {
        self.specs
            .iter()
            .map(|spec| match spec.kind {
                CompressKind::Matrix { rows, cols } => Tensor::zeros(&[rows, cols]),
                CompressKind::Vector { len } => Tensor::zeros(&[len]),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_matricization_matches_paper_table10() {
        // layer4.1.conv2: 512×512×3×3 → 512×4608, 9216 KB, 461/r ×
        let s = ParamSpec::new("layer4.1.conv2", &[512, 512, 3, 3]);
        assert_eq!(s.matrix_dims(), Some((512, 4608)));
        assert_eq!(s.bytes(), 9216 * 1024);
        let ratio = s.bytes() as f64 / s.rank_r_bytes(1) as f64;
        assert!((ratio - 460.8).abs() < 1.0, "ratio {ratio}");
    }

    #[test]
    fn bias_stays_vector() {
        let s = ParamSpec::new("bias", &[128]);
        assert_eq!(s.kind, CompressKind::Vector { len: 128 });
        assert_eq!(s.rank_r_bytes(1), 512); // full size
    }

    #[test]
    fn lstm_encoder_matches_paper_table11() {
        // encoder 28869×650: 636/r ×
        let s = ParamSpec::new("encoder", &[28869, 650]);
        let ratio = s.bytes() as f64 / s.rank_r_bytes(1) as f64;
        assert!((ratio - 635.8).abs() < 1.5, "ratio {ratio}");
    }

    #[test]
    fn rank_r_bytes_capped_at_uncompressed() {
        let s = ParamSpec::new("tiny", &[4, 4]);
        assert_eq!(s.rank_r_bytes(100), s.bytes());
    }

    #[test]
    fn matricize_roundtrip() {
        let reg = ParamRegistry::from_shapes(&[
            ("w", vec![8, 2, 3, 3]),
            ("b", vec![8]),
        ]);
        let grads = vec![Tensor::full(&[8 * 2 * 3 * 3], 1.0).reshape(&[8, 2, 3, 3]), Tensor::zeros(&[8])];
        let m = reg.matricize(grads.clone());
        assert_eq!(m[0].shape(), &[8, 18]);
        assert_eq!(m[1].shape(), &[8]);
        let back = reg.dematricize(m);
        assert_eq!(back[0].shape(), &[8, 2, 3, 3]);
        assert_eq!(back[0], grads[0]);
    }

    #[test]
    fn registry_totals() {
        let reg = ParamRegistry::from_shapes(&[("w", vec![10, 20]), ("b", vec![20])]);
        assert_eq!(reg.numel(), 220);
        assert_eq!(reg.total_bytes(), 880);
        // rank 1: (10+20)*4 + 80 = 200
        assert_eq!(reg.total_rank_r_bytes(1), 200);
    }
}
