//! Shape-profile timing simulator.
//!
//! Regenerates the paper's systems numbers (time per batch, its
//! breakdown, data per epoch, scaling curves) from first principles:
//! exact byte arithmetic over the published layer shapes
//! ([`crate::profiles`]), the α–β collective model ([`crate::net`]), and
//! closed-form encode/decode cost models calibrated against the paper's
//! Table 4/5/6 measurements (constants documented inline).
//!
//! Compute (fwd/bwd) is constant per profile — the paper states it is
//! "constant across all algorithms and numbers of workers" (Table 5).

use crate::collectives::CollKind;
use crate::compress::{decentralized_by_name, Compressor, DecentralizedCompressor};
use crate::grad::{CompressKind, ParamRegistry, ParamSpec, ELEM_BYTES};
use crate::net::Backend;
use crate::profiles::ModelProfile;
use crate::transport::{schedule_step, Bucketer, Cluster, ComputePhases, LayerTiming, OverlapOutcome};

/// Compression scheme, as the simulator sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Uncompressed baseline (full-gradient all-reduce).
    Sgd,
    /// Rank-`rank` PowerSGD (Algorithm 1).
    PowerSgd {
        /// Compression rank `r`.
        rank: usize,
    },
    /// Unbiased rank-`rank` sketching (§4.1).
    UnbiasedRank {
        /// Compression rank `r`.
        rank: usize,
    },
    /// Random contiguous block, `(n+m)·rank` values (Appendix G.1).
    RandomBlock {
        /// PowerSGD-equivalent rank setting the value budget.
        rank: usize,
    },
    /// Random coordinates without replacement (Appendix G.2).
    RandomK {
        /// PowerSGD-equivalent rank setting the value budget.
        rank: usize,
    },
    /// Largest-magnitude coordinates, gathered (Appendix G.3).
    TopK {
        /// PowerSGD-equivalent rank setting the value budget.
        rank: usize,
    },
    /// Sign + L1 norm (Algorithm 5), gathered.
    SignNorm,
    /// Signum majority vote (Appendix G.5), gathered.
    Signum,
    /// Rank-`rank` Spectral Atomo (Appendix G.6): full SVD per step.
    Atomo {
        /// Number of sampled singular components.
        rank: usize,
    },
}

impl Scheme {
    /// Display name matching the paper's table rows ("Rank 2",
    /// "Sign+Norm", ...).
    pub fn name(&self) -> String {
        match self {
            Scheme::Sgd => "SGD".into(),
            Scheme::PowerSgd { rank } => format!("Rank {rank}"),
            Scheme::UnbiasedRank { rank } => format!("Unbiased Rank {rank}"),
            Scheme::RandomBlock { rank } => format!("Random Block (r={rank})"),
            Scheme::RandomK { rank } => format!("Random K (r={rank})"),
            Scheme::TopK { rank } => format!("Top K (r={rank})"),
            Scheme::SignNorm => "Sign+Norm".into(),
            Scheme::Signum => "Signum".into(),
            Scheme::Atomo { rank } => format!("Atomo (rank {rank})"),
        }
    }

    /// Whether aggregation can use all-reduce (Table 4's ✓ column).
    pub fn all_reduce(&self) -> bool {
        matches!(
            self,
            Scheme::Sgd
                | Scheme::PowerSgd { .. }
                | Scheme::UnbiasedRank { .. }
                | Scheme::RandomBlock { .. }
                | Scheme::RandomK { .. }
        )
    }

    /// Per-worker message bytes one parameter contributes per step (the
    /// per-layer granularity the bucketer packs).
    ///
    /// Every value on the wire is an f32 ([`ELEM_BYTES`] — the single
    /// home of that assumption); sign schemes pack one bit per
    /// coordinate plus one f32 scale, and top-K sends `(index, value)`
    /// pairs at `2·ELEM_BYTES` each.
    pub fn spec_message_bytes(&self, s: &ParamSpec) -> u64 {
        let budget = |r: usize, per_val: u64| -> u64 {
            match s.kind {
                CompressKind::Matrix { rows, cols } => {
                    (((rows + cols) * r).min(rows * cols) as u64) * per_val
                }
                CompressKind::Vector { len } => len as u64 * ELEM_BYTES,
            }
        };
        match self {
            Scheme::Sgd => s.bytes(),
            Scheme::PowerSgd { rank } => s.rank_r_bytes_uncapped(*rank),
            Scheme::UnbiasedRank { rank } => match s.kind {
                CompressKind::Matrix { rows, .. } => (rows * rank) as u64 * ELEM_BYTES,
                CompressKind::Vector { len } => len as u64 * ELEM_BYTES,
            },
            Scheme::RandomBlock { rank } | Scheme::RandomK { rank } => budget(*rank, ELEM_BYTES),
            Scheme::TopK { rank } => budget(*rank, 2 * ELEM_BYTES),
            Scheme::SignNorm => match s.kind {
                CompressKind::Matrix { rows, cols } => {
                    ELEM_BYTES + ((rows * cols).div_ceil(8)) as u64
                }
                CompressKind::Vector { len } => len as u64 * ELEM_BYTES,
            },
            Scheme::Signum => match s.kind {
                CompressKind::Matrix { rows, cols } => ((rows * cols).div_ceil(8)) as u64,
                CompressKind::Vector { len } => len as u64 * ELEM_BYTES,
            },
            Scheme::Atomo { rank } => match s.kind {
                CompressKind::Matrix { rows, cols } => ((rows + cols) * rank) as u64 * ELEM_BYTES,
                CompressKind::Vector { len } => len as u64 * ELEM_BYTES,
            },
        }
    }

    /// Per-worker message bytes per step (paper's data-volume unit).
    pub fn message_bytes(&self, reg: &ParamRegistry) -> u64 {
        reg.specs.iter().map(|s| self.spec_message_bytes(s)).sum()
    }

    /// Per-layer sizing for the bucketer/overlap scheduler.
    ///
    /// Both byte columns assume f32 elements
    /// ([`ELEM_BYTES`](crate::grad::ELEM_BYTES)): `msg_bytes` via
    /// [`Scheme::spec_message_bytes`], `raw_bytes` via
    /// [`ParamSpec::bytes`].
    pub fn layer_timings(&self, reg: &ParamRegistry) -> Vec<LayerTiming> {
        reg.specs
            .iter()
            .map(|s| LayerTiming { msg_bytes: self.spec_message_bytes(s), raw_bytes: s.bytes() })
            .collect()
    }

    /// Canonical CLI spelling as a `(scheme, rank)` argument pair that
    /// round-trips through [`scheme_by_name`]:
    /// `scheme_by_name(&name, rank) == Some(*self)` for every scheme.
    /// Used by the experiment registry so every registered scenario is
    /// reachable from the command line.
    pub fn cli_spelling(&self) -> (String, usize) {
        match self {
            Scheme::Sgd => ("sgd".into(), 0),
            Scheme::PowerSgd { rank } => (format!("rank{rank}"), 0),
            Scheme::UnbiasedRank { rank } => ("unbiased-rank".into(), *rank),
            Scheme::RandomBlock { rank } => ("random-block".into(), *rank),
            Scheme::RandomK { rank } => ("random-k".into(), *rank),
            Scheme::TopK { rank } => ("top-k".into(), *rank),
            Scheme::SignNorm => ("sign-norm".into(), 0),
            Scheme::Signum => ("signum".into(), 0),
            Scheme::Atomo { rank } => ("atomo".into(), *rank),
        }
    }
}

/// Scheme by CLI name. Accepts the long `train`-subcommand spellings
/// ("powersgd", "sign-norm", ...) plus the compact "rank1"/"rank2"/...
/// spellings of the paper's tables (which override `rank`).
pub fn scheme_by_name(name: &str, rank: usize) -> Option<Scheme> {
    Some(match name {
        "sgd" | "none" => Scheme::Sgd,
        "powersgd" | "rank" => Scheme::PowerSgd { rank },
        "unbiased-rank" => Scheme::UnbiasedRank { rank },
        "random-block" => Scheme::RandomBlock { rank },
        "random-k" => Scheme::RandomK { rank },
        "top-k" => Scheme::TopK { rank },
        "sign-norm" => Scheme::SignNorm,
        "signum" => Scheme::Signum,
        "atomo" => Scheme::Atomo { rank },
        other => {
            let r: usize = other.strip_prefix("rank")?.parse().ok().filter(|&r| r >= 1)?;
            return Some(Scheme::PowerSgd { rank: r });
        }
    })
}

/// The decentralized per-worker implementation of `scheme`, when one
/// exists (PowerSGD, unbiased rank-r, sign, top-K, no compression).
pub fn decentralized_for_scheme(scheme: Scheme, seed: u64) -> Option<DecentralizedCompressor> {
    match scheme {
        Scheme::Sgd => decentralized_by_name("identity", 0, seed),
        Scheme::PowerSgd { rank } => decentralized_by_name("powersgd", rank, seed),
        Scheme::UnbiasedRank { rank } => decentralized_by_name("unbiased-rank", rank, seed),
        Scheme::TopK { rank } => decentralized_by_name("top-k", rank, seed),
        Scheme::SignNorm => decentralized_by_name("sign-norm", 0, seed),
        _ => None,
    }
}

/// The centralized oracle implementation of `scheme`, for checking the
/// decentralized path against (same seed ⇒ bitwise-identical output).
pub fn centralized_for_scheme(scheme: Scheme, seed: u64) -> Option<Box<dyn Compressor>> {
    use crate::compress::{NoCompression, PowerSgd, SignNorm, TopK, UnbiasedRank};
    Some(match scheme {
        Scheme::Sgd => Box::new(NoCompression::new()),
        Scheme::PowerSgd { rank } => Box::new(PowerSgd::new(rank, seed)),
        Scheme::UnbiasedRank { rank } => Box::new(UnbiasedRank::new(rank, seed)),
        Scheme::TopK { rank } => Box::new(TopK::new(rank)),
        Scheme::SignNorm => Box::new(SignNorm::new()),
        _ => return None,
    })
}

// ---------------------------------------------------------------------
// Encode/decode cost model constants — calibrated to Table 4 & §4.2
// on the paper's GTX Titan X + Xeon testbed. Each constant is the
// effective throughput of the relevant primitive on that hardware.
// ---------------------------------------------------------------------

/// Effective GEMM throughput for PowerSGD's skinny products (GPU is far
/// below peak at r ≤ 4; bandwidth-bound). Calibrated so rank-2 ResNet18
/// encode+decode ≈ 4 ms (Table 4: 239 ms total = 235 fwd/bwd + ~1 comm
/// + ~4 code).
const SKINNY_GEMM_FLOPS: f64 = 3.0e11;
/// Streaming pack/unpack (sign pack, block copy), bytes/s — the paper's
/// C++ bit-packing extension.
const PACK_BW: f64 = 2.0e9;
/// Per-gathered-message decode cost of Sign+Norm, s/value: each worker
/// unpacks W float-scaled sign tensors and averages them (Table 4:
/// 429 ms total ⇒ decode ≈ 143 ms at W=16 on ResNet18).
const SIGN_DECODE_S: f64 = 0.8e-9;
/// Random (gather/scatter) access cost per value, seconds.
const RANDOM_ACCESS_S: f64 = 25e-9;
/// Random-K's per-*scanned*-value cost: numpy samples indices without
/// replacement on the CPU, which permutes the full tensor (Appendix G.2:
/// "This operation is relatively expensive"). Calibrated: Random-K on
/// ResNet18 ⇒ encode+decode ≈ 300 ms ⇒ 540 ms total (Table 4).
const SAMPLE_SCAN_S: f64 = 13.4e-9;
/// Top-K selection cost per scanned value (torch.topk over the full
/// tensor). Calibrated: Table 4 Top-K medium = 444 ms.
const SELECT_S: f64 = 8.0e-9;
/// Effective CPU SVD throughput (LAPACK gesdd on the Xeon E5-2680 v3),
/// FLOP/s. Calibrated: ResNet18 full SVD ≈ 673 ms (§4.2).
const SVD_FLOPS: f64 = 2.9e10;

/// One simulated step's time breakdown, seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepBreakdown {
    /// Forward pass (constant per profile).
    pub fwd: f64,
    /// Backward pass (constant per profile).
    pub bwd: f64,
    /// Gradient compression (encode) time.
    pub encode: f64,
    /// Collective communication time (α–β model).
    pub comm: f64,
    /// Decompression (decode) time.
    pub decode: f64,
}

impl StepBreakdown {
    /// End-to-end step time: the paper's "time per batch" column.
    pub fn total(&self) -> f64 {
        self.fwd + self.bwd + self.encode + self.comm + self.decode
    }
}

/// Sum of low-rank GEMM flops for `M·Q` (or equivalent) over the model.
fn lowrank_gemm_flops(reg: &ParamRegistry, rank: usize) -> f64 {
    reg.specs
        .iter()
        .filter_map(|s| s.matrix_dims())
        .map(|(n, m)| 2.0 * (n * m * rank) as f64)
        .sum()
}

/// Sum of SVD flops (`O(n·m·min²)` one-sided) over the model's matrices.
fn svd_flops(reg: &ParamRegistry) -> f64 {
    reg.specs
        .iter()
        .filter_map(|s| s.matrix_dims())
        .map(|(n, m)| {
            let (hi, lo) = if n > m { (n, m) } else { (m, n) };
            4.0 * hi as f64 * lo as f64 * lo as f64
        })
        .sum()
}

fn sparsify_values(reg: &ParamRegistry, rank: usize) -> f64 {
    reg.specs
        .iter()
        .filter_map(|s| s.matrix_dims())
        .map(|(n, m)| ((n + m) * rank).min(n * m) as f64)
        .sum()
}

fn total_matrix_values(reg: &ParamRegistry) -> f64 {
    reg.specs
        .iter()
        .filter_map(|s| s.matrix_dims())
        .map(|(n, m)| (n * m) as f64)
        .sum()
}

/// Encode/decode times (seconds) for `scheme` on `reg` with `w` workers —
/// the closed-form cost models calibrated against Tables 4/5.
fn codec_times(reg: &ParamRegistry, scheme: Scheme, w: usize) -> (f64, f64) {
    let msg = scheme.message_bytes(reg);
    let nm = total_matrix_values(reg);

    match scheme {
        Scheme::Sgd => (0.0, 0.0),
        Scheme::PowerSgd { rank } => {
            // encode: P = M·Q and Q = Mᵀ·P̂ (two skinny GEMMs) + GS;
            // decode: P̂·Qᵀ (one skinny GEMM). All-reduce pre-aggregates,
            // so decode is independent of W.
            let gemm = lowrank_gemm_flops(reg, rank);
            ((2.0 * gemm) / SKINNY_GEMM_FLOPS, gemm / SKINNY_GEMM_FLOPS)
        }
        Scheme::UnbiasedRank { rank } => {
            let gemm = lowrank_gemm_flops(reg, rank);
            (gemm / SKINNY_GEMM_FLOPS, gemm / SKINNY_GEMM_FLOPS)
        }
        Scheme::RandomBlock { .. } => {
            // contiguous copy in, scatter out — streaming speed
            ((msg as f64) / PACK_BW, (msg as f64) / PACK_BW)
        }
        Scheme::RandomK { rank } => {
            // CPU index sampling scans the full tensor, plus random
            // gathers/scatters of the k selected values.
            let k = sparsify_values(reg, rank);
            (
                nm * SAMPLE_SCAN_S + k * RANDOM_ACCESS_S,
                nm * SAMPLE_SCAN_S + k * RANDOM_ACCESS_S,
            )
        }
        Scheme::TopK { rank } => {
            // selection scans every value; decode scatters W messages
            let k = sparsify_values(reg, rank);
            (nm * SELECT_S, w as f64 * k * RANDOM_ACCESS_S)
        }
        Scheme::SignNorm => {
            // bit-pack encode; decode unpacks + float-averages W gathered
            // sign tensors (per-value work, W-scaled)
            (nm * 4.0 / PACK_BW, w as f64 * nm * SIGN_DECODE_S)
        }
        Scheme::Signum => {
            // same encode; majority vote decodes in the packed domain
            // with the optimized C++ extension (4 bit-ops per byte)
            (nm * 4.0 / PACK_BW, w as f64 * (nm / 8.0) * 4.0 / PACK_BW)
        }
        Scheme::Atomo { .. } => {
            // full SVD every step (encode); decode reconstructs W
            // rank-r outer products
            (
                svd_flops(reg) / SVD_FLOPS,
                w as f64 * lowrank_gemm_flops(reg, 1) / SKINNY_GEMM_FLOPS,
            )
        }
    }
}

/// Simulate one training step for `scheme` on `profile` with `w` workers
/// over `backend`.
pub fn simulate_step(
    profile: &ModelProfile,
    scheme: Scheme,
    w: usize,
    backend: &Backend,
) -> StepBreakdown {
    let reg = &profile.registry;
    let (encode, decode) = codec_times(reg, scheme, w);

    let comm = if w <= 1 {
        0.0
    } else {
        let kind = if scheme.all_reduce() { CollKind::AllReduce } else { CollKind::AllGather };
        backend.time(kind, scheme.message_bytes(reg), w)
    };

    StepBreakdown { fwd: profile.fwd_s, bwd: profile.bwd_s, encode, comm, decode }
}

/// Simulate one training step with DDP-style gradient bucketing and
/// (optionally) comm/compute overlap on a heterogeneous `cluster` — the
/// threaded engine's timing model. `bucket_bytes` caps each bucket's raw
/// gradient bytes (0 = one bucket, i.e. no bucketing, in which case
/// overlap buys nothing by construction).
pub fn simulate_step_overlapped(
    profile: &ModelProfile,
    scheme: Scheme,
    cluster: &Cluster,
    bucket_bytes: u64,
    overlap: bool,
) -> OverlapOutcome {
    let reg = &profile.registry;
    let (encode, decode) = codec_times(reg, scheme, cluster.workers());
    let layers = scheme.layer_timings(reg);
    let buckets = Bucketer::new(bucket_bytes).assign(&layers);
    let kind = if scheme.all_reduce() { CollKind::AllReduce } else { CollKind::AllGather };
    let compute = ComputePhases {
        fwd_s: profile.fwd_s,
        bwd_s: profile.bwd_s,
        encode_s: encode,
        decode_s: decode,
    };
    schedule_step(
        &layers,
        &buckets,
        compute,
        &|b| cluster.time(kind, b.msg_bytes),
        cluster,
        overlap,
    )
}

/// Data sent per epoch in the paper's "MB" (actually MiB — Table 10's
/// 9216 KB for a 512×4608 f32 tensor is KiB). Assumes f32 elements
/// throughout, via [`Scheme::message_bytes`] and the crate-wide
/// [`ELEM_BYTES`](crate::grad::ELEM_BYTES) constant it is built on.
pub fn data_per_epoch_mb(profile: &ModelProfile, scheme: Scheme) -> f64 {
    scheme.message_bytes(&profile.registry) as f64 * profile.steps_per_epoch / (1024.0 * 1024.0)
}

/// Figure 3: epoch time relative to 1-worker SGD, at `w` workers
/// (batch size scales with W, so steps/epoch scale as 1/W).
pub fn epoch_speedup_vs_single_sgd(
    profile: &ModelProfile,
    scheme: Scheme,
    w: usize,
    backend: &Backend,
) -> f64 {
    let single = simulate_step(profile, Scheme::Sgd, 1, backend).total() * profile.steps_per_epoch;
    let multi =
        simulate_step(profile, scheme, w, backend).total() * profile.steps_per_epoch / w as f64;
    single / multi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SchemeMeta;
    use crate::net::{GLOO, NCCL};
    use crate::profiles::{lstm_wikitext2, resnet18};

    #[test]
    fn scheme_names_parse_including_compact_rank() {
        assert_eq!(scheme_by_name("rank2", 0), Some(Scheme::PowerSgd { rank: 2 }));
        assert_eq!(scheme_by_name("powersgd", 4), Some(Scheme::PowerSgd { rank: 4 }));
        assert_eq!(scheme_by_name("sign-norm", 1), Some(Scheme::SignNorm));
        assert_eq!(scheme_by_name("bogus", 1), None);
        assert_eq!(scheme_by_name("rankx", 1), None);
        // rank 0 must be a clean parse error, not a downstream panic.
        assert_eq!(scheme_by_name("rank0", 1), None);
        assert!(decentralized_for_scheme(Scheme::PowerSgd { rank: 2 }, 1).is_some());
        assert!(decentralized_for_scheme(Scheme::Signum, 1).is_none());
        assert!(centralized_for_scheme(Scheme::SignNorm, 1).is_some());
        assert!(centralized_for_scheme(Scheme::Atomo { rank: 2 }, 1).is_none());
    }

    #[test]
    fn cli_spelling_round_trips_every_scheme() {
        let all = [
            Scheme::Sgd,
            Scheme::PowerSgd { rank: 4 },
            Scheme::UnbiasedRank { rank: 2 },
            Scheme::RandomBlock { rank: 2 },
            Scheme::RandomK { rank: 7 },
            Scheme::TopK { rank: 2 },
            Scheme::SignNorm,
            Scheme::Signum,
            Scheme::Atomo { rank: 2 },
        ];
        for scheme in all {
            let (name, rank) = scheme.cli_spelling();
            assert_eq!(scheme_by_name(&name, rank), Some(scheme), "{name}");
        }
    }

    #[test]
    fn scheme_compressor_mappings_stay_in_sync() {
        // The scheme → compressor mappings live in several match arms;
        // this pins them together so adding a decentralized path without
        // its oracle counterpart (or vice versa) fails loudly instead of
        // silently skipping / falling back.
        let all = [
            Scheme::Sgd,
            Scheme::PowerSgd { rank: 2 },
            Scheme::UnbiasedRank { rank: 2 },
            Scheme::RandomBlock { rank: 2 },
            Scheme::RandomK { rank: 2 },
            Scheme::TopK { rank: 2 },
            Scheme::SignNorm,
            Scheme::Signum,
            Scheme::Atomo { rank: 2 },
        ];
        for scheme in all {
            let dec = decentralized_for_scheme(scheme, 1);
            let cen = centralized_for_scheme(scheme, 1);
            assert_eq!(
                dec.is_some(),
                cen.is_some(),
                "{}: decentralized and oracle mappings drifted",
                scheme.name()
            );
            if let (Some(d), Some(c)) = (dec, cen) {
                assert_eq!(d.name(), format!("{} (per-worker)", c.name()));
                assert_eq!(d.supports_all_reduce(), c.supports_all_reduce());
                assert_eq!(d.supports_all_reduce(), scheme.all_reduce());
            }
        }
    }

    #[test]
    fn scheme_bytes_match_worker_models_on_the_tcp_harness_registry() {
        // The TCP harness verifies measured wire bytes against the
        // per-worker compressor's message_bytes model; this pins that
        // model to the simulator's Scheme::message_bytes for every
        // mapped scheme, closing the chain
        // measured ↔ logged ↔ worker model ↔ analytic Scheme.
        use crate::compress::worker_by_name;
        use crate::transport::tcp::harness_registry;
        let reg = harness_registry();
        let cases: [(Scheme, &str); 5] = [
            (Scheme::PowerSgd { rank: 2 }, "powersgd"),
            (Scheme::UnbiasedRank { rank: 2 }, "unbiased-rank"),
            (Scheme::TopK { rank: 2 }, "top-k"),
            (Scheme::SignNorm, "sign-norm"),
            (Scheme::Sgd, "none"),
        ];
        for (scheme, name) in cases {
            let worker = worker_by_name(name, 2, 0).unwrap();
            assert_eq!(
                scheme.message_bytes(&reg),
                worker.message_bytes(&reg),
                "{}",
                scheme.name()
            );
        }
    }

    #[test]
    fn table3_resnet_times_reproduced() {
        let p = resnet18();
        let sgd = simulate_step(&p, Scheme::Sgd, 16, &NCCL).total() * 1e3;
        let r1 = simulate_step(&p, Scheme::PowerSgd { rank: 1 }, 16, &NCCL).total() * 1e3;
        let r2 = simulate_step(&p, Scheme::PowerSgd { rank: 2 }, 16, &NCCL).total() * 1e3;
        // paper: 312 / 229 / 239 ms. Accept the ordering + rough scale.
        assert!((280.0..340.0).contains(&sgd), "sgd {sgd}");
        assert!(r1 < r2 + 1.0 && r2 < sgd, "r1 {r1} r2 {r2} sgd {sgd}");
        assert!((220.0..260.0).contains(&r2), "rank2 {r2}");
        let saving = (sgd - r2) / sgd;
        assert!((0.15..0.32).contains(&saving), "rank-2 saving {saving}");
    }

    #[test]
    fn table7_lstm_times_reproduced() {
        let p = lstm_wikitext2();
        let sgd = simulate_step(&p, Scheme::Sgd, 16, &NCCL).total() * 1e3;
        let r4 = simulate_step(&p, Scheme::PowerSgd { rank: 4 }, 16, &NCCL).total() * 1e3;
        // paper: 300 vs 134 ms (−55%)
        assert!((260.0..340.0).contains(&sgd), "sgd {sgd}");
        assert!((115.0..165.0).contains(&r4), "rank4 {r4}");
        let saving = (sgd - r4) / sgd;
        assert!((0.45..0.65).contains(&saving), "saving {saving}");
    }

    #[test]
    fn table6_orderings() {
        let p = resnet18();
        let sgd = simulate_step(&p, Scheme::Sgd, 16, &NCCL).total();
        let atomo = simulate_step(&p, Scheme::Atomo { rank: 2 }, 16, &NCCL).total();
        let signum = simulate_step(&p, Scheme::Signum, 16, &NCCL).total();
        let rank2 = simulate_step(&p, Scheme::PowerSgd { rank: 2 }, 16, &NCCL).total();
        // paper: Atomo 948 ms ≫ SGD 312 ≳ Signum 301 > Rank2 239
        assert!(atomo > 2.0 * sgd, "atomo {atomo} sgd {sgd}");
        assert!(rank2 < signum && signum < sgd * 1.1, "signum {signum}");
    }

    #[test]
    fn table4_random_k_slower_than_sgd() {
        let p = resnet18();
        let rk = simulate_step(&p, Scheme::RandomK { rank: 7 }, 16, &NCCL).total() * 1e3;
        let sgd = simulate_step(&p, Scheme::Sgd, 16, &NCCL).total() * 1e3;
        // paper: 540 ms vs 312 ms
        assert!(rk > sgd, "random-k {rk} vs sgd {sgd}");
        assert!((420.0..680.0).contains(&rk), "{rk}");
    }

    #[test]
    fn table4_random_block_fast() {
        let p = resnet18();
        let rb = simulate_step(&p, Scheme::RandomBlock { rank: 2 }, 16, &NCCL).total() * 1e3;
        // paper: 240 ms (high compression)
        assert!((225.0..260.0).contains(&rb), "{rb}");
    }

    #[test]
    fn table5_decode_scales_with_w_only_for_gather() {
        let p = resnet18();
        let d4 = simulate_step(&p, Scheme::SignNorm, 4, &NCCL).decode;
        let d16 = simulate_step(&p, Scheme::SignNorm, 16, &NCCL).decode;
        assert!((d16 / d4 - 4.0).abs() < 0.2, "gather decode should scale 4x");
        let p4 = simulate_step(&p, Scheme::PowerSgd { rank: 2 }, 4, &NCCL).decode;
        let p16 = simulate_step(&p, Scheme::PowerSgd { rank: 2 }, 16, &NCCL).decode;
        assert!((p16 - p4).abs() < 1e-9, "all-reduce decode must be constant");
    }

    #[test]
    fn fig3_powersgd_scales_best_on_gloo() {
        let p = resnet18();
        let s_sgd = epoch_speedup_vs_single_sgd(&p, Scheme::Sgd, 16, &GLOO);
        let s_pow = epoch_speedup_vs_single_sgd(&p, Scheme::PowerSgd { rank: 2 }, 16, &GLOO);
        let s_sig = epoch_speedup_vs_single_sgd(&p, Scheme::Signum, 16, &GLOO);
        assert!(s_pow > s_sgd && s_pow > s_sig, "pow {s_pow} sgd {s_sgd} sig {s_sig}");
        // PowerSGD keeps near-linear scaling even on GLOO
        assert!(s_pow > 10.0, "{s_pow}");
    }

    #[test]
    fn svd_cost_matches_section_4_2() {
        // §4.2: "computing the SVD of a stochastic gradient takes 673 ms"
        let p = resnet18();
        let t = svd_flops(&p.registry) / SVD_FLOPS * 1e3;
        assert!((450.0..900.0).contains(&t), "svd {t} ms");
        // "one full step of rank-2 POWERSGD, including communication
        // between 16 workers, takes only 105 ms" — compression + comm only
        let b = simulate_step(&p, Scheme::PowerSgd { rank: 2 }, 16, &NCCL);
        let step = (b.encode + b.comm + b.decode) * 1e3;
        assert!(step < 110.0, "powersgd step {step} ms");
    }

    #[test]
    fn per_spec_bytes_pin_hand_computed_constants() {
        // Pin the per-layer formulas against hand-computed values for a
        // layer4.1.conv2-shaped matrix (512×4608 after matricization)
        // and the ResNet bias vector, so a regression in any scheme's
        // per-spec formula cannot cancel out of the aggregate.
        let m = ParamSpec::new("conv", &[512, 512, 3, 3]);
        let v = ParamSpec::new("biases", &[9728]);
        let cases: [(Scheme, u64); 9] = [
            (Scheme::Sgd, 512 * 4608 * 4),
            (Scheme::PowerSgd { rank: 2 }, (512 + 4608) * 2 * 4),
            (Scheme::UnbiasedRank { rank: 2 }, 512 * 2 * 4),
            (Scheme::RandomBlock { rank: 2 }, (512 + 4608) * 2 * 4),
            (Scheme::RandomK { rank: 2 }, (512 + 4608) * 2 * 4),
            (Scheme::TopK { rank: 2 }, (512 + 4608) * 2 * 8),
            (Scheme::SignNorm, 4 + (512u64 * 4608).div_ceil(8)),
            (Scheme::Signum, (512u64 * 4608).div_ceil(8)),
            (Scheme::Atomo { rank: 2 }, (512 + 4608) * 2 * 4),
        ];
        for (scheme, want) in cases {
            assert_eq!(scheme.spec_message_bytes(&m), want, "{}", scheme.name());
            // vectors always travel uncompressed
            assert_eq!(scheme.spec_message_bytes(&v), 9728 * 4, "{} vector", scheme.name());
        }
    }

    #[test]
    fn overlap_beats_no_overlap_for_powersgd_rank2() {
        // Acceptance: bucketing+overlap strictly below no-overlap at
        // W ∈ {4, 8, 16} for PowerSGD rank 2.
        let p = resnet18();
        let bucket = 4 * 1024 * 1024;
        for &w in &[4usize, 8, 16] {
            let cluster = Cluster::uniform(w, &NCCL);
            let scheme = Scheme::PowerSgd { rank: 2 };
            let with = simulate_step_overlapped(&p, scheme, &cluster, bucket, true);
            let without = simulate_step_overlapped(&p, scheme, &cluster, bucket, false);
            assert!(
                with.total < without.total,
                "W={w}: overlapped {} must beat sequential {}",
                with.total,
                without.total
            );
            assert!(with.exposed_comm < without.exposed_comm, "W={w}");
        }
    }

    #[test]
    fn unbucketed_sequential_matches_flat_model() {
        // bucket_bytes = 0 (one bucket) + no overlap reproduces the flat
        // fwd+bwd+encode+comm+decode model on a uniform cluster.
        let p = resnet18();
        let scheme = Scheme::PowerSgd { rank: 2 };
        let flat = simulate_step(&p, scheme, 16, &NCCL).total();
        let cluster = Cluster::uniform(16, &NCCL);
        let o = simulate_step_overlapped(&p, scheme, &cluster, 0, false);
        assert!((o.total - flat).abs() < 1e-9, "{} vs {flat}", o.total);
        assert_eq!(o.buckets, 1);
    }

    #[test]
    fn straggler_stretches_the_step() {
        let p = resnet18();
        let scheme = Scheme::PowerSgd { rank: 2 };
        let nominal = simulate_step_overlapped(
            &p,
            scheme,
            &Cluster::uniform(8, &NCCL),
            4 << 20,
            true,
        );
        let straggled = simulate_step_overlapped(
            &p,
            scheme,
            &Cluster::with_straggler(8, &NCCL, 2.0),
            4 << 20,
            true,
        );
        assert!(
            straggled.total > 1.8 * nominal.total,
            "{} vs {}",
            straggled.total,
            nominal.total
        );
    }

    #[test]
    fn overlap_helps_sgd_too() {
        // Agarwal et al.: overlap shrinks compression's edge — plain SGD
        // hides most of its 43 MB all-reduce behind the 140 ms backprop.
        let p = resnet18();
        let cluster = Cluster::uniform(16, &NCCL);
        let with = simulate_step_overlapped(&p, Scheme::Sgd, &cluster, 4 << 20, true);
        let without = simulate_step_overlapped(&p, Scheme::Sgd, &cluster, 4 << 20, false);
        assert!(with.total < without.total);
        assert!(with.exposed_comm < 0.5 * without.exposed_comm);
    }

    #[test]
    fn data_per_epoch_columns() {
        let p = resnet18();
        assert!((data_per_epoch_mb(&p, Scheme::Sgd) - 1023.0).abs() < 60.0);
        let r1 = data_per_epoch_mb(&p, Scheme::PowerSgd { rank: 1 });
        assert!((3.0..5.5).contains(&r1), "rank1 {r1}");
        let lstm = lstm_wikitext2();
        let r4 = data_per_epoch_mb(&lstm, Scheme::PowerSgd { rank: 4 });
        assert!((55.0..75.0).contains(&r4), "lstm rank4 {r4}");
    }
}
