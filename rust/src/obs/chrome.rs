//! Chrome-trace-event (Perfetto-compatible) export, rank-suffixed
//! artifact naming, and the coordinator-side trace merge.
//!
//! The output follows the Trace Event Format's JSON-object flavor:
//! `{"traceEvents": [...]}` with duration events emitted as balanced
//! `B`/`E` pairs (`ph`, `ts` in microseconds, `pid` = rank, `tid` =
//! track index) plus `M` metadata events naming each process and
//! track. Open the file at <https://ui.perfetto.dev> or
//! `chrome://tracing`.
//!
//! Everything here is hand-rolled string assembly (serde is unavailable
//! offline) in a fixed line-oriented layout: one event per line inside
//! the `traceEvents` array. [`merge_chrome_traces`] relies on that
//! layout to splice per-rank files into one document without a JSON
//! parser, and [`validate_chrome_trace`] re-checks the structural
//! invariants (balanced begin/end, per-track timestamp monotonicity)
//! that `tests/integration_obs.rs` pins.

use super::SpanEvent;
use crate::util::bench::json_escape;
use std::path::{Path, PathBuf};

/// Serialize named tracks into one Chrome-trace JSON document.
///
/// `pid` groups every track under one process row (the worker rank in
/// multi-process runs; 0 for single-process `train`/`simulate`), and
/// `process_name` labels it. Spans on one track may nest but — by the
/// RAII span discipline — never partially overlap; the begin/end pairs
/// are emitted from a stack so the output is always balanced even if a
/// clock hiccup produced a crossing interval (the child is clamped to
/// its enclosing span).
pub fn chrome_trace_json(
    pid: u32,
    process_name: &str,
    tracks: &[(String, Vec<SpanEvent>)],
) -> String {
    let mut lines: Vec<String> = Vec::new();
    lines.push(format!(
        "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {pid}, \"tid\": 0, \
         \"args\": {{\"name\": \"{}\"}}}}",
        json_escape(process_name)
    ));
    for (tid, (name, events)) in tracks.iter().enumerate() {
        lines.push(format!(
            "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": {pid}, \"tid\": {tid}, \
             \"args\": {{\"name\": \"{}\"}}}}",
            json_escape(name)
        ));
        // Events arrive sorted by (start asc, end desc) from
        // `drain_tracks`; a stack of end timestamps turns the nesting
        // into balanced B/E pairs. A child end is clamped to its
        // enclosing span's end, so even a crossing interval (clock
        // hiccup) emits monotone, balanced output.
        let mut stack: Vec<u64> = Vec::new();
        for e in events {
            while stack.last().is_some_and(|&top| top <= e.start_ns) {
                let top = stack.pop().expect("checked non-empty");
                lines.push(end_line(pid, tid, top));
            }
            lines.push(format!(
                "{{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"B\", \"pid\": {pid}, \
                 \"tid\": {tid}, \"ts\": {}}}",
                e.phase.name(),
                e.phase.category(),
                micros(e.start_ns)
            ));
            let end = stack.last().map_or(e.end_ns, |&parent| e.end_ns.min(parent));
            stack.push(end.max(e.start_ns));
        }
        while let Some(top) = stack.pop() {
            lines.push(end_line(pid, tid, top));
        }
    }
    let mut out = String::from(EVENTS_OPEN);
    out.push_str(&lines.join(",\n"));
    out.push_str(EVENTS_CLOSE);
    out.push('\n');
    out
}

/// One `E` (span end) event line.
fn end_line(pid: u32, tid: usize, end_ns: u64) -> String {
    format!("{{\"ph\": \"E\", \"pid\": {pid}, \"tid\": {tid}, \"ts\": {}}}", micros(end_ns))
}

/// Timestamp in microseconds with nanosecond precision (Perfetto
/// accepts fractional `ts`).
fn micros(ns: u64) -> String {
    let micros = ns / 1000;
    let frac = ns % 1000;
    if frac == 0 {
        format!("{micros}")
    } else {
        format!("{micros}.{frac:03}")
    }
}

/// Rank-suffixed artifact path: `TRACE.json` → `TRACE_r3.json`. The
/// per-rank naming convention every multi-process artifact follows.
pub fn rank_trace_path(base: &Path, rank: usize) -> PathBuf {
    let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("TRACE");
    let ext = base.extension().and_then(|s| s.to_str()).unwrap_or("json");
    base.with_file_name(format!("{stem}_r{rank}.{ext}"))
}

const EVENTS_OPEN: &str = "{\"traceEvents\": [\n";
const EVENTS_CLOSE: &str = "\n], \"displayTimeUnit\": \"ms\"}";

/// Merge documents produced by [`chrome_trace_json`] into one. Ranks
/// whose file is missing simply contribute nothing (the dead-peer-safe
/// partial merge: `launch` merges whatever per-rank files survived).
/// Returns `None` when a part does not follow the writer's layout.
pub fn merge_chrome_traces(parts: &[String]) -> Option<String> {
    let mut events: Vec<&str> = Vec::new();
    for part in parts {
        let body = part
            .strip_prefix(EVENTS_OPEN)?
            .split(EVENTS_CLOSE)
            .next()?;
        if !body.is_empty() {
            events.push(body);
        }
    }
    let mut out = String::from(EVENTS_OPEN);
    out.push_str(&events.join(",\n"));
    out.push_str(EVENTS_CLOSE);
    out.push('\n');
    Some(out)
}

/// Structural validation of a [`chrome_trace_json`] document: every
/// `B` has a matching `E` on its `(pid, tid)` track and timestamps are
/// monotone per track. Returns the number of complete `B`/`E` pairs.
///
/// This is a checker for the writer's own line-oriented layout, not a
/// general JSON parser — exactly what the well-formedness tests and
/// the `launch` merge path need.
pub fn validate_chrome_trace(doc: &str) -> Result<usize, String> {
    let body = doc
        .strip_prefix(EVENTS_OPEN)
        .ok_or("missing traceEvents header")?
        .split(EVENTS_CLOSE)
        .next()
        .ok_or("missing traceEvents close")?;
    let mut pairs = 0usize;
    // (pid, tid) -> (open B count, last ts seen)
    let mut tracks: std::collections::HashMap<(u64, u64), (usize, f64)> =
        std::collections::HashMap::new();
    for (i, line) in body.lines().enumerate() {
        let line = line.trim().trim_end_matches(',');
        if line.is_empty() {
            continue;
        }
        let ph = field_str(line, "ph").ok_or_else(|| format!("line {i}: no ph"))?;
        if ph == "M" {
            continue;
        }
        let pid = field_num(line, "pid").ok_or_else(|| format!("line {i}: no pid"))?;
        let tid = field_num(line, "tid").ok_or_else(|| format!("line {i}: no tid"))?;
        let ts = field_num(line, "ts").ok_or_else(|| format!("line {i}: no ts"))?;
        let entry = tracks.entry((pid as u64, tid as u64)).or_insert((0, f64::MIN));
        if ts < entry.1 {
            return Err(format!(
                "line {i}: ts {ts} decreases on track ({pid}, {tid}) (last {})",
                entry.1
            ));
        }
        entry.1 = ts;
        match ph {
            "B" => entry.0 += 1,
            "E" => {
                if entry.0 == 0 {
                    return Err(format!("line {i}: E without open B on ({pid}, {tid})"));
                }
                entry.0 -= 1;
                pairs += 1;
            }
            other => return Err(format!("line {i}: unexpected ph {other:?}")),
        }
    }
    for ((pid, tid), (open, _)) in tracks {
        if open != 0 {
            return Err(format!("track ({pid}, {tid}): {open} unclosed B events"));
        }
    }
    Ok(pairs)
}

/// Value of a `"key": "string"` field on one event line.
fn field_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\": \"");
    let start = line.find(&pat)? + pat.len();
    let end = line[start..].find('"')?;
    Some(&line[start..start + end])
}

/// Value of a `"key": number` field on one event line.
fn field_num(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\": ");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Phase;

    fn ev(phase: Phase, start_ns: u64, end_ns: u64) -> SpanEvent {
        SpanEvent { phase, start_ns, end_ns }
    }

    fn sample_tracks() -> Vec<(String, Vec<SpanEvent>)> {
        vec![
            (
                "worker-0".into(),
                vec![
                    ev(Phase::Step, 0, 10_000),
                    ev(Phase::Compress, 1_000, 4_000),
                    ev(Phase::Collective, 4_500, 9_000),
                ],
            ),
            ("ring-0".into(), vec![ev(Phase::RingSend, 2_000, 2_500)]),
        ]
    }

    #[test]
    fn export_is_balanced_and_monotone() {
        let doc = chrome_trace_json(0, "rank 0", &sample_tracks());
        let pairs = validate_chrome_trace(&doc).expect("valid trace");
        assert_eq!(pairs, 4);
        assert!(doc.contains("\"name\": \"step\""));
        assert!(doc.contains("\"cat\": \"kernel\"") || doc.contains("\"cat\": \"compress\""));
        assert!(doc.contains("\"thread_name\""));
        // Fractional-microsecond timestamps survive.
        assert!(doc.contains("\"ts\": 4.500"), "{doc}");
    }

    #[test]
    fn nested_spans_emit_inner_end_first() {
        let tracks = vec![(
            "t".to_string(),
            vec![ev(Phase::Step, 0, 5_000), ev(Phase::Compress, 1_000, 2_000)],
        )];
        let doc = chrome_trace_json(0, "p", &tracks);
        validate_chrome_trace(&doc).expect("valid");
        let inner_end = doc.find("\"ts\": 2}").expect("inner E at 2µs");
        let outer_end = doc.find("\"ts\": 5}").expect("outer E at 5µs");
        assert!(inner_end < outer_end);
    }

    #[test]
    fn crossing_interval_is_clamped_not_unbalanced() {
        // A child whose end crosses its parent's end (clock hiccup):
        // the export must still balance.
        let tracks = vec![(
            "t".to_string(),
            vec![ev(Phase::Step, 0, 3_000), ev(Phase::Compress, 1_000, 9_000)],
        )];
        let doc = chrome_trace_json(0, "p", &tracks);
        validate_chrome_trace(&doc).expect("clamped trace stays valid");
    }

    #[test]
    fn empty_tracks_export_and_validate() {
        let doc = chrome_trace_json(3, "rank 3", &[]);
        assert_eq!(validate_chrome_trace(&doc).unwrap(), 0);
    }

    #[test]
    fn rank_paths_insert_suffix_before_extension() {
        assert_eq!(
            rank_trace_path(Path::new("TRACE.json"), 0),
            PathBuf::from("TRACE_r0.json")
        );
        assert_eq!(
            rank_trace_path(Path::new("/tmp/out/trace.json"), 12),
            PathBuf::from("/tmp/out/trace_r12.json")
        );
    }

    #[test]
    fn merge_concatenates_and_stays_valid() {
        let a = chrome_trace_json(0, "rank 0", &sample_tracks());
        let b = chrome_trace_json(1, "rank 1", &sample_tracks());
        let merged = merge_chrome_traces(&[a.clone(), b]).expect("merge");
        let pairs = validate_chrome_trace(&merged).expect("merged trace valid");
        assert_eq!(pairs, 8);
        assert!(merged.contains("\"pid\": 0"));
        assert!(merged.contains("\"pid\": 1"));
        // Partial merge (a dead peer's file missing) still validates.
        let partial = merge_chrome_traces(&[a]).expect("partial merge");
        assert_eq!(validate_chrome_trace(&partial).unwrap(), 4);
    }

    #[test]
    fn merge_rejects_foreign_layout() {
        assert!(merge_chrome_traces(&["not a trace".to_string()]).is_none());
    }
}
