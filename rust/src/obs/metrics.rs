//! Crate-wide numeric metrics: counters, gauges, and fixed-bucket
//! histograms — the run-health layer next to the span recorder.
//!
//! Where spans answer "*where did the time go*" (DESIGN.md §13), the
//! metrics registry answers "*is the run healthy*": compression quality
//! (EF residual norm, low-rank approximation error, achieved
//! compression ratio), pipelining state (delayed-aggregate staleness,
//! in-flight ticket depth on the completion queues), and per-rank step
//! timing. On the multi-process path every worker folds one
//! [`StepMetrics`] frame per step onto the rendezvous control
//! connection; the coordinator aggregates cluster health with
//! [`aggregate`] (median/p95 step time, straggler flags, dead-peer
//! tolerant) and writes `METRICS_r<k>.jsonl` per-rank streams plus a
//! merged `METRICS.json` (the `--metrics <path>` CLI flag).
//!
//! # Discipline (mirrors the span recorder)
//!
//! 1. **One relaxed atomic load when off.** Every recording call checks
//!    [`crate::obs::mode`] for [`MODE_METRICS`] first and returns
//!    before touching anything else.
//! 2. **No value perturbation.** Recording only reads values the
//!    workload already computed (plus clocks for duration histograms);
//!    metrics-on runs are bitwise identical to metrics-off runs —
//!    pinned by `tests/integration_metrics.rs`.
//! 3. **Zero allocation in steady state.** The registry is a fixed
//!    static table of atomics: counters and gauges are single cells,
//!    histograms are pre-sized at registration ([`HISTO_BUCKETS`]
//!    buckets, compile-time). Recording never allocates; only
//!    [`snapshot`] and the JSON writers do.
//! 4. **Deterministic projection.** Counter values and *value*-histogram
//!    bucket counts are functions of the workload and reproduce run to
//!    run (atomic adds commute); gauges (last-write-wins) and
//!    *duration* histograms (wall clock) are volatile.
//!    [`MetricsSnapshot::deterministic_key`] keeps only the stable
//!    part, mirroring `Summary::deterministic_key`.

use super::{mode, MODE_METRICS};
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotone event/byte counters — the deterministic core of the
/// registry. Discriminants index the static cell table; order is part
/// of the snapshot format and new counters append.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Counter {
    /// Optimizer/trainer steps completed.
    StepsCompleted,
    /// Per-worker compression rounds executed.
    CompressRounds,
    /// Wire payload bytes charged at `post_send` on metered transports.
    WireSentBytes,
    /// Wire payload bytes charged at receive resolution on metered
    /// transports.
    WireRecvBytes,
    /// Receive tickets posted to a transport completion queue.
    RecvTicketsPosted,
    /// Per-step metrics frames encoded for the coordinator sideband.
    MetricsFrames,
    /// Connect retries burned by backoff policies (every attempt after
    /// the first, across rendezvous, ring-edge, and elastic
    /// re-formation dials).
    ReconnectAttempts,
}

/// Number of counters (size of the static cell table).
pub const COUNTER_COUNT: usize = 7;

/// All counters in discriminant order (the snapshot order).
pub const COUNTERS: [Counter; COUNTER_COUNT] = [
    Counter::StepsCompleted,
    Counter::CompressRounds,
    Counter::WireSentBytes,
    Counter::WireRecvBytes,
    Counter::RecvTicketsPosted,
    Counter::MetricsFrames,
    Counter::ReconnectAttempts,
];

impl Counter {
    /// Stable snake_case name (snapshot key, JSON field).
    pub fn name(self) -> &'static str {
        match self {
            Counter::StepsCompleted => "steps_completed",
            Counter::CompressRounds => "compress_rounds",
            Counter::WireSentBytes => "wire_sent_bytes",
            Counter::WireRecvBytes => "wire_recv_bytes",
            Counter::RecvTicketsPosted => "recv_tickets_posted",
            Counter::MetricsFrames => "metrics_frames",
            Counter::ReconnectAttempts => "reconnect_attempts",
        }
    }
}

/// Last-write-wins instantaneous values (f64). Volatile in the
/// deterministic projection: when several worker threads share the
/// process the final write order is scheduling-dependent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Gauge {
    /// Error-feedback residual norm `‖e‖_F` after the latest step
    /// (summed over layers and workers on the centralized path).
    EfResidual,
    /// Low-rank approximation error `‖M − P̂Q̄ᵀ‖_F / ‖M‖_F` of the
    /// latest reconstruction (`M` = the worker's own update on the
    /// per-worker path, the cross-worker mean on the oracle path).
    ApproxError,
    /// Achieved compression ratio: raw gradient bytes over logical
    /// bytes transmitted, for the latest step.
    CompressionRatio,
    /// Delayed-aggregate staleness of the latest applied update, in
    /// steps (0 synchronous, 1 under `--pipeline delayed`).
    StalenessSteps,
}

/// Number of gauges.
pub const GAUGE_COUNT: usize = 4;

/// All gauges in discriminant order.
pub const GAUGES: [Gauge; GAUGE_COUNT] =
    [Gauge::EfResidual, Gauge::ApproxError, Gauge::CompressionRatio, Gauge::StalenessSteps];

impl Gauge {
    /// Stable snake_case name (snapshot key, JSON field).
    pub fn name(self) -> &'static str {
        match self {
            Gauge::EfResidual => "ef_residual",
            Gauge::ApproxError => "approx_error",
            Gauge::CompressionRatio => "compression_ratio",
            Gauge::StalenessSteps => "staleness_steps",
        }
    }
}

/// High-water marks (u64, `fetch_max`). Volatile like gauges.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum MaxGauge {
    /// Deepest completion-queue in-flight ticket backlog observed.
    InflightDepthPeak,
}

/// Number of max-gauges.
pub const MAX_COUNT: usize = 1;

/// All max-gauges in discriminant order.
pub const MAXES: [MaxGauge; MAX_COUNT] = [MaxGauge::InflightDepthPeak];

impl MaxGauge {
    /// Stable snake_case name (snapshot key, JSON field).
    pub fn name(self) -> &'static str {
        match self {
            MaxGauge::InflightDepthPeak => "inflight_depth_peak",
        }
    }
}

/// Fixed-bucket histograms, pre-sized at registration
/// ([`HISTO_BUCKETS`] buckets each, so recording never allocates).
/// Value histograms bucket by fixed decade thresholds (pure
/// comparisons, no libm) and their bucket counts are deterministic;
/// duration histograms bucket observed wall-clock and are volatile.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Histogram {
    /// Low-rank approximation errors (value histogram, deterministic).
    ApproxError,
    /// EF residual norms (value histogram, deterministic).
    EfResidual,
    /// Completion-queue depth at recv-ticket post (value histogram,
    /// deterministic: posting order is program order per rank).
    InflightDepth,
    /// Step wall-clock seconds (duration histogram, volatile).
    StepSeconds,
}

/// Number of histograms.
pub const HISTOGRAM_COUNT: usize = 4;

/// All histograms in discriminant order.
pub const HISTOGRAMS: [Histogram; HISTOGRAM_COUNT] = [
    Histogram::ApproxError,
    Histogram::EfResidual,
    Histogram::InflightDepth,
    Histogram::StepSeconds,
];

impl Histogram {
    /// Stable snake_case name (snapshot key, JSON field).
    pub fn name(self) -> &'static str {
        match self {
            Histogram::ApproxError => "approx_error",
            Histogram::EfResidual => "ef_residual",
            Histogram::InflightDepth => "inflight_depth",
            Histogram::StepSeconds => "step_seconds",
        }
    }

    /// Whether bucket counts are wall-clock-dependent (excluded from
    /// the deterministic projection).
    pub fn is_volatile(self) -> bool {
        matches!(self, Histogram::StepSeconds)
    }
}

/// Buckets per histogram: one per decade threshold in
/// [`BUCKET_THRESHOLDS`], plus the overflow bucket.
pub const HISTO_BUCKETS: usize = 12;

/// Decade upper bounds: bucket `i` counts observations
/// `< BUCKET_THRESHOLDS[i]` (and `>=` every earlier threshold); the
/// last bucket is overflow (`>= 1e1`). Shared by values and durations
/// (seconds): 1 ns to 10 s covers every duration this crate times,
/// and 1e-9 to 1e1 covers ratios, norms, and queue depths.
pub const BUCKET_THRESHOLDS: [f64; HISTO_BUCKETS - 1] =
    [1e-9, 1e-8, 1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1e0, 1e1];

/// Deterministic threshold bucketing: pure comparisons, no libm.
fn bucket_of(v: f64) -> usize {
    for (i, &t) in BUCKET_THRESHOLDS.iter().enumerate() {
        if v < t {
            return i;
        }
    }
    HISTO_BUCKETS - 1
}

// ---------------------------------------------------------------------
// The static registry.
// ---------------------------------------------------------------------

#[allow(clippy::declare_interior_mutable_const)] // array-init template
const CELL_INIT: AtomicU64 = AtomicU64::new(0);

static COUNTER_CELLS: [AtomicU64; COUNTER_COUNT] = [CELL_INIT; COUNTER_COUNT];
/// Gauge cells hold `f64::to_bits` of the last written value.
static GAUGE_CELLS: [AtomicU64; GAUGE_COUNT] = [CELL_INIT; GAUGE_COUNT];
static MAX_CELLS: [AtomicU64; MAX_COUNT] = [CELL_INIT; MAX_COUNT];
#[allow(clippy::declare_interior_mutable_const)] // array-init template
const ROW_INIT: [AtomicU64; HISTO_BUCKETS] = [CELL_INIT; HISTO_BUCKETS];
static HISTO_CELLS: [[AtomicU64; HISTO_BUCKETS]; HISTOGRAM_COUNT] = [ROW_INIT; HISTOGRAM_COUNT];

/// One relaxed load: is metrics recording on?
#[inline]
pub fn on() -> bool {
    mode() & MODE_METRICS != 0
}

/// Add `n` to a counter (no-op when metrics mode is off).
#[inline]
pub fn add(c: Counter, n: u64) {
    if !on() || n == 0 {
        return;
    }
    COUNTER_CELLS[c as usize].fetch_add(n, Ordering::SeqCst);
}

/// Set a gauge to `v` (last write wins; no-op when off).
#[inline]
pub fn set_gauge(g: Gauge, v: f64) {
    if !on() {
        return;
    }
    GAUGE_CELLS[g as usize].store(v.to_bits(), Ordering::SeqCst);
}

/// Raise a high-water mark to at least `v` (no-op when off).
#[inline]
pub fn raise_max(m: MaxGauge, v: u64) {
    if !on() {
        return;
    }
    MAX_CELLS[m as usize].fetch_max(v, Ordering::SeqCst);
}

/// Record one observation into a histogram (no-op when off). The
/// buckets exist since registration, so this is a compare loop plus one
/// atomic add — no allocation, ever.
#[inline]
pub fn observe(h: Histogram, v: f64) {
    if !on() {
        return;
    }
    HISTO_CELLS[h as usize][bucket_of(v)].fetch_add(1, Ordering::SeqCst);
}

/// Record a duration observation, in seconds, into a (volatile)
/// histogram (no-op when off).
#[inline]
pub fn observe_seconds(h: Histogram, seconds: f64) {
    observe(h, seconds);
}

/// Current gauge value (0.0 until first write; reads even when the mode
/// is off — consumers snapshot after a run regardless).
pub fn gauge_value(g: Gauge) -> f64 {
    f64::from_bits(GAUGE_CELLS[g as usize].load(Ordering::SeqCst))
}

/// Current high-water mark.
pub fn max_value(m: MaxGauge) -> u64 {
    MAX_CELLS[m as usize].load(Ordering::SeqCst)
}

// ---------------------------------------------------------------------
// Snapshots.
// ---------------------------------------------------------------------

/// A point-in-time copy of the whole registry.
///
/// `counters` and non-volatile histogram rows are deterministic for a
/// fixed workload (use [`Self::delta_since`] to scope them to an
/// interval); `gauges`, `maxes`, and volatile histogram rows are not.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    /// Counter values, [`COUNTERS`] order.
    pub counters: [u64; COUNTER_COUNT],
    /// Gauge values, [`GAUGES`] order (volatile).
    pub gauges: [f64; GAUGE_COUNT],
    /// High-water marks, [`MAXES`] order (volatile).
    pub maxes: [u64; MAX_COUNT],
    /// Histogram bucket counts, [`HISTOGRAMS`] × bucket order.
    pub histograms: [[u64; HISTO_BUCKETS]; HISTOGRAM_COUNT],
}

/// Serialize scoped measurements that toggle the process-global
/// registry bit (the report's run-health check, unit tests that assert
/// on-vs-off gating). Holding this lock guarantees no other holder
/// flips the bit off mid-measurement and under-counts a delta;
/// concurrent *recorders* that never toggle the bit can still add, so
/// scoped deltas are an over-approximation under a parallel harness.
pub fn registry_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Snapshot the registry (works whether or not the mode is on).
pub fn snapshot() -> MetricsSnapshot {
    let mut s = MetricsSnapshot {
        counters: [0; COUNTER_COUNT],
        gauges: [0.0; GAUGE_COUNT],
        maxes: [0; MAX_COUNT],
        histograms: [[0; HISTO_BUCKETS]; HISTOGRAM_COUNT],
    };
    for (i, c) in COUNTER_CELLS.iter().enumerate() {
        s.counters[i] = c.load(Ordering::SeqCst);
    }
    for (i, c) in GAUGE_CELLS.iter().enumerate() {
        s.gauges[i] = f64::from_bits(c.load(Ordering::SeqCst));
    }
    for (i, c) in MAX_CELLS.iter().enumerate() {
        s.maxes[i] = c.load(Ordering::SeqCst);
    }
    for (i, row) in HISTO_CELLS.iter().enumerate() {
        for (j, c) in row.iter().enumerate() {
            s.histograms[i][j] = c.load(Ordering::SeqCst);
        }
    }
    s
}

impl MetricsSnapshot {
    /// Value of one counter.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// Value of one gauge.
    pub fn gauge(&self, g: Gauge) -> f64 {
        self.gauges[g as usize]
    }

    /// One histogram's bucket row.
    pub fn histogram(&self, h: Histogram) -> &[u64; HISTO_BUCKETS] {
        &self.histograms[h as usize]
    }

    /// Monotone parts (`counters`, `histograms`) as the difference
    /// `self − earlier` (saturating); instantaneous parts (`gauges`,
    /// `maxes`) keep `self`'s values. The registry is process-global,
    /// so interval deltas are how tests and the report scope a
    /// measurement to one workload.
    pub fn delta_since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        let mut out = *self;
        for i in 0..COUNTER_COUNT {
            out.counters[i] = self.counters[i].saturating_sub(earlier.counters[i]);
        }
        for i in 0..HISTOGRAM_COUNT {
            for j in 0..HISTO_BUCKETS {
                out.histograms[i][j] =
                    self.histograms[i][j].saturating_sub(earlier.histograms[i][j]);
            }
        }
        out
    }

    /// The deterministic projection, mirroring
    /// `Summary::deterministic_key`: named counter values plus the
    /// bucket rows of every *non-volatile* histogram. Gauges, maxes,
    /// and duration histograms — everything wall-clock- or
    /// write-order-dependent — are dropped. Two metrics-enabled runs of
    /// the same single-process workload must agree on this exactly.
    pub fn deterministic_key(&self) -> (Vec<(String, u64)>, Vec<(String, Vec<u64>)>) {
        let counters = COUNTERS
            .iter()
            .map(|&c| (c.name().to_string(), self.counters[c as usize]))
            .collect();
        let histos = HISTOGRAMS
            .iter()
            .filter(|h| !h.is_volatile())
            .map(|&h| (h.name().to_string(), self.histograms[h as usize].to_vec()))
            .collect();
        (counters, histos)
    }

    /// Render the snapshot as a JSON object (single-process `--metrics`
    /// output for `train`/`simulate`). Keys are emitted in registry
    /// order, so the document layout is stable.
    pub fn to_json(&self) -> String {
        use crate::util::bench::{json_escape, json_num};
        let mut out = String::from("{\n  \"schema_version\": 1,\n  \"counters\": {");
        for (i, c) in COUNTERS.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            out.push_str(&format!(
                "{sep}\n    \"{}\": {}",
                json_escape(c.name()),
                self.counters[*c as usize]
            ));
        }
        out.push_str("\n  },\n  \"gauges\": {");
        for (i, g) in GAUGES.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            out.push_str(&format!(
                "{sep}\n    \"{}\": {}",
                json_escape(g.name()),
                json_num(self.gauges[*g as usize])
            ));
        }
        out.push_str("\n  },\n  \"maxes\": {");
        for (i, m) in MAXES.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            out.push_str(&format!(
                "{sep}\n    \"{}\": {}",
                json_escape(m.name()),
                self.maxes[*m as usize]
            ));
        }
        out.push_str("\n  },\n  \"histograms\": {");
        for (i, h) in HISTOGRAMS.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let row: Vec<String> =
                self.histograms[*h as usize].iter().map(|n| n.to_string()).collect();
            out.push_str(&format!(
                "{sep}\n    \"{}\": {{\"volatile\": {}, \"buckets\": [{}]}}",
                json_escape(h.name()),
                h.is_volatile(),
                row.join(", ")
            ));
        }
        out.push_str("\n  }\n}\n");
        out
    }
}

// ---------------------------------------------------------------------
// Per-step frames (the coordinator sideband).
// ---------------------------------------------------------------------

/// One worker's per-step health record — the payload of a
/// `Frame::Metrics` on the rendezvous control connection, and one line
/// of a `METRICS_r<k>.jsonl` stream.
///
/// Fields marked *volatile* vary run to run; the rest are deterministic
/// for a fixed workload. `ef_residual`/`approx_error` are authoritative
/// in the one-process-per-rank setting (the registry is process-global;
/// in-process multi-worker tests see interleaved writes there, but the
/// locally measured fields are always per-rank exact).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepMetrics {
    /// Worker rank.
    pub rank: u64,
    /// 0-based step index.
    pub step: u64,
    /// Measured step wall-clock seconds (*volatile*).
    pub step_seconds: f64,
    /// Wire payload bytes sent this step (metered transport delta).
    pub wire_sent: u64,
    /// Wire payload bytes received this step (metered transport delta).
    pub wire_received: u64,
    /// EF residual norm after this step (gauge read).
    pub ef_residual: f64,
    /// Low-rank approximation error of this step's reconstruction
    /// (gauge read).
    pub approx_error: f64,
    /// Raw gradient bytes over logical bytes transmitted this step.
    pub compression_ratio: f64,
    /// Staleness of the applied aggregate, in steps.
    pub staleness: u64,
    /// Peak completion-queue in-flight ticket depth so far (*volatile*
    /// ordering, deterministic value per rank on a fixed schedule).
    pub inflight_peak: u64,
}

impl StepMetrics {
    /// One JSON object on one line (the JSONL record format).
    pub fn jsonl_line(&self) -> String {
        use crate::util::bench::json_num;
        format!(
            "{{\"rank\": {}, \"step\": {}, \"step_seconds\": {}, \"wire_sent\": {}, \
             \"wire_received\": {}, \"ef_residual\": {}, \"approx_error\": {}, \
             \"compression_ratio\": {}, \"staleness\": {}, \"inflight_peak\": {}}}",
            self.rank,
            self.step,
            json_num(self.step_seconds),
            self.wire_sent,
            self.wire_received,
            json_num(self.ef_residual),
            json_num(self.approx_error),
            json_num(self.compression_ratio),
            self.staleness,
            self.inflight_peak,
        )
    }
}

/// Rank-suffixed per-rank metrics path: `METRICS.json` →
/// `METRICS_r<k>.jsonl` (the stream is line-oriented regardless of the
/// base extension), mirroring `chrome::rank_trace_path`.
pub fn rank_metrics_path(base: &std::path::Path, rank: usize) -> std::path::PathBuf {
    let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("METRICS");
    base.with_file_name(format!("{stem}_r{rank}.jsonl"))
}

// ---------------------------------------------------------------------
// Cluster-health aggregation (coordinator side).
// ---------------------------------------------------------------------

/// Default straggler multiple: a rank is flagged when its step time
/// exceeds `STRAGGLER_FACTOR ×` the cluster median for that step.
pub const STRAGGLER_FACTOR: f64 = 2.0;

/// Default absolute slack added on top of the multiple: ranks within
/// this many seconds of the median are never flagged, so uniform runs
/// with microsecond medians don't flag scheduler noise.
pub const STRAGGLER_MIN_EXCESS_S: f64 = 0.02;

/// Cluster health for one step, over the ranks that reported it.
#[derive(Debug, Clone, PartialEq)]
pub struct StepHealth {
    /// 0-based step index.
    pub step: u64,
    /// Ranks that reported this step (sorted).
    pub ranks: Vec<u64>,
    /// Median step seconds (lower median, deterministic pick).
    pub median_step_s: f64,
    /// p95 step seconds (nearest-rank on the sorted sample).
    pub p95_step_s: f64,
    /// Largest per-rank deviation from the median, seconds.
    pub max_deviation_s: f64,
    /// Ranks whose step time exceeded `factor × median` by at least the
    /// absolute slack.
    pub stragglers: Vec<u64>,
}

/// One membership epoch in an elastic run (DESIGN.md §16): the world
/// size it ran at, the step it began, and the previous epoch's ranks
/// that departed into it. A fixed-membership run has exactly one epoch
/// with no departures.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochInfo {
    /// Monotone epoch number (0 = initial formation).
    pub epoch: u64,
    /// World size during this epoch.
    pub world: usize,
    /// First step executed under this epoch.
    pub start_step: u64,
    /// Previous-epoch ranks that departed at this transition (their EF
    /// residual contributions were dropped, per the §16 policy).
    pub missing_ranks: Vec<u64>,
    /// Number of workers that joined at this transition.
    pub joined: usize,
}

/// Whole-run cluster health: per-step aggregation over every rank's
/// frame stream, dead-peer tolerant (a rank with no frames is listed in
/// `missing_ranks` and excluded from the per-step statistics, like
/// `merge_chrome_traces` skipping an unreadable part).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterHealth {
    /// World size the aggregation was asked to cover.
    pub world: usize,
    /// Ranks that contributed no frames at all (dead peers).
    pub missing_ranks: Vec<u64>,
    /// Per-step health, in step order.
    pub steps: Vec<StepHealth>,
    /// Sum of every reporting rank's `wire_sent` deltas.
    pub wire_sent_total: u64,
    /// Sum of every reporting rank's `wire_received` deltas.
    pub wire_received_total: u64,
    /// The straggler multiple used.
    pub straggler_factor: f64,
    /// The absolute slack used, seconds.
    pub straggler_min_excess_s: f64,
    /// Membership epochs, in epoch order. [`aggregate`] leaves this
    /// empty (it cannot know the schedule); the elastic coordinator
    /// fills it in before rendering `METRICS.json`.
    pub epochs: Vec<EpochInfo>,
    /// Total connect retries across every reporting rank (each
    /// worker's own backoff tallies, carried in its `Report`).
    pub reconnect_attempts_total: u64,
}

impl ClusterHealth {
    /// Ranks flagged as stragglers at any step (sorted, deduplicated).
    pub fn straggler_ranks(&self) -> Vec<u64> {
        let mut out: Vec<u64> =
            self.steps.iter().flat_map(|s| s.stragglers.iter().copied()).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Render the merged `METRICS.json` summary document.
    /// `reconciles_metered` reports whether the summed per-step wire
    /// deltas matched the `MeteredTransport` totals exactly (`null`
    /// when the caller had no metered totals to check against).
    pub fn to_json(&self, reconciles_metered: Option<bool>) -> String {
        use crate::util::bench::json_num;
        let mut out = String::from("{\n  \"schema_version\": 1,\n");
        out.push_str(&format!("  \"world\": {},\n", self.world));
        let missing: Vec<String> = self.missing_ranks.iter().map(|r| r.to_string()).collect();
        out.push_str(&format!("  \"missing_ranks\": [{}],\n", missing.join(", ")));
        out.push_str(&format!("  \"wire_sent_total\": {},\n", self.wire_sent_total));
        out.push_str(&format!("  \"wire_received_total\": {},\n", self.wire_received_total));
        out.push_str(&format!(
            "  \"reconciles_metered\": {},\n",
            match reconciles_metered {
                Some(b) => b.to_string(),
                None => "null".to_string(),
            }
        ));
        out.push_str(&format!("  \"straggler_factor\": {},\n", json_num(self.straggler_factor)));
        out.push_str(&format!(
            "  \"straggler_min_excess_s\": {},\n",
            json_num(self.straggler_min_excess_s)
        ));
        let stragglers: Vec<String> =
            self.straggler_ranks().iter().map(|r| r.to_string()).collect();
        out.push_str(&format!("  \"straggler_ranks\": [{}],\n", stragglers.join(", ")));
        out.push_str(&format!(
            "  \"reconnect_attempts_total\": {},\n",
            self.reconnect_attempts_total
        ));
        out.push_str("  \"epochs\": [");
        for (i, e) in self.epochs.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let missing: Vec<String> = e.missing_ranks.iter().map(|r| r.to_string()).collect();
            out.push_str(&format!(
                "{sep}\n    {{\"epoch\": {}, \"world\": {}, \"start_step\": {}, \
                 \"missing_ranks\": [{}], \"joined\": {}}}",
                e.epoch,
                e.world,
                e.start_step,
                missing.join(", "),
                e.joined
            ));
        }
        if self.epochs.is_empty() {
            out.push_str("],\n");
        } else {
            out.push_str("\n  ],\n");
        }
        out.push_str("  \"steps\": [");
        for (i, s) in self.steps.iter().enumerate() {
            let sep = if i == 0 { "" } else { "," };
            let ranks: Vec<String> = s.ranks.iter().map(|r| r.to_string()).collect();
            let flagged: Vec<String> = s.stragglers.iter().map(|r| r.to_string()).collect();
            out.push_str(&format!(
                "{sep}\n    {{\"step\": {}, \"ranks\": [{}], \"median_step_s\": {}, \
                 \"p95_step_s\": {}, \"max_deviation_s\": {}, \"stragglers\": [{}]}}",
                s.step,
                ranks.join(", "),
                json_num(s.median_step_s),
                json_num(s.p95_step_s),
                json_num(s.max_deviation_s),
                flagged.join(", ")
            ));
        }
        out.push_str("\n  ]\n}\n");
        out
    }
}

/// Aggregate per-rank frame streams into cluster health.
///
/// `frames_by_rank[k]` holds rank `k`'s frames in step order; an empty
/// stream marks rank `k` as a dead peer (tolerated — statistics run
/// over the survivors). A rank is flagged a straggler at a step when
/// its step time exceeds `factor × median` *and* `median + min_excess_s`
/// — the lower median (`sorted[(n−1)/2]`) keeps the threshold
/// meaningful at `W = 2`, and the absolute slack keeps uniform runs
/// with tiny medians from flagging scheduler noise.
pub fn aggregate(
    frames_by_rank: &[Vec<StepMetrics>],
    factor: f64,
    min_excess_s: f64,
) -> ClusterHealth {
    let world = frames_by_rank.len();
    let missing_ranks: Vec<u64> = frames_by_rank
        .iter()
        .enumerate()
        .filter(|(_, f)| f.is_empty())
        .map(|(k, _)| k as u64)
        .collect();
    let max_step = frames_by_rank
        .iter()
        .flat_map(|f| f.iter().map(|m| m.step))
        .max();
    let mut steps = Vec::new();
    if let Some(max_step) = max_step {
        for step in 0..=max_step {
            // (rank, step_seconds) for every rank that reported `step`.
            let mut sample: Vec<(u64, f64)> = frames_by_rank
                .iter()
                .flatten()
                .filter(|m| m.step == step)
                .map(|m| (m.rank, m.step_seconds))
                .collect();
            if sample.is_empty() {
                continue;
            }
            sample.sort_by_key(|&(r, _)| r);
            let ranks: Vec<u64> = sample.iter().map(|&(r, _)| r).collect();
            let mut times: Vec<f64> = sample.iter().map(|&(_, t)| t).collect();
            times.sort_by(f64::total_cmp);
            let n = times.len();
            let median = times[(n - 1) / 2];
            let p95 = times[((n * 95).div_ceil(100)).saturating_sub(1).min(n - 1)];
            let max_deviation =
                times.iter().map(|t| (t - median).abs()).fold(0.0f64, f64::max);
            let threshold = (median * factor).max(median + min_excess_s);
            let stragglers: Vec<u64> = sample
                .iter()
                .filter(|&&(_, t)| t > threshold)
                .map(|&(r, _)| r)
                .collect();
            steps.push(StepHealth {
                step,
                ranks,
                median_step_s: median,
                p95_step_s: p95,
                max_deviation_s: max_deviation,
                stragglers,
            });
        }
    }
    let wire_sent_total = frames_by_rank.iter().flatten().map(|m| m.wire_sent).sum();
    let wire_received_total = frames_by_rank.iter().flatten().map(|m| m.wire_received).sum();
    ClusterHealth {
        world,
        missing_ranks,
        steps,
        wire_sent_total,
        wire_received_total,
        straggler_factor: factor,
        straggler_min_excess_s: min_excess_s,
        epochs: Vec::new(),
        reconnect_attempts_total: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(rank: u64, step: u64, step_seconds: f64) -> StepMetrics {
        StepMetrics {
            rank,
            step,
            step_seconds,
            wire_sent: 100,
            wire_received: 100,
            ef_residual: 0.5,
            approx_error: 0.1,
            compression_ratio: 8.0,
            staleness: 0,
            inflight_peak: 2,
        }
    }

    #[test]
    fn bucketing_is_total_and_monotone() {
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(5e-10), 0);
        assert_eq!(bucket_of(5e-9), 1);
        assert_eq!(bucket_of(0.5), 9);
        assert_eq!(bucket_of(5.0), 10);
        assert_eq!(bucket_of(50.0), 11);
        assert_eq!(bucket_of(1e9), 11);
        let mut prev = 0;
        for v in [0.0, 1e-9, 1e-6, 1e-3, 1.0, 10.0, 100.0, 1e6] {
            let b = bucket_of(v);
            assert!(b >= prev, "bucket_of({v}) = {b} < {prev}");
            prev = b;
        }
    }

    /// Recording while the mode is off must leave every cell untouched;
    /// recording while on must land. The registry is process-global, so
    /// the on-assertions are `>=` deltas (a concurrent test could add).
    #[test]
    fn recording_respects_the_mode_bit() {
        let _guard = registry_lock();
        let before = snapshot();
        // Off by default in the test process (no test leaves it on).
        if !on() {
            add(Counter::MetricsFrames, 3);
            observe(Histogram::ApproxError, 0.25);
            let mid = snapshot();
            assert_eq!(
                mid.counter(Counter::MetricsFrames),
                before.counter(Counter::MetricsFrames)
            );
        }
        crate::obs::enable_metrics(true);
        add(Counter::MetricsFrames, 3);
        observe(Histogram::ApproxError, 0.25);
        crate::obs::enable_metrics(false);
        let after = snapshot().delta_since(&before);
        assert!(after.counter(Counter::MetricsFrames) >= 3);
        assert!(after.histogram(Histogram::ApproxError)[9] >= 1);
    }

    #[test]
    fn deterministic_key_drops_volatile_parts() {
        let mut a = MetricsSnapshot {
            counters: [1; COUNTER_COUNT],
            gauges: [0.5; GAUGE_COUNT],
            maxes: [7; MAX_COUNT],
            histograms: [[2; HISTO_BUCKETS]; HISTOGRAM_COUNT],
        };
        let mut b = a;
        // Perturb only volatile parts: the keys must still agree.
        b.gauges = [9.0; GAUGE_COUNT];
        b.maxes = [99; MAX_COUNT];
        b.histograms[Histogram::StepSeconds as usize] = [11; HISTO_BUCKETS];
        assert_eq!(a.deterministic_key(), b.deterministic_key());
        // Perturb a deterministic part: the keys must diverge.
        a.counters[0] += 1;
        assert_ne!(a.deterministic_key(), b.deterministic_key());
        let (counters, histos) = a.deterministic_key();
        assert_eq!(counters.len(), COUNTER_COUNT);
        assert_eq!(histos.len(), HISTOGRAM_COUNT - 1, "volatile histogram excluded");
    }

    #[test]
    fn delta_since_subtracts_monotone_parts_only() {
        let mut before = MetricsSnapshot {
            counters: [10; COUNTER_COUNT],
            gauges: [1.0; GAUGE_COUNT],
            maxes: [5; MAX_COUNT],
            histograms: [[4; HISTO_BUCKETS]; HISTOGRAM_COUNT],
        };
        let mut after = before;
        after.counters = [17; COUNTER_COUNT];
        after.gauges = [3.0; GAUGE_COUNT];
        after.histograms = [[9; HISTO_BUCKETS]; HISTOGRAM_COUNT];
        before.maxes = [5; MAX_COUNT];
        after.maxes = [8; MAX_COUNT];
        let d = after.delta_since(&before);
        assert_eq!(d.counters, [7; COUNTER_COUNT]);
        assert_eq!(d.histograms, [[5; HISTO_BUCKETS]; HISTOGRAM_COUNT]);
        assert_eq!(d.gauges, [3.0; GAUGE_COUNT], "gauges keep the later value");
        assert_eq!(d.maxes, [8; MAX_COUNT], "maxes keep the later value");
    }

    #[test]
    fn registry_metadata_is_total() {
        assert_eq!(COUNTERS.len(), COUNTER_COUNT);
        assert_eq!(GAUGES.len(), GAUGE_COUNT);
        assert_eq!(MAXES.len(), MAX_COUNT);
        assert_eq!(HISTOGRAMS.len(), HISTOGRAM_COUNT);
        for (i, c) in COUNTERS.iter().enumerate() {
            assert_eq!(*c as usize, i, "{}", c.name());
            assert!(!c.name().is_empty());
        }
        for (i, g) in GAUGES.iter().enumerate() {
            assert_eq!(*g as usize, i, "{}", g.name());
        }
        for (i, h) in HISTOGRAMS.iter().enumerate() {
            assert_eq!(*h as usize, i, "{}", h.name());
        }
    }

    #[test]
    fn jsonl_line_is_one_parseable_object() {
        let line = frame(3, 7, 0.0125).jsonl_line();
        assert!(!line.contains('\n'));
        assert!(line.starts_with('{') && line.ends_with('}'));
        for key in [
            "\"rank\": 3",
            "\"step\": 7",
            "\"step_seconds\"",
            "\"wire_sent\": 100",
            "\"ef_residual\"",
            "\"approx_error\"",
            "\"compression_ratio\"",
            "\"staleness\": 0",
            "\"inflight_peak\": 2",
        ] {
            assert!(line.contains(key), "{line} missing {key}");
        }
    }

    #[test]
    fn rank_paths_are_suffixed() {
        use std::path::Path;
        assert_eq!(
            rank_metrics_path(Path::new("METRICS.json"), 2),
            Path::new("METRICS_r2.jsonl")
        );
        assert_eq!(
            rank_metrics_path(Path::new("out/run.metrics"), 0),
            Path::new("out/run_r0.jsonl")
        );
    }

    #[test]
    fn aggregate_flags_the_straggler_and_only_it() {
        // 4 ranks × 3 steps; rank 2 is 10× slower than the 10 ms pack.
        let frames: Vec<Vec<StepMetrics>> = (0..4)
            .map(|rank| {
                (0..3)
                    .map(|step| {
                        frame(rank, step, if rank == 2 { 0.1 } else { 0.01 })
                    })
                    .collect()
            })
            .collect();
        let health = aggregate(&frames, STRAGGLER_FACTOR, STRAGGLER_MIN_EXCESS_S);
        assert_eq!(health.world, 4);
        assert!(health.missing_ranks.is_empty());
        assert_eq!(health.steps.len(), 3);
        for s in &health.steps {
            assert_eq!(s.stragglers, vec![2], "step {}", s.step);
            assert!((s.median_step_s - 0.01).abs() < 1e-12);
            assert!((s.p95_step_s - 0.1).abs() < 1e-12);
            assert!((s.max_deviation_s - 0.09).abs() < 1e-12);
        }
        assert_eq!(health.straggler_ranks(), vec![2]);
        assert_eq!(health.wire_sent_total, 4 * 3 * 100);
    }

    #[test]
    fn uniform_run_flags_nobody() {
        let frames: Vec<Vec<StepMetrics>> = (0..4)
            .map(|rank| (0..3).map(|step| frame(rank, step, 0.001 + rank as f64 * 1e-5)).collect())
            .collect();
        let health = aggregate(&frames, STRAGGLER_FACTOR, STRAGGLER_MIN_EXCESS_S);
        assert!(health.straggler_ranks().is_empty(), "{:?}", health.straggler_ranks());
    }

    /// W = 2 with the lower median: the slow rank's own time never sets
    /// the threshold, so a genuine 2-rank straggler is still caught.
    #[test]
    fn two_rank_straggler_is_flagged() {
        let frames = vec![
            (0..3).map(|s| frame(0, s, 0.01)).collect::<Vec<_>>(),
            (0..3).map(|s| frame(1, s, 0.2)).collect::<Vec<_>>(),
        ];
        let health = aggregate(&frames, STRAGGLER_FACTOR, STRAGGLER_MIN_EXCESS_S);
        assert_eq!(health.straggler_ranks(), vec![1]);
    }

    /// Dead peer: an empty frame stream is reported, tolerated, and
    /// excluded from the statistics — the merge still succeeds.
    #[test]
    fn dead_peer_is_tolerated() {
        let frames = vec![
            (0..2).map(|s| frame(0, s, 0.01)).collect::<Vec<_>>(),
            Vec::new(),
            (0..2).map(|s| frame(2, s, 0.012)).collect::<Vec<_>>(),
        ];
        let health = aggregate(&frames, STRAGGLER_FACTOR, STRAGGLER_MIN_EXCESS_S);
        assert_eq!(health.missing_ranks, vec![1]);
        assert_eq!(health.steps.len(), 2);
        for s in &health.steps {
            assert_eq!(s.ranks, vec![0, 2]);
        }
        let doc = health.to_json(Some(true));
        assert!(doc.contains("\"missing_ranks\": [1]"));
        assert!(doc.contains("\"reconciles_metered\": true"));
    }

    #[test]
    fn merged_json_layout_is_stable() {
        let frames = vec![vec![frame(0, 0, 0.01)], vec![frame(1, 0, 0.011)]];
        let health = aggregate(&frames, 1.5, 0.001);
        let doc = health.to_json(None);
        for key in [
            "\"schema_version\": 1",
            "\"world\": 2",
            "\"missing_ranks\": []",
            "\"wire_sent_total\": 200",
            "\"wire_received_total\": 200",
            "\"reconciles_metered\": null",
            "\"straggler_factor\": 1.5",
            "\"straggler_ranks\": []",
            "\"steps\": [",
            "\"median_step_s\":",
            "\"p95_step_s\":",
        ] {
            assert!(doc.contains(key), "merged doc missing {key}:\n{doc}");
        }
    }
}
