//! Structured tracing and phase attribution (DESIGN.md §13).
//!
//! The paper's headline claim is wall-clock speedup, and whether
//! compression wins is decided by the *timeline*: how much communication
//! is exposed versus hidden behind compute (arXiv:2103.00543), and how
//! much of "compression" is really encode/pack overhead
//! (arXiv:2306.08881). This module is the crate-wide instrumentation
//! layer that makes that timeline observable: phase-tagged spans through
//! the coordinator step, the GEMM/Gram–Schmidt kernels, the per-worker
//! compressors, and both ring transports.
//!
//! # Design constraints
//!
//! 1. **No value perturbation.** A span only reads clocks and bumps
//!    atomics; it never touches the data a kernel computes. The bitwise
//!    determinism contract of DESIGN.md §11 therefore holds with tracing
//!    on or off — pinned by `tests/integration_obs.rs`.
//! 2. **Near-zero cost when disabled.** [`span`] loads one relaxed
//!    atomic and, when every mode bit is clear, returns an inert guard
//!    without ever reading a clock. The hot path pays one predictable
//!    branch.
//! 3. **Deterministic counts, volatile durations.** Span *counts* and
//!    byte counters are functions of the workload and are reproducible
//!    run to run; wall-clock durations are not. Every consumer
//!    (summaries, REPORT.md) keeps the two separated so deterministic
//!    artifacts stay byte-for-byte stable.
//!
//! # Two recording modes
//!
//! - **Timing** ([`enable_timing`]): closed spans fold their duration
//!   into global per-phase accumulators ([`phase_totals`]). This is how
//!   [`crate::coordinator::Trainer`] splits the old `compress_s` wall
//!   interval into compress / collective / decompress attribution.
//! - **Tracing** ([`enable_trace`]): closed spans are additionally
//!   appended to a per-thread track buffer, exported as a
//!   Chrome-trace-event/Perfetto JSON by [`chrome::chrome_trace_json`]
//!   (the `--trace <path>` CLI flag).
//!
//! Both modes are process-wide switches: a trainer or CLI run flips
//! them once at startup. (Engine selection, by contrast, is explicit
//! per-run configuration — see
//! [`crate::transport::EngineKind`] and `CommLog::on`.) [`capture`] serializes scoped
//! recordings (tests, the experiment report) behind a global lock so
//! concurrent captures cannot interleave.

pub mod chrome;
pub mod metrics;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Recording-mode bit: fold closed spans into the per-phase totals.
pub const MODE_TIMING: u8 = 1;
/// Recording-mode bit: append closed spans to per-thread track buffers.
pub const MODE_TRACE: u8 = 2;
/// Recording-mode bit: record numeric metrics (counters / gauges /
/// histograms in [`metrics`]). Orthogonal to the span modes: metrics-only
/// runs never read a clock in [`span`].
pub const MODE_METRICS: u8 = 4;

static MODE: AtomicU8 = AtomicU8::new(0);

/// Every instrumented phase, the span taxonomy of DESIGN.md §13.
///
/// The discriminant indexes the global accumulator table; the order is
/// part of the deterministic-summary format and new phases append.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Phase {
    /// One full `Trainer::train_step`.
    Step,
    /// Forward+backward gradient computation (per step, all workers).
    Grad,
    /// Compressor encode work (GEMMs, orthogonalization, packing).
    Compress,
    /// A ring collective (all-reduce / all-gather), entry to exit.
    Collective,
    /// Compressor decode work (reconstruction from factors/messages).
    Decompress,
    /// One posted transport send (`post_send`; `send_next` is its
    /// blocking wrapper) — in-process channel or TCP frame handoff.
    RingSend,
    /// One blocking wait on a posted receive (`wait`; `recv_prev` is
    /// its wrapper) — blocked time is exposed recv wait.
    RingRecv,
    /// Wire-codec frame encode (TCP backend only).
    WireEncode,
    /// Wire-codec frame decode (TCP backend only).
    WireDecode,
    /// Multi-process rendezvous handshake (bind/hello/welcome/connect).
    Rendezvous,
    /// `matmul_into` (`P = M·Q`) kernel.
    MatmulNn,
    /// `matmul_tn_into` (`Q = Mᵀ·P̂`) kernel.
    MatmulTn,
    /// `matmul_nt_into` (reconstruction `P̂·Qᵀ`) kernel.
    MatmulNt,
    /// `gram_schmidt_in_place` orthogonalization.
    GramSchmidt,
    /// One sharded job slice on a kernel-pool worker thread.
    PoolChunk,
    /// A posted collective's in-flight window: first post to final
    /// drain (pipelined modes) — comm hidden behind compute shows up
    /// here instead of in `RingRecv`.
    InFlight,
}

/// Number of phases (size of the accumulator table).
pub const PHASE_COUNT: usize = 16;

/// All phases in discriminant order (the deterministic-summary order).
pub const PHASES: [Phase; PHASE_COUNT] = [
    Phase::Step,
    Phase::Grad,
    Phase::Compress,
    Phase::Collective,
    Phase::Decompress,
    Phase::RingSend,
    Phase::RingRecv,
    Phase::WireEncode,
    Phase::WireDecode,
    Phase::Rendezvous,
    Phase::MatmulNn,
    Phase::MatmulTn,
    Phase::MatmulNt,
    Phase::GramSchmidt,
    Phase::PoolChunk,
    Phase::InFlight,
];

impl Phase {
    /// Stable snake_case name (trace event name, summary table key).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Step => "step",
            Phase::Grad => "grad",
            Phase::Compress => "compress",
            Phase::Collective => "collective",
            Phase::Decompress => "decompress",
            Phase::RingSend => "ring_send",
            Phase::RingRecv => "ring_recv",
            Phase::WireEncode => "wire_encode",
            Phase::WireDecode => "wire_decode",
            Phase::Rendezvous => "rendezvous",
            Phase::MatmulNn => "matmul_nn",
            Phase::MatmulTn => "matmul_tn",
            Phase::MatmulNt => "matmul_nt",
            Phase::GramSchmidt => "gram_schmidt",
            Phase::PoolChunk => "pool_chunk",
            Phase::InFlight => "in_flight",
        }
    }

    /// Trace-event category: which layer of the system the span lives in.
    pub fn category(self) -> &'static str {
        match self {
            Phase::Step | Phase::Grad => "coordinator",
            Phase::Compress | Phase::Collective | Phase::Decompress => "compress",
            Phase::RingSend | Phase::RingRecv | Phase::Rendezvous | Phase::InFlight => "transport",
            Phase::WireEncode | Phase::WireDecode => "wire",
            Phase::MatmulNn | Phase::MatmulTn | Phase::MatmulNt | Phase::GramSchmidt
            | Phase::PoolChunk => "kernel",
        }
    }
}

/// Enable or disable timing mode (per-phase accumulators).
pub fn enable_timing(on: bool) {
    set_mode_bit(MODE_TIMING, on);
}

/// Enable or disable trace mode (per-thread span buffers). Implies that
/// durations are being recorded; timing totals still require
/// [`enable_timing`].
pub fn enable_trace(on: bool) {
    set_mode_bit(MODE_TRACE, on);
}

/// Enable or disable metrics mode (the [`metrics`] registry: counters,
/// gauges, histograms — the `--metrics <path>` CLI flag). Recording
/// never touches computed values, so metrics-on runs stay bitwise
/// identical to metrics-off runs (`tests/integration_metrics.rs`).
pub fn enable_metrics(on: bool) {
    set_mode_bit(MODE_METRICS, on);
}

fn set_mode_bit(bit: u8, on: bool) {
    if on {
        MODE.fetch_or(bit, Ordering::SeqCst);
    } else {
        MODE.fetch_and(!bit, Ordering::SeqCst);
    }
}

/// Current mode bits ([`MODE_TIMING`] | [`MODE_TRACE`] | [`MODE_METRICS`]).
pub fn mode() -> u8 {
    MODE.load(Ordering::Relaxed)
}

/// Process-wide epoch all trace timestamps are relative to.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

// ---------------------------------------------------------------------
// Per-phase accumulators (timing mode).
// ---------------------------------------------------------------------

struct PhaseCell {
    count: AtomicU64,
    nanos: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)] // array-init template
const PHASE_CELL_INIT: PhaseCell =
    PhaseCell { count: AtomicU64::new(0), nanos: AtomicU64::new(0) };

static PHASE_CELLS: [PhaseCell; PHASE_COUNT] = [PHASE_CELL_INIT; PHASE_COUNT];

/// Wire bytes sent / received, folded in from
/// [`crate::transport::tcp::MeteredTransport`] endpoints that opted in
/// via [`add_wire_bytes`].
static WIRE_SENT: AtomicU64 = AtomicU64::new(0);
static WIRE_RECEIVED: AtomicU64 = AtomicU64::new(0);

/// Snapshot of every phase accumulator plus the global wire counters.
///
/// `counts` are deterministic for a fixed workload; `nanos` are
/// wall-clock and vary run to run. Indexed in [`PHASES`] order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseTotals {
    /// Closed spans per phase.
    pub counts: [u64; PHASE_COUNT],
    /// Accumulated span nanoseconds per phase (volatile).
    pub nanos: [u64; PHASE_COUNT],
    /// Wire bytes sent through metered transports.
    pub wire_sent: u64,
    /// Wire bytes received through metered transports.
    pub wire_received: u64,
}

impl PhaseTotals {
    /// Seconds accumulated in `phase` (volatile wall-clock).
    pub fn seconds(&self, phase: Phase) -> f64 {
        self.nanos[phase as usize] as f64 * 1e-9
    }

    /// Closed spans in `phase`.
    pub fn count(&self, phase: Phase) -> u64 {
        self.counts[phase as usize]
    }

    /// Elementwise difference `self − earlier` (saturating), for
    /// before/after interval attribution.
    pub fn delta_since(&self, earlier: &PhaseTotals) -> PhaseTotals {
        let mut out = *self;
        for i in 0..PHASE_COUNT {
            out.counts[i] = self.counts[i].saturating_sub(earlier.counts[i]);
            out.nanos[i] = self.nanos[i].saturating_sub(earlier.nanos[i]);
        }
        out.wire_sent = self.wire_sent.saturating_sub(earlier.wire_sent);
        out.wire_received = self.wire_received.saturating_sub(earlier.wire_received);
        out
    }
}

/// Snapshot the global per-phase accumulators and wire counters.
pub fn phase_totals() -> PhaseTotals {
    let mut counts = [0u64; PHASE_COUNT];
    let mut nanos = [0u64; PHASE_COUNT];
    for (i, cell) in PHASE_CELLS.iter().enumerate() {
        counts[i] = cell.count.load(Ordering::SeqCst);
        nanos[i] = cell.nanos.load(Ordering::SeqCst);
    }
    PhaseTotals {
        counts,
        nanos,
        wire_sent: WIRE_SENT.load(Ordering::SeqCst),
        wire_received: WIRE_RECEIVED.load(Ordering::SeqCst),
    }
}

/// Fold transport-level byte counts into the global wire counters
/// (no-op unless a recording mode is on). Called by metered transports
/// so trace summaries carry bytes next to span counts.
pub fn add_wire_bytes(sent: u64, received: u64) {
    if mode() == 0 {
        return;
    }
    if sent > 0 {
        WIRE_SENT.fetch_add(sent, Ordering::SeqCst);
    }
    if received > 0 {
        WIRE_RECEIVED.fetch_add(received, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------
// Tracks (trace mode): one named event buffer per recording thread.
// ---------------------------------------------------------------------

/// One closed span on a track.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// What the span measured.
    pub phase: Phase,
    /// Start, nanoseconds since the process trace epoch.
    pub start_ns: u64,
    /// End, nanoseconds since the process trace epoch.
    pub end_ns: u64,
}

/// A named event buffer. Tracks are keyed by *name*, not by thread id:
/// ephemeral threads re-created every step (the decentralized engine's
/// per-worker threads, the threaded ring's collective threads) adopt
/// the same track via [`set_track`], so a trace shows one stable row
/// per logical worker instead of thousands of one-shot threads.
struct Track {
    name: String,
    events: Mutex<Vec<SpanEvent>>,
}

fn registry() -> &'static Mutex<Vec<Arc<Track>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Track>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static CURRENT_TRACK: RefCell<Option<Arc<Track>>> = const { RefCell::new(None) };
}

fn track_named(name: &str) -> Arc<Track> {
    let mut tracks = registry().lock().expect("obs track registry poisoned");
    if let Some(t) = tracks.iter().find(|t| t.name == name) {
        return Arc::clone(t);
    }
    let t = Arc::new(Track { name: name.to_string(), events: Mutex::new(Vec::new()) });
    tracks.push(Arc::clone(&t));
    t
}

/// Bind the current thread's spans to the track called `name`,
/// creating it on first use. A no-op outside trace mode, so hot paths
/// (the threaded ring and the worker fleet re-bind on every spawned
/// thread) may call it unconditionally without touching the registry
/// lock. Threads that never call this record onto a track named after
/// the OS thread name (e.g. `powersgd-kernel-0`), or `main` for the
/// unnamed main thread.
pub fn set_track(name: &str) {
    if mode() & MODE_TRACE == 0 {
        return;
    }
    let t = track_named(name);
    CURRENT_TRACK.with(|cur| *cur.borrow_mut() = Some(t));
}

fn current_track() -> Arc<Track> {
    CURRENT_TRACK.with(|cur| {
        let mut cur = cur.borrow_mut();
        if let Some(t) = cur.as_ref() {
            return Arc::clone(t);
        }
        let name = std::thread::current().name().unwrap_or("main").to_string();
        let t = track_named(&name);
        *cur = Some(Arc::clone(&t));
        t
    })
}

/// All tracks with their events, sorted by track name then span start —
/// the input to [`chrome::chrome_trace_json`] and [`Summary::from_tracks`].
pub fn drain_tracks() -> Vec<(String, Vec<SpanEvent>)> {
    let tracks = registry().lock().expect("obs track registry poisoned");
    let mut out: Vec<(String, Vec<SpanEvent>)> = tracks
        .iter()
        .map(|t| {
            let mut events =
                std::mem::take(&mut *t.events.lock().expect("obs track poisoned"));
            events.sort_by_key(|e| (e.start_ns, std::cmp::Reverse(e.end_ns)));
            (t.name.clone(), events)
        })
        .filter(|(_, events)| !events.is_empty())
        .collect();
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

// ---------------------------------------------------------------------
// Spans.
// ---------------------------------------------------------------------

/// RAII span guard: records on drop. Inert (no clock read) when every
/// recording mode is off at [`span`] time.
pub struct SpanGuard {
    live: Option<(Phase, u64)>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some((phase, start_ns)) = self.live else { return };
        let end_ns = now_ns();
        let m = mode();
        if m & MODE_TIMING != 0 {
            let cell = &PHASE_CELLS[phase as usize];
            cell.count.fetch_add(1, Ordering::SeqCst);
            cell.nanos.fetch_add(end_ns.saturating_sub(start_ns), Ordering::SeqCst);
        }
        if m & MODE_TRACE != 0 {
            let track = current_track();
            track
                .events
                .lock()
                .expect("obs track poisoned")
                .push(SpanEvent { phase, start_ns, end_ns });
        }
    }
}

/// Open a span for `phase`. The returned guard records when dropped;
/// when no recording mode is enabled this is one relaxed atomic load.
#[inline]
pub fn span(phase: Phase) -> SpanGuard {
    // Only the span modes arm the guard: a metrics-only run
    // (MODE_METRICS set, both span bits clear) must not read clocks
    // here either.
    if mode() & (MODE_TIMING | MODE_TRACE) == 0 {
        return SpanGuard { live: None };
    }
    SpanGuard { live: Some((phase, now_ns())) }
}

// ---------------------------------------------------------------------
// Scoped capture (tests, experiment report).
// ---------------------------------------------------------------------

/// A finished scoped recording: the traced workload's tracks plus the
/// phase-total delta over the captured interval.
pub struct Capture {
    /// Tracks recorded during the capture, name-sorted.
    pub tracks: Vec<(String, Vec<SpanEvent>)>,
    /// Per-phase totals accumulated during the capture.
    pub totals: PhaseTotals,
}

impl Capture {
    /// Deterministic/volatile summary restricted to tracks whose name
    /// starts with one of `prefixes` (empty = all tracks). Restricting
    /// by prefix keeps parallel test binaries from polluting each
    /// other's counts: a capture of `worker-*` tracks is blind to spans
    /// another test records on `main`.
    pub fn summary(&self, prefixes: &[&str]) -> Summary {
        let filtered: Vec<&(String, Vec<SpanEvent>)> = self
            .tracks
            .iter()
            .filter(|(name, _)| {
                prefixes.is_empty() || prefixes.iter().any(|p| name.starts_with(p))
            })
            .collect();
        let mut counts = [0u64; PHASE_COUNT];
        let mut nanos = [0u64; PHASE_COUNT];
        for (_, events) in &filtered {
            for e in events.iter() {
                counts[e.phase as usize] += 1;
                nanos[e.phase as usize] += e.end_ns - e.start_ns;
            }
        }
        Summary {
            counts,
            nanos,
            tracks: filtered.iter().map(|(name, _)| name.clone()).collect(),
            wire_sent: self.totals.wire_sent,
            wire_received: self.totals.wire_received,
        }
    }
}

/// Per-phase aggregation of a capture's tracks. `counts`, `tracks`,
/// `wire_*` are deterministic for a fixed workload; `nanos` are not.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Summary {
    /// Spans per phase ([`PHASES`] order) — deterministic.
    pub counts: [u64; PHASE_COUNT],
    /// Nanoseconds per phase — volatile wall-clock.
    pub nanos: [u64; PHASE_COUNT],
    /// Names of the tracks aggregated, sorted — deterministic.
    pub tracks: Vec<String>,
    /// Wire bytes sent during the capture — deterministic.
    pub wire_sent: u64,
    /// Wire bytes received during the capture — deterministic.
    pub wire_received: u64,
}

impl Summary {
    /// Seconds spent in `phase` (volatile).
    pub fn seconds(&self, phase: Phase) -> f64 {
        self.nanos[phase as usize] as f64 * 1e-9
    }

    /// Span count for `phase` (deterministic).
    pub fn count(&self, phase: Phase) -> u64 {
        self.counts[phase as usize]
    }

    /// The deterministic projection: per-phase counts plus byte
    /// counters, with every duration dropped. Two captures of the same
    /// workload must agree on this exactly
    /// (`tests/integration_obs.rs`).
    pub fn deterministic_key(&self) -> (Vec<(String, u64)>, Vec<String>, u64, u64) {
        let counts = PHASES
            .iter()
            .map(|&p| (p.name().to_string(), self.counts[p as usize]))
            .collect();
        (counts, self.tracks.clone(), self.wire_sent, self.wire_received)
    }
}

fn capture_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Run `f` with tracing + timing enabled and return its result together
/// with everything recorded while it ran.
///
/// Captures are serialized behind a global lock (two concurrent
/// captures in one process would otherwise interleave their spans);
/// the previous mode bits are restored on exit, so a capture inside an
/// always-timing trainer process leaves timing on. Spans recorded by
/// *other* threads during the capture do land in the capture's tracks —
/// filter with [`Capture::summary`] prefixes where that matters.
pub fn capture<T>(f: impl FnOnce() -> T) -> (T, Capture) {
    let _guard = capture_lock().lock().unwrap_or_else(|e| e.into_inner());
    let before_mode = MODE.load(Ordering::SeqCst);
    drain_tracks(); // discard anything stale from before the capture
    let before = phase_totals();
    // OR onto the previous bits: a capture inside a metrics-enabled
    // process must not switch metrics recording off for its duration.
    MODE.store(before_mode | MODE_TIMING | MODE_TRACE, Ordering::SeqCst);
    let out = f();
    MODE.store(before_mode, Ordering::SeqCst);
    let totals = phase_totals().delta_since(&before);
    let tracks = drain_tracks();
    (out, Capture { tracks, totals })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tracks created by tests in this module, distinct per test so
    /// parallel test threads cannot collide on a track name.
    fn spin(track: &str, phase: Phase, n: usize) {
        set_track(track);
        for _ in 0..n {
            let _s = span(phase);
            std::hint::black_box(2 + 2);
        }
    }

    #[test]
    fn disabled_span_records_nothing() {
        // No capture lock needed: this asserts on the *absence* of
        // recording for a unique track name.
        let before = phase_totals();
        {
            let _s = span(Phase::GramSchmidt);
        }
        // Another test may have a capture live; only assert when the
        // mode really was off at span time.
        if mode() == 0 {
            let after = phase_totals();
            assert_eq!(after.counts, before.counts);
        }
    }

    #[test]
    fn capture_counts_are_deterministic() {
        let work = || spin("obs-unit-a", Phase::Compress, 7);
        let ((), cap1) = capture(work);
        let ((), cap2) = capture(work);
        let s1 = cap1.summary(&["obs-unit-a"]);
        let s2 = cap2.summary(&["obs-unit-a"]);
        assert_eq!(s1.count(Phase::Compress), 7);
        assert_eq!(s1.deterministic_key(), s2.deterministic_key());
        assert_eq!(s1.tracks, vec!["obs-unit-a".to_string()]);
    }

    #[test]
    fn summary_prefix_filter_excludes_other_tracks() {
        let ((), cap) = capture(|| {
            spin("obs-unit-b1", Phase::Collective, 3);
            spin("obs-unit-b2", Phase::Collective, 2);
        });
        assert_eq!(cap.summary(&["obs-unit-b1"]).count(Phase::Collective), 3);
        assert_eq!(cap.summary(&["obs-unit-b"]).count(Phase::Collective), 5);
        assert_eq!(cap.summary(&["no-such-prefix"]).count(Phase::Collective), 0);
    }

    #[test]
    fn wire_bytes_fold_into_the_capture() {
        let ((), cap) = capture(|| add_wire_bytes(120, 64));
        // `>=`, not `==`: the wire counters are process-global, and a
        // concurrent test exercising a metered transport while this
        // capture holds the mode on would fold its bytes in too.
        assert!(cap.totals.wire_sent >= 120, "sent {}", cap.totals.wire_sent);
        assert!(cap.totals.wire_received >= 64, "received {}", cap.totals.wire_received);
    }

    #[test]
    fn span_durations_are_ordered_and_nested() {
        let ((), cap) = capture(|| {
            set_track("obs-unit-c");
            let _outer = span(Phase::Step);
            {
                let _inner = span(Phase::Compress);
                std::hint::black_box([0u8; 64]);
            }
        });
        let track = cap
            .tracks
            .iter()
            .find(|(name, _)| name == "obs-unit-c")
            .expect("track recorded");
        // Inner closes before outer; both are well-formed intervals.
        let inner = track.1.iter().find(|e| e.phase == Phase::Compress).unwrap();
        let outer = track.1.iter().find(|e| e.phase == Phase::Step).unwrap();
        assert!(inner.start_ns <= inner.end_ns);
        assert!(outer.start_ns <= inner.start_ns);
        assert!(inner.end_ns <= outer.end_ns);
    }

    #[test]
    fn phase_metadata_is_total() {
        assert_eq!(PHASES.len(), PHASE_COUNT);
        for (i, p) in PHASES.iter().enumerate() {
            assert_eq!(*p as usize, i, "{}", p.name());
            assert!(!p.name().is_empty());
            assert!(!p.category().is_empty());
        }
    }
}
