//! Experiment registry and report generator (DESIGN.md §12): reproduce
//! the paper's §5 sweeps end-to-end with one command.
//!
//! The rest of the crate can *price* any single configuration
//! ([`crate::simulate`]) and *execute* any single run
//! ([`crate::transport`]); this module composes them into the paper's
//! multi-axis sweeps. A declarative registry ([`registry()`]) names
//! suites of scenarios (scheme × rank × workers × backend × model
//! profile × engine); [`run_suite`] evaluates a suite into flat
//! records; each run is written as a versioned `EXPERIMENTS_<suite>.json`
//! artifact (the `util/bench.rs` BenchJson conventions: hand-rolled
//! writer, stable key order, flat records), and
//! [`generate_report`](report::generate_report) renders the whole
//! registry — plus one *measured* threaded-engine run per
//! [`WireConfig`] — into a deterministic `REPORT.md` with paper-style
//! tables. The CLI entry point is `powersgd experiment`.
//!
//! Determinism is a hard requirement: for a fixed seed every report
//! cell except the `~`-prefixed measured durations is byte-for-byte
//! reproducible (pinned by `tests/integration_experiments.rs` under the
//! [`report::redact_measured`] projection, which maps every `~`-number
//! to `~X`), so a diff of `REPORT.md` is a diff of the model, never of
//! the run. The time-attribution section follows the obs-layer policy
//! (DESIGN.md §13): span *counts* and byte counters are deterministic
//! and compared exactly; wall-clock durations are published but marked
//! volatile.
//!
//! # Worked example
//!
//! Expand a registered suite and evaluate one of its scenarios:
//!
//! ```
//! use powersgd::experiments::{registry, run_scenario, scenarios_for};
//!
//! assert!(registry().iter().any(|s| s.name == "scheme-compare"));
//! let scenarios = scenarios_for("scheme-compare", /*quick=*/ true);
//! let record = run_scenario(&scenarios[0]).unwrap();
//! // Flat record: a stable name plus numeric metrics.
//! assert!(record.name.starts_with("scheme-compare/resnet18/"));
//! assert!(record.metrics.iter().any(|(k, _)| *k == "total_ms"));
//! ```

pub mod registry;
pub mod report;

pub use registry::{
    registry, scenarios_for, suite_by_name, wire_configs, ScenarioSpec, Suite, WireConfig,
    DEFAULT_WORKERS, PROFILES, SCALING_WORKERS, SUITES,
};
pub use report::{generate_report, redact_measured, write_report};

use crate::collectives::{ring_wire_bytes, CollOp};
use crate::compress::SchemeMeta;
use crate::net::backend_by_name;
use crate::obs::{self, Phase};
use crate::profiles;
use crate::simulate::{
    data_per_epoch_mb, epoch_speedup_vs_single_sgd, simulate_step, simulate_step_overlapped,
};
use crate::transport::tcp::{
    harness_registry, oracle_trajectory, worker_trajectory, HarnessConfig, MeteredTransport,
};
use crate::transport::{Cluster, InProcDuplex, PipelineMode};
use crate::util::bench::{json_escape, json_num};
use crate::util::Table;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Schema version stamped into every `EXPERIMENTS_*.json` document.
/// Bump when a record field changes meaning, so downstream consumers of
/// the uploaded CI artifacts can dispatch on it.
pub const SCHEMA_VERSION: u32 = 1;

/// Bucket cap used when a suite prices the overlapped schedule
/// (`pipeline = "overlap"`): 4 MiB of raw gradient per bucket, the
/// crate's usual `--bucket-mb 4` working point (small enough that the
/// first reduction launches early in the backward pass, large enough
/// that per-bucket latency does not dominate).
pub const OVERLAP_BUCKET_BYTES: u64 = 4 << 20;

/// One flat result record of a suite run: a stable name, string tags
/// (axis values), and numeric metrics.
pub struct Record {
    /// Stable identifier ([`ScenarioSpec::id`] or the wire-check slug).
    pub name: String,
    /// String-valued axes (profile, scheme, backend, engine, ...).
    pub tags: Vec<(&'static str, String)>,
    /// Numeric results, in a stable order.
    pub metrics: Vec<(&'static str, f64)>,
}

/// One executed suite: the input axes plus every record it produced.
pub struct SuiteRun {
    /// The registry entry that was run.
    pub suite: Suite,
    /// Seed the run (and its measured parts) used.
    pub seed: u64,
    /// Whether the quick (CI smoke) axes were used.
    pub quick: bool,
    /// Flat results, in registry order.
    pub records: Vec<Record>,
}

/// Evaluate one analytic scenario on the calibrated simulator.
pub fn run_scenario(spec: &ScenarioSpec) -> Result<Record> {
    let profile = profiles::by_name(spec.profile)
        .ok_or_else(|| anyhow!("scenario {}: unknown profile {:?}", spec.id(), spec.profile))?;
    let backend = backend_by_name(spec.backend)
        .ok_or_else(|| anyhow!("scenario {}: unknown backend {:?}", spec.id(), spec.backend))?;
    let b = simulate_step(&profile, spec.scheme, spec.workers, &backend);
    let speedup = epoch_speedup_vs_single_sgd(&profile, spec.scheme, spec.workers, &backend);
    let mut metrics = vec![
        ("workers", spec.workers as f64),
        ("msg_bytes", spec.scheme.message_bytes(&profile.registry) as f64),
        ("data_epoch_mb", data_per_epoch_mb(&profile, spec.scheme)),
        ("encode_ms", b.encode * 1e3),
        ("comm_ms", b.comm * 1e3),
        ("decode_ms", b.decode * 1e3),
        ("total_ms", b.total() * 1e3),
        ("speedup_vs_single_sgd", speedup),
    ];
    // The backend-compare suite carries the pipeline axis: price the
    // bucketed schedule too, with overlap on or off per the spec, so the
    // sequential and pipelined points of one (profile, scheme, backend)
    // differ only in what the scheduler hides.
    if spec.suite == "backend-compare" {
        let cluster = Cluster::uniform(spec.workers, &backend);
        let ov = simulate_step_overlapped(
            &profile,
            spec.scheme,
            &cluster,
            OVERLAP_BUCKET_BYTES,
            spec.pipeline == "overlap",
        );
        metrics.push(("exposed_comm_ms", ov.exposed_comm * 1e3));
        metrics.push(("pipelined_total_ms", ov.total * 1e3));
    }
    Ok(Record {
        name: spec.id(),
        tags: vec![
            ("suite", spec.suite.to_string()),
            ("profile", spec.profile.to_string()),
            ("scheme", spec.scheme.name()),
            ("backend", spec.backend.to_string()),
            ("engine", spec.engine.to_string()),
            ("pipeline", spec.pipeline.to_string()),
        ],
        metrics,
    })
}

/// Run a named suite: analytic suites expand via [`scenarios_for`] and
/// evaluate on the simulator; `wire-check` executes one real threaded
/// run per [`WireConfig`] ([`measured_wire_check`]).
pub fn run_suite(name: &str, seed: u64, quick: bool) -> Result<SuiteRun> {
    let suite = suite_by_name(name).ok_or_else(|| {
        anyhow!("unknown suite {name:?}; `powersgd experiment --list` shows the registry")
    })?;
    let mut records = Vec::new();
    if suite.name == "wire-check" {
        for cfg in wire_configs(quick) {
            let outcome =
                measured_wire_check(cfg.compressor, cfg.rank, cfg.workers, cfg.steps, seed)?;
            records.extend(outcome.records());
        }
    } else {
        for spec in scenarios_for(suite.name, quick) {
            records.push(run_scenario(&spec)?);
        }
    }
    Ok(SuiteRun { suite, seed, quick, records })
}

impl SuiteRun {
    /// Serialize the run as one flat-record JSON document (the
    /// `BenchJson` conventions: hand-rolled writer, stable key order,
    /// tags as strings, metrics as numbers, non-finite → null).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"schema_version\": {},\n", SCHEMA_VERSION));
        out.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(self.suite.name)));
        out.push_str(&format!("  \"title\": \"{}\",\n", json_escape(self.suite.title)));
        out.push_str(&format!("  \"paper_ref\": \"{}\",\n", json_escape(self.suite.paper_ref)));
        out.push_str(&format!("  \"seed\": {},\n", self.seed));
        out.push_str(&format!("  \"quick\": {},\n", self.quick));
        out.push_str(&format!("  \"threads\": {},\n", crate::runtime::pool::threads()));
        out.push_str("  \"records\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str(&format!("    {{\"name\": \"{}\"", json_escape(&r.name)));
            for (k, v) in &r.tags {
                out.push_str(&format!(", \"{}\": \"{}\"", json_escape(k), json_escape(v)));
            }
            for (k, v) in &r.metrics {
                out.push_str(&format!(", \"{}\": {}", json_escape(k), json_num(*v)));
            }
            out.push_str(if i + 1 < self.records.len() { "},\n" } else { "}\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write `EXPERIMENTS_<suite>.json` into `dir`; returns the path.
    pub fn write_json(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("EXPERIMENTS_{}.json", self.suite.name));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Summary table of every record (scenario id + metrics), for the
    /// CLI's stdout. Metric columns come from the first record; suites
    /// produce homogeneous records, and a missing metric renders `-`.
    pub fn table(&self) -> Table {
        let metric_keys: Vec<&'static str> = self
            .records
            .first()
            .map(|r| r.metrics.iter().map(|(k, _)| *k).collect())
            .unwrap_or_default();
        let mut header: Vec<&str> = vec!["Scenario"];
        header.extend(metric_keys.iter().copied());
        let mut t =
            Table::new(&format!("{} ({})", self.suite.title, self.suite.paper_ref), &header);
        for r in &self.records {
            let mut cells = vec![r.name.clone()];
            for key in &metric_keys {
                let cell = match r.metrics.iter().find(|(k, _)| k == key) {
                    Some((_, v)) if v.fract() == 0.0 && v.abs() < 1e15 => {
                        format!("{}", *v as i64)
                    }
                    Some((_, v)) => format!("{v:.3}"),
                    None => "-".into(),
                };
                cells.push(cell);
            }
            t.row(&cells);
        }
        t
    }
}

/// One rank's measured vs analytic wire traffic in a
/// [`measured_wire_check`] run.
pub struct RankWire {
    /// Ring rank.
    pub rank: usize,
    /// Payload bytes the metered transport counted on the wire.
    pub measured: u64,
    /// The [`ring_wire_bytes`] expansion of every collective the run
    /// logged — the closed-form prediction of `measured`.
    pub analytic: u64,
    /// Logical per-worker bytes (the paper's data-volume unit).
    pub logical: u64,
}

/// A verified measured run of the threaded engine.
pub struct WireCheckOutcome {
    /// Compressor CLI name the run used.
    pub compressor: String,
    /// Collective schedule the workers ran
    /// (`--pipeline {off,overlap}`; byte counts are schedule-invariant,
    /// blocked-time attribution is not).
    pub pipeline: PipelineMode,
    /// Compression rank where applicable.
    pub rank: usize,
    /// Worker threads in the ring.
    pub workers: usize,
    /// EF-SGD steps run.
    pub steps: usize,
    /// Per-rank traffic, rank-ordered.
    pub per_rank: Vec<RankWire>,
    /// Closed-form per-worker message bytes per step (the
    /// `message_bytes` model on the harness registry).
    pub model_bytes_per_step: u64,
    /// Span summary of the traced run, restricted to the `worker-*`
    /// tracks: per-phase counts, track names, and wire counters are
    /// deterministic for the workload; durations are wall-clock.
    pub spans: obs::Summary,
    /// The α/β overlap model's exposed-communication price for this
    /// traffic on the calibrated NCCL cluster, seconds per step
    /// (deterministic). The harness trajectory is strictly sequential,
    /// so its lockstep schedule exposes every collective second.
    pub analytic_exposed_s: f64,
}

impl WireCheckOutcome {
    /// Mean measured seconds per worker per step spent blocked in ring
    /// `recv_prev` during the traced run — the run's actually-exposed
    /// communication on the in-process ring. Volatile wall-clock.
    pub fn measured_recv_blocked_s(&self) -> f64 {
        self.spans.seconds(Phase::RingRecv) / (self.workers * self.steps.max(1)) as f64
    }

    /// Short scheme slug for table titles and record names
    /// (`powersgd-r2`, `sign-norm`).
    pub fn slug(&self) -> String {
        if self.rank > 0 {
            format!("{}-r{}", self.compressor, self.rank)
        } else {
            self.compressor.clone()
        }
    }

    /// Flat per-rank records in the artifact schema.
    pub fn records(&self) -> Vec<Record> {
        self.per_rank
            .iter()
            .map(|r| Record {
                name: if self.pipeline == PipelineMode::Off {
                    format!("wire-check/{}/w{}/rank{}", self.slug(), self.workers, r.rank)
                } else {
                    format!(
                        "wire-check/{}/w{}/rank{}/{}",
                        self.slug(),
                        self.workers,
                        r.rank,
                        self.pipeline.cli_name()
                    )
                },
                tags: vec![
                    ("suite", "wire-check".to_string()),
                    ("compressor", self.compressor.clone()),
                    ("engine", "threaded".to_string()),
                    ("transport", "inproc-metered".to_string()),
                    ("pipeline", self.pipeline.cli_name().to_string()),
                ],
                metrics: vec![
                    ("rank", r.rank as f64),
                    ("workers", self.workers as f64),
                    ("steps", self.steps as f64),
                    ("measured_wire_bytes", r.measured as f64),
                    ("analytic_wire_bytes", r.analytic as f64),
                    ("logical_bytes", r.logical as f64),
                    ("model_bytes_per_step", self.model_bytes_per_step as f64),
                ],
            })
            .collect()
    }
}

/// Execute one **real** threaded-engine EF-SGD run and verify its byte
/// accounting end to end.
///
/// Spawns `workers` OS threads, each running the *same* per-worker
/// trajectory the multi-process TCP harness runs
/// ([`worker_trajectory`]) — an unmodified `EfSgd` whose compressor
/// aggregates over a metered [`InProcDuplex`] ring. The verification
/// chain, every link checked on every run:
///
/// 1. measured wire bytes == the [`ring_wire_bytes`] expansion of every
///    logged collective (checked inside `worker_trajectory`, and
///    recomputed here into [`RankWire::analytic`]);
/// 2. logged logical bytes == the closed-form `message_bytes` model;
/// 3. every worker's final parameters are **bit-identical** to the
///    centralized lockstep oracle's.
///
/// This is the "measured wire bytes from a real `--engine threaded`
/// run" artifact of the generated report; byte counts are independent
/// of thread scheduling, so the outcome is deterministic.
///
/// The run executes under an [`obs::capture`]: every worker thread
/// records onto a `worker-<rank>` span track, and the resulting
/// [`obs::Summary`] feeds the report's time-attribution section. The
/// capture lock also serializes concurrent wire checks, so summaries
/// never interleave.
pub fn measured_wire_check(
    compressor: &str,
    rank: usize,
    workers: usize,
    steps: usize,
    seed: u64,
) -> Result<WireCheckOutcome> {
    measured_wire_check_pipelined(compressor, rank, workers, steps, seed, PipelineMode::Off)
}

/// [`measured_wire_check`] with an explicit collective schedule. With
/// [`PipelineMode::Overlap`] the workers post the vector reduction
/// early and drain it behind the factor collectives — the bitwise and
/// byte-accounting verification chain is unchanged (overlap reorders
/// traffic, it never changes bits), but the traced run's ring-recv
/// blocked time drops and `Phase::InFlight` spans appear. The report's
/// overlap-vs-lockstep section runs this on the same [`WireConfig`]
/// twice to show exactly that.
pub fn measured_wire_check_pipelined(
    compressor: &str,
    rank: usize,
    workers: usize,
    steps: usize,
    seed: u64,
    pipeline: PipelineMode,
) -> Result<WireCheckOutcome> {
    let cfg = HarnessConfig {
        compressor: compressor.to_string(),
        rank,
        seed,
        steps,
        pipeline,
        ..HarnessConfig::default()
    };
    let endpoints = InProcDuplex::endpoints(workers);
    let (reports, cap) = obs::capture(|| {
        std::thread::scope(|scope| {
            let handles: Vec<_> = endpoints
                .into_iter()
                .enumerate()
                .map(|(rank, ep)| {
                    let cfg = cfg.clone();
                    scope.spawn(move || {
                        obs::set_track(&format!("worker-{rank}"));
                        worker_trajectory(MeteredTransport::new(ep), &cfg)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("wire-check worker thread panicked"))
                .collect::<Result<Vec<_>>>()
        })
    });
    let reports = reports.context("wire-check: a worker trajectory failed")?;
    let spans = cap.summary(&["worker-"]);

    // The same cross-checks `powersgd launch` runs over real sockets:
    // bitwise parameters and logical bytes against the lockstep oracle.
    let (oracle_params, oracle_logical) = oracle_trajectory(workers, &cfg)?;
    let mut per_rank = Vec::with_capacity(workers);
    for report in &reports {
        let bitwise = report.params.len() == oracle_params.len()
            && report.params.iter().zip(oracle_params.iter()).all(|(a, b)| {
                a.data().len() == b.data().len()
                    && a.data()
                        .iter()
                        .zip(b.data().iter())
                        .all(|(x, y)| x.to_bits() == y.to_bits())
            });
        if !bitwise {
            bail!("wire-check: rank {} diverged from the lockstep oracle", report.rank);
        }
        if report.logical_bytes != oracle_logical {
            bail!(
                "wire-check: rank {} logged {} logical bytes, oracle logged {}",
                report.rank,
                report.logical_bytes,
                oracle_logical
            );
        }
        let analytic: u64 = report
            .ops
            .iter()
            .map(|op| ring_wire_bytes(op.kind, op.bytes, workers, report.rank))
            .sum();
        per_rank.push(RankWire {
            rank: report.rank,
            measured: report.wire_bytes,
            analytic,
            logical: report.logical_bytes,
        });
    }
    per_rank.sort_by_key(|r| r.rank);
    let model_bytes_per_step = crate::compress::worker_by_name(compressor, rank, seed)
        .map(|w| w.message_bytes(&harness_registry()))
        .unwrap_or(0);
    let nccl = backend_by_name("nccl").expect("nccl backend registered");
    let analytic_exposed_s =
        analytic_exposed_comm(&reports[0].ops, &Cluster::uniform(workers, &nccl), steps);
    Ok(WireCheckOutcome {
        compressor: compressor.to_string(),
        pipeline,
        rank,
        workers,
        steps,
        per_rank,
        model_bytes_per_step,
        spans,
        analytic_exposed_s,
    })
}

/// Outcome of the report's run-health check: one measured wire-check
/// re-run with the crate-wide metrics registry (DESIGN.md §15) enabled,
/// plus the registry delta the run produced.
pub struct MetricsCheckOutcome {
    /// The verified measured run (same chain as [`measured_wire_check`]:
    /// bitwise vs the oracle, measured == analytic wire bytes).
    pub outcome: WireCheckOutcome,
    /// Registry delta over the run: counters and histograms are
    /// [`MetricsSnapshot::delta_since`] differences, gauges and maxes
    /// the final values.
    pub delta: crate::obs::metrics::MetricsSnapshot,
}

/// Re-run the first [`WireConfig`] with the metrics registry enabled
/// and return the run plus its registry delta — the report's "Run
/// health" section. The registry is process-global, so the measurement
/// holds [`crate::obs::metrics::registry_lock`] (no concurrent holder
/// can flip the bit off mid-run and under-count); concurrent recorders
/// in a parallel test harness can still inflate the delta, which is why
/// the report checks that the counters *cover* the metered traffic and
/// marks the values volatile. In a single-run process (the CLI, the CI
/// smoke jobs) the counters equal the metered totals exactly — the
/// per-rank equality is what `powersgd launch --metrics` reconciles and
/// `tests/integration_metrics.rs` pins.
pub fn measured_metrics_check(seed: u64, quick: bool) -> Result<MetricsCheckOutcome> {
    use crate::obs::metrics;
    let cfg = wire_configs(quick).into_iter().next().expect("wire_configs is never empty");
    let _guard = metrics::registry_lock();
    let was_on = metrics::on();
    obs::enable_metrics(true);
    let before = metrics::snapshot();
    let result = measured_wire_check(cfg.compressor, cfg.rank, cfg.workers, cfg.steps, seed);
    let after = metrics::snapshot();
    if !was_on {
        obs::enable_metrics(false);
    }
    Ok(MetricsCheckOutcome {
        outcome: result.context("measured run-health check")?,
        delta: after.delta_since(&before),
    })
}

/// Price one harness run's logged collectives on the α/β cluster model
/// and return the exposed-communication seconds per step. The
/// per-worker trajectory is strictly sequential — compress, collective,
/// decompress, with nothing overlapping the collectives — so *every*
/// priced collective second is exposed and the price is the plain sum
/// of [`Cluster::time`] over the logged ops (exactly what the overlap
/// scheduler computes with `overlap = false`, without the detour
/// through its bucket machinery).
fn analytic_exposed_comm(ops: &[CollOp], cluster: &Cluster, steps: usize) -> f64 {
    let total: f64 = ops.iter().map(|op| cluster.time(op.kind, op.bytes)).sum();
    total / steps.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_json_is_well_formed() {
        let run = run_suite("rank-sweep", 42, true).unwrap();
        assert!(!run.records.is_empty());
        let doc = run.to_json();
        assert!(doc.contains("\"schema_version\": 1"));
        assert!(doc.contains("\"suite\": \"rank-sweep\""));
        assert!(doc.contains("\"profile\": \"resnet18\""));
        for (open, close) in [('{', '}'), ('[', ']')] {
            assert_eq!(doc.matches(open).count(), doc.matches(close).count());
        }
    }

    #[test]
    fn rank_sweep_pins_hand_computed_resnet_bytes() {
        // Independently hand-computed from the Appendix F shapes:
        // rank-2 PowerSGD on ResNet18 transmits 329 512 bytes/step,
        // SGD 44 696 320. A regression in any per-spec byte formula
        // cannot hide in the aggregate.
        let run = run_suite("rank-sweep", 42, false).unwrap();
        let metric = |name: &str, key: &str| -> f64 {
            let r = run
                .records
                .iter()
                .find(|r| r.name == name)
                .unwrap_or_else(|| panic!("record {name}"));
            r.metrics.iter().find(|(k, _)| *k == key).expect(key).1
        };
        assert_eq!(metric("rank-sweep/resnet18/rank2/w16/nccl", "msg_bytes"), 329_512.0);
        assert_eq!(metric("rank-sweep/resnet18/sgd/w16/nccl", "msg_bytes"), 44_696_320.0);
    }

    #[test]
    fn unknown_suite_is_a_clean_error() {
        assert!(run_suite("bogus", 1, false).is_err());
    }
}
