//! Declarative scenario registry: which (suite × profile × scheme ×
//! workers × backend × engine × pipeline) points `powersgd experiment`
//! runs.
//!
//! A [`Suite`] names a group of scenarios reproducing one paper
//! artifact; [`scenarios_for`] expands a suite name into concrete
//! [`ScenarioSpec`]s. Every axis value is expressed in its CLI spelling
//! — `tests/integration_experiments.rs` pins that each registered
//! scenario round-trips through the CLI parsers
//! ([`crate::simulate::scheme_by_name`], [`crate::profiles::by_name`],
//! [`crate::net::backend_by_name`],
//! [`crate::transport::engine_by_name`]), so nothing can be registered
//! that a user could not also run by hand.
//!
//! The `wire-check` suite has no analytic scenarios: its points are
//! real measured runs of the threaded engine, described by
//! [`WireConfig`] and executed by
//! [`measured_wire_check`](crate::experiments::measured_wire_check).

use crate::simulate::Scheme;

/// One registered experiment suite.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Suite {
    /// CLI name (`powersgd experiment --suite <name>`).
    pub name: &'static str,
    /// Human-readable title for reports and logs.
    pub title: &'static str,
    /// The paper artifact(s) the suite reproduces.
    pub paper_ref: &'static str,
}

/// Every registered suite, in report order.
pub const SUITES: [Suite; 5] = [
    Suite {
        name: "rank-sweep",
        title: "Rank sweep",
        paper_ref: "Table 3 / Table 7 / Appendix D",
    },
    Suite { name: "scheme-compare", title: "Scheme compare", paper_ref: "Table 4" },
    Suite { name: "scaling", title: "Worker scaling", paper_ref: "Figure 3" },
    Suite { name: "backend-compare", title: "Backend compare", paper_ref: "Appendix B" },
    Suite {
        name: "wire-check",
        title: "Measured wire bytes",
        paper_ref: "Section 3 aggregation / DESIGN.md par. 10",
    },
];

/// The full registry, in report order.
pub fn registry() -> &'static [Suite] {
    &SUITES
}

/// Suite by CLI name.
pub fn suite_by_name(name: &str) -> Option<Suite> {
    SUITES.iter().copied().find(|s| s.name == name)
}

/// One fully-specified analytic experiment point.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Owning suite's CLI name.
    pub suite: &'static str,
    /// Model profile CLI name ([`crate::profiles::by_name`]).
    pub profile: &'static str,
    /// Compression scheme.
    pub scheme: Scheme,
    /// Worker count `W`.
    pub workers: usize,
    /// Backend CLI name ([`crate::net::backend_by_name`]).
    pub backend: &'static str,
    /// Engine CLI name ([`crate::transport::engine_by_name`]); analytic
    /// scenarios price the lockstep schedule.
    pub engine: &'static str,
    /// Pipeline CLI name ([`crate::transport::pipeline_by_name`]):
    /// `"off"` prices the sequential schedule, `"overlap"` the
    /// bucketed comm/compute-overlapped one (the backend-compare
    /// suite's extra axis — the analytic counterpart of
    /// `--pipeline overlap`).
    pub pipeline: &'static str,
}

impl ScenarioSpec {
    /// Stable identifier, used as the JSON record name:
    /// `suite/profile/scheme/wW/backend`, with a `/overlap` suffix on
    /// pipelined points (so pre-existing record names never change).
    pub fn id(&self) -> String {
        let (name, rank) = self.scheme.cli_spelling();
        let scheme = if rank > 0 { format!("{name}-r{rank}") } else { name };
        let base = format!(
            "{}/{}/{}/w{}/{}",
            self.suite, self.profile, scheme, self.workers, self.backend
        );
        if self.pipeline == "off" {
            base
        } else {
            format!("{base}/{}", self.pipeline)
        }
    }
}

/// Model profiles every suite covers (all three of the paper's §5
/// workloads).
pub const PROFILES: [&str; 3] = ["resnet18", "lstm", "transformer"];

/// Ranks the rank sweep visits for `profile`: Table 3's 1/2/4 for the
/// CNN and LSTM, Appendix D's 4–32 for the transformer (whose adaptive
/// embeddings need higher ranks for the same quality).
pub fn sweep_ranks(profile: &str) -> &'static [usize] {
    match profile {
        "transformer" => &[4, 8, 16, 32],
        _ => &[1, 2, 4],
    }
}

/// The Table 4 compressor zoo at PowerSGD-equivalent rank 2.
pub fn scheme_zoo() -> Vec<Scheme> {
    vec![
        Scheme::Sgd,
        Scheme::PowerSgd { rank: 2 },
        Scheme::UnbiasedRank { rank: 2 },
        Scheme::RandomBlock { rank: 2 },
        Scheme::RandomK { rank: 2 },
        Scheme::TopK { rank: 2 },
        Scheme::SignNorm,
        Scheme::Signum,
        Scheme::Atomo { rank: 2 },
    ]
}

/// Worker counts of the scaling suite (Figure 3's x axis).
pub const SCALING_WORKERS: [usize; 5] = [2, 4, 8, 16, 32];

/// Schemes Figure 3 tracks across worker counts.
pub fn scaling_schemes() -> Vec<Scheme> {
    vec![Scheme::Sgd, Scheme::PowerSgd { rank: 2 }, Scheme::Signum]
}

/// Schemes the backend-compare suite prices on both backends — shared
/// by the suite expansion and the report section so the two published
/// artifacts cannot drift apart.
pub fn backend_compare_schemes() -> Vec<Scheme> {
    vec![Scheme::Sgd, Scheme::PowerSgd { rank: 2 }, Scheme::SignNorm]
}

/// Worker count of the single-point suites (the paper's 16-GPU testbed).
pub const DEFAULT_WORKERS: usize = 16;

/// One measured-run configuration of the `wire-check` suite: a real
/// threaded-engine EF-SGD trajectory over a metered in-process ring.
#[derive(Debug, Clone, Copy)]
pub struct WireConfig {
    /// Compressor CLI name (must have a per-worker implementation,
    /// [`crate::compress::worker_by_name`]).
    pub compressor: &'static str,
    /// Compression rank where applicable (0 for rank-free schemes).
    pub rank: usize,
    /// Worker threads in the ring.
    pub workers: usize,
    /// EF-SGD steps.
    pub steps: usize,
}

/// Measured-run configurations: one all-reduce scheme (PowerSGD) and
/// one gather scheme (Sign+Norm), so both ring expansions are
/// exercised. `quick` keeps a single small config for the CI smoke
/// tier.
pub fn wire_configs(quick: bool) -> Vec<WireConfig> {
    if quick {
        vec![WireConfig { compressor: "powersgd", rank: 2, workers: 2, steps: 2 }]
    } else {
        vec![
            WireConfig { compressor: "powersgd", rank: 2, workers: 4, steps: 3 },
            WireConfig { compressor: "sign-norm", rank: 0, workers: 2, steps: 3 },
        ]
    }
}

/// Expand a suite name into its analytic scenarios. Unknown names and
/// the measured-only `wire-check` suite yield an empty list (the latter
/// is driven by [`wire_configs`] instead). `quick` shrinks every axis
/// for the CI `experiment-smoke` tier without changing its shape.
pub fn scenarios_for(suite: &str, quick: bool) -> Vec<ScenarioSpec> {
    let mut out = Vec::new();
    let suite_name = suite_by_name(suite).map(|s| s.name).unwrap_or("");
    let spec = |profile: &'static str, scheme: Scheme, workers: usize, backend: &'static str| {
        ScenarioSpec {
            suite: suite_name,
            profile,
            scheme,
            workers,
            backend,
            engine: "lockstep",
            pipeline: "off",
        }
    };
    match suite {
        "rank-sweep" => {
            for &profile in &PROFILES {
                let ranks = sweep_ranks(profile);
                let ranks = if quick { &ranks[..2] } else { ranks };
                out.push(spec(profile, Scheme::Sgd, DEFAULT_WORKERS, "nccl"));
                for &r in ranks {
                    out.push(spec(profile, Scheme::PowerSgd { rank: r }, DEFAULT_WORKERS, "nccl"));
                }
            }
        }
        "scheme-compare" => {
            for &profile in &PROFILES {
                let schemes = if quick {
                    vec![Scheme::Sgd, Scheme::PowerSgd { rank: 2 }, Scheme::SignNorm]
                } else {
                    scheme_zoo()
                };
                for &scheme in &schemes {
                    out.push(spec(profile, scheme, DEFAULT_WORKERS, "nccl"));
                }
            }
        }
        "scaling" => {
            let workers: &[usize] = if quick { &[4, 16] } else { &SCALING_WORKERS };
            let backends: &[&'static str] = if quick { &["nccl"] } else { &["nccl", "gloo"] };
            for &profile in &PROFILES {
                let schemes = scaling_schemes();
                for &scheme in &schemes {
                    for &backend in backends {
                        for &w in workers {
                            out.push(spec(profile, scheme, w, backend));
                        }
                    }
                }
            }
        }
        "backend-compare" => {
            let schemes = backend_compare_schemes();
            let schemes: &[Scheme] = if quick { &schemes[..2] } else { &schemes };
            for &profile in &PROFILES {
                for &scheme in schemes {
                    for backend in ["nccl", "gloo"] {
                        // The pipeline axis: each point is priced both
                        // sequentially and with bucketed overlap, so
                        // the report can show what `--pipeline overlap`
                        // is predicted to hide on each backend.
                        for pipeline in ["off", "overlap"] {
                            let mut s = spec(profile, scheme, DEFAULT_WORKERS, backend);
                            s.pipeline = pipeline;
                            out.push(s);
                        }
                    }
                }
            }
        }
        _ => {}
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_resolvable() {
        for s in registry() {
            assert_eq!(suite_by_name(s.name), Some(*s));
        }
        let mut names: Vec<&str> = registry().iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), registry().len(), "duplicate suite names");
        assert!(suite_by_name("bogus").is_none());
    }

    #[test]
    fn analytic_suites_expand_and_quick_shrinks() {
        for s in registry() {
            let full = scenarios_for(s.name, false);
            let quick = scenarios_for(s.name, true);
            if s.name == "wire-check" {
                assert!(full.is_empty(), "wire-check is measured-only");
                assert_eq!(wire_configs(false).len(), 2);
                assert_eq!(wire_configs(true).len(), 1);
            } else {
                assert!(!full.is_empty(), "{} expanded to nothing", s.name);
                assert!(quick.len() < full.len(), "{}: quick must shrink", s.name);
                // Every profile appears in every analytic suite.
                for profile in PROFILES {
                    assert!(full.iter().any(|sp| sp.profile == profile), "{}/{profile}", s.name);
                }
            }
        }
    }

    #[test]
    fn scenario_ids_are_unique_within_a_suite() {
        for s in registry() {
            let ids: Vec<String> = scenarios_for(s.name, false).iter().map(|x| x.id()).collect();
            let mut sorted = ids.clone();
            sorted.sort();
            sorted.dedup();
            assert_eq!(sorted.len(), ids.len(), "{}: duplicate scenario ids", s.name);
        }
    }

    #[test]
    fn backend_axis_is_exercised() {
        let scaling = scenarios_for("scaling", false);
        assert!(scaling.iter().any(|s| s.backend == "gloo"));
        assert!(scaling.iter().any(|s| s.workers == 32));
    }

    #[test]
    fn backend_compare_carries_the_pipeline_axis() {
        for quick in [false, true] {
            let specs = scenarios_for("backend-compare", quick);
            assert!(specs.iter().any(|s| s.pipeline == "overlap"), "quick={quick}");
            assert!(specs.iter().any(|s| s.pipeline == "off"), "quick={quick}");
            // Overlap points suffix their ids; sequential ids are
            // unchanged from before the axis existed.
            let overlap = specs.iter().find(|s| s.pipeline == "overlap").unwrap();
            assert!(overlap.id().ends_with("/overlap"), "{}", overlap.id());
            let off = specs.iter().find(|s| s.pipeline == "off").unwrap();
            assert!(!off.id().contains("overlap"), "{}", off.id());
        }
        // Other suites stay on the sequential schedule.
        assert!(scenarios_for("scaling", false).iter().all(|s| s.pipeline == "off"));
    }
}
