//! Sparsification compressors (Appendix G.1, G.2, G.4): Random Block,
//! Random K and Top K, each budgeted at `(n+m)·r` values per matrix "to
//! match rank-r PowerSGD".

use super::{
    aggregate_vectors_uncompressed, sparsify_budget, split_kinds, Aggregated, Compressor, SchemeMeta, Locals,
};
use crate::collectives::{all_gather, all_reduce_mean, CommLog};
use crate::grad::{CompressKind, ParamRegistry};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Random Block compression (Algorithm 3): a contiguous slice of the
/// flattened matrix, start index shared across workers (same seed), so
/// the blocks align and aggregate with all-reduce. The slice wraps
/// around the end of the buffer so every coordinate has equal coverage
/// probability — without wraparound, edge coordinates are visited
/// O(b/nm) as often, their error-feedback memory accumulates for
/// hundreds of steps, and the eventual replay destabilizes training.
pub struct RandomBlock {
    rank_equiv: usize,
    rng: Rng,
}

impl RandomBlock {
    /// Budget matched to rank-`rank_equiv` PowerSGD (`(n+m)·r` values).
    pub fn new(rank_equiv: usize, seed: u64) -> RandomBlock {
        RandomBlock { rank_equiv, rng: Rng::new(seed) }
    }
}

impl SchemeMeta for RandomBlock {
    fn name(&self) -> String {
        format!("Random Block (r={})", self.rank_equiv)
    }

    fn supports_all_reduce(&self) -> bool {
        true
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        sparsified_bytes(registry, self.rank_equiv, 4)
    }
}

impl Compressor for RandomBlock {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let w = updates.len();
        let (mat_idx, vec_idx) = split_kinds(&updates[0]);
        let mut mean: Vec<Tensor> = updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
        aggregate_vectors_uncompressed(updates, &vec_idx, &mut mean, log);

        // Shared (cyclic) block positions per matrix.
        let blocks: Vec<(usize, usize)> = mat_idx
            .iter()
            .map(|&p| {
                let (n, m) = (updates[0][p].rows(), updates[0][p].cols());
                let numel = n * m;
                let b = sparsify_budget(n, m, self.rank_equiv);
                let s = if numel > b { self.rng.below(numel as u64) as usize } else { 0 };
                (s, b)
            })
            .collect();

        // Pack each worker's (wrapping) slices, all-reduce, scatter back.
        let mut buffers: Vec<Vec<f32>> = updates
            .iter()
            .map(|wu| {
                let mut buf = Vec::new();
                for (&p, &(s, b)) in mat_idx.iter().zip(blocks.iter()) {
                    let d = wu[p].data();
                    for k in 0..b {
                        buf.push(d[(s + k) % d.len()]);
                    }
                }
                buf
            })
            .collect();
        // Per-worker locals: own slice scattered into zeros.
        let locals: Vec<Vec<Tensor>> = (0..w)
            .map(|wi| {
                let mut lt: Vec<Tensor> =
                    updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
                for &p in &vec_idx {
                    // identity compression on vectors: zero error
                    lt[p] = updates[wi][p].clone();
                }
                let mut off = 0;
                for (&p, &(s, b)) in mat_idx.iter().zip(blocks.iter()) {
                    let d = lt[p].data_mut();
                    let len = d.len();
                    for k in 0..b {
                        d[(s + k) % len] = buffers[wi][off + k];
                    }
                    off += b;
                }
                lt
            })
            .collect();
        all_reduce_mean(&mut buffers, log);
        let mut off = 0;
        for (&p, &(s, b)) in mat_idx.iter().zip(blocks.iter()) {
            let d = mean[p].data_mut();
            let len = d.len();
            for k in 0..b {
                d[(s + k) % len] = buffers[0][off + k];
            }
            off += b;
        }
        Aggregated { mean, locals: Locals::PerWorker(locals) }
    }
}

/// Random K compression (Algorithm 4): `(n+m)·r` random coordinates,
/// sampled without replacement with a seed shared across workers
/// (all-reduce capable). The paper notes the random-access overhead makes
/// it slow on GPU despite the same byte budget.
pub struct RandomK {
    rank_equiv: usize,
    rng: Rng,
}

impl RandomK {
    /// Budget matched to rank-`rank_equiv` PowerSGD (`(n+m)·r` values).
    pub fn new(rank_equiv: usize, seed: u64) -> RandomK {
        RandomK { rank_equiv, rng: Rng::new(seed) }
    }
}

impl SchemeMeta for RandomK {
    fn name(&self) -> String {
        format!("Random K (r={})", self.rank_equiv)
    }

    fn supports_all_reduce(&self) -> bool {
        true
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        // values only: indices are derived from the shared seed
        sparsified_bytes(registry, self.rank_equiv, 4)
    }
}

impl Compressor for RandomK {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let w = updates.len();
        let (mat_idx, vec_idx) = split_kinds(&updates[0]);
        let mut mean: Vec<Tensor> = updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
        aggregate_vectors_uncompressed(updates, &vec_idx, &mut mean, log);

        let index_sets: Vec<Vec<usize>> = mat_idx
            .iter()
            .map(|&p| {
                let (n, m) = (updates[0][p].rows(), updates[0][p].cols());
                let k = sparsify_budget(n, m, self.rank_equiv);
                self.rng.sample_indices(n * m, k)
            })
            .collect();

        let mut buffers: Vec<Vec<f32>> = updates
            .iter()
            .map(|wu| {
                let mut buf = Vec::new();
                for (&p, idx) in mat_idx.iter().zip(index_sets.iter()) {
                    let d = wu[p].data();
                    buf.extend(idx.iter().map(|&i| d[i]));
                }
                buf
            })
            .collect();
        let locals: Vec<Vec<Tensor>> = (0..w)
            .map(|wi| {
                let mut lt: Vec<Tensor> =
                    updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
                for &p in &vec_idx {
                    lt[p] = updates[wi][p].clone();
                }
                let mut off = 0;
                for (&p, idx) in mat_idx.iter().zip(index_sets.iter()) {
                    let d = lt[p].data_mut();
                    for &i in idx {
                        d[i] = buffers[wi][off];
                        off += 1;
                    }
                }
                lt
            })
            .collect();
        all_reduce_mean(&mut buffers, log);
        let mut off = 0;
        for (&p, idx) in mat_idx.iter().zip(index_sets.iter()) {
            let d = mean[p].data_mut();
            for &i in idx {
                d[i] = buffers[0][off];
                off += 1;
            }
        }
        Aggregated { mean, locals: Locals::PerWorker(locals) }
    }
}

/// Top K compression (Algorithm 6): each worker's own largest-|value|
/// coordinates. Indices differ per worker, so aggregation needs
/// all-gather (values + indices transmitted), and decode cost scales
/// with W.
pub struct TopK {
    rank_equiv: usize,
}

impl TopK {
    /// Budget matched to rank-`rank_equiv` PowerSGD (`(n+m)·r` values).
    pub fn new(rank_equiv: usize) -> TopK {
        TopK { rank_equiv }
    }

    /// Indices of the k largest-magnitude entries (unordered). Shared
    /// with the per-worker [`crate::compress::TopKWorker`] path.
    pub(crate) fn top_indices(data: &[f32], k: usize) -> Vec<usize> {
        // Partial selection via binary-heap of (|v|, idx) — O(n log k).
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let mut heap: BinaryHeap<Reverse<(u32, usize)>> = BinaryHeap::with_capacity(k + 1);
        for (i, &v) in data.iter().enumerate() {
            // total order on f32 magnitude via bit tricks (all finite)
            let key = v.abs().to_bits();
            if heap.len() < k {
                heap.push(Reverse((key, i)));
            } else if let Some(&Reverse((min_key, _))) = heap.peek() {
                if key > min_key {
                    heap.pop();
                    heap.push(Reverse((key, i)));
                }
            }
        }
        heap.into_iter().map(|Reverse((_, i))| i).collect()
    }
}

impl SchemeMeta for TopK {
    fn name(&self) -> String {
        format!("Top K (r={})", self.rank_equiv)
    }

    fn supports_all_reduce(&self) -> bool {
        false
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        // values + indices, 4 bytes each
        sparsified_bytes(registry, self.rank_equiv, 8)
    }
}

impl Compressor for TopK {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let w = updates.len();
        let (mat_idx, vec_idx) = split_kinds(&updates[0]);
        let mut mean: Vec<Tensor> = updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
        aggregate_vectors_uncompressed(updates, &vec_idx, &mut mean, log);

        // Each worker builds (indices, values) messages; encode both as
        // f32 words in one buffer for the gather (index as bits).
        let messages: Vec<Vec<f32>> = updates
            .iter()
            .map(|wu| {
                let mut msg = Vec::new();
                for &p in &mat_idx {
                    let (n, m) = (wu[p].rows(), wu[p].cols());
                    let k = sparsify_budget(n, m, self.rank_equiv);
                    let idx = TopK::top_indices(wu[p].data(), k);
                    for &i in &idx {
                        msg.push(f32::from_bits(i as u32));
                        msg.push(wu[p].data()[i]);
                    }
                }
                msg
            })
            .collect();
        let gathered = all_gather(&messages, log);

        // Decode: every worker receives all W messages (we decode once and
        // share the result — identical on all workers).
        let received = &gathered[0];
        let mut locals: Vec<Vec<Tensor>> = (0..w)
            .map(|wi| {
                let mut lt: Vec<Tensor> =
                    updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
                for &p in &vec_idx {
                    lt[p] = updates[wi][p].clone();
                }
                lt
            })
            .collect();
        for (wi, msg) in received.iter().enumerate() {
            let mut cursor = 0;
            for &p in &mat_idx {
                let (n, m) = (updates[0][p].rows(), updates[0][p].cols());
                let k = sparsify_budget(n, m, self.rank_equiv);
                for _ in 0..k {
                    let i = msg[cursor].to_bits() as usize;
                    let v = msg[cursor + 1];
                    cursor += 2;
                    mean[p].data_mut()[i] += v / w as f32;
                    locals[wi][p].data_mut()[i] = v;
                }
            }
        }
        Aggregated { mean, locals: Locals::PerWorker(locals) }
    }
}

/// Shared byte formula: `budget × bytes_per_value` over matrices, plus
/// uncompressed vectors.
pub(crate) fn sparsified_bytes(
    registry: &ParamRegistry,
    rank_equiv: usize,
    bytes_per_value: u64,
) -> u64 {
    registry
        .specs
        .iter()
        .map(|s| match s.kind {
            CompressKind::Matrix { rows, cols } => {
                sparsify_budget(rows, cols, rank_equiv) as u64 * bytes_per_value
            }
            CompressKind::Vector { len } => (len * 4) as u64,
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_updates(w: usize, shape: &[usize], seed: u64) -> Vec<Vec<Tensor>> {
        let mut rng = Rng::new(seed);
        (0..w)
            .map(|_| {
                let mut t = Tensor::zeros(shape);
                rng.fill_normal(t.data_mut(), 1.0);
                vec![t]
            })
            .collect()
    }

    fn mean_of(updates: &[Vec<Tensor>]) -> Tensor {
        let mut m = Tensor::zeros(updates[0][0].shape());
        for wu in updates {
            m.axpy(1.0 / updates.len() as f32, &wu[0]);
        }
        m
    }

    #[test]
    fn random_block_preserves_block_mean_and_zeros_elsewhere() {
        let updates = rand_updates(3, &[8, 6], 91);
        let mut c = RandomBlock::new(1, 92);
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        let mean = mean_of(&updates);
        let out = &agg.mean[0];
        // Non-zero entries must match the true mean; count equals budget.
        let budget = sparsify_budget(8, 6, 1);
        let nz: Vec<usize> =
            (0..48).filter(|&i| out.data()[i] != 0.0).collect();
        assert!(nz.len() <= budget);
        // contiguity of the (possibly wrapping) block: the complement of
        // the nonzero set must also be contiguous modulo the length
        if nz.len() > 1 && nz.len() < 48 {
            let gaps = nz.windows(2).filter(|wd| wd[1] - wd[0] > 1).count();
            assert!(gaps <= 1, "block not cyclic-contiguous: {nz:?}");
        }
        for &i in &nz {
            assert!((out.data()[i] - mean.data()[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn random_k_hits_budget_and_matches_mean() {
        let updates = rand_updates(2, &[10, 5], 93);
        let mut c = RandomK::new(2, 94);
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        let mean = mean_of(&updates);
        let budget = sparsify_budget(10, 5, 2);
        let nz = agg.mean[0].data().iter().filter(|&&v| v != 0.0).count();
        assert!(nz <= budget && nz >= budget - 2, "nz={nz} budget={budget}");
        for i in 0..50 {
            let v = agg.mean[0].data()[i];
            if v != 0.0 {
                assert!((v - mean.data()[i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn top_k_selects_largest() {
        let mut t = Tensor::zeros(&[4, 4]);
        t.set(1, 2, 10.0);
        t.set(3, 3, -20.0);
        t.set(0, 0, 0.5);
        let idx = TopK::top_indices(t.data(), 2);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![6, 15]);
    }

    #[test]
    fn top_k_aggregate_is_mean_of_worker_selections() {
        let updates = rand_updates(2, &[6, 4], 95);
        let mut c = TopK::new(1);
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        // Every nonzero of the aggregate must be explainable as
        // (sum of selecting workers' values) / W.
        let w = 2.0f32;
        for i in 0..24 {
            let got = agg.mean[0].data()[i];
            if got == 0.0 {
                continue;
            }
            let mut expect = 0.0;
            if let Locals::PerWorker(ref locals) = agg.locals {
                for lw in locals {
                    expect += lw[0].data()[i];
                }
            }
            assert!((got - expect / w).abs() < 1e-5);
        }
    }

    #[test]
    fn top_k_needs_gather() {
        assert!(!TopK::new(1).supports_all_reduce());
        assert!(RandomK::new(1, 0).supports_all_reduce());
        assert!(RandomBlock::new(1, 0).supports_all_reduce());
    }

    #[test]
    fn ef_error_identity_holds_per_worker() {
        // update == local + (update - local): the error each worker keeps
        // is exactly what its compression dropped.
        let updates = rand_updates(3, &[5, 5], 96);
        let mut c = RandomK::new(1, 97);
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        if let Locals::PerWorker(ref locals) = agg.locals {
            for (wu, lw) in updates.iter().zip(locals.iter()) {
                let err = wu[0].sub(&lw[0]);
                let recon = err.add(&lw[0]);
                assert!(recon.allclose(&wu[0], 1e-6, 1e-6));
            }
        } else {
            panic!("expected per-worker locals");
        }
    }

    #[test]
    fn byte_accounting() {
        let reg = ParamRegistry::from_shapes(&[("w", vec![10, 5]), ("b", vec![3])]);
        let b = sparsify_budget(10, 5, 2) as u64;
        assert_eq!(RandomK::new(2, 0).message_bytes(&reg), b * 4 + 12);
        assert_eq!(TopK::new(2).message_bytes(&reg), b * 8 + 12);
        let updates = vec![
            vec![Tensor::zeros(&[10, 5]), Tensor::zeros(&[3])],
            vec![Tensor::zeros(&[10, 5]), Tensor::zeros(&[3])],
        ];
        let mut c = RandomK::new(2, 1);
        let mut log = CommLog::default();
        c.compress_aggregate(&updates, &mut log);
        assert_eq!(log.bytes_sent(), c.message_bytes(&reg));
        let mut c2 = TopK::new(2);
        let mut log2 = CommLog::default();
        c2.compress_aggregate(&updates, &mut log2);
        assert_eq!(log2.bytes_sent(), c2.message_bytes(&reg));
    }
}
