//! Adaptive-rank PowerSGD — an extension in the paper's future-work
//! direction (§6: compression quality vs cost trade-off varies by task;
//! Appendix D shows the transformer needs rank 32 where the LSTM needs
//! rank 4).
//!
//! After every step we know exactly what compression discarded: the
//! relative EF residual `‖Δ − P̂Qᵀ‖ / ‖Δ‖`. This controller keeps that
//! residual inside a target band by adjusting the rank between
//! `min_rank` and `max_rank`: grow when the gradient spectrum is too
//! rich for the current rank, shrink when compression is already nearly
//! lossless. Hysteresis + cooldown prevent oscillation. Warm-start `Q`
//! columns are preserved on grow (new columns re-seeded) and truncated
//! on shrink, so subspace tracking survives adaptation.

use super::{Aggregated, Compressor, SchemeMeta, Locals, PowerSgd};
use crate::collectives::CommLog;
use crate::grad::ParamRegistry;
use crate::tensor::Tensor;

/// PowerSGD with residual-controlled rank.
pub struct AdaptivePowerSgd {
    inner: PowerSgd,
    seed: u64,
    /// Smallest rank the controller may shrink to.
    pub min_rank: usize,
    /// Largest rank the controller may grow to.
    pub max_rank: usize,
    /// Grow when relative residual exceeds this.
    pub grow_threshold: f64,
    /// Shrink when relative residual falls below this.
    pub shrink_threshold: f64,
    /// Steps to wait between rank changes.
    pub cooldown: usize,
    since_change: usize,
    last_residual: f64,
    rank_history: Vec<usize>,
}

impl AdaptivePowerSgd {
    /// Controller starting at `initial_rank`, bounded to
    /// `[min_rank, max_rank]`.
    pub fn new(initial_rank: usize, min_rank: usize, max_rank: usize, seed: u64) -> Self {
        assert!(min_rank >= 1 && min_rank <= initial_rank && initial_rank <= max_rank);
        AdaptivePowerSgd {
            inner: PowerSgd::new(initial_rank, seed),
            seed,
            min_rank,
            max_rank,
            grow_threshold: 0.7,
            shrink_threshold: 0.3,
            cooldown: 10,
            since_change: 0,
            last_residual: 0.0,
            rank_history: Vec::new(),
        }
    }

    /// Current compression rank.
    pub fn rank(&self) -> usize {
        self.inner.rank()
    }

    /// Rank after every step so far (the adaptation trace).
    pub fn rank_history(&self) -> &[usize] {
        &self.rank_history
    }

    /// Most recent relative reconstruction residual.
    pub fn last_residual(&self) -> f64 {
        self.last_residual
    }

    fn maybe_adapt(&mut self, residual: f64) {
        self.since_change += 1;
        if self.since_change < self.cooldown {
            return;
        }
        let r = self.inner.rank();
        let new_rank = if residual > self.grow_threshold && r < self.max_rank {
            r * 2
        } else if residual < self.shrink_threshold && r > self.min_rank {
            r / 2
        } else {
            return;
        };
        let new_rank = new_rank.clamp(self.min_rank, self.max_rank);
        if new_rank != r {
            // Re-seed a fresh PowerSGD at the new rank. (Q columns are
            // re-initialized; the warm start re-converges within a few
            // steps — Theorem I — which the cooldown absorbs.)
            self.inner = PowerSgd::new(new_rank, self.seed ^ new_rank as u64);
            self.since_change = 0;
        }
    }
}

impl SchemeMeta for AdaptivePowerSgd {
    fn name(&self) -> String {
        format!("Adaptive Rank [{}..{}] (now {})", self.min_rank, self.max_rank, self.inner.rank())
    }

    fn supports_all_reduce(&self) -> bool {
        true
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        registry.total_rank_r_bytes_uncapped(self.inner.rank())
    }
}

impl Compressor for AdaptivePowerSgd {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let agg = self.inner.compress_aggregate(updates, log);
        // Relative residual of the aggregate reconstruction vs the true
        // mean update (matrix params only).
        let w = updates.len() as f32;
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (p, out) in agg.mean.iter().enumerate() {
            if out.shape().len() < 2 {
                continue;
            }
            let mut mean = Tensor::zeros(out.shape());
            for wu in updates {
                mean.axpy(1.0 / w, &wu[p]);
            }
            num += mean.sub(out).norm().powi(2);
            den += mean.norm().powi(2);
        }
        let residual = if den > 0.0 { (num / den).sqrt() } else { 0.0 };
        self.last_residual = residual;
        self.rank_history.push(self.inner.rank());
        self.maybe_adapt(residual);
        Aggregated { mean: agg.mean, locals: Locals::SharedAggregate }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_updates(shape: &[usize], rank_of_data: usize, rng: &mut Rng) -> Vec<Vec<Tensor>> {
        // construct a matrix of known rank
        let (n, m) = (shape[0], shape[1]);
        let mut acc = Tensor::zeros(&[n, m]);
        for _ in 0..rank_of_data {
            let mut u = Tensor::zeros(&[n, 1]);
            let mut v = Tensor::zeros(&[1, m]);
            rng.fill_normal(u.data_mut(), 1.0);
            rng.fill_normal(v.data_mut(), 1.0);
            acc.axpy(1.0, &crate::tensor::matmul(&u, &v));
        }
        vec![vec![acc]]
    }

    #[test]
    fn grows_rank_on_rich_spectrum() {
        let mut rng = Rng::new(71);
        let mut c = AdaptivePowerSgd::new(1, 1, 8, 5);
        c.cooldown = 3;
        // full-rank-ish data: rank-1 approximation leaves a big residual
        for _ in 0..30 {
            let updates = rand_updates(&[20, 16], 12, &mut rng);
            let mut log = CommLog::default();
            c.compress_aggregate(&updates, &mut log);
        }
        assert!(c.rank() > 1, "rank should have grown, history {:?}", c.rank_history());
    }

    #[test]
    fn shrinks_rank_on_low_rank_data() {
        let mut rng = Rng::new(72);
        let mut c = AdaptivePowerSgd::new(8, 1, 8, 6);
        c.cooldown = 3;
        // rank-1 data: rank-8 compression is lossless => shrink
        for _ in 0..40 {
            let updates = rand_updates(&[20, 16], 1, &mut rng);
            let mut log = CommLog::default();
            c.compress_aggregate(&updates, &mut log);
        }
        assert!(c.rank() < 8, "rank should have shrunk, history {:?}", c.rank_history());
        assert!(c.last_residual() < 0.3);
    }

    #[test]
    fn respects_bounds_and_cooldown() {
        let mut rng = Rng::new(73);
        let mut c = AdaptivePowerSgd::new(2, 2, 4, 7);
        c.cooldown = 5;
        for _ in 0..50 {
            let updates = rand_updates(&[12, 10], 10, &mut rng);
            let mut log = CommLog::default();
            c.compress_aggregate(&updates, &mut log);
        }
        for &r in c.rank_history() {
            assert!((2..=4).contains(&r));
        }
        // no two consecutive changes closer than cooldown
        let mut last_change = 0usize;
        let mut prev = c.rank_history()[0];
        for (i, &r) in c.rank_history().iter().enumerate().skip(1) {
            if r != prev {
                assert!(i - last_change >= 5, "change too soon at {i}");
                last_change = i;
                prev = r;
            }
        }
    }

    #[test]
    fn bytes_track_current_rank() {
        let reg = ParamRegistry::from_shapes(&[("w", vec![20, 16])]);
        let c = AdaptivePowerSgd::new(4, 1, 8, 9);
        assert_eq!(c.message_bytes(&reg), ((20 + 16) * 4 * 4) as u64);
    }
}
