//! Uncompressed baseline: plain all-reduce of the full gradient (the
//! paper's "SGD" / "No compression" rows).

use super::{Aggregated, Compressor, SchemeMeta, Locals};
use crate::collectives::CommLog;
use crate::grad::ParamRegistry;
use crate::tensor::Tensor;

/// Identity "compressor": full-precision all-reduce.
#[derive(Debug, Default)]
pub struct NoCompression;

impl NoCompression {
    /// The identity compressor.
    pub fn new() -> NoCompression {
        NoCompression
    }
}

impl SchemeMeta for NoCompression {
    fn name(&self) -> String {
        "No compression".into()
    }

    fn supports_all_reduce(&self) -> bool {
        true
    }

    fn is_biased(&self) -> bool {
        false
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        registry.total_bytes()
    }
}

impl Compressor for NoCompression {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let mean = super::all_reduce_mean_packed(updates, log);
        // Identity compression: each worker's local reconstruction is its
        // own update, so EF error stays exactly zero.
        let locals = Locals::PerWorker(updates.to_vec());
        Aggregated { mean, locals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::ParamRegistry;

    #[test]
    fn aggregates_to_exact_mean_with_zero_error() {
        let updates = vec![
            vec![Tensor::full(&[2, 2], 2.0), Tensor::full(&[3], 1.0)],
            vec![Tensor::full(&[2, 2], 4.0), Tensor::full(&[3], 3.0)],
        ];
        let mut c = NoCompression::new();
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        assert_eq!(agg.mean[0].data(), &[3.0; 4]);
        assert_eq!(agg.mean[1].data(), &[2.0; 3]);
        // local = own update -> error = update - local = 0
        let local0 = agg.local_for(0);
        assert_eq!(local0[0].data(), &[2.0; 4]);
        let reg = ParamRegistry::from_shapes(&[("w", vec![2, 2]), ("b", vec![3])]);
        assert_eq!(log.bytes_sent(), c.message_bytes(&reg));
        assert_eq!(c.message_bytes(&reg), 7 * 4);
    }
}
