//! Gradient compression operators (paper §3, §5.1 and Appendix G).
//!
//! Every operator implements [`Compressor`]: given each worker's update
//! tensors (already matricized by [`crate::grad::ParamRegistry`]), it
//! compresses, aggregates across workers with the collective its
//! linearity permits, and returns
//! - the decompressed **aggregate** update `Δ'` (identical on all
//!   workers, like a real collective), and
//! - the per-worker **local decompressions** `DECOMPRESS(C(Δ_w))` that
//!   error feedback subtracts (Algorithm 2, line 9).
//!
//! Linear compressors (PowerSGD, unbiased rank-r, Random Block, Random K,
//! no-compression) aggregate with all-reduce; sign- and top-K-based ones
//! require all-gather. The distinction drives both the byte accounting
//! and the simulated timing (Tables 4/5).
//!
//! Vector-shaped parameters (biases) are always sent uncompressed in a
//! single packed all-reduce, per §3 of the paper; their local
//! decompression is the identity, so they accumulate no error.
//!
//! Two execution paths (DESIGN.md §5):
//! - **Centralized oracle** — [`Compressor::compress_aggregate`] sees
//!   all workers' updates in one call and simulates the collectives
//!   inline; the reference semantics every test pins.
//! - **Decentralized per-worker** — [`WorkerCompressor`] instances run
//!   one per worker thread against a [`crate::transport::Transport`]
//!   endpoint, with reusable [`ScratchArena`] buffers;
//!   [`DecentralizedCompressor`] adapts a fleet of them back to the
//!   [`Compressor`] interface, bitwise-identical to the oracle.

mod adaptive;
mod atomo;
mod none;
mod powersgd;
mod scratch;
mod sign;
mod sparsify;
mod unbiased;
mod worker;

pub use adaptive::AdaptivePowerSgd;
pub use atomo::Atomo;
pub use none::NoCompression;
pub use powersgd::{BestRankR, PowerSgd};
pub use scratch::{ScratchArena, TensorPool};
pub use sign::{SignNorm, Signum};
pub use sparsify::{RandomBlock, RandomK, TopK};
pub use unbiased::UnbiasedRank;
pub use worker::{
    decentralized_by_name, oracle_by_name, worker_by_name, DecentralizedCompressor,
    EndpointCompressor, InFlightMean, NoCompressionWorker, PowerSgdWorker, SignNormWorker,
    TopKWorker, UnbiasedRankWorker, WorkerCompressor, WorkerLink, WorkerRound,
};

use crate::collectives::{all_reduce_mean, CommLog};
use crate::grad::ParamRegistry;
use crate::tensor::Tensor;

/// Per-worker local decompressions for error feedback.
#[derive(Debug, Clone)]
pub enum Locals {
    /// `DECOMPRESS(C(Δ_w))` equals the aggregate for every worker (the
    /// PowerSGD convention: errors are taken against the shared
    /// reconstruction — see epfml/powersgd `gradient_reducers.py`).
    SharedAggregate,
    /// Per-worker reconstructions (sign / top-K / sparsification).
    PerWorker(Vec<Vec<Tensor>>),
}

/// Result of one compress+aggregate round.
#[derive(Debug, Clone)]
pub struct Aggregated {
    /// Decompressed aggregate update `Δ'` (same on all workers).
    pub mean: Vec<Tensor>,
    /// What each worker's own compression reconstructed to (for EF).
    pub locals: Locals,
}

impl Aggregated {
    /// Local reconstruction for worker `w` (resolving `SharedAggregate`).
    pub fn local_for(&self, w: usize) -> &[Tensor] {
        match &self.locals {
            Locals::SharedAggregate => &self.mean,
            Locals::PerWorker(per) => &per[w],
        }
    }
}

/// Compression-scheme metadata shared by the centralized oracle
/// ([`Compressor`]) and the per-worker half ([`WorkerCompressor`]).
///
/// Both execution paths of one scheme must present identical metadata —
/// the name the report prints, the collective the aggregation uses, the
/// closed-form byte model the harness cross-checks, and the bias flag
/// error feedback keys on. Factoring it into one supertrait removes
/// the copy-paste surface that let the two paths drift (the
/// `Scheme::cli_spelling` round-trip regression).
pub trait SchemeMeta {
    /// Human-readable name ("Rank 2", "Sign+Norm", ...).
    fn name(&self) -> String;

    /// True iff the scheme is linear and can aggregate with all-reduce
    /// (the "All-reduce" column of Table 4).
    fn supports_all_reduce(&self) -> bool;

    /// Closed-form per-worker message size in bytes per step for the
    /// given model (must agree with what the scheme's round logs).
    fn message_bytes(&self, registry: &ParamRegistry) -> u64;

    /// Whether this operator is biased (needs error feedback to converge).
    fn is_biased(&self) -> bool {
        true
    }
}

/// A gradient compression + aggregation operator.
pub trait Compressor: SchemeMeta + Send {
    /// Compress every worker's update, aggregate, decompress.
    ///
    /// `updates[w][p]` is worker `w`'s update for parameter `p` in
    /// compression shape. All collective traffic must be recorded in
    /// `log`.
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated;

    /// Tensor allocations made by reusable scratch buffers so far —
    /// the decentralized per-worker path's [`ScratchArena`]s, or the
    /// centralized PowerSGD oracle's factor arena (`None` for oracles
    /// without reusable scratch). On a shape-stable workload the count
    /// must stop moving after step 1 — the zero-alloc regression hook.
    fn scratch_allocations(&self) -> Option<u64> {
        None
    }

    /// How many threads record a `Collective` span for one logical
    /// collective: 1 for centralized oracles (the calling thread times
    /// it), W for the decentralized driver (every worker thread times
    /// the same collective, so summed span seconds are W × wall time).
    /// `Trainer::train_step` divides by this to recover per-worker
    /// wall time in its step-time split.
    fn collective_span_threads(&self) -> usize {
        1
    }

    /// Elastic membership changed (DESIGN.md §16): the run entered
    /// `epoch` with `new_world` workers. Implementations drop any
    /// state keyed to the old world size (per-worker scratch sizing,
    /// staleness) and keep world-independent state (PowerSGD's
    /// warm-start `Q` factors are shared across workers, so the
    /// departed rank's copy was identical to every survivor's and
    /// nothing is lost). Default: no world-sized state, no-op.
    fn on_reconfigure(&mut self, _epoch: u64, _new_world: usize) {}
}

/// Indices of matrix-kind (compressed) and vector-kind (uncompressed)
/// parameters in an update list.
pub(crate) fn split_kinds(updates: &[Tensor]) -> (Vec<usize>, Vec<usize>) {
    let mut mats = Vec::new();
    let mut vecs = Vec::new();
    for (i, t) in updates.iter().enumerate() {
        if t.shape().len() >= 2 {
            mats.push(i);
        } else {
            vecs.push(i);
        }
    }
    (mats, vecs)
}

/// All-reduce-mean the vector-shaped parameters uncompressed, writing
/// the mean into `mean_out` and leaving per-worker error at zero
/// (identity compression). Packs all vectors into one flat buffer, like
/// the paper's flat-buffer optimization (Appendix H).
pub(crate) fn aggregate_vectors_uncompressed(
    updates: &[Vec<Tensor>],
    vec_idx: &[usize],
    mean_out: &mut [Tensor],
    log: &mut CommLog,
) {
    if vec_idx.is_empty() {
        return;
    }
    let total: usize = vec_idx.iter().map(|&i| updates[0][i].len()).sum();
    let mut buffers: Vec<Vec<f32>> = updates
        .iter()
        .map(|wu| {
            let mut buf = Vec::with_capacity(total);
            for &i in vec_idx {
                buf.extend_from_slice(wu[i].data());
            }
            buf
        })
        .collect();
    all_reduce_mean(&mut buffers, log);
    let mut off = 0;
    for &i in vec_idx {
        let n = updates[0][i].len();
        mean_out[i] = Tensor::from_vec(&[n], buffers[0][off..off + n].to_vec());
        off += n;
    }
}

/// Pack each worker's per-parameter tensors into one flat per-worker
/// buffer, all-reduce-mean across workers, and unpack the shared mean
/// back into tensors shaped like the first worker's list.
pub(crate) fn all_reduce_mean_packed(
    per_worker: &[Vec<Tensor>],
    log: &mut CommLog,
) -> Vec<Tensor> {
    let total: usize = per_worker[0].iter().map(|t| t.len()).sum();
    let mut buffers: Vec<Vec<f32>> = per_worker
        .iter()
        .map(|ts| {
            let mut buf = Vec::with_capacity(total);
            for t in ts {
                buf.extend_from_slice(t.data());
            }
            buf
        })
        .collect();
    all_reduce_mean(&mut buffers, log);
    let mut out = Vec::with_capacity(per_worker[0].len());
    let mut off = 0;
    for t in &per_worker[0] {
        let n = t.len();
        out.push(Tensor::from_vec(t.shape(), buffers[0][off..off + n].to_vec()));
        off += n;
    }
    out
}

/// Paper's sparsification budget: `(n + m) · r` values for an `n×m`
/// matrix "to match rank-r PowerSGD" (Appendix G), capped at the matrix
/// size.
pub(crate) fn sparsify_budget(n: usize, m: usize, rank_equiv: usize) -> usize {
    ((n + m) * rank_equiv).min(n * m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_kinds_separates() {
        let ts = vec![
            Tensor::zeros(&[3, 4]),
            Tensor::zeros(&[5]),
            Tensor::zeros(&[2, 2]),
        ];
        let (m, v) = split_kinds(&ts);
        assert_eq!(m, vec![0, 2]);
        assert_eq!(v, vec![1]);
    }

    #[test]
    fn vectors_aggregate_to_mean() {
        let updates = vec![
            vec![Tensor::zeros(&[2, 2]), Tensor::from_vec(&[3], vec![1., 2., 3.])],
            vec![Tensor::zeros(&[2, 2]), Tensor::from_vec(&[3], vec![3., 2., 1.])],
        ];
        let mut mean = vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[3])];
        let mut log = CommLog::default();
        aggregate_vectors_uncompressed(&updates, &[1], &mut mean, &mut log);
        assert_eq!(mean[1].data(), &[2., 2., 2.]);
        assert_eq!(log.bytes_sent(), 12);
    }

    #[test]
    fn packed_allreduce_roundtrips_shapes() {
        let per_worker = vec![
            vec![Tensor::full(&[2, 2], 1.0), Tensor::full(&[3], 0.0)],
            vec![Tensor::full(&[2, 2], 3.0), Tensor::full(&[3], 2.0)],
        ];
        let mut log = CommLog::default();
        let mean = all_reduce_mean_packed(&per_worker, &mut log);
        assert_eq!(mean[0].shape(), &[2, 2]);
        assert_eq!(mean[0].data(), &[2.0; 4]);
        assert_eq!(mean[1].data(), &[1.0; 3]);
        assert_eq!(log.bytes_sent(), 7 * 4);
    }

    #[test]
    fn budget_matches_paper_and_caps() {
        assert_eq!(sparsify_budget(512, 4608, 2), (512 + 4608) * 2);
        assert_eq!(sparsify_budget(2, 2, 10), 4); // capped at n*m
    }
}
