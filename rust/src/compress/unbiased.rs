//! Unbiased low-rank compression (§4.1): the natural unbiased counterpart
//! of PowerSGD against which Table 1 compares.
//!
//! Sample a shared random `U ∈ R^{m×r}` with `E[U·Uᵀ] = I_m` (i.i.d.
//! `N(0, 1/r)` entries) and transmit `M·U`; decompress `(M·U)·Uᵀ`. The
//! scheme is linear (all-reduce capable) and unbiased, so the paper runs
//! it *without* error feedback — which is exactly why it loses badly
//! (71.2% vs 93.6% test accuracy at rank 1).

use super::{aggregate_vectors_uncompressed, all_reduce_mean_packed, split_kinds, Aggregated, Compressor, SchemeMeta, Locals};
use crate::collectives::CommLog;
use crate::grad::{CompressKind, ParamRegistry};
use crate::tensor::{matmul_into, matmul_nt_into, Tensor};
use crate::util::Rng;

/// Unbiased rank-r sketching compressor.
pub struct UnbiasedRank {
    rank: usize,
    /// Shared across workers: all workers draw the same `U` each step
    /// (same seed), so only `M·U` needs transmission.
    rng: Rng,
}

impl UnbiasedRank {
    /// Unbiased rank-`rank` sketching with shared-seed `U` draws.
    pub fn new(rank: usize, seed: u64) -> UnbiasedRank {
        assert!(rank >= 1);
        UnbiasedRank { rank, rng: Rng::new(seed) }
    }
}

impl SchemeMeta for UnbiasedRank {
    fn name(&self) -> String {
        format!("Unbiased Rank {}", self.rank)
    }

    fn supports_all_reduce(&self) -> bool {
        true
    }

    fn is_biased(&self) -> bool {
        false
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        // Only M·U is transmitted (U is derived from the shared seed):
        // n·r·4 per matrix — the reason Table 1 reports 3 MB for unbiased
        // rank 1 vs 4 MB for PowerSGD rank 1.
        registry
            .specs
            .iter()
            .map(|s| match s.kind {
                CompressKind::Matrix { rows, .. } => (rows * self.rank * 4) as u64,
                CompressKind::Vector { len } => (len * 4) as u64,
            })
            .sum()
    }
}

impl Compressor for UnbiasedRank {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let (mat_idx, vec_idx) = split_kinds(&updates[0]);
        let mut mean: Vec<Tensor> = updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
        aggregate_vectors_uncompressed(updates, &vec_idx, &mut mean, log);

        // Shared sketching matrices, E[U Uᵀ] = I  =>  entries N(0, 1/r).
        let sigma = (1.0 / self.rank as f64).sqrt() as f32;
        let us: Vec<Tensor> = mat_idx
            .iter()
            .map(|&p| {
                let mut u = Tensor::zeros(&[updates[0][p].cols(), self.rank]);
                self.rng.fill_normal(u.data_mut(), sigma);
                u
            })
            .collect();

        let per_worker_p: Vec<Vec<Tensor>> = updates
            .iter()
            .map(|wu| {
                mat_idx
                    .iter()
                    .zip(us.iter())
                    .map(|(&p, u)| {
                        let mut out = Tensor::zeros(&[wu[p].rows(), self.rank]);
                        matmul_into(&wu[p], u, &mut out);
                        out
                    })
                    .collect()
            })
            .collect();
        let p_mean = all_reduce_mean_packed(&per_worker_p, log);

        for (&p, (pm, u)) in mat_idx.iter().zip(p_mean.iter().zip(us.iter())) {
            let mut rec = Tensor::zeros(&[pm.rows(), u.rows()]);
            matmul_nt_into(pm, u, &mut rec);
            mean[p] = rec;
        }
        Aggregated { mean, locals: Locals::SharedAggregate }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_updates(w: usize, shape: &[usize], seed: u64) -> Vec<Vec<Tensor>> {
        let mut rng = Rng::new(seed);
        (0..w)
            .map(|_| {
                let mut t = Tensor::zeros(shape);
                rng.fill_normal(t.data_mut(), 1.0);
                vec![t]
            })
            .collect()
    }

    #[test]
    fn unbiased_in_expectation() {
        // Averaging the reconstruction over many independent draws of U
        // must converge to M itself.
        let updates = rand_updates(1, &[6, 5], 81);
        let m = &updates[0][0];
        let mut c = UnbiasedRank::new(2, 82);
        let mut log = CommLog::default();
        let trials = 3000;
        let mut acc = Tensor::zeros(&[6, 5]);
        for _ in 0..trials {
            let rec = c.compress_aggregate(&updates, &mut log).mean[0].clone();
            acc.axpy(1.0 / trials as f32, &rec);
        }
        let rel = acc.sub(m).norm() / m.norm();
        assert!(rel < 0.12, "bias too large: rel err {rel}");
    }

    #[test]
    fn linear_and_variance_larger_than_powersgd_error() {
        // Single draw: reconstruction error should be sizable (this is the
        // point of Table 1 — the unbiased scheme is high-variance).
        let updates = rand_updates(4, &[12, 10], 83);
        let mut c = UnbiasedRank::new(1, 84);
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        assert!(matches!(agg.locals, Locals::SharedAggregate));
        let mut mean = Tensor::zeros(&[12, 10]);
        for wu in &updates {
            mean.axpy(0.25, &wu[0]);
        }
        assert!(mean.sub(&agg.mean[0]).norm() > 0.1 * mean.norm());
    }

    #[test]
    fn message_bytes_counts_only_p() {
        let reg = ParamRegistry::from_shapes(&[("w", vec![12, 10]), ("b", vec![4])]);
        let c = UnbiasedRank::new(2, 1);
        assert_eq!(c.message_bytes(&reg), (12 * 2 * 4 + 4 * 4) as u64);
    }
}
