//! Decentralized compression: one compressor instance **per worker**,
//! aggregating over the transport engine.
//!
//! The centralized [`Compressor`] trait is an oracle: it receives every
//! worker's update in one call and simulates the collectives inline.
//! The paper's actual execution structure (§3, Lemma 3) is the inverse —
//! each worker compresses *its own* gradient and the small `P`/`Q`
//! factors (or packed messages) are aggregated with a real collective.
//! [`WorkerCompressor`] is that per-worker half: `compress → collective
//! over a [`Transport`] endpoint → decompress`, with all reusable
//! buffers in a per-worker [`ScratchArena`].
//!
//! [`DecentralizedCompressor`] adapts a fleet of per-worker instances
//! back to the [`Compressor`] interface: every call spawns one OS
//! thread per worker, wires them into an [`InProcRing`], and runs each
//! worker's round concurrently. Because the threaded ring reproduces
//! the lockstep reference bitwise (see [`crate::transport::ring`]) and
//! every shared random draw is replicated from the same seed, the
//! decentralized path matches the centralized oracle **bitwise** — the
//! oracle stays the reference, asserted by
//! `tests/integration_decentralized.rs`.
//!
//! Worker state (warm-start `Q`, scratch arenas) persists across steps;
//! changing the worker count between calls re-initializes it, like
//! re-building a process group.
//!
//! # Worked example
//!
//! Run one decentralized rank-2 PowerSGD round (one compressor instance
//! per worker, aggregating over an in-process ring) and check it
//! against the centralized oracle — the bitwise-equivalence contract:
//!
//! ```
//! use powersgd::collectives::CommLog;
//! use powersgd::compress::{decentralized_by_name, oracle_by_name, Compressor};
//! use powersgd::tensor::Tensor;
//!
//! // Two workers' updates: a 4×3 matrix parameter and a bias vector.
//! let updates: Vec<Vec<Tensor>> = (0..2)
//!     .map(|w| {
//!         let data: Vec<f32> = (0..12).map(|i| ((w * 12 + i) as f32).sin()).collect();
//!         vec![Tensor::from_vec(&[4, 3], data), Tensor::full(&[2], 0.5)]
//!     })
//!     .collect();
//! let mut fleet = decentralized_by_name("powersgd", 2, 7).unwrap();
//! let mut oracle = oracle_by_name("powersgd", 2, 7).unwrap();
//! let (mut dlog, mut olog) = (CommLog::default(), CommLog::default());
//! let dec = fleet.compress_aggregate(&updates, &mut dlog);
//! let ora = oracle.compress_aggregate(&updates, &mut olog);
//! for (a, b) in dec.mean.iter().zip(ora.mean.iter()) {
//!     assert_eq!(a.data(), b.data()); // identical bits, not just close
//! }
//! assert_eq!(dlog.bytes_sent(), olog.bytes_sent());
//! ```

use super::scratch::ScratchArena;
use super::sign::pack_signs_into;
use super::sparsify::{sparsified_bytes, TopK};
use super::{
    split_kinds, sparsify_budget, Aggregated, Compressor, Locals, NoCompression, PowerSgd,
    SchemeMeta, SignNorm, UnbiasedRank,
};
use crate::collectives::{CollKind, CommLog};
use crate::grad::{CompressKind, ParamRegistry};
use crate::linalg::gram_schmidt_in_place;
use crate::tensor::{matmul_into, matmul_nt_into, matmul_tn_into, Tensor};
use crate::transport::{
    ring_all_gather_worker, ring_all_reduce_worker, InProcRing, PipelineMode, PostedAllReduce,
    Transport,
};
use crate::util::Rng;

/// Record the relative low-rank approximation error
/// `‖M − rec‖_F / ‖M‖_F` into the metrics registry (gauge + value
/// histogram). Read-only telemetry: it runs only when metrics mode is
/// on and never touches the tensors, so metrics-on trajectories stay
/// bitwise identical to metrics-off ones.
pub(crate) fn record_approx_error(target: &Tensor, rec: &Tensor) {
    use crate::obs::metrics::{self, Gauge, Histogram};
    if !metrics::on() {
        return;
    }
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in target.data().iter().zip(rec.data().iter()) {
        let d = f64::from(*a) - f64::from(*b);
        num += d * d;
        den += f64::from(*a) * f64::from(*a);
    }
    let err = if den > 0.0 { (num / den).sqrt() } else { 0.0 };
    metrics::set_gauge(Gauge::ApproxError, err);
    metrics::observe(Histogram::ApproxError, err);
}

/// One worker's handle on the collective fabric: a typed [`Transport`]
/// endpoint per message kind, plus mean/gather conveniences that do the
/// byte accounting exactly like the centralized [`crate::collectives`].
pub struct WorkerLink<'a> {
    /// f32 ring endpoint (all-reduce chunks, top-K gather messages).
    pub f32s: &'a dyn Transport<Vec<f32>>,
    /// Byte ring endpoint (packed sign bitmaps).
    pub bytes: &'a dyn Transport<Vec<u8>>,
}

impl WorkerLink<'_> {
    /// This worker's rank in the ring.
    pub fn rank(&self) -> usize {
        self.f32s.rank()
    }

    /// Number of workers in the ring.
    pub fn world(&self) -> usize {
        self.f32s.world()
    }

    /// All-reduce-mean `buf` in place across the ring. Chunk schedule
    /// and divide order are exactly the centralized
    /// [`crate::collectives::all_reduce_mean`], so results are bitwise
    /// identical to the lockstep oracle.
    pub fn all_reduce_mean(&self, buf: &mut [f32], log: &mut CommLog) {
        let _span = crate::obs::span(crate::obs::Phase::Collective);
        let bytes = (buf.len() * 4) as u64;
        ring_all_reduce_worker(self.f32s, buf);
        let w = self.world() as f32;
        for v in buf.iter_mut() {
            *v /= w;
        }
        log.record(CollKind::AllReduce, bytes);
    }

    /// All-gather this worker's byte message; the returned view is
    /// indexed by source rank (identical on every worker).
    pub fn all_gather_bytes(&self, msg: Vec<u8>, log: &mut CommLog) -> Vec<Vec<u8>> {
        let _span = crate::obs::span(crate::obs::Phase::Collective);
        log.record(CollKind::AllGather, msg.len() as u64);
        ring_all_gather_worker(self.bytes, msg)
    }

    /// All-gather this worker's f32 message (top-K index/value pairs).
    pub fn all_gather_f32(&self, msg: Vec<f32>, log: &mut CommLog) -> Vec<Vec<f32>> {
        let _span = crate::obs::span(crate::obs::Phase::Collective);
        log.record(CollKind::AllGather, (msg.len() * 4) as u64);
        ring_all_gather_worker(self.f32s, msg)
    }
}

impl<'a> WorkerLink<'a> {
    /// Post a packed all-reduce-mean and return the in-flight handle —
    /// the pipelined counterpart of [`Self::all_reduce_mean`]. Traffic
    /// is logged here, at the post, which is the program point the
    /// blocking path occupies, so lockstep and overlap rounds produce
    /// identical [`CommLog`]s. Phase attribution differs by design:
    /// the posted window lands in `in_flight` (plus the per-wait
    /// `ring_recv` the transport records) instead of `collective`.
    pub fn post_reduce_mean(&self, buf: Vec<f32>, log: &mut CommLog) -> InFlightMean<'a> {
        log.record(CollKind::AllReduce, (buf.len() * 4) as u64);
        InFlightMean { inner: PostedAllReduce::start(self.f32s, buf), world: self.world() }
    }
}

/// A packed all-reduce-mean in flight, from [`WorkerLink::post_reduce_mean`].
///
/// Must be drained with [`finish`](InFlightMean::finish) before the
/// round ends — abandoning it mid-collective desynchronizes the ring
/// for every later operation on the link.
pub struct InFlightMean<'a> {
    inner: PostedAllReduce<'a, dyn Transport<Vec<f32>> + 'a>,
    world: usize,
}

impl InFlightMean<'_> {
    /// Drain the remaining ring steps and return the mean buffer —
    /// bit-for-bit what the blocking [`WorkerLink::all_reduce_mean`]
    /// leaves in place (identical chunk schedule and fold order, then
    /// the same elementwise divide).
    pub fn finish(self) -> Vec<f32> {
        let mut buf = self.inner.finish();
        let w = self.world as f32;
        for v in buf.iter_mut() {
            *v /= w;
        }
        buf
    }
}

/// Result of one per-worker compress → collective → decompress round.
pub struct WorkerRound {
    /// Decompressed aggregate `Δ'` — identical bits on every worker.
    pub mean: Vec<Tensor>,
    /// This worker's own reconstruction for error feedback; `None`
    /// means it equals the aggregate (the PowerSGD convention).
    pub local: Option<Vec<Tensor>>,
}

/// The per-worker half of a compression scheme.
///
/// Instances hold one worker's state (warm-start `Q`, shared-seed RNG)
/// and run one round per step against a [`WorkerLink`]. Shared
/// randomness is replicated: every worker is constructed with the same
/// seed and draws the same sequence, so `Q`/`U` agree across workers
/// without extra traffic — exactly the centralized oracle's convention.
pub trait WorkerCompressor: SchemeMeta + Send {
    /// One round: compress `update` (this worker's tensors in
    /// compression shape), aggregate over `link`, decompress. All
    /// step-invariant intermediates live in `scratch`; traffic goes to
    /// `log`.
    fn round(
        &mut self,
        update: &[Tensor],
        link: &WorkerLink<'_>,
        scratch: &mut ScratchArena,
        log: &mut CommLog,
    ) -> WorkerRound;

    /// Choose how [`round`](Self::round) schedules its collectives.
    /// The default ignores the mode: schemes with a single collective
    /// per round have nothing to overlap, and `Off` is always correct.
    /// Schemes that do overlap must keep the result bitwise identical
    /// to `Off` (the delayed trajectory lives in the optimizer, not
    /// here).
    fn set_pipeline(&mut self, _mode: PipelineMode) {}

    /// Elastic membership changed (DESIGN.md §16): the ring entered
    /// `epoch` with `new_world` workers. Per-worker state that is
    /// *shared by construction* (warm-start `Q`, the shared-seed RNG
    /// stream) survives — every member held identical bits, so the
    /// departed rank's copy is not lost — while anything sized or
    /// keyed to the old world must be dropped. Default: no such
    /// state, no-op.
    fn on_reconfigure(&mut self, _epoch: u64, _new_world: usize) {}
}

/// Pack tensors into one flat buffer (reusing its capacity).
fn pack(buf: &mut Vec<f32>, tensors: &[Tensor]) {
    buf.clear();
    for t in tensors {
        buf.extend_from_slice(t.data());
    }
}

/// Unpack a flat buffer back into same-shaped tensors.
fn unpack(buf: &[f32], tensors: &mut [Tensor]) {
    let mut off = 0;
    for t in tensors.iter_mut() {
        let n = t.len();
        t.data_mut().copy_from_slice(&buf[off..off + n]);
        off += n;
    }
}

/// All-reduce-mean the vector-shaped parameters uncompressed (one
/// packed flat buffer, like the centralized
/// `aggregate_vectors_uncompressed`), writing the mean tensors into
/// `mean`. No traffic when there are no vector parameters.
fn reduce_vectors(
    update: &[Tensor],
    vec_idx: &[usize],
    mean: &mut [Tensor],
    buf: &mut Vec<f32>,
    link: &WorkerLink<'_>,
    log: &mut CommLog,
) {
    if vec_idx.is_empty() {
        return;
    }
    buf.clear();
    for &i in vec_idx {
        buf.extend_from_slice(update[i].data());
    }
    link.all_reduce_mean(buf, log);
    let mut off = 0;
    for &i in vec_idx {
        let n = update[i].len();
        mean[i] = Tensor::from_vec(&[n], buf[off..off + n].to_vec());
        off += n;
    }
}

/// Placeholder mean list: empty tensors for matrix slots (overwritten
/// by the reconstruction), zeros for vector slots (overwritten by
/// [`reduce_vectors`]).
fn mean_placeholders(update: &[Tensor]) -> Vec<Tensor> {
    update
        .iter()
        .map(|t| {
            if t.shape().len() >= 2 {
                Tensor::zeros(&[0])
            } else {
                Tensor::zeros(t.shape())
            }
        })
        .collect()
}

/// Sign bit `i` of a packed bitmap as ±1.0 (the `unpack_signs` mapping).
#[inline]
fn sign_at(bits: &[u8], i: usize) -> f32 {
    if bits[i / 8] >> (i % 8) & 1 == 1 {
        1.0
    } else {
        -1.0
    }
}

// ---------------------------------------------------------------------
// PowerSGD (Algorithm 1), per-worker half.
// ---------------------------------------------------------------------

/// Rank-r PowerSGD, one worker's side: `P ← M·Q` → all-reduce-mean →
/// orthogonalize → `Q ← Mᵀ·P̂` → all-reduce-mean → reconstruct `P̂·Qᵀ`.
/// Warm-start `Q` persists in this instance; both GEMM outputs and the
/// packed collective buffers live in the [`ScratchArena`].
pub struct PowerSgdWorker {
    rank: usize,
    warm_start: bool,
    pipeline: PipelineMode,
    /// Warm-start `Q` per matrix slot (same bits on every worker).
    qs: Vec<Tensor>,
    rng: Rng,
}

impl PowerSgdWorker {
    /// One worker's rank-`rank` PowerSGD half, warm start on.
    pub fn new(rank: usize, seed: u64) -> PowerSgdWorker {
        assert!(rank >= 1, "rank must be >= 1");
        PowerSgdWorker {
            rank,
            warm_start: true,
            pipeline: PipelineMode::Off,
            qs: Vec::new(),
            rng: Rng::new(seed),
        }
    }

    /// Disable warm start (Table 2 ablation): re-sample `Q` every step.
    pub fn without_warm_start(mut self) -> PowerSgdWorker {
        self.warm_start = false;
        self
    }

    /// Ensure the `Q` for `slot` exists, drawing from the shared-seed
    /// RNG in slot order — the exact draw order of the centralized
    /// oracle's `ensure_q`, so the bits agree.
    fn ensure_q(&mut self, slot: usize, m: usize) {
        let fresh = if self.qs.len() <= slot {
            self.qs.push(Tensor::zeros(&[m, self.rank]));
            true
        } else {
            !self.warm_start
        };
        if fresh {
            let q = &mut self.qs[slot];
            if q.shape() != [m, self.rank] {
                *q = Tensor::zeros(&[m, self.rank]);
            }
            self.rng.fill_normal(q.data_mut(), 1.0);
        }
    }

    /// The overlap-mode round: same arithmetic as the lockstep path in
    /// [`WorkerCompressor::round`], different traffic schedule. The
    /// uncompressed vector reduction is posted before the first GEMM
    /// and drained only after `Q`'s reduction is posted, so its ring
    /// steps ride under both matmuls and the orthogonalization; `P`'s
    /// reduction still blocks (Gram–Schmidt needs its result). Every
    /// collective reuses the lockstep chunk schedule and fold order,
    /// so the round is bitwise identical to `Off` — asserted by
    /// `tests/integration_pipeline.rs`. Post order (vectors, P, Q) is
    /// a static schedule, identical on every worker, which is what the
    /// positional receive matching of the completion-queue transports
    /// requires.
    fn round_overlapped(
        &mut self,
        update: &[Tensor],
        link: &WorkerLink<'_>,
        scratch: &mut ScratchArena,
        log: &mut CommLog,
    ) -> WorkerRound {
        let (mat_idx, vec_idx) = split_kinds(update);
        let mut mean = mean_placeholders(update);
        let k = mat_idx.len();

        // Post (don't drain) the vector reduction at the program point
        // where the lockstep path runs it to completion.
        let vecs = if vec_idx.is_empty() {
            None
        } else {
            let mut vbuf = std::mem::take(&mut scratch.vbuf);
            vbuf.clear();
            for &i in &vec_idx {
                vbuf.extend_from_slice(update[i].data());
            }
            Some(link.post_reduce_mean(vbuf, log))
        };

        for (slot, &p) in mat_idx.iter().enumerate() {
            self.ensure_q(slot, update[p].cols());
        }

        // Stage 1: P = M·Q. Its reduction gates Gram–Schmidt, so it is
        // drained in place; the in-flight vector reduce overlaps it.
        {
            let _c = crate::obs::span(crate::obs::Phase::Compress);
            for (slot, &p) in mat_idx.iter().enumerate() {
                let out = scratch.p.get(slot, &[update[p].rows(), self.rank]);
                matmul_into(&update[p], &self.qs[slot], out);
            }
            pack(&mut scratch.buf, scratch.p.first(k));
        }
        link.all_reduce_mean(&mut scratch.buf, log);

        // Stage 2: Q = Mᵀ·P̂, posted before the vector drain so the
        // schedule stays static.
        {
            let _c = crate::obs::span(crate::obs::Phase::Compress);
            unpack(&scratch.buf, scratch.p.first_mut(k));
            for phat in scratch.p.first_mut(k) {
                gram_schmidt_in_place(phat);
            }
            for (slot, &p) in mat_idx.iter().enumerate() {
                let out = scratch.q.get(slot, &[update[p].cols(), self.rank]);
                matmul_tn_into(&update[p], scratch.p.at(slot), out);
            }
            pack(&mut scratch.buf, scratch.q.first(k));
        }
        let q_reduce = link.post_reduce_mean(std::mem::take(&mut scratch.buf), log);

        if let Some(in_flight) = vecs {
            let vbuf = in_flight.finish();
            let mut off = 0;
            for &i in &vec_idx {
                let n = update[i].len();
                mean[i] = Tensor::from_vec(&[n], vbuf[off..off + n].to_vec());
                off += n;
            }
            scratch.vbuf = vbuf;
        }
        let qbuf = q_reduce.finish();

        let _d = crate::obs::span(crate::obs::Phase::Decompress);
        unpack(&qbuf, scratch.q.first_mut(k));
        scratch.buf = qbuf;
        for (slot, &p) in mat_idx.iter().enumerate() {
            let mut rec = Tensor::zeros(&[update[p].rows(), update[p].cols()]);
            matmul_nt_into(scratch.p.at(slot), scratch.q.at(slot), &mut rec);
            record_approx_error(&update[p], &rec);
            mean[p] = rec;
            if self.warm_start {
                self.qs[slot].data_mut().copy_from_slice(scratch.q.at(slot).data());
            }
        }
        WorkerRound { mean, local: None }
    }
}

impl SchemeMeta for PowerSgdWorker {
    fn name(&self) -> String {
        if self.warm_start {
            format!("Rank {}", self.rank)
        } else {
            format!("Rank {} (no warm start)", self.rank)
        }
    }

    fn supports_all_reduce(&self) -> bool {
        true
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        registry.total_rank_r_bytes_uncapped(self.rank)
    }
}

impl WorkerCompressor for PowerSgdWorker {
    fn round(
        &mut self,
        update: &[Tensor],
        link: &WorkerLink<'_>,
        scratch: &mut ScratchArena,
        log: &mut CommLog,
    ) -> WorkerRound {
        // Delayed mode overlaps at the round level too — the one-step
        // delay itself lives in the optimizer, not here.
        if self.pipeline != PipelineMode::Off {
            return self.round_overlapped(update, link, scratch, log);
        }
        let (mat_idx, vec_idx) = split_kinds(update);
        let mut mean = mean_placeholders(update);
        reduce_vectors(update, &vec_idx, &mut mean, &mut scratch.buf, link, log);
        let k = mat_idx.len();

        // Cold start re-samples every Q up front, in slot order, so the
        // RNG stream matches the centralized oracle step for step.
        for (slot, &p) in mat_idx.iter().enumerate() {
            self.ensure_q(slot, update[p].cols());
        }

        // Stage 1: P = M·Q into the arena, packed all-reduce-mean; the
        // reduced buffer unpacks back into the same slots, which then
        // hold the shared mean and are orthogonalized in place.
        {
            let _c = crate::obs::span(crate::obs::Phase::Compress);
            for (slot, &p) in mat_idx.iter().enumerate() {
                let out = scratch.p.get(slot, &[update[p].rows(), self.rank]);
                matmul_into(&update[p], &self.qs[slot], out);
            }
            pack(&mut scratch.buf, scratch.p.first(k));
        }
        link.all_reduce_mean(&mut scratch.buf, log);

        // Stage 2: Q = Mᵀ·P̂, packed all-reduce-mean, same slot reuse.
        {
            let _c = crate::obs::span(crate::obs::Phase::Compress);
            unpack(&scratch.buf, scratch.p.first_mut(k));
            for phat in scratch.p.first_mut(k) {
                gram_schmidt_in_place(phat);
            }
            for (slot, &p) in mat_idx.iter().enumerate() {
                let out = scratch.q.get(slot, &[update[p].cols(), self.rank]);
                matmul_tn_into(&update[p], scratch.p.at(slot), out);
            }
            pack(&mut scratch.buf, scratch.q.first(k));
        }
        link.all_reduce_mean(&mut scratch.buf, log);

        // Reconstruct P̂·Qᵀ directly into the returned aggregate (the
        // API hands ownership out, so this is the one per-step tensor
        // allocation left on the hot path) and persist warm-start Q.
        let _d = crate::obs::span(crate::obs::Phase::Decompress);
        unpack(&scratch.buf, scratch.q.first_mut(k));
        for (slot, &p) in mat_idx.iter().enumerate() {
            let mut rec = Tensor::zeros(&[update[p].rows(), update[p].cols()]);
            matmul_nt_into(scratch.p.at(slot), scratch.q.at(slot), &mut rec);
            record_approx_error(&update[p], &rec);
            mean[p] = rec;
            if self.warm_start {
                self.qs[slot].data_mut().copy_from_slice(scratch.q.at(slot).data());
            }
        }
        WorkerRound { mean, local: None }
    }

    fn set_pipeline(&mut self, mode: PipelineMode) {
        self.pipeline = mode;
    }

    /// Warm-start `Q` is per-parameter-slot and identical on every
    /// member (it is the all-reduced mean each step), so a membership
    /// change keeps it: survivors and the oracle continue from the
    /// same factors, and the departed rank's copy was redundant. Only
    /// the collective *denominator* changes, and that is read live
    /// from the transport each round.
    fn on_reconfigure(&mut self, _epoch: u64, _new_world: usize) {}
}

// ---------------------------------------------------------------------
// Unbiased rank-r sketching (§4.1), per-worker half.
// ---------------------------------------------------------------------

/// Unbiased rank-r: every worker draws the same `U ~ N(0, 1/r)` from
/// the shared seed, transmits `M·U` (packed all-reduce-mean) and
/// reconstructs `(M·U)·Uᵀ`.
pub struct UnbiasedRankWorker {
    rank: usize,
    rng: Rng,
}

impl UnbiasedRankWorker {
    /// One worker's unbiased rank-`rank` half.
    pub fn new(rank: usize, seed: u64) -> UnbiasedRankWorker {
        assert!(rank >= 1);
        UnbiasedRankWorker { rank, rng: Rng::new(seed) }
    }
}

impl SchemeMeta for UnbiasedRankWorker {
    fn name(&self) -> String {
        format!("Unbiased Rank {}", self.rank)
    }

    fn supports_all_reduce(&self) -> bool {
        true
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        registry
            .specs
            .iter()
            .map(|s| match s.kind {
                CompressKind::Matrix { rows, .. } => (rows * self.rank * 4) as u64,
                CompressKind::Vector { len } => (len * 4) as u64,
            })
            .sum()
    }

    fn is_biased(&self) -> bool {
        false
    }
}

impl WorkerCompressor for UnbiasedRankWorker {
    fn round(
        &mut self,
        update: &[Tensor],
        link: &WorkerLink<'_>,
        scratch: &mut ScratchArena,
        log: &mut CommLog,
    ) -> WorkerRound {
        let (mat_idx, vec_idx) = split_kinds(update);
        let mut mean = mean_placeholders(update);
        reduce_vectors(update, &vec_idx, &mut mean, &mut scratch.buf, link, log);
        let k = mat_idx.len();

        // Shared sketching matrices: same seed on every worker, drawn
        // in matrix order — E[U·Uᵀ] = I via N(0, 1/r) entries.
        {
            let _c = crate::obs::span(crate::obs::Phase::Compress);
            let sigma = (1.0 / self.rank as f64).sqrt() as f32;
            for (slot, &p) in mat_idx.iter().enumerate() {
                let u = scratch.q.get(slot, &[update[p].cols(), self.rank]);
                self.rng.fill_normal(u.data_mut(), sigma);
            }
            for (slot, &p) in mat_idx.iter().enumerate() {
                let out = scratch.p.get(slot, &[update[p].rows(), self.rank]);
                matmul_into(&update[p], scratch.q.at(slot), out);
            }
            pack(&mut scratch.buf, scratch.p.first(k));
        }
        link.all_reduce_mean(&mut scratch.buf, log);

        let _d = crate::obs::span(crate::obs::Phase::Decompress);
        unpack(&scratch.buf, scratch.p.first_mut(k));
        for (slot, &p) in mat_idx.iter().enumerate() {
            let mut rec = Tensor::zeros(&[update[p].rows(), update[p].cols()]);
            matmul_nt_into(scratch.p.at(slot), scratch.q.at(slot), &mut rec);
            mean[p] = rec;
        }
        WorkerRound { mean, local: None }
    }
}

// ---------------------------------------------------------------------
// Sign + L1 norm (Algorithm 5), per-worker half (all-gather path).
// ---------------------------------------------------------------------

/// Sign+Norm: transmit `(‖M‖₁/nm, sign(M))` packed to one bit per
/// coordinate, all-gather, decode all `W` messages into the average.
#[derive(Default)]
pub struct SignNormWorker;

impl SignNormWorker {
    /// One worker's sign+norm half.
    pub fn new() -> SignNormWorker {
        SignNormWorker
    }
}

impl SchemeMeta for SignNormWorker {
    fn name(&self) -> String {
        "Sign+Norm".into()
    }

    fn supports_all_reduce(&self) -> bool {
        false
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        registry
            .specs
            .iter()
            .map(|s| match s.kind {
                CompressKind::Matrix { rows, cols } => 4 + ((rows * cols).div_ceil(8)) as u64,
                CompressKind::Vector { len } => (len * 4) as u64,
            })
            .sum()
    }
}

impl WorkerCompressor for SignNormWorker {
    fn round(
        &mut self,
        update: &[Tensor],
        link: &WorkerLink<'_>,
        scratch: &mut ScratchArena,
        log: &mut CommLog,
    ) -> WorkerRound {
        let (mat_idx, vec_idx) = split_kinds(update);
        let w = link.world() as f32;
        // Gather path: the aggregate is accumulated, so matrix means
        // start at zero; vectors still travel uncompressed first.
        let mut mean: Vec<Tensor> = update.iter().map(|t| Tensor::zeros(t.shape())).collect();
        reduce_vectors(update, &vec_idx, &mut mean, &mut scratch.buf, link, log);

        // Own message: per matrix, 4-byte scale then packed sign bits.
        {
            let _c = crate::obs::span(crate::obs::Phase::Compress);
            scratch.bytes.clear();
            for &p in &mat_idx {
                let nm = update[p].len() as f64;
                let scale = (update[p].norm_l1() / nm) as f32;
                scratch.bytes.extend_from_slice(&scale.to_le_bytes());
                pack_signs_into(update[p].data(), &mut scratch.bytes);
            }
        }
        // Hand the scratch buffer itself to the gather (it lands in the
        // view at our own rank) and reclaim it below — no per-step copy.
        let mut gathered = link.all_gather_bytes(std::mem::take(&mut scratch.bytes), log);

        // Decode every worker's message in rank order — the same
        // accumulation order as the centralized oracle, so the mean
        // agrees bitwise. Only our own message feeds the EF local.
        let _d = crate::obs::span(crate::obs::Phase::Decompress);
        let me = link.rank();
        let mut local: Vec<Tensor> = update.iter().map(|t| Tensor::zeros(t.shape())).collect();
        for &p in &vec_idx {
            local[p] = update[p].clone();
        }
        for (wi, msg) in gathered.iter().enumerate() {
            let mut cursor = 0;
            for &p in &mat_idx {
                let n = update[p].len();
                let scale = f32::from_le_bytes(msg[cursor..cursor + 4].try_into().unwrap());
                cursor += 4;
                let bits = &msg[cursor..cursor + n.div_ceil(8)];
                cursor += n.div_ceil(8);
                let md = mean[p].data_mut();
                for i in 0..n {
                    md[i] += scale * sign_at(bits, i) / w;
                }
                if wi == me {
                    let ld = local[p].data_mut();
                    for i in 0..n {
                        ld[i] = scale * sign_at(bits, i);
                    }
                }
            }
        }
        scratch.bytes = std::mem::take(&mut gathered[me]);
        WorkerRound { mean, local: Some(local) }
    }
}

// ---------------------------------------------------------------------
// Top-K (Algorithm 6), per-worker half (all-gather path).
// ---------------------------------------------------------------------

/// Top-K: each worker gathers its own `(index, value)` pairs for the
/// `(n+m)·r` largest-magnitude coordinates; decode scatters all `W`
/// messages (the cost that scales with W in Table 5).
pub struct TopKWorker {
    rank_equiv: usize,
}

impl TopKWorker {
    /// One worker's top-K half, budget matched to rank `rank_equiv`.
    pub fn new(rank_equiv: usize) -> TopKWorker {
        TopKWorker { rank_equiv }
    }
}

impl SchemeMeta for TopKWorker {
    fn name(&self) -> String {
        format!("Top K (r={})", self.rank_equiv)
    }

    fn supports_all_reduce(&self) -> bool {
        false
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        sparsified_bytes(registry, self.rank_equiv, 8)
    }
}

impl WorkerCompressor for TopKWorker {
    fn round(
        &mut self,
        update: &[Tensor],
        link: &WorkerLink<'_>,
        scratch: &mut ScratchArena,
        log: &mut CommLog,
    ) -> WorkerRound {
        let (mat_idx, vec_idx) = split_kinds(update);
        let w = link.world() as f32;
        let mut mean: Vec<Tensor> = update.iter().map(|t| Tensor::zeros(t.shape())).collect();
        reduce_vectors(update, &vec_idx, &mut mean, &mut scratch.buf, link, log);

        // Own message: (index bits, value) pairs, f32-encoded.
        {
            let _c = crate::obs::span(crate::obs::Phase::Compress);
            scratch.buf.clear();
            for &p in &mat_idx {
                let (n, m) = (update[p].rows(), update[p].cols());
                let budget = sparsify_budget(n, m, self.rank_equiv);
                let idx = TopK::top_indices(update[p].data(), budget);
                let d = update[p].data();
                for &i in &idx {
                    scratch.buf.push(f32::from_bits(i as u32));
                    scratch.buf.push(d[i]);
                }
            }
        }
        // As in the sign path: move the scratch buffer into the gather
        // and reclaim it from our own slot of the view afterwards.
        let mut gathered = link.all_gather_f32(std::mem::take(&mut scratch.buf), log);

        let _d = crate::obs::span(crate::obs::Phase::Decompress);
        let me = link.rank();
        let mut local: Vec<Tensor> = update.iter().map(|t| Tensor::zeros(t.shape())).collect();
        for &p in &vec_idx {
            local[p] = update[p].clone();
        }
        for (wi, msg) in gathered.iter().enumerate() {
            let mut cursor = 0;
            for &p in &mat_idx {
                let (n, m) = (update[p].rows(), update[p].cols());
                let budget = sparsify_budget(n, m, self.rank_equiv);
                let md = mean[p].data_mut();
                for _ in 0..budget {
                    let i = msg[cursor].to_bits() as usize;
                    let v = msg[cursor + 1];
                    cursor += 2;
                    md[i] += v / w;
                    if wi == me {
                        local[p].data_mut()[i] = v;
                    }
                }
            }
        }
        scratch.buf = std::mem::take(&mut gathered[me]);
        WorkerRound { mean, local: Some(local) }
    }
}

// ---------------------------------------------------------------------
// No compression, per-worker half.
// ---------------------------------------------------------------------

/// Identity "compression": one packed full-gradient all-reduce-mean.
/// The EF local is the worker's own update (zero error).
#[derive(Default)]
pub struct NoCompressionWorker;

impl NoCompressionWorker {
    /// One worker's identity half.
    pub fn new() -> NoCompressionWorker {
        NoCompressionWorker
    }
}

impl SchemeMeta for NoCompressionWorker {
    fn name(&self) -> String {
        "No compression".into()
    }

    fn supports_all_reduce(&self) -> bool {
        true
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        registry.total_bytes()
    }

    fn is_biased(&self) -> bool {
        false
    }
}

impl WorkerCompressor for NoCompressionWorker {
    fn round(
        &mut self,
        update: &[Tensor],
        link: &WorkerLink<'_>,
        scratch: &mut ScratchArena,
        log: &mut CommLog,
    ) -> WorkerRound {
        {
            let _c = crate::obs::span(crate::obs::Phase::Compress);
            pack(&mut scratch.buf, update);
        }
        link.all_reduce_mean(&mut scratch.buf, log);
        let _d = crate::obs::span(crate::obs::Phase::Decompress);
        let mut mean = Vec::with_capacity(update.len());
        let mut off = 0;
        for t in update {
            let n = t.len();
            mean.push(Tensor::from_vec(t.shape(), scratch.buf[off..off + n].to_vec()));
            off += n;
        }
        WorkerRound { mean, local: Some(update.to_vec()) }
    }
}

// ---------------------------------------------------------------------
// Driver: per-worker fleet behind the centralized Compressor interface.
// ---------------------------------------------------------------------

type BoxedWorker = Box<dyn WorkerCompressor>;
type WorkerFactory = Box<dyn Fn() -> BoxedWorker + Send>;

struct WorkerSlot {
    comp: BoxedWorker,
    scratch: ScratchArena,
}

/// Runs one [`WorkerCompressor`] instance per worker, each on its own
/// OS thread with its own [`ScratchArena`], aggregating over an
/// [`InProcRing`]. Drop-in [`Compressor`], bitwise-identical to the
/// centralized oracle for the schemes implemented here.
pub struct DecentralizedCompressor {
    workers: Vec<WorkerSlot>,
    factory: WorkerFactory,
    /// Prototype instance for name/byte metadata before the first round.
    proto: BoxedWorker,
    pipeline: PipelineMode,
}

impl DecentralizedCompressor {
    /// Build from a per-worker factory. The factory must produce
    /// identically-seeded instances so shared random draws (warm-start
    /// `Q`, sketching `U`) agree across workers.
    pub fn new<F>(factory: F) -> DecentralizedCompressor
    where
        F: Fn() -> BoxedWorker + Send + 'static,
    {
        let proto = factory();
        DecentralizedCompressor {
            workers: Vec::new(),
            factory: Box::new(factory),
            proto,
            pipeline: PipelineMode::Off,
        }
    }

    /// Set the collective scheduling mode for every worker in the
    /// fleet, existing and future. Overlap keeps each round bitwise
    /// identical, so the fleet stays a drop-in [`Compressor`].
    pub fn with_pipeline(mut self, mode: PipelineMode) -> DecentralizedCompressor {
        self.pipeline = mode;
        for slot in &mut self.workers {
            slot.comp.set_pipeline(mode);
        }
        self
    }

    fn ensure_workers(&mut self, w: usize) {
        if self.workers.len() != w {
            self.workers = (0..w)
                .map(|_| {
                    let mut comp = (self.factory)();
                    comp.set_pipeline(self.pipeline);
                    WorkerSlot { comp, scratch: ScratchArena::new() }
                })
                .collect();
        }
    }

    /// Total [`ScratchArena`] tensor allocations across all workers —
    /// the zero-alloc regression hook: on a shape-stable workload this
    /// must not change after the first step. Kernel-side scratch (the
    /// blocked kernels' packed panels and tiles) is tracked separately
    /// by [`kernel_scratch_grows`](crate::runtime::pool::kernel_scratch_grows)
    /// and must go flat at the same point.
    pub fn scratch_allocations(&self) -> u64 {
        self.workers.iter().map(|s| s.scratch.allocations()).sum()
    }
}

impl SchemeMeta for DecentralizedCompressor {
    fn name(&self) -> String {
        format!("{} (per-worker)", self.proto.name())
    }

    fn supports_all_reduce(&self) -> bool {
        self.proto.supports_all_reduce()
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        self.proto.message_bytes(registry)
    }

    fn is_biased(&self) -> bool {
        self.proto.is_biased()
    }
}

impl Compressor for DecentralizedCompressor {
    fn scratch_allocations(&self) -> Option<u64> {
        Some(DecentralizedCompressor::scratch_allocations(self))
    }

    fn collective_span_threads(&self) -> usize {
        // One Collective span per worker thread. The slots exist after
        // the first round (the trainer reads this after `step`); before
        // that the centralized default of 1 is harmless.
        self.workers.len().max(1)
    }

    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let w = updates.len();
        assert!(w > 0, "decentralized compressor needs at least one worker");
        self.ensure_workers(w);
        let f32_nodes = InProcRing::endpoints::<Vec<f32>>(w);
        let byte_nodes = InProcRing::endpoints::<Vec<u8>>(w);
        let mut results: Vec<(WorkerRound, CommLog)> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .workers
                .iter_mut()
                .zip(updates.iter())
                .zip(f32_nodes.into_iter().zip(byte_nodes))
                .map(|((slot, update), (fnode, bnode))| {
                    scope.spawn(move || {
                        let link = WorkerLink { f32s: &fnode, bytes: &bnode };
                        // One trace track per rank: the fleet re-spawns
                        // these threads every step, and rank-keyed
                        // tracks keep each worker on one timeline.
                        crate::obs::set_track(&format!("worker-{}", link.rank()));
                        let mut wlog = CommLog::default();
                        crate::obs::metrics::add(crate::obs::metrics::Counter::CompressRounds, 1);
                        let round = slot.comp.round(update, &link, &mut slot.scratch, &mut wlog);
                        (round, wlog)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("worker compressor thread panicked"))
                .collect()
        });
        // Every worker holds the identical aggregate; adopt worker 0's
        // view of the result and of the per-worker traffic (the
        // CommLog unit is bytes sent *per worker*).
        let (first, wlog) = results.remove(0);
        log.ops.extend(wlog.ops);
        let locals = match first.local {
            None => Locals::SharedAggregate,
            Some(own) => {
                let mut per = Vec::with_capacity(w);
                per.push(own);
                for (round, _) in results {
                    per.push(round.local.expect("workers disagree on locals kind"));
                }
                Locals::PerWorker(per)
            }
        };
        Aggregated { mean: first.mean, locals }
    }
}

/// One per-worker [`WorkerCompressor`] instance for a CLI compressor
/// name; `None` when the scheme has no decentralized path. This is the
/// single name→scheme mapping shared by the threaded fleet
/// ([`decentralized_by_name`]) and the multi-process TCP harness (one
/// instance per OS process).
pub fn worker_by_name(name: &str, rank: usize, seed: u64) -> Option<Box<dyn WorkerCompressor>> {
    Some(match name {
        "powersgd" => Box::new(PowerSgdWorker::new(rank, seed)),
        "powersgd-cold" => Box::new(PowerSgdWorker::new(rank, seed).without_warm_start()),
        "unbiased-rank" => Box::new(UnbiasedRankWorker::new(rank, seed)),
        "sign-norm" => Box::new(SignNormWorker::new()),
        "top-k" => Box::new(TopKWorker::new(rank)),
        "none" | "sgd" | "identity" => Box::new(NoCompressionWorker::new()),
        _ => return None,
    })
}

/// The centralized oracle for the same CLI names [`worker_by_name`]
/// covers — the reference a decentralized run (threaded or TCP) is
/// checked against. Kept next to the per-worker mapping so the two
/// cannot drift.
pub fn oracle_by_name(name: &str, rank: usize, seed: u64) -> Option<Box<dyn Compressor>> {
    Some(match name {
        "powersgd" => Box::new(PowerSgd::new(rank, seed)),
        "powersgd-cold" => Box::new(PowerSgd::new(rank, seed).without_warm_start()),
        "unbiased-rank" => Box::new(UnbiasedRank::new(rank, seed)),
        "sign-norm" => Box::new(SignNorm::new()),
        "top-k" => Box::new(TopK::new(rank)),
        "none" | "sgd" | "identity" => Box::new(NoCompression::new()),
        _ => return None,
    })
}

/// Per-worker fleet for a CLI compressor name; `None` when the scheme
/// has no decentralized path yet (callers fall back to the centralized
/// oracle).
pub fn decentralized_by_name(
    name: &str,
    rank: usize,
    seed: u64,
) -> Option<DecentralizedCompressor> {
    // Probe once so unknown names return None instead of a factory
    // that fails later.
    worker_by_name(name, rank, seed)?;
    let name = name.to_string();
    let factory: WorkerFactory = Box::new(move || {
        worker_by_name(&name, rank, seed).expect("probed at construction")
    });
    Some(DecentralizedCompressor::new(factory))
}

// ---------------------------------------------------------------------
// Endpoint adapter: one worker process behind the Compressor interface.
// ---------------------------------------------------------------------

/// One worker's [`Compressor`] view over a live transport endpoint.
///
/// [`DecentralizedCompressor`] adapts a *fleet* of per-worker instances
/// (it owns every worker and wires an [`InProcRing`] per call); this
/// adapter is the multi-process counterpart: the process holds exactly
/// **one** worker's state and one connected endpoint (e.g. a
/// `transport::tcp::TcpRing`, usually metered), and `compress_aggregate`
/// receives only this worker's update. The collective inside
/// [`WorkerCompressor::round`] reaches the other processes through the
/// endpoint, so the returned aggregate is still the cross-worker mean —
/// which is exactly what lets an unmodified [`crate::optim::EfSgd`]
/// drive a distributed run: its per-worker error feedback state is this
/// process's own, and the momentum update sees the shared aggregate.
pub struct EndpointCompressor<E> {
    endpoint: E,
    comp: Box<dyn WorkerCompressor>,
    scratch: ScratchArena,
}

impl<E> EndpointCompressor<E>
where
    E: Transport<Vec<f32>> + Transport<Vec<u8>>,
{
    /// Wrap a connected endpoint and one worker's compressor half.
    pub fn new(endpoint: E, comp: Box<dyn WorkerCompressor>) -> EndpointCompressor<E> {
        EndpointCompressor { endpoint, comp, scratch: ScratchArena::new() }
    }

    /// The wrapped endpoint (e.g. to read metered byte counters).
    pub fn endpoint(&self) -> &E {
        &self.endpoint
    }

    /// Set the collective scheduling mode for the wrapped worker.
    pub fn with_pipeline(mut self, mode: PipelineMode) -> EndpointCompressor<E> {
        self.comp.set_pipeline(mode);
        self
    }
}

impl<E> SchemeMeta for EndpointCompressor<E>
where
    E: Transport<Vec<f32>> + Transport<Vec<u8>>,
{
    fn name(&self) -> String {
        format!("{} (endpoint)", self.comp.name())
    }

    fn supports_all_reduce(&self) -> bool {
        self.comp.supports_all_reduce()
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        self.comp.message_bytes(registry)
    }

    fn is_biased(&self) -> bool {
        self.comp.is_biased()
    }
}

impl<E> Compressor for EndpointCompressor<E>
where
    E: Transport<Vec<f32>> + Transport<Vec<u8>>,
{
    fn scratch_allocations(&self) -> Option<u64> {
        Some(self.scratch.allocations())
    }

    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        assert_eq!(
            updates.len(),
            1,
            "an endpoint compressor holds exactly this process's worker; \
             other workers' updates live in other processes"
        );
        let link = WorkerLink { f32s: &self.endpoint, bytes: &self.endpoint };
        crate::obs::metrics::add(crate::obs::metrics::Counter::CompressRounds, 1);
        let round = self.comp.round(&updates[0], &link, &mut self.scratch, log);
        Aggregated {
            mean: round.mean,
            locals: match round.local {
                None => Locals::SharedAggregate,
                Some(own) => Locals::PerWorker(vec![own]),
            },
        }
    }

    /// Drop the scratch arena (its packed-collective buffers are
    /// re-sized lazily on the next round) and forward the epoch change
    /// to the wrapped worker compressor.
    fn on_reconfigure(&mut self, epoch: u64, new_world: usize) {
        self.scratch = ScratchArena::new();
        self.comp.on_reconfigure(epoch, new_world);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_mapping_covers_worker_schemes() {
        for name in ["powersgd", "powersgd-cold", "unbiased-rank", "sign-norm", "top-k", "none"] {
            let c = decentralized_by_name(name, 2, 1).unwrap_or_else(|| panic!("{name}"));
            assert!(c.name().ends_with("(per-worker)"), "{}", c.name());
        }
        assert!(decentralized_by_name("atomo", 2, 1).is_none());
        assert!(decentralized_by_name("random-k", 2, 1).is_none());
    }

    #[test]
    fn aggregation_kind_matches_scheme() {
        assert!(decentralized_by_name("powersgd", 1, 0).unwrap().supports_all_reduce());
        assert!(!decentralized_by_name("sign-norm", 1, 0).unwrap().supports_all_reduce());
        assert!(!decentralized_by_name("top-k", 1, 0).unwrap().supports_all_reduce());
    }

    #[test]
    fn single_worker_round_is_mean_of_itself() {
        let mut c = decentralized_by_name("none", 1, 0).unwrap();
        let updates = vec![vec![Tensor::full(&[2, 3], 2.5), Tensor::full(&[4], -1.0)]];
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        assert_eq!(agg.mean[0].data(), updates[0][0].data());
        assert_eq!(agg.mean[1].data(), updates[0][1].data());
        assert_eq!(log.bytes_sent(), (6 + 4) * 4);
    }

    #[test]
    fn worker_and_oracle_mappings_stay_in_sync() {
        let reg = ParamRegistry::from_shapes(&[("w", vec![16, 10]), ("b", vec![5])]);
        for name in ["powersgd", "powersgd-cold", "unbiased-rank", "sign-norm", "top-k", "none"] {
            let worker = worker_by_name(name, 2, 1).unwrap_or_else(|| panic!("{name}"));
            let oracle = oracle_by_name(name, 2, 1).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(worker.supports_all_reduce(), oracle.supports_all_reduce(), "{name}");
            assert_eq!(worker.is_biased(), oracle.is_biased(), "{name}");
            assert_eq!(worker.message_bytes(&reg), oracle.message_bytes(&reg), "{name}");
        }
        assert!(worker_by_name("atomo", 2, 1).is_none());
        assert!(oracle_by_name("atomo", 2, 1).is_none());
        assert!(worker_by_name("random-k", 2, 1).is_none());
        assert!(oracle_by_name("random-k", 2, 1).is_none());
    }

    /// The endpoint adapter, one instance per "process" (thread here)
    /// over a dual-typed [`crate::transport::InProcDuplex`] endpoint,
    /// must reproduce the centralized oracle bitwise — aggregate,
    /// per-worker locals, and logged traffic.
    #[test]
    fn endpoint_compressor_matches_oracle_bitwise() {
        use crate::transport::InProcDuplex;
        use crate::util::Rng;
        let world = 2;
        let shapes: [&[usize]; 3] = [&[6, 4], &[3], &[5, 5]];
        let mut rng = Rng::new(9);
        for name in ["powersgd", "sign-norm", "top-k", "none"] {
            let updates: Vec<Vec<Tensor>> = (0..world)
                .map(|_| {
                    shapes
                        .iter()
                        .map(|s| {
                            let mut t = Tensor::zeros(s);
                            rng.fill_normal(t.data_mut(), 1.0);
                            t
                        })
                        .collect()
                })
                .collect();
            let mut oracle = oracle_by_name(name, 2, 5).unwrap();
            let mut olog = CommLog::default();
            let want = oracle.compress_aggregate(&updates, &mut olog);

            let endpoints = InProcDuplex::endpoints(world);
            let results: Vec<(Aggregated, CommLog)> = std::thread::scope(|scope| {
                let handles: Vec<_> = endpoints
                    .into_iter()
                    .zip(updates.iter())
                    .map(|(endpoint, up)| {
                        scope.spawn(move || {
                            let mut comp = EndpointCompressor::new(
                                endpoint,
                                worker_by_name(name, 2, 5).unwrap(),
                            );
                            let mut log = CommLog::default();
                            let agg =
                                comp.compress_aggregate(std::slice::from_ref(up), &mut log);
                            (agg, log)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (wi, (agg, log)) in results.iter().enumerate() {
                assert_eq!(log.bytes_sent(), olog.bytes_sent(), "{name}: bytes");
                for (p, (a, b)) in agg.mean.iter().zip(want.mean.iter()).enumerate() {
                    assert_eq!(a.data(), b.data(), "{name}: mean[{p}] (worker {wi})");
                }
                for (p, (a, b)) in
                    agg.local_for(0).iter().zip(want.local_for(wi).iter()).enumerate()
                {
                    assert_eq!(a.data(), b.data(), "{name}: local[{p}] (worker {wi})");
                }
            }
        }
    }

    /// Overlap mode reorders traffic, never arithmetic: a fleet running
    /// `--pipeline overlap` must reproduce the lockstep fleet bit for
    /// bit across warm-started steps (matrix + vector params, so the
    /// posted vector reduce really is in flight across both GEMMs).
    #[test]
    fn overlap_fleet_matches_lockstep_bitwise() {
        use crate::util::Rng;
        let world = 3;
        let mut lock = decentralized_by_name("powersgd", 2, 9).unwrap();
        let mut ovl =
            decentralized_by_name("powersgd", 2, 9).unwrap().with_pipeline(PipelineMode::Overlap);
        let mut rng = Rng::new(77);
        for step in 0..4 {
            let updates: Vec<Vec<Tensor>> = (0..world)
                .map(|_| {
                    [&[7, 5][..], &[4][..], &[6, 6][..]]
                        .iter()
                        .map(|s| {
                            let mut t = Tensor::zeros(s);
                            rng.fill_normal(t.data_mut(), 1.0);
                            t
                        })
                        .collect()
                })
                .collect();
            let (mut llog, mut olog) = (CommLog::default(), CommLog::default());
            let want = lock.compress_aggregate(&updates, &mut llog);
            let got = ovl.compress_aggregate(&updates, &mut olog);
            assert_eq!(llog.bytes_sent(), olog.bytes_sent(), "step {step}: logged bytes");
            for (p, (a, b)) in got.mean.iter().zip(want.mean.iter()).enumerate() {
                assert_eq!(a.data(), b.data(), "step {step}: mean[{p}]");
            }
        }
    }

    #[test]
    fn message_bytes_match_centralized_formulas() {
        let reg = ParamRegistry::from_shapes(&[("w", vec![16, 10]), ("b", vec![5])]);
        let d = decentralized_by_name("powersgd", 2, 3).unwrap();
        assert_eq!(d.message_bytes(&reg), reg.total_rank_r_bytes_uncapped(2));
        let s = decentralized_by_name("sign-norm", 2, 3).unwrap();
        assert_eq!(s.message_bytes(&reg), 4 + (160u64).div_ceil(8) + 20);
    }
}
