//! PowerSGD (Algorithm 1) and the best-approximation reference (App. G.7).

use super::scratch::TensorPool;
use super::{
    aggregate_vectors_uncompressed, all_reduce_mean_packed, split_kinds, Aggregated, Compressor,
    Locals, SchemeMeta,
};
use crate::collectives::{all_reduce_mean, CommLog};
use crate::grad::ParamRegistry;
use crate::linalg::gram_schmidt_in_place;
use crate::tensor::{matmul_into, matmul_nt_into, matmul_tn_into, Tensor};
use crate::util::Rng;

/// Reusable buffers for the centralized oracle's per-worker P/Q GEMM
/// sweeps — the [`super::ScratchArena`] pattern applied to the
/// all-workers-in-one-call view. Factor tensors are slot-addressed
/// `w·k + slot` (worker-major); the flat per-worker buffers feed the
/// packed all-reduces. Everything is claimed on the first step of a
/// shape-stable workload and reused verbatim afterwards
/// ([`TensorPool::allocations`] is the regression counter; the blocked
/// kernels' own panels/tiles amortize the same way under
/// [`kernel_scratch_grows`](crate::runtime::pool::kernel_scratch_grows)).
#[derive(Debug, Default)]
struct OracleScratch {
    /// Left factors `P_w = M_w·Q`; slots `0..k` double as the shared
    /// mean `P̂` after the all-reduce unpacks into them.
    p: TensorPool,
    /// Right factors `Q_w = M_wᵀ·P̂`; slots `0..k` hold the shared mean.
    q: TensorPool,
    /// One packed flat buffer per worker for the all-reduces.
    bufs: Vec<Vec<f32>>,
}

/// Pack each worker's `k` factor tensors (slots `w·k..w·k+k`) into one
/// reusable flat buffer per worker.
fn pack_workers(bufs: &mut Vec<Vec<f32>>, pool: &TensorPool, w: usize, k: usize) {
    if bufs.len() < w {
        bufs.resize_with(w, Vec::new);
    }
    for (wi, buf) in bufs.iter_mut().enumerate().take(w) {
        buf.clear();
        for slot in 0..k {
            buf.extend_from_slice(pool.at(wi * k + slot).data());
        }
    }
}

/// Unpack the reduced flat buffer back into worker 0's slots (which
/// then hold the shared mean).
fn unpack_first_worker(buf: &[f32], tensors: &mut [Tensor]) {
    let mut off = 0;
    for t in tensors {
        let len = t.len();
        t.data_mut().copy_from_slice(&buf[off..off + len]);
        off += len;
    }
}

/// Rank-r PowerSGD compression (Algorithm 1).
///
/// One warm-started subspace-iteration step per optimization step:
/// `P ← M·Q` → all-reduce-mean → `P̂ ← orthogonalize(P)` → `Q ← Mᵀ·P̂`
/// → all-reduce-mean → reconstruct `P̂·Qᵀ`. Both matrix products are
/// linear in `M`, so the all-reduce computes exactly the factorization of
/// the *mean* gradient — the "linearity" property (§3, Lemma 3).
pub struct PowerSgd {
    rank: usize,
    /// Reuse `Q` across steps (§4.2 warm start). When false, `Q` is
    /// re-sampled i.i.d. normal every step ("without warm start").
    warm_start: bool,
    /// Per-matrix-parameter `Q ∈ R^{m×r}` state, lazily initialized.
    qs: Vec<Option<Tensor>>,
    rng: Rng,
    /// Reusable per-worker P/Q factors + packed collective buffers.
    scratch: OracleScratch,
}

impl PowerSgd {
    /// Rank-`rank` PowerSGD with warm start, shared-seed `Q` draws.
    pub fn new(rank: usize, seed: u64) -> PowerSgd {
        assert!(rank >= 1, "rank must be >= 1");
        PowerSgd {
            rank,
            warm_start: true,
            qs: Vec::new(),
            rng: Rng::new(seed),
            scratch: OracleScratch::default(),
        }
    }

    /// Disable warm start (Table 2 ablation).
    pub fn without_warm_start(mut self) -> PowerSgd {
        self.warm_start = false;
        self
    }

    /// The compression rank `r`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Ensure the warm-start `Q` for `slot` exists (re-sampling it when
    /// warm start is off) and return a borrow. Returning `&Tensor`
    /// instead of a clone saves one full `m×r` copy per matrix per step.
    fn ensure_q(&mut self, slot: usize, m: usize) -> &Tensor {
        if self.qs.len() <= slot {
            self.qs.resize(slot + 1, None);
        }
        let need_fresh = !self.warm_start || self.qs[slot].is_none();
        if need_fresh {
            let mut q = Tensor::zeros(&[m, self.rank]);
            self.rng.fill_normal(q.data_mut(), 1.0);
            self.qs[slot] = Some(q);
        }
        self.qs[slot].as_ref().expect("initialized above")
    }
}

impl SchemeMeta for PowerSgd {
    fn name(&self) -> String {
        if self.warm_start {
            format!("Rank {}", self.rank)
        } else {
            format!("Rank {} (no warm start)", self.rank)
        }
    }

    fn supports_all_reduce(&self) -> bool {
        true
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        registry.total_rank_r_bytes_uncapped(self.rank)
    }
}

impl Compressor for PowerSgd {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let w = updates.len();
        assert!(w > 0);
        let (mat_idx, vec_idx) = split_kinds(&updates[0]);
        let k = mat_idx.len();
        // Matrix slots are fully overwritten by the reconstruction below;
        // allocate empty placeholders instead of zeroed n×m buffers
        // (perf pass: saves one full-gradient memset per step).
        let mut mean: Vec<Tensor> = updates[0]
            .iter()
            .map(|t| if t.shape().len() >= 2 { Tensor::zeros(&[0]) } else { Tensor::zeros(t.shape()) })
            .collect();
        aggregate_vectors_uncompressed(updates, &vec_idx, &mut mean, log);

        // --- Stage 1: P_w = M_w · Q for every matrix, packed all-reduce.
        // Ensure every warm-start Q exists first (one RNG pass in slot
        // order); the GEMM sweep then writes into arena slots (worker-
        // major `w·k + slot`) so the steady-state step allocates no
        // fresh factor tensors.
        for (slot, &p) in mat_idx.iter().enumerate() {
            self.ensure_q(slot, updates[0][p].cols());
        }
        let rank = self.rank;
        for (wi, wu) in updates.iter().enumerate() {
            for (slot, &p) in mat_idx.iter().enumerate() {
                let q = self.qs[slot].as_ref().expect("warm-start Q ensured above");
                let out = self.scratch.p.get(wi * k + slot, &[wu[p].rows(), rank]);
                matmul_into(&wu[p], q, out);
            }
        }
        pack_workers(&mut self.scratch.bufs, &self.scratch.p, w, k);
        all_reduce_mean(&mut self.scratch.bufs[..w], log);
        unpack_first_worker(&self.scratch.bufs[0], self.scratch.p.first_mut(k));

        // --- Orthogonalize the shared mean (Gram–Schmidt; paper §3) in
        // worker 0's slots, which now hold P̂.
        for phat in self.scratch.p.first_mut(k) {
            gram_schmidt_in_place(phat);
        }

        // --- Stage 2: Q_w = M_wᵀ · P̂, same arena slots + packed all-reduce.
        for (wi, wu) in updates.iter().enumerate() {
            for (slot, &p) in mat_idx.iter().enumerate() {
                let scratch = &mut self.scratch;
                let out = scratch.q.get(wi * k + slot, &[wu[p].cols(), rank]);
                matmul_tn_into(&wu[p], scratch.p.at(slot), out);
            }
        }
        pack_workers(&mut self.scratch.bufs, &self.scratch.q, w, k);
        all_reduce_mean(&mut self.scratch.bufs[..w], log);
        unpack_first_worker(&self.scratch.bufs[0], self.scratch.q.first_mut(k));

        // --- Reconstruct P̂·Qᵀ directly into the returned aggregate (the
        // API hands ownership out, so this is the one per-step tensor
        // allocation left) and persist warm-start Q without cloning.
        for (slot, &p) in mat_idx.iter().enumerate() {
            let phat = self.scratch.p.at(slot);
            let qn = self.scratch.q.at(slot);
            let mut rec = Tensor::zeros(&[phat.rows(), qn.rows()]);
            matmul_nt_into(phat, qn, &mut rec);
            if crate::obs::metrics::on() {
                // Telemetry only: relative error of the shared
                // reconstruction against the cross-worker mean update —
                // the `M` of ‖M − P̂Q̄ᵀ‖_F / ‖M‖_F on the oracle path.
                // Gated on the metrics bit so the hot path never pays
                // for the mean recomputation.
                let wf = updates.len() as f64;
                let mut num = 0.0f64;
                let mut den = 0.0f64;
                for (i, r) in rec.data().iter().enumerate() {
                    let m: f64 =
                        updates.iter().map(|wu| f64::from(wu[p].data()[i])).sum::<f64>() / wf;
                    let d = m - f64::from(*r);
                    num += d * d;
                    den += m * m;
                }
                let err = if den > 0.0 { (num / den).sqrt() } else { 0.0 };
                crate::obs::metrics::set_gauge(crate::obs::metrics::Gauge::ApproxError, err);
                crate::obs::metrics::observe(crate::obs::metrics::Histogram::ApproxError, err);
            }
            mean[p] = rec;
            if self.warm_start {
                self.qs[slot]
                    .as_mut()
                    .expect("warm-start Q ensured above")
                    .data_mut()
                    .copy_from_slice(self.scratch.q.at(slot).data());
            }
        }

        Aggregated { mean, locals: Locals::SharedAggregate }
    }

    fn scratch_allocations(&self) -> Option<u64> {
        Some(self.scratch.p.allocations() + self.scratch.q.allocations())
    }
}

/// "Best rank-r approximation" reference compressor (Appendix G.7):
/// `iters` full subspace iterations per step, fresh random start, no
/// reuse. Used by Table 2 to upper-bound warm-started PowerSGD and by
/// §4.2's cost argument (it is ~`2·iters`× the GEMM work).
pub struct BestRankR {
    rank: usize,
    iters: usize,
    rng: Rng,
}

impl BestRankR {
    /// Best-rank-`rank` reference with the paper's 4 subspace
    /// iterations per step.
    pub fn new(rank: usize, seed: u64) -> BestRankR {
        // Paper: "4 steps of subspace iterations (8 matrix multiplications)
        // is enough to converge to the best low-rank approximation".
        BestRankR { rank, iters: 4, rng: Rng::new(seed) }
    }

    /// Override the subspace iteration count (≥ 1).
    pub fn with_iters(mut self, iters: usize) -> BestRankR {
        assert!(iters >= 1);
        self.iters = iters;
        self
    }
}

impl SchemeMeta for BestRankR {
    fn name(&self) -> String {
        format!("Best rank {} ({} iters)", self.rank, self.iters)
    }

    fn supports_all_reduce(&self) -> bool {
        true
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        // matrices pay per iteration; vectors are all-reduced once
        let vec_bytes: u64 = registry
            .specs
            .iter()
            .filter(|s| s.matrix_dims().is_none())
            .map(|s| s.bytes())
            .sum();
        let mat_bytes = registry.total_rank_r_bytes_uncapped(self.rank) - vec_bytes;
        mat_bytes * self.iters as u64 + vec_bytes
    }
}

impl Compressor for BestRankR {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let (mat_idx, vec_idx) = split_kinds(&updates[0]);
        let mut mean: Vec<Tensor> = updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
        aggregate_vectors_uncompressed(updates, &vec_idx, &mut mean, log);

        // Fresh random Q per step.
        let mut qs: Vec<Tensor> = mat_idx
            .iter()
            .map(|&p| {
                let mut q = Tensor::zeros(&[updates[0][p].cols(), self.rank]);
                self.rng.fill_normal(q.data_mut(), 1.0);
                q
            })
            .collect();

        let mut p_mean: Vec<Tensor> = Vec::new();
        for _ in 0..self.iters {
            let per_worker_p: Vec<Vec<Tensor>> = updates
                .iter()
                .map(|wu| {
                    mat_idx
                        .iter()
                        .zip(qs.iter())
                        .map(|(&p, q)| {
                            let mut out = Tensor::zeros(&[wu[p].rows(), self.rank]);
                            matmul_into(&wu[p], q, &mut out);
                            out
                        })
                        .collect()
                })
                .collect();
            p_mean = all_reduce_mean_packed(&per_worker_p, log);
            for p in p_mean.iter_mut() {
                gram_schmidt_in_place(p);
            }
            let per_worker_q: Vec<Vec<Tensor>> = updates
                .iter()
                .map(|wu| {
                    mat_idx
                        .iter()
                        .zip(p_mean.iter())
                        .map(|(&p, phat)| {
                            let mut out = Tensor::zeros(&[wu[p].cols(), self.rank]);
                            matmul_tn_into(&wu[p], phat, &mut out);
                            out
                        })
                        .collect()
                })
                .collect();
            qs = all_reduce_mean_packed(&per_worker_q, log);
        }

        for (&p, (phat, qn)) in mat_idx.iter().zip(p_mean.iter().zip(qs.iter())) {
            let mut rec = Tensor::zeros(&[phat.rows(), qn.rows()]);
            matmul_nt_into(phat, qn, &mut rec);
            mean[p] = rec;
        }
        Aggregated { mean, locals: Locals::SharedAggregate }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::best_rank_r;

    fn rand_updates(w: usize, shapes: &[&[usize]], seed: u64) -> Vec<Vec<Tensor>> {
        let mut rng = Rng::new(seed);
        (0..w)
            .map(|_| {
                shapes
                    .iter()
                    .map(|s| {
                        let mut t = Tensor::zeros(s);
                        rng.fill_normal(t.data_mut(), 1.0);
                        t
                    })
                    .collect()
            })
            .collect()
    }

    fn mean_of(updates: &[Vec<Tensor>], p: usize) -> Tensor {
        let mut m = Tensor::zeros(updates[0][p].shape());
        for wu in updates {
            m.axpy(1.0 / updates.len() as f32, &wu[p]);
        }
        m
    }

    #[test]
    fn output_is_rank_r() {
        let updates = rand_updates(2, &[&[12, 8]], 71);
        let mut c = PowerSgd::new(2, 1);
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        // Rank of the output ≈ 2: singular values beyond index 2 vanish.
        let svd = crate::linalg::svd(&agg.mean[0]);
        assert!(svd.s[2] < 1e-4 * svd.s[0].max(1e-9), "sv tail {:?}", &svd.s[..4]);
    }

    #[test]
    fn single_vs_multi_worker_equivalence() {
        // Lemma 3: compressing the per-worker updates and averaging equals
        // compressing the average (with identical Q init).
        let shapes: &[&[usize]] = &[&[10, 6], &[6]];
        let updates = rand_updates(4, shapes, 72);
        let mean_update = vec![mean_of(&updates, 0), mean_of(&updates, 1)];

        let mut multi = PowerSgd::new(2, 9);
        let mut single = PowerSgd::new(2, 9);
        let mut log = CommLog::default();
        let agg_multi = multi.compress_aggregate(&updates, &mut log);
        let agg_single = single.compress_aggregate(&[mean_update], &mut log);
        for (a, b) in agg_multi.mean.iter().zip(agg_single.mean.iter()) {
            assert!(a.allclose(b, 1e-3, 1e-4), "max diff {}", a.max_abs_diff(b));
        }
    }

    #[test]
    fn warm_start_converges_to_best_rank_r() {
        // Theorem I: repeated warm-started steps on a FIXED matrix recover
        // the best rank-r approximation.
        let updates = rand_updates(1, &[&[16, 10]], 73);
        let m = &updates[0][0];
        let mut c = PowerSgd::new(2, 5);
        let mut log = CommLog::default();
        let mut last = Tensor::zeros(&[16, 10]);
        for _ in 0..50 {
            last = c.compress_aggregate(&updates, &mut log).mean[0].clone();
        }
        let best = best_rank_r(m, 2);
        let err_power = m.sub(&last).norm();
        let err_best = m.sub(&best).norm();
        assert!(
            (err_power - err_best).abs() / err_best.max(1e-9) < 0.02,
            "power {err_power} vs best {err_best}"
        );
    }

    #[test]
    fn cold_start_single_step_is_worse_than_warm() {
        let updates = rand_updates(1, &[&[32, 20]], 74);
        let m = &updates[0][0];
        let mut warm = PowerSgd::new(1, 6);
        let mut cold = PowerSgd::new(1, 6).without_warm_start();
        let mut log = CommLog::default();
        let mut warm_err = 0.0;
        let mut cold_err = 0.0;
        for _ in 0..20 {
            warm_err = m.sub(&warm.compress_aggregate(&updates, &mut log).mean[0]).norm();
            cold_err = m.sub(&cold.compress_aggregate(&updates, &mut log).mean[0]).norm();
        }
        assert!(
            warm_err < cold_err,
            "warm {warm_err} should beat cold {cold_err} on a fixed matrix"
        );
    }

    #[test]
    fn vectors_pass_through_uncompressed() {
        let updates = rand_updates(3, &[&[4, 4], &[5]], 75);
        let mut c = PowerSgd::new(1, 2);
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        let expect = mean_of(&updates, 1);
        assert!(agg.mean[1].allclose(&expect, 1e-5, 1e-6));
    }

    #[test]
    fn byte_accounting_matches_closed_form() {
        use crate::grad::ParamRegistry;
        let reg = ParamRegistry::from_shapes(&[("w", vec![16, 10]), ("b", vec![5])]);
        let updates = rand_updates(2, &[&[16, 10], &[5]], 76);
        let mut c = PowerSgd::new(2, 3);
        let mut log = CommLog::default();
        c.compress_aggregate(&updates, &mut log);
        assert_eq!(log.bytes_sent(), c.message_bytes(&reg));
    }

    #[test]
    fn best_rank_r_compressor_tracks_svd() {
        let updates = rand_updates(1, &[&[14, 9]], 77);
        let m = &updates[0][0];
        let mut c = BestRankR::new(2, 8);
        let mut log = CommLog::default();
        let out = c.compress_aggregate(&updates, &mut log).mean[0].clone();
        let best = best_rank_r(m, 2);
        let err_c = m.sub(&out).norm();
        let err_b = m.sub(&best).norm();
        assert!((err_c - err_b).abs() / err_b < 0.05, "{err_c} vs {err_b}");
    }
}
