//! Sign-based compressors (Appendix G.3, G.5).
//!
//! Both transmit one bit per coordinate, packed into bytes exactly like
//! the C++ bit-packing extension the paper uses — the byte accounting is
//! `⌈nm/8⌉` per matrix. Neither is linear, so aggregation uses
//! all-gather and decode cost scales with W (Table 5's hatched bars).

use super::{aggregate_vectors_uncompressed, split_kinds, Aggregated, Compressor, SchemeMeta, Locals};
use crate::collectives::{all_gather_bytes, CommLog};
use crate::grad::{CompressKind, ParamRegistry};
use crate::tensor::Tensor;

/// Append the sign bits of `data` (1 = non-negative) to `out` —
/// allocation-free when `out` has capacity (the per-worker hot path).
pub(crate) fn pack_signs_into(data: &[f32], out: &mut Vec<u8>) {
    let start = out.len();
    out.resize(start + data.len().div_ceil(8), 0);
    for (i, &v) in data.iter().enumerate() {
        if v >= 0.0 {
            out[start + i / 8] |= 1 << (i % 8);
        }
    }
}

/// Unpack sign bits back to ±1.0 values.
pub(crate) fn unpack_signs(bytes: &[u8], n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| if bytes[i / 8] >> (i % 8) & 1 == 1 { 1.0 } else { -1.0 })
        .collect()
}

/// Sign + L1-norm compression (Algorithm 5), the EF-SGD-compatible
/// sign scheme: transmit `sign(M)` and `ℓ = ‖M‖₁`; decompress
/// `(ℓ / nm) · sign(M)`, aggregated by averaging over workers.
pub struct SignNorm;

impl SignNorm {
    /// The sign + L1-norm compressor.
    pub fn new() -> SignNorm {
        SignNorm
    }
}

impl Default for SignNorm {
    fn default() -> Self {
        Self::new()
    }
}

impl SchemeMeta for SignNorm {
    fn name(&self) -> String {
        "Sign+Norm".into()
    }

    fn supports_all_reduce(&self) -> bool {
        false
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        registry
            .specs
            .iter()
            .map(|s| match s.kind {
                CompressKind::Matrix { rows, cols } => 4 + ((rows * cols).div_ceil(8)) as u64,
                CompressKind::Vector { len } => (len * 4) as u64,
            })
            .sum()
    }
}

impl Compressor for SignNorm {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let w = updates.len();
        let (mat_idx, vec_idx) = split_kinds(&updates[0]);
        let mut mean: Vec<Tensor> = updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
        aggregate_vectors_uncompressed(updates, &vec_idx, &mut mean, log);

        // Message: per matrix, 4-byte scale then packed sign bits.
        let messages: Vec<Vec<u8>> = updates
            .iter()
            .map(|wu| {
                let mut msg = Vec::new();
                for &p in &mat_idx {
                    let nm = wu[p].len() as f64;
                    let scale = (wu[p].norm_l1() / nm) as f32;
                    msg.extend_from_slice(&scale.to_le_bytes());
                    pack_signs_into(wu[p].data(), &mut msg);
                }
                msg
            })
            .collect();
        let gathered = all_gather_bytes(&messages, log);
        let received = &gathered[0];

        let mut locals: Vec<Vec<Tensor>> = (0..w)
            .map(|wi| {
                let mut lt: Vec<Tensor> =
                    updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
                for &p in &vec_idx {
                    lt[p] = updates[wi][p].clone();
                }
                lt
            })
            .collect();
        for (wi, msg) in received.iter().enumerate() {
            let mut cursor = 0;
            for &p in &mat_idx {
                let n = updates[0][p].len();
                let scale = f32::from_le_bytes(msg[cursor..cursor + 4].try_into().unwrap());
                cursor += 4;
                let nbytes = n.div_ceil(8);
                let signs = unpack_signs(&msg[cursor..cursor + nbytes], n);
                cursor += nbytes;
                for (i, s) in signs.iter().enumerate() {
                    let v = scale * s;
                    mean[p].data_mut()[i] += v / w as f32;
                    locals[wi][p].data_mut()[i] = v;
                }
            }
        }
        Aggregated { mean, locals: Locals::PerWorker(locals) }
    }
}

/// Signum compression (Algorithm 7, Bernstein et al. 2019): transmit
/// `sign(M)`, aggregate by **majority vote**, run WITHOUT error feedback
/// (the caller pairs it with sign-of-momentum Signum updates).
pub struct Signum;

impl Signum {
    /// The majority-vote sign compressor.
    pub fn new() -> Signum {
        Signum
    }
}

impl Default for Signum {
    fn default() -> Self {
        Self::new()
    }
}

impl SchemeMeta for Signum {
    fn name(&self) -> String {
        "Signum".into()
    }

    fn supports_all_reduce(&self) -> bool {
        false
    }

    fn is_biased(&self) -> bool {
        // Biased, but the Signum optimizer uses it without EF by design.
        true
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        registry
            .specs
            .iter()
            .map(|s| match s.kind {
                CompressKind::Matrix { rows, cols } => ((rows * cols).div_ceil(8)) as u64,
                CompressKind::Vector { len } => (len * 4) as u64,
            })
            .sum()
    }
}

impl Compressor for Signum {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let w = updates.len();
        let (mat_idx, vec_idx) = split_kinds(&updates[0]);
        let mut mean: Vec<Tensor> = updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
        aggregate_vectors_uncompressed(updates, &vec_idx, &mut mean, log);

        let messages: Vec<Vec<u8>> = updates
            .iter()
            .map(|wu| {
                let mut msg = Vec::new();
                for &p in &mat_idx {
                    pack_signs_into(wu[p].data(), &mut msg);
                }
                msg
            })
            .collect();
        let gathered = all_gather_bytes(&messages, log);
        let received = &gathered[0];

        let mut locals: Vec<Vec<Tensor>> = (0..w)
            .map(|wi| {
                let mut lt: Vec<Tensor> =
                    updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
                for &p in &vec_idx {
                    lt[p] = updates[wi][p].clone();
                }
                lt
            })
            .collect();
        // Majority vote: sign(sum of signs).
        for &p in &mat_idx {
            let n = updates[0][p].len();
            let mut votes = vec![0.0f32; n];
            let mut cursor = 0;
            // locate this matrix's bits within each message
            for &q in &mat_idx {
                if q == p {
                    break;
                }
                cursor += updates[0][q].len().div_ceil(8);
            }
            for (wi, msg) in received.iter().enumerate() {
                let signs = unpack_signs(&msg[cursor..cursor + n.div_ceil(8)], n);
                for (i, s) in signs.iter().enumerate() {
                    votes[i] += s;
                }
                for (i, s) in signs.iter().enumerate() {
                    locals[wi][p].data_mut()[i] = *s;
                }
            }
            for (i, v) in votes.iter().enumerate() {
                mean[p].data_mut()[i] = if *v >= 0.0 { 1.0 } else { -1.0 };
            }
        }
        Aggregated { mean, locals: Locals::PerWorker(locals) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sign_pack_roundtrip() {
        let data = [1.0f32, -2.0, 0.0, -0.5, 3.0, -1.0, -1.0, 2.0, 5.0];
        let mut packed = Vec::new();
        pack_signs_into(&data, &mut packed);
        assert_eq!(packed.len(), 2);
        let signs = unpack_signs(&packed, data.len());
        for (v, s) in data.iter().zip(signs.iter()) {
            assert_eq!(*s, if *v >= 0.0 { 1.0 } else { -1.0 });
        }
    }

    fn rand_updates(w: usize, shape: &[usize], seed: u64) -> Vec<Vec<Tensor>> {
        let mut rng = Rng::new(seed);
        (0..w)
            .map(|_| {
                let mut t = Tensor::zeros(shape);
                rng.fill_normal(t.data_mut(), 1.0);
                vec![t]
            })
            .collect()
    }

    #[test]
    fn sign_norm_scale_is_mean_abs() {
        let updates = rand_updates(1, &[6, 6], 101);
        let mut c = SignNorm::new();
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        let m = &updates[0][0];
        let scale = (m.norm_l1() / m.len() as f64) as f32;
        for (o, v) in agg.mean[0].data().iter().zip(m.data().iter()) {
            let want = scale * v.signum().max(-1.0); // signum(0)=0 edge irrelevant here
            assert!((o - want).abs() < 1e-5, "{o} vs {want}");
        }
    }

    #[test]
    fn sign_norm_multiworker_averages() {
        let updates = vec![
            vec![Tensor::full(&[2, 2], 1.0)],
            vec![Tensor::full(&[2, 2], -3.0)],
        ];
        let mut c = SignNorm::new();
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        // worker0: scale 1, signs +; worker1: scale 3, signs −
        // mean = (1·1 + 3·(−1))/2 = −1
        for v in agg.mean[0].data() {
            assert!((v + 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn signum_majority_vote() {
        let updates = vec![
            vec![Tensor::from_vec(&[1, 3], vec![1.0, -1.0, 1.0])],
            vec![Tensor::from_vec(&[1, 3], vec![1.0, -1.0, -1.0])],
            vec![Tensor::from_vec(&[1, 3], vec![-1.0, -1.0, -1.0])],
        ];
        let mut c = Signum::new();
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&updates, &mut log);
        assert_eq!(agg.mean[0].data(), &[1.0, -1.0, -1.0]);
    }

    #[test]
    fn byte_accounting_one_bit_per_coord() {
        let reg = ParamRegistry::from_shapes(&[("w", vec![16, 16]), ("b", vec![4])]);
        // 256 bits = 32 bytes + 4 scale + 16 bias bytes
        assert_eq!(SignNorm::new().message_bytes(&reg), 32 + 4 + 16);
        assert_eq!(Signum::new().message_bytes(&reg), 32 + 16);
        let updates = rand_updates(2, &[16, 16], 102);
        let updates: Vec<Vec<Tensor>> = updates
            .into_iter()
            .map(|mut wu| {
                wu.push(Tensor::zeros(&[4]));
                wu
            })
            .collect();
        let mut log = CommLog::default();
        let mut c = SignNorm::new();
        c.compress_aggregate(&updates, &mut log);
        assert_eq!(log.bytes_sent(), c.message_bytes(&reg));
    }

    #[test]
    fn gather_not_reduce() {
        assert!(!SignNorm::new().supports_all_reduce());
        assert!(!Signum::new().supports_all_reduce());
    }
}
