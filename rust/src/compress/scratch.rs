//! Reusable per-worker scratch storage for the decentralized
//! compression hot path.
//!
//! The centralized oracle allocates a fresh `Tensor::zeros` for every
//! GEMM output and re-packs a fresh flat buffer for every collective,
//! every step. A [`ScratchArena`] gives each worker thread a private
//! set of slot-addressed buffers that are allocated on the first step
//! and reused verbatim afterwards: pools for the `P`/`Q` factor
//! tensors, one growable f32 buffer for packed collectives and decode
//! votes, and one byte buffer for packed sign messages.
//!
//! [`ScratchArena::allocations`] counts every tensor the arena had to
//! allocate; after the shapes stabilize (step 1) the count must stop
//! moving — `tests/integration_decentralized.rs` pins exactly that.
//!
//! The arena covers the *compressor-owned* buffers only. The blocked
//! GEMM / Gram–Schmidt kernels keep their packed panels, accumulator
//! tiles and reduction partials in per-thread pool scratch with its
//! own growth counter
//! ([`kernel_scratch_grows`](crate::runtime::pool::kernel_scratch_grows));
//! together the two counters make the whole step's zero-alloc steady
//! state observable, and `tests/proptest_invariants.rs` pins the
//! kernel side at every thread count.

use crate::tensor::Tensor;

/// Slot-addressed pool of reusable tensors.
///
/// `get(idx, shape)` returns the tensor at `idx`, reusing the previous
/// step's buffer whenever the shape is unchanged (contents are stale —
/// every user overwrites). A shape change reallocates and bumps the
/// allocation counter.
#[derive(Debug, Default)]
pub struct TensorPool {
    items: Vec<Tensor>,
    allocs: u64,
}

impl TensorPool {
    /// Empty pool with a zeroed allocation counter.
    pub fn new() -> TensorPool {
        TensorPool { items: Vec::new(), allocs: 0 }
    }

    /// Tensor slot `idx` shaped exactly `shape`. Contents are whatever
    /// the previous step left behind; callers must overwrite.
    pub fn get(&mut self, idx: usize, shape: &[usize]) -> &mut Tensor {
        while self.items.len() <= idx {
            self.items.push(Tensor::zeros(&[0]));
        }
        if self.items[idx].shape() != shape {
            self.items[idx] = Tensor::zeros(shape);
            self.allocs += 1;
        }
        &mut self.items[idx]
    }

    /// Shared view of slot `idx` (must have been `get` before).
    pub fn at(&self, idx: usize) -> &Tensor {
        &self.items[idx]
    }

    /// The first `k` slots, for packing into a flat collective buffer.
    pub fn first(&self, k: usize) -> &[Tensor] {
        &self.items[..k]
    }

    /// Mutable view of the first `k` slots, for unpacking a collective
    /// result back into tensors.
    pub fn first_mut(&mut self, k: usize) -> &mut [Tensor] {
        &mut self.items[..k]
    }

    /// How many tensors this pool has allocated so far.
    pub fn allocations(&self) -> u64 {
        self.allocs
    }
}

/// Per-worker scratch: everything a [`WorkerCompressor`] round needs
/// besides its own state, reused across steps.
///
/// [`WorkerCompressor`]: super::WorkerCompressor
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// Left factors: the worker's `M·Q` products, then (after the
    /// all-reduce unpacks into the same slots) the shared `P̂` mean.
    pub p: TensorPool,
    /// Right factors: sketching matrices / `Mᵀ·P̂` products, then the
    /// shared `Q` mean.
    pub q: TensorPool,
    /// Flat f32 buffer for packed all-reduces, gather messages and
    /// decode votes; capacity grows to the step maximum once and then
    /// amortizes every later use.
    pub buf: Vec<f32>,
    /// Second flat f32 buffer for pipelined rounds, where the vector
    /// reduction is still in flight while `buf` packs the factor
    /// collectives; lockstep rounds leave it empty.
    pub vbuf: Vec<f32>,
    /// Byte buffer for packed sign messages.
    pub bytes: Vec<u8>,
}

impl ScratchArena {
    /// Empty arena (buffers grow to the workload's steady state once).
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }

    /// Total tensors allocated by the arena's pools so far — the
    /// counter the zero-alloc regression test pins: it must not move
    /// after the first step of a shape-stable workload.
    pub fn allocations(&self) -> u64 {
        self.p.allocations() + self.q.allocations()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_matching_shapes() {
        let mut pool = TensorPool::new();
        pool.get(0, &[3, 2]).data_mut().fill(7.0);
        assert_eq!(pool.allocations(), 1);
        // Same shape: stale contents, no new allocation.
        assert_eq!(pool.get(0, &[3, 2]).data(), &[7.0; 6]);
        assert_eq!(pool.allocations(), 1);
        // Shape change: reallocates.
        pool.get(0, &[2, 2]);
        assert_eq!(pool.allocations(), 2);
    }

    #[test]
    fn pool_grows_to_slot_index() {
        let mut pool = TensorPool::new();
        pool.get(2, &[4]);
        assert_eq!(pool.first(3).len(), 3);
        assert_eq!(pool.at(2).shape(), &[4]);
        // Slots 0/1 are placeholders until claimed; only slot 2 counted.
        assert_eq!(pool.allocations(), 1);
    }

    #[test]
    fn arena_counter_sums_pools() {
        let mut a = ScratchArena::new();
        a.p.get(0, &[2, 2]);
        a.q.get(0, &[2, 1]);
        a.q.get(1, &[3, 1]);
        assert_eq!(a.allocations(), 3);
        a.p.get(0, &[2, 2]);
        assert_eq!(a.allocations(), 3);
    }
}
