//! Spectral Atomo (Wang et al. 2018; paper Appendix G.6).
//!
//! Unbiased importance sampling of the gradient's singular components:
//! decompose `M = Σ σᵢ uᵢ vᵢᵀ`, compute inclusion probabilities `pᵢ`
//! with `Σ pᵢ = r`, sample until exactly `r` components are selected
//! (the paper's modification), and transmit `{(uᵢ·σᵢ/pᵢ, vᵢ)}`.
//! Requires a full SVD every step — the cost §4.2 and Table 6 show to be
//! prohibitive (948 ms vs 239 ms per batch), which our `kernel_hotpath`
//! bench reproduces with the Jacobi SVD substrate.

use super::{aggregate_vectors_uncompressed, split_kinds, Aggregated, Compressor, SchemeMeta, Locals};
use crate::collectives::{all_gather, CommLog};
use crate::grad::{CompressKind, ParamRegistry};
use crate::linalg::svd;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Rank-r Spectral Atomo compressor.
pub struct Atomo {
    rank: usize,
    rng: Rng,
}

impl Atomo {
    /// Atomo sampling `rank` singular components per matrix.
    pub fn new(rank: usize, seed: u64) -> Atomo {
        assert!(rank >= 1);
        Atomo { rank, rng: Rng::new(seed) }
    }

    /// Atomo inclusion probabilities: the water-filling solution of
    /// min variance s.t. Σpᵢ = s, 0 < pᵢ ≤ 1 — iteratively assign
    /// `pᵢ = σᵢ·s' / Σ_unsaturated σ` and clamp at 1.
    pub(crate) fn probabilities(sigmas: &[f32], budget: usize) -> Vec<f64> {
        let k = sigmas.len();
        let s = budget.min(k);
        let mut p = vec![0.0f64; k];
        let mut saturated = vec![false; k];
        loop {
            let remaining_budget = s as f64 - saturated.iter().filter(|&&x| x).count() as f64;
            let mass: f64 = sigmas
                .iter()
                .zip(&saturated)
                .filter(|(_, &sat)| !sat)
                .map(|(&x, _)| x as f64)
                .sum();
            if mass <= 0.0 || remaining_budget <= 0.0 {
                for i in 0..k {
                    if saturated[i] {
                        p[i] = 1.0;
                    }
                }
                break;
            }
            let mut newly = false;
            for i in 0..k {
                if !saturated[i] {
                    p[i] = (sigmas[i] as f64) * remaining_budget / mass;
                    if p[i] >= 1.0 {
                        saturated[i] = true;
                        newly = true;
                    }
                }
            }
            if !newly {
                for i in 0..k {
                    if saturated[i] {
                        p[i] = 1.0;
                    }
                }
                break;
            }
        }
        p
    }
}

impl SchemeMeta for Atomo {
    fn name(&self) -> String {
        format!("Atomo (rank {})", self.rank)
    }

    fn supports_all_reduce(&self) -> bool {
        false
    }

    fn is_biased(&self) -> bool {
        false // unbiased by construction; the paper runs it without EF
    }

    fn message_bytes(&self, registry: &ParamRegistry) -> u64 {
        registry
            .specs
            .iter()
            .map(|s| match s.kind {
                CompressKind::Matrix { rows, cols } => ((rows + cols) * self.rank * 4) as u64,
                CompressKind::Vector { len } => (len * 4) as u64,
            })
            .sum()
    }
}

impl Compressor for Atomo {
    fn compress_aggregate(&mut self, updates: &[Vec<Tensor>], log: &mut CommLog) -> Aggregated {
        let w = updates.len();
        let (mat_idx, vec_idx) = split_kinds(&updates[0]);
        let mut mean: Vec<Tensor> = updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
        aggregate_vectors_uncompressed(updates, &vec_idx, &mut mean, log);

        // Per worker: SVD each matrix, sample exactly `rank` components,
        // message = [u'_1 | v_1 | ... | u'_r | v_r] per matrix.
        let mut per_worker_recon: Vec<Vec<Tensor>> = (0..w)
            .map(|wi| {
                let mut lt: Vec<Tensor> =
                    updates[0].iter().map(|t| Tensor::zeros(t.shape())).collect();
                for &p in &vec_idx {
                    lt[p] = updates[wi][p].clone();
                }
                lt
            })
            .collect();
        let mut msg_len = 0usize;
        let messages: Vec<Vec<f32>> = updates
            .iter()
            .enumerate()
            .map(|(wi, wu)| {
                let mut msg = Vec::new();
                for &p in &mat_idx {
                    let (n, m) = (wu[p].rows(), wu[p].cols());
                    let d = svd(&wu[p]);
                    let probs = Atomo::probabilities(&d.s, self.rank);
                    // Repeat sampling until exactly `rank` selected
                    // (Appendix G.6's modification). Guard with a retry cap.
                    let mut selected: Vec<usize> = Vec::new();
                    for _attempt in 0..200 {
                        selected = (0..d.s.len())
                            .filter(|&i| self.rng.uniform() < probs[i])
                            .collect();
                        if selected.len() == self.rank.min(d.s.len()) {
                            break;
                        }
                    }
                    selected.truncate(self.rank);
                    while selected.len() < self.rank.min(d.s.len()) {
                        // pathological fallback: take argmax-prob components
                        let extra = (0..d.s.len()).find(|i| !selected.contains(i)).unwrap();
                        selected.push(extra);
                    }
                    for &i in &selected {
                        let scale = if probs[i] > 0.0 { d.s[i] as f64 / probs[i] } else { 0.0 };
                        for row in 0..n {
                            msg.push((d.u.at(row, i) as f64 * scale) as f32);
                        }
                        for row in 0..m {
                            msg.push(d.v.at(row, i));
                        }
                    }
                    // local reconstruction for this worker
                    let rec = per_worker_recon[wi][p].data_mut();
                    for &i in &selected {
                        let scale = if probs[i] > 0.0 { d.s[i] as f64 / probs[i] } else { 0.0 };
                        for row in 0..n {
                            let uv = d.u.at(row, i) as f64 * scale;
                            for col in 0..m {
                                rec[row * m + col] += (uv * d.v.at(col, i) as f64) as f32;
                            }
                        }
                    }
                }
                msg_len = msg.len();
                msg
            })
            .collect();
        let _ = all_gather(&messages, log);

        // Aggregate = average of per-worker reconstructions.
        for &p in &mat_idx {
            for wrec in per_worker_recon.iter() {
                mean[p].axpy(1.0 / w as f32, &wrec[p]);
            }
        }
        let _ = msg_len;
        Aggregated { mean, locals: Locals::PerWorker(per_worker_recon) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probabilities_sum_to_budget() {
        let sig = [5.0f32, 3.0, 1.0, 0.5, 0.1];
        for budget in 1..=4 {
            let p = Atomo::probabilities(&sig, budget);
            let sum: f64 = p.iter().sum();
            assert!((sum - budget as f64).abs() < 1e-9, "budget {budget} sum {sum}");
            assert!(p.iter().all(|&x| (0.0..=1.0 + 1e-12).contains(&x)));
        }
    }

    #[test]
    fn probabilities_saturate_dominant_component() {
        let sig = [100.0f32, 1.0, 1.0];
        let p = Atomo::probabilities(&sig, 2);
        assert!((p[0] - 1.0).abs() < 1e-9);
        assert!((p[1] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn unbiased_in_expectation() {
        let mut rng = Rng::new(111);
        let mut m = Tensor::zeros(&[6, 4]);
        rng.fill_normal(m.data_mut(), 1.0);
        let updates = vec![vec![m.clone()]];
        let mut c = Atomo::new(2, 112);
        let mut log = CommLog::default();
        let trials = 800;
        let mut acc = Tensor::zeros(&[6, 4]);
        for _ in 0..trials {
            let rec = c.compress_aggregate(&updates, &mut log).mean[0].clone();
            acc.axpy(1.0 / trials as f32, &rec);
        }
        let rel = acc.sub(&m).norm() / m.norm();
        assert!(rel < 0.15, "Atomo bias too large: {rel}");
    }

    #[test]
    fn exact_rank_components() {
        let mut rng = Rng::new(113);
        let mut m = Tensor::zeros(&[8, 5]);
        rng.fill_normal(m.data_mut(), 1.0);
        let mut c = Atomo::new(2, 114);
        let mut log = CommLog::default();
        let agg = c.compress_aggregate(&[vec![m]], &mut log);
        // Output is a sum of exactly 2 rank-1 terms => rank ≤ 2.
        let d = svd(&agg.mean[0]);
        assert!(d.s[2] < 1e-3 * d.s[0].max(1e-9), "{:?}", &d.s[..3]);
    }
}
