//! Exact model shape profiles from the paper (Appendix F) plus compute
//! constants for the timing simulator.
//!
//! These drive the communication-volume and time-per-batch columns of
//! Tables 3–7 and Figure 3: data volumes are *exact arithmetic* over the
//! published layer shapes; compute times are the paper's (constant)
//! fwd/bwd measurements on 2×GTX Titan X per node.

use crate::grad::ParamRegistry;

/// A workload profile: model shapes + measured compute constants.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    /// Display name matching the paper's table captions.
    pub name: &'static str,
    /// Exact layer shapes (Appendix F), matricized per §3.
    pub registry: ParamRegistry,
    /// Forward-pass time per batch, seconds (constant across algorithms —
    /// Table 5 "the time spent in the forward and backward pass is
    /// constant across all algorithms and numbers of workers").
    pub fwd_s: f64,
    /// Backward-pass time per batch, seconds.
    pub bwd_s: f64,
    /// Steps per epoch in the paper's setting (dataset size / global
    /// batch), used to convert per-step bytes to "data sent per epoch".
    pub steps_per_epoch: f64,
    /// Throughput of the testbed GPU for dense GEMM, FLOP/s — used to
    /// translate *our measured* encode/decode CPU times onto the paper's
    /// hardware scale.
    pub gpu_flops: f64,
}

/// ResNet18 on CIFAR10 (paper Table 10). 16 workers × batch 128 ⇒
/// 50000/2048 ≈ 24.4 steps/epoch; fwd+bwd ≈ 235 ms calibrated from
/// Table 3 (312 ms total − 75 ms comm − encode 0).
pub fn resnet18() -> ModelProfile {
    let shapes: Vec<(&str, Vec<usize>)> = vec![
        ("conv1", vec![64, 3, 3, 3]),
        ("layer1.0.conv1", vec![64, 64, 3, 3]),
        ("layer1.0.conv2", vec![64, 64, 3, 3]),
        ("layer1.1.conv1", vec![64, 64, 3, 3]),
        ("layer1.1.conv2", vec![64, 64, 3, 3]),
        ("layer2.0.conv1", vec![128, 64, 3, 3]),
        ("layer2.0.conv2", vec![128, 128, 3, 3]),
        ("layer2.0.shortcut.0", vec![128, 64, 1, 1]),
        ("layer2.1.conv1", vec![128, 128, 3, 3]),
        ("layer2.1.conv2", vec![128, 128, 3, 3]),
        ("layer3.0.conv1", vec![256, 128, 3, 3]),
        ("layer3.0.conv2", vec![256, 256, 3, 3]),
        ("layer3.0.shortcut.0", vec![256, 128, 1, 1]),
        ("layer3.1.conv1", vec![256, 256, 3, 3]),
        ("layer3.1.conv2", vec![256, 256, 3, 3]),
        ("layer4.0.conv1", vec![512, 256, 3, 3]),
        ("layer4.0.conv2", vec![512, 512, 3, 3]),
        ("layer4.0.shortcut.0", vec![512, 256, 1, 1]),
        ("layer4.1.conv1", vec![512, 512, 3, 3]),
        ("layer4.1.conv2", vec![512, 512, 3, 3]),
        ("linear", vec![10, 512]),
        // Bias vectors + BatchNorm parameters: 38 KB total (Table 10)
        ("biases", vec![9728]),
    ];
    let named: Vec<(&str, Vec<usize>)> = shapes;
    ModelProfile {
        name: "ResNet18/CIFAR10",
        registry: ParamRegistry::from_shapes(&named),
        fwd_s: 0.095,
        bwd_s: 0.140,
        steps_per_epoch: 50000.0 / (128.0 * 16.0),
        gpu_flops: 6.6e12, // GTX Titan X fp32 peak
    }
}

/// 3-layer LSTM language model on WikiText-2 (paper Table 11): 650
/// hidden units, tied 28869-token embedding.
pub fn lstm_wikitext2() -> ModelProfile {
    let shapes: Vec<(&str, Vec<usize>)> = vec![
        ("encoder", vec![28869, 650]),
        ("rnn-ih-l0", vec![2600, 650]),
        ("rnn-hh-l0", vec![2600, 650]),
        ("rnn-ih-l1", vec![2600, 650]),
        ("rnn-hh-l1", vec![2600, 650]),
        ("rnn-ih-l2", vec![2600, 650]),
        ("rnn-hh-l2", vec![2600, 650]),
        // bias vectors: 174 KB total
        ("biases", vec![44544]),
    ];
    ModelProfile {
        name: "LSTM/WikiText-2",
        registry: ParamRegistry::from_shapes(&shapes),
        fwd_s: 0.055,
        bwd_s: 0.070,
        // Table 7: 7730 MB/epoch at 110 MB/step ⇒ ≈ 70 steps/epoch
        steps_per_epoch: 70.0,
        gpu_flops: 6.6e12,
    }
}

/// Transformer LM for Appendix D (Baevski & Auli adaptive-input style,
/// reduced bookkeeping: we model the dominant decoder matrices; ~247M
/// params ⇒ the paper's 14×–105× compression ratios at ranks 32–4).
pub fn transformer_wikitext103() -> ModelProfile {
    let d = 1024usize;
    let ffn = 4096usize;
    let layers = 16usize;
    // Adaptive input representation (Baevski & Auli 2019): the 267k-token
    // vocabulary is split into frequency clusters with decreasing embed
    // dims. These wide-but-short matrices dominate the *compressed* size
    // ((n+m)·r with huge n), which is why Appendix D needs rank 32 for
    // only 14× compression.
    let mut shapes: Vec<(String, Vec<usize>)> = vec![
        ("embed.cluster0".to_string(), vec![20000, d]),
        ("embed.cluster1".to_string(), vec![40000, 256]),
        ("embed.cluster2".to_string(), vec![207735, 64]),
        ("embed.proj1".to_string(), vec![d, 256]),
        ("embed.proj2".to_string(), vec![d, 64]),
    ];
    for l in 0..layers {
        shapes.push((format!("l{l}.attn.qkv"), vec![3 * d, d]));
        shapes.push((format!("l{l}.attn.out"), vec![d, d]));
        shapes.push((format!("l{l}.ffn.w1"), vec![ffn, d]));
        shapes.push((format!("l{l}.ffn.w2"), vec![d, ffn]));
        shapes.push((format!("l{l}.biases"), vec![2 * d + ffn + 3 * d]));
    }
    let named: Vec<(&str, Vec<usize>)> =
        shapes.iter().map(|(n, s)| (n.as_str(), s.clone())).collect();
    ModelProfile {
        name: "Transformer/WikiText-103",
        registry: ParamRegistry::from_shapes(&named),
        fwd_s: 0.35,
        bwd_s: 0.70,
        steps_per_epoch: 1.0, // reported per-update in Appendix D
        gpu_flops: 4.1e12,    // Tesla K80 (per GPU)
    }
}

/// Profile by (CLI) name: `resnet18`, `lstm`, `transformer`. The single
/// name→profile mapping shared by the `simulate`/`experiment`
/// subcommands and the experiment registry, so registered scenario
/// names always parse.
pub fn by_name(name: &str) -> Option<ModelProfile> {
    match name {
        "resnet18" => Some(resnet18()),
        "lstm" => Some(lstm_wikitext2()),
        "transformer" => Some(transformer_wikitext103()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_lookup_by_cli_name() {
        assert_eq!(by_name("resnet18").unwrap().name, "ResNet18/CIFAR10");
        assert_eq!(by_name("lstm").unwrap().name, "LSTM/WikiText-2");
        assert_eq!(by_name("transformer").unwrap().name, "Transformer/WikiText-103");
        assert!(by_name("vgg").is_none());
    }

    #[test]
    fn resnet18_total_matches_table10() {
        let p = resnet18();
        let mb = p.registry.total_bytes() as f64 / 1e6;
        // Table 10: total 43 MB
        assert!((42.0..46.0).contains(&mb), "total {mb} MB");
        // Total compression 243/r ×
        let ratio = p.registry.compression_ratio(1);
        assert!((230.0..256.0).contains(&ratio), "ratio {ratio}");
    }

    const MIB: f64 = 1024.0 * 1024.0;

    #[test]
    fn resnet18_data_per_epoch_matches_table3() {
        // The paper reports MB = MiB (9216 KB for 512×4608×4 bytes).
        let p = resnet18();
        // SGD: 1023 MB/epoch
        let sgd = p.registry.total_bytes() as f64 * p.steps_per_epoch / MIB;
        assert!((990.0..1080.0).contains(&sgd), "SGD {sgd} MiB/epoch");
        // Rank 2: 8 MB/epoch
        let r2 = p.registry.total_rank_r_bytes(2) as f64 * p.steps_per_epoch / MIB;
        assert!((6.5..9.5).contains(&r2), "rank-2 {r2} MiB/epoch");
    }

    #[test]
    fn lstm_totals_match_table11() {
        let p = lstm_wikitext2();
        let mb = p.registry.total_bytes() as f64 / MIB;
        // Table 11: total 110 MB
        assert!((106.0..114.0).contains(&mb), "total {mb} MiB");
        let ratio = p.registry.compression_ratio(1);
        // Table 11: 310/r ×
        assert!((295.0..325.0).contains(&ratio), "ratio {ratio}");
        // Table 7: 7730 MB/epoch
        let per_epoch = p.registry.total_bytes() as f64 * p.steps_per_epoch / MIB;
        assert!((7400.0..8100.0).contains(&per_epoch), "{per_epoch} MiB/epoch");
    }

    #[test]
    fn transformer_compression_matches_table9() {
        let p = transformer_wikitext103();
        // ~247M parameters (Baevski & Auli)
        let params = p.registry.numel() as f64 / 1e6;
        assert!((200.0..280.0).contains(&params), "{params}M params");
        // Table 9: rank 32 ⇒ 14×, rank 4 ⇒ 105×
        let r32 = p.registry.compression_ratio(32);
        assert!((11.0..18.0).contains(&r32), "rank-32 ratio {r32}");
        let r4 = p.registry.compression_ratio(4);
        assert!((85.0..135.0).contains(&r4), "rank-4 ratio {r4}");
    }
}
