//! # PowerSGD — practical low-rank gradient compression
//!
//! Reproduction of Vogels, Karimireddy & Jaggi, *PowerSGD: Practical
//! Low-Rank Gradient Compression for Distributed Optimization* (NeurIPS
//! 2019) as a three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the distributed-training coordinator: simulated
//!   multi-worker data parallelism, collectives, nine gradient
//!   compressors, error-feedback SGD, metrics and a network cost model.
//! - **Transport engine (`transport`)** — the concurrent execution
//!   substrate under L3: thread-per-worker channel-based ring
//!   collectives, DDP-style gradient bucketing, and a comm/compute
//!   overlap scheduler over heterogeneous clusters (per-link α/β,
//!   per-worker stragglers).
//! - **L2 (`python/compile/`)** — JAX models AOT-lowered to HLO text,
//!   executed from Rust via PJRT (`runtime`).
//! - **L1 (`python/compile/kernels/`)** — Pallas kernels for the
//!   compression hot-spot, verified against pure-jnp oracles.
//! - **Experiments (`experiments`)** — declarative scenario registry
//!   reproducing the paper's §5 sweeps (`powersgd experiment`):
//!   versioned `EXPERIMENTS_*.json` artifacts plus a deterministic
//!   generated `REPORT.md` with paper-style tables, including measured
//!   wire bytes from real threaded-engine runs.
//!
//! See DESIGN.md for the system inventory and per-experiment index.
#![warn(missing_docs)]
pub mod collectives;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod runtime;
pub mod compress;
pub mod grad;
pub mod linalg;
pub mod net;
pub mod obs;
pub mod optim;
pub mod profiles;
pub mod simulate;
pub mod tensor;
pub mod transport;
pub mod util;
