//! Channel-based ring transport and thread-per-worker collectives.
//!
//! [`InProcRing::endpoints`] wires `W` [`RingNode`]s into a directed
//! ring of `std::sync::mpsc` channels. [`ring_all_reduce_sum_threaded`]
//! and [`ring_all_gather_threaded`] then give every worker its own OS
//! thread; each thread runs the per-worker half of the collective
//! ([`ring_all_reduce_worker`] / [`ring_all_gather_worker`]) against the
//! [`Transport`] trait, so a future TCP transport plugs in by
//! implementing `Transport` — the collective algorithms don't change.
//!
//! **Determinism.** The reduce-scatter schedule (chunk boundaries at
//! `c·n/W`, one accumulation per worker per step, partial sums forwarded
//! around the ring) is exactly the schedule of the lockstep
//! [`crate::collectives::ring_all_reduce_sum`]: every floating-point
//! addition happens in the same order on the same values, regardless of
//! how the OS schedules the threads (channels sequence all cross-worker
//! data flow). The threaded engine therefore matches the lockstep oracle
//! *bitwise*, not just within associativity tolerance — see
//! `tests/integration_transport.rs`.

use crate::obs::{span, Phase};
use std::sync::mpsc::{channel, Receiver, Sender};

/// A worker's point-to-point endpoint in a directed ring.
///
/// Generic over the message type `M` so the same trait carries f32
/// chunks (all-reduce), byte-packed sign bitmaps, and whole gathered
/// messages.
pub trait Transport<M: Send = Vec<f32>>: Send {
    /// This worker's position in the ring.
    fn rank(&self) -> usize;
    /// Number of workers in the ring.
    fn world(&self) -> usize;
    /// Send a message to the ring successor (never blocks).
    fn send_next(&self, msg: M);
    /// Receive the next message from the ring predecessor (blocks).
    fn recv_prev(&self) -> M;
}

/// [`Transport`] endpoint backed by in-process mpsc channels.
pub struct RingNode<M: Send = Vec<f32>> {
    rank: usize,
    world: usize,
    tx_next: Sender<M>,
    rx_prev: Receiver<M>,
}

impl<M: Send> Transport<M> for RingNode<M> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send_next(&self, msg: M) {
        let _span = span(Phase::RingSend);
        self.tx_next.send(msg).expect("ring successor hung up");
    }

    fn recv_prev(&self) -> M {
        // The span covers blocked time: recv wait is exactly the
        // exposed-communication gap the trace is meant to show.
        let _span = span(Phase::RingRecv);
        self.rx_prev.recv().expect("ring predecessor hung up")
    }
}

/// In-process ring fabric: a factory for connected [`RingNode`]s.
pub struct InProcRing;

impl InProcRing {
    /// Build `world` endpoints wired into a directed ring: node `i`
    /// sends to node `(i+1) % world` and receives from
    /// `(i+world-1) % world`.
    pub fn endpoints<M: Send>(world: usize) -> Vec<RingNode<M>> {
        assert!(world > 0, "ring needs at least one worker");
        let mut txs: Vec<Sender<M>> = Vec::with_capacity(world);
        let mut rxs: Vec<Option<Receiver<M>>> = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(Some(rx));
        }
        (0..world)
            .map(|i| RingNode {
                rank: i,
                world,
                tx_next: txs[i].clone(),
                rx_prev: rxs[(i + world - 1) % world]
                    .take()
                    .expect("each receiver is handed out exactly once"),
            })
            .collect()
    }
}

/// A dual-typed in-process endpoint: one f32 ring and one byte ring
/// with the same rank assignment, behind a single value.
///
/// This is the in-process shape of a multi-process endpoint
/// (`transport::tcp::TcpRing` multiplexes both message types over one
/// connection pair; here each type gets its own channel ring), so code
/// written against `Transport<Vec<f32>> + Transport<Vec<u8>>` — the
/// per-worker compression rounds, the metered TCP-harness trajectory —
/// runs unmodified on threads without sockets. The experiment
/// subsystem's measured wire-byte check
/// ([`crate::experiments::measured_wire_check`]) and the endpoint-
/// compressor tests are the main users.
pub struct InProcDuplex {
    f32s: RingNode<Vec<f32>>,
    bytes: RingNode<Vec<u8>>,
}

impl InProcDuplex {
    /// Build `world` connected dual-typed endpoints (rank `i` sends to
    /// rank `(i+1) % world` on both rings).
    pub fn endpoints(world: usize) -> Vec<InProcDuplex> {
        InProcRing::endpoints::<Vec<f32>>(world)
            .into_iter()
            .zip(InProcRing::endpoints::<Vec<u8>>(world))
            .map(|(f32s, bytes)| InProcDuplex { f32s, bytes })
            .collect()
    }
}

impl Transport<Vec<f32>> for InProcDuplex {
    fn rank(&self) -> usize {
        self.f32s.rank()
    }

    fn world(&self) -> usize {
        self.f32s.world()
    }

    fn send_next(&self, msg: Vec<f32>) {
        self.f32s.send_next(msg);
    }

    fn recv_prev(&self) -> Vec<f32> {
        self.f32s.recv_prev()
    }
}

impl Transport<Vec<u8>> for InProcDuplex {
    fn rank(&self) -> usize {
        Transport::<Vec<u8>>::rank(&self.bytes)
    }

    fn world(&self) -> usize {
        Transport::<Vec<u8>>::world(&self.bytes)
    }

    fn send_next(&self, msg: Vec<u8>) {
        self.bytes.send_next(msg);
    }

    fn recv_prev(&self) -> Vec<u8> {
        self.bytes.recv_prev()
    }
}

/// The per-worker half of ring all-reduce (sum), run by one thread per
/// worker against its [`Transport`] endpoint. `buf` is this worker's
/// full-length buffer; on return it holds the elementwise sum over all
/// workers.
pub fn ring_all_reduce_worker<T: Transport + ?Sized>(t: &T, buf: &mut [f32]) {
    let w = t.world();
    let n = buf.len();
    if w == 1 || n == 0 {
        return;
    }
    let i = t.rank();
    let starts: Vec<usize> = (0..=w).map(|c| c * n / w).collect();

    // Outgoing messages reuse the Vec received on the previous step
    // (its owner, our predecessor, is done with it), so a worker
    // allocates one chunk per collective instead of one per step.
    // Values and send order are unchanged — this is a buffer-recycling
    // optimization only.
    let mut spare: Option<Vec<f32>> = None;
    let send_chunk = |t: &T, src: &[f32], spare: &mut Option<Vec<f32>>| {
        let msg = match spare.take() {
            Some(mut v) => {
                v.clear();
                v.extend_from_slice(src);
                v
            }
            None => src.to_vec(),
        };
        t.send_next(msg);
    };

    // Phase 1: reduce-scatter. Step s: send chunk (i−s) mod w to the
    // successor, accumulate chunk (i−1−s) mod w from the predecessor.
    // The chunk sent at step s is exactly the partial sum accumulated at
    // step s−1, so partial sums travel the ring just like the lockstep
    // reference.
    for s in 0..w - 1 {
        let c_send = (i + w - s) % w;
        send_chunk(t, &buf[starts[c_send]..starts[c_send + 1]], &mut spare);
        let c_recv = (i + 2 * w - 1 - s) % w;
        let chunk = t.recv_prev();
        let dst = &mut buf[starts[c_recv]..starts[c_recv + 1]];
        debug_assert_eq!(dst.len(), chunk.len(), "ring chunk size mismatch");
        for (d, v) in dst.iter_mut().zip(chunk.iter()) {
            *d += v;
        }
        spare = Some(chunk);
    }

    // Phase 2: all-gather of the reduced chunks. Step s: send chunk
    // (i+1−s) mod w, overwrite chunk (i−s) mod w from the predecessor.
    for s in 0..w - 1 {
        let c_send = (i + 1 + w - s) % w;
        send_chunk(t, &buf[starts[c_send]..starts[c_send + 1]], &mut spare);
        let c_recv = (i + w - s) % w;
        let chunk = t.recv_prev();
        buf[starts[c_recv]..starts[c_recv + 1]].copy_from_slice(&chunk);
        spare = Some(chunk);
    }
}

/// Ring all-reduce (sum) on the threaded engine: every buffer is owned
/// by its own OS thread for the duration of the collective; chunks move
/// over mpsc channels. Bitwise-identical to the lockstep
/// [`crate::collectives::ring_all_reduce_sum`].
pub fn ring_all_reduce_sum_threaded(buffers: &mut [Vec<f32>]) {
    let w = buffers.len();
    if w == 0 {
        return;
    }
    let n = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == n), "buffer length mismatch");
    if w == 1 || n == 0 {
        return;
    }
    let nodes = InProcRing::endpoints::<Vec<f32>>(w);
    std::thread::scope(|scope| {
        for (node, buf) in nodes.into_iter().zip(buffers.iter_mut()) {
            scope.spawn(move || {
                // One stable trace track per ring position: these
                // threads are re-spawned every collective, and keying
                // by rank keeps a trace at one row per worker instead
                // of one per short-lived thread.
                crate::obs::set_track(&format!("ring-{}", node.rank()));
                ring_all_reduce_worker(&node, buf)
            });
        }
    });
}

/// The per-worker half of ring all-gather: after `W−1` steps every
/// worker holds all `W` messages, indexed by source rank.
pub fn ring_all_gather_worker<M, T>(t: &T, msg: M) -> Vec<M>
where
    M: Clone + Send + Default,
    T: Transport<M> + ?Sized,
{
    let w = t.world();
    let i = t.rank();
    let mut gathered: Vec<M> = vec![M::default(); w];
    if w == 1 {
        gathered[0] = msg;
        return gathered;
    }
    gathered[i] = msg;
    // Step s forwards the message that originated at rank (i−s) mod w —
    // i.e. the one received at step s−1 (own message at step 0).
    for s in 0..w - 1 {
        let src_send = (i + w - s) % w;
        t.send_next(gathered[src_send].clone());
        let src_recv = (i + 2 * w - 1 - s) % w;
        gathered[src_recv] = t.recv_prev();
    }
    gathered
}

/// Ring all-gather on the threaded engine. All workers end up with
/// identical gathered views (each message is copied verbatim around the
/// ring), so only one view is returned; callers share it (see the `Arc`
/// sharing in [`crate::collectives::all_gather`]).
pub fn ring_all_gather_threaded<M>(messages: &[M]) -> Vec<M>
where
    M: Clone + Send + Sync + Default,
{
    let w = messages.len();
    if w == 0 {
        return Vec::new();
    }
    if w == 1 {
        return messages.to_vec();
    }
    let nodes = InProcRing::endpoints::<M>(w);
    let mut views: Vec<Vec<M>> = std::thread::scope(|scope| {
        let handles: Vec<_> = nodes
            .into_iter()
            .zip(messages.iter())
            .map(|(node, msg)| {
                scope.spawn(move || {
                    crate::obs::set_track(&format!("ring-{}", node.rank()));
                    ring_all_gather_worker(&node, msg.clone())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("gather worker panicked"))
            .collect()
    });
    views.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_buffers(w: usize, n: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
        (0..w)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn threaded_ring_matches_lockstep_bitwise() {
        let mut rng = Rng::new(61);
        for &w in &[1usize, 2, 3, 5, 8, 16] {
            for &n in &[0usize, 1, 7, 256, 1003] {
                let bufs = random_buffers(w, n, &mut rng);
                let mut lockstep = bufs.clone();
                crate::collectives::ring_all_reduce_sum_lockstep(&mut lockstep);
                let mut threaded = bufs.clone();
                ring_all_reduce_sum_threaded(&mut threaded);
                assert_eq!(threaded, lockstep, "w={w} n={n}");
            }
        }
    }

    #[test]
    fn threaded_gather_preserves_source_order() {
        let msgs: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32; 3]).collect();
        let view = ring_all_gather_threaded(&msgs);
        assert_eq!(view, msgs);
    }

    #[test]
    fn threaded_gather_bytes() {
        let msgs: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8, 10 + i as u8]).collect();
        let view = ring_all_gather_threaded(&msgs);
        assert_eq!(view, msgs);
    }

    #[test]
    fn gather_handles_uneven_message_lengths() {
        let msgs = vec![vec![1.0f32], vec![2.0, 3.0], vec![]];
        let view = ring_all_gather_threaded(&msgs);
        assert_eq!(view, msgs);
    }

    #[test]
    fn single_worker_ring_is_identity() {
        let mut bufs = vec![vec![4.0f32, -2.0]];
        ring_all_reduce_sum_threaded(&mut bufs);
        assert_eq!(bufs[0], vec![4.0, -2.0]);
        let view = ring_all_gather_threaded(&[vec![9.0f32]]);
        assert_eq!(view, vec![vec![9.0]]);
    }

    #[test]
    fn endpoints_form_a_cycle() {
        let nodes = InProcRing::endpoints::<Vec<f32>>(3);
        // Pass one token all the way around the ring by hand.
        nodes[0].send_next(vec![7.0]);
        let at1 = nodes[1].recv_prev();
        nodes[1].send_next(at1);
        let at2 = nodes[2].recv_prev();
        nodes[2].send_next(at2);
        assert_eq!(nodes[0].recv_prev(), vec![7.0]);
    }
}
