//! Channel-based ring transport and thread-per-worker collectives.
//!
//! [`InProcRing::endpoints`] wires `W` [`RingNode`]s into a directed
//! ring of `std::sync::mpsc` channels. [`ring_all_reduce_sum_threaded`]
//! and [`ring_all_gather_threaded`] then give every worker its own OS
//! thread; each thread runs the per-worker half of the collective
//! ([`ring_all_reduce_worker`] / [`ring_all_gather_worker`]) against the
//! [`Transport`] trait, so a future TCP transport plugs in by
//! implementing `Transport` — the collective algorithms don't change.
//!
//! **Determinism.** The reduce-scatter schedule (chunk boundaries at
//! `c·n/W`, one accumulation per worker per step, partial sums forwarded
//! around the ring) is exactly the schedule of the lockstep
//! [`crate::collectives::ring_all_reduce_sum`]: every floating-point
//! addition happens in the same order on the same values, regardless of
//! how the OS schedules the threads (channels sequence all cross-worker
//! data flow). The threaded engine therefore matches the lockstep oracle
//! *bitwise*, not just within associativity tolerance — see
//! `tests/integration_transport.rs`.

use crate::obs::{span, Phase};
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

/// Handle for an in-flight posted operation on one [`Transport`]
/// endpoint. Tickets are endpoint-local and message-type-local: a
/// ticket from `post_send::<Vec<f32>>` on endpoint A means nothing to
/// endpoint B or to the `Vec<u8>` half of a duplex endpoint.
pub type Ticket = u64;

/// Resolution state of a posted operation.
#[derive(Debug, Clone, PartialEq)]
pub enum Completion<M> {
    /// The operation has not completed yet (only returned by `poll`).
    Pending,
    /// A posted send has completed — the transport took responsibility
    /// for delivery. Sends complete at post time on every backend.
    Sent,
    /// A posted receive completed with the delivered message.
    Received(M),
}

/// A worker's point-to-point endpoint in a directed ring, as a
/// completion-queue API: operations are *posted* (never blocking on the
/// peer) and return a [`Ticket`]; `poll`/`wait` resolve tickets.
///
/// Generic over the message type `M` so the same trait carries f32
/// chunks (all-reduce), byte-packed sign bitmaps, and whole gathered
/// messages.
///
/// # Contract
///
/// - `post_send` **completes at post**: the transport buffers the
///   message (mpsc channel, or a dedicated writer thread for TCP) and
///   returns immediately. A delivery failure (dead peer, timeout)
///   surfaces on a *later* operation on the same endpoint, with the
///   failing rank named in the panic message.
/// - `post_recv` registers interest in the next message from the ring
///   predecessor. Receives fulfill in FIFO post order: the k-th posted
///   receive gets the k-th message off the link. This positional
///   matching is what makes pipelined schedules deterministic — every
///   worker posts operations at the same program points, so the k-th
///   frame on a link always means the same thing on both sides (see
///   [`crate::transport::pipeline`]).
/// - `wait` blocks until the ticket resolves; `poll` never blocks.
///   Waiting on a recv ticket records a [`Phase::RingRecv`] span
///   covering the blocked time — the exposed-communication gap the
///   trace is meant to show.
///
/// The blocking `send_next`/`recv_prev` wrappers are provided for
/// lockstep callers (post + wait in one call); the collective
/// algorithms below still use them, so pre-redesign code runs
/// unmodified.
pub trait Transport<M: Send = Vec<f32>>: Send {
    /// This worker's position in the ring.
    fn rank(&self) -> usize;
    /// Number of workers in the ring.
    fn world(&self) -> usize;
    /// Post a send to the ring successor. Never blocks on the peer;
    /// completes at post (see the trait-level contract).
    fn post_send(&self, msg: M) -> Ticket;
    /// Post a receive from the ring predecessor. Never blocks.
    /// Receives fulfill in FIFO post order.
    fn post_recv(&self) -> Ticket;
    /// Resolve a ticket without blocking.
    fn poll(&self, ticket: Ticket) -> Completion<M>;
    /// Block until the ticket resolves. Never returns `Pending`.
    fn wait(&self, ticket: Ticket) -> Completion<M>;

    /// Send a message to the ring successor. Completes at post — the
    /// transport takes responsibility for delivery; it does **not**
    /// wait for the peer (but see the posted-send failure contract).
    fn send_next(&self, msg: M) {
        let t = self.post_send(msg);
        match self.wait(t) {
            Completion::Sent => {}
            _ => panic!("send ticket resolved to a non-send completion"),
        }
    }

    /// Receive the next message from the ring predecessor (blocks).
    fn recv_prev(&self) -> M {
        let t = self.post_recv();
        match self.wait(t) {
            Completion::Received(m) => m,
            _ => panic!("recv ticket resolved without a message"),
        }
    }
}

/// Completion-queue bookkeeping shared by channel-backed endpoints:
/// ticket allocation, the FIFO of outstanding receives, and messages
/// that arrived before their ticket was waited on.
struct CqState<M> {
    next_ticket: Ticket,
    /// Posted, unfulfilled recv tickets in post order.
    pending: VecDeque<Ticket>,
    /// Fulfilled recv tickets whose message has not been claimed yet.
    ready: HashMap<Ticket, M>,
}

impl<M> Default for CqState<M> {
    fn default() -> Self {
        CqState { next_ticket: 0, pending: VecDeque::new(), ready: HashMap::new() }
    }
}

impl<M> CqState<M> {
    fn fresh(&mut self) -> Ticket {
        let t = self.next_ticket;
        self.next_ticket += 1;
        t
    }

    fn is_recv(&self, t: Ticket) -> bool {
        self.ready.contains_key(&t) || self.pending.contains(&t)
    }

    /// Hand an arrived message to the oldest outstanding recv ticket.
    fn fulfill(&mut self, msg: M) {
        let owner = self.pending.pop_front().expect("ring message with no posted receive");
        self.ready.insert(owner, msg);
    }
}

/// [`Transport`] endpoint backed by in-process mpsc channels.
///
/// The endpoint is `Send` but not `Sync`: each ring position is owned
/// and driven by exactly one worker thread, which is what makes the
/// `RefCell` completion-queue state safe.
pub struct RingNode<M: Send = Vec<f32>> {
    rank: usize,
    world: usize,
    tx_next: Sender<M>,
    rx_prev: Receiver<M>,
    cq: RefCell<CqState<M>>,
}

impl<M: Send> Transport<M> for RingNode<M> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn post_send(&self, msg: M) -> Ticket {
        let _span = span(Phase::RingSend);
        self.tx_next.send(msg).expect("ring successor hung up");
        self.cq.borrow_mut().fresh()
    }

    fn post_recv(&self) -> Ticket {
        let mut cq = self.cq.borrow_mut();
        let t = cq.fresh();
        cq.pending.push_back(t);
        // Ticket-depth telemetry: posting order is program order per
        // endpoint, so the depth-at-post histogram is deterministic.
        crate::obs::metrics::add(crate::obs::metrics::Counter::RecvTicketsPosted, 1);
        crate::obs::metrics::observe(
            crate::obs::metrics::Histogram::InflightDepth,
            cq.pending.len() as f64,
        );
        crate::obs::metrics::raise_max(
            crate::obs::metrics::MaxGauge::InflightDepthPeak,
            cq.pending.len() as u64,
        );
        t
    }

    fn poll(&self, ticket: Ticket) -> Completion<M> {
        let mut cq = self.cq.borrow_mut();
        if !cq.is_recv(ticket) {
            return Completion::Sent;
        }
        // Drain whatever already arrived; FIFO assignment to tickets.
        loop {
            match self.rx_prev.try_recv() {
                Ok(msg) => cq.fulfill(msg),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        match cq.ready.remove(&ticket) {
            Some(m) => Completion::Received(m),
            None => Completion::Pending,
        }
    }

    fn wait(&self, ticket: Ticket) -> Completion<M> {
        let mut cq = self.cq.borrow_mut();
        if !cq.is_recv(ticket) {
            return Completion::Sent;
        }
        // The span covers blocked time: recv wait is exactly the
        // exposed-communication gap the trace is meant to show.
        let _span = span(Phase::RingRecv);
        while !cq.ready.contains_key(&ticket) {
            let msg = self.rx_prev.recv().expect("ring predecessor hung up");
            cq.fulfill(msg);
        }
        Completion::Received(cq.ready.remove(&ticket).expect("ticket just fulfilled"))
    }
}

/// In-process ring fabric: a factory for connected [`RingNode`]s.
pub struct InProcRing;

impl InProcRing {
    /// Build `world` endpoints wired into a directed ring: node `i`
    /// sends to node `(i+1) % world` and receives from
    /// `(i+world-1) % world`.
    pub fn endpoints<M: Send>(world: usize) -> Vec<RingNode<M>> {
        assert!(world > 0, "ring needs at least one worker");
        let mut txs: Vec<Sender<M>> = Vec::with_capacity(world);
        let mut rxs: Vec<Option<Receiver<M>>> = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(Some(rx));
        }
        (0..world)
            .map(|i| RingNode {
                rank: i,
                world,
                tx_next: txs[i].clone(),
                rx_prev: rxs[(i + world - 1) % world]
                    .take()
                    .expect("each receiver is handed out exactly once"),
                cq: RefCell::new(CqState::default()),
            })
            .collect()
    }
}

/// A dual-typed in-process endpoint: one f32 ring and one byte ring
/// with the same rank assignment, behind a single value.
///
/// This is the in-process shape of a multi-process endpoint
/// (`transport::tcp::TcpRing` multiplexes both message types over one
/// connection pair; here each type gets its own channel ring), so code
/// written against `Transport<Vec<f32>> + Transport<Vec<u8>>` — the
/// per-worker compression rounds, the metered TCP-harness trajectory —
/// runs unmodified on threads without sockets. The experiment
/// subsystem's measured wire-byte check
/// ([`crate::experiments::measured_wire_check`]) and the endpoint-
/// compressor tests are the main users.
pub struct InProcDuplex {
    f32s: RingNode<Vec<f32>>,
    bytes: RingNode<Vec<u8>>,
}

impl InProcDuplex {
    /// Build `world` connected dual-typed endpoints (rank `i` sends to
    /// rank `(i+1) % world` on both rings).
    pub fn endpoints(world: usize) -> Vec<InProcDuplex> {
        InProcRing::endpoints::<Vec<f32>>(world)
            .into_iter()
            .zip(InProcRing::endpoints::<Vec<u8>>(world))
            .map(|(f32s, bytes)| InProcDuplex { f32s, bytes })
            .collect()
    }
}

impl Transport<Vec<f32>> for InProcDuplex {
    fn rank(&self) -> usize {
        self.f32s.rank()
    }

    fn world(&self) -> usize {
        self.f32s.world()
    }

    fn post_send(&self, msg: Vec<f32>) -> Ticket {
        self.f32s.post_send(msg)
    }

    fn post_recv(&self) -> Ticket {
        Transport::<Vec<f32>>::post_recv(&self.f32s)
    }

    fn poll(&self, ticket: Ticket) -> Completion<Vec<f32>> {
        self.f32s.poll(ticket)
    }

    fn wait(&self, ticket: Ticket) -> Completion<Vec<f32>> {
        self.f32s.wait(ticket)
    }
}

impl Transport<Vec<u8>> for InProcDuplex {
    fn rank(&self) -> usize {
        Transport::<Vec<u8>>::rank(&self.bytes)
    }

    fn world(&self) -> usize {
        Transport::<Vec<u8>>::world(&self.bytes)
    }

    fn post_send(&self, msg: Vec<u8>) -> Ticket {
        self.bytes.post_send(msg)
    }

    fn post_recv(&self) -> Ticket {
        Transport::<Vec<u8>>::post_recv(&self.bytes)
    }

    fn poll(&self, ticket: Ticket) -> Completion<Vec<u8>> {
        self.bytes.poll(ticket)
    }

    fn wait(&self, ticket: Ticket) -> Completion<Vec<u8>> {
        self.bytes.wait(ticket)
    }
}

/// The per-worker half of ring all-reduce (sum), run by one thread per
/// worker against its [`Transport`] endpoint. `buf` is this worker's
/// full-length buffer; on return it holds the elementwise sum over all
/// workers.
pub fn ring_all_reduce_worker<T: Transport + ?Sized>(t: &T, buf: &mut [f32]) {
    let w = t.world();
    let n = buf.len();
    if w == 1 || n == 0 {
        return;
    }
    let i = t.rank();
    let starts: Vec<usize> = (0..=w).map(|c| c * n / w).collect();

    // Outgoing messages reuse the Vec received on the previous step
    // (its owner, our predecessor, is done with it), so a worker
    // allocates one chunk per collective instead of one per step.
    // Values and send order are unchanged — this is a buffer-recycling
    // optimization only.
    let mut spare: Option<Vec<f32>> = None;
    let send_chunk = |t: &T, src: &[f32], spare: &mut Option<Vec<f32>>| {
        let msg = match spare.take() {
            Some(mut v) => {
                v.clear();
                v.extend_from_slice(src);
                v
            }
            None => src.to_vec(),
        };
        t.send_next(msg);
    };

    // Phase 1: reduce-scatter. Step s: send chunk (i−s) mod w to the
    // successor, accumulate chunk (i−1−s) mod w from the predecessor.
    // The chunk sent at step s is exactly the partial sum accumulated at
    // step s−1, so partial sums travel the ring just like the lockstep
    // reference.
    for s in 0..w - 1 {
        let c_send = (i + w - s) % w;
        send_chunk(t, &buf[starts[c_send]..starts[c_send + 1]], &mut spare);
        let c_recv = (i + 2 * w - 1 - s) % w;
        let chunk = t.recv_prev();
        let dst = &mut buf[starts[c_recv]..starts[c_recv + 1]];
        debug_assert_eq!(dst.len(), chunk.len(), "ring chunk size mismatch");
        for (d, v) in dst.iter_mut().zip(chunk.iter()) {
            *d += v;
        }
        spare = Some(chunk);
    }

    // Phase 2: all-gather of the reduced chunks. Step s: send chunk
    // (i+1−s) mod w, overwrite chunk (i−s) mod w from the predecessor.
    for s in 0..w - 1 {
        let c_send = (i + 1 + w - s) % w;
        send_chunk(t, &buf[starts[c_send]..starts[c_send + 1]], &mut spare);
        let c_recv = (i + w - s) % w;
        let chunk = t.recv_prev();
        buf[starts[c_recv]..starts[c_recv + 1]].copy_from_slice(&chunk);
        spare = Some(chunk);
    }
}

/// Ring all-reduce (sum) on the threaded engine: every buffer is owned
/// by its own OS thread for the duration of the collective; chunks move
/// over mpsc channels. Bitwise-identical to the lockstep
/// [`crate::collectives::ring_all_reduce_sum`].
pub fn ring_all_reduce_sum_threaded(buffers: &mut [Vec<f32>]) {
    let w = buffers.len();
    if w == 0 {
        return;
    }
    let n = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == n), "buffer length mismatch");
    if w == 1 || n == 0 {
        return;
    }
    let nodes = InProcRing::endpoints::<Vec<f32>>(w);
    std::thread::scope(|scope| {
        for (node, buf) in nodes.into_iter().zip(buffers.iter_mut()) {
            scope.spawn(move || {
                // One stable trace track per ring position: these
                // threads are re-spawned every collective, and keying
                // by rank keeps a trace at one row per worker instead
                // of one per short-lived thread.
                crate::obs::set_track(&format!("ring-{}", node.rank()));
                ring_all_reduce_worker(&node, buf)
            });
        }
    });
}

/// The per-worker half of ring all-gather: after `W−1` steps every
/// worker holds all `W` messages, indexed by source rank.
pub fn ring_all_gather_worker<M, T>(t: &T, msg: M) -> Vec<M>
where
    M: Clone + Send + Default,
    T: Transport<M> + ?Sized,
{
    let w = t.world();
    let i = t.rank();
    let mut gathered: Vec<M> = vec![M::default(); w];
    if w == 1 {
        gathered[0] = msg;
        return gathered;
    }
    gathered[i] = msg;
    // Step s forwards the message that originated at rank (i−s) mod w —
    // i.e. the one received at step s−1 (own message at step 0).
    for s in 0..w - 1 {
        let src_send = (i + w - s) % w;
        t.send_next(gathered[src_send].clone());
        let src_recv = (i + 2 * w - 1 - s) % w;
        gathered[src_recv] = t.recv_prev();
    }
    gathered
}

/// Ring all-gather on the threaded engine. All workers end up with
/// identical gathered views (each message is copied verbatim around the
/// ring), so only one view is returned; callers share it (see the `Arc`
/// sharing in [`crate::collectives::all_gather`]).
pub fn ring_all_gather_threaded<M>(messages: &[M]) -> Vec<M>
where
    M: Clone + Send + Sync + Default,
{
    let w = messages.len();
    if w == 0 {
        return Vec::new();
    }
    if w == 1 {
        return messages.to_vec();
    }
    let nodes = InProcRing::endpoints::<M>(w);
    let mut views: Vec<Vec<M>> = std::thread::scope(|scope| {
        let handles: Vec<_> = nodes
            .into_iter()
            .zip(messages.iter())
            .map(|(node, msg)| {
                scope.spawn(move || {
                    crate::obs::set_track(&format!("ring-{}", node.rank()));
                    ring_all_gather_worker(&node, msg.clone())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("gather worker panicked"))
            .collect()
    });
    views.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_buffers(w: usize, n: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
        (0..w)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn threaded_ring_matches_lockstep_bitwise() {
        let mut rng = Rng::new(61);
        for &w in &[1usize, 2, 3, 5, 8, 16] {
            for &n in &[0usize, 1, 7, 256, 1003] {
                let bufs = random_buffers(w, n, &mut rng);
                let mut lockstep = bufs.clone();
                crate::collectives::ring_all_reduce_sum_lockstep(&mut lockstep);
                let mut threaded = bufs.clone();
                ring_all_reduce_sum_threaded(&mut threaded);
                assert_eq!(threaded, lockstep, "w={w} n={n}");
            }
        }
    }

    #[test]
    fn threaded_gather_preserves_source_order() {
        let msgs: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32; 3]).collect();
        let view = ring_all_gather_threaded(&msgs);
        assert_eq!(view, msgs);
    }

    #[test]
    fn threaded_gather_bytes() {
        let msgs: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8, 10 + i as u8]).collect();
        let view = ring_all_gather_threaded(&msgs);
        assert_eq!(view, msgs);
    }

    #[test]
    fn gather_handles_uneven_message_lengths() {
        let msgs = vec![vec![1.0f32], vec![2.0, 3.0], vec![]];
        let view = ring_all_gather_threaded(&msgs);
        assert_eq!(view, msgs);
    }

    #[test]
    fn single_worker_ring_is_identity() {
        let mut bufs = vec![vec![4.0f32, -2.0]];
        ring_all_reduce_sum_threaded(&mut bufs);
        assert_eq!(bufs[0], vec![4.0, -2.0]);
        let view = ring_all_gather_threaded(&[vec![9.0f32]]);
        assert_eq!(view, vec![vec![9.0]]);
    }

    #[test]
    fn posted_receives_fulfill_in_fifo_order() {
        let nodes = InProcRing::endpoints::<Vec<f32>>(2);
        // Post two receives on node 1 before anything arrives, then
        // send two messages from node 0: the first ticket must get the
        // first message even when the second ticket is waited first.
        let t_a = Transport::<Vec<f32>>::post_recv(&nodes[1]);
        let t_b = Transport::<Vec<f32>>::post_recv(&nodes[1]);
        assert_eq!(nodes[1].poll(t_a), Completion::Pending);
        nodes[0].post_send(vec![1.0]);
        nodes[0].post_send(vec![2.0]);
        assert_eq!(nodes[1].wait(t_b), Completion::Received(vec![2.0]));
        assert_eq!(nodes[1].wait(t_a), Completion::Received(vec![1.0]));
    }

    #[test]
    fn send_tickets_complete_at_post() {
        let nodes = InProcRing::endpoints::<Vec<f32>>(2);
        let t = nodes[0].post_send(vec![3.0]);
        assert_eq!(nodes[0].poll(t), Completion::<Vec<f32>>::Sent);
        assert_eq!(nodes[0].wait(t), Completion::<Vec<f32>>::Sent);
        // The posted message is still delivered.
        assert_eq!(nodes[1].recv_prev(), vec![3.0]);
    }

    #[test]
    fn poll_resolves_an_arrived_receive_without_blocking() {
        let nodes = InProcRing::endpoints::<Vec<u8>>(2);
        nodes[0].post_send(vec![9u8]);
        let t = Transport::<Vec<u8>>::post_recv(&nodes[1]);
        // The message is already in the channel; poll must find it.
        let got = loop {
            match nodes[1].poll(t) {
                Completion::Pending => std::thread::yield_now(),
                other => break other,
            }
        };
        assert_eq!(got, Completion::Received(vec![9u8]));
    }

    #[test]
    fn endpoints_form_a_cycle() {
        let nodes = InProcRing::endpoints::<Vec<f32>>(3);
        // Pass one token all the way around the ring by hand.
        nodes[0].send_next(vec![7.0]);
        let at1 = nodes[1].recv_prev();
        nodes[1].send_next(at1);
        let at2 = nodes[2].recv_prev();
        nodes[2].send_next(at2);
        assert_eq!(nodes[0].recv_prev(), vec![7.0]);
    }
}
