//! PyTorch-DDP-style gradient bucketing.
//!
//! Launching one collective per layer drowns small tensors in α (latency)
//! terms; launching one collective for the whole model forfeits
//! comm/compute overlap. DDP's answer — adopted here — is to pack layers
//! into fixed-capacity buckets in **gradient-ready order** (reverse
//! declaration order, because backprop produces gradients output→input)
//! and launch one collective per bucket as soon as its layers are ready.
//!
//! The capacity is measured in *raw gradient bytes* (like DDP's
//! `bucket_cap_mb`): readiness is governed by backprop, which runs at
//! raw-gradient granularity, while the wire cost of the bucket is the sum
//! of its layers' *compressed* message bytes.

/// MiB → bytes for bucket capacities (negative input clamps to 0,
/// which [`Bucketer::new`] treats as unbounded). The single home for
/// the CLI's `--bucket-mb` unit convention.
pub fn bytes_from_mb(mb: f64) -> u64 {
    (mb.max(0.0) * 1024.0 * 1024.0) as u64
}

/// Per-layer sizing input to the bucketer, in declaration order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerTiming {
    /// Compressed bytes this layer contributes to the wire message.
    pub msg_bytes: u64,
    /// Raw gradient bytes (drives backprop-readiness and bucket caps).
    pub raw_bytes: u64,
}

/// One bucket of layers whose compressed messages travel together.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Bucket {
    /// Layer indices, in gradient-ready order (reverse declaration).
    pub layers: Vec<usize>,
    /// Compressed bytes this bucket puts on the wire per worker.
    pub msg_bytes: u64,
    /// Raw gradient bytes backprop must produce before the bucket is
    /// ready.
    pub raw_bytes: u64,
}

/// Packs layers into fixed-capacity buckets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bucketer {
    cap_bytes: u64,
}

impl Bucketer {
    /// Bucketer with a raw-gradient-byte capacity. `0` is treated as
    /// unbounded (a single bucket — i.e. no bucketing).
    pub fn new(cap_bytes: u64) -> Bucketer {
        Bucketer { cap_bytes: if cap_bytes == 0 { u64::MAX } else { cap_bytes } }
    }

    /// Bucketer with a capacity in MiB (the CLI's `--bucket-mb` unit).
    pub fn from_mb(mb: f64) -> Bucketer {
        Bucketer::new(bytes_from_mb(mb))
    }

    /// Assign layers (given in declaration order) to buckets, walking in
    /// reverse declaration order. A bucket closes when the next layer
    /// would push it past the capacity; a single layer larger than the
    /// capacity still gets a (dedicated) bucket.
    pub fn assign(&self, layers: &[LayerTiming]) -> Vec<Bucket> {
        let mut buckets: Vec<Bucket> = Vec::new();
        let mut cur = Bucket::default();
        for idx in (0..layers.len()).rev() {
            let l = layers[idx];
            if !cur.layers.is_empty() && cur.raw_bytes + l.raw_bytes > self.cap_bytes {
                buckets.push(std::mem::take(&mut cur));
            }
            cur.layers.push(idx);
            cur.msg_bytes += l.msg_bytes;
            cur.raw_bytes += l.raw_bytes;
        }
        if !cur.layers.is_empty() {
            buckets.push(cur);
        }
        buckets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(msg: u64, raw: u64) -> LayerTiming {
        LayerTiming { msg_bytes: msg, raw_bytes: raw }
    }

    #[test]
    fn partitions_every_layer_exactly_once() {
        let layers: Vec<LayerTiming> = (0..13).map(|i| layer(i + 1, 10 * (i + 1))).collect();
        let buckets = Bucketer::new(300).assign(&layers);
        let mut seen: Vec<usize> = buckets.iter().flat_map(|b| b.layers.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..13).collect::<Vec<_>>());
        for b in &buckets {
            let msg: u64 = b.layers.iter().map(|&i| layers[i].msg_bytes).sum();
            assert_eq!(msg, b.msg_bytes);
        }
    }

    #[test]
    fn respects_capacity_except_oversized_layers() {
        let layers = vec![layer(1, 100), layer(1, 100), layer(1, 1000), layer(1, 100)];
        let buckets = Bucketer::new(250).assign(&layers);
        for b in &buckets {
            assert!(b.raw_bytes <= 250 || b.layers.len() == 1, "{b:?}");
        }
        // The 1000-byte layer sits alone in its bucket.
        assert!(buckets.iter().any(|b| b.layers == vec![2]));
    }

    #[test]
    fn reverse_declaration_order() {
        let layers = vec![layer(1, 10); 6];
        let buckets = Bucketer::new(20).assign(&layers);
        assert_eq!(buckets.len(), 3);
        assert_eq!(buckets[0].layers, vec![5, 4]);
        assert_eq!(buckets[1].layers, vec![3, 2]);
        assert_eq!(buckets[2].layers, vec![1, 0]);
    }

    #[test]
    fn zero_capacity_means_single_bucket() {
        let layers = vec![layer(5, 50); 4];
        let buckets = Bucketer::new(0).assign(&layers);
        assert_eq!(buckets.len(), 1);
        assert_eq!(buckets[0].raw_bytes, 200);
        assert_eq!(buckets[0].msg_bytes, 20);
        let via_mb = Bucketer::from_mb(0.0).assign(&layers);
        assert_eq!(via_mb.len(), 1);
    }

    #[test]
    fn empty_layer_list() {
        assert!(Bucketer::new(100).assign(&[]).is_empty());
    }
}
