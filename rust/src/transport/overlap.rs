//! Comm/compute overlap scheduler over a heterogeneous simulated
//! cluster.
//!
//! Agarwal et al. and Zhang et al. (PAPERS.md) both find that gradient
//! compression only yields wall-clock wins when the system overlaps
//! communication with the remaining backprop and buckets small tensors —
//! exactly what PyTorch DDP does for uncompressed SGD. This module
//! prices that schedule: backprop emits per-layer gradients in reverse
//! declaration order; each [`Bucket`]'s collective launches as soon as
//! its layers (plus its share of encode) are done, concurrently with the
//! remaining compute. Two simulated resources serialize work — the
//! compute stream (fwd, per-bucket bwd and encode, final decode) and the
//! network stream (one collective per bucket, FIFO).
//!
//! [`Cluster`] generalizes the α–β [`Backend`](crate::net::Backend) to
//! per-link parameters and per-worker compute jitter: a synchronous ring
//! advances at the pace of its slowest link, and a lockstep collective
//! cannot start before the slowest worker's compute — the straggler and
//! heterogeneous-cluster scenarios.

use super::bucket::{Bucket, LayerTiming};
use crate::collectives::CollKind;
use crate::net::Backend;

/// One directed ring link (worker `i` → `i+1`): latency α (s) and
/// bandwidth β (bytes/s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    /// Per-hop latency α, seconds.
    pub alpha: f64,
    /// Effective bandwidth β, bytes/second.
    pub beta: f64,
}

impl From<&Backend> for Link {
    fn from(b: &Backend) -> Link {
        Link { alpha: b.alpha, beta: b.beta }
    }
}

/// A simulated cluster: per-link α/β and per-worker compute speed.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// Ring links; `links[i]` carries worker `i`'s sends. `links.len()`
    /// is the worker count.
    pub links: Vec<Link>,
    /// Per-worker compute-time multiplier (1.0 = nominal, >1 = slower).
    pub jitter: Vec<f64>,
}

impl Cluster {
    /// Homogeneous cluster: every link gets `backend`'s α/β, every
    /// worker nominal compute.
    pub fn uniform(workers: usize, backend: &Backend) -> Cluster {
        Cluster { links: vec![Link::from(backend); workers], jitter: vec![1.0; workers] }
    }

    /// Homogeneous cluster with worker 0 slowed by `slowdown` (≥ 1):
    /// the straggler scenario.
    pub fn with_straggler(workers: usize, backend: &Backend, slowdown: f64) -> Cluster {
        let mut c = Cluster::uniform(workers, backend);
        if let Some(j) = c.jitter.first_mut() {
            *j = slowdown.max(1.0);
        }
        c
    }

    /// Heterogeneous cluster: deterministic per-worker compute jitter in
    /// `[1, 1+spread)` drawn from `seed`.
    pub fn with_jitter(workers: usize, backend: &Backend, spread: f64, seed: u64) -> Cluster {
        let mut c = Cluster::uniform(workers, backend);
        let mut rng = crate::util::Rng::new(seed);
        for j in c.jitter.iter_mut() {
            *j = 1.0 + spread.max(0.0) * rng.uniform();
        }
        c
    }

    /// Number of workers (= ring links) in the cluster.
    pub fn workers(&self) -> usize {
        self.links.len()
    }

    /// Compute multiplier that gates every lockstep collective: the
    /// slowest worker's.
    pub fn compute_scale(&self) -> f64 {
        self.jitter.iter().copied().fold(1.0, f64::max)
    }

    /// One synchronous ring step moving `step_bytes` over every link
    /// concurrently: the slowest link sets the pace.
    fn worst_step_time(&self, step_bytes: f64) -> f64 {
        self.links
            .iter()
            .map(|l| l.alpha + step_bytes / l.beta)
            .fold(0.0, f64::max)
    }

    /// Time (seconds) for one collective with per-worker message size
    /// `bytes`. With uniform links this reduces to the closed forms in
    /// [`Backend::time`].
    pub fn time(&self, kind: CollKind, bytes: u64) -> f64 {
        let w = self.workers();
        if w <= 1 {
            return 0.0;
        }
        let wf = w as f64;
        let s = bytes as f64;
        match kind {
            // 2(W−1) steps of S/W bytes per link.
            CollKind::AllReduce => 2.0 * (wf - 1.0) * self.worst_step_time(s / wf),
            // W−1 steps forwarding whole messages.
            CollKind::AllGather => (wf - 1.0) * self.worst_step_time(s),
            // reduce then broadcast, both at full message size.
            CollKind::ReduceBroadcast => 2.0 * (wf - 1.0) * self.worst_step_time(s),
        }
    }

}

/// Compute-phase durations (seconds, nominal — i.e. before straggler
/// scaling) for one training step.
#[derive(Debug, Clone, Copy, Default)]
pub struct ComputePhases {
    /// Forward pass, seconds.
    pub fwd_s: f64,
    /// Backward pass, seconds (apportioned per bucket by raw bytes).
    pub bwd_s: f64,
    /// Compression encode, seconds (apportioned per bucket by msg bytes).
    pub encode_s: f64,
    /// Decompression decode, seconds (runs after both streams drain).
    pub decode_s: f64,
}

/// Outcome of scheduling one training step.
#[derive(Debug, Clone, Copy, Default)]
pub struct OverlapOutcome {
    /// End-to-end simulated step time, seconds.
    pub total: f64,
    /// Network time *not* hidden behind compute, seconds.
    pub exposed_comm: f64,
    /// Total network busy time, seconds.
    pub comm_busy: f64,
    /// Number of buckets scheduled.
    pub buckets: usize,
}

/// Schedule one step over `cluster`.
///
/// Backprop walks the buckets in their given (gradient-ready) order;
/// each bucket costs its raw-byte share of `bwd_s` plus its msg-byte
/// share of `encode_s` on the compute stream. With `overlap`, the
/// bucket's collective (priced by `comm`, typically
/// `|b| cluster.time(kind, b.msg_bytes)`) launches the moment the bucket
/// is ready, queuing FIFO on the network stream; without it, all
/// collectives wait for the full backward+encode — the lockstep
/// schedule. Decode runs after both streams drain. Compute segments are
/// scaled by [`Cluster::compute_scale`] (the slowest worker gates every
/// synchronous collective).
pub fn schedule_step(
    layers: &[LayerTiming],
    buckets: &[Bucket],
    compute: ComputePhases,
    comm: &dyn Fn(&Bucket) -> f64,
    cluster: &Cluster,
    overlap: bool,
) -> OverlapOutcome {
    let scale = cluster.compute_scale();
    let total_raw: f64 = layers.iter().map(|l| l.raw_bytes as f64).sum();
    let total_msg: f64 = layers.iter().map(|l| l.msg_bytes as f64).sum();

    if !overlap {
        let compute_end = (compute.fwd_s + compute.bwd_s + compute.encode_s) * scale;
        let comm_busy: f64 = buckets.iter().map(comm).sum();
        let total = compute_end + comm_busy + compute.decode_s * scale;
        return OverlapOutcome {
            total,
            exposed_comm: comm_busy,
            comm_busy,
            buckets: buckets.len(),
        };
    }

    let mut compute_t = compute.fwd_s * scale;
    let mut net_free = 0.0f64;
    let mut comm_busy = 0.0f64;
    let mut last_comm_done = 0.0f64;
    for b in buckets {
        let bwd_share = if total_raw > 0.0 {
            compute.bwd_s * (b.raw_bytes as f64) / total_raw
        } else {
            0.0
        };
        let enc_share = if total_msg > 0.0 {
            compute.encode_s * (b.msg_bytes as f64) / total_msg
        } else {
            0.0
        };
        compute_t += (bwd_share + enc_share) * scale;
        let c = comm(b);
        let start = compute_t.max(net_free);
        net_free = start + c;
        comm_busy += c;
        last_comm_done = last_comm_done.max(net_free);
    }
    // Backward/encode not attributed to any bucket still happens on the
    // compute stream (callers normally cover all layers, making this
    // exactly zero — the bucket byte sums are integers).
    let covered_raw: f64 = buckets.iter().map(|b| b.raw_bytes as f64).sum();
    let covered_msg: f64 = buckets.iter().map(|b| b.msg_bytes as f64).sum();
    let raw_done = if total_raw > 0.0 { covered_raw / total_raw } else { 0.0 };
    let msg_done = if total_msg > 0.0 { covered_msg / total_msg } else { 0.0 };
    compute_t +=
        (compute.bwd_s * (1.0 - raw_done) + compute.encode_s * (1.0 - msg_done)) * scale;
    let both_done = last_comm_done.max(compute_t);
    OverlapOutcome {
        total: both_done + compute.decode_s * scale,
        exposed_comm: (last_comm_done - compute_t).max(0.0),
        comm_busy,
        buckets: buckets.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NCCL;
    use crate::transport::Bucketer;

    fn layers_uniform(n: usize, msg: u64, raw: u64) -> Vec<LayerTiming> {
        vec![LayerTiming { msg_bytes: msg, raw_bytes: raw }; n]
    }

    #[test]
    fn uniform_cluster_matches_backend_closed_forms() {
        let c = Cluster::uniform(16, &NCCL);
        for &bytes in &[1_000u64, 330_000, 43_000_000] {
            for kind in [CollKind::AllReduce, CollKind::AllGather, CollKind::ReduceBroadcast] {
                let a = c.time(kind, bytes);
                let b = NCCL.time(kind, bytes, 16);
                assert!((a - b).abs() <= 1e-12 * b.max(1.0), "{kind:?} {bytes}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn slow_link_gates_the_ring() {
        let mut c = Cluster::uniform(8, &NCCL);
        c.links[3].beta /= 10.0;
        let slow = c.time(CollKind::AllReduce, 10_000_000);
        let fast = Cluster::uniform(8, &NCCL).time(CollKind::AllReduce, 10_000_000);
        assert!(slow > 5.0 * fast, "slow {slow} vs fast {fast}");
    }

    #[test]
    fn straggler_scales_compute_not_comm() {
        let c = Cluster::with_straggler(4, &NCCL, 3.0);
        assert_eq!(c.compute_scale(), 3.0);
        assert!((c.time(CollKind::AllReduce, 1_000_000)
            - Cluster::uniform(4, &NCCL).time(CollKind::AllReduce, 1_000_000))
        .abs()
            < 1e-15);
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let a = Cluster::with_jitter(8, &NCCL, 0.5, 7);
        let b = Cluster::with_jitter(8, &NCCL, 0.5, 7);
        assert_eq!(a.jitter, b.jitter);
        assert!(a.jitter.iter().all(|&j| (1.0..1.5).contains(&j)));
    }

    #[test]
    fn overlap_hides_comm_behind_backprop() {
        let layers = layers_uniform(20, 10_000, 2_000_000);
        let buckets = Bucketer::new(4_000_000).assign(&layers);
        assert!(buckets.len() > 1);
        let cluster = Cluster::uniform(8, &NCCL);
        let compute =
            ComputePhases { fwd_s: 0.1, bwd_s: 0.14, encode_s: 0.004, decode_s: 0.002 };
        let comm = |b: &Bucket| cluster.time(CollKind::AllReduce, b.msg_bytes);
        let with = schedule_step(&layers, &buckets, compute, &comm, &cluster, true);
        let without = schedule_step(&layers, &buckets, compute, &comm, &cluster, false);
        assert!(with.total < without.total, "{} !< {}", with.total, without.total);
        assert!(with.exposed_comm < without.exposed_comm);
        assert!((with.comm_busy - without.comm_busy).abs() < 1e-12);
    }

    #[test]
    fn single_bucket_overlap_equals_sequential() {
        // No bucketing ⇒ the one collective only becomes ready when all
        // compute is done ⇒ overlap buys nothing.
        let layers = layers_uniform(5, 50_000, 1_000_000);
        let buckets = Bucketer::new(0).assign(&layers);
        assert_eq!(buckets.len(), 1);
        let cluster = Cluster::uniform(4, &NCCL);
        let compute = ComputePhases { fwd_s: 0.05, bwd_s: 0.07, encode_s: 0.001, decode_s: 0.001 };
        let comm = |b: &Bucket| cluster.time(CollKind::AllReduce, b.msg_bytes);
        let with = schedule_step(&layers, &buckets, compute, &comm, &cluster, true);
        let without = schedule_step(&layers, &buckets, compute, &comm, &cluster, false);
        assert!((with.total - without.total).abs() < 1e-12);
    }

    #[test]
    fn empty_buckets_cost_only_compute() {
        let cluster = Cluster::uniform(4, &NCCL);
        let compute = ComputePhases { fwd_s: 0.1, bwd_s: 0.2, encode_s: 0.0, decode_s: 0.0 };
        let comm = |_: &Bucket| 0.0;
        let out = schedule_step(&[], &[], compute, &comm, &cluster, true);
        assert!((out.total - 0.3).abs() < 1e-12);
        assert_eq!(out.buckets, 0);
    }
}
