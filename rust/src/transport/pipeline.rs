//! Split-phase ring collectives and the pipeline-mode axis
//! (DESIGN.md §14).
//!
//! The completion-queue [`Transport`] API decouples *posting* traffic
//! from *waiting* on it; this module packages that into a resumable
//! ring all-reduce — [`PostedAllReduce`] — that a caller starts, parks
//! while it computes something else, and drains later. The arithmetic
//! (chunk boundaries at `c·n/W`, accumulation order, buffer recycling)
//! is copied exactly from
//! [`ring_all_reduce_worker`](super::ring_all_reduce_worker), so a
//! posted reduction is **bitwise identical** to the lockstep oracle no
//! matter where its waits land.
//!
//! # Determinism policy for in-flight operations
//!
//! Receive tickets are fulfilled positionally (k-th frame on a link →
//! k-th posted receive), so correctness with several collectives in
//! flight requires a *static schedule*: every worker must post sends
//! and receives at the same program points, in the same order. Posting
//! must never depend on timing (e.g. "post whichever bucket finished
//! first") — that would let two workers disagree about which frame is
//! k-th on a link. All pipelined drivers in this crate follow the
//! rule; [`PostedAllReduce::advance`] only posts step *k+1* after
//! folding step *k*, which keeps each machine's traffic in lockstep
//! program order even when machines interleave.
//!
//! # Modes
//!
//! [`PipelineMode`] is the CLI-visible axis (`--pipeline
//! {off,overlap,delayed}`):
//!
//! - **Off** — the lockstep reference: compress → collective →
//!   decompress, fully synchronous.
//! - **Overlap** — collectives are posted early and drained late, so
//!   transport I/O (channel buffering in-process, the writer/reader
//!   threads over TCP) proceeds while compression of later factors
//!   runs. Synchronous semantics are preserved: results are bitwise
//!   identical to `Off`.
//! - **Delayed** — the PyTorch DDP PowerSGD-hook trick: apply step
//!   *t−1*'s aggregate while step *t*'s collective is in flight. This
//!   *changes the optimizer trajectory* (by one step of staleness); it
//!   is compared against its own delayed oracle, not the synchronous
//!   one.

use super::ring::{Completion, Ticket, Transport};
use crate::obs::{span, Phase, SpanGuard};

/// How the step driver schedules collectives relative to compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PipelineMode {
    /// Fully synchronous (the correctness oracle).
    #[default]
    Off,
    /// Post early / drain late; bitwise identical to `Off`.
    Overlap,
    /// One-step-delayed aggregation (different trajectory).
    Delayed,
}

impl PipelineMode {
    /// The CLI spelling (`--pipeline <name>`), round-tripping through
    /// [`pipeline_by_name`].
    pub fn cli_name(self) -> &'static str {
        match self {
            PipelineMode::Off => "off",
            PipelineMode::Overlap => "overlap",
            PipelineMode::Delayed => "delayed",
        }
    }
}

/// Look up a pipeline mode by (case-insensitive) CLI name.
pub fn pipeline_by_name(name: &str) -> Option<PipelineMode> {
    match name.to_ascii_lowercase().as_str() {
        "off" | "lockstep" | "none" => Some(PipelineMode::Off),
        "overlap" | "pipelined" => Some(PipelineMode::Overlap),
        "delayed" | "one-step-delayed" => Some(PipelineMode::Delayed),
        _ => None,
    }
}

/// A ring all-reduce (sum) in flight: started with [`start`], driven
/// one ring step at a time by [`advance`], drained by [`finish`].
///
/// The machine owns its buffer for the duration of the collective and
/// hands it back (fully reduced) from [`finish`]. An [`Phase::InFlight`]
/// span covers the window from the first post to the last drain, so
/// traces show how much communication was hidden behind compute.
///
/// [`start`]: PostedAllReduce::start
/// [`advance`]: PostedAllReduce::advance
/// [`finish`]: PostedAllReduce::finish
pub struct PostedAllReduce<'t, T: Transport + ?Sized> {
    t: &'t T,
    buf: Vec<f32>,
    starts: Vec<usize>,
    spare: Option<Vec<f32>>,
    /// Next ring step to complete, `0..total`.
    next: usize,
    /// `2(W−1)` ring steps, or 0 for trivial collectives.
    total: usize,
    pending: Option<Ticket>,
    inflight: Option<SpanGuard>,
}

impl<'t, T: Transport + ?Sized> PostedAllReduce<'t, T> {
    /// Post the first ring step's traffic and return the in-flight
    /// machine. Trivial collectives (`W == 1` or empty buffers) start
    /// already done.
    pub fn start(t: &'t T, buf: Vec<f32>) -> PostedAllReduce<'t, T> {
        let w = t.world();
        let n = buf.len();
        let total = if w == 1 || n == 0 { 0 } else { 2 * (w - 1) };
        let starts: Vec<usize> = (0..=w).map(|c| c * n / w).collect();
        let mut machine = PostedAllReduce {
            t,
            buf,
            starts,
            spare: None,
            next: 0,
            total,
            pending: None,
            inflight: None,
        };
        if machine.total > 0 {
            machine.inflight = Some(span(Phase::InFlight));
            machine.post_step();
        }
        machine
    }

    /// `(c_send, c_recv)` for ring step `step`, identical to the
    /// schedule in `ring_all_reduce_worker`: reduce-scatter for the
    /// first `W−1` steps, all-gather for the rest.
    fn chunk_indices(&self, step: usize) -> (usize, usize) {
        let w = self.t.world();
        let i = self.t.rank();
        if step < w - 1 {
            let s = step;
            ((i + w - s) % w, (i + 2 * w - 1 - s) % w)
        } else {
            let s = step - (w - 1);
            ((i + 1 + w - s) % w, (i + w - s) % w)
        }
    }

    /// Post step `self.next`'s send and receive (in that order — the
    /// static-schedule program points).
    fn post_step(&mut self) {
        let (c_send, _) = self.chunk_indices(self.next);
        let src = &self.buf[self.starts[c_send]..self.starts[c_send + 1]];
        let msg = match self.spare.take() {
            Some(mut v) => {
                v.clear();
                v.extend_from_slice(src);
                v
            }
            None => src.to_vec(),
        };
        let _ = self.t.post_send(msg);
        self.pending = Some(self.t.post_recv());
    }

    /// Whether every ring step has completed.
    pub fn is_done(&self) -> bool {
        self.next >= self.total
    }

    /// Drive exactly one ring step: wait on the posted receive, fold
    /// the chunk into the buffer (accumulate during reduce-scatter,
    /// overwrite during all-gather), and post the next step's traffic.
    /// No-op once done.
    pub fn advance(&mut self) {
        if self.is_done() {
            return;
        }
        let ticket = self.pending.take().expect("pending receive exists while steps remain");
        let chunk = match self.t.wait(ticket) {
            Completion::Received(c) => c,
            _ => panic!("recv ticket resolved without a message"),
        };
        let w = self.t.world();
        let (_, c_recv) = self.chunk_indices(self.next);
        let dst = &mut self.buf[self.starts[c_recv]..self.starts[c_recv + 1]];
        debug_assert_eq!(dst.len(), chunk.len(), "ring chunk size mismatch");
        if self.next < w - 1 {
            for (d, v) in dst.iter_mut().zip(chunk.iter()) {
                *d += v;
            }
        } else {
            dst.copy_from_slice(&chunk);
        }
        self.spare = Some(chunk);
        self.next += 1;
        if self.is_done() {
            self.inflight = None;
        } else {
            self.post_step();
        }
    }

    /// Drain every remaining ring step and hand back the reduced
    /// buffer.
    pub fn finish(mut self) -> Vec<f32> {
        while !self.is_done() {
            self.advance();
        }
        self.inflight = None;
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{ring_all_reduce_sum_threaded, InProcRing};
    use crate::util::Rng;

    fn random_buffers(world: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..world).map(|_| (0..n).map(|_| rng.normal() as f32).collect()).collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Drive one posted machine per worker round-robin on a single
    /// thread (mpsc sends never block, so no worker thread is needed)
    /// and compare bitwise against the lockstep threaded reference.
    #[test]
    fn posted_all_reduce_matches_lockstep_bitwise() {
        for &(world, n) in &[(1usize, 8usize), (2, 8), (3, 10), (4, 1003), (5, 7), (4, 0)] {
            let inputs = random_buffers(world, n, 0xA11CE ^ (world as u64) << 8 ^ n as u64);
            let mut oracle = inputs.clone();
            ring_all_reduce_sum_threaded(&mut oracle);

            let nodes = InProcRing::endpoints::<Vec<f32>>(world);
            let mut machines: Vec<_> = nodes
                .iter()
                .zip(inputs.into_iter())
                .map(|(node, buf)| PostedAllReduce::start(node, buf))
                .collect();
            while machines.iter().any(|m| !m.is_done()) {
                for m in machines.iter_mut() {
                    m.advance();
                }
            }
            for (rank, (m, want)) in machines.into_iter().zip(oracle.iter()).enumerate() {
                let got = m.finish();
                assert_eq!(
                    bits(&got),
                    bits(want),
                    "world={world} n={n} rank={rank}: posted != lockstep"
                );
            }
        }
    }

    /// Two collectives in flight per endpoint, finished in reverse
    /// start order. Positional FIFO matching must still route each
    /// frame to the right machine because every worker posts in the
    /// same program order (the static-schedule policy).
    #[test]
    fn interleaved_posted_reduces_stay_fifo_consistent() {
        let world = 3;
        let n = 10;
        let a_in = random_buffers(world, n, 11);
        let b_in = random_buffers(world, n, 22);
        let mut a_oracle = a_in.clone();
        let mut b_oracle = b_in.clone();
        ring_all_reduce_sum_threaded(&mut a_oracle);
        ring_all_reduce_sum_threaded(&mut b_oracle);

        let nodes = InProcRing::endpoints::<Vec<f32>>(world);
        // Program order on every worker: start A, start B, finish B,
        // finish A.
        let mut a: Vec<_> = nodes
            .iter()
            .zip(a_in.into_iter())
            .map(|(node, buf)| PostedAllReduce::start(node, buf))
            .collect();
        let mut b: Vec<_> = nodes
            .iter()
            .zip(b_in.into_iter())
            .map(|(node, buf)| PostedAllReduce::start(node, buf))
            .collect();
        while b.iter().any(|m| !m.is_done()) {
            for m in b.iter_mut() {
                m.advance();
            }
        }
        while a.iter().any(|m| !m.is_done()) {
            for m in a.iter_mut() {
                m.advance();
            }
        }
        for (rank, (m, want)) in b.into_iter().zip(b_oracle.iter()).enumerate() {
            assert_eq!(bits(&m.finish()), bits(want), "B rank={rank}");
        }
        for (rank, (m, want)) in a.into_iter().zip(a_oracle.iter()).enumerate() {
            assert_eq!(bits(&m.finish()), bits(want), "A rank={rank}");
        }
    }

    #[test]
    fn mode_names_round_trip() {
        for mode in [PipelineMode::Off, PipelineMode::Overlap, PipelineMode::Delayed] {
            assert_eq!(pipeline_by_name(mode.cli_name()), Some(mode));
        }
        assert_eq!(pipeline_by_name("OVERLAP"), Some(PipelineMode::Overlap));
        assert_eq!(pipeline_by_name("eager"), None);
        assert_eq!(PipelineMode::default(), PipelineMode::Off);
    }
}
