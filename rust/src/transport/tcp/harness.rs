//! The `powersgd launch` / `powersgd worker` driver: a deterministic
//! multi-process EF-SGD run over the TCP ring, verified **bitwise**
//! against the centralized lockstep oracle.
//!
//! The workload is a fixed small parameter set with seeded synthetic
//! gradients: every process regenerates the full `W`-worker gradient
//! draw from the shared seed and uses only its own slice
//! ([`synthetic_grads`]), so `W` OS processes and the in-process oracle
//! see identical bits without moving any training data. Each worker
//! runs an **unmodified** [`EfSgd`] whose compressor is an
//! [`EndpointCompressor`] over a metered [`super::TcpRing`]: the same
//! per-worker compression rounds, the same ring collectives, real
//! sockets.
//!
//! Verification chain (every link checked on every run):
//!
//! 1. worker-side: measured wire bytes == the
//!    [`ring_wire_bytes`] expansion of every logged collective;
//! 2. coordinator-side: every worker's logged (logical) bytes == the
//!    compressor's closed-form `message_bytes` model × steps;
//! 3. coordinator-side: every worker's final parameters are
//!    **bit-identical** to the oracle trajectory's.
//!
//! `tests/integration_tcp.rs` drives this both in-process (threads with
//! real sockets) and as true multi-process runs of the binary.

use super::metered::MeteredTransport;
use super::rendezvous::{join, Rendezvous};
use super::wire::{read_frame, write_frame, Frame};
use super::TcpRing;
use crate::collectives::{ring_wire_bytes, CollOp, CommLog};
use crate::compress::{oracle_by_name, worker_by_name, EndpointCompressor, SchemeMeta};
use crate::grad::{ParamRegistry, ELEM_BYTES};
use crate::obs::metrics::{self, Counter, Gauge, MaxGauge, StepMetrics};
use crate::optim::{DistOptimizer, EfSgd, LrSchedule};
use crate::tensor::Tensor;
use crate::transport::{PipelineMode, Transport};
use crate::util::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::time::{Duration, Instant};

/// What a launch and its workers agree to run. Every field must be
/// identical on the coordinator and all workers (the launch subcommand
/// forwards them on each worker's command line).
#[derive(Debug, Clone)]
pub struct HarnessConfig {
    /// Compressor CLI name (must have a per-worker implementation).
    pub compressor: String,
    /// Compression rank `r` where applicable.
    pub rank: usize,
    /// Shared seed for parameters, gradients and compressor state.
    pub seed: u64,
    /// EF-SGD steps to run.
    pub steps: usize,
    /// Constant learning rate.
    pub lr: f64,
    /// Momentum λ (an f32 so coordinator and forwarded worker values
    /// are bit-identical — see `harness_config` in `main.rs`).
    pub momentum: f32,
    /// Collective scheduling (`--pipeline {off,overlap,delayed}`).
    /// Overlap reorders traffic only, so it is verified against the
    /// same lockstep oracle; delayed changes the trajectory and is
    /// verified against a one-step-delayed oracle.
    pub pipeline: PipelineMode,
    /// Collect per-step [`StepMetrics`] and push them to the
    /// coordinator as `Frame::Metrics` sideband records (`--metrics`).
    /// Recording never touches computed values, so the trajectory stays
    /// bitwise-identical either way.
    pub metrics: bool,
    /// Rank to slow down artificially (straggler injection for the
    /// run-health tests and `metrics-smoke`). Ignored unless
    /// `straggle_ms > 0`.
    pub straggle_rank: usize,
    /// Milliseconds the straggling rank sleeps per step (0 = no
    /// injection). Sleeping perturbs wall-clock only, never values.
    pub straggle_ms: u64,
}

impl Default for HarnessConfig {
    fn default() -> HarnessConfig {
        HarnessConfig {
            compressor: "powersgd".into(),
            rank: 2,
            seed: 42,
            steps: 3,
            lr: 0.05,
            momentum: 0.9,
            pipeline: PipelineMode::Off,
            metrics: false,
            straggle_rank: 0,
            straggle_ms: 0,
        }
    }
}

/// The harness model: mixed matrix/vector parameters, vectors
/// interleaved like a real network.
pub fn harness_shapes() -> Vec<Vec<usize>> {
    vec![vec![12, 8], vec![5], vec![6, 10], vec![3]]
}

/// [`ParamRegistry`] over [`harness_shapes`], for the closed-form
/// `message_bytes` cross-check.
pub fn harness_registry() -> ParamRegistry {
    ParamRegistry::from_shapes(&[
        ("w0", vec![12, 8]),
        ("b0", vec![5]),
        ("w1", vec![6, 10]),
        ("b1", vec![3]),
    ])
}

/// Deterministic per-step gradients for all `world` workers. Every
/// process calls this with the same arguments and slices out its own
/// rank; the oracle consumes the whole draw. One shared RNG stream in
/// worker-major order keeps the bits identical everywhere.
pub fn synthetic_grads(world: usize, seed: u64, step: usize) -> Vec<Vec<Tensor>> {
    let mut rng = Rng::new(seed ^ ((step as u64 + 1).wrapping_mul(0x9e37_79b9)));
    (0..world)
        .map(|_| {
            harness_shapes()
                .iter()
                .map(|shape| {
                    let mut t = Tensor::zeros(shape);
                    rng.fill_normal(t.data_mut(), 1.0);
                    t
                })
                .collect()
        })
        .collect()
}

/// Deterministic initial parameters (identical on every process).
pub fn initial_params(seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed ^ 0xA11CE);
    harness_shapes()
        .iter()
        .map(|shape| {
            let mut t = Tensor::zeros(shape);
            rng.fill_normal(t.data_mut(), 1.0);
            t
        })
        .collect()
}

/// The centralized lockstep oracle trajectory: the same EF-SGD loop the
/// worker processes run, driven in one process with all `world` updates
/// per call. Returns the final parameters and the total per-worker
/// logical bytes logged.
pub fn oracle_trajectory(world: usize, cfg: &HarnessConfig) -> Result<(Vec<Tensor>, u64)> {
    let comp = oracle_by_name(&cfg.compressor, cfg.rank, cfg.seed)
        .ok_or_else(|| anyhow!("no centralized oracle for compressor {:?}", cfg.compressor))?;
    let mut opt = EfSgd::new(comp, LrSchedule::constant(cfg.lr), cfg.momentum);
    if cfg.pipeline == PipelineMode::Delayed {
        opt = opt.with_delayed_aggregate();
    }
    let mut params = initial_params(cfg.seed);
    let mut log = CommLog::default();
    for step in 0..cfg.steps {
        let grads = synthetic_grads(world, cfg.seed, step);
        let delta = opt.step(&grads, step, &mut log);
        for (x, d) in params.iter_mut().zip(delta.iter()) {
            x.axpy(-1.0, d);
        }
    }
    Ok((params, log.bytes_sent()))
}

/// One worker's finished run.
pub struct WorkerRunReport {
    /// This worker's ring rank.
    pub rank: usize,
    /// Final parameters after the EF-SGD trajectory.
    pub params: Vec<Tensor>,
    /// Per-worker logical bytes (the `CommLog` unit), summed over steps.
    pub logical_bytes: u64,
    /// Payload bytes this worker actually put on the wire.
    pub wire_bytes: u64,
    /// Every collective the run logged, in execution order — the input
    /// to the analytic [`ring_wire_bytes`] expansion (the experiment
    /// report recomputes and publishes it per rank).
    pub ops: Vec<CollOp>,
    /// Per-step run-health records, one per step when the config asked
    /// for metrics (`cfg.metrics`), empty otherwise. The wire-byte
    /// fields are per-step deltas of this worker's own metered
    /// counters, so their sum equals `wire_bytes` exactly.
    pub step_metrics: Vec<StepMetrics>,
}

/// Run this process's half of the EF-SGD trajectory over a connected,
/// metered endpoint. A peer dying mid-collective surfaces as a
/// contextual error (the infallible [`Transport`] methods panic with
/// the dead rank's name; this loop converts the panic back). Before
/// returning, the measured wire bytes are cross-checked against the
/// [`ring_wire_bytes`] expansion of every logged collective.
pub fn worker_trajectory<T>(
    endpoint: MeteredTransport<T>,
    cfg: &HarnessConfig,
) -> Result<WorkerRunReport>
where
    T: Transport<Vec<f32>> + Transport<Vec<u8>> + 'static,
{
    let world = <MeteredTransport<T> as Transport<Vec<f32>>>::world(&endpoint);
    let rank = <MeteredTransport<T> as Transport<Vec<f32>>>::rank(&endpoint);
    let counters = endpoint.counters();
    let comp = worker_by_name(&cfg.compressor, cfg.rank, cfg.seed).ok_or_else(|| {
        anyhow!("compressor {:?} has no per-worker implementation", cfg.compressor)
    })?;
    let logical_model = comp.message_bytes(&harness_registry()) * cfg.steps as u64;
    let mut opt = EfSgd::new(
        Box::new(EndpointCompressor::new(endpoint, comp).with_pipeline(cfg.pipeline)),
        LrSchedule::constant(cfg.lr),
        cfg.momentum,
    );
    if cfg.pipeline == PipelineMode::Delayed {
        opt = opt.with_delayed_aggregate();
    }

    let mut params = initial_params(cfg.seed);
    let mut log = CommLog::default();
    let mut step_metrics = Vec::with_capacity(if cfg.metrics { cfg.steps } else { 0 });
    let raw_bytes_per_step = harness_registry().numel() as u64 * ELEM_BYTES;
    let (mut prev_sent, mut prev_received) = (counters.sent(), counters.received());
    let mut prev_logical = 0u64;
    for step in 0..cfg.steps {
        let t0 = cfg.metrics.then(Instant::now);
        if cfg.straggle_ms > 0 && rank == cfg.straggle_rank {
            std::thread::sleep(Duration::from_millis(cfg.straggle_ms));
        }
        let grads = vec![synthetic_grads(world, cfg.seed, step).swap_remove(rank)];
        let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            opt.step(&grads, step, &mut log)
        }));
        let delta = match stepped {
            Ok(delta) => delta,
            Err(payload) => bail!(
                "rank {rank}: ring collective failed at step {step}: {}",
                panic_message(payload)
            ),
        };
        for (x, d) in params.iter_mut().zip(delta.iter()) {
            x.axpy(-1.0, d);
        }
        if let Some(t0) = t0 {
            // The wire fields are local per-step deltas (exact per
            // rank); the quality fields read the process-global gauge
            // registry, which is authoritative in the one-process-
            // per-rank setting and merely indicative when several
            // worker threads share a test process.
            let (sent, received) = (counters.sent(), counters.received());
            let logical = log.bytes_sent();
            let logical_delta = logical - prev_logical;
            step_metrics.push(StepMetrics {
                rank: rank as u64,
                step: step as u64,
                step_seconds: t0.elapsed().as_secs_f64(),
                wire_sent: sent - prev_sent,
                wire_received: received - prev_received,
                ef_residual: metrics::gauge_value(Gauge::EfResidual),
                approx_error: metrics::gauge_value(Gauge::ApproxError),
                compression_ratio: if logical_delta == 0 {
                    0.0
                } else {
                    raw_bytes_per_step as f64 / logical_delta as f64
                },
                staleness: u64::from(cfg.pipeline == PipelineMode::Delayed),
                inflight_peak: metrics::max_value(MaxGauge::InflightDepthPeak),
            });
            (prev_sent, prev_received, prev_logical) = (sent, received, logical);
        }
    }

    let logical_bytes = log.bytes_sent();
    if logical_bytes != logical_model {
        bail!(
            "rank {rank}: logged {logical_bytes} logical bytes but the closed-form \
             message_bytes model predicts {logical_model}"
        );
    }
    let wire_bytes = counters.sent();
    let expected_wire: u64 = log
        .ops
        .iter()
        .map(|op| ring_wire_bytes(op.kind, op.bytes, world, rank))
        .sum();
    if wire_bytes != expected_wire {
        bail!(
            "rank {rank}: measured {wire_bytes} wire bytes but the ring expansion of the \
             logged collectives predicts {expected_wire}"
        );
    }
    Ok(WorkerRunReport { rank, params, logical_bytes, wire_bytes, ops: log.ops, step_metrics })
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(msg) = payload.downcast_ref::<String>() {
        msg.clone()
    } else if let Some(msg) = payload.downcast_ref::<&'static str>() {
        (*msg).to_string()
    } else {
        "worker panicked".into()
    }
}

/// Full worker process: rendezvous at `coordinator`, run the
/// trajectory over a metered [`TcpRing`], report the final parameters
/// and byte counters back on the control connection. Returns the rank
/// the rendezvous assigned (callers use it for rank-suffixed artifacts
/// like per-rank trace files).
pub fn run_worker(coordinator: &str, cfg: &HarnessConfig, timeout: Duration) -> Result<usize> {
    run_worker_with_metrics(coordinator, cfg, timeout).map(|(rank, _)| rank)
}

/// [`run_worker`], also returning the per-step [`StepMetrics`] the run
/// collected (empty unless `cfg.metrics`) so callers can write the
/// rank's `METRICS_r<k>.jsonl` stream. When metrics are on, every
/// record is additionally pushed to the coordinator as a
/// `Frame::Metrics` sideband frame on the control connection, ahead of
/// the final `Report`.
pub fn run_worker_with_metrics(
    coordinator: &str,
    cfg: &HarnessConfig,
    timeout: Duration,
) -> Result<(usize, Vec<StepMetrics>)> {
    let joined = join(coordinator, timeout)?;
    let (ring, mut control) = TcpRing::from_joined(joined, timeout)?;
    let report = worker_trajectory(MeteredTransport::new(ring), cfg)?;
    for m in &report.step_metrics {
        metrics::add(Counter::MetricsFrames, 1);
        write_frame(&mut control, &Frame::Metrics(*m))
            .map_err(|e| anyhow!(e))
            .with_context(|| {
                format!("rank {}: pushing step {} metrics to the coordinator", report.rank, m.step)
            })?;
    }
    write_frame(
        &mut control,
        &Frame::Report {
            rank: report.rank as u32,
            wire_bytes: report.wire_bytes,
            logical_bytes: report.logical_bytes,
            tensors: report.params.iter().map(|t| t.data().to_vec()).collect(),
        },
    )
    .map_err(|e| anyhow!(e))
    .with_context(|| format!("rank {}: reporting to the coordinator", report.rank))?;
    Ok((report.rank, report.step_metrics))
}

/// One worker's verified outcome, as the coordinator sees it.
pub struct WorkerWireReport {
    /// The reporting worker's ring rank.
    pub rank: usize,
    /// Payload bytes the worker measured on its metered transport.
    pub wire_bytes: u64,
    /// Logical per-worker bytes the worker logged.
    pub logical_bytes: u64,
    /// Final parameters bit-identical to the oracle's.
    pub bitwise: bool,
}

/// A verified launch.
pub struct LaunchOutcome {
    /// Number of worker processes in the ring.
    pub world: usize,
    /// EF-SGD steps every worker ran.
    pub steps: usize,
    /// Per-rank reports (rank-indexed).
    pub reports: Vec<WorkerWireReport>,
    /// The oracle's per-worker logical bytes over the whole run.
    pub logical_bytes: u64,
    /// Closed-form per-worker message bytes per step.
    pub model_bytes_per_step: u64,
    /// Per-rank sideband metrics frames, rank-indexed; a rank's stream
    /// is empty when it pushed no frames (metrics off, or a peer that
    /// died after its `Report` would have — tolerated downstream by
    /// [`metrics::aggregate`]).
    pub metrics_by_rank: Vec<Vec<StepMetrics>>,
}

impl LaunchOutcome {
    /// Whether every reporting rank's summed per-step wire deltas match
    /// the wire bytes its metered transport reported — the exact
    /// reconciliation pinned by the acceptance criteria. `None` when no
    /// rank pushed metrics frames (metrics off).
    pub fn metrics_reconcile(&self) -> Option<bool> {
        if self.metrics_by_rank.iter().all(|f| f.is_empty()) {
            return None;
        }
        Some(self.reports.iter().all(|r| {
            let frames = &self.metrics_by_rank[r.rank];
            frames.is_empty() || frames.iter().map(|m| m.wire_sent).sum::<u64>() == r.wire_bytes
        }))
    }
}

/// Coordinator half of a launch: rendezvous `world` workers, run the
/// lockstep oracle in-process, collect every worker's report, and
/// verify the whole chain — bitwise parameters, logical bytes against
/// the oracle, and the closed-form model. Any mismatch (or a worker
/// dying before it reports) is an error.
pub fn coordinate(
    rendezvous: &Rendezvous,
    world: usize,
    cfg: &HarnessConfig,
    timeout: Duration,
) -> Result<LaunchOutcome> {
    let mut controls = rendezvous.run(world, timeout)?;
    let (oracle_params, oracle_logical) = oracle_trajectory(world, cfg)?;
    let model_bytes_per_step = worker_by_name(&cfg.compressor, cfg.rank, cfg.seed)
        .map(|w| w.message_bytes(&harness_registry()))
        .unwrap_or(0);

    let mut reports = Vec::with_capacity(world);
    let mut metrics_by_rank: Vec<Vec<StepMetrics>> = vec![Vec::new(); world];
    for (rank, control) in controls.iter_mut().enumerate() {
        // Drain the metrics sideband (zero or more frames) until the
        // final Report — workers only push frames when metrics are on,
        // so the loop is tolerant either way.
        let (got, wire_bytes, logical_bytes, tensors) = loop {
            let frame = read_frame(control).map_err(|e| anyhow!(e)).with_context(|| {
                format!("launch: worker rank {rank} died before reporting its result")
            })?;
            match frame {
                Frame::Metrics(m) => {
                    if m.rank as usize != rank {
                        bail!(
                            "launch: control stream {rank} delivered metrics from rank {}",
                            m.rank
                        );
                    }
                    metrics_by_rank[rank].push(m);
                }
                Frame::Report { rank, wire_bytes, logical_bytes, tensors } => {
                    break (rank, wire_bytes, logical_bytes, tensors)
                }
                other => {
                    bail!("launch: expected a Report from rank {rank}, got {}", other.kind_name())
                }
            }
        };
        if got as usize != rank {
            bail!("launch: control stream {rank} delivered a report from rank {got}");
        }
        let bitwise = tensors.len() == oracle_params.len()
            && tensors.iter().zip(oracle_params.iter()).all(|(got, want)| {
                got.len() == want.len()
                    && got
                        .iter()
                        .zip(want.data().iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits())
            });
        if !bitwise {
            bail!(
                "launch: rank {rank}'s final parameters diverged from the lockstep oracle \
                 (the TCP path must be bitwise-identical)"
            );
        }
        if logical_bytes != oracle_logical {
            bail!(
                "launch: rank {rank} logged {logical_bytes} logical bytes, oracle logged \
                 {oracle_logical}"
            );
        }
        reports.push(WorkerWireReport { rank, wire_bytes, logical_bytes, bitwise });
    }
    Ok(LaunchOutcome {
        world,
        steps: cfg.steps,
        reports,
        logical_bytes: oracle_logical,
        model_bytes_per_step,
        metrics_by_rank,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_grads_are_deterministic_and_worker_major() {
        let a = synthetic_grads(4, 7, 2);
        let b = synthetic_grads(4, 7, 2);
        for (wa, wb) in a.iter().zip(b.iter()) {
            for (ta, tb) in wa.iter().zip(wb.iter()) {
                assert_eq!(ta.data(), tb.data());
            }
        }
        // A different step or seed draws different bits.
        let c = synthetic_grads(4, 7, 3);
        assert_ne!(a[0][0].data(), c[0][0].data());
        // A smaller world is a prefix of a larger one (worker-major
        // stream), so every process can slice out its own rank.
        let small = synthetic_grads(2, 7, 2);
        for (wa, wb) in small.iter().zip(a.iter().take(2)) {
            for (ta, tb) in wa.iter().zip(wb.iter()) {
                assert_eq!(ta.data(), tb.data());
            }
        }
    }

    #[test]
    fn registry_matches_shapes() {
        let reg = harness_registry();
        let shapes = harness_shapes();
        assert_eq!(reg.len(), shapes.len());
        let numel: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        assert_eq!(reg.numel(), numel);
    }

    #[test]
    fn oracle_trajectory_moves_and_is_deterministic() {
        let cfg = HarnessConfig::default();
        let (a, bytes_a) = oracle_trajectory(2, &cfg).unwrap();
        let (b, bytes_b) = oracle_trajectory(2, &cfg).unwrap();
        assert_eq!(bytes_a, bytes_b);
        let mut moved = false;
        for (ta, tb) in a.iter().zip(b.iter()) {
            assert_eq!(ta.data(), tb.data());
        }
        let x0 = initial_params(cfg.seed);
        for (t, t0) in a.iter().zip(x0.iter()) {
            if t.data() != t0.data() {
                moved = true;
            }
        }
        assert!(moved, "three EF-SGD steps must move the parameters");
    }

    #[test]
    fn unknown_compressor_is_a_clean_error() {
        let cfg = HarnessConfig { compressor: "atomo".into(), ..HarnessConfig::default() };
        assert!(oracle_trajectory(2, &cfg).is_err());
    }
}
