//! The `powersgd launch` / `powersgd worker` driver: a deterministic
//! multi-process EF-SGD run over the TCP ring, verified **bitwise**
//! against the centralized lockstep oracle.
//!
//! The workload is a fixed small parameter set with seeded synthetic
//! gradients: every process regenerates the full `W`-worker gradient
//! draw from the shared seed and uses only its own slice
//! ([`synthetic_grads`]), so `W` OS processes and the in-process oracle
//! see identical bits without moving any training data. Each worker
//! runs an **unmodified** [`EfSgd`] whose compressor is an
//! [`EndpointCompressor`] over a metered [`super::TcpRing`]: the same
//! per-worker compression rounds, the same ring collectives, real
//! sockets.
//!
//! Verification chain (every link checked on every run):
//!
//! 1. worker-side: measured wire bytes == the
//!    [`ring_wire_bytes`] expansion of every logged collective;
//! 2. coordinator-side: every worker's logged (logical) bytes == the
//!    compressor's closed-form `message_bytes` model × steps;
//! 3. coordinator-side: every worker's final parameters are
//!    **bit-identical** to the oracle trajectory's.
//!
//! `tests/integration_tcp.rs` drives this both in-process (threads with
//! real sockets) and as true multi-process runs of the binary.

use super::metered::{MeteredTransport, WireCounters};
use super::rendezvous::{
    form_ring_edges, hello, join_with_retries, Rendezvous, DEFAULT_CONNECT_RETRIES,
};
use super::wire::{read_frame, write_frame, Frame, RECONFIGURE_VERSION};
use super::TcpRing;
use crate::collectives::{ring_wire_bytes, CollOp, CommLog};
use crate::compress::{oracle_by_name, worker_by_name, EndpointCompressor, SchemeMeta};
use crate::grad::{ParamRegistry, ELEM_BYTES};
use crate::net::backoff::Backoff;
use crate::obs::metrics::{self, Counter, EpochInfo, Gauge, MaxGauge, StepMetrics};
use crate::optim::{DistOptimizer, EfSgd, LrSchedule};
use crate::tensor::Tensor;
use crate::transport::{Completion, PipelineMode, Ticket, Transport};
use crate::util::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// What a launch and its workers agree to run. Every field must be
/// identical on the coordinator and all workers (the launch subcommand
/// forwards them on each worker's command line).
#[derive(Debug, Clone)]
pub struct HarnessConfig {
    /// Compressor CLI name (must have a per-worker implementation).
    pub compressor: String,
    /// Compression rank `r` where applicable.
    pub rank: usize,
    /// Shared seed for parameters, gradients and compressor state.
    pub seed: u64,
    /// EF-SGD steps to run.
    pub steps: usize,
    /// Constant learning rate.
    pub lr: f64,
    /// Momentum λ (an f32 so coordinator and forwarded worker values
    /// are bit-identical — see `harness_config` in `main.rs`).
    pub momentum: f32,
    /// Collective scheduling (`--pipeline {off,overlap,delayed}`).
    /// Overlap reorders traffic only, so it is verified against the
    /// same lockstep oracle; delayed changes the trajectory and is
    /// verified against a one-step-delayed oracle.
    pub pipeline: PipelineMode,
    /// Collect per-step [`StepMetrics`] and push them to the
    /// coordinator as `Frame::Metrics` sideband records (`--metrics`).
    /// Recording never touches computed values, so the trajectory stays
    /// bitwise-identical either way.
    pub metrics: bool,
    /// Rank to slow down artificially (straggler injection for the
    /// run-health tests and `metrics-smoke`). Ignored unless
    /// `straggle_ms > 0`.
    pub straggle_rank: usize,
    /// Milliseconds the straggling rank sleeps per step (0 = no
    /// injection). Sleeping perturbs wall-clock only, never values.
    /// In elastic mode the sleep happens *before* the step heartbeat,
    /// so `heartbeat_ms` must exceed `straggle_ms` (plus step time) or
    /// the straggler trips the dead-peer detector — see DESIGN.md §16.
    pub straggle_ms: u64,
    /// Epoch-based elastic membership (`--elastic`, DESIGN.md §16):
    /// workers heartbeat the coordinator at every step boundary and the
    /// ring re-forms around crashes, departures, and late joins instead
    /// of failing the run.
    pub elastic: bool,
    /// Coordinator-side step-heartbeat timeout (`--heartbeat-ms`): a
    /// live member that goes silent for longer than this between step
    /// boundaries is declared dead and reconfigured away. Must exceed
    /// the slowest member's per-step time (including `straggle_ms`).
    pub heartbeat_ms: u64,
    /// Connect retry budget (`--reconnect-retries`) threaded through
    /// every rendezvous and ring-edge connect's [`Backoff`].
    pub reconnect_retries: u32,
    /// Ring I/O timeout override in milliseconds (`--comm-timeout-ms`);
    /// `None` falls back to the run timeout (`--timeout-s`). Bounds
    /// every blocking ring read and write, so it must also exceed
    /// `straggle_ms` or a straggling peer is indistinguishable from a
    /// dead one.
    pub comm_timeout_ms: Option<u64>,
    /// Fault injection (`--fail-rank`): the worker whose *epoch-0* rank
    /// matches exits deliberately at `fail_at_step`, exercising the
    /// re-formation path deterministically in tests and CI.
    pub fail_rank: Option<usize>,
    /// Step at which the failing rank exits (`--fail-at-step`).
    pub fail_at_step: u64,
    /// When set, the injected crash happens *after* the step barrier
    /// releases (mid-step, with ring collectives in flight) instead of
    /// at the boundary, exercising survivor rollback + re-run.
    pub fail_midstep: bool,
}

impl Default for HarnessConfig {
    fn default() -> HarnessConfig {
        HarnessConfig {
            compressor: "powersgd".into(),
            rank: 2,
            seed: 42,
            steps: 3,
            lr: 0.05,
            momentum: 0.9,
            pipeline: PipelineMode::Off,
            metrics: false,
            straggle_rank: 0,
            straggle_ms: 0,
            elastic: false,
            heartbeat_ms: 5000,
            reconnect_retries: DEFAULT_CONNECT_RETRIES,
            comm_timeout_ms: None,
            fail_rank: None,
            fail_at_step: 0,
            fail_midstep: false,
        }
    }
}

impl HarnessConfig {
    /// The ring I/O timeout this run uses: the `--comm-timeout-ms`
    /// override when present, otherwise the overall run timeout.
    pub fn ring_timeout(&self, run_timeout: Duration) -> Duration {
        self.comm_timeout_ms.map(Duration::from_millis).unwrap_or(run_timeout)
    }
}

/// The harness model: mixed matrix/vector parameters, vectors
/// interleaved like a real network.
pub fn harness_shapes() -> Vec<Vec<usize>> {
    vec![vec![12, 8], vec![5], vec![6, 10], vec![3]]
}

/// [`ParamRegistry`] over [`harness_shapes`], for the closed-form
/// `message_bytes` cross-check.
pub fn harness_registry() -> ParamRegistry {
    ParamRegistry::from_shapes(&[
        ("w0", vec![12, 8]),
        ("b0", vec![5]),
        ("w1", vec![6, 10]),
        ("b1", vec![3]),
    ])
}

/// Deterministic per-step gradients for all `world` workers. Every
/// process calls this with the same arguments and slices out its own
/// rank; the oracle consumes the whole draw. One shared RNG stream in
/// worker-major order keeps the bits identical everywhere.
pub fn synthetic_grads(world: usize, seed: u64, step: usize) -> Vec<Vec<Tensor>> {
    let mut rng = Rng::new(seed ^ ((step as u64 + 1).wrapping_mul(0x9e37_79b9)));
    (0..world)
        .map(|_| {
            harness_shapes()
                .iter()
                .map(|shape| {
                    let mut t = Tensor::zeros(shape);
                    rng.fill_normal(t.data_mut(), 1.0);
                    t
                })
                .collect()
        })
        .collect()
}

/// Deterministic initial parameters (identical on every process).
pub fn initial_params(seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed ^ 0xA11CE);
    harness_shapes()
        .iter()
        .map(|shape| {
            let mut t = Tensor::zeros(shape);
            rng.fill_normal(t.data_mut(), 1.0);
            t
        })
        .collect()
}

/// The centralized lockstep oracle trajectory: the same EF-SGD loop the
/// worker processes run, driven in one process with all `world` updates
/// per call. Returns the final parameters and the total per-worker
/// logical bytes logged.
pub fn oracle_trajectory(world: usize, cfg: &HarnessConfig) -> Result<(Vec<Tensor>, u64)> {
    let comp = oracle_by_name(&cfg.compressor, cfg.rank, cfg.seed)
        .ok_or_else(|| anyhow!("no centralized oracle for compressor {:?}", cfg.compressor))?;
    let mut opt = EfSgd::new(comp, LrSchedule::constant(cfg.lr), cfg.momentum);
    if cfg.pipeline == PipelineMode::Delayed {
        opt = opt.with_delayed_aggregate();
    }
    let mut params = initial_params(cfg.seed);
    let mut log = CommLog::default();
    for step in 0..cfg.steps {
        let grads = synthetic_grads(world, cfg.seed, step);
        let delta = opt.step(&grads, step, &mut log);
        for (x, d) in params.iter_mut().zip(delta.iter()) {
            x.axpy(-1.0, d);
        }
    }
    Ok((params, log.bytes_sent()))
}

/// One worker's finished run.
pub struct WorkerRunReport {
    /// This worker's ring rank.
    pub rank: usize,
    /// Final parameters after the EF-SGD trajectory.
    pub params: Vec<Tensor>,
    /// Per-worker logical bytes (the `CommLog` unit), summed over steps.
    pub logical_bytes: u64,
    /// Payload bytes this worker actually put on the wire.
    pub wire_bytes: u64,
    /// Every collective the run logged, in execution order — the input
    /// to the analytic [`ring_wire_bytes`] expansion (the experiment
    /// report recomputes and publishes it per rank).
    pub ops: Vec<CollOp>,
    /// Per-step run-health records, one per step when the config asked
    /// for metrics (`cfg.metrics`), empty otherwise. The wire-byte
    /// fields are per-step deltas of this worker's own metered
    /// counters, so their sum equals `wire_bytes` exactly.
    pub step_metrics: Vec<StepMetrics>,
}

/// Run this process's half of the EF-SGD trajectory over a connected,
/// metered endpoint. A peer dying mid-collective surfaces as a
/// contextual error (the infallible [`Transport`] methods panic with
/// the dead rank's name; this loop converts the panic back). Before
/// returning, the measured wire bytes are cross-checked against the
/// [`ring_wire_bytes`] expansion of every logged collective.
pub fn worker_trajectory<T>(
    endpoint: MeteredTransport<T>,
    cfg: &HarnessConfig,
) -> Result<WorkerRunReport>
where
    T: Transport<Vec<f32>> + Transport<Vec<u8>> + 'static,
{
    let world = <MeteredTransport<T> as Transport<Vec<f32>>>::world(&endpoint);
    let rank = <MeteredTransport<T> as Transport<Vec<f32>>>::rank(&endpoint);
    let counters = endpoint.counters();
    let comp = worker_by_name(&cfg.compressor, cfg.rank, cfg.seed).ok_or_else(|| {
        anyhow!("compressor {:?} has no per-worker implementation", cfg.compressor)
    })?;
    let logical_model = comp.message_bytes(&harness_registry()) * cfg.steps as u64;
    let mut opt = EfSgd::new(
        Box::new(EndpointCompressor::new(endpoint, comp).with_pipeline(cfg.pipeline)),
        LrSchedule::constant(cfg.lr),
        cfg.momentum,
    );
    if cfg.pipeline == PipelineMode::Delayed {
        opt = opt.with_delayed_aggregate();
    }

    let mut params = initial_params(cfg.seed);
    let mut log = CommLog::default();
    let mut step_metrics = Vec::with_capacity(if cfg.metrics { cfg.steps } else { 0 });
    let raw_bytes_per_step = harness_registry().numel() as u64 * ELEM_BYTES;
    let (mut prev_sent, mut prev_received) = (counters.sent(), counters.received());
    let mut prev_logical = 0u64;
    for step in 0..cfg.steps {
        let t0 = cfg.metrics.then(Instant::now);
        if cfg.straggle_ms > 0 && rank == cfg.straggle_rank {
            std::thread::sleep(Duration::from_millis(cfg.straggle_ms));
        }
        let grads = vec![synthetic_grads(world, cfg.seed, step).swap_remove(rank)];
        let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            opt.step(&grads, step, &mut log)
        }));
        let delta = match stepped {
            Ok(delta) => delta,
            Err(payload) => bail!(
                "rank {rank}: ring collective failed at step {step}: {}",
                panic_message(payload)
            ),
        };
        for (x, d) in params.iter_mut().zip(delta.iter()) {
            x.axpy(-1.0, d);
        }
        if let Some(t0) = t0 {
            // The wire fields are local per-step deltas (exact per
            // rank); the quality fields read the process-global gauge
            // registry, which is authoritative in the one-process-
            // per-rank setting and merely indicative when several
            // worker threads share a test process.
            let (sent, received) = (counters.sent(), counters.received());
            let logical = log.bytes_sent();
            let logical_delta = logical - prev_logical;
            step_metrics.push(StepMetrics {
                rank: rank as u64,
                step: step as u64,
                step_seconds: t0.elapsed().as_secs_f64(),
                wire_sent: sent - prev_sent,
                wire_received: received - prev_received,
                ef_residual: metrics::gauge_value(Gauge::EfResidual),
                approx_error: metrics::gauge_value(Gauge::ApproxError),
                compression_ratio: if logical_delta == 0 {
                    0.0
                } else {
                    raw_bytes_per_step as f64 / logical_delta as f64
                },
                staleness: u64::from(cfg.pipeline == PipelineMode::Delayed),
                inflight_peak: metrics::max_value(MaxGauge::InflightDepthPeak),
            });
            (prev_sent, prev_received, prev_logical) = (sent, received, logical);
        }
    }

    let logical_bytes = log.bytes_sent();
    if logical_bytes != logical_model {
        bail!(
            "rank {rank}: logged {logical_bytes} logical bytes but the closed-form \
             message_bytes model predicts {logical_model}"
        );
    }
    let wire_bytes = counters.sent();
    let expected_wire: u64 = log
        .ops
        .iter()
        .map(|op| ring_wire_bytes(op.kind, op.bytes, world, rank))
        .sum();
    if wire_bytes != expected_wire {
        bail!(
            "rank {rank}: measured {wire_bytes} wire bytes but the ring expansion of the \
             logged collectives predicts {expected_wire}"
        );
    }
    Ok(WorkerRunReport { rank, params, logical_bytes, wire_bytes, ops: log.ops, step_metrics })
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(msg) = payload.downcast_ref::<String>() {
        msg.clone()
    } else if let Some(msg) = payload.downcast_ref::<&'static str>() {
        (*msg).to_string()
    } else {
        "worker panicked".into()
    }
}

/// Full worker process: rendezvous at `coordinator`, run the
/// trajectory over a metered [`TcpRing`], report the final parameters
/// and byte counters back on the control connection. Returns the rank
/// the rendezvous assigned (callers use it for rank-suffixed artifacts
/// like per-rank trace files).
pub fn run_worker(coordinator: &str, cfg: &HarnessConfig, timeout: Duration) -> Result<usize> {
    run_worker_with_metrics(coordinator, cfg, timeout).map(|(rank, _)| rank)
}

/// [`run_worker`], also returning the per-step [`StepMetrics`] the run
/// collected (empty unless `cfg.metrics`) so callers can write the
/// rank's `METRICS_r<k>.jsonl` stream. When metrics are on, every
/// record is additionally pushed to the coordinator as a
/// `Frame::Metrics` sideband frame on the control connection, ahead of
/// the final `Report`.
pub fn run_worker_with_metrics(
    coordinator: &str,
    cfg: &HarnessConfig,
    timeout: Duration,
) -> Result<(usize, Vec<StepMetrics>)> {
    if cfg.elastic {
        return run_worker_elastic(coordinator, cfg, timeout);
    }
    let joined = join_with_retries(coordinator, timeout, cfg.reconnect_retries)?;
    let reconnect_attempts = joined.reconnect_attempts;
    let (ring, mut control) = TcpRing::from_joined(joined, cfg.ring_timeout(timeout))?;
    let report = worker_trajectory(MeteredTransport::new(ring), cfg)?;
    for m in &report.step_metrics {
        metrics::add(Counter::MetricsFrames, 1);
        write_frame(&mut control, &Frame::Metrics(*m))
            .map_err(|e| anyhow!(e))
            .with_context(|| {
                format!("rank {}: pushing step {} metrics to the coordinator", report.rank, m.step)
            })?;
    }
    write_frame(
        &mut control,
        &Frame::Report {
            rank: report.rank as u32,
            wire_bytes: report.wire_bytes,
            logical_bytes: report.logical_bytes,
            reconnect_attempts,
            tensors: report.params.iter().map(|t| t.data().to_vec()).collect(),
        },
    )
    .map_err(|e| anyhow!(e))
    .with_context(|| format!("rank {}: reporting to the coordinator", report.rank))?;
    Ok((report.rank, report.step_metrics))
}

/// One worker's verified outcome, as the coordinator sees it.
pub struct WorkerWireReport {
    /// The reporting worker's ring rank.
    pub rank: usize,
    /// Payload bytes the worker measured on its metered transport.
    pub wire_bytes: u64,
    /// Logical per-worker bytes the worker logged.
    pub logical_bytes: u64,
    /// Final parameters bit-identical to the oracle's.
    pub bitwise: bool,
}

/// A verified launch.
pub struct LaunchOutcome {
    /// Number of worker processes in the ring.
    pub world: usize,
    /// EF-SGD steps every worker ran.
    pub steps: usize,
    /// Per-rank reports (rank-indexed).
    pub reports: Vec<WorkerWireReport>,
    /// The oracle's per-worker logical bytes over the whole run.
    pub logical_bytes: u64,
    /// Closed-form per-worker message bytes per step.
    pub model_bytes_per_step: u64,
    /// Per-rank sideband metrics frames, rank-indexed; a rank's stream
    /// is empty when it pushed no frames (metrics off, or a peer that
    /// died after its `Report` would have — tolerated downstream by
    /// [`metrics::aggregate`]).
    pub metrics_by_rank: Vec<Vec<StepMetrics>>,
    /// Elastic membership history, one record per epoch (a single
    /// epoch-0 record for non-elastic or churn-free runs). Rendered
    /// into the merged `METRICS.json` by `cmd_launch`.
    pub epochs: Vec<EpochInfo>,
    /// Total connect retries across every reporting worker (each
    /// worker's local [`Backoff`] tallies, reconciled cluster-wide).
    pub reconnect_attempts_total: u64,
    /// Whether verification ran against a bitwise oracle — the
    /// lockstep oracle, or the composed elastic oracle where the churn
    /// kind preserves replay — as opposed to falling back to
    /// member-consistency (every member bitwise-equal to every other;
    /// see DESIGN.md §16). Always `true` for non-elastic launches.
    pub oracle_verified: bool,
}

impl LaunchOutcome {
    /// Whether every reporting rank's summed per-step wire deltas match
    /// the wire bytes its metered transport reported — the exact
    /// reconciliation pinned by the acceptance criteria. `None` when no
    /// rank pushed metrics frames (metrics off).
    pub fn metrics_reconcile(&self) -> Option<bool> {
        if self.metrics_by_rank.iter().all(|f| f.is_empty()) {
            return None;
        }
        Some(self.reports.iter().all(|r| {
            let frames = &self.metrics_by_rank[r.rank];
            frames.is_empty() || frames.iter().map(|m| m.wire_sent).sum::<u64>() == r.wire_bytes
        }))
    }
}

/// Coordinator half of a launch: rendezvous `world` workers, run the
/// lockstep oracle in-process, collect every worker's report, and
/// verify the whole chain — bitwise parameters, logical bytes against
/// the oracle, and the closed-form model. Any mismatch (or a worker
/// dying before it reports) is an error.
pub fn coordinate(
    rendezvous: &Rendezvous,
    world: usize,
    cfg: &HarnessConfig,
    timeout: Duration,
) -> Result<LaunchOutcome> {
    let mut controls = rendezvous.run(world, timeout)?;
    let (oracle_params, oracle_logical) = oracle_trajectory(world, cfg)?;
    let model_bytes_per_step = worker_by_name(&cfg.compressor, cfg.rank, cfg.seed)
        .map(|w| w.message_bytes(&harness_registry()))
        .unwrap_or(0);

    let mut reports = Vec::with_capacity(world);
    let mut metrics_by_rank: Vec<Vec<StepMetrics>> = vec![Vec::new(); world];
    let mut reconnect_attempts_total = 0u64;
    for (rank, control) in controls.iter_mut().enumerate() {
        // Drain the metrics sideband (zero or more frames) until the
        // final Report — workers only push frames when metrics are on,
        // so the loop is tolerant either way.
        let (got, wire_bytes, logical_bytes, tensors) = loop {
            let frame = read_frame(control).map_err(|e| anyhow!(e)).with_context(|| {
                format!("launch: worker rank {rank} died before reporting its result")
            })?;
            match frame {
                Frame::Metrics(m) => {
                    if m.rank as usize != rank {
                        bail!(
                            "launch: control stream {rank} delivered metrics from rank {}",
                            m.rank
                        );
                    }
                    metrics_by_rank[rank].push(m);
                }
                Frame::Report { rank, wire_bytes, logical_bytes, reconnect_attempts, tensors } => {
                    reconnect_attempts_total += reconnect_attempts;
                    break (rank, wire_bytes, logical_bytes, tensors)
                }
                other => {
                    bail!("launch: expected a Report from rank {rank}, got {}", other.kind_name())
                }
            }
        };
        if got as usize != rank {
            bail!("launch: control stream {rank} delivered a report from rank {got}");
        }
        let bitwise = tensors.len() == oracle_params.len()
            && tensors.iter().zip(oracle_params.iter()).all(|(got, want)| {
                got.len() == want.len()
                    && got
                        .iter()
                        .zip(want.data().iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits())
            });
        if !bitwise {
            bail!(
                "launch: rank {rank}'s final parameters diverged from the lockstep oracle \
                 (the TCP path must be bitwise-identical)"
            );
        }
        if logical_bytes != oracle_logical {
            bail!(
                "launch: rank {rank} logged {logical_bytes} logical bytes, oracle logged \
                 {oracle_logical}"
            );
        }
        reports.push(WorkerWireReport { rank, wire_bytes, logical_bytes, bitwise });
    }
    Ok(LaunchOutcome {
        world,
        steps: cfg.steps,
        reports,
        logical_bytes: oracle_logical,
        model_bytes_per_step,
        metrics_by_rank,
        epochs: vec![EpochInfo {
            epoch: 0,
            world,
            start_step: 0,
            missing_ranks: Vec::new(),
            joined: 0,
        }],
        reconnect_attempts_total,
        oracle_verified: true,
    })
}

/// Worker compressors with no cross-step state: a late joiner's fresh
/// instance is indistinguishable from a survivor's, so join runs stay
/// bitwise-verifiable against the composed elastic oracle.
pub fn stateless_worker_scheme(name: &str) -> bool {
    matches!(name, "sign-norm" | "top-k" | "none" | "sgd" | "identity")
}

/// Worker compressors whose per-step execution is a pure function of
/// pre-step state, so an aborted step re-runs bitwise-identically after
/// a mid-step reconfigure. Warm-start PowerSGD qualifies (its RNG is
/// consumed only at construction and the warm `Q` commits only after
/// the final all-reduce); per-step-RNG schemes (`powersgd-cold`,
/// `unbiased-rank`) do not — an aborted attempt advances their RNG.
pub fn midstep_replay_safe(name: &str) -> bool {
    name == "powersgd" || stateless_worker_scheme(name)
}

/// A swappable ring endpoint: the one [`Transport`] the optimizer holds
/// for a whole elastic run, delegating every call to the current
/// epoch's [`MeteredTransport<TcpRing>`]. On `Reconfigure` the driver
/// takes the old ring out (tearing its sockets down, which cascades EOF
/// to both neighbours) and installs the re-formed one. The optimizer
/// never observes the swap: it happens only between steps, or after a
/// step already aborted.
#[derive(Clone)]
pub struct ElasticLink {
    slot: Arc<Mutex<Option<MeteredTransport<TcpRing>>>>,
}

impl Default for ElasticLink {
    fn default() -> ElasticLink {
        ElasticLink::empty()
    }
}

impl ElasticLink {
    /// A link with no ring installed yet.
    pub fn empty() -> ElasticLink {
        ElasticLink { slot: Arc::new(Mutex::new(None)) }
    }

    // A ring panic mid-collective poisons the mutex; every accessor
    // bypasses the poison because the inner value is just a socket pair
    // that the reconfigure replaces wholesale.
    fn lock(&self) -> std::sync::MutexGuard<'_, Option<MeteredTransport<TcpRing>>> {
        self.slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Install the current epoch's ring (dropping any previous one).
    pub fn install(&self, ring: MeteredTransport<TcpRing>) {
        *self.lock() = Some(ring);
    }

    /// Take the ring out, leaving the link empty. Dropping the returned
    /// value closes both ring sockets — the teardown half of an epoch
    /// transition.
    pub fn take(&self) -> Option<MeteredTransport<TcpRing>> {
        self.lock().take()
    }

    fn with<R>(&self, f: impl FnOnce(&MeteredTransport<TcpRing>) -> R) -> R {
        let guard = self.lock();
        f(guard.as_ref().expect("elastic link: no ring installed"))
    }
}

impl Transport<Vec<f32>> for ElasticLink {
    fn rank(&self) -> usize {
        self.with(|t| Transport::<Vec<f32>>::rank(t))
    }

    fn world(&self) -> usize {
        self.with(|t| Transport::<Vec<f32>>::world(t))
    }

    fn post_send(&self, msg: Vec<f32>) -> Ticket {
        self.with(|t| Transport::<Vec<f32>>::post_send(t, msg))
    }

    fn post_recv(&self) -> Ticket {
        self.with(|t| Transport::<Vec<f32>>::post_recv(t))
    }

    fn poll(&self, ticket: Ticket) -> Completion<Vec<f32>> {
        self.with(|t| Transport::<Vec<f32>>::poll(t, ticket))
    }

    fn wait(&self, ticket: Ticket) -> Completion<Vec<f32>> {
        self.with(|t| Transport::<Vec<f32>>::wait(t, ticket))
    }
}

impl Transport<Vec<u8>> for ElasticLink {
    fn rank(&self) -> usize {
        self.with(|t| Transport::<Vec<u8>>::rank(t))
    }

    fn world(&self) -> usize {
        self.with(|t| Transport::<Vec<u8>>::world(t))
    }

    fn post_send(&self, msg: Vec<u8>) -> Ticket {
        self.with(|t| Transport::<Vec<u8>>::post_send(t, msg))
    }

    fn post_recv(&self) -> Ticket {
        self.with(|t| Transport::<Vec<u8>>::post_recv(t))
    }

    fn poll(&self, ticket: Ticket) -> Completion<Vec<u8>> {
        self.with(|t| Transport::<Vec<u8>>::poll(t, ticket))
    }

    fn wait(&self, ticket: Ticket) -> Completion<Vec<u8>> {
        self.with(|t| Transport::<Vec<u8>>::wait(t, ticket))
    }
}

/// Replay the centralized oracle for `upto` steps at `world` workers
/// and return the parameters and shared momentum at that boundary —
/// the state a late joiner seeds from (its error-feedback residual
/// starts at zero by policy; see DESIGN.md §16).
pub fn oracle_state_at(
    world: usize,
    cfg: &HarnessConfig,
    upto: usize,
) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
    let comp = oracle_by_name(&cfg.compressor, cfg.rank, cfg.seed)
        .ok_or_else(|| anyhow!("no centralized oracle for compressor {:?}", cfg.compressor))?;
    let mut opt = EfSgd::new(comp, LrSchedule::constant(cfg.lr), cfg.momentum);
    if cfg.pipeline == PipelineMode::Delayed {
        opt = opt.with_delayed_aggregate();
    }
    let mut params = initial_params(cfg.seed);
    let mut log = CommLog::default();
    for step in 0..upto {
        let grads = synthetic_grads(world, cfg.seed, step);
        let delta = opt.step(&grads, step, &mut log);
        for (x, d) in params.iter_mut().zip(delta.iter()) {
            x.axpy(-1.0, d);
        }
    }
    Ok((params, opt.momentum_state()))
}

/// One epoch of an elastic run's membership schedule, as the composed
/// oracle replays it: the world size, the step the epoch begins at, and
/// the membership edit that produced it from the previous epoch.
#[derive(Debug, Clone)]
pub struct EpochPlan {
    /// Number of workers during this epoch.
    pub world: usize,
    /// First step executed under this epoch.
    pub start_step: usize,
    /// Error-feedback slots (previous epoch's rank order, descending)
    /// removed at the transition — the departed ranks.
    pub departed_slots: Vec<usize>,
    /// Fresh zero-EF slots appended at the transition — late joiners.
    pub joined: usize,
}

/// The composed elastic oracle: the centralized lockstep trajectory
/// driven through the recorded epoch schedule, editing its per-worker
/// EF slots exactly as the coordinator compacted ranks (survivors keep
/// relative order and their own residuals; the departed rank's residual
/// is dropped; joiners append with zero residual). Under stable
/// membership this degenerates to [`oracle_trajectory`]. Returns the
/// final parameters and the full-run per-worker logical bytes of an
/// epoch-0 member.
pub fn elastic_oracle_trajectory(
    cfg: &HarnessConfig,
    plans: &[EpochPlan],
) -> Result<(Vec<Tensor>, u64)> {
    let comp = oracle_by_name(&cfg.compressor, cfg.rank, cfg.seed)
        .ok_or_else(|| anyhow!("no centralized oracle for compressor {:?}", cfg.compressor))?;
    let mut opt = EfSgd::new(comp, LrSchedule::constant(cfg.lr), cfg.momentum);
    if cfg.pipeline == PipelineMode::Delayed {
        opt = opt.with_delayed_aggregate();
    }
    let mut params = initial_params(cfg.seed);
    let mut log = CommLog::default();
    for (i, plan) in plans.iter().enumerate() {
        if i > 0 {
            for &slot in &plan.departed_slots {
                opt.remove_worker(slot);
            }
            for _ in 0..plan.joined {
                opt.add_worker();
            }
            opt.on_reconfigure(i as u64, plan.world);
        }
        let end = plans.get(i + 1).map(|next| next.start_step).unwrap_or(cfg.steps);
        for step in plan.start_step..end {
            let grads = synthetic_grads(plan.world, cfg.seed, step);
            let delta = opt.step(&grads, step, &mut log);
            for (x, d) in params.iter_mut().zip(delta.iter()) {
                x.axpy(-1.0, d);
            }
        }
    }
    Ok((params, log.bytes_sent()))
}

/// Per-epoch wire accounting on the worker side: which metered
/// counters, ops range, and ring identity the current epoch runs under.
struct EpochAcct {
    counters: WireCounters,
    ops_start: usize,
    rank: usize,
    world: usize,
    /// A ring collective aborted during this epoch: its posted-but-
    /// undelivered sends pollute the counters, so the per-epoch wire
    /// self-check is skipped (the logical log was rolled back instead).
    aborted: bool,
    prev_sent: u64,
    prev_received: u64,
}

impl EpochAcct {
    /// Close the epoch: cross-check measured wire bytes against the
    /// ring expansion of the ops logged under it (clean epochs only)
    /// and return the measured total.
    fn close(&self, log: &CommLog, orig_rank: usize) -> Result<u64> {
        let measured = self.counters.sent();
        if !self.aborted {
            let expected: u64 = log.ops[self.ops_start..]
                .iter()
                .map(|op| ring_wire_bytes(op.kind, op.bytes, self.world, self.rank))
                .sum();
            if measured != expected {
                bail!(
                    "rank {orig_rank}: epoch measured {measured} wire bytes but the ring \
                     expansion of its logged collectives predicts {expected}"
                );
            }
        }
        Ok(measured)
    }
}

/// The mutable identity of an elastic worker across epochs.
struct ElasticWorker<'a> {
    cfg: &'a HarnessConfig,
    ring_timeout: Duration,
    listener: std::net::TcpListener,
    port_seed: u64,
    link: ElasticLink,
    orig_rank: usize,
    epoch: u64,
    rank: usize,
    world: usize,
    acct: EpochAcct,
    wire_total: u64,
    /// Wire bytes accumulated since the last step-metrics record but
    /// charged to an epoch that has since closed (abort + re-form);
    /// folded into the next record so per-step deltas still sum to the
    /// run's wire total.
    carry_sent: u64,
    carry_received: u64,
    /// Connect retries this worker's own dials consumed (`Hello` plus
    /// every ring formation), reported to the coordinator at end of
    /// run — a local tally, so concurrent in-process workers never
    /// inflate each other's counts.
    reconnects: u64,
}

impl ElasticWorker<'_> {
    /// Apply a `Reconfigure`: close the old epoch's accounting, tear
    /// down the old ring (if the abort path didn't already), re-form
    /// the edges under the new identity, and reset the optimizer's
    /// membership-sensitive state.
    fn reconfigure(&mut self, frame: Frame, opt: &mut EfSgd, log: &CommLog) -> Result<()> {
        let (epoch, rank, world, peers) = match frame {
            Frame::Reconfigure { version: _, epoch, step: _, rank, world, departed: _, peers } => {
                (epoch, rank as usize, world as usize, peers)
            }
            other => bail!(
                "rank {}: expected Reconfigure on the control stream, got {}",
                self.orig_rank,
                other.kind_name()
            ),
        };
        if world == 0 || rank >= world || peers.len() != world {
            bail!(
                "rank {}: malformed Reconfigure (rank {rank}, world {world}, {} peers)",
                self.orig_rank,
                peers.len()
            );
        }
        self.wire_total += self.acct.close(log, self.orig_rank)?;
        self.carry_sent += self.acct.counters.sent() - self.acct.prev_sent;
        self.carry_received += self.acct.counters.received() - self.acct.prev_received;
        drop(self.link.take());
        let mut backoff =
            Backoff::standard(self.cfg.reconnect_retries, self.port_seed ^ rank as u64 ^ epoch);
        let (to_next, from_prev) =
            form_ring_edges(rank, world, &peers, &self.listener, self.ring_timeout, &mut backoff)
                .with_context(|| {
                    format!("rank {}: re-forming the ring for epoch {epoch}", self.orig_rank)
                })?;
        self.reconnects += backoff.attempts();
        let metered = MeteredTransport::new(TcpRing::new(
            rank,
            world,
            to_next,
            from_prev,
            self.ring_timeout,
        )?);
        self.acct = EpochAcct {
            counters: metered.counters(),
            ops_start: log.ops.len(),
            rank,
            world,
            aborted: false,
            prev_sent: 0,
            prev_received: 0,
        };
        self.link.install(metered);
        opt.on_reconfigure(epoch, world);
        (self.epoch, self.rank, self.world) = (epoch, rank, world);
        Ok(())
    }
}

/// Elastic worker process (DESIGN.md §16): `Hello` the coordinator,
/// receive either a `Welcome` (initial member) or a `Reconfigure` (late
/// joiner — replay the shared trajectory locally to the join step),
/// then run the EF-SGD loop under a step-heartbeat barrier. Every step
/// boundary sends `Heartbeat` and blocks for the coordinator's release:
/// an echoed heartbeat continues the epoch, a `Reconfigure` tears the
/// ring down and re-forms it before running the same step under the new
/// membership. A ring collective failing mid-step rolls the logical log
/// back to the step boundary, drops the ring (cascading EOF to the
/// neighbours), re-heartbeats the *same* step, and waits for the
/// re-formation. Returns the epoch-0 rank and collected step metrics.
pub fn run_worker_elastic(
    coordinator: &str,
    cfg: &HarnessConfig,
    timeout: Duration,
) -> Result<(usize, Vec<StepMetrics>)> {
    let ring_timeout = cfg.ring_timeout(timeout);
    let (mut control, listener, _my_addr, hello_retries) =
        hello(coordinator, timeout, cfg.reconnect_retries)?;
    let port_seed = u64::from(listener.local_addr().map(|a| a.port()).unwrap_or(0));

    let first = read_frame(&mut control)
        .map_err(|e| anyhow!(e))
        .context("worker: waiting for Welcome/Reconfigure (coordinator died or timed out?)")?;
    let (epoch, rank, world, peers, start_step, late_joiner) = match first {
        Frame::Welcome { rank, world, peers } => {
            (0u64, rank as usize, world as usize, peers, 0u64, false)
        }
        Frame::Reconfigure { version: _, epoch, step, rank, world, departed: _, peers } => {
            (epoch, rank as usize, world as usize, peers, step, true)
        }
        other => bail!("worker: expected Welcome or Reconfigure, got {}", other.kind_name()),
    };
    if world == 0 || rank >= world || peers.len() != world {
        bail!("worker: malformed membership (rank {rank}, world {world}, {} peers)", peers.len());
    }
    let orig_rank = rank;

    // A late joiner recovers the shared parameters and momentum by
    // replaying the centralized oracle at the pre-join world (documented
    // restriction: joins assume stable membership before the join); its
    // error-feedback residual starts at zero by policy.
    let (mut params, replay_momentum) = if start_step > 0 {
        oracle_state_at(world - 1, cfg, start_step as usize)?
    } else {
        (initial_params(cfg.seed), Vec::new())
    };

    let link = ElasticLink::empty();
    let mut backoff =
        Backoff::standard(cfg.reconnect_retries, port_seed ^ rank as u64 ^ epoch);
    let (to_next, from_prev) =
        form_ring_edges(rank, world, &peers, &listener, ring_timeout, &mut backoff)?;
    let metered =
        MeteredTransport::new(TcpRing::new(rank, world, to_next, from_prev, ring_timeout)?);
    let acct = EpochAcct {
        counters: metered.counters(),
        ops_start: 0,
        rank,
        world,
        aborted: false,
        prev_sent: 0,
        prev_received: 0,
    };
    link.install(metered);

    let comp = worker_by_name(&cfg.compressor, cfg.rank, cfg.seed).ok_or_else(|| {
        anyhow!("compressor {:?} has no per-worker implementation", cfg.compressor)
    })?;
    let model_bytes_per_step = comp.message_bytes(&harness_registry());
    let mut opt = EfSgd::new(
        Box::new(EndpointCompressor::new(link.clone(), comp).with_pipeline(cfg.pipeline)),
        LrSchedule::constant(cfg.lr),
        cfg.momentum,
    );
    if cfg.pipeline == PipelineMode::Delayed {
        opt = opt.with_delayed_aggregate();
    }
    if !replay_momentum.is_empty() {
        opt = opt.with_momentum_state(replay_momentum);
    }

    let mut me = ElasticWorker {
        cfg,
        ring_timeout,
        listener,
        port_seed,
        link,
        orig_rank,
        epoch,
        rank,
        world,
        acct,
        wire_total: 0,
        carry_sent: 0,
        carry_received: 0,
        reconnects: hello_retries + backoff.attempts(),
    };

    let mut log = CommLog::default();
    let mut step_metrics = Vec::new();
    let raw_bytes_per_step = harness_registry().numel() as u64 * ELEM_BYTES;
    let mut prev_logical = 0u64;
    let mut step = start_step as usize;
    // A joiner's admission `Reconfigure` already released the barrier
    // for its first step (whatever step that is — keyed on the frame
    // kind, not on `start_step`, so a step-0 join doesn't barrier
    // twice); initial members heartbeat from step 0.
    let mut need_barrier = !late_joiner;
    while step < cfg.steps {
        if need_barrier {
            if cfg.straggle_ms > 0 && orig_rank == cfg.straggle_rank {
                std::thread::sleep(Duration::from_millis(cfg.straggle_ms));
            }
            if !cfg.fail_midstep
                && cfg.fail_rank == Some(orig_rank)
                && step as u64 == cfg.fail_at_step
            {
                bail!("fault injection: rank {orig_rank} crashing at the step {step} boundary");
            }
            write_frame(
                &mut control,
                &Frame::Heartbeat { rank: orig_rank as u32, epoch: me.epoch, step: step as u64 },
            )
            .map_err(|e| anyhow!(e))
            .with_context(|| format!("rank {orig_rank}: heartbeat for step {step}"))?;
            let reply = read_frame(&mut control).map_err(|e| anyhow!(e)).with_context(|| {
                format!("rank {orig_rank}: waiting for the step {step} barrier release")
            })?;
            match reply {
                Frame::Heartbeat { .. } => {}
                reconf @ Frame::Reconfigure { .. } => me.reconfigure(reconf, &mut opt, &log)?,
                other => bail!(
                    "rank {orig_rank}: expected a barrier release for step {step}, got {}",
                    other.kind_name()
                ),
            }
        }
        need_barrier = true;
        if cfg.fail_midstep && cfg.fail_rank == Some(orig_rank) && step as u64 == cfg.fail_at_step
        {
            bail!("fault injection: rank {orig_rank} crashing mid-step {step}");
        }
        let t0 = cfg.metrics.then(Instant::now);
        let grads = vec![synthetic_grads(me.world, cfg.seed, step).swap_remove(me.rank)];
        let ops_before = log.ops.len();
        let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            opt.step(&grads, step, &mut log)
        }));
        match stepped {
            Ok(delta) => {
                for (x, d) in params.iter_mut().zip(delta.iter()) {
                    x.axpy(-1.0, d);
                }
                if let Some(t0) = t0 {
                    let (sent, received) =
                        (me.acct.counters.sent(), me.acct.counters.received());
                    let logical = log.bytes_sent();
                    let logical_delta = logical - prev_logical;
                    step_metrics.push(StepMetrics {
                        rank: orig_rank as u64,
                        step: step as u64,
                        step_seconds: t0.elapsed().as_secs_f64(),
                        wire_sent: me.carry_sent + sent - me.acct.prev_sent,
                        wire_received: me.carry_received + received - me.acct.prev_received,
                        ef_residual: metrics::gauge_value(Gauge::EfResidual),
                        approx_error: metrics::gauge_value(Gauge::ApproxError),
                        compression_ratio: if logical_delta == 0 {
                            0.0
                        } else {
                            raw_bytes_per_step as f64 / logical_delta as f64
                        },
                        staleness: u64::from(cfg.pipeline == PipelineMode::Delayed),
                        inflight_peak: metrics::max_value(MaxGauge::InflightDepthPeak),
                    });
                    (me.carry_sent, me.carry_received) = (0, 0);
                    (me.acct.prev_sent, me.acct.prev_received) = (sent, received);
                    prev_logical = logical;
                }
                step += 1;
            }
            Err(payload) => {
                // A peer died mid-collective. Roll the logical log back
                // to the step boundary (the optimizer's own state only
                // commits after a successful step), drop the ring so
                // the failure cascades to the neighbours, and re-sync
                // with the coordinator by heartbeating the same step.
                let cause = panic_message(payload);
                log.ops.truncate(ops_before);
                me.acct.aborted = true;
                drop(me.link.take());
                write_frame(
                    &mut control,
                    &Frame::Heartbeat {
                        rank: orig_rank as u32,
                        epoch: me.epoch,
                        step: step as u64,
                    },
                )
                .map_err(|e| anyhow!(e))
                .with_context(|| {
                    format!("rank {orig_rank}: reporting the step {step} ring failure")
                })?;
                let reply = read_frame(&mut control).map_err(|e| anyhow!(e)).with_context(
                    || format!("rank {orig_rank}: waiting for re-formation after step {step}"),
                )?;
                match reply {
                    reconf @ Frame::Reconfigure { .. } => {
                        me.reconfigure(reconf, &mut opt, &log)?;
                        // The Reconfigure releases the barrier for this
                        // same step; re-run it under the new epoch.
                        need_barrier = false;
                    }
                    Frame::Heartbeat { .. } => bail!(
                        "rank {orig_rank}: ring collective failed at step {step} ({cause}) \
                         but the coordinator reports stable membership"
                    ),
                    other => bail!(
                        "rank {orig_rank}: expected re-formation after step {step}, got {}",
                        other.kind_name()
                    ),
                }
            }
        }
    }

    me.wire_total += me.acct.close(&log, orig_rank)?;
    let logical_bytes = log.bytes_sent();
    let executed = cfg.steps as u64 - start_step;
    let logical_model = model_bytes_per_step * executed;
    if logical_bytes != logical_model {
        bail!(
            "rank {orig_rank}: logged {logical_bytes} logical bytes over {executed} steps but \
             the closed-form message_bytes model predicts {logical_model}"
        );
    }
    for m in &step_metrics {
        metrics::add(Counter::MetricsFrames, 1);
        write_frame(&mut control, &Frame::Metrics(*m)).map_err(|e| anyhow!(e)).with_context(
            || format!("rank {orig_rank}: pushing step {} metrics to the coordinator", m.step),
        )?;
    }
    write_frame(
        &mut control,
        &Frame::Report {
            rank: orig_rank as u32,
            wire_bytes: me.wire_total,
            logical_bytes,
            reconnect_attempts: me.reconnects,
            tensors: params.iter().map(|t| t.data().to_vec()).collect(),
        },
    )
    .map_err(|e| anyhow!(e))
    .with_context(|| format!("rank {orig_rank}: reporting to the coordinator"))?;
    Ok((orig_rank, step_metrics))
}

/// One live member of an elastic run, as the coordinator tracks it.
/// The vec of members is always in *current rank order*; `orig` is the
/// stable identity (epoch-0 rank, or the next id for joiners) that
/// reports and metrics are keyed by.
struct Member {
    orig: u64,
    control: TcpStream,
    addr: String,
    start_step: u64,
    report: Option<(u64, u64, Vec<Vec<f32>>)>,
}

/// Elastic coordinator (DESIGN.md §16): a synchronous round loop over
/// the members' control streams. Each round reads one frame per live
/// member — a `Heartbeat` (step barrier), sideband `Metrics`, or the
/// final `Report` — with a read failure marking the member dead (EOF
/// for a crash, the `--heartbeat-ms` timeout for a hang). A round with
/// deaths (or a pending `--join-at-step` admission) becomes an epoch
/// transition: the coordinator verifies every survivor stopped at the
/// same step boundary, compacts ranks preserving order, admits the
/// joiner's held `Hello` if due, and broadcasts `Reconfigure` as the
/// barrier release; otherwise it echoes the heartbeats. After all
/// members report, the run is verified against the composed elastic
/// oracle (or member-consistency where the oracle's bitwise guarantee
/// does not survive the churn kind — see DESIGN.md §16).
pub fn coordinate_elastic(
    rendezvous: &Rendezvous,
    world: usize,
    cfg: &HarnessConfig,
    timeout: Duration,
    join_at_step: Option<u64>,
) -> Result<LaunchOutcome> {
    let heartbeat_timeout = Duration::from_millis(cfg.heartbeat_ms.max(1));
    let mut members: Vec<Member> = rendezvous
        .run_collecting(world, timeout)?
        .into_iter()
        .enumerate()
        .map(|(rank, (control, addr))| {
            control.set_read_timeout(Some(heartbeat_timeout)).ok();
            Member { orig: rank as u64, control, addr, start_step: 0, report: None }
        })
        .collect();
    let model_bytes_per_step = worker_by_name(&cfg.compressor, cfg.rank, cfg.seed)
        .map(|w| w.message_bytes(&harness_registry()))
        .unwrap_or(0);

    let mut metrics_by_rank: Vec<Vec<StepMetrics>> = vec![Vec::new(); world];
    let mut plans =
        vec![EpochPlan { world, start_step: 0, departed_slots: Vec::new(), joined: 0 }];
    let mut infos = vec![EpochInfo {
        epoch: 0,
        world,
        start_step: 0,
        missing_ranks: Vec::new(),
        joined: 0,
    }];
    let mut epoch = 0u64;
    let mut next_orig = world as u64;
    let mut join_at = join_at_step;
    let mut reconnect_attempts_total = 0u64;
    // The last step boundary the coordinator released (by heartbeat
    // echo or Reconfigure). Survivors re-heartbeating an already
    // released step means a collective aborted *mid-step* and is being
    // rolled back and re-run — observed behavior, not the injection
    // flag, decides the verification tier below.
    let mut last_released: Option<u64> = None;
    let mut any_midstep_abort = false;

    while members.iter().any(|m| m.report.is_none()) {
        let mut dead: Vec<usize> = Vec::new();
        let mut hb: Vec<Option<u64>> = vec![None; members.len()];
        for (i, m) in members.iter_mut().enumerate() {
            if m.report.is_some() {
                continue;
            }
            loop {
                match read_frame(&mut m.control) {
                    Ok(Frame::Metrics(sm)) => {
                        if sm.rank != m.orig {
                            bail!(
                                "launch: member {} delivered metrics from rank {}",
                                m.orig,
                                sm.rank
                            );
                        }
                        metrics_by_rank[m.orig as usize].push(sm);
                    }
                    Ok(Frame::Heartbeat { rank, epoch: _, step }) => {
                        if u64::from(rank) != m.orig {
                            bail!(
                                "launch: member {} delivered a heartbeat from rank {rank}",
                                m.orig
                            );
                        }
                        hb[i] = Some(step);
                        break;
                    }
                    Ok(Frame::Report {
                        rank,
                        wire_bytes,
                        logical_bytes,
                        reconnect_attempts,
                        tensors,
                    }) => {
                        if u64::from(rank) != m.orig {
                            bail!(
                                "launch: member {} delivered a report from rank {rank}",
                                m.orig
                            );
                        }
                        reconnect_attempts_total += reconnect_attempts;
                        m.report = Some((wire_bytes, logical_bytes, tensors));
                        break;
                    }
                    Ok(other) => {
                        bail!("launch: unexpected {} from member {}", other.kind_name(), m.orig)
                    }
                    Err(_) => {
                        // EOF = crash or departure; a read timeout means
                        // the member outlived --heartbeat-ms silently.
                        // Either way it leaves the membership.
                        dead.push(i);
                        break;
                    }
                }
            }
        }

        let live_steps: Vec<u64> = (0..members.len())
            .filter(|i| !dead.contains(i) && members[*i].report.is_none())
            .filter_map(|i| hb[i])
            .collect();
        let barrier_step = live_steps.first().copied();
        let join_now = join_at.is_some() && barrier_step == join_at && !live_steps.is_empty();

        if !dead.is_empty() || join_now {
            // Epoch-transition gate: every survivor must have stopped
            // at the same step boundary; a partially-delivered step
            // cannot be reconciled deterministically.
            let survivors_inconsistent = live_steps.windows(2).any(|w| w[0] != w[1])
                || members
                    .iter()
                    .enumerate()
                    .any(|(i, m)| !dead.contains(&i) && m.report.is_some());
            if survivors_inconsistent {
                bail!(
                    "launch: membership changed but survivors stopped at different step \
                     boundaries ({live_steps:?}) — a partially delivered step cannot be \
                     re-formed deterministically"
                );
            }
            let Some(step) = barrier_step else {
                bail!("launch: every member died; nothing left to re-form");
            };
            if last_released == Some(step) {
                any_midstep_abort = true;
            }
            let mut departed_slots = dead.clone();
            departed_slots.sort_unstable_by_key(|&slot| std::cmp::Reverse(slot));
            let departed_origs: Vec<u64> =
                departed_slots.iter().map(|&slot| members[slot].orig).collect();
            for &slot in &departed_slots {
                members.remove(slot);
            }
            let mut joined = 0usize;
            if join_now {
                // The joiner's stable identity is the ring rank its
                // admission `Reconfigure` carries, which only matches
                // `next_orig` while no member has ever departed — and
                // its state replay assumes an unchurned prefix. Reject
                // the combination here (DESIGN.md §16) instead of
                // failing the joiner's first heartbeat with a
                // confusing identity mismatch.
                if members.len() as u64 != next_orig {
                    bail!(
                        "launch: --join-at-step {step} falls after a departure — the joiner \
                         cannot replay the churned prefix, so joining a churned run is out of \
                         scope (DESIGN.md §16)"
                    );
                }
                let (control, addr) = rendezvous
                    .accept_hello(Instant::now() + timeout, timeout)
                    .context("launch: --join-at-step reached but no extra worker said Hello")?;
                control.set_read_timeout(Some(heartbeat_timeout)).ok();
                members.push(Member {
                    orig: next_orig,
                    control,
                    addr,
                    start_step: step,
                    report: None,
                });
                metrics_by_rank.push(Vec::new());
                next_orig += 1;
                joined = 1;
                join_at = None;
            }
            if members.is_empty() {
                bail!("launch: every member died at step {step}; nothing left to re-form");
            }
            epoch += 1;
            let world_now = members.len();
            let peers: Vec<String> = members.iter().map(|m| m.addr.clone()).collect();
            for (new_rank, m) in members.iter_mut().enumerate() {
                write_frame(
                    &mut m.control,
                    &Frame::Reconfigure {
                        version: RECONFIGURE_VERSION,
                        epoch,
                        step,
                        rank: new_rank as u32,
                        world: world_now as u32,
                        departed: departed_origs.iter().map(|&o| o as u32).collect(),
                        peers: peers.clone(),
                    },
                )
                .map_err(|e| anyhow!(e))
                .with_context(|| {
                    format!("launch: sending epoch {epoch} Reconfigure to member {}", m.orig)
                })?;
            }
            plans.push(EpochPlan {
                world: world_now,
                start_step: step as usize,
                departed_slots,
                joined,
            });
            infos.push(EpochInfo {
                epoch,
                world: world_now,
                start_step: step,
                missing_ranks: departed_origs,
                joined,
            });
            last_released = Some(step);
        } else {
            // Stable round: echo every heartbeat (the barrier release).
            for (i, m) in members.iter_mut().enumerate() {
                if let Some(step) = hb[i] {
                    write_frame(
                        &mut m.control,
                        &Frame::Heartbeat { rank: m.orig as u32, epoch, step },
                    )
                    .map_err(|e| anyhow!(e))
                    .with_context(|| {
                        format!("launch: releasing step {step} for member {}", m.orig)
                    })?;
                }
            }
            if let Some(step) = hb.iter().flatten().next() {
                last_released = Some(*step);
            }
        }
    }

    // Verification. The composed oracle is bitwise-authoritative except
    // where churn kind and compressor state interact (DESIGN.md §16):
    // a joiner's fresh compressor state breaks bitwise for stateful
    // schemes, and an aborted mid-step attempt (as observed by the
    // round loop — injected or not) advances per-step-RNG schemes.
    // Those runs fall back to member-consistency: every member's final
    // parameters must still be identical to each other.
    let any_join = plans.iter().any(|p| p.joined > 0);
    let oracle_applicable = (!any_join || stateless_worker_scheme(&cfg.compressor))
        && (!any_midstep_abort || midstep_replay_safe(&cfg.compressor));
    let (oracle_params, oracle_logical) = if oracle_applicable {
        let (p, l) = elastic_oracle_trajectory(cfg, &plans)?;
        (Some(p), l)
    } else {
        (None, model_bytes_per_step * cfg.steps as u64)
    };
    let mut reference_owned: Vec<Vec<f32>> = Vec::new();
    let mut reports = Vec::with_capacity(members.len());
    for m in &members {
        let (wire_bytes, logical_bytes, tensors) =
            m.report.as_ref().expect("loop exits only when every member reported");
        let expect_logical = model_bytes_per_step * (cfg.steps as u64 - m.start_step);
        if *logical_bytes != expect_logical {
            bail!(
                "launch: member {} logged {logical_bytes} logical bytes but its {} executed \
                 steps predict {expect_logical}",
                m.orig,
                cfg.steps as u64 - m.start_step
            );
        }
        let bitwise = match &oracle_params {
            Some(oracle) => bits_equal_tensors(tensors, oracle),
            None => {
                if reference_owned.is_empty() {
                    reference_owned = tensors.clone();
                    true
                } else {
                    bits_equal_raw(tensors, &reference_owned)
                }
            }
        };
        if !bitwise {
            bail!(
                "launch: member {}'s final parameters diverged from the {} \
                 (elastic runs must stay deterministic within the recorded epoch schedule)",
                m.orig,
                if oracle_params.is_some() { "composed elastic oracle" } else { "other members" }
            );
        }
        reports.push(WorkerWireReport {
            rank: m.orig as usize,
            wire_bytes: *wire_bytes,
            logical_bytes: *logical_bytes,
            bitwise,
        });
    }
    reports.sort_by_key(|r| r.rank);
    Ok(LaunchOutcome {
        world,
        steps: cfg.steps,
        reports,
        logical_bytes: oracle_logical,
        model_bytes_per_step,
        metrics_by_rank,
        epochs: infos,
        reconnect_attempts_total,
        oracle_verified: oracle_applicable,
    })
}

fn bits_equal_tensors(got: &[Vec<f32>], want: &[Tensor]) -> bool {
    got.len() == want.len()
        && got.iter().zip(want.iter()).all(|(g, w)| {
            g.len() == w.len()
                && g.iter().zip(w.data().iter()).all(|(a, b)| a.to_bits() == b.to_bits())
        })
}

fn bits_equal_raw(got: &[Vec<f32>], want: &[Vec<f32>]) -> bool {
    got.len() == want.len()
        && got.iter().zip(want.iter()).all(|(g, w)| {
            g.len() == w.len() && g.iter().zip(w.iter()).all(|(a, b)| a.to_bits() == b.to_bits())
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_grads_are_deterministic_and_worker_major() {
        let a = synthetic_grads(4, 7, 2);
        let b = synthetic_grads(4, 7, 2);
        for (wa, wb) in a.iter().zip(b.iter()) {
            for (ta, tb) in wa.iter().zip(wb.iter()) {
                assert_eq!(ta.data(), tb.data());
            }
        }
        // A different step or seed draws different bits.
        let c = synthetic_grads(4, 7, 3);
        assert_ne!(a[0][0].data(), c[0][0].data());
        // A smaller world is a prefix of a larger one (worker-major
        // stream), so every process can slice out its own rank.
        let small = synthetic_grads(2, 7, 2);
        for (wa, wb) in small.iter().zip(a.iter().take(2)) {
            for (ta, tb) in wa.iter().zip(wb.iter()) {
                assert_eq!(ta.data(), tb.data());
            }
        }
    }

    #[test]
    fn registry_matches_shapes() {
        let reg = harness_registry();
        let shapes = harness_shapes();
        assert_eq!(reg.len(), shapes.len());
        let numel: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        assert_eq!(reg.numel(), numel);
    }

    #[test]
    fn oracle_trajectory_moves_and_is_deterministic() {
        let cfg = HarnessConfig::default();
        let (a, bytes_a) = oracle_trajectory(2, &cfg).unwrap();
        let (b, bytes_b) = oracle_trajectory(2, &cfg).unwrap();
        assert_eq!(bytes_a, bytes_b);
        let mut moved = false;
        for (ta, tb) in a.iter().zip(b.iter()) {
            assert_eq!(ta.data(), tb.data());
        }
        let x0 = initial_params(cfg.seed);
        for (t, t0) in a.iter().zip(x0.iter()) {
            if t.data() != t0.data() {
                moved = true;
            }
        }
        assert!(moved, "three EF-SGD steps must move the parameters");
    }

    #[test]
    fn unknown_compressor_is_a_clean_error() {
        let cfg = HarnessConfig { compressor: "atomo".into(), ..HarnessConfig::default() };
        assert!(oracle_trajectory(2, &cfg).is_err());
    }
}
