//! Length-prefixed binary wire codec for the TCP ring transport
//! (DESIGN.md §10).
//!
//! Every message on a ring edge or a rendezvous control connection is
//! one frame:
//!
//! ```text
//! ┌───────┬──────┬───────────┬─────────────┐
//! │ magic │ kind │ len (u32) │ payload     │
//! │ "PS"  │ 1 B  │ LE        │ `len` bytes │
//! └───────┴──────┴───────────┴─────────────┘
//! ```
//!
//! Control frames carry the rendezvous handshake (`Hello` / `Welcome` /
//! `Connect`), the elastic-membership protocol (`Heartbeat` /
//! `Reconfigure`, DESIGN.md §16) and the end-of-run `Report`; data
//! frames carry the ring collectives' payloads (`F32s` for all-reduce
//! chunks and top-K gather messages, `Bytes` for packed sign bitmaps). All integers are
//! little-endian; f32 payloads round-trip **bit-exactly** (the codec
//! moves `f32::to_le_bytes` bits, never reformats values), which is
//! what lets the TCP engine stay bitwise-identical to the in-process
//! oracle.
//!
//! Decoding never panics: truncated input, a bad magic, an unknown
//! kind, an oversized length prefix, or a payload inconsistent with its
//! kind all surface as a typed [`WireError`]. A corrupt peer can
//! therefore produce at worst a contextual error, not a crash or a
//! multi-gigabyte allocation (lengths are capped at [`MAX_PAYLOAD`]).

use std::fmt;
use std::io::{self, Read, Write};

/// Every frame starts with these two bytes.
pub const MAGIC: [u8; 2] = *b"PS";

/// Upper bound on a frame payload: a corrupt length prefix is rejected
/// instead of being trusted as an allocation size.
pub const MAX_PAYLOAD: u32 = 1 << 30;

/// Fixed frame header size: magic (2) + kind (1) + length (4).
pub const HEADER_LEN: usize = 7;

const KIND_HELLO: u8 = 1;
const KIND_WELCOME: u8 = 2;
const KIND_CONNECT: u8 = 3;
const KIND_F32S: u8 = 4;
const KIND_BYTES: u8 = 5;
const KIND_REPORT: u8 = 6;
const KIND_METRICS: u8 = 7;
const KIND_HEARTBEAT: u8 = 8;
const KIND_RECONFIGURE: u8 = 9;

/// Version tag carried by every [`Frame::Reconfigure`]; a decoder that
/// sees a higher version rejects the frame with a typed error instead
/// of misinterpreting fields added later.
pub const RECONFIGURE_VERSION: u32 = 1;

/// One wire message.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Worker → coordinator: "I want to join; my ring listener is at
    /// `listen_addr`."
    Hello { listen_addr: String },
    /// Coordinator → worker: assigned rank, world size, and every
    /// worker's ring listener address indexed by rank.
    Welcome { rank: u32, world: u32, peers: Vec<String> },
    /// Ring predecessor → successor, first frame on a ring edge:
    /// identifies who is connecting.
    Connect { rank: u32 },
    /// An f32 collective payload (all-reduce chunk, top-K message).
    F32s(Vec<f32>),
    /// A raw byte collective payload (packed sign bitmap).
    Bytes(Vec<u8>),
    /// Worker → coordinator at end of run: final parameters plus the
    /// measured-bytes accounting for cross-checking, and the number of
    /// connect retries this rank burned (reconciled in the cluster
    /// summary).
    Report {
        rank: u32,
        wire_bytes: u64,
        logical_bytes: u64,
        reconnect_attempts: u64,
        tensors: Vec<Vec<f32>>,
    },
    /// Worker → coordinator run-health sideband: one per-step metrics
    /// record (`--metrics`), sent on the rendezvous control connection
    /// ahead of the final `Report`. Encoded as ten little-endian u64
    /// words — f64 fields travel as `f64::to_bits`, so values
    /// round-trip bit-exactly like the f32 data frames.
    Metrics(crate::obs::metrics::StepMetrics),
    /// Worker → coordinator at every step boundary under `--elastic`:
    /// "I am alive in `epoch` and about to run `step`." The coordinator
    /// echoes the frame back as the go-ahead, which makes each step
    /// boundary a membership barrier (DESIGN.md §16).
    Heartbeat { rank: u32, epoch: u64, step: u64 },
    /// Coordinator → worker on a membership change: the new epoch, the
    /// step at which it begins, this worker's new rank, the new world
    /// size, the old-epoch ranks that departed, and every member's ring
    /// listener address indexed by new rank. Carries a version field so
    /// future layouts are rejected, not misread.
    Reconfigure {
        version: u32,
        epoch: u64,
        step: u64,
        rank: u32,
        world: u32,
        departed: Vec<u32>,
        peers: Vec<String>,
    },
}

impl Frame {
    fn kind_byte(&self) -> u8 {
        match self {
            Frame::Hello { .. } => KIND_HELLO,
            Frame::Welcome { .. } => KIND_WELCOME,
            Frame::Connect { .. } => KIND_CONNECT,
            Frame::F32s(_) => KIND_F32S,
            Frame::Bytes(_) => KIND_BYTES,
            Frame::Report { .. } => KIND_REPORT,
            Frame::Metrics(_) => KIND_METRICS,
            Frame::Heartbeat { .. } => KIND_HEARTBEAT,
            Frame::Reconfigure { .. } => KIND_RECONFIGURE,
        }
    }

    /// Human-readable kind for protocol-mismatch errors.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "Hello",
            Frame::Welcome { .. } => "Welcome",
            Frame::Connect { .. } => "Connect",
            Frame::F32s(_) => "F32s",
            Frame::Bytes(_) => "Bytes",
            Frame::Report { .. } => "Report",
            Frame::Metrics(_) => "Metrics",
            Frame::Heartbeat { .. } => "Heartbeat",
            Frame::Reconfigure { .. } => "Reconfigure",
        }
    }

    fn payload(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Frame::Hello { listen_addr } => put_str(&mut out, listen_addr),
            Frame::Welcome { rank, world, peers } => {
                out.extend_from_slice(&rank.to_le_bytes());
                out.extend_from_slice(&world.to_le_bytes());
                for p in peers {
                    put_str(&mut out, p);
                }
            }
            Frame::Connect { rank } => out.extend_from_slice(&rank.to_le_bytes()),
            Frame::F32s(vals) => {
                out.reserve(vals.len() * 4);
                for v in vals {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::Bytes(b) => out.extend_from_slice(b),
            Frame::Report { rank, wire_bytes, logical_bytes, reconnect_attempts, tensors } => {
                out.extend_from_slice(&rank.to_le_bytes());
                out.extend_from_slice(&wire_bytes.to_le_bytes());
                out.extend_from_slice(&logical_bytes.to_le_bytes());
                out.extend_from_slice(&reconnect_attempts.to_le_bytes());
                out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
                for t in tensors {
                    out.extend_from_slice(&(t.len() as u32).to_le_bytes());
                    for v in t {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            Frame::Metrics(m) => {
                for word in [
                    m.rank,
                    m.step,
                    m.step_seconds.to_bits(),
                    m.wire_sent,
                    m.wire_received,
                    m.ef_residual.to_bits(),
                    m.approx_error.to_bits(),
                    m.compression_ratio.to_bits(),
                    m.staleness,
                    m.inflight_peak,
                ] {
                    out.extend_from_slice(&word.to_le_bytes());
                }
            }
            Frame::Heartbeat { rank, epoch, step } => {
                out.extend_from_slice(&rank.to_le_bytes());
                out.extend_from_slice(&epoch.to_le_bytes());
                out.extend_from_slice(&step.to_le_bytes());
            }
            Frame::Reconfigure { version, epoch, step, rank, world, departed, peers } => {
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&epoch.to_le_bytes());
                out.extend_from_slice(&step.to_le_bytes());
                out.extend_from_slice(&rank.to_le_bytes());
                out.extend_from_slice(&world.to_le_bytes());
                debug_assert!(departed.len() <= u16::MAX as usize);
                out.extend_from_slice(&(departed.len() as u16).to_le_bytes());
                for d in departed {
                    out.extend_from_slice(&d.to_le_bytes());
                }
                for p in peers {
                    put_str(&mut out, p);
                }
            }
        }
        out
    }

    /// Serialize to a complete frame (header + payload).
    ///
    /// Panics if the payload exceeds [`MAX_PAYLOAD`]: a length prefix
    /// that wrapped past `u32` would silently desynchronize the stream
    /// and surface on a *healthy* peer as a corrupt-stream error — a
    /// loud local failure at the sender is strictly better.
    pub fn encode(&self) -> Vec<u8> {
        let _span = crate::obs::span(crate::obs::Phase::WireEncode);
        let payload = self.payload();
        assert!(
            payload.len() as u64 <= MAX_PAYLOAD as u64,
            "frame payload of {} bytes exceeds the {MAX_PAYLOAD}-byte wire cap",
            payload.len()
        );
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&MAGIC);
        out.push(self.kind_byte());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= u16::MAX as usize, "address string too long");
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Why a frame could not be read or decoded.
#[derive(Debug)]
pub enum WireError {
    /// The stream or buffer ended mid-frame — the peer closed the
    /// connection or the message was cut short.
    Truncated(&'static str),
    /// The first bytes are not a frame header.
    BadMagic([u8; 2]),
    /// Unknown frame kind byte.
    BadKind(u8),
    /// The length prefix exceeds [`MAX_PAYLOAD`].
    Oversize(u32),
    /// The payload bytes are inconsistent with the frame kind.
    Malformed(&'static str),
    /// Transport-level I/O failure (includes read timeouts).
    Io(io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated(what) => write!(f, "truncated frame ({what})"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?} (corrupt stream)"),
            WireError::BadKind(k) => write!(f, "unknown frame kind {k} (corrupt stream)"),
            WireError::Oversize(n) => {
                write!(f, "frame length {n} exceeds the {MAX_PAYLOAD}-byte cap (corrupt stream)")
            }
            WireError::Malformed(what) => write!(f, "malformed frame payload ({what})"),
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        WireError::Io(e)
    }
}

impl WireError {
    /// True when the error means the peer timed out rather than died or
    /// sent garbage (SO_RCVTIMEO surfaces as `WouldBlock` on Linux and
    /// `TimedOut` on other platforms).
    pub fn is_timeout(&self) -> bool {
        matches!(
            self,
            WireError::Io(e)
                if e.kind() == io::ErrorKind::WouldBlock || e.kind() == io::ErrorKind::TimedOut
        )
    }
}

/// Write one frame. The caller flushes (ring sends flush per frame;
/// rendezvous flushes per handshake message).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<(), WireError> {
    w.write_all(&frame.encode())?;
    Ok(())
}

/// Read exactly one frame from a blocking stream. An EOF before or
/// inside a frame is [`WireError::Truncated`]; a read timeout surfaces
/// as [`WireError::Io`] with `is_timeout() == true`.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut header = [0u8; HEADER_LEN];
    read_exact(r, &mut header, "header")?;
    if header[..2] != MAGIC {
        return Err(WireError::BadMagic([header[0], header[1]]));
    }
    let kind = header[2];
    let len = u32::from_le_bytes([header[3], header[4], header[5], header[6]]);
    if len > MAX_PAYLOAD {
        return Err(WireError::Oversize(len));
    }
    let mut payload = vec![0u8; len as usize];
    read_exact(r, &mut payload, "payload")?;
    let _span = crate::obs::span(crate::obs::Phase::WireDecode);
    decode_payload(kind, &payload)
}

fn read_exact<R: Read>(r: &mut R, buf: &mut [u8], what: &'static str) -> Result<(), WireError> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            WireError::Truncated(what)
        } else {
            WireError::Io(e)
        }
    })
}

/// Decode one frame from a byte buffer; returns the frame and the
/// number of bytes consumed. For tests and for parsing recorded
/// streams — the live path uses [`read_frame`].
pub fn decode(buf: &[u8]) -> Result<(Frame, usize), WireError> {
    if buf.len() < HEADER_LEN {
        return Err(WireError::Truncated("header"));
    }
    if buf[..2] != MAGIC {
        return Err(WireError::BadMagic([buf[0], buf[1]]));
    }
    let kind = buf[2];
    let len = u32::from_le_bytes([buf[3], buf[4], buf[5], buf[6]]);
    if len > MAX_PAYLOAD {
        return Err(WireError::Oversize(len));
    }
    let end = HEADER_LEN + len as usize;
    if buf.len() < end {
        return Err(WireError::Truncated("payload"));
    }
    Ok((decode_payload(kind, &buf[HEADER_LEN..end])?, end))
}

fn decode_payload(kind: u8, payload: &[u8]) -> Result<Frame, WireError> {
    let mut cur = Cur { buf: payload, off: 0 };
    let frame = match kind {
        KIND_HELLO => Frame::Hello { listen_addr: cur.string()? },
        KIND_WELCOME => {
            let rank = cur.u32()?;
            let world = cur.u32()?;
            let mut peers = Vec::with_capacity(world.min(1 << 16) as usize);
            for _ in 0..world {
                peers.push(cur.string()?);
            }
            Frame::Welcome { rank, world, peers }
        }
        KIND_CONNECT => Frame::Connect { rank: cur.u32()? },
        KIND_F32S => {
            if payload.len() % 4 != 0 {
                return Err(WireError::Malformed("f32 payload length not a multiple of 4"));
            }
            let vals = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            cur.off = payload.len();
            Frame::F32s(vals)
        }
        KIND_BYTES => {
            cur.off = payload.len();
            Frame::Bytes(payload.to_vec())
        }
        KIND_REPORT => {
            let rank = cur.u32()?;
            let wire_bytes = cur.u64()?;
            let logical_bytes = cur.u64()?;
            let reconnect_attempts = cur.u64()?;
            let count = cur.u32()?;
            let mut tensors = Vec::with_capacity(count.min(1 << 16) as usize);
            for _ in 0..count {
                let n = cur.u32()? as usize;
                let Some(nbytes) = n.checked_mul(4) else {
                    return Err(WireError::Malformed("tensor length overflows"));
                };
                let raw = cur.take(nbytes)?;
                tensors.push(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                );
            }
            Frame::Report { rank, wire_bytes, logical_bytes, reconnect_attempts, tensors }
        }
        KIND_METRICS => {
            let rank = cur.u64()?;
            let step = cur.u64()?;
            let step_seconds = f64::from_bits(cur.u64()?);
            let wire_sent = cur.u64()?;
            let wire_received = cur.u64()?;
            let ef_residual = f64::from_bits(cur.u64()?);
            let approx_error = f64::from_bits(cur.u64()?);
            let compression_ratio = f64::from_bits(cur.u64()?);
            let staleness = cur.u64()?;
            let inflight_peak = cur.u64()?;
            Frame::Metrics(crate::obs::metrics::StepMetrics {
                rank,
                step,
                step_seconds,
                wire_sent,
                wire_received,
                ef_residual,
                approx_error,
                compression_ratio,
                staleness,
                inflight_peak,
            })
        }
        KIND_HEARTBEAT => {
            let rank = cur.u32()?;
            let epoch = cur.u64()?;
            let step = cur.u64()?;
            Frame::Heartbeat { rank, epoch, step }
        }
        KIND_RECONFIGURE => {
            let version = cur.u32()?;
            if version != RECONFIGURE_VERSION {
                return Err(WireError::Malformed("unsupported Reconfigure version"));
            }
            let epoch = cur.u64()?;
            let step = cur.u64()?;
            let rank = cur.u32()?;
            let world = cur.u32()?;
            let n_departed = cur.u16()?;
            let mut departed = Vec::with_capacity(n_departed as usize);
            for _ in 0..n_departed {
                departed.push(cur.u32()?);
            }
            let mut peers = Vec::with_capacity(world.min(1 << 16) as usize);
            for _ in 0..world {
                peers.push(cur.string()?);
            }
            Frame::Reconfigure { version, epoch, step, rank, world, departed, peers }
        }
        other => return Err(WireError::BadKind(other)),
    };
    cur.done()?;
    Ok(frame)
}

/// Bounds-checked payload cursor; every read can fail, none can panic.
struct Cur<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self
            .off
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or(WireError::Malformed("field runs past the payload end"))?;
        let out = &self.buf[self.off..end];
        self.off = end;
        Ok(out)
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn string(&mut self) -> Result<String, WireError> {
        let n = self.u16()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| WireError::Malformed("non-utf8 string"))
    }

    fn done(&self) -> Result<(), WireError> {
        if self.off == self.buf.len() {
            Ok(())
        } else {
            Err(WireError::Malformed("trailing bytes after the payload"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip(frame: &Frame) {
        let bytes = frame.encode();
        let (decoded, consumed) = decode(&bytes).expect("decode");
        assert_eq!(consumed, bytes.len());
        assert_eq!(&decoded, frame);
        // Streaming path agrees with the buffer path.
        let mut cursor: &[u8] = &bytes;
        assert_eq!(&read_frame(&mut cursor).expect("read_frame"), frame);
    }

    #[test]
    fn control_frames_roundtrip() {
        roundtrip(&Frame::Hello { listen_addr: "127.0.0.1:45123".into() });
        roundtrip(&Frame::Welcome {
            rank: 2,
            world: 4,
            peers: (0..4).map(|i| format!("127.0.0.1:{}", 40000 + i)).collect(),
        });
        roundtrip(&Frame::Connect { rank: 3 });
        roundtrip(&Frame::Report {
            rank: 1,
            wire_bytes: u64::MAX - 7,
            logical_bytes: 12345,
            reconnect_attempts: 3,
            tensors: vec![vec![1.0, -2.5], vec![], vec![f32::MIN_POSITIVE]],
        });
        roundtrip(&Frame::Heartbeat { rank: 2, epoch: 5, step: u64::MAX - 1 });
        roundtrip(&Frame::Reconfigure {
            version: RECONFIGURE_VERSION,
            epoch: 3,
            step: 42,
            rank: 1,
            world: 3,
            departed: vec![2],
            peers: (0..3).map(|i| format!("127.0.0.1:{}", 41000 + i)).collect(),
        });
        roundtrip(&Frame::Reconfigure {
            version: RECONFIGURE_VERSION,
            epoch: 1,
            step: 0,
            rank: 0,
            world: 1,
            departed: vec![],
            peers: vec!["127.0.0.1:41000".into()],
        });
        roundtrip(&Frame::Metrics(crate::obs::metrics::StepMetrics {
            rank: 3,
            step: 17,
            step_seconds: 0.0123456789,
            wire_sent: 329_512,
            wire_received: 329_512,
            ef_residual: 1.5e-3,
            approx_error: f64::MIN_POSITIVE,
            compression_ratio: 243.7,
            staleness: 1,
            inflight_peak: 6,
        }));
    }

    /// Proptest-style seeded sweep (no proptest crate offline):
    /// encode→decode identity over random chunk shapes and lengths,
    /// including exact bit patterns for f32 payloads.
    #[test]
    fn prop_data_frames_roundtrip_bit_exactly() {
        let mut rng = Rng::new(91);
        for case in 0..60 {
            let n = rng.below(4000) as usize;
            let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let frame = Frame::F32s(vals.clone());
            let (decoded, _) = decode(&frame.encode()).unwrap_or_else(|e| panic!("case {case}: {e}"));
            match decoded {
                Frame::F32s(got) => {
                    assert_eq!(got.len(), vals.len(), "case {case}");
                    for (a, b) in got.iter().zip(vals.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "case {case}");
                    }
                }
                other => panic!("case {case}: wrong kind {}", other.kind_name()),
            }

            let m = rng.below(3000) as usize;
            let bytes: Vec<u8> = (0..m).map(|_| rng.below(256) as u8).collect();
            roundtrip(&Frame::Bytes(bytes));
        }
    }

    #[test]
    fn special_f32_values_survive_the_wire() {
        roundtrip(&Frame::F32s(vec![
            0.0,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN,
            f32::MAX,
            f32::EPSILON,
        ]));
        // NaN payload bits survive (PartialEq would fail; check bits).
        let nan = f32::from_bits(0x7fc0_dead);
        let (decoded, _) = decode(&Frame::F32s(vec![nan]).encode()).unwrap();
        match decoded {
            Frame::F32s(v) => assert_eq!(v[0].to_bits(), nan.to_bits()),
            _ => panic!("wrong kind"),
        }
    }

    /// Every truncation point of every frame must be a clean error.
    #[test]
    fn prop_truncation_never_panics() {
        let frames = [
            Frame::Hello { listen_addr: "127.0.0.1:9".into() },
            Frame::Welcome { rank: 0, world: 2, peers: vec!["a:1".into(), "b:2".into()] },
            Frame::F32s(vec![1.0, 2.0, 3.0]),
            Frame::Bytes(vec![9, 8, 7]),
            Frame::Report {
                rank: 0,
                wire_bytes: 1,
                logical_bytes: 2,
                reconnect_attempts: 0,
                tensors: vec![vec![1.0]],
            },
            Frame::Heartbeat { rank: 1, epoch: 2, step: 3 },
            Frame::Reconfigure {
                version: RECONFIGURE_VERSION,
                epoch: 1,
                step: 7,
                rank: 0,
                world: 2,
                departed: vec![1, 3],
                peers: vec!["a:1".into(), "b:2".into()],
            },
            Frame::Metrics(crate::obs::metrics::StepMetrics {
                rank: 1,
                step: 0,
                step_seconds: 0.5,
                wire_sent: 2,
                wire_received: 3,
                ef_residual: 0.25,
                approx_error: 0.125,
                compression_ratio: 8.0,
                staleness: 0,
                inflight_peak: 4,
            }),
        ];
        for frame in &frames {
            let bytes = frame.encode();
            for cut in 0..bytes.len() {
                let err = decode(&bytes[..cut]).expect_err("truncated input must be rejected");
                assert!(
                    matches!(err, WireError::Truncated(_) | WireError::Malformed(_)),
                    "cut {cut}: unexpected {err}"
                );
            }
            // Streaming reader agrees on a truncated stream.
            let mut cursor = &bytes[..bytes.len() - 1];
            assert!(matches!(
                read_frame(&mut cursor).expect_err("truncated stream"),
                WireError::Truncated(_)
            ));
        }
    }

    #[test]
    fn corrupt_frames_are_rejected_not_panicked() {
        // Bad magic.
        let mut bad = Frame::Connect { rank: 1 }.encode();
        bad[0] = b'X';
        assert!(matches!(decode(&bad).unwrap_err(), WireError::BadMagic(_)));

        // Unknown kind.
        let mut bad = Frame::Connect { rank: 1 }.encode();
        bad[2] = 0xEE;
        assert!(matches!(decode(&bad).unwrap_err(), WireError::BadKind(0xEE)));

        // Oversized length prefix must not allocate.
        let mut bad = Frame::Bytes(vec![0; 4]).encode();
        bad[3..7].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(decode(&bad).unwrap_err(), WireError::Oversize(_)));

        // f32 payload with a non-multiple-of-4 length.
        let mut bad = Frame::F32s(vec![1.0]).encode();
        bad[3..7].copy_from_slice(&3u32.to_le_bytes());
        assert!(matches!(decode(&bad[..HEADER_LEN + 3]).unwrap_err(), WireError::Malformed(_)));

        // Non-utf8 address string.
        let mut bad = Frame::Hello { listen_addr: "ab".into() }.encode();
        bad[HEADER_LEN + 2] = 0xFF;
        bad[HEADER_LEN + 3] = 0xFE;
        assert!(matches!(decode(&bad).unwrap_err(), WireError::Malformed(_)));

        // Trailing garbage after a well-formed payload.
        let mut bad = Frame::Connect { rank: 1 }.encode();
        bad.push(0);
        let len = (bad.len() - HEADER_LEN) as u32;
        bad[3..7].copy_from_slice(&len.to_le_bytes());
        assert!(matches!(decode(&bad).unwrap_err(), WireError::Malformed(_)));

        // A Welcome whose peer list runs past the payload (world says 9
        // peers but the payload carries none).
        let bad = Frame::Welcome { rank: 0, world: 9, peers: vec![] }.encode();
        assert!(matches!(decode(&bad).unwrap_err(), WireError::Malformed(_)));

        // A Reconfigure whose peer list runs past the payload.
        let bad = Frame::Reconfigure {
            version: RECONFIGURE_VERSION,
            epoch: 1,
            step: 0,
            rank: 0,
            world: 9,
            departed: vec![],
            peers: vec![],
        }
        .encode();
        assert!(matches!(decode(&bad).unwrap_err(), WireError::Malformed(_)));
    }

    /// Forward compatibility: every unassigned kind byte is a typed
    /// [`WireError::BadKind`], never a panic — a newer peer speaking
    /// frames this build does not know produces a contextual error.
    #[test]
    fn unknown_kinds_are_typed_errors_not_panics() {
        let mut frame = Frame::Connect { rank: 1 }.encode();
        for kind in [0u8, KIND_RECONFIGURE + 1, 0x42, 0xFF] {
            frame[2] = kind;
            match decode(&frame).unwrap_err() {
                WireError::BadKind(k) => assert_eq!(k, kind),
                other => panic!("kind {kind}: unexpected {other}"),
            }
            // The streaming reader agrees (and consumes cleanly).
            let mut cursor: &[u8] = &frame;
            assert!(matches!(read_frame(&mut cursor).unwrap_err(), WireError::BadKind(_)));
        }
    }

    /// A Reconfigure from a future protocol version is rejected with a
    /// typed error instead of silently misreading the new layout.
    #[test]
    fn future_reconfigure_version_is_rejected() {
        let frame = Frame::Reconfigure {
            version: RECONFIGURE_VERSION + 1,
            epoch: 1,
            step: 0,
            rank: 0,
            world: 1,
            departed: vec![],
            peers: vec!["a:1".into()],
        };
        assert!(matches!(decode(&frame.encode()).unwrap_err(), WireError::Malformed(_)));
    }

    #[test]
    fn timeout_classification() {
        let t = WireError::Io(io::Error::new(io::ErrorKind::WouldBlock, "rcvtimeo"));
        assert!(t.is_timeout());
        let t2 = WireError::Io(io::Error::new(io::ErrorKind::TimedOut, "rcvtimeo"));
        assert!(t2.is_timeout());
        let e = WireError::Truncated("header");
        assert!(!e.is_timeout());
    }
}
