//! `transport/tcp` — the multi-process TCP ring transport
//! (DESIGN.md §10).
//!
//! Everything under [`crate::transport`] so far runs inside one OS
//! process; this module is the backend that turns the reproduction into
//! a distributed system: `W` independent processes on real OS sockets,
//! carrying the **same** ring collectives and the **same** per-worker
//! compression path, bitwise-identical to the in-process oracle.
//!
//! - [`wire`] — length-prefixed binary frame codec (control frames for
//!   rendezvous/reports, data frames for f32 chunks and sign bitmaps).
//! - [`rendezvous`] — coordinator-hosted handshake: workers `Hello` a
//!   coordinator, get rank + peer addresses back, and dial each other
//!   into a directed ring.
//! - [`TcpRing`] — the [`Transport`] implementation over one socket
//!   pair (read from predecessor, write to successor). The existing
//!   collective workers ([`crate::transport::ring_all_reduce_worker`],
//!   [`crate::transport::ring_all_gather_worker`]) and the
//!   [`crate::compress::WorkerCompressor`] round run on it unmodified.
//! - [`MeteredTransport`] — wraps any [`Transport`] and counts the
//!   bytes that actually cross the wire, for cross-checking against the
//!   analytic [`crate::collectives::ring_wire_bytes`] expansion of the
//!   `Scheme::message_bytes` model.
//! - [`harness`] — the `powersgd launch` / `powersgd worker` driver: a
//!   deterministic multi-process EF-SGD run whose final parameters the
//!   coordinator verifies **bitwise** against the centralized lockstep
//!   oracle.
//!
//! # Failure semantics
//!
//! The [`Transport`] trait is infallible (collectives assume a healthy
//! ring), so [`TcpRing`] exposes two layers: checked inherent methods
//! ([`TcpRing::send_f32s_checked`] etc.) that return a contextual
//! [`anyhow`] error naming the dead peer's rank, and the trait impls,
//! which panic with that same message. The harness converts the panic
//! back into an error with `catch_unwind`, so a worker process that
//! dies mid-collective surfaces as "rank 0: ring predecessor rank 1
//! closed the connection mid-collective" instead of a hang — every
//! blocking read carries a timeout.
//!
//! # Blocking
//!
//! [`Transport::send_next`] is documented "never blocks" for the mpsc
//! backend; a TCP send can block once the OS socket buffer fills. The
//! ring schedule alternates one send and one receive per step on every
//! worker, so in-flight data is bounded by one chunk per edge and
//! backpressure clears as soon as the successor reads. For chunks
//! larger than the socket buffers a fully-blocked ring is still
//! possible (every rank stuck in `write`), so the successor socket
//! carries a **write timeout** too — the worst case is a contextual
//! error naming the stuck peer, never a silent permanent hang.

pub mod harness;
mod metered;
pub mod rendezvous;
pub mod wire;

pub use harness::{
    coordinate, harness_registry, harness_shapes, initial_params, oracle_trajectory, run_worker,
    synthetic_grads, worker_trajectory, HarnessConfig, LaunchOutcome, WorkerWireReport,
};
pub use metered::{MeteredTransport, WireCounters, WireSized};
pub use rendezvous::{join, JoinedRing, Rendezvous};

use super::Transport;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::time::Duration;
use wire::{read_frame, write_frame, Frame, WireError};

/// [`Transport`] endpoint over real OS sockets: one buffered writer to
/// the ring successor, one buffered reader from the ring predecessor.
///
/// Implements both `Transport<Vec<f32>>` and `Transport<Vec<u8>>` over
/// the same connection pair: frames are tagged, and because every
/// worker executes the same deterministic sequence of typed collective
/// ops, the predecessor's send order always matches this worker's
/// receive order — a tag mismatch therefore means a corrupt or
/// misbehaving peer and surfaces as an error, never a reinterpreted
/// payload.
pub struct TcpRing {
    rank: usize,
    world: usize,
    writer: RefCell<BufWriter<TcpStream>>,
    reader: RefCell<BufReader<TcpStream>>,
}

impl TcpRing {
    /// Wrap an established ring edge pair. `timeout` bounds every
    /// blocking read from the predecessor *and* every blocking write to
    /// the successor, so a dead, hung, or deadlocked peer becomes a
    /// contextual error instead of a hang. Must be non-zero.
    pub fn new(
        rank: usize,
        world: usize,
        to_next: TcpStream,
        from_prev: TcpStream,
        timeout: Duration,
    ) -> Result<TcpRing> {
        assert!(world > 0 && rank < world, "bad ring identity {rank}/{world}");
        from_prev
            .set_read_timeout(Some(timeout))
            .context("tcp ring: setting predecessor read timeout")?;
        to_next
            .set_write_timeout(Some(timeout))
            .context("tcp ring: setting successor write timeout")?;
        to_next.set_nodelay(true).ok();
        Ok(TcpRing {
            rank,
            world,
            writer: RefCell::new(BufWriter::new(to_next)),
            reader: RefCell::new(BufReader::new(from_prev)),
        })
    }

    /// Build from a completed rendezvous handshake; hands the control
    /// stream back to the caller (it is not part of the ring).
    pub fn from_joined(joined: JoinedRing, timeout: Duration) -> Result<(TcpRing, TcpStream)> {
        let JoinedRing { rank, world, control, to_next, from_prev } = joined;
        Ok((TcpRing::new(rank, world, to_next, from_prev, timeout)?, control))
    }

    fn succ(&self) -> usize {
        (self.rank + 1) % self.world
    }

    fn pred(&self) -> usize {
        (self.rank + self.world - 1) % self.world
    }

    fn send_frame_checked(&self, frame: &Frame) -> Result<()> {
        let _span = crate::obs::span(crate::obs::Phase::RingSend);
        fn write_and_flush(
            writer: &mut BufWriter<TcpStream>,
            frame: &Frame,
        ) -> Result<(), WireError> {
            write_frame(writer, frame)?;
            writer.flush()?;
            Ok(())
        }
        let mut writer = self.writer.borrow_mut();
        write_and_flush(&mut writer, frame).map_err(|e| {
            let (me, succ) = (self.rank, self.succ());
            if e.is_timeout() {
                anyhow!(
                    "rank {me}: timed out sending to ring successor rank {succ} \
                     (worker {succ} hung or the ring is backpressure-deadlocked?)"
                )
            } else {
                anyhow!(e).context(format!(
                    "rank {me}: cannot send to ring successor rank {succ} (worker {succ} died?)"
                ))
            }
        })
    }

    fn recv_frame_checked(&self) -> Result<Frame> {
        // Covers blocked socket time: the exposed-communication gap.
        let _span = crate::obs::span(crate::obs::Phase::RingRecv);
        let mut reader = self.reader.borrow_mut();
        read_frame(&mut *reader).map_err(|e| {
            let (me, pred) = (self.rank, self.pred());
            if e.is_timeout() {
                anyhow!(
                    "rank {me}: timed out waiting for ring predecessor rank {pred} \
                     (worker {pred} dead or hung?)"
                )
            } else if matches!(e, WireError::Truncated(_)) {
                anyhow!(
                    "rank {me}: ring predecessor rank {pred} closed the connection \
                     mid-collective (worker {pred} died?)"
                )
            } else {
                anyhow!(e).context(format!(
                    "rank {me}: corrupt frame from ring predecessor rank {pred}"
                ))
            }
        })
    }

    /// Fallible send of an f32 chunk to the ring successor.
    pub fn send_f32s_checked(&self, msg: Vec<f32>) -> Result<()> {
        self.send_frame_checked(&Frame::F32s(msg))
    }

    /// Fallible receive of an f32 chunk from the ring predecessor.
    pub fn recv_f32s_checked(&self) -> Result<Vec<f32>> {
        match self.recv_frame_checked()? {
            Frame::F32s(vals) => Ok(vals),
            other => bail!(
                "rank {}: protocol mismatch — expected an f32 chunk from rank {}, got {}",
                self.rank,
                self.pred(),
                other.kind_name()
            ),
        }
    }

    /// Fallible send of a byte message to the ring successor.
    pub fn send_bytes_checked(&self, msg: Vec<u8>) -> Result<()> {
        self.send_frame_checked(&Frame::Bytes(msg))
    }

    /// Fallible receive of a byte message from the ring predecessor.
    pub fn recv_bytes_checked(&self) -> Result<Vec<u8>> {
        match self.recv_frame_checked()? {
            Frame::Bytes(bytes) => Ok(bytes),
            other => bail!(
                "rank {}: protocol mismatch — expected a byte message from rank {}, got {}",
                self.rank,
                self.pred(),
                other.kind_name()
            ),
        }
    }
}

impl Transport<Vec<f32>> for TcpRing {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send_next(&self, msg: Vec<f32>) {
        if let Err(e) = self.send_f32s_checked(msg) {
            panic!("{e:#}");
        }
    }

    fn recv_prev(&self) -> Vec<f32> {
        match self.recv_f32s_checked() {
            Ok(vals) => vals,
            Err(e) => panic!("{e:#}"),
        }
    }
}

impl Transport<Vec<u8>> for TcpRing {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send_next(&self, msg: Vec<u8>) {
        if let Err(e) = self.send_bytes_checked(msg) {
            panic!("{e:#}");
        }
    }

    fn recv_prev(&self) -> Vec<u8> {
        match self.recv_bytes_checked() {
            Ok(bytes) => bytes,
            Err(e) => panic!("{e:#}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{ring_all_gather_worker, ring_all_reduce_worker};

    const T: Duration = Duration::from_secs(10);

    /// Rendezvous `world` threads and hand each its connected TcpRing.
    fn socket_ring(world: usize) -> Vec<TcpRing> {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        let handles: Vec<_> = (0..world)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let joined = join(&addr, T).unwrap();
                    let (ring, _control) = TcpRing::from_joined(joined, T).unwrap();
                    ring
                })
            })
            .collect();
        rv.run(world, T).unwrap();
        let mut rings: Vec<TcpRing> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        rings.sort_by_key(|r| r.rank);
        rings
    }

    #[test]
    fn tcp_ring_all_reduce_matches_lockstep_bitwise() {
        use crate::util::Rng;
        let mut rng = Rng::new(62);
        for &(world, n) in &[(2usize, 7usize), (3, 256), (4, 1003)] {
            let bufs: Vec<Vec<f32>> = (0..world)
                .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
                .collect();
            let mut lockstep = bufs.clone();
            crate::collectives::ring_all_reduce_sum_lockstep(&mut lockstep);

            let rings = socket_ring(world);
            let mut tcp = bufs.clone();
            // TcpRing is Send but not Sync (buffered streams behind
            // RefCell): each worker thread owns its endpoint, exactly
            // like a worker process owns its sockets.
            std::thread::scope(|scope| {
                for (ring, buf) in rings.into_iter().zip(tcp.iter_mut()) {
                    scope.spawn(move || ring_all_reduce_worker(&ring, buf));
                }
            });
            assert_eq!(tcp, lockstep, "world={world} n={n}");
        }
    }

    #[test]
    fn tcp_ring_all_gather_mixed_types() {
        let world = 3;
        let rings = socket_ring(world);
        let views: Vec<(usize, Vec<Vec<f32>>, Vec<Vec<u8>>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = rings
                .into_iter()
                .map(|ring| {
                    scope.spawn(move || {
                        let rank = Transport::<Vec<f32>>::rank(&ring);
                        // Interleave typed collectives on one connection.
                        let f = ring_all_gather_worker(&ring, vec![rank as f32; 2]);
                        let b = ring_all_gather_worker(&ring, vec![rank as u8, 0xAB]);
                        (rank, f, b)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(views.len(), world);
        for (_, f32_view, byte_view) in &views {
            for w in 0..world {
                assert_eq!(f32_view[w], vec![w as f32; 2]);
                assert_eq!(byte_view[w], vec![w as u8, 0xAB]);
            }
        }
    }

    #[test]
    fn dead_predecessor_names_the_rank() {
        let rings = socket_ring(2);
        let mut iter = rings.into_iter();
        let r0 = iter.next().unwrap();
        let r1 = iter.next().unwrap();
        // Worker 1 dies: both its sockets close.
        drop(r1);
        let err = r0.recv_f32s_checked().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 1"), "{msg}");
        assert!(msg.contains("closed the connection"), "{msg}");
    }

    #[test]
    fn silent_predecessor_times_out_with_rank() {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || join(&addr, T).unwrap())
            })
            .collect();
        rv.run(2, T).unwrap();
        let mut joined: Vec<JoinedRing> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        joined.sort_by_key(|j| j.rank);
        let j1 = joined.pop().unwrap();
        let j0 = joined.pop().unwrap();
        // Rank 1 stays alive but never sends; rank 0 uses a short timeout.
        let (r0, _c0) = TcpRing::from_joined(j0, Duration::from_millis(200)).unwrap();
        let err = r0.recv_f32s_checked().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("timed out"), "{msg}");
        assert!(msg.contains("rank 1"), "{msg}");
        drop(j1);
    }

    #[test]
    fn type_confusion_is_a_protocol_error() {
        let rings = socket_ring(2);
        // Rank 0 sends bytes; rank 1 expects f32s.
        rings[0].send_bytes_checked(vec![1, 2, 3]).unwrap();
        let err = rings[1].recv_f32s_checked().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("protocol mismatch"), "{msg}");
    }
}
