//! `transport/tcp` — the multi-process TCP ring transport
//! (DESIGN.md §10).
//!
//! Everything under [`crate::transport`] so far runs inside one OS
//! process; this module is the backend that turns the reproduction into
//! a distributed system: `W` independent processes on real OS sockets,
//! carrying the **same** ring collectives and the **same** per-worker
//! compression path, bitwise-identical to the in-process oracle.
//!
//! - [`wire`] — length-prefixed binary frame codec (control frames for
//!   rendezvous/reports, data frames for f32 chunks and sign bitmaps).
//! - [`rendezvous`] — coordinator-hosted handshake: workers `Hello` a
//!   coordinator, get rank + peer addresses back, and dial each other
//!   into a directed ring.
//! - [`TcpRing`] — the [`Transport`] implementation over one socket
//!   pair (read from predecessor, write to successor). The existing
//!   collective workers ([`crate::transport::ring_all_reduce_worker`],
//!   [`crate::transport::ring_all_gather_worker`]) and the
//!   [`crate::compress::WorkerCompressor`] round run on it unmodified.
//! - [`MeteredTransport`] — wraps any [`Transport`] and counts the
//!   bytes that actually cross the wire, for cross-checking against the
//!   analytic [`crate::collectives::ring_wire_bytes`] expansion of the
//!   `Scheme::message_bytes` model.
//! - [`harness`] — the `powersgd launch` / `powersgd worker` driver: a
//!   deterministic multi-process EF-SGD run whose final parameters the
//!   coordinator verifies **bitwise** against the centralized lockstep
//!   oracle.
//!
//! # Failure semantics
//!
//! The [`Transport`] trait is infallible (collectives assume a healthy
//! ring), so [`TcpRing`] exposes two layers: checked inherent methods
//! ([`TcpRing::send_f32s_checked`] etc.) that return a contextual
//! [`anyhow`] error naming the dead peer's rank, and the trait impls,
//! which panic with that same message. The harness converts the panic
//! back into an error with `catch_unwind`, so a worker process that
//! dies mid-collective surfaces as "rank 0: ring predecessor rank 1
//! closed the connection mid-collective" instead of a hang — every
//! blocking wait carries a timeout.
//!
//! In **elastic** mode (`launch --elastic`, DESIGN.md §16) a dead peer
//! is no longer fatal: membership is epoch-based, each step boundary
//! is a heartbeat barrier with the coordinator, and on a detected
//! departure (control-connection EOF or heartbeat timeout) the
//! coordinator broadcasts a `Reconfigure` frame; survivors tear this
//! ring down, re-form the edges over their retained listeners with
//! backoff reconnects, and continue at `W−1` (or `W+1` on a late
//! join) under the next epoch. A *crash* needs no tuning — the closed
//! sockets cascade EOF through the ring immediately. Surviving a
//! *hang* (peer alive but stuck, sockets open) additionally requires
//! `--comm-timeout-ms` below `--heartbeat-ms`: blocked survivors must
//! abort their ring waits and re-heartbeat before the coordinator's
//! heartbeat timeout declares *them* dead too; with the default ring
//! timeout (the whole-run `--timeout-s`) a hang stalls the run until
//! that deadline instead.
//!
//! # Posted sends and the I/O threads
//!
//! Early versions documented `Transport::send_next` as "never blocks",
//! which was only true for the mpsc backend: a TCP write could block
//! once the OS socket buffer filled. The endpoint now runs a dedicated
//! **writer thread** (owns the buffered successor stream, fed by an
//! unbounded channel) and a dedicated **reader thread** (owns the
//! predecessor stream, decodes frames as they arrive), so the
//! completion-queue contract holds for real sockets too:
//!
//! - `post_send` enqueues the frame and completes at post — the
//!   endpoint took responsibility for delivery. A write failure
//!   (dead or backpressure-deadlocked successor, bounded by the write
//!   timeout) is parked and surfaces on the next operation, with the
//!   successor's rank named.
//! - received frames accumulate in the reader thread while the worker
//!   computes, which is what lets a pipelined schedule hide the wire
//!   time; `wait` on a recv ticket blocks at most the configured
//!   timeout before naming the silent predecessor.
//!
//! Because sends complete at post, [`MeteredTransport`] counts wire
//! bytes at post time — the bytes are committed to the wire the moment
//! the transport accepts them.

pub mod harness;
mod metered;
pub mod rendezvous;
pub mod wire;

pub use harness::{
    coordinate, coordinate_elastic, elastic_oracle_trajectory, harness_registry, harness_shapes,
    initial_params, midstep_replay_safe, oracle_state_at, oracle_trajectory, run_worker,
    run_worker_elastic, run_worker_with_metrics, stateless_worker_scheme, synthetic_grads,
    worker_trajectory, ElasticLink, EpochPlan, HarnessConfig, LaunchOutcome, WorkerRunReport,
    WorkerWireReport,
};
pub use metered::{MeteredTransport, WireCounters, WireSized};
pub use rendezvous::{
    form_ring_edges, join, join_with_retries, JoinedRing, Rendezvous, DEFAULT_CONNECT_RETRIES,
};

use super::{Completion, Ticket, Transport};
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;
use wire::{read_frame, write_frame, Frame, WireError};

/// Which payload type a posted receive expects. The peer executes the
/// same deterministic program, so the k-th frame on the link always
/// matches the k-th posted receive's expectation; a mismatch means a
/// corrupt or misbehaving peer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Expect {
    F32s,
    Bytes,
}

/// Completion-queue state shared by both typed halves of a [`TcpRing`]:
/// one FIFO of outstanding receives (frames fulfill the oldest first,
/// regardless of type — the link is a single ordered byte stream) and
/// per-type ready maps. Errors resolve per ticket so a protocol
/// mismatch names the offending frame.
#[derive(Default)]
struct TcpCq {
    next_ticket: Ticket,
    pending: VecDeque<(Ticket, Expect)>,
    ready_f32: HashMap<Ticket, Result<Vec<f32>>>,
    ready_bytes: HashMap<Ticket, Result<Vec<u8>>>,
    /// A terminal stream error (peer died, corrupt frame): every
    /// outstanding and future receive resolves to this message.
    dead: Option<String>,
}

impl TcpCq {
    fn fresh(&mut self) -> Ticket {
        let t = self.next_ticket;
        self.next_ticket += 1;
        t
    }
}

/// [`Transport`] endpoint over real OS sockets: a dedicated writer
/// thread owns the buffered stream to the ring successor, a dedicated
/// reader thread owns the stream from the ring predecessor, and the
/// worker thread talks to both through channels — so posted sends
/// complete at post and received frames accumulate while the worker
/// computes (see the module-level posted-send contract).
///
/// Implements both `Transport<Vec<f32>>` and `Transport<Vec<u8>>` over
/// the same connection pair: frames are tagged, and because every
/// worker executes the same deterministic sequence of typed collective
/// ops, the predecessor's send order always matches this worker's
/// receive order — a tag mismatch therefore means a corrupt or
/// misbehaving peer and surfaces as an error, never a reinterpreted
/// payload.
pub struct TcpRing {
    rank: usize,
    world: usize,
    timeout: Duration,
    /// Frames queued to the writer thread; dropped first on `Drop` so
    /// the writer flushes the queue and exits.
    to_writer: Option<Sender<Frame>>,
    /// First write failure the writer thread hit, surfaced on the next
    /// operation (sends complete at post, so the failing send itself
    /// has already returned).
    write_err: Arc<Mutex<Option<WireError>>>,
    from_reader: Receiver<Result<Frame, WireError>>,
    /// Raw handles for shutdown on `Drop` (the buffered streams moved
    /// into the I/O threads).
    next_sock: TcpStream,
    prev_sock: TcpStream,
    writer_thread: Option<JoinHandle<()>>,
    reader_thread: Option<JoinHandle<()>>,
    cq: RefCell<TcpCq>,
}

impl TcpRing {
    /// Wrap an established ring edge pair. `timeout` bounds every
    /// blocking wait on the predecessor *and* every write the writer
    /// thread makes to the successor, so a dead, hung, or deadlocked
    /// peer becomes a contextual error instead of a hang. Must be
    /// non-zero.
    pub fn new(
        rank: usize,
        world: usize,
        to_next: TcpStream,
        from_prev: TcpStream,
        timeout: Duration,
    ) -> Result<TcpRing> {
        use anyhow::Context;
        assert!(world > 0 && rank < world, "bad ring identity {rank}/{world}");
        to_next
            .set_write_timeout(Some(timeout))
            .context("tcp ring: setting successor write timeout")?;
        to_next.set_nodelay(true).ok();
        let next_sock = to_next.try_clone().context("tcp ring: cloning successor handle")?;
        let prev_sock = from_prev.try_clone().context("tcp ring: cloning predecessor handle")?;

        let (to_writer, writer_rx) = channel::<Frame>();
        let write_err = Arc::new(Mutex::new(None::<WireError>));
        let writer_slot = Arc::clone(&write_err);
        let writer_thread = std::thread::Builder::new()
            .name(format!("tcp-tx-{rank}"))
            .spawn(move || {
                crate::obs::set_track(&format!("wire-tx-{rank}"));
                let mut writer = BufWriter::new(to_next);
                while let Ok(frame) = writer_rx.recv() {
                    let done = write_frame(&mut writer, &frame).and_then(|()| {
                        writer.flush().map_err(WireError::from)
                    });
                    if let Err(e) = done {
                        *writer_slot.lock().expect("write-error slot poisoned") = Some(e);
                        // Exiting drops the receiver: the owner's next
                        // post_send fails fast and reads the slot.
                        return;
                    }
                }
            })
            .context("tcp ring: spawning the writer thread")?;

        let (reader_tx, from_reader) = channel::<Result<Frame, WireError>>();
        let reader_thread = std::thread::Builder::new()
            .name(format!("tcp-rx-{rank}"))
            .spawn(move || {
                crate::obs::set_track(&format!("wire-rx-{rank}"));
                let mut reader = BufReader::new(from_prev);
                loop {
                    match read_frame(&mut reader) {
                        Ok(frame) => {
                            if reader_tx.send(Ok(frame)).is_err() {
                                return; // owner gone
                            }
                        }
                        Err(e) => {
                            // Terminal: EOF, reset, or a corrupt frame.
                            let _ = reader_tx.send(Err(e));
                            return;
                        }
                    }
                }
            })
            .context("tcp ring: spawning the reader thread")?;

        Ok(TcpRing {
            rank,
            world,
            timeout,
            to_writer: Some(to_writer),
            write_err,
            from_reader,
            next_sock,
            prev_sock,
            writer_thread: Some(writer_thread),
            reader_thread: Some(reader_thread),
            cq: RefCell::new(TcpCq::default()),
        })
    }

    /// Build from a completed rendezvous handshake; hands the control
    /// stream back to the caller (it is not part of the ring).
    pub fn from_joined(joined: JoinedRing, timeout: Duration) -> Result<(TcpRing, TcpStream)> {
        let JoinedRing { rank, world, control, to_next, from_prev, .. } = joined;
        Ok((TcpRing::new(rank, world, to_next, from_prev, timeout)?, control))
    }

    fn succ(&self) -> usize {
        (self.rank + 1) % self.world
    }

    fn pred(&self) -> usize {
        (self.rank + self.world - 1) % self.world
    }

    /// The parked writer-thread failure as a contextual error, if any.
    fn take_write_err(&self) -> Option<anyhow::Error> {
        let e = self.write_err.lock().expect("write-error slot poisoned").take()?;
        let (me, succ) = (self.rank, self.succ());
        Some(if e.is_timeout() {
            anyhow!(
                "rank {me}: timed out sending to ring successor rank {succ} \
                 (worker {succ} hung or the ring is backpressure-deadlocked?)"
            )
        } else {
            anyhow!(e).context(format!(
                "rank {me}: cannot send to ring successor rank {succ} (worker {succ} died?)"
            ))
        })
    }

    /// Contextual error for a terminal predecessor-stream failure.
    fn recv_stream_err(&self, e: WireError) -> anyhow::Error {
        let (me, pred) = (self.rank, self.pred());
        if e.is_timeout() {
            anyhow!(
                "rank {me}: timed out waiting for ring predecessor rank {pred} \
                 (worker {pred} dead or hung?)"
            )
        } else if matches!(e, WireError::Truncated(_)) {
            anyhow!(
                "rank {me}: ring predecessor rank {pred} closed the connection \
                 mid-collective (worker {pred} died?)"
            )
        } else {
            anyhow!(e).context(format!(
                "rank {me}: corrupt frame from ring predecessor rank {pred}"
            ))
        }
    }

    /// Post a frame to the writer thread. Completes at post; a parked
    /// write failure from an earlier send surfaces here.
    fn post_frame_checked(&self, frame: Frame) -> Result<Ticket> {
        let _span = crate::obs::span(crate::obs::Phase::RingSend);
        if let Some(err) = self.take_write_err() {
            return Err(err);
        }
        let tx = self.to_writer.as_ref().expect("writer channel live until Drop");
        if tx.send(frame).is_err() {
            // The writer thread exited on a failure; report its cause.
            return Err(self.take_write_err().unwrap_or_else(|| {
                anyhow!(
                    "rank {}: cannot send to ring successor rank {} (worker {} died?)",
                    self.rank,
                    self.succ(),
                    self.succ()
                )
            }));
        }
        Ok(self.cq.borrow_mut().fresh())
    }

    /// Hand one incoming event to the oldest outstanding receive.
    fn fulfill(&self, cq: &mut TcpCq, event: Result<Frame, WireError>) {
        let (ticket, expect) =
            cq.pending.pop_front().expect("frame arrived with no posted receive");
        match event {
            Err(e) => {
                let msg = format!("{:#}", self.recv_stream_err(e));
                // Terminal: every other outstanding receive dies too.
                cq.dead = Some(msg.clone());
                match expect {
                    Expect::F32s => cq.ready_f32.insert(ticket, Err(anyhow!(msg))),
                    Expect::Bytes => cq.ready_bytes.insert(ticket, Err(anyhow!(msg))),
                };
            }
            Ok(Frame::F32s(vals)) if expect == Expect::F32s => {
                cq.ready_f32.insert(ticket, Ok(vals));
            }
            Ok(Frame::Bytes(bytes)) if expect == Expect::Bytes => {
                cq.ready_bytes.insert(ticket, Ok(bytes));
            }
            Ok(other) => {
                let (kind, what) = match expect {
                    Expect::F32s => (other.kind_name(), "an f32 chunk"),
                    Expect::Bytes => (other.kind_name(), "a byte message"),
                };
                let err = anyhow!(
                    "rank {}: protocol mismatch — expected {what} from rank {}, got {kind}",
                    self.rank,
                    self.pred()
                );
                match expect {
                    Expect::F32s => cq.ready_f32.insert(ticket, Err(err)),
                    Expect::Bytes => cq.ready_bytes.insert(ticket, Err(err)),
                };
            }
        }
    }

    fn post_recv_expect(&self, expect: Expect) -> Ticket {
        let mut cq = self.cq.borrow_mut();
        let t = cq.fresh();
        if let Some(msg) = cq.dead.clone() {
            // The stream already failed; resolve immediately.
            match expect {
                Expect::F32s => cq.ready_f32.insert(t, Err(anyhow!(msg))),
                Expect::Bytes => cq.ready_bytes.insert(t, Err(anyhow!(msg))),
            };
        } else {
            cq.pending.push_back((t, expect));
            // Ticket-depth telemetry: posting order is program order
            // per endpoint, so the depth-at-post histogram is
            // deterministic (mirrors `RingNode::post_recv`).
            crate::obs::metrics::add(crate::obs::metrics::Counter::RecvTicketsPosted, 1);
            crate::obs::metrics::observe(
                crate::obs::metrics::Histogram::InflightDepth,
                cq.pending.len() as f64,
            );
            crate::obs::metrics::raise_max(
                crate::obs::metrics::MaxGauge::InflightDepthPeak,
                cq.pending.len() as u64,
            );
        }
        t
    }

    /// True iff `ticket` belongs to an unresolved or resolved receive
    /// (anything else is a completed-at-post send).
    fn is_recv_ticket(cq: &TcpCq, ticket: Ticket) -> bool {
        cq.ready_f32.contains_key(&ticket)
            || cq.ready_bytes.contains_key(&ticket)
            || cq.pending.iter().any(|(t, _)| *t == ticket)
    }

    /// Drain already-arrived frames without blocking.
    fn drain_ready(&self, cq: &mut TcpCq) {
        while !cq.pending.is_empty() {
            match self.from_reader.try_recv() {
                Ok(event) => self.fulfill(cq, event),
                Err(_) => break,
            }
        }
    }

    /// Block until `ticket`'s receive resolves, bounded by the timeout.
    fn wait_recv(&self, cq: &mut TcpCq, ticket: Ticket) -> Result<()> {
        // Covers blocked socket time: the exposed-communication gap.
        let _span = crate::obs::span(crate::obs::Phase::RingRecv);
        while !cq.ready_f32.contains_key(&ticket) && !cq.ready_bytes.contains_key(&ticket) {
            match self.from_reader.recv_timeout(self.timeout) {
                Ok(event) => self.fulfill(cq, event),
                Err(RecvTimeoutError::Timeout) => {
                    let timed_out = WireError::Io(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "wait timeout",
                    ));
                    return Err(self.recv_stream_err(timed_out));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // Reader exited and its final event was consumed.
                    let msg = cq.dead.clone().unwrap_or_else(|| {
                        format!("{:#}", self.recv_stream_err(WireError::Truncated("stream")))
                    });
                    return Err(anyhow!(msg));
                }
            }
        }
        Ok(())
    }

    /// Fallible send of an f32 chunk to the ring successor. Completes
    /// at post (see the module-level contract).
    pub fn send_f32s_checked(&self, msg: Vec<f32>) -> Result<()> {
        self.post_frame_checked(Frame::F32s(msg)).map(|_| ())
    }

    /// Fallible receive of an f32 chunk from the ring predecessor.
    pub fn recv_f32s_checked(&self) -> Result<Vec<f32>> {
        let t = self.post_recv_expect(Expect::F32s);
        let mut cq = self.cq.borrow_mut();
        self.wait_recv(&mut cq, t)?;
        cq.ready_f32.remove(&t).expect("f32 ticket just resolved")
    }

    /// Fallible send of a byte message to the ring successor. Completes
    /// at post (see the module-level contract).
    pub fn send_bytes_checked(&self, msg: Vec<u8>) -> Result<()> {
        self.post_frame_checked(Frame::Bytes(msg)).map(|_| ())
    }

    /// Fallible receive of a byte message from the ring predecessor.
    pub fn recv_bytes_checked(&self) -> Result<Vec<u8>> {
        let t = self.post_recv_expect(Expect::Bytes);
        let mut cq = self.cq.borrow_mut();
        self.wait_recv(&mut cq, t)?;
        cq.ready_bytes.remove(&t).expect("byte ticket just resolved")
    }
}

impl Drop for TcpRing {
    fn drop(&mut self) {
        // Disconnect the writer channel first: the writer thread drains
        // every queued frame (posted sends stay good), then exits.
        self.to_writer.take();
        if let Some(h) = self.writer_thread.take() {
            let _ = h.join();
        }
        // Shutdown wakes the reader thread out of a blocking read.
        let _ = self.next_sock.shutdown(Shutdown::Both);
        let _ = self.prev_sock.shutdown(Shutdown::Both);
        if let Some(h) = self.reader_thread.take() {
            let _ = h.join();
        }
    }
}

impl Transport<Vec<f32>> for TcpRing {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn post_send(&self, msg: Vec<f32>) -> Ticket {
        match self.post_frame_checked(Frame::F32s(msg)) {
            Ok(t) => t,
            Err(e) => panic!("{e:#}"),
        }
    }

    fn post_recv(&self) -> Ticket {
        self.post_recv_expect(Expect::F32s)
    }

    fn poll(&self, ticket: Ticket) -> Completion<Vec<f32>> {
        let mut cq = self.cq.borrow_mut();
        if !Self::is_recv_ticket(&cq, ticket) {
            return Completion::Sent;
        }
        self.drain_ready(&mut cq);
        match cq.ready_f32.remove(&ticket) {
            Some(Ok(vals)) => Completion::Received(vals),
            Some(Err(e)) => panic!("{e:#}"),
            None => Completion::Pending,
        }
    }

    fn wait(&self, ticket: Ticket) -> Completion<Vec<f32>> {
        let mut cq = self.cq.borrow_mut();
        if !Self::is_recv_ticket(&cq, ticket) {
            return Completion::Sent;
        }
        if let Err(e) = self.wait_recv(&mut cq, ticket) {
            panic!("{e:#}");
        }
        match cq.ready_f32.remove(&ticket).expect("f32 ticket just resolved") {
            Ok(vals) => Completion::Received(vals),
            Err(e) => panic!("{e:#}"),
        }
    }
}

impl Transport<Vec<u8>> for TcpRing {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn post_send(&self, msg: Vec<u8>) -> Ticket {
        match self.post_frame_checked(Frame::Bytes(msg)) {
            Ok(t) => t,
            Err(e) => panic!("{e:#}"),
        }
    }

    fn post_recv(&self) -> Ticket {
        self.post_recv_expect(Expect::Bytes)
    }

    fn poll(&self, ticket: Ticket) -> Completion<Vec<u8>> {
        let mut cq = self.cq.borrow_mut();
        if !Self::is_recv_ticket(&cq, ticket) {
            return Completion::Sent;
        }
        self.drain_ready(&mut cq);
        match cq.ready_bytes.remove(&ticket) {
            Some(Ok(bytes)) => Completion::Received(bytes),
            Some(Err(e)) => panic!("{e:#}"),
            None => Completion::Pending,
        }
    }

    fn wait(&self, ticket: Ticket) -> Completion<Vec<u8>> {
        let mut cq = self.cq.borrow_mut();
        if !Self::is_recv_ticket(&cq, ticket) {
            return Completion::Sent;
        }
        if let Err(e) = self.wait_recv(&mut cq, ticket) {
            panic!("{e:#}");
        }
        match cq.ready_bytes.remove(&ticket).expect("byte ticket just resolved") {
            Ok(bytes) => Completion::Received(bytes),
            Err(e) => panic!("{e:#}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{ring_all_gather_worker, ring_all_reduce_worker};

    const T: Duration = Duration::from_secs(10);

    /// Rendezvous `world` threads and hand each its connected TcpRing.
    fn socket_ring(world: usize) -> Vec<TcpRing> {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        let handles: Vec<_> = (0..world)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let joined = join(&addr, T).unwrap();
                    let (ring, _control) = TcpRing::from_joined(joined, T).unwrap();
                    ring
                })
            })
            .collect();
        rv.run(world, T).unwrap();
        let mut rings: Vec<TcpRing> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        rings.sort_by_key(|r| r.rank);
        rings
    }

    #[test]
    fn tcp_ring_all_reduce_matches_lockstep_bitwise() {
        use crate::util::Rng;
        let mut rng = Rng::new(62);
        for &(world, n) in &[(2usize, 7usize), (3, 256), (4, 1003)] {
            let bufs: Vec<Vec<f32>> = (0..world)
                .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
                .collect();
            let mut lockstep = bufs.clone();
            crate::collectives::ring_all_reduce_sum_lockstep(&mut lockstep);

            let rings = socket_ring(world);
            let mut tcp = bufs.clone();
            // TcpRing is Send but not Sync (buffered streams behind
            // RefCell): each worker thread owns its endpoint, exactly
            // like a worker process owns its sockets.
            std::thread::scope(|scope| {
                for (ring, buf) in rings.into_iter().zip(tcp.iter_mut()) {
                    scope.spawn(move || ring_all_reduce_worker(&ring, buf));
                }
            });
            assert_eq!(tcp, lockstep, "world={world} n={n}");
        }
    }

    #[test]
    fn tcp_ring_all_gather_mixed_types() {
        let world = 3;
        let rings = socket_ring(world);
        let views: Vec<(usize, Vec<Vec<f32>>, Vec<Vec<u8>>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = rings
                .into_iter()
                .map(|ring| {
                    scope.spawn(move || {
                        let rank = Transport::<Vec<f32>>::rank(&ring);
                        // Interleave typed collectives on one connection.
                        let f = ring_all_gather_worker(&ring, vec![rank as f32; 2]);
                        let b = ring_all_gather_worker(&ring, vec![rank as u8, 0xAB]);
                        (rank, f, b)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(views.len(), world);
        for (_, f32_view, byte_view) in &views {
            for w in 0..world {
                assert_eq!(f32_view[w], vec![w as f32; 2]);
                assert_eq!(byte_view[w], vec![w as u8, 0xAB]);
            }
        }
    }

    #[test]
    fn dead_predecessor_names_the_rank() {
        let rings = socket_ring(2);
        let mut iter = rings.into_iter();
        let r0 = iter.next().unwrap();
        let r1 = iter.next().unwrap();
        // Worker 1 dies: both its sockets close.
        drop(r1);
        let err = r0.recv_f32s_checked().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 1"), "{msg}");
        assert!(msg.contains("closed the connection"), "{msg}");
    }

    #[test]
    fn silent_predecessor_times_out_with_rank() {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || join(&addr, T).unwrap())
            })
            .collect();
        rv.run(2, T).unwrap();
        let mut joined: Vec<JoinedRing> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        joined.sort_by_key(|j| j.rank);
        let j1 = joined.pop().unwrap();
        let j0 = joined.pop().unwrap();
        // Rank 1 stays alive but never sends; rank 0 uses a short timeout.
        let (r0, _c0) = TcpRing::from_joined(j0, Duration::from_millis(200)).unwrap();
        let err = r0.recv_f32s_checked().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("timed out"), "{msg}");
        assert!(msg.contains("rank 1"), "{msg}");
        drop(j1);
    }

    #[test]
    fn type_confusion_is_a_protocol_error() {
        let rings = socket_ring(2);
        // Rank 0 sends bytes; rank 1 expects f32s.
        rings[0].send_bytes_checked(vec![1, 2, 3]).unwrap();
        let err = rings[1].recv_f32s_checked().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("protocol mismatch"), "{msg}");
    }
}
