//! Coordinator-hosted rendezvous: how `W` independent OS processes
//! become a ring (DESIGN.md §10), and how survivors re-form it after
//! churn (DESIGN.md §16).
//!
//! The protocol has four steps, all over the [`super::wire`] codec:
//!
//! 1. Each worker binds its own ring listener on an ephemeral localhost
//!    port **before** announcing itself, then connects to the
//!    coordinator and sends `Hello { listen_addr }`.
//! 2. The coordinator accepts `W` hellos, assigns ranks in arrival
//!    order, and sends every worker `Welcome { rank, world, peers }`
//!    with the full rank-indexed address list.
//! 3. Each worker dials its ring **successor**'s listener (rank+1 mod W)
//!    and introduces itself with `Connect { rank }`.
//! 4. Each worker accepts exactly one connection on its own listener
//!    and verifies the `Connect` frame names its ring **predecessor**.
//!
//! Because every listener is bound before any `Hello` is sent, step 3
//! can never race step 4: the successor's listener already exists (the
//! OS backlog holds the connection until the accept). The `Hello`
//! connection stays open as the **control channel** — workers send
//! per-step `Heartbeat`s (elastic mode) and their end-of-run `Report`
//! on it.
//!
//! Steps 3–4 are factored into [`form_ring_edges`] because elastic
//! runs re-execute them on every `Reconfigure`: the ring listener
//! stays alive for the whole worker lifetime (it is part of
//! [`JoinedRing`]), so the addresses exchanged at `Hello` time remain
//! valid across epochs and re-formation needs no second
//! address-collection round-trip.
//!
//! Every connect path retries through a bounded exponential
//! [`Backoff`] with deterministic jitter instead of making a single
//! timed-out attempt, and every blocking call (accept, connect,
//! handshake read) carries a deadline, so a worker that never shows up
//! or dies mid-handshake surfaces as a contextual error naming the
//! missing rank instead of a hang.

use super::wire::{read_frame, write_frame, Frame};
use crate::net::backoff::Backoff;
use anyhow::{anyhow, bail, Context, Result};
use std::io;
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Default connect retry budget when the caller does not thread an
/// explicit `--reconnect-retries` through (attempts beyond the first).
pub const DEFAULT_CONNECT_RETRIES: u32 = 4;

/// The coordinator's half of the handshake.
pub struct Rendezvous {
    listener: TcpListener,
}

impl Rendezvous {
    /// Bind the rendezvous listener (e.g. `"127.0.0.1:0"` for an
    /// ephemeral port).
    pub fn bind(addr: &str) -> Result<Rendezvous> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("rendezvous: cannot bind {addr}"))?;
        Ok(Rendezvous { listener })
    }

    /// The address workers should dial (resolved, with the real port).
    pub fn addr(&self) -> Result<String> {
        Ok(self.listener.local_addr().context("rendezvous: no local addr")?.to_string())
    }

    /// Accept one worker's `Hello` before `deadline`. Returns the
    /// control stream and the worker's ring-listener address. Elastic
    /// coordinators call this with a short deadline to poll for late
    /// joiners between step barriers.
    pub fn accept_hello(&self, deadline: Instant, timeout: Duration) -> Result<(TcpStream, String)> {
        let (mut stream, from) = accept_with_deadline(&self.listener, deadline)?;
        stream.set_read_timeout(Some(timeout)).context("rendezvous: set timeout")?;
        stream.set_nodelay(true).ok();
        match read_frame(&mut stream)
            .map_err(|e| anyhow!(e))
            .with_context(|| format!("rendezvous: handshake with {from}"))?
        {
            Frame::Hello { listen_addr } => Ok((stream, listen_addr)),
            other => bail!("rendezvous: expected Hello from {from}, got {}", other.kind_name()),
        }
    }

    /// Accept `world` workers, assign ranks in arrival order, and send
    /// each its `Welcome`. Returns the control streams indexed by rank;
    /// workers send their final `Report` frames on these.
    pub fn run(&self, world: usize, timeout: Duration) -> Result<Vec<TcpStream>> {
        self.run_collecting(world, timeout).map(|joined| {
            joined.into_iter().map(|(stream, _)| stream).collect()
        })
    }

    /// [`Rendezvous::run`], also returning each worker's ring-listener
    /// address (rank-indexed). The elastic coordinator keeps the
    /// addresses: they stay valid across epochs (workers never rebind),
    /// so every later `Reconfigure` peer map is computed from them.
    pub fn run_collecting(
        &self,
        world: usize,
        timeout: Duration,
    ) -> Result<Vec<(TcpStream, String)>> {
        let _span = crate::obs::span(crate::obs::Phase::Rendezvous);
        assert!(world > 0, "rendezvous needs at least one worker");
        let mut joined: Vec<(TcpStream, String)> = Vec::with_capacity(world);
        let deadline = Instant::now() + timeout;
        while joined.len() < world {
            let remaining = world - joined.len();
            let (stream, addr) =
                self.accept_hello(deadline, timeout).with_context(|| {
                    format!(
                        "rendezvous: only {}/{world} workers joined ({remaining} missing)",
                        joined.len()
                    )
                })?;
            joined.push((stream, addr));
        }
        let peers: Vec<String> = joined.iter().map(|(_, addr)| addr.clone()).collect();
        for (rank, (stream, _)) in joined.iter_mut().enumerate() {
            write_frame(
                stream,
                &Frame::Welcome { rank: rank as u32, world: world as u32, peers: peers.clone() },
            )
            .map_err(|e| anyhow!(e))
            .with_context(|| format!("rendezvous: sending Welcome to rank {rank}"))?;
        }
        Ok(joined)
    }
}

/// A worker's completed handshake: its identity plus the three live
/// connections (control to the coordinator, ring edge to the successor,
/// ring edge from the predecessor) and the ring listener, which stays
/// alive for the whole worker lifetime so elastic re-formation can
/// accept the new predecessor without rebinding (the peer addresses
/// exchanged at `Hello` time stay valid across epochs).
pub struct JoinedRing {
    /// The rank the coordinator assigned this worker.
    pub rank: usize,
    /// Total number of workers in the ring.
    pub world: usize,
    /// The original `Hello` connection; carries heartbeats (elastic
    /// mode) and the final `Report`.
    pub control: TcpStream,
    /// Ring edge this worker writes to (its successor reads it).
    pub to_next: TcpStream,
    /// Ring edge this worker reads from (its predecessor writes it).
    pub from_prev: TcpStream,
    /// This worker's ring listener (the address it announced in its
    /// `Hello`); kept open across epochs for re-formation accepts.
    pub listener: TcpListener,
    /// Connect retries (attempts beyond each dial's first) the
    /// handshake consumed — this worker's share of the cluster-wide
    /// `reconnect_attempts` total it reports at end of run.
    pub reconnect_attempts: u64,
}

/// The worker's first contact: bind the ring listener, dial the
/// coordinator (with backoff), and send `Hello`. Returns the control
/// stream, the retained ring listener, the announced address, and the
/// connect retries the dial consumed (the worker folds these into its
/// reported `reconnect_attempts`). Callers then read either a
/// `Welcome` (initial formation) or a `Reconfigure` (late join into an
/// elastic run) on the control stream.
pub fn hello(
    coordinator: &str,
    timeout: Duration,
    retries: u32,
) -> Result<(TcpStream, TcpListener, String, u64)> {
    // Bind the ring listener *before* saying Hello, so the predecessor
    // can dial us the moment it learns our address.
    let listener =
        TcpListener::bind("127.0.0.1:0").context("worker: cannot bind ring listener")?;
    let my_addr = listener.local_addr().context("worker: ring listener addr")?.to_string();
    let seed = u64::from(listener.local_addr().map(|a| a.port()).unwrap_or(0));

    let mut backoff = Backoff::standard(retries, seed);
    let mut control = connect(coordinator, timeout, &mut backoff)
        .with_context(|| format!("worker: coordinator {coordinator} unreachable"))?;
    control.set_read_timeout(Some(timeout)).context("worker: set control timeout")?;
    write_frame(&mut control, &Frame::Hello { listen_addr: my_addr.clone() })
        .map_err(|e| anyhow!(e))
        .context("worker: sending Hello")?;
    let retries_used = backoff.attempts();
    Ok((control, listener, my_addr, retries_used))
}

/// Steps 3–4 of the handshake, re-executed on every elastic
/// `Reconfigure`: dial the ring successor (`rank+1 mod world`) through
/// `backoff`, introduce ourselves with `Connect { rank }`, then accept
/// the predecessor's connection on the retained `listener` and verify
/// its `Connect` names the right rank (a stray or stale connection is
/// dropped and the accept retried until the deadline).
pub fn form_ring_edges(
    rank: usize,
    world: usize,
    peers: &[String],
    listener: &TcpListener,
    timeout: Duration,
    backoff: &mut Backoff,
) -> Result<(TcpStream, TcpStream)> {
    if world == 0 || rank >= world || peers.len() != world {
        bail!("ring formation: bad identity (rank {rank}, world {world}, {} peers)", peers.len());
    }
    let next = (rank + 1) % world;
    let mut to_next = connect(&peers[next], timeout, backoff).with_context(|| {
        format!("rank {rank}: ring successor rank {next} at {} unreachable", peers[next])
    })?;
    write_frame(&mut to_next, &Frame::Connect { rank: rank as u32 })
        .map_err(|e| anyhow!(e))
        .with_context(|| format!("rank {rank}: introducing to successor rank {next}"))?;

    let prev = (rank + world - 1) % world;
    let deadline = Instant::now() + timeout;
    loop {
        let (mut from_prev, _) = accept_with_deadline(listener, deadline).with_context(|| {
            format!("rank {rank}: ring predecessor rank {prev} never connected")
        })?;
        from_prev.set_read_timeout(Some(timeout)).context("worker: set ring timeout")?;
        match read_frame(&mut from_prev)
            .map_err(|e| anyhow!(e))
            .with_context(|| format!("rank {rank}: handshake from predecessor rank {prev}"))?
        {
            Frame::Connect { rank: got } if got as usize == prev => {
                return Ok((to_next, from_prev))
            }
            // A stale dial from a previous epoch's topology: drop it and
            // keep accepting until the real predecessor shows up.
            Frame::Connect { rank: got } => {
                if Instant::now() >= deadline {
                    bail!(
                        "rank {rank}: expected Connect from predecessor rank {prev}, got rank {got}"
                    );
                }
            }
            other => bail!(
                "rank {rank}: expected Connect from predecessor rank {prev}, got {}",
                other.kind_name()
            ),
        }
    }
}

/// The worker's half of the handshake: join the ring hosted by
/// `coordinator` (a `host:port` string) with the default connect retry
/// budget.
pub fn join(coordinator: &str, timeout: Duration) -> Result<JoinedRing> {
    join_with_retries(coordinator, timeout, DEFAULT_CONNECT_RETRIES)
}

/// [`join`] with an explicit connect retry budget
/// (`--reconnect-retries`): `Hello` the coordinator, wait for the
/// `Welcome`, and form the ring edges.
pub fn join_with_retries(coordinator: &str, timeout: Duration, retries: u32) -> Result<JoinedRing> {
    let _span = crate::obs::span(crate::obs::Phase::Rendezvous);
    let (mut control, listener, _my_addr, hello_retries) = hello(coordinator, timeout, retries)?;
    let (rank, world, peers) = match read_frame(&mut control)
        .map_err(|e| anyhow!(e))
        .context("worker: waiting for Welcome (coordinator died or timed out?)")?
    {
        Frame::Welcome { rank, world, peers } => (rank as usize, world as usize, peers),
        other => bail!("worker: expected Welcome, got {}", other.kind_name()),
    };
    if world == 0 || rank >= world || peers.len() != world {
        bail!("worker: malformed Welcome (rank {rank}, world {world}, {} peers)", peers.len());
    }
    let seed = u64::from(listener.local_addr().map(|a| a.port()).unwrap_or(0));
    let mut backoff = Backoff::standard(retries, seed ^ rank as u64);
    let (to_next, from_prev) =
        form_ring_edges(rank, world, &peers, &listener, timeout, &mut backoff)?;
    let reconnect_attempts = hello_retries + backoff.attempts();
    Ok(JoinedRing { rank, world, control, to_next, from_prev, listener, reconnect_attempts })
}

/// `TcpListener::accept` with a deadline: `accept` alone blocks forever
/// if the peer never dials, which is exactly the hang the TCP transport
/// must turn into an error.
fn accept_with_deadline(
    listener: &TcpListener,
    deadline: Instant,
) -> Result<(TcpStream, std::net::SocketAddr)> {
    listener.set_nonblocking(true).context("set_nonblocking")?;
    let out = loop {
        match listener.accept() {
            Ok((stream, from)) => {
                // Accepted sockets must be blocking regardless of what
                // they inherited from the listener.
                stream.set_nonblocking(false).context("accepted stream")?;
                stream.set_nodelay(true).ok();
                break Ok((stream, from));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    break Err(anyhow!("accept timed out"));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => break Err(e).context("accept failed"),
        }
    };
    // Restore blocking accepts for any later use of the listener.
    listener.set_nonblocking(false).ok();
    out
}

/// `TcpStream::connect` through a [`Backoff`] policy, resolving
/// `host:port` strings. Every attempt is individually bounded by
/// `timeout`; the whole retry loop is bounded by the same deadline, so
/// the worst case stays one timeout regardless of the retry budget.
fn connect(addr: &str, timeout: Duration, backoff: &mut Backoff) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    backoff.run(deadline, || {
        // Bound each attempt by the time left to the shared deadline
        // (not the full `timeout`): a retry that starts late must not
        // stretch the whole loop past one timeout.
        let left = deadline
            .saturating_duration_since(Instant::now())
            .max(Duration::from_millis(1));
        connect_once(addr, left.min(timeout))
    })
}

/// A single resolve-and-dial attempt.
fn connect_once(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let mut last: Option<io::Error> = None;
    for sock_addr in addr
        .to_socket_addrs()
        .with_context(|| format!("cannot resolve {addr}"))?
    {
        match TcpStream::connect_timeout(&sock_addr, timeout) {
            Ok(stream) => {
                stream.set_nodelay(true).ok();
                return Ok(stream);
            }
            Err(e) => last = Some(e),
        }
    }
    match last {
        Some(e) => Err(anyhow!("connect {addr}: {e}")),
        None => Err(anyhow!("connect {addr}: no addresses resolved")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: Duration = Duration::from_secs(10);

    /// Three threads rendezvous into a ring and pass one token all the
    /// way around it — the ring topology (successor/predecessor wiring)
    /// is exactly rank order.
    #[test]
    fn three_workers_form_a_ring() {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        let world = 3;

        let workers: Vec<_> = (0..world)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || -> Result<(usize, usize)> {
                    let mut joined = join(&addr, T)?;
                    // Send own rank to the successor; read predecessor's.
                    write_frame(&mut joined.to_next, &Frame::Connect {
                        rank: joined.rank as u32,
                    })
                    .map_err(|e| anyhow!(e))?;
                    let got = match read_frame(&mut joined.from_prev).map_err(|e| anyhow!(e))? {
                        Frame::Connect { rank } => rank as usize,
                        other => bail!("unexpected {}", other.kind_name()),
                    };
                    Ok((joined.rank, got))
                })
            })
            .collect();

        let controls = rv.run(world, T).unwrap();
        assert_eq!(controls.len(), world);
        for handle in workers {
            let (rank, from_pred) = handle.join().unwrap().unwrap();
            assert_eq!(from_pred, (rank + world - 1) % world, "rank {rank}");
        }
    }

    #[test]
    fn single_worker_ring_loops_to_itself() {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        let worker = std::thread::spawn(move || join(&addr, T));
        rv.run(1, T).unwrap();
        let joined = worker.join().unwrap().unwrap();
        assert_eq!(joined.rank, 0);
        assert_eq!(joined.world, 1);
    }

    #[test]
    fn missing_worker_times_out_with_count() {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        // Only one of two workers ever joins.
        let worker = std::thread::spawn(move || join(&addr, Duration::from_secs(5)));
        let err = rv.run(2, Duration::from_millis(400)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("1/2 workers joined"), "{msg}");
        // The joined worker fails too (its Welcome never arrives).
        assert!(worker.join().unwrap().is_err());
    }

    #[test]
    fn unreachable_coordinator_is_an_error_not_a_hang() {
        // A bound-then-dropped listener leaves a port with no acceptor.
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let t0 = Instant::now();
        let err = join(&format!("127.0.0.1:{port}"), Duration::from_millis(300)).unwrap_err();
        assert!(format!("{err:#}").contains("coordinator"), "{err:#}");
        // Backoff retries stay bounded by the connect deadline.
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    /// A successor that comes up *after* the first dial attempt is
    /// still reached: the backoff retries the connect instead of
    /// failing on the first refused attempt.
    #[test]
    fn connect_retries_through_backoff() {
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let addr = format!("127.0.0.1:{port}");
        let late = std::thread::spawn({
            let addr = addr.clone();
            move || {
                std::thread::sleep(Duration::from_millis(80));
                TcpListener::bind(addr).unwrap().accept().unwrap()
            }
        });
        let mut backoff = Backoff::standard(10, 7);
        let stream = connect(&addr, Duration::from_secs(5), &mut backoff);
        assert!(stream.is_ok(), "{:?}", stream.err());
        // The listener only binds 80 ms in, so the first dial was
        // refused and the success must have consumed retries — which
        // the policy's local tally records.
        assert!(backoff.attempts() >= 1, "retries must be tallied");
        late.join().unwrap();
    }

    /// Re-formation: two workers form a ring, tear the edges down, and
    /// re-form them in the opposite orientation over the *same*
    /// retained listeners — the elastic epoch-transition primitive.
    #[test]
    fn edges_reform_on_retained_listeners() {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || join(&addr, T).unwrap())
            })
            .collect();
        rv.run(2, T).unwrap();
        let mut joined: Vec<JoinedRing> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        joined.sort_by_key(|j| j.rank);
        let peers: Vec<String> = joined
            .iter()
            .map(|j| j.listener.local_addr().unwrap().to_string())
            .collect();
        // Tear down the old edges, keep the listeners.
        for j in &mut joined {
            let _ = j.to_next.shutdown(std::net::Shutdown::Both);
            let _ = j.from_prev.shutdown(std::net::Shutdown::Both);
        }
        // Swap ranks (the compaction a reconfigure performs) and re-form.
        let reformed: Vec<_> = std::thread::scope(|scope| {
            let handles: Vec<_> = joined
                .iter()
                .enumerate()
                .map(|(i, j)| {
                    let new_rank = 1 - i;
                    let peers = vec![peers[1].clone(), peers[0].clone()];
                    let listener = &j.listener;
                    scope.spawn(move || {
                        let mut b = Backoff::standard(4, new_rank as u64);
                        form_ring_edges(new_rank, 2, &peers, listener, T, &mut b)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for edges in reformed {
            assert!(edges.is_ok(), "{:?}", edges.err());
        }
    }
}
