//! Coordinator-hosted rendezvous: how `W` independent OS processes
//! become a ring (DESIGN.md §10).
//!
//! The protocol has four steps, all over the [`super::wire`] codec:
//!
//! 1. Each worker binds its own ring listener on an ephemeral localhost
//!    port **before** announcing itself, then connects to the
//!    coordinator and sends `Hello { listen_addr }`.
//! 2. The coordinator accepts `W` hellos, assigns ranks in arrival
//!    order, and sends every worker `Welcome { rank, world, peers }`
//!    with the full rank-indexed address list.
//! 3. Each worker dials its ring **successor**'s listener (rank+1 mod W)
//!    and introduces itself with `Connect { rank }`.
//! 4. Each worker accepts exactly one connection on its own listener
//!    and verifies the `Connect` frame names its ring **predecessor**.
//!
//! Because every listener is bound before any `Hello` is sent, step 3
//! can never race step 4: the successor's listener already exists (the
//! OS backlog holds the connection until the accept). The `Hello`
//! connection stays open as the **control channel** — workers send
//! their end-of-run `Report` on it.
//!
//! Every blocking call (accept, connect, handshake read) carries a
//! timeout, so a worker that never shows up or dies mid-handshake
//! surfaces as a contextual error naming the missing rank instead of a
//! hang.

use super::wire::{read_frame, write_frame, Frame};
use anyhow::{anyhow, bail, Context, Result};
use std::io;
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// The coordinator's half of the handshake.
pub struct Rendezvous {
    listener: TcpListener,
}

impl Rendezvous {
    /// Bind the rendezvous listener (e.g. `"127.0.0.1:0"` for an
    /// ephemeral port).
    pub fn bind(addr: &str) -> Result<Rendezvous> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("rendezvous: cannot bind {addr}"))?;
        Ok(Rendezvous { listener })
    }

    /// The address workers should dial (resolved, with the real port).
    pub fn addr(&self) -> Result<String> {
        Ok(self.listener.local_addr().context("rendezvous: no local addr")?.to_string())
    }

    /// Accept `world` workers, assign ranks in arrival order, and send
    /// each its `Welcome`. Returns the control streams indexed by rank;
    /// workers send their final `Report` frames on these.
    pub fn run(&self, world: usize, timeout: Duration) -> Result<Vec<TcpStream>> {
        let _span = crate::obs::span(crate::obs::Phase::Rendezvous);
        assert!(world > 0, "rendezvous needs at least one worker");
        let mut joined: Vec<(TcpStream, String)> = Vec::with_capacity(world);
        let deadline = Instant::now() + timeout;
        while joined.len() < world {
            let remaining = world - joined.len();
            let (mut stream, from) = accept_with_deadline(&self.listener, deadline)
                .with_context(|| {
                    format!(
                        "rendezvous: only {}/{world} workers joined ({remaining} missing)",
                        joined.len()
                    )
                })?;
            stream.set_read_timeout(Some(timeout)).context("rendezvous: set timeout")?;
            stream.set_nodelay(true).ok();
            let rank = joined.len();
            match read_frame(&mut stream)
                .map_err(|e| anyhow!(e))
                .with_context(|| format!("rendezvous: handshake with {from} (would-be rank {rank})"))?
            {
                Frame::Hello { listen_addr } => joined.push((stream, listen_addr)),
                other => bail!(
                    "rendezvous: expected Hello from {from}, got {}",
                    other.kind_name()
                ),
            }
        }
        let peers: Vec<String> = joined.iter().map(|(_, addr)| addr.clone()).collect();
        for (rank, (stream, _)) in joined.iter_mut().enumerate() {
            write_frame(
                stream,
                &Frame::Welcome { rank: rank as u32, world: world as u32, peers: peers.clone() },
            )
            .map_err(|e| anyhow!(e))
            .with_context(|| format!("rendezvous: sending Welcome to rank {rank}"))?;
        }
        Ok(joined.into_iter().map(|(stream, _)| stream).collect())
    }
}

/// A worker's completed handshake: its identity plus the three live
/// connections (control to the coordinator, ring edge to the successor,
/// ring edge from the predecessor).
pub struct JoinedRing {
    /// The rank the coordinator assigned this worker.
    pub rank: usize,
    /// Total number of workers in the ring.
    pub world: usize,
    /// The original `Hello` connection; carries the final `Report`.
    pub control: TcpStream,
    /// Ring edge this worker writes to (its successor reads it).
    pub to_next: TcpStream,
    /// Ring edge this worker reads from (its predecessor writes it).
    pub from_prev: TcpStream,
}

/// The worker's half of the handshake: join the ring hosted by
/// `coordinator` (a `host:port` string).
pub fn join(coordinator: &str, timeout: Duration) -> Result<JoinedRing> {
    let _span = crate::obs::span(crate::obs::Phase::Rendezvous);
    // Bind the ring listener *before* saying Hello, so the predecessor
    // can dial us the moment it learns our address.
    let listener =
        TcpListener::bind("127.0.0.1:0").context("worker: cannot bind ring listener")?;
    let my_addr = listener.local_addr().context("worker: ring listener addr")?.to_string();

    let mut control = connect(coordinator, timeout)
        .with_context(|| format!("worker: coordinator {coordinator} unreachable"))?;
    control.set_read_timeout(Some(timeout)).context("worker: set control timeout")?;
    write_frame(&mut control, &Frame::Hello { listen_addr: my_addr })
        .map_err(|e| anyhow!(e))
        .context("worker: sending Hello")?;

    let (rank, world, peers) = match read_frame(&mut control)
        .map_err(|e| anyhow!(e))
        .context("worker: waiting for Welcome (coordinator died or timed out?)")?
    {
        Frame::Welcome { rank, world, peers } => (rank as usize, world as usize, peers),
        other => bail!("worker: expected Welcome, got {}", other.kind_name()),
    };
    if world == 0 || rank >= world || peers.len() != world {
        bail!("worker: malformed Welcome (rank {rank}, world {world}, {} peers)", peers.len());
    }

    let next = (rank + 1) % world;
    let mut to_next = connect(&peers[next], timeout).with_context(|| {
        format!("rank {rank}: ring successor rank {next} at {} unreachable", peers[next])
    })?;
    write_frame(&mut to_next, &Frame::Connect { rank: rank as u32 })
        .map_err(|e| anyhow!(e))
        .with_context(|| format!("rank {rank}: introducing to successor rank {next}"))?;

    let prev = (rank + world - 1) % world;
    let deadline = Instant::now() + timeout;
    let (mut from_prev, _) = accept_with_deadline(&listener, deadline).with_context(|| {
        format!("rank {rank}: ring predecessor rank {prev} never connected")
    })?;
    from_prev.set_read_timeout(Some(timeout)).context("worker: set ring timeout")?;
    match read_frame(&mut from_prev)
        .map_err(|e| anyhow!(e))
        .with_context(|| format!("rank {rank}: handshake from predecessor rank {prev}"))?
    {
        Frame::Connect { rank: got } if got as usize == prev => {}
        Frame::Connect { rank: got } => bail!(
            "rank {rank}: expected Connect from predecessor rank {prev}, got rank {got}"
        ),
        other => bail!(
            "rank {rank}: expected Connect from predecessor rank {prev}, got {}",
            other.kind_name()
        ),
    }

    Ok(JoinedRing { rank, world, control, to_next, from_prev })
}

/// `TcpListener::accept` with a deadline: `accept` alone blocks forever
/// if the peer never dials, which is exactly the hang the TCP transport
/// must turn into an error.
fn accept_with_deadline(
    listener: &TcpListener,
    deadline: Instant,
) -> Result<(TcpStream, std::net::SocketAddr)> {
    listener.set_nonblocking(true).context("set_nonblocking")?;
    let out = loop {
        match listener.accept() {
            Ok((stream, from)) => {
                // Accepted sockets must be blocking regardless of what
                // they inherited from the listener.
                stream.set_nonblocking(false).context("accepted stream")?;
                stream.set_nodelay(true).ok();
                break Ok((stream, from));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    break Err(anyhow!("accept timed out"));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => break Err(e).context("accept failed"),
        }
    };
    // Restore blocking accepts for any later use of the listener.
    listener.set_nonblocking(false).ok();
    out
}

/// `TcpStream::connect` with a timeout, resolving `host:port` strings.
fn connect(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let mut last: Option<io::Error> = None;
    for sock_addr in addr
        .to_socket_addrs()
        .with_context(|| format!("cannot resolve {addr}"))?
    {
        match TcpStream::connect_timeout(&sock_addr, timeout) {
            Ok(stream) => {
                stream.set_nodelay(true).ok();
                return Ok(stream);
            }
            Err(e) => last = Some(e),
        }
    }
    match last {
        Some(e) => Err(anyhow!("connect {addr}: {e}")),
        None => Err(anyhow!("connect {addr}: no addresses resolved")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: Duration = Duration::from_secs(10);

    /// Three threads rendezvous into a ring and pass one token all the
    /// way around it — the ring topology (successor/predecessor wiring)
    /// is exactly rank order.
    #[test]
    fn three_workers_form_a_ring() {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        let world = 3;

        let workers: Vec<_> = (0..world)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || -> Result<(usize, usize)> {
                    let mut joined = join(&addr, T)?;
                    // Send own rank to the successor; read predecessor's.
                    write_frame(&mut joined.to_next, &Frame::Connect {
                        rank: joined.rank as u32,
                    })
                    .map_err(|e| anyhow!(e))?;
                    let got = match read_frame(&mut joined.from_prev).map_err(|e| anyhow!(e))? {
                        Frame::Connect { rank } => rank as usize,
                        other => bail!("unexpected {}", other.kind_name()),
                    };
                    Ok((joined.rank, got))
                })
            })
            .collect();

        let controls = rv.run(world, T).unwrap();
        assert_eq!(controls.len(), world);
        for handle in workers {
            let (rank, from_pred) = handle.join().unwrap().unwrap();
            assert_eq!(from_pred, (rank + world - 1) % world, "rank {rank}");
        }
    }

    #[test]
    fn single_worker_ring_loops_to_itself() {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        let worker = std::thread::spawn(move || join(&addr, T));
        rv.run(1, T).unwrap();
        let joined = worker.join().unwrap().unwrap();
        assert_eq!(joined.rank, 0);
        assert_eq!(joined.world, 1);
    }

    #[test]
    fn missing_worker_times_out_with_count() {
        let rv = Rendezvous::bind("127.0.0.1:0").unwrap();
        let addr = rv.addr().unwrap();
        // Only one of two workers ever joins.
        let worker = std::thread::spawn(move || join(&addr, Duration::from_secs(5)));
        let err = rv.run(2, Duration::from_millis(400)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("1/2 workers joined"), "{msg}");
        // The joined worker fails too (its Welcome never arrives).
        assert!(worker.join().unwrap().is_err());
    }

    #[test]
    fn unreachable_coordinator_is_an_error_not_a_hang() {
        // A bound-then-dropped listener leaves a port with no acceptor.
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let err = join(&format!("127.0.0.1:{port}"), Duration::from_millis(300)).unwrap_err();
        assert!(format!("{err:#}").contains("coordinator"), "{err:#}");
    }
}
