//! Measured-bytes accounting: a [`Transport`] wrapper that counts what
//! actually crosses the wire.
//!
//! The [`crate::collectives::CommLog`] unit is *logical*: one record
//! per collective with the per-worker message size — the paper's
//! data-volume metric. A ring collective physically moves more than
//! that (an all-reduce sends `2(W−1)` chunks, an all-gather forwards
//! `W−1` messages). [`MeteredTransport`] counts the physical payload
//! bytes at the transport seam, and
//! [`crate::collectives::ring_wire_bytes`] is the closed-form
//! prediction; the TCP harness cross-checks `measured == predicted` for
//! every run, which pins the analytic `Scheme::message_bytes` model to
//! real socket traffic.
//!
//! The wrapper works over any [`Transport`] — the in-process
//! [`crate::transport::InProcRing`] endpoints in unit tests, the real
//! [`super::TcpRing`] in multi-process runs — so byte accounting is
//! testable without sockets and identical with them.
//!
//! # Accounting under posted sends
//!
//! A send is charged when it is *posted* — the moment the transport
//! takes responsibility for the bytes — not when they drain onto the
//! socket. A receive is charged when its ticket resolves to
//! [`Completion::Received`] (via `poll` or `wait`), which is the only
//! point the payload length is known. The blocking wrappers
//! `send_next`/`recv_prev` are the trait's defaults over post + wait,
//! so both call styles meter identically.

use crate::transport::{Completion, Ticket, Transport};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Payload size of a message as the wire codec would carry it
/// (frame headers excluded: the accounting unit is payload bytes, the
/// same unit as `Scheme::message_bytes`).
pub trait WireSized {
    /// Payload bytes this message occupies on the wire.
    fn wire_bytes(&self) -> u64;
}

impl WireSized for Vec<f32> {
    fn wire_bytes(&self) -> u64 {
        (self.len() * 4) as u64
    }
}

impl WireSized for Vec<u8> {
    fn wire_bytes(&self) -> u64 {
        self.len() as u64
    }
}

/// Shared handles on a [`MeteredTransport`]'s counters; stays readable
/// after the transport itself moves into a compressor or optimizer.
#[derive(Clone)]
pub struct WireCounters {
    sent: Arc<AtomicU64>,
    received: Arc<AtomicU64>,
}

impl WireCounters {
    /// Total payload bytes sent to the ring successor so far.
    pub fn sent(&self) -> u64 {
        self.sent.load(Ordering::SeqCst)
    }

    /// Total payload bytes received from the ring predecessor so far.
    pub fn received(&self) -> u64 {
        self.received.load(Ordering::SeqCst)
    }
}

/// [`Transport`] wrapper that meters every message in both directions.
pub struct MeteredTransport<T> {
    inner: T,
    sent: Arc<AtomicU64>,
    received: Arc<AtomicU64>,
}

impl<T> MeteredTransport<T> {
    /// Wrap a transport with zeroed counters.
    pub fn new(inner: T) -> MeteredTransport<T> {
        MeteredTransport {
            inner,
            sent: Arc::new(AtomicU64::new(0)),
            received: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Counter handles that outlive moves of the transport itself.
    pub fn counters(&self) -> WireCounters {
        WireCounters { sent: Arc::clone(&self.sent), received: Arc::clone(&self.received) }
    }

    /// Total payload bytes sent so far.
    pub fn bytes_sent(&self) -> u64 {
        self.sent.load(Ordering::SeqCst)
    }

    /// Total payload bytes received so far.
    pub fn bytes_received(&self) -> u64 {
        self.received.load(Ordering::SeqCst)
    }

    /// The wrapped transport.
    pub fn inner(&self) -> &T {
        &self.inner
    }

    /// Charge a resolved receive; called exactly once per ticket since
    /// a completion queue hands each message out a single time.
    fn count_received<M: WireSized>(&self, msg: &M) {
        let n = msg.wire_bytes();
        self.received.fetch_add(n, Ordering::SeqCst);
        crate::obs::add_wire_bytes(0, n);
        // Same charge point as the span-layer counter, so the metrics
        // registry reconciles structurally with the metered totals.
        crate::obs::metrics::add(crate::obs::metrics::Counter::WireRecvBytes, n);
    }
}

impl<M, T> Transport<M> for MeteredTransport<T>
where
    M: Send + WireSized,
    T: Transport<M>,
{
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn world(&self) -> usize {
        self.inner.world()
    }

    fn post_send(&self, msg: M) -> Ticket {
        // Charged at post: the transport has taken responsibility for
        // these bytes even though they may still be in flight.
        let n = msg.wire_bytes();
        self.sent.fetch_add(n, Ordering::SeqCst);
        crate::obs::add_wire_bytes(n, 0);
        // Same charge point as the span-layer counter, so the metrics
        // registry reconciles structurally with the metered totals.
        crate::obs::metrics::add(crate::obs::metrics::Counter::WireSentBytes, n);
        self.inner.post_send(msg)
    }

    fn post_recv(&self) -> Ticket {
        self.inner.post_recv()
    }

    fn poll(&self, ticket: Ticket) -> Completion<M> {
        let completion = self.inner.poll(ticket);
        if let Completion::Received(ref msg) = completion {
            self.count_received(msg);
        }
        completion
    }

    fn wait(&self, ticket: Ticket) -> Completion<M> {
        let completion = self.inner.wait(ticket);
        if let Completion::Received(ref msg) = completion {
            self.count_received(msg);
        }
        completion
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{ring_wire_bytes, CollKind};
    use crate::transport::{ring_all_gather_worker, ring_all_reduce_worker, InProcRing};
    use crate::util::Rng;

    /// The metered counters on a real ring all-reduce must equal the
    /// closed-form expansion, per rank, including uneven chunk splits.
    #[test]
    fn metered_all_reduce_matches_analytic_expansion() {
        let mut rng = Rng::new(81);
        for &(world, n) in &[(2usize, 8usize), (3, 10), (4, 1003), (5, 7), (8, 0), (1, 64)] {
            let nodes = InProcRing::endpoints::<Vec<f32>>(world);
            let metered: Vec<_> = nodes.into_iter().map(MeteredTransport::new).collect();
            // Counter handles stay readable after the endpoints move
            // into their worker threads (endpoints are Send, not Sync).
            let counters: Vec<WireCounters> = metered.iter().map(|m| m.counters()).collect();
            let mut bufs: Vec<Vec<f32>> = (0..world)
                .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
                .collect();
            std::thread::scope(|scope| {
                for (node, buf) in metered.into_iter().zip(bufs.iter_mut()) {
                    scope.spawn(move || ring_all_reduce_worker(&node, buf));
                }
            });
            let msg_bytes = (n * 4) as u64;
            for (rank, counter) in counters.iter().enumerate() {
                assert_eq!(
                    counter.sent(),
                    ring_wire_bytes(CollKind::AllReduce, msg_bytes, world, rank),
                    "sent: world={world} n={n} rank={rank}"
                );
                // Everything a worker receives was sent by its predecessor.
                assert_eq!(
                    counter.received(),
                    ring_wire_bytes(CollKind::AllReduce, msg_bytes, world, (rank + world - 1) % world),
                    "received: world={world} n={n} rank={rank}"
                );
            }
        }
    }

    #[test]
    fn metered_all_gather_matches_analytic_expansion() {
        for world in [1usize, 2, 3, 5] {
            let nodes = InProcRing::endpoints::<Vec<u8>>(world);
            let metered: Vec<_> = nodes.into_iter().map(MeteredTransport::new).collect();
            let counters: Vec<WireCounters> = metered.iter().map(|m| m.counters()).collect();
            let msg_len = 6usize;
            std::thread::scope(|scope| {
                for node in metered.into_iter() {
                    scope.spawn(move || {
                        let rank = Transport::<Vec<u8>>::rank(&node);
                        ring_all_gather_worker(&node, vec![rank as u8; msg_len])
                    });
                }
            });
            for (rank, counter) in counters.iter().enumerate() {
                assert_eq!(
                    counter.sent(),
                    ring_wire_bytes(CollKind::AllGather, msg_len as u64, world, rank),
                    "world={world} rank={rank}"
                );
            }
        }
    }

    #[test]
    fn counters_survive_moving_the_transport() {
        let nodes = InProcRing::endpoints::<Vec<f32>>(1);
        let metered = MeteredTransport::new(nodes.into_iter().next().unwrap());
        let counters = metered.counters();
        // Move the transport away (as the harness moves it into the
        // optimizer); the handle still reads the counters.
        let moved = metered;
        moved.send_next(vec![1.0f32, 2.0]);
        let _ = moved.recv_prev();
        assert_eq!(counters.sent(), 8);
        assert_eq!(counters.received(), 8);
        assert_eq!(moved.bytes_sent(), 8);
    }

    /// Sends are charged at post (before any wait); receives only when
    /// the ticket resolves with the payload.
    #[test]
    fn posted_ops_meter_at_post_and_resolution() {
        let nodes = InProcRing::endpoints::<Vec<f32>>(1);
        let metered = MeteredTransport::new(nodes.into_iter().next().unwrap());
        let counters = metered.counters();
        let send = metered.post_send(vec![1.0f32, 2.0, 3.0]);
        assert_eq!(counters.sent(), 12);
        assert_eq!(metered.wait(send), Completion::Sent);
        assert_eq!(counters.sent(), 12);
        let recv = metered.post_recv();
        assert_eq!(counters.received(), 0);
        match metered.wait(recv) {
            Completion::Received(msg) => assert_eq!(msg, vec![1.0, 2.0, 3.0]),
            other => panic!("expected a message, got {other:?}"),
        }
        assert_eq!(counters.received(), 12);
    }

    #[test]
    fn wire_sizes() {
        assert_eq!(vec![0.0f32; 3].wire_bytes(), 12);
        assert_eq!(vec![0u8; 3].wire_bytes(), 3);
        assert_eq!(Vec::<f32>::new().wire_bytes(), 0);
    }
}
