//! The concurrent worker engine (DESIGN.md §9).
//!
//! Everything below [`crate::coordinator`] used to execute every
//! simulated worker sequentially on the caller's thread, and only
//! *priced* network time with the α–β model. This module is a real
//! execution substrate:
//!
//! - [`Transport`] — the point-to-point seam: a worker endpoint that can
//!   send a message to its ring successor and (blockingly) receive from
//!   its predecessor. [`InProcRing`] implements it with `std::sync::mpsc`
//!   channels; [`TcpRing`] implements it over real OS sockets.
//! - [`ring`] — channel-based ring collectives: each simulated worker
//!   runs on its own OS thread and moves chunks over its endpoint. The
//!   arithmetic (chunk boundaries, accumulation order) is identical to
//!   the lockstep reference in [`crate::collectives`], so the threaded
//!   engine reproduces its results *bitwise* — the lockstep path is the
//!   correctness oracle.
//! - [`Bucketer`] — PyTorch-DDP-style gradient bucketing: per-layer
//!   messages are packed into fixed-capacity buckets in gradient-ready
//!   (reverse declaration) order.
//! - **Per-worker compression** — the [`Transport`] seam also carries
//!   the decentralized compression path
//!   ([`crate::compress::WorkerCompressor`]): under the threaded engine
//!   each worker thread compresses its own gradient and aggregates the
//!   `P`/`Q` factors (or packed messages) over an [`InProcRing`],
//!   bitwise-matching the centralized lockstep oracle.
//! - [`overlap`] — the comm/compute overlap scheduler: each bucket's
//!   collective launches as soon as backprop has produced its layers,
//!   over a [`Cluster`] with per-link α/β and per-worker compute jitter
//!   (straggler and heterogeneous-cluster scenarios).
//! - [`tcp`] — the multi-process backend (DESIGN.md §10): a
//!   length-prefixed wire codec, a coordinator-hosted rendezvous that
//!   assigns ranks and distributes peer addresses, the [`TcpRing`]
//!   transport over real sockets, [`MeteredTransport`] measured-bytes
//!   accounting, and the `powersgd launch`/`worker` harness that pins
//!   a localhost multi-process run bitwise to the lockstep oracle.
//!
//! # Engine selection
//!
//! The engine is process-wide configuration, like a `torch.distributed`
//! backend: [`set_engine`] flips every collective in the process between
//! the lockstep reference and the threaded ring. [`crate::coordinator`]
//! sets it from [`TrainerConfig::engine`](crate::coordinator::TrainerConfig),
//! and the CLI exposes it as `--engine {lockstep,threaded}`. Both engines
//! produce identical bytes, so concurrent tests that race on the switch
//! can differ only in thread schedule, never in results.
//!
//! # Worked example
//!
//! One ring all-reduce on the threaded substrate — one OS thread per
//! worker, chunks really moving through channels — summing three
//! workers' buffers in place:
//!
//! ```
//! use powersgd::transport::{ring_all_reduce_worker, InProcRing};
//!
//! let mut bufs = vec![vec![1.0f32, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
//! let nodes = InProcRing::endpoints::<Vec<f32>>(bufs.len());
//! std::thread::scope(|scope| {
//!     for (node, buf) in nodes.into_iter().zip(bufs.iter_mut()) {
//!         scope.spawn(move || ring_all_reduce_worker(&node, buf));
//!     }
//! });
//! // Every worker holds the identical elementwise sum — and the bits
//! // match the sequential lockstep reference exactly.
//! for buf in &bufs {
//!     assert_eq!(buf, &vec![111.0, 222.0]);
//! }
//! ```

mod bucket;
pub mod overlap;
pub mod ring;
pub mod tcp;

pub use bucket::{bytes_from_mb, Bucket, Bucketer, LayerTiming};
pub use overlap::{schedule_step, Cluster, ComputePhases, Link, OverlapOutcome};
pub use ring::{
    ring_all_gather_threaded, ring_all_gather_worker, ring_all_reduce_sum_threaded,
    ring_all_reduce_worker, InProcDuplex, InProcRing, RingNode, Transport,
};
pub use tcp::{MeteredTransport, TcpRing, WireCounters};

use std::sync::atomic::{AtomicU8, Ordering};

/// Which execution substrate collectives run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Sequential reference implementation (the correctness oracle).
    #[default]
    Lockstep,
    /// Thread-per-worker ring over mpsc channels.
    Threaded,
}

/// Look up an engine by (case-insensitive) CLI name.
pub fn engine_by_name(name: &str) -> Option<EngineKind> {
    match name.to_ascii_lowercase().as_str() {
        "lockstep" | "sequential" => Some(EngineKind::Lockstep),
        "threaded" | "ring" => Some(EngineKind::Threaded),
        _ => None,
    }
}

static ENGINE: AtomicU8 = AtomicU8::new(0);

/// Select the process-wide collective engine.
pub fn set_engine(kind: EngineKind) {
    ENGINE.store(kind as u8, Ordering::SeqCst);
}

/// The currently selected collective engine.
pub fn engine() -> EngineKind {
    match ENGINE.load(Ordering::SeqCst) {
        1 => EngineKind::Threaded,
        _ => EngineKind::Lockstep,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_names() {
        assert_eq!(engine_by_name("lockstep"), Some(EngineKind::Lockstep));
        assert_eq!(engine_by_name("THREADED"), Some(EngineKind::Threaded));
        assert_eq!(engine_by_name("ring"), Some(EngineKind::Threaded));
        assert_eq!(engine_by_name("mpi"), None);
    }

    #[test]
    fn default_engine_is_lockstep() {
        assert_eq!(EngineKind::default(), EngineKind::Lockstep);
    }
}
