//! The concurrent worker engine (DESIGN.md §9).
//!
//! Everything below [`crate::coordinator`] used to execute every
//! simulated worker sequentially on the caller's thread, and only
//! *priced* network time with the α–β model. This module is a real
//! execution substrate:
//!
//! - [`Transport`] — the point-to-point seam: a completion queue over a
//!   worker endpoint's two ring links (post a send to the successor /
//!   a receive from the predecessor, then poll or wait the ticket).
//!   [`InProcRing`] implements it with `std::sync::mpsc` channels;
//!   [`TcpRing`] implements it over real OS sockets with a dedicated
//!   I/O thread per direction.
//! - [`ring`] — channel-based ring collectives: each simulated worker
//!   runs on its own OS thread and moves chunks over its endpoint. The
//!   arithmetic (chunk boundaries, accumulation order) is identical to
//!   the lockstep reference in [`crate::collectives`], so the threaded
//!   engine reproduces its results *bitwise* — the lockstep path is the
//!   correctness oracle.
//! - [`Bucketer`] — PyTorch-DDP-style gradient bucketing: per-layer
//!   messages are packed into fixed-capacity buckets in gradient-ready
//!   (reverse declaration) order.
//! - **Per-worker compression** — the [`Transport`] seam also carries
//!   the decentralized compression path
//!   ([`crate::compress::WorkerCompressor`]): under the threaded engine
//!   each worker thread compresses its own gradient and aggregates the
//!   `P`/`Q` factors (or packed messages) over an [`InProcRing`],
//!   bitwise-matching the centralized lockstep oracle.
//! - [`overlap`] — the comm/compute overlap scheduler: each bucket's
//!   collective launches as soon as backprop has produced its layers,
//!   over a [`Cluster`] with per-link α/β and per-worker compute jitter
//!   (straggler and heterogeneous-cluster scenarios).
//! - [`tcp`] — the multi-process backend (DESIGN.md §10): a
//!   length-prefixed wire codec, a coordinator-hosted rendezvous that
//!   assigns ranks and distributes peer addresses, the [`TcpRing`]
//!   transport over real sockets, [`MeteredTransport`] measured-bytes
//!   accounting, and the `powersgd launch`/`worker` harness that pins
//!   a localhost multi-process run bitwise to the lockstep oracle.
//!
//! # Engine selection
//!
//! The engine is *explicit per-run configuration*, not process-global
//! state: every collective takes a
//! [`CommLog`](crate::collectives::CommLog) and dispatches on its
//! `engine` field ([`CommLog::on`](crate::collectives::CommLog::on)
//! selects it; `CommLog::default()` is the lockstep oracle).
//! [`crate::coordinator`] builds its log from
//! [`TrainerConfig::engine`](crate::coordinator::TrainerConfig), and the
//! CLI exposes it as `--engine {lockstep,threaded}`. Because nothing is
//! process-wide, two engines coexist in one process — the comparison
//! tests run them side by side with no global lock. Both engines
//! produce identical bytes, so a switch can differ only in thread
//! schedule, never in results.
//!
//! # Posted operations and pipelining
//!
//! [`Transport`] is a completion queue: [`Transport::post_send`] /
//! [`Transport::post_recv`] return [`Ticket`]s resolved by
//! [`Transport::poll`] / [`Transport::wait`]; the blocking
//! `send_next`/`recv_prev` calls are default wrappers over post + wait.
//! [`pipeline`] builds split-phase ring collectives on top
//! ([`PostedAllReduce`]) and defines the `--pipeline
//! {off,overlap,delayed}` axis ([`PipelineMode`]); see DESIGN.md §14
//! for the determinism policy governing in-flight operations.
//!
//! # Worked example
//!
//! One ring all-reduce on the threaded substrate — one OS thread per
//! worker, chunks really moving through channels — summing three
//! workers' buffers in place:
//!
//! ```
//! use powersgd::transport::{ring_all_reduce_worker, InProcRing};
//!
//! let mut bufs = vec![vec![1.0f32, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
//! let nodes = InProcRing::endpoints::<Vec<f32>>(bufs.len());
//! std::thread::scope(|scope| {
//!     for (node, buf) in nodes.into_iter().zip(bufs.iter_mut()) {
//!         scope.spawn(move || ring_all_reduce_worker(&node, buf));
//!     }
//! });
//! // Every worker holds the identical elementwise sum — and the bits
//! // match the sequential lockstep reference exactly.
//! for buf in &bufs {
//!     assert_eq!(buf, &vec![111.0, 222.0]);
//! }
//! ```

mod bucket;
pub mod overlap;
pub mod pipeline;
pub mod ring;
pub mod tcp;

pub use bucket::{bytes_from_mb, Bucket, Bucketer, LayerTiming};
pub use overlap::{schedule_step, Cluster, ComputePhases, Link, OverlapOutcome};
pub use pipeline::{pipeline_by_name, PipelineMode, PostedAllReduce};
pub use ring::{
    ring_all_gather_threaded, ring_all_gather_worker, ring_all_reduce_sum_threaded,
    ring_all_reduce_worker, Completion, InProcDuplex, InProcRing, RingNode, Ticket, Transport,
};
pub use tcp::{MeteredTransport, TcpRing, WireCounters};

/// Which execution substrate collectives run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Sequential reference implementation (the correctness oracle).
    #[default]
    Lockstep,
    /// Thread-per-worker ring over mpsc channels.
    Threaded,
}

/// Look up an engine by (case-insensitive) CLI name.
pub fn engine_by_name(name: &str) -> Option<EngineKind> {
    match name.to_ascii_lowercase().as_str() {
        "lockstep" | "sequential" => Some(EngineKind::Lockstep),
        "threaded" | "ring" => Some(EngineKind::Threaded),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_names() {
        assert_eq!(engine_by_name("lockstep"), Some(EngineKind::Lockstep));
        assert_eq!(engine_by_name("THREADED"), Some(EngineKind::Threaded));
        assert_eq!(engine_by_name("ring"), Some(EngineKind::Threaded));
        assert_eq!(engine_by_name("mpi"), None);
    }

    #[test]
    fn default_engine_is_lockstep() {
        assert_eq!(EngineKind::default(), EngineKind::Lockstep);
    }
}
