//! The distributed-training coordinator (L3).
//!
//! [`Trainer`] owns the full per-step schedule of data-parallel training
//! with compressed gradient aggregation:
//!
//! 1. each of the `W` simulated workers draws its data shard and runs the
//!    AOT-compiled `train_step` artifact (fwd + bwd) via PJRT;
//! 2. raw gradients are matricized (paper §3);
//! 3. the [`DistOptimizer`] compresses, aggregates over the simulated
//!    collective, applies error feedback + momentum, and emits the
//!    parameter delta;
//! 4. parameters are updated and metrics recorded (measured compute
//!    times, exact byte counts, simulated network time).
//!
//! Python never runs here — the artifacts were lowered once at build
//! time (`make artifacts`).

mod checkpoint;
mod metrics;
pub use checkpoint::{load as load_checkpoint, save as save_checkpoint};
pub use metrics::{Metrics, StepRecord};

use crate::collectives::CommLog;
use crate::data::DataSource;
use crate::grad::ParamRegistry;
use crate::net::Backend;
use crate::optim::DistOptimizer;
use crate::runtime::{Artifact, Value};
use crate::tensor::Tensor;
use crate::transport::{
    schedule_step, Bucket, Bucketer, Cluster, ComputePhases, EngineKind, LayerTiming,
    PipelineMode,
};
use crate::util::Rng;
use anyhow::{bail, Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// Share of the measured fwd+bwd time attributed to backprop when
/// projecting comm/compute overlap (≈ the fwd:bwd split of the paper's
/// profiles; per-layer timings are not observable through PJRT).
const BWD_FRACTION: f64 = 0.6;

/// How evaluation output is interpreted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalKind {
    /// eval artifact returns (loss, correct_count) → report accuracy %.
    Accuracy,
    /// eval artifact returns loss → report perplexity `exp(loss)`.
    Perplexity,
}

/// Trainer configuration.
pub struct TrainerConfig {
    /// Number of simulated data-parallel workers.
    pub workers: usize,
    /// α–β backend pricing the collectives.
    pub backend: Backend,
    /// Seed for parameter init and data sharding.
    pub seed: u64,
    /// Evaluate every this many steps (0 = never).
    pub eval_every: usize,
    /// How evaluation output is interpreted.
    pub eval_kind: EvalKind,
    /// Print a progress line every this many steps (0 = never).
    pub log_every: usize,
    /// Collective execution substrate. `Threaded` runs every collective
    /// on the channel-based ring (one OS thread per worker), runs
    /// compression decentralized when the scheme has a per-worker
    /// implementation (see `powersgd::compress::decentralized_by_name`),
    /// and projects step time with comm/compute overlap; `Lockstep` is
    /// the sequential reference. Both produce identical gradients.
    pub engine: EngineKind,
    /// Collective scheduling relative to compute: `Off` is the lockstep
    /// reference, `Overlap` posts collectives early and drains them late
    /// (bitwise-identical results), `Delayed` applies step *t−1*'s
    /// aggregate while step *t*'s collective is in flight (one step of
    /// staleness, the PyTorch DDP PowerSGD-hook trick).
    pub pipeline: PipelineMode,
    /// DDP-style bucket capacity in raw gradient bytes (0 = a single
    /// bucket per step, i.e. no bucketing).
    pub bucket_bytes: u64,
    /// Compute slowdown of worker 0 (1.0 = homogeneous cluster); feeds
    /// the simulated timing, not the real execution.
    pub straggler: f64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            workers: 4,
            backend: crate::net::NCCL,
            seed: 42,
            eval_every: 0,
            eval_kind: EvalKind::Accuracy,
            log_every: 0,
            engine: EngineKind::Lockstep,
            pipeline: PipelineMode::default(),
            bucket_bytes: 0,
            straggler: 1.0,
        }
    }
}

/// Distributed trainer over one `train_step` artifact.
pub struct Trainer {
    train_step: Arc<Artifact>,
    eval_step: Option<Arc<Artifact>>,
    /// Current model parameters (original shapes).
    pub params: Vec<Tensor>,
    registry: ParamRegistry,
    opt: Box<dyn DistOptimizer>,
    cfg: TrainerConfig,
    /// Accumulated run metrics (times, bytes, losses, evals).
    pub metrics: Metrics,
    step: usize,
    /// Simulated cluster pricing the collectives (per-link α/β from the
    /// backend, straggler jitter from the config).
    cluster: Cluster,
    /// Per-layer raw gradient sizes, declaration order.
    layers: Vec<LayerTiming>,
    /// DDP-style buckets over `layers` (one bucket when bucketing off).
    buckets: Vec<Bucket>,
}

impl Trainer {
    /// Build a trainer: initializes parameters exactly as the artifact
    /// manifest directs (`param <name> zero|one|normal:<sigma>` lines
    /// emitted by aot.py) with the config seed — identical across
    /// workers, as in the paper's replicated-parameters setting.
    pub fn new(
        train_step: Arc<Artifact>,
        eval_step: Option<Arc<Artifact>>,
        opt: Box<dyn DistOptimizer>,
        cfg: TrainerConfig,
    ) -> Result<Trainer> {
        use crate::runtime::Init;
        let registry = train_step.manifest.param_registry();
        if registry.is_empty() {
            bail!("artifact {} declares no params", train_step.manifest.name);
        }
        let mut rng = Rng::new(cfg.seed);
        let params: Vec<Tensor> = train_step
            .manifest
            .param_specs()
            .iter()
            .zip(train_step.manifest.inits.iter())
            .map(|(spec, init)| {
                let shape: Vec<usize> =
                    if spec.shape.is_empty() { vec![1] } else { spec.shape.clone() };
                let mut t = Tensor::zeros(&shape);
                match init {
                    Init::Zero => {}
                    Init::One => t.data_mut().fill(1.0),
                    Init::Normal(sigma) => rng.fill_normal(t.data_mut(), *sigma),
                }
                t
            })
            .collect();
        // The engine is per-run configuration: collectives dispatch on
        // the CommLog built in `train_step` (CommLog::on), so nothing
        // process-global is mutated and other trainers/tests in the
        // same process are unaffected.
        // Phase accumulators feed the per-step time split
        // (compress/collective/decompress); they only read clocks, never
        // data, so trajectories are identical with or without them
        // (DESIGN.md §13).
        crate::obs::enable_timing(true);
        let cluster = Cluster::with_straggler(cfg.workers, &cfg.backend, cfg.straggler);
        // Bucket by raw gradient bytes (readiness is governed by
        // backprop). Wire bytes per bucket are apportioned from the
        // logged traffic by raw-byte share at pricing time, since the
        // per-layer compressed split is compressor-internal.
        let layers: Vec<LayerTiming> = registry
            .specs
            .iter()
            .map(|s| LayerTiming { msg_bytes: s.bytes(), raw_bytes: s.bytes() })
            .collect();
        let buckets = Bucketer::new(cfg.bucket_bytes).assign(&layers);
        Ok(Trainer {
            train_step,
            eval_step,
            params,
            registry,
            opt,
            cfg,
            metrics: Metrics::default(),
            step: 0,
            cluster,
            layers,
            buckets,
        })
    }

    /// The model's parameter registry (matricization view).
    pub fn registry(&self) -> &ParamRegistry {
        &self.registry
    }

    /// The optimizer's display name.
    pub fn optimizer_name(&self) -> String {
        self.opt.name()
    }

    /// Number of completed training steps.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Run one distributed step; returns the mean worker loss.
    pub fn train_step(&mut self, data: &mut dyn DataSource) -> Result<f64> {
        let _step_span = crate::obs::span(crate::obs::Phase::Step);
        let w = self.cfg.workers;
        let t0 = Instant::now();

        // 1. per-worker fwd/bwd via PJRT (simulated workers execute
        //    sequentially on the shared CPU client; grad_s reports the
        //    per-worker mean, which is what a real worker would spend).
        let grad_span = crate::obs::span(crate::obs::Phase::Grad);
        let mut losses = 0.0f64;
        let mut per_worker_grads: Vec<Vec<Tensor>> = Vec::with_capacity(w);
        for worker in 0..w {
            let batch = data.next_batch(worker);
            let mut inputs: Vec<Value> =
                self.params.iter().cloned().map(Value::F32).collect();
            inputs.extend(batch);
            let mut outs = self
                .train_step
                .execute(&inputs)
                .with_context(|| format!("train_step worker {worker}"))?;
            let loss = outs.remove(0);
            losses += loss.data()[0] as f64;
            per_worker_grads.push(self.registry.matricize(outs));
        }
        drop(grad_span);
        let grad_s = t0.elapsed().as_secs_f64() / w as f64;
        let loss = losses / w as f64;

        // 2–3. compress + aggregate + optimize. Obs span deltas split
        // the optimizer wall time into encode / collective / decode.
        // Span time sums across recording threads, so it is normalized
        // by how many threads the optimizer says time each collective
        // (W on the decentralized per-worker path, 1 for centralized
        // compressors — even on the threaded engine, whose ring threads
        // record ring spans, not Collective ones); encode is the
        // remainder, so the three parts always sum back to the
        // measured wall clock.
        let t1 = Instant::now();
        let before = crate::obs::phase_totals();
        let mut log = CommLog::on(self.cfg.engine);
        let delta = self.opt.step(&per_worker_grads, self.step, &mut log);
        let opt_s = t1.elapsed().as_secs_f64();
        let spans = crate::obs::phase_totals().delta_since(&before);
        let scale = self.opt.collective_span_threads().max(1) as f64;
        let collective_s =
            (spans.seconds(crate::obs::Phase::Collective) / scale).min(opt_s);
        let decompress_s = (spans.seconds(crate::obs::Phase::Decompress) / scale)
            .min(opt_s - collective_s);
        let compress_s = (opt_s - collective_s - decompress_s).max(0.0);

        // 4. apply the (de-matricized) delta.
        let delta = self.registry.dematricize(delta);
        for (p, d) in self.params.iter_mut().zip(delta.into_iter()) {
            assert_eq!(p.len(), d.len(), "delta length mismatch");
            let d = d.reshape(&p.shape().to_vec());
            p.axpy(-1.0, &d);
        }

        let bytes = log.bytes_sent();
        // Price the logged traffic on the simulated cluster, split into
        // the configured buckets (raw-byte apportioning), and project
        // the end-to-end step time: the threaded engine overlaps each
        // bucket's collective with the remaining backprop.
        //
        // The span-based split keeps the in-memory execution of the
        // collectives *out* of the encode/decode phases fed to the
        // cluster model (the old whole-wall `compress_s` double-counted
        // a memcpy-speed version of the traffic the model prices at
        // network speed). `compress_s` still differs slightly between
        // engines (thread spawns), and on the lockstep engine decode
        // stays folded into encode for oracle compressors without
        // decompress spans. The exact per-scheme model lives in
        // `simulate::simulate_step_overlapped`; this projection is for
        // trend-level comparison on measured runs.
        let cluster = &self.cluster;
        let total_raw: f64 = self.layers.iter().map(|l| l.raw_bytes as f64).sum::<f64>().max(1.0);
        let bucket_comm = |b: &Bucket| -> f64 {
            let share = b.raw_bytes as f64 / total_raw;
            log.ops
                .iter()
                .map(|o| cluster.time(o.kind, (o.bytes as f64 * share).round() as u64))
                .sum()
        };
        let compute = ComputePhases {
            fwd_s: grad_s * (1.0 - BWD_FRACTION),
            bwd_s: grad_s * BWD_FRACTION,
            encode_s: compress_s,
            decode_s: decompress_s,
        };
        // The cluster projection overlaps bucket collectives with the
        // remaining backprop when either the threaded engine or an
        // explicit pipelined mode is in play (delayed hides even more
        // in practice; the projection models it like overlap).
        let overlap = self.cfg.engine == EngineKind::Threaded
            || self.cfg.pipeline != PipelineMode::Off;
        let outcome =
            schedule_step(&self.layers, &self.buckets, compute, &bucket_comm, cluster, overlap);
        let sim_comm_s = outcome.comm_busy;
        self.metrics.record(StepRecord {
            step: self.step,
            loss,
            grad_s,
            compress_s,
            collective_s,
            decompress_s,
            bytes,
            sim_comm_s,
            sim_step_s: outcome.total,
            lr: self.opt.lr_at(self.step),
        });
        crate::obs::metrics::add(crate::obs::metrics::Counter::StepsCompleted, 1);
        crate::obs::metrics::observe_seconds(
            crate::obs::metrics::Histogram::StepSeconds,
            t0.elapsed().as_secs_f64(),
        );

        if self.cfg.log_every > 0 && self.step % self.cfg.log_every == 0 {
            // Decentralized compressors report their scratch-arena
            // allocation count; a number still moving after step 1 means
            // the zero-alloc hot path regressed.
            let scratch = match self.opt.scratch_allocations() {
                Some(n) => format!(" scratch-allocs {n}"),
                None => String::new(),
            };
            eprintln!(
                "[{}] step {:>5} loss {:.4} lr {:.4} bytes/step {} grad {:.1} ms \
                 compress {:.1} ms coll {:.1} ms decode {:.1} ms{}",
                self.opt.name(),
                self.step,
                loss,
                self.opt.lr_at(self.step),
                bytes,
                grad_s * 1e3,
                compress_s * 1e3,
                collective_s * 1e3,
                decompress_s * 1e3,
                scratch,
            );
        }

        if self.cfg.eval_every > 0 && (self.step + 1) % self.cfg.eval_every == 0 {
            let v = self.evaluate(data)?;
            self.metrics.record_eval(self.step, v);
        }

        self.step += 1;
        Ok(loss)
    }

    /// Save current parameters to a checkpoint file.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let named: Vec<(String, &Tensor)> = self
            .train_step
            .manifest
            .params
            .iter()
            .cloned()
            .zip(self.params.iter())
            .collect();
        checkpoint::save(path, &named)
    }

    /// Restore parameters from a checkpoint (names and shapes must match
    /// the artifact manifest).
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let loaded = checkpoint::load(path)?;
        if loaded.len() != self.params.len() {
            bail!("checkpoint has {} tensors, model has {}", loaded.len(), self.params.len());
        }
        for ((name, t), (want_name, slot)) in loaded
            .into_iter()
            .zip(self.train_step.manifest.params.iter().zip(self.params.iter_mut()))
        {
            if &name != want_name {
                bail!("checkpoint tensor {name:?} does not match param {want_name:?}");
            }
            if t.shape() != slot.shape() {
                bail!("checkpoint shape {:?} != param shape {:?} for {name}", t.shape(), slot.shape());
            }
            *slot = t;
        }
        Ok(())
    }

    /// Run `n` steps.
    pub fn train(&mut self, data: &mut dyn DataSource, n: usize) -> Result<()> {
        for _ in 0..n {
            self.train_step(data)?;
        }
        Ok(())
    }

    /// Evaluate on the held-out batch. Returns accuracy % or perplexity
    /// depending on [`TrainerConfig::eval_kind`].
    pub fn evaluate(&mut self, data: &mut dyn DataSource) -> Result<f64> {
        let eval = match &self.eval_step {
            Some(e) => e.clone(),
            None => bail!("no eval artifact configured"),
        };
        let batch = data.eval_batch();
        let mut inputs: Vec<Value> = self.params.iter().cloned().map(Value::F32).collect();
        inputs.extend(batch.clone());
        let outs = eval.execute(&inputs).context("eval_step")?;
        Ok(match self.cfg.eval_kind {
            EvalKind::Accuracy => {
                // outputs: (loss, correct_count); batch size from data
                let n = batch[0].shape()[0] as f64;
                100.0 * outs[1].data()[0] as f64 / n
            }
            EvalKind::Perplexity => (outs[0].data()[0] as f64).exp(),
        })
    }
}
