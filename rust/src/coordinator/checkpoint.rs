//! Checkpointing: save/restore trainer parameters (and nothing else —
//! optimizer state is reconstructible and the paper's algorithms are
//! robust to EF-memory resets, cf. §A).
//!
//! Format: a minimal self-describing binary —
//! `PSGD1` magic, tensor count, then per tensor: name length/bytes,
//! rank, dims (u64 LE), f32 LE data. No serde offline; 60 lines by hand.

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 5] = b"PSGD1";

/// Write named parameter tensors to `path`.
pub fn save(path: impl AsRef<Path>, named: &[(String, &Tensor)]) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(named.len() as u64).to_le_bytes())?;
    for (name, t) in named {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u64).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&(t.shape().len() as u64).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        // f32 LE payload
        let mut buf = Vec::with_capacity(t.len() * 4);
        for v in t.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    Ok(())
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Load a checkpoint written by [`save`].
pub fn load(path: impl AsRef<Path>) -> Result<Vec<(String, Tensor)>> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut magic = [0u8; 5];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a PowerSGD checkpoint (bad magic)");
    }
    let count = read_u64(&mut f)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let nlen = read_u64(&mut f)? as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).context("checkpoint name not utf8")?;
        let rank = read_u64(&mut f)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut f)? as usize);
        }
        let numel: usize = shape.iter().product();
        let mut buf = vec![0u8; numel * 4];
        f.read_exact(&mut buf)?;
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.push((name, Tensor::from_vec(&shape, data)));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(61);
        let mut a = Tensor::zeros(&[7, 5]);
        rng.fill_normal(a.data_mut(), 1.0);
        let b = Tensor::from_vec(&[3], vec![1.0, -2.0, 3.5]);
        let dir = std::env::temp_dir().join("powersgd_ckpt_test.bin");
        save(&dir, &[("w".to_string(), &a), ("b".to_string(), &b)]).unwrap();
        let loaded = load(&dir).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].0, "w");
        assert_eq!(loaded[0].1, a);
        assert_eq!(loaded[1].1, b);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("powersgd_ckpt_bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
