//! Training metrics: per-step time breakdown, communication volume,
//! loss/accuracy history — the inputs to the paper-style tables and the
//! convergence curves (Figures 4/5).
//!
//! This is the *per-run training* record keeper (losses, times, bytes
//! for one `Trainer`); the crate-wide *run-health* registry — counters,
//! gauges, histograms behind the `--metrics` flag, cluster aggregation,
//! straggler flags — lives in [`crate::obs::metrics`] (DESIGN.md §15).

use crate::util::stats;

/// One training step's record.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    /// 0-based step index.
    pub step: usize,
    /// Mean worker loss this step.
    pub loss: f64,
    /// Measured per-worker gradient computation time (fwd+bwd), seconds.
    pub grad_s: f64,
    /// Measured compression (encode) time, seconds: optimizer wall time
    /// minus the collective and decompress span time, so the three
    /// parts sum back to the measured `opt.step` wall clock.
    pub compress_s: f64,
    /// Measured collective (aggregation) time inside the optimizer
    /// step, seconds — from obs spans, normalized to a per-worker mean
    /// on the threaded engine.
    pub collective_s: f64,
    /// Measured decompression (decode/reconstruct) time, seconds — from
    /// obs spans, same normalization as `collective_s`. Zero on paths
    /// without dedicated decompress spans (the centralized oracle folds
    /// decode into `compress_s`).
    pub decompress_s: f64,
    /// Per-worker bytes transmitted this step.
    pub bytes: u64,
    /// Simulated network busy time on the configured cluster, seconds.
    pub sim_comm_s: f64,
    /// Simulated end-to-end step time (compute + exposed communication;
    /// the threaded engine overlaps bucketed collectives with backprop),
    /// seconds. The encode/decode phases it folds in come from the
    /// span-based split, so the in-memory execution of the collectives
    /// is priced once, by the cluster model (see `Trainer::train_step`).
    pub sim_step_s: f64,
    /// Learning rate used this step.
    pub lr: f64,
}

/// Accumulated run metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Per-step records, in order.
    pub steps: Vec<StepRecord>,
    /// (step, eval metric) pairs; meaning depends on the task
    /// (accuracy for classification, perplexity for LM).
    pub evals: Vec<(usize, f64)>,
}

impl Metrics {
    /// Append one step record.
    pub fn record(&mut self, r: StepRecord) {
        self.steps.push(r);
    }

    /// Append one evaluation result.
    pub fn record_eval(&mut self, step: usize, value: f64) {
        self.evals.push((step, value));
    }

    /// Total per-worker bytes transmitted over the run.
    pub fn total_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.bytes).sum()
    }

    /// Mean loss over the last `n` steps.
    pub fn mean_loss_last(&self, n: usize) -> f64 {
        let tail: Vec<f64> =
            self.steps.iter().rev().take(n).map(|s| s.loss).collect();
        stats::mean(&tail)
    }

    /// Most recent evaluation value, if any.
    pub fn last_eval(&self) -> Option<f64> {
        self.evals.last().map(|&(_, v)| v)
    }

    /// Best evaluation value over the run.
    pub fn best_eval(&self, higher_is_better: bool) -> Option<f64> {
        let vals: Vec<f64> = self.evals.iter().map(|&(_, v)| v).collect();
        if vals.is_empty() {
            return None;
        }
        Some(if higher_is_better { stats::max(&vals) } else { stats::min(&vals) })
    }

    /// Mean measured per-step times (grad, compress, collective,
    /// decompress) in seconds.
    pub fn mean_times(&self) -> (f64, f64, f64, f64) {
        let g: Vec<f64> = self.steps.iter().map(|s| s.grad_s).collect();
        let c: Vec<f64> = self.steps.iter().map(|s| s.compress_s).collect();
        let a: Vec<f64> = self.steps.iter().map(|s| s.collective_s).collect();
        let d: Vec<f64> = self.steps.iter().map(|s| s.decompress_s).collect();
        (stats::mean(&g), stats::mean(&c), stats::mean(&a), stats::mean(&d))
    }

    /// Mean simulated communication time per step, seconds.
    pub fn mean_sim_comm(&self) -> f64 {
        let c: Vec<f64> = self.steps.iter().map(|s| s.sim_comm_s).collect();
        stats::mean(&c)
    }

    /// Mean simulated end-to-end step time, seconds.
    pub fn mean_sim_step(&self) -> f64 {
        let c: Vec<f64> = self.steps.iter().map(|s| s.sim_step_s).collect();
        stats::mean(&c)
    }

    /// Render the loss curve as step/loss CSV (`train --loss-curve`).
    pub fn loss_curve_csv(&self, every: usize) -> String {
        let mut out = String::from("step,loss\n");
        for r in self.steps.iter().filter(|r| r.step % every == 0) {
            out.push_str(&format!("{},{:.5}\n", r.step, r.loss));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f64) -> StepRecord {
        StepRecord {
            step,
            loss,
            grad_s: 0.01,
            compress_s: 0.002,
            collective_s: 0.0005,
            decompress_s: 0.0003,
            bytes: 100,
            sim_comm_s: 0.001,
            sim_step_s: 0.013,
            lr: 0.1,
        }
    }

    #[test]
    fn accumulates() {
        let mut m = Metrics::default();
        m.record(rec(0, 2.0));
        m.record(rec(1, 1.0));
        assert_eq!(m.total_bytes(), 200);
        assert!((m.mean_loss_last(2) - 1.5).abs() < 1e-12);
        assert!((m.mean_loss_last(1) - 1.0).abs() < 1e-12);
        let (g, c, a, d) = m.mean_times();
        assert!((g - 0.01).abs() < 1e-12 && (c - 0.002).abs() < 1e-12);
        assert!((a - 0.0005).abs() < 1e-12 && (d - 0.0003).abs() < 1e-12);
    }

    #[test]
    fn evals_and_best() {
        let mut m = Metrics::default();
        m.record_eval(10, 0.7);
        m.record_eval(20, 0.9);
        m.record_eval(30, 0.85);
        assert_eq!(m.last_eval(), Some(0.85));
        assert_eq!(m.best_eval(true), Some(0.9));
        assert_eq!(m.best_eval(false), Some(0.7));
    }

    #[test]
    fn csv_subsamples() {
        let mut m = Metrics::default();
        for s in 0..10 {
            m.record(rec(s, s as f64));
        }
        let csv = m.loss_curve_csv(5);
        assert_eq!(csv.lines().count(), 3); // header + steps 0,5
    }
}
