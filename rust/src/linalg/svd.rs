//! One-sided Jacobi singular value decomposition.
//!
//! Needed as a *substrate* for two of the paper's baselines:
//! Spectral Atomo (Appendix G.6, importance-samples singular components)
//! and the "best rank-r approximation" reference used in Table 2 and
//! §4.2's cost comparison (SVD 673 ms vs PowerSGD step 105 ms — our
//! `kernel_hotpath` bench reproduces the ordering with this code).
//!
//! One-sided Jacobi orthogonalizes the columns of a working copy of `A`
//! by a sequence of Givens rotations; converged column norms are the
//! singular values, the rotated columns are `U·Σ`, and the accumulated
//! rotations form `V`. It is simple, dependency-free, and accurate for
//! the moderate matrix sizes gradients produce.
//!
//! The returned factors are **polished** through the same fused
//! [`gram_schmidt_in_place`](crate::linalg::gram_schmidt_in_place)
//! path the compression hot loop uses: the Jacobi sweep stops at a
//! residual tolerance (or the sweep cap), which leaves `UᵀU` off the
//! identity by up to that residual on clustered spectra — the MGS pass
//! pins [`orthonormal_error`](crate::linalg::orthonormal_error) to f32
//! rounding regardless, while leaving the singular values untouched
//! and perturbing the subspaces only at the defect's own magnitude
//! (regression-pinned by `fused_gs_polish_pins_orthonormal_error`).

use crate::tensor::Tensor;

/// Full (thin) SVD result: `A ≈ U · diag(s) · Vᵀ`, singular values sorted
/// in descending order. `U` is `n×k`, `V` is `m×k` with `k = min(n, m)`.
#[derive(Debug, Clone)]
pub struct Svd {
    /// Left singular vectors, `n×k`.
    pub u: Tensor,
    /// Singular values, descending.
    pub s: Vec<f32>,
    /// Right singular vectors, `m×k`.
    pub v: Tensor,
}

/// One-sided Jacobi SVD of `a` (`n×m`). For `n < m` we decompose `Aᵀ` and
/// swap the factors, keeping the working matrix tall. Factors are
/// polished through the fused Gram–Schmidt path (module docs).
pub fn svd(a: &Tensor) -> Svd {
    svd_impl(a, true)
}

/// `polish = false` skips the Gram–Schmidt factor polish — only the
/// regression test uses it, to measure the raw Jacobi defect.
fn svd_impl(a: &Tensor, polish: bool) -> Svd {
    let (n, m) = (a.rows(), a.cols());
    if n < m {
        let t = svd_impl(&a.transpose(), polish);
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    let k = m;
    // Column-major working copy of A (each column contiguous).
    let mut w = vec![0.0f64; n * m];
    for i in 0..n {
        for j in 0..m {
            w[j * n + i] = a.at(i, j) as f64;
        }
    }
    // V accumulator, column-major m×m.
    let mut v = vec![0.0f64; m * m];
    for j in 0..m {
        v[j * m + j] = 1.0;
    }

    let max_sweeps = 60;
    let tol = 1e-12;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..m {
            for q in (p + 1)..m {
                // 2x2 Gram block of columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..n {
                    let wp = w[p * n + i];
                    let wq = w[q * n + i];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                // Degenerate (zero) columns carry no rotation work; skip
                // them to avoid 0/0 NaNs on near-zero matrices.
                if apq == 0.0 || app == 0.0 || aqq == 0.0 {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                if apq.abs() <= tol * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing the off-diagonal Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let wp = w[p * n + i];
                    let wq = w[q * n + i];
                    w[p * n + i] = c * wp - s * wq;
                    w[q * n + i] = s * wp + c * wq;
                }
                for i in 0..m {
                    let vp = v[p * m + i];
                    let vq = v[q * m + i];
                    v[p * m + i] = c * vp - s * vq;
                    v[q * m + i] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-10 {
            break;
        }
    }

    // Extract singular values (column norms) and normalize U's columns.
    let mut order: Vec<usize> = (0..k).collect();
    let norms: Vec<f64> = (0..k)
        .map(|j| (0..n).map(|i| w[j * n + i] * w[j * n + i]).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&a, &b| norms[b].total_cmp(&norms[a]));

    let mut u = Tensor::zeros(&[n, k]);
    let mut vt = Tensor::zeros(&[m, k]);
    let mut s = Vec::with_capacity(k);
    for (col, &j) in order.iter().enumerate() {
        let nj = norms[j];
        s.push(nj as f32);
        let inv = if nj > 1e-300 { 1.0 / nj } else { 0.0 };
        for i in 0..n {
            u.set(i, col, (w[j * n + i] * inv) as f32);
        }
        for i in 0..m {
            vt.set(i, col, v[j * m + i] as f32);
        }
    }
    let mut out = Svd { u, s, v: vt };
    if polish {
        // Route both factors through the fused Gram–Schmidt kernel —
        // the same code path (and determinism contract) as the
        // PowerSGD step itself. Exactly-zero columns (singular value
        // below the extraction floor) are zeroed again by GS's
        // rank-deficiency policy, never inflated.
        crate::linalg::gram_schmidt_in_place(&mut out.u);
        crate::linalg::gram_schmidt_in_place(&mut out.v);
    }
    out
}

impl Svd {
    /// Reconstruct `U · diag(s) · Vᵀ` (for tests and rank-truncation).
    pub fn reconstruct(&self, rank: usize) -> Tensor {
        let n = self.u.rows();
        let m = self.v.rows();
        let k = rank.min(self.s.len());
        let mut out = Tensor::zeros(&[n, m]);
        let od = out.data_mut();
        for c in 0..k {
            let sc = self.s[c];
            if sc == 0.0 {
                continue;
            }
            for i in 0..n {
                let ui = self.u.at(i, c) * sc;
                if ui == 0.0 {
                    continue;
                }
                for j in 0..m {
                    od[i * m + j] += ui * self.v.at(j, c);
                }
            }
        }
        out
    }
}

/// Best rank-`r` approximation of `a` (Eckart–Young via the Jacobi SVD).
pub fn best_rank_r(a: &Tensor, r: usize) -> Tensor {
    svd(a).reconstruct(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::Rng;

    fn random(shape: &[usize], rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn reconstructs_full_rank() {
        let mut rng = Rng::new(31);
        for &(n, m) in &[(4, 4), (10, 6), (6, 10), (33, 17)] {
            let a = random(&[n, m], &mut rng);
            let d = svd(&a);
            let rec = d.reconstruct(n.min(m));
            assert!(
                rec.allclose(&a, 1e-3, 1e-3),
                "n={n} m={m} max diff {}",
                rec.max_abs_diff(&a)
            );
        }
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut rng = Rng::new(32);
        let a = random(&[20, 12], &mut rng);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(d.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn u_and_v_orthonormal() {
        use crate::linalg::orthonormal_error;
        let mut rng = Rng::new(33);
        let a = random(&[25, 9], &mut rng);
        let d = svd(&a);
        assert!(orthonormal_error(&d.u) < 1e-4, "U err {}", orthonormal_error(&d.u));
        assert!(orthonormal_error(&d.v) < 1e-4, "V err {}", orthonormal_error(&d.v));
    }

    #[test]
    fn recovers_known_low_rank() {
        // A = x yᵀ has exactly one nonzero singular value = |x||y|.
        let mut rng = Rng::new(34);
        let x = random(&[15, 1], &mut rng);
        let y = random(&[8, 1], &mut rng);
        let a = matmul(&x, &y.transpose());
        let d = svd(&a);
        let expect = (x.norm() * y.norm()) as f32;
        assert!((d.s[0] - expect).abs() / expect < 1e-4);
        for &s in &d.s[1..] {
            assert!(s < 1e-4 * expect, "tail sv {s}");
        }
        let rec = d.reconstruct(1);
        assert!(rec.allclose(&a, 1e-3, 1e-4));
    }

    #[test]
    fn eckart_young_beats_random_projection() {
        // Truncated-SVD error must not exceed the error of projecting onto
        // random columns (sanity for best_rank_r).
        let mut rng = Rng::new(35);
        let a = random(&[30, 20], &mut rng);
        let r = 3;
        let best = best_rank_r(&a, r);
        let err_best = a.sub(&best).norm();
        // Random rank-3: MQ(QᵀQ)⁻¹Qᵀ approximated via GS-orthonormal Q.
        let mut q = random(&[20, r], &mut rng);
        crate::linalg::gram_schmidt_in_place(&mut q);
        let p = matmul(&a, &q);
        let approx = matmul(&p, &q.transpose());
        let err_rand = a.sub(&approx).norm();
        assert!(err_best <= err_rand + 1e-6, "{err_best} vs {err_rand}");
    }

    /// The factor polish (module docs): with polish the orthonormal
    /// error of both factors is pinned to f32 rounding; without it the
    /// raw Jacobi factors are only tolerance-orthonormal. The polish
    /// must never loosen a factor, and must leave singular values and
    /// the reconstruction intact.
    #[test]
    fn fused_gs_polish_pins_orthonormal_error() {
        use crate::linalg::orthonormal_error;
        // f32 rounding pin: MGS leaves residual correlations of order
        // sqrt(n)·eps_f32 ≈ 1e-6 at these sizes; 2e-5 gives slack
        // while sitting far below the suite's 1e-4 working tolerance.
        const PIN: f64 = 2e-5;
        let mut rng = Rng::new(37);
        for &(n, m) in &[(60, 12), (25, 9), (9, 33)] {
            let a = random(&[n, m], &mut rng);
            let raw = svd_impl(&a, false);
            let pol = svd_impl(&a, true);
            for (t, (r, p)) in [(&raw.u, &pol.u), (&raw.v, &pol.v)].into_iter().enumerate() {
                let (er, ep) = (orthonormal_error(r), orthonormal_error(p));
                assert!(ep < PIN, "n={n} m={m} factor={t}: polished err {ep}");
                assert!(
                    ep <= er.max(PIN),
                    "n={n} m={m} factor={t}: polish loosened {er} -> {ep}"
                );
            }
            // Same singular values, same reconstruction (to working
            // tolerance — the polish moves factors only by the raw
            // orthogonality defect).
            assert_eq!(raw.s, pol.s, "n={n} m={m}");
            let k = n.min(m);
            assert!(
                pol.reconstruct(k).allclose(&raw.reconstruct(k), 1e-3, 1e-3),
                "n={n} m={m}"
            );
        }
    }

    #[test]
    fn wide_matrix_transposed_path() {
        let mut rng = Rng::new(36);
        let a = random(&[5, 40], &mut rng);
        let d = svd(&a);
        assert_eq!(d.u.shape(), &[5, 5]);
        assert_eq!(d.v.shape(), &[40, 5]);
        assert!(d.reconstruct(5).allclose(&a, 1e-3, 1e-3));
    }
}
