//! Numerical linear algebra for the compressors.
//!
//! - [`gram_schmidt_in_place`] — the paper's orthogonalization choice ("we use the
//!   Gram–Schmidt procedure to orthogonalize our matrices since they have
//!   very few columns (1–4)").
//! - [`svd`] — one-sided Jacobi SVD, needed by the Spectral-Atomo baseline
//!   (Appendix G.6) and by the "best rank-r approximation" reference
//!   (Table 2 / Appendix G.7 sanity checks).

mod gram_schmidt;
mod svd;

pub use gram_schmidt::{gram_schmidt_in_place, orthonormal_error, reference_gram_schmidt_in_place};
pub use svd::{best_rank_r, svd, Svd};
