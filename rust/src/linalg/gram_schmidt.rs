//! Modified Gram–Schmidt orthogonalization of the columns of a tall
//! matrix `P[n×r]`, in place — fused single-sweep implementation.
//!
//! This is the only non-GEMM compute in a PowerSGD step and, per the
//! paper (§3), "the most expensive part of the compression procedure".
//! Cost is O(n·r²) with r ≤ 32. We use the *modified* variant for
//! numerical stability. Rank-deficient columns are zeroed (see the
//! rationale at the decision site): substituting an arbitrary unit
//! direction instead would hand that direction real mass in the
//! subsequent `Q = MᵀP̂` and corrupt the reconstruction.
//!
//! **Fusion.** The textbook left-looking loop makes ~r² passes over
//! the n×r matrix (per column: one dot + one subtract sweep against
//! every previous column). For n ≫ r that is r² streams of a matrix
//! that doesn't fit in cache — pure memory bandwidth waste. This
//! implementation is the *right-looking* reordering of the exact same
//! arithmetic: once column `col` is normalized, ONE fused sweep
//! normalizes it and computes its dots against all r−col−1 later
//! columns (the row is hot in registers), and one more fused sweep
//! subtracts all those projections. Total ~3r+1 passes instead of
//! ~r². Left- and right-looking MGS perform the identical sequence of
//! per-element operations — when column `col` is processed it has had
//! exactly the projections of columns 0..col−1 subtracted, in order —
//! so the fusion changes no bits (the differential harness pins this
//! against [`reference_gram_schmidt_in_place`]).
//!
//! **Determinism policy (DESIGN.md §11).** Column dots and norms are
//! fixed-chunk reductions: chunks of [`REDUCE_CHUNK`] rows summed
//! serially in f64 (per column, in row order), partials combined in a
//! pairwise tree whose shape depends only on `n` — never on the
//! thread count. Elementwise sweeps shard disjoint row bands with
//! unchanged per-element arithmetic. Together this makes the kernel
//! bitwise identical at every thread count. Versus the serial
//! reference (one f64 stream per reduction), results are `==`-equal
//! for `n ≤ REDUCE_CHUNK` and ULP-bounded beyond — the one documented
//! numerics divergence, pinned by `tests/integration_kernel_equiv.rs`.
//! f64 reduction partials live in per-thread pool scratch
//! ([`with_partials`]) so the steady-state step allocates nothing.
//!
//! [`REDUCE_CHUNK`]: crate::runtime::pool::REDUCE_CHUNK
//! [`with_partials`]: crate::runtime::pool::with_partials

use crate::runtime::pool::{
    deterministic_sum, kernel_backend, parallel_ranges, with_partials, DisjointSlice,
    KernelBackend, REDUCE_CHUNK,
};
use crate::tensor::Tensor;

const EPS: f64 = 1e-30;
/// Residual below this fraction of the original column norm counts as
/// numerically rank-deficient (f32 inputs carry ~1e-7 relative noise).
const REL_TOL: f64 = 1e-4;

/// Minimum rows per parallel band (elementwise sweeps only; the
/// reductions chunk by `REDUCE_CHUNK` regardless).
const MIN_PAR_ROWS: usize = 4096;

/// Column L2 norm of column `col` of row-major `d[n×r]`, via the
/// fixed-chunk deterministic reduction.
fn col_norm(d: &[f32], n: usize, r: usize, col: usize) -> f64 {
    deterministic_sum(n, |i| {
        let v = d[i * r + col] as f64;
        v * v
    })
    .sqrt()
}

/// Orthonormalize the columns of `p` (row-major `n×r`) in place.
/// Bitwise identical at every kernel thread count. Dispatches on the
/// process kernel backend; the blocked path is the fused sweep
/// documented in the module header.
pub fn gram_schmidt_in_place(p: &mut Tensor) {
    let _span = crate::obs::span(crate::obs::Phase::GramSchmidt);
    match kernel_backend() {
        KernelBackend::Reference => reference_gram_schmidt_in_place(p),
        KernelBackend::Blocked => fused_gram_schmidt_in_place(p),
    }
}

/// Textbook serial left-looking modified Gram–Schmidt: per column,
/// one dot + one subtract pass against each previous column, every
/// reduction a single serial f64 stream. The executable specification
/// for the fused kernel — same rank-deficiency policy, no fusion, no
/// chunked reductions, no pool. Used by the differential harness and
/// the naive side of the kernel benches.
pub fn reference_gram_schmidt_in_place(p: &mut Tensor) {
    let (n, r) = (p.rows(), p.cols());
    let d = p.data_mut();
    for col in 0..r {
        let orig = serial_col_norm(d, n, r, col);
        for prev in 0..col {
            let mut dot = 0.0f64;
            for i in 0..n {
                dot += d[i * r + col] as f64 * d[i * r + prev] as f64;
            }
            let dot = dot as f32;
            for i in 0..n {
                d[i * r + col] -= dot * d[i * r + prev];
            }
        }
        let norm = serial_col_norm(d, n, r, col);
        if norm <= REL_TOL * orig + EPS {
            for i in 0..n {
                d[i * r + col] = 0.0;
            }
        } else {
            let inv = (1.0 / norm) as f32;
            for i in 0..n {
                d[i * r + col] *= inv;
            }
        }
    }
}

/// Single-stream serial f64 column norm (reference reduction).
fn serial_col_norm(d: &[f32], n: usize, r: usize, col: usize) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..n {
        let v = d[i * r + col] as f64;
        acc += v * v;
    }
    acc.sqrt()
}

/// The fused right-looking sweep (module docs). Layout of the
/// per-thread f64 scratch: `chunks·r` chunk partials, then `r`
/// original norms, then `r` projection dots.
fn fused_gram_schmidt_in_place(p: &mut Tensor) {
    let (n, r) = (p.rows(), p.cols());
    if r == 0 {
        return;
    }
    let d = p.data_mut();
    let chunks = n.div_ceil(REDUCE_CHUNK);
    with_partials(chunks * r + 2 * r, |buf| {
        let (chunk_part, rest) = buf.split_at_mut(chunks * r);
        let (orig, dots) = rest.split_at_mut(r);
        // One fused pass for all r original norms — the yardsticks for
        // the rank-deficiency decision. Identical bits to computing
        // col_norm per column up front (per-column chunk chains and
        // pairwise trees are per-column anyway).
        fused_col_squares(d, n, r, chunk_part, orig);
        for o in orig.iter_mut() {
            *o = o.sqrt();
        }
        for col in 0..r {
            // Projections of columns 0..col have already been swept
            // out (right-looking), so this is the residual norm the
            // left-looking loop would see here.
            let norm = col_norm(d, n, r, col);
            // A column whose residual collapsed relative to its
            // original norm is numerically inside the span of the
            // previous columns. It MUST be zeroed, not normalized: the
            // residual is f32 cancellation noise *correlated with the
            // span*, and dividing by its tiny norm manufactures a unit
            // direction with O(1/sqrt(n)) overlap onto the data —
            // `Q = M^T P_hat` then hands it real mass and injects a
            // spurious rank-1 term into the reconstruction (breaks
            // exactly low-rank gradients; observable as 0.9 relative
            // error at rank 8 on rank-1 inputs). Later columns skip
            // their dot/subtract against it — on finite data those are
            // exact no-ops (dot of anything with an all-zero column is
            // +0.0; subtracting 0·0 changes no bits).
            if norm <= REL_TOL * orig[col] + EPS {
                set_col(d, n, r, col, |_| 0.0);
                continue;
            }
            let inv = (1.0 / norm) as f32;
            let w = r - col - 1;
            if w == 0 {
                set_col(d, n, r, col, move |v| v * inv);
            } else {
                normalize_and_dots(d, n, r, col, inv, &mut chunk_part[..chunks * w], &mut dots[..w]);
                subtract_projections(d, n, r, col, &dots[..w]);
            }
        }
    });
}

/// Fused squared-norm reduction for all `r` columns: fixed
/// `REDUCE_CHUNK`-row chunks, per-column serial f64 chains, per-column
/// pairwise combine — `out[c]` equals `deterministic_sum` of column
/// c's squares bit for bit.
fn fused_col_squares(d: &[f32], n: usize, r: usize, chunk_part: &mut [f64], out: &mut [f64]) {
    let chunks = n.div_ceil(REDUCE_CHUNK);
    chunk_part[..chunks * r].fill(0.0);
    {
        let slots = DisjointSlice::new(&mut chunk_part[..chunks * r]);
        parallel_ranges(chunks, 1, move |c0, c1| {
            // SAFETY: chunk ranges are disjoint across tasks.
            let part = unsafe { slots.range_mut(c0 * r, c1 * r) };
            for ch in c0..c1 {
                let base = (ch - c0) * r;
                let start = ch * REDUCE_CHUNK;
                let end = ((ch + 1) * REDUCE_CHUNK).min(n);
                for i in start..end {
                    let row = &d[i * r..(i + 1) * r];
                    for (acc, &v) in part[base..base + r].iter_mut().zip(row.iter()) {
                        let v = v as f64;
                        *acc += v * v;
                    }
                }
            }
        });
    }
    for (c, o) in out.iter_mut().enumerate() {
        *o = pairwise_strided(chunk_part, 0, chunks, r, c);
    }
}

/// Pairwise (tree) combine of `part[ch·stride + off]` for
/// `ch ∈ [lo, hi)` — the same tree shape as the pool's `pairwise_sum`
/// over a contiguous partial slice of length `hi − lo`.
fn pairwise_strided(part: &[f64], lo: usize, hi: usize, stride: usize, off: usize) -> f64 {
    match hi - lo {
        0 => 0.0,
        1 => part[lo * stride + off],
        len => {
            let mid = lo + len / 2;
            pairwise_strided(part, lo, mid, stride, off)
                + pairwise_strided(part, mid, hi, stride, off)
        }
    }
}

/// The first fused sweep for column `col`: write the normalized value
/// `x̂ = x·inv` and accumulate `⟨x̂, later⟩` partials for every later
/// column in the same pass over the rows. Per-column reduction chains
/// and per-element writes are identical to the unfused normalize +
/// per-column deterministic dots.
fn normalize_and_dots(
    d: &mut [f32],
    n: usize,
    r: usize,
    col: usize,
    inv: f32,
    chunk_part: &mut [f64],
    dots: &mut [f64],
) {
    let chunks = n.div_ceil(REDUCE_CHUNK);
    let w = r - col - 1;
    chunk_part[..chunks * w].fill(0.0);
    {
        let rows = DisjointSlice::new(&mut *d);
        let slots = DisjointSlice::new(&mut chunk_part[..chunks * w]);
        parallel_ranges(chunks, 1, move |c0, c1| {
            // SAFETY: chunk ranges are disjoint across tasks, in both
            // the row bands and the partial slots.
            let part = unsafe { slots.range_mut(c0 * w, c1 * w) };
            for ch in c0..c1 {
                let base = (ch - c0) * w;
                let start = ch * REDUCE_CHUNK;
                let end = ((ch + 1) * REDUCE_CHUNK).min(n);
                let band = unsafe { rows.range_mut(start * r, end * r) };
                for ii in 0..(end - start) {
                    let row = &mut band[ii * r..(ii + 1) * r];
                    let x = row[col] * inv;
                    row[col] = x;
                    let xf = x as f64;
                    for (acc, &v) in part[base..base + w].iter_mut().zip(row[col + 1..].iter()) {
                        *acc += xf * v as f64;
                    }
                }
            }
        });
    }
    for (k, dk) in dots.iter_mut().enumerate() {
        *dk = pairwise_strided(chunk_part, 0, chunks, w, k);
    }
}

/// The second fused sweep for column `col`: subtract every later
/// column's projection onto the (now unit) column in one pass.
/// Per-element arithmetic matches the unfused per-column subtract —
/// `later −= (dot as f32)·x̂`, with `col`'s own value untouched.
fn subtract_projections(d: &mut [f32], n: usize, r: usize, col: usize, dots: &[f64]) {
    let w = dots.len();
    let rows = DisjointSlice::new(d);
    parallel_ranges(n, MIN_PAR_ROWS, move |i0, i1| {
        // SAFETY: row bands are disjoint across tasks.
        let band = unsafe { rows.range_mut(i0 * r, i1 * r) };
        for ii in 0..(i1 - i0) {
            let row = &mut band[ii * r..(ii + 1) * r];
            let x = row[col];
            for (v, &dk) in row[col + 1..col + 1 + w].iter_mut().zip(dots.iter()) {
                *v -= (dk as f32) * x;
            }
        }
    });
}

/// Overwrite every element of column `col` with `f(old)`, sharded over
/// disjoint row bands.
fn set_col(d: &mut [f32], n: usize, r: usize, col: usize, f: impl Fn(f32) -> f32 + Sync) {
    let rows = DisjointSlice::new(d);
    parallel_ranges(n, MIN_PAR_ROWS, move |i0, i1| {
        // SAFETY: row bands are disjoint across tasks.
        let band = unsafe { rows.range_mut(i0 * r, i1 * r) };
        for ii in 0..(i1 - i0) {
            let x = &mut band[ii * r + col];
            *x = f(*x);
        }
    });
}

/// Max deviation of `PᵀP` from the identity — 0 for perfectly orthonormal
/// columns. Used by tests and the property suite.
pub fn orthonormal_error(p: &Tensor) -> f64 {
    let (n, r) = (p.rows(), p.cols());
    let d = p.data();
    let mut worst = 0.0f64;
    for a in 0..r {
        for b in a..r {
            let mut dot = 0.0f64;
            for i in 0..n {
                dot += d[i * r + a] as f64 * d[i * r + b] as f64;
            }
            let target = if a == b { 1.0 } else { 0.0 };
            worst = worst.max((dot - target).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pool::{set_threads, test_guard};
    use crate::util::Rng;

    #[test]
    fn orthonormalizes_random_matrices() {
        let mut rng = Rng::new(21);
        for &(n, r) in &[(4, 1), (16, 2), (100, 4), (513, 8), (40, 16)] {
            let mut p = Tensor::zeros(&[n, r]);
            rng.fill_normal(p.data_mut(), 1.0);
            gram_schmidt_in_place(&mut p);
            let err = orthonormal_error(&p);
            assert!(err < 1e-4, "n={n} r={r} err={err}");
        }
    }

    #[test]
    fn preserves_column_span() {
        // After GS, the first column must be parallel to the original first
        // column.
        let mut rng = Rng::new(22);
        let mut p = Tensor::zeros(&[50, 3]);
        rng.fill_normal(p.data_mut(), 1.0);
        let orig_col0: Vec<f32> = (0..50).map(|i| p.at(i, 0)).collect();
        gram_schmidt_in_place(&mut p);
        let new_col0: Vec<f32> = (0..50).map(|i| p.at(i, 0)).collect();
        let dot: f64 = orig_col0.iter().zip(&new_col0).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let norm: f64 = orig_col0.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
        assert!((dot.abs() - norm).abs() / norm < 1e-4);
    }

    #[test]
    fn handles_rank_deficiency() {
        // Two identical columns: first normalizes, the duplicate's
        // residual must stay near zero (NOT become an arbitrary unit
        // vector) so it contributes nothing downstream.
        let mut p = Tensor::zeros(&[10, 2]);
        for i in 0..10 {
            p.set(i, 0, 1.0);
            p.set(i, 1, 1.0);
        }
        gram_schmidt_in_place(&mut p);
        assert!(p.data().iter().all(|v| v.is_finite()));
        let col1_norm: f64 =
            (0..10).map(|i| (p.at(i, 1) as f64).powi(2)).sum::<f64>().sqrt();
        assert!(col1_norm < 0.1, "degenerate column should stay small: {col1_norm}");
        // first column is unit
        let col0_norm: f64 =
            (0..10).map(|i| (p.at(i, 0) as f64).powi(2)).sum::<f64>().sqrt();
        assert!((col0_norm - 1.0).abs() < 1e-4);
    }

    #[test]
    fn zero_matrix_stays_zero() {
        let mut p = Tensor::zeros(&[8, 2]);
        gram_schmidt_in_place(&mut p);
        assert!(p.data().iter().all(|v| v.is_finite()));
        assert!(p.norm() < 1e-3, "zero input must stay ~zero");
    }

    /// Unit-scale determinism check, including an `n > REDUCE_CHUNK`
    /// shape that exercises the multi-chunk pairwise reduction. The
    /// full sweep over paper shapes lives in
    /// `tests/integration_kernels.rs`.
    #[test]
    fn parallel_gram_schmidt_bitwise_matches_serial() {
        let _g = test_guard();
        let mut rng = Rng::new(23);
        for &(n, r) in &[(1, 1), (513, 4), (9000, 3)] {
            let mut p0 = Tensor::zeros(&[n, r]);
            rng.fill_normal(p0.data_mut(), 1.0);
            set_threads(1);
            let mut want = p0.clone();
            gram_schmidt_in_place(&mut want);
            for t in [2usize, 4, 8] {
                set_threads(t);
                let mut got = p0.clone();
                gram_schmidt_in_place(&mut got);
                assert_eq!(got.data(), want.data(), "n={n} r={r} t={t}");
            }
        }
    }

    /// The fusion is a pure reordering: for `n ≤ REDUCE_CHUNK` (where
    /// the chunked reductions degenerate to one serial stream) the
    /// fused kernel equals the textbook serial reference on every
    /// element, including rank-deficient inputs. Both implementations
    /// are called directly — the dispatch path is the harness's job.
    #[test]
    fn fused_equals_reference_below_one_chunk() {
        let mut rng = Rng::new(24);
        for &(n, r) in &[(1, 1), (10, 2), (100, 4), (513, 8), (4096, 3)] {
            let mut p = Tensor::zeros(&[n, r]);
            rng.fill_normal(p.data_mut(), 1.0);
            let mut fused = p.clone();
            fused_gram_schmidt_in_place(&mut fused);
            reference_gram_schmidt_in_place(&mut p);
            assert_eq!(fused.data(), p.data(), "n={n} r={r}");
        }
        // Rank-deficient *middle* column: column 1 duplicates column 0
        // and gets zeroed, so column 2 exercises the fused skip versus
        // the reference's dot-against-zero no-op.
        let mut p = Tensor::zeros(&[64, 3]);
        let mut rng2 = Rng::new(25);
        rng2.fill_normal(p.data_mut(), 1.0);
        for i in 0..64 {
            let v = p.at(i, 0);
            p.set(i, 1, v);
        }
        let mut fused = p.clone();
        fused_gram_schmidt_in_place(&mut fused);
        reference_gram_schmidt_in_place(&mut p);
        assert_eq!(fused.data(), p.data(), "rank-deficient middle column");
        // All-zero input: every column takes the zeroing path.
        let mut z = Tensor::zeros(&[32, 4]);
        let mut zf = z.clone();
        fused_gram_schmidt_in_place(&mut zf);
        reference_gram_schmidt_in_place(&mut z);
        assert_eq!(zf.data(), z.data(), "all-zero");
        assert!(zf.data().iter().all(|&v| v == 0.0));
    }

    /// Above one chunk the reductions differ (chunked pairwise vs one
    /// serial stream) — the documented ULP-level divergence. Tight
    /// tolerance here; the harness pins the bound across shapes.
    #[test]
    fn fused_vs_reference_above_one_chunk_is_ulp_close() {
        let mut rng = Rng::new(26);
        let mut p = Tensor::zeros(&[REDUCE_CHUNK + 777, 4]);
        rng.fill_normal(p.data_mut(), 1.0);
        let mut fused = p.clone();
        fused_gram_schmidt_in_place(&mut fused);
        reference_gram_schmidt_in_place(&mut p);
        assert!(
            fused.allclose(&p, 1e-6, 1e-6),
            "max diff {}",
            fused.max_abs_diff(&p)
        );
        assert!(orthonormal_error(&fused) < 1e-4);
    }
}
