//! Modified Gram–Schmidt orthogonalization of the columns of a tall
//! matrix `P[n×r]`, in place.
//!
//! This is the only non-GEMM compute in a PowerSGD step and, per the
//! paper (§3), "the most expensive part of the compression procedure".
//! Cost is O(n·r²) with r ≤ 32. We use the *modified* variant for
//! numerical stability. Rank-deficient columns are normalized by
//! (norm + ε) and stay near zero, matching the reference implementation
//! (epfml/powersgd `orthogonalize`): substituting an arbitrary unit
//! direction instead would hand that direction real mass in the
//! subsequent `Q = MᵀP̂` and corrupt the reconstruction.
//!
//! **Determinism policy (DESIGN.md §11).** The column dots and norms
//! are [`deterministic_sum`] reductions: fixed chunks of
//! [`REDUCE_CHUNK`] rows summed serially in f64, partials combined in a
//! pairwise tree whose shape depends only on `n` — never on the thread
//! count. The projection/normalization sweeps shard disjoint row bands
//! with unchanged per-element arithmetic. Together this makes the
//! kernel bitwise identical at every thread count. Adopting the fixed
//! chunking changed the serial numerics *once* (only for `n >
//! REDUCE_CHUNK`, where the old code summed all `n` rows in one f64
//! stream); no pinned golden in the repo depends on those bits — every
//! equivalence suite compares two paths running this same kernel, and
//! accuracy tests use tolerances.
//!
//! [`deterministic_sum`]: crate::runtime::pool::deterministic_sum
//! [`REDUCE_CHUNK`]: crate::runtime::pool::REDUCE_CHUNK

use crate::runtime::pool::{deterministic_sum, parallel_ranges, DisjointSlice};
use crate::tensor::Tensor;

const EPS: f64 = 1e-30;
/// Residual below this fraction of the original column norm counts as
/// numerically rank-deficient (f32 inputs carry ~1e-7 relative noise).
const REL_TOL: f64 = 1e-4;

/// Minimum rows per parallel band (elementwise sweeps only; the
/// reductions chunk by `REDUCE_CHUNK` regardless).
const MIN_PAR_ROWS: usize = 4096;

/// Column L2 norm of column `col` of row-major `d[n×r]`, via the
/// fixed-chunk deterministic reduction.
fn col_norm(d: &[f32], n: usize, r: usize, col: usize) -> f64 {
    deterministic_sum(n, |i| {
        let v = d[i * r + col] as f64;
        v * v
    })
    .sqrt()
}

/// Orthonormalize the columns of `p` (row-major `n×r`) in place.
/// Bitwise identical at every kernel thread count.
pub fn gram_schmidt_in_place(p: &mut Tensor) {
    let _span = crate::obs::span(crate::obs::Phase::GramSchmidt);
    let (n, r) = (p.rows(), p.cols());
    let d = p.data_mut();
    for col in 0..r {
        // Original column norm: the yardstick for numerical dependence.
        let orig = col_norm(d, n, r, col);
        // Subtract projections onto the previous (already orthonormal) cols.
        for prev in 0..col {
            let dot = {
                let dd: &[f32] = d;
                deterministic_sum(n, |i| dd[i * r + col] as f64 * dd[i * r + prev] as f64) as f32
            };
            let rows = DisjointSlice::new(&mut *d);
            parallel_ranges(n, MIN_PAR_ROWS, move |i0, i1| {
                // SAFETY: row bands are disjoint across tasks; each
                // element reads only its own row.
                let band = unsafe { rows.range_mut(i0 * r, i1 * r) };
                for ii in 0..(i1 - i0) {
                    band[ii * r + col] -= dot * band[ii * r + prev];
                }
            });
        }
        let norm = col_norm(d, n, r, col);
        // A column whose residual collapsed relative to its original norm
        // is numerically inside the span of the previous columns. It MUST
        // be zeroed, not normalized: the residual is f32 cancellation
        // noise *correlated with the span*, and dividing by its tiny norm
        // manufactures a unit direction with O(1/sqrt(n)) overlap onto the
        // data — `Q = M^T P_hat` then hands it real mass and injects a
        // spurious rank-1 term into the reconstruction (breaks exactly
        // low-rank gradients; observable as 0.9 relative error at rank 8
        // on rank-1 inputs).
        if norm <= REL_TOL * orig + EPS {
            set_col(d, n, r, col, |_| 0.0);
        } else {
            let inv = (1.0 / norm) as f32;
            set_col(d, n, r, col, move |v| v * inv);
        }
    }
}

/// Overwrite every element of column `col` with `f(old)`, sharded over
/// disjoint row bands.
fn set_col(d: &mut [f32], n: usize, r: usize, col: usize, f: impl Fn(f32) -> f32 + Sync) {
    let rows = DisjointSlice::new(d);
    parallel_ranges(n, MIN_PAR_ROWS, move |i0, i1| {
        // SAFETY: row bands are disjoint across tasks.
        let band = unsafe { rows.range_mut(i0 * r, i1 * r) };
        for ii in 0..(i1 - i0) {
            let x = &mut band[ii * r + col];
            *x = f(*x);
        }
    });
}

/// Max deviation of `PᵀP` from the identity — 0 for perfectly orthonormal
/// columns. Used by tests and the property suite.
pub fn orthonormal_error(p: &Tensor) -> f64 {
    let (n, r) = (p.rows(), p.cols());
    let d = p.data();
    let mut worst = 0.0f64;
    for a in 0..r {
        for b in a..r {
            let mut dot = 0.0f64;
            for i in 0..n {
                dot += d[i * r + a] as f64 * d[i * r + b] as f64;
            }
            let target = if a == b { 1.0 } else { 0.0 };
            worst = worst.max((dot - target).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pool::{set_threads, test_guard};
    use crate::util::Rng;

    #[test]
    fn orthonormalizes_random_matrices() {
        let mut rng = Rng::new(21);
        for &(n, r) in &[(4, 1), (16, 2), (100, 4), (513, 8), (40, 16)] {
            let mut p = Tensor::zeros(&[n, r]);
            rng.fill_normal(p.data_mut(), 1.0);
            gram_schmidt_in_place(&mut p);
            let err = orthonormal_error(&p);
            assert!(err < 1e-4, "n={n} r={r} err={err}");
        }
    }

    #[test]
    fn preserves_column_span() {
        // After GS, the first column must be parallel to the original first
        // column.
        let mut rng = Rng::new(22);
        let mut p = Tensor::zeros(&[50, 3]);
        rng.fill_normal(p.data_mut(), 1.0);
        let orig_col0: Vec<f32> = (0..50).map(|i| p.at(i, 0)).collect();
        gram_schmidt_in_place(&mut p);
        let new_col0: Vec<f32> = (0..50).map(|i| p.at(i, 0)).collect();
        let dot: f64 = orig_col0.iter().zip(&new_col0).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let norm: f64 = orig_col0.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
        assert!((dot.abs() - norm).abs() / norm < 1e-4);
    }

    #[test]
    fn handles_rank_deficiency() {
        // Two identical columns: first normalizes, the duplicate's
        // residual must stay near zero (NOT become an arbitrary unit
        // vector) so it contributes nothing downstream.
        let mut p = Tensor::zeros(&[10, 2]);
        for i in 0..10 {
            p.set(i, 0, 1.0);
            p.set(i, 1, 1.0);
        }
        gram_schmidt_in_place(&mut p);
        assert!(p.data().iter().all(|v| v.is_finite()));
        let col1_norm: f64 =
            (0..10).map(|i| (p.at(i, 1) as f64).powi(2)).sum::<f64>().sqrt();
        assert!(col1_norm < 0.1, "degenerate column should stay small: {col1_norm}");
        // first column is unit
        let col0_norm: f64 =
            (0..10).map(|i| (p.at(i, 0) as f64).powi(2)).sum::<f64>().sqrt();
        assert!((col0_norm - 1.0).abs() < 1e-4);
    }

    #[test]
    fn zero_matrix_stays_zero() {
        let mut p = Tensor::zeros(&[8, 2]);
        gram_schmidt_in_place(&mut p);
        assert!(p.data().iter().all(|v| v.is_finite()));
        assert!(p.norm() < 1e-3, "zero input must stay ~zero");
    }

    /// Unit-scale determinism check, including an `n > REDUCE_CHUNK`
    /// shape that exercises the multi-chunk pairwise reduction. The
    /// full sweep over paper shapes lives in
    /// `tests/integration_kernels.rs`.
    #[test]
    fn parallel_gram_schmidt_bitwise_matches_serial() {
        let _g = test_guard();
        let mut rng = Rng::new(23);
        for &(n, r) in &[(1, 1), (513, 4), (9000, 3)] {
            let mut p0 = Tensor::zeros(&[n, r]);
            rng.fill_normal(p0.data_mut(), 1.0);
            set_threads(1);
            let mut want = p0.clone();
            gram_schmidt_in_place(&mut want);
            for t in [2usize, 4, 8] {
                set_threads(t);
                let mut got = p0.clone();
                gram_schmidt_in_place(&mut got);
                assert_eq!(got.data(), want.data(), "n={n} r={r} t={t}");
            }
        }
    }
}
