//! Learning-rate schedules (paper §5, "Default experimental setting").
//!
//! The paper's rule: LRs are defined per worker and scaled linearly by
//! the number of workers, with a linear warmup over the first 5 epochs
//! starting from the single-worker LR; step decay /10 at fixed epochs.

/// Decay shape after warmup.
#[derive(Debug, Clone, PartialEq)]
pub enum ScheduleKind {
    /// Constant after warmup.
    Constant,
    /// Multiply by `factor` at each milestone step.
    Step { milestones: Vec<usize>, factor: f64 },
    /// Cosine decay to zero at `total_steps` (Appendix D's transformer).
    Cosine { total_steps: usize },
}

/// Learning-rate schedule with linear warmup and worker scaling.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    /// Single-worker base learning rate.
    pub base_lr: f64,
    /// Linear scaling factor (number of workers).
    pub workers: usize,
    /// Warmup duration in steps (0 = none). Warmup goes from `base_lr`
    /// to `base_lr × workers` linearly, per Goyal et al. (2017).
    pub warmup_steps: usize,
    /// Decay shape after warmup.
    pub kind: ScheduleKind,
}

impl LrSchedule {
    /// Constant LR (no scaling, no warmup) — for tests and toy runs.
    pub fn constant(lr: f64) -> LrSchedule {
        LrSchedule { base_lr: lr, workers: 1, warmup_steps: 0, kind: ScheduleKind::Constant }
    }

    /// The paper's CIFAR10 recipe scaled to `workers`, expressed in steps:
    /// warmup over `warmup_steps`, /10 at the given milestones.
    pub fn paper_step(
        base_lr: f64,
        workers: usize,
        warmup_steps: usize,
        milestones: Vec<usize>,
    ) -> LrSchedule {
        LrSchedule {
            base_lr,
            workers,
            warmup_steps,
            kind: ScheduleKind::Step { milestones, factor: 0.1 },
        }
    }

    /// Cosine decay to zero at `total_steps` (Appendix D's transformer
    /// recipe), with linear warmup and worker scaling.
    pub fn cosine(
        base_lr: f64,
        workers: usize,
        warmup_steps: usize,
        total_steps: usize,
    ) -> LrSchedule {
        LrSchedule {
            base_lr,
            workers,
            warmup_steps,
            kind: ScheduleKind::Cosine { total_steps },
        }
    }

    /// Learning rate at a (0-based) step.
    pub fn lr_at(&self, step: usize) -> f64 {
        let target = self.base_lr * self.workers as f64;
        if self.warmup_steps > 0 && step < self.warmup_steps {
            // linear from base_lr to target
            let t = step as f64 / self.warmup_steps as f64;
            return self.base_lr + (target - self.base_lr) * t;
        }
        match &self.kind {
            ScheduleKind::Constant => target,
            ScheduleKind::Step { milestones, factor } => {
                let passed = milestones.iter().filter(|&&m| step >= m).count();
                target * factor.powi(passed as i32)
            }
            ScheduleKind::Cosine { total_steps } => {
                let t = ((step - self.warmup_steps) as f64
                    / (total_steps.saturating_sub(self.warmup_steps)).max(1) as f64)
                    .min(1.0);
                target * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(1000), 0.1);
    }

    #[test]
    fn warmup_ramps_from_base_to_scaled() {
        let s = LrSchedule::paper_step(0.1, 16, 100, vec![]);
        assert!((s.lr_at(0) - 0.1).abs() < 1e-12);
        assert!(s.lr_at(50) > 0.1 && s.lr_at(50) < 1.6);
        assert!((s.lr_at(100) - 1.6).abs() < 1e-12);
        assert!((s.lr_at(500) - 1.6).abs() < 1e-12);
    }

    #[test]
    fn step_decay_applies_at_milestones() {
        let s = LrSchedule::paper_step(0.1, 1, 0, vec![150, 250]);
        assert!((s.lr_at(149) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(150) - 0.01).abs() < 1e-12);
        assert!((s.lr_at(250) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_zero() {
        let s = LrSchedule::cosine(0.1, 1, 0, 100);
        assert!((s.lr_at(0) - 0.1).abs() < 1e-12);
        assert!(s.lr_at(50) < 0.1 && s.lr_at(50) > 0.0);
        assert!(s.lr_at(100) < 1e-9);
        // clamps past the end
        assert!(s.lr_at(1000) < 1e-9);
    }

    #[test]
    fn monotone_decreasing_after_warmup() {
        let s = LrSchedule::cosine(0.5, 4, 10, 200);
        let mut prev = f64::INFINITY;
        for step in 10..200 {
            let lr = s.lr_at(step);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }
}
