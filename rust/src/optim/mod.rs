//! Distributed optimizers.
//!
//! - [`EfSgd`] — Algorithm 2: distributed error-feedback SGD with
//!   post-compression momentum, the paper's main training loop. Works
//!   with any [`Compressor`]; with [`NoCompression`] it degenerates to
//!   (a variant of) momentum SGD.
//! - [`Sgd`] — classic full-precision momentum SGD, the baseline rows.
//! - [`SignumOpt`] — Bernstein et al.'s Signum: per-worker momentum,
//!   sign compression, majority vote, no error feedback (Appendix G.5).
//! - [`LrSchedule`] — linear warmup + step decay / cosine, with the
//!   paper's linear-scaling rule over workers (§5, experimental setup).

mod schedule;
pub use schedule::{LrSchedule, ScheduleKind};

use crate::collectives::CommLog;
use crate::compress::{Compressor, NoCompression, SchemeMeta};
use crate::tensor::Tensor;

/// A distributed optimizer: consumes per-worker (matricized) gradients,
/// performs compression + aggregation + state updates, and returns the
/// parameter delta to subtract (`x ← x − delta`), in compression shapes.
pub trait DistOptimizer: Send {
    /// Human-readable optimizer name (for logs and tables).
    fn name(&self) -> String;

    /// One optimization step. `grads[w][p]` = worker w's gradient for
    /// parameter p. Returns the (shared) parameter delta.
    fn step(&mut self, grads: &[Vec<Tensor>], step: usize, log: &mut CommLog) -> Vec<Tensor>;

    /// Learning rate used at `step` (for logging).
    fn lr_at(&self, step: usize) -> f64;

    /// Scratch-arena tensor allocations so far, when the optimizer
    /// drives a decentralized per-worker compressor (see
    /// [`Compressor::scratch_allocations`]); `None` otherwise.
    fn scratch_allocations(&self) -> Option<u64> {
        None
    }

    /// How many threads record a `Collective` span per logical
    /// collective (see [`Compressor::collective_span_threads`]); 1 for
    /// optimizers that run collectives on the calling thread.
    fn collective_span_threads(&self) -> usize {
        1
    }
}

/// Distributed error-feedback SGD with momentum (Algorithm 2).
pub struct EfSgd {
    schedule: LrSchedule,
    /// Momentum parameter λ.
    momentum: f32,
    compressor: Box<dyn Compressor>,
    /// Per-worker error memory `e_w` (line 4), lazily initialized.
    errors: Vec<Vec<Tensor>>,
    /// Momentum buffer `m` (identical on all workers).
    m: Vec<Tensor>,
    /// Fig. 7 ablation: disable the feedback (errors stay zero).
    use_error_feedback: bool,
    /// One-step-delayed aggregation (`--pipeline delayed`): apply step
    /// `t−1`'s aggregate at step `t`.
    delayed: bool,
    /// The aggregate computed last step, not yet applied (delayed mode).
    pending_mean: Option<Vec<Tensor>>,
}

impl EfSgd {
    /// EF-SGD over `compressor` with the given schedule and momentum λ.
    pub fn new(compressor: Box<dyn Compressor>, schedule: LrSchedule, momentum: f32) -> EfSgd {
        EfSgd {
            schedule,
            momentum,
            compressor,
            errors: Vec::new(),
            m: Vec::new(),
            use_error_feedback: true,
            delayed: false,
            pending_mean: None,
        }
    }

    /// Disable error feedback (Appendix E / Fig. 7 ablation).
    pub fn without_error_feedback(mut self) -> EfSgd {
        self.use_error_feedback = false;
        self
    }

    /// One-step-delayed aggregation (the PyTorch DDP PowerSGD-hook
    /// trick, `--pipeline delayed`): step `t` applies step `t−1`'s
    /// aggregate, so the collective can stay in flight across the next
    /// step's backward pass; step 0 applies nothing. Error feedback
    /// still uses each round's own reconstruction — only the *applied*
    /// aggregate is stale. The trajectory therefore differs from the
    /// synchronous one (by exactly one step of staleness; see the
    /// shifted-trajectory test) and must be compared against a delayed
    /// oracle.
    pub fn with_delayed_aggregate(mut self) -> EfSgd {
        self.delayed = true;
        self
    }

    /// Whether one-step-delayed aggregation is on.
    pub fn is_delayed(&self) -> bool {
        self.delayed
    }

    /// Name of the wrapped compressor (for logs).
    pub fn compressor_name(&self) -> String {
        self.compressor.name()
    }

    /// Elastic membership changed (DESIGN.md §16): the run entered
    /// `epoch` with `new_world` workers. Resets the delayed-aggregation
    /// staleness — a pending aggregate was computed under the old
    /// membership, and every member (and the oracle) drops it
    /// identically, so the post-transition trajectory stays shared —
    /// and forwards the event to the compressor. Error-feedback slots
    /// are *not* touched here: a worker-side `EfSgd` owns exactly its
    /// own residual (survivors keep theirs), and the oracle edits its
    /// slot list explicitly via [`EfSgd::remove_worker`] /
    /// [`EfSgd::add_worker`].
    pub fn on_reconfigure(&mut self, epoch: u64, new_world: usize) {
        self.pending_mean = None;
        self.compressor.on_reconfigure(epoch, new_world);
    }

    /// Oracle-side membership edit: drop worker `slot`'s error-feedback
    /// residual (the departed rank's EF contribution is lost — the
    /// documented policy; survivors' slots compact and keep their own
    /// residuals, matching what the distributed survivors hold).
    pub fn remove_worker(&mut self, slot: usize) {
        if slot < self.errors.len() {
            self.errors.remove(slot);
        }
    }

    /// Oracle-side membership edit: append a fresh worker slot with a
    /// zero error-feedback residual (a late joiner starts with empty
    /// EF state — the documented policy).
    pub fn add_worker(&mut self) {
        if let Some(first) = self.errors.first() {
            let zeros: Vec<Tensor> = first.iter().map(|t| Tensor::zeros(t.shape())).collect();
            self.errors.push(zeros);
        }
    }

    /// The shared momentum buffer `m` (identical on every worker) —
    /// empty before the first step. A late joiner replays the shared
    /// trajectory to the join step and seeds its own optimizer from
    /// this (see `transport::tcp::harness::oracle_state_at`).
    pub fn momentum_state(&self) -> Vec<Tensor> {
        self.m.clone()
    }

    /// Seed the momentum buffer (see [`EfSgd::momentum_state`]).
    pub fn with_momentum_state(mut self, m: Vec<Tensor>) -> EfSgd {
        self.m = m;
        self
    }

    fn ensure_state(&mut self, grads: &[Vec<Tensor>]) {
        if self.errors.len() != grads.len() {
            self.errors = grads
                .iter()
                .map(|wg| wg.iter().map(|g| Tensor::zeros(g.shape())).collect())
                .collect();
        }
        if self.m.is_empty() {
            self.m = grads[0].iter().map(|g| Tensor::zeros(g.shape())).collect();
        }
    }
}

impl DistOptimizer for EfSgd {
    fn name(&self) -> String {
        let ef = if self.use_error_feedback { "" } else { " (no EF)" };
        let delay = if self.delayed { " (delayed)" } else { "" };
        format!("EF-SGD[{}]{}{}", self.compressor.name(), ef, delay)
    }

    fn lr_at(&self, step: usize) -> f64 {
        self.schedule.lr_at(step)
    }

    fn scratch_allocations(&self) -> Option<u64> {
        self.compressor.scratch_allocations()
    }

    fn collective_span_threads(&self) -> usize {
        self.compressor.collective_span_threads()
    }

    fn step(&mut self, grads: &[Vec<Tensor>], step: usize, log: &mut CommLog) -> Vec<Tensor> {
        self.ensure_state(grads);
        let nparams = grads[0].len();

        // Line 7: Δ_w ← g_w + e_w
        let updates: Vec<Vec<Tensor>> = grads
            .iter()
            .zip(self.errors.iter())
            .map(|(wg, we)| {
                wg.iter()
                    .zip(we.iter())
                    .map(|(g, e)| g.add(e))
                    .collect()
            })
            .collect();

        // Lines 8, 10, 11: compress, aggregate, decompress.
        let logical_before = crate::obs::metrics::on().then(|| log.bytes_sent());
        let agg = self.compressor.compress_aggregate(&updates, log);
        if let Some(before) = logical_before {
            // Achieved compression ratio: raw per-worker gradient bytes
            // over the logical bytes this aggregate actually logged.
            // Telemetry only — reads the log, never the values.
            let raw: u64 = updates[0].iter().map(|t| t.len() as u64 * crate::grad::ELEM_BYTES).sum();
            let logical = log.bytes_sent() - before;
            if logical > 0 {
                crate::obs::metrics::set_gauge(
                    crate::obs::metrics::Gauge::CompressionRatio,
                    raw as f64 / logical as f64,
                );
            }
        }

        // Line 9: e_w ← Δ_w − DECOMPRESS(C(Δ_w))
        if self.use_error_feedback {
            for (w, we) in self.errors.iter_mut().enumerate() {
                let local = agg.local_for(w);
                for p in 0..nparams {
                    *&mut we[p] = updates[w][p].sub(&local[p]);
                }
            }
            if crate::obs::metrics::on() {
                // EF residual norm ‖e‖_F summed over layers and workers
                // — the quantity whose boundedness underwrites the EF
                // convergence argument. Read-only telemetry.
                let mut sq = 0.0f64;
                for we in &self.errors {
                    for e in we {
                        for v in e.data() {
                            sq += f64::from(*v) * f64::from(*v);
                        }
                    }
                }
                let norm = sq.sqrt();
                crate::obs::metrics::set_gauge(crate::obs::metrics::Gauge::EfResidual, norm);
                crate::obs::metrics::observe(crate::obs::metrics::Histogram::EfResidual, norm);
            }
        }

        // Lines 12–13: m ← λm + Δ';  x ← x − γ(Δ' + m). In delayed
        // mode Δ' is the previous step's aggregate; step 0 has nothing
        // to apply and leaves the momentum untouched.
        crate::obs::metrics::set_gauge(
            crate::obs::metrics::Gauge::StalenessSteps,
            if self.delayed { 1.0 } else { 0.0 },
        );
        let applied = if self.delayed {
            match self.pending_mean.replace(agg.mean) {
                Some(prev) => prev,
                None => return grads[0].iter().map(|g| Tensor::zeros(g.shape())).collect(),
            }
        } else {
            agg.mean
        };
        let gamma = self.schedule.lr_at(step) as f32;
        let mut delta = Vec::with_capacity(nparams);
        for p in 0..nparams {
            self.m[p].scale(self.momentum);
            self.m[p].axpy(1.0, &applied[p]);
            let mut d = applied[p].clone();
            d.axpy(1.0, &self.m[p]);
            d.scale(gamma);
            delta.push(d);
        }
        delta
    }
}

/// Classic full-precision momentum SGD over all-reduced gradients
/// (`m ← λm + ḡ; x ← x − γm`), the paper's "SGD" baseline.
pub struct Sgd {
    schedule: LrSchedule,
    momentum: f32,
    m: Vec<Tensor>,
    agg: NoCompression,
}

impl Sgd {
    /// Momentum SGD with the given schedule.
    pub fn new(schedule: LrSchedule, momentum: f32) -> Sgd {
        Sgd { schedule, momentum, m: Vec::new(), agg: NoCompression::new() }
    }
}

impl DistOptimizer for Sgd {
    fn name(&self) -> String {
        "SGD".into()
    }

    fn lr_at(&self, step: usize) -> f64 {
        self.schedule.lr_at(step)
    }

    fn step(&mut self, grads: &[Vec<Tensor>], step: usize, log: &mut CommLog) -> Vec<Tensor> {
        let aggd = self.agg.compress_aggregate(grads, log);
        if self.m.is_empty() {
            self.m = aggd.mean.iter().map(|g| Tensor::zeros(g.shape())).collect();
        }
        let gamma = self.schedule.lr_at(step) as f32;
        let mut delta = Vec::with_capacity(aggd.mean.len());
        for (p, g) in aggd.mean.iter().enumerate() {
            self.m[p].scale(self.momentum);
            self.m[p].axpy(1.0, g);
            let mut d = self.m[p].clone();
            d.scale(gamma);
            delta.push(d);
        }
        delta
    }
}

/// Signum (Bernstein et al. 2019): per-worker momentum, transmit
/// `sign(m_w)`, aggregate by majority vote, update `x ← x − γ·sign`.
/// No error feedback; the learning rate must be tuned separately
/// (Appendix I: 5e-5 for CIFAR10 vs 0.1 for SGD).
pub struct SignumOpt {
    schedule: LrSchedule,
    beta: f32,
    per_worker_m: Vec<Vec<Tensor>>,
    compressor: crate::compress::Signum,
}

impl SignumOpt {
    /// Signum with momentum parameter `beta`.
    pub fn new(schedule: LrSchedule, beta: f32) -> SignumOpt {
        SignumOpt {
            schedule,
            beta,
            per_worker_m: Vec::new(),
            compressor: crate::compress::Signum::new(),
        }
    }
}

impl DistOptimizer for SignumOpt {
    fn name(&self) -> String {
        "Signum".into()
    }

    fn lr_at(&self, step: usize) -> f64 {
        self.schedule.lr_at(step)
    }

    fn step(&mut self, grads: &[Vec<Tensor>], step: usize, log: &mut CommLog) -> Vec<Tensor> {
        use crate::compress::Compressor as _;
        if self.per_worker_m.len() != grads.len() {
            self.per_worker_m = grads
                .iter()
                .map(|wg| wg.iter().map(|g| Tensor::zeros(g.shape())).collect())
                .collect();
        }
        // m_w ← β·m_w + (1−β)·g_w
        for (wm, wg) in self.per_worker_m.iter_mut().zip(grads.iter()) {
            for (m, g) in wm.iter_mut().zip(wg.iter()) {
                m.scale(self.beta);
                m.axpy(1.0 - self.beta, g);
            }
        }
        let agg = self.compressor.compress_aggregate(&self.per_worker_m, log);
        let gamma = self.schedule.lr_at(step) as f32;
        agg.mean
            .iter()
            .map(|s| {
                let mut d = s.clone();
                d.scale(gamma);
                d
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{PowerSgd, RandomK};
    use crate::util::Rng;

    fn quad_grads(x: &[Tensor], w: usize, noise: f32, rng: &mut Rng) -> Vec<Vec<Tensor>> {
        // gradient of f(x) = ||x||²/2 is x; add per-worker noise.
        (0..w)
            .map(|_| {
                x.iter()
                    .map(|t| {
                        let mut g = t.clone();
                        let mut nz = Tensor::zeros(t.shape());
                        rng.fill_normal(nz.data_mut(), noise);
                        g.axpy(1.0, &nz);
                        g
                    })
                    .collect()
            })
            .collect()
    }

    fn const_schedule(lr: f64) -> LrSchedule {
        LrSchedule::constant(lr)
    }

    #[test]
    fn efsgd_minimizes_quadratic() {
        let mut rng = Rng::new(201);
        let mut x = vec![Tensor::full(&[8, 6], 1.0), Tensor::full(&[4], -2.0)];
        let mut opt = EfSgd::new(Box::new(PowerSgd::new(2, 7)), const_schedule(0.05), 0.9);
        let mut log = CommLog::default();
        for step in 0..300 {
            let grads = quad_grads(&x, 4, 0.01, &mut rng);
            let delta = opt.step(&grads, step, &mut log);
            for (xi, di) in x.iter_mut().zip(delta.iter()) {
                xi.axpy(-1.0, di);
            }
        }
        let norm: f64 = x.iter().map(|t| t.norm()).sum();
        assert!(norm < 0.2, "EF-SGD failed to converge: |x| = {norm}");
    }

    #[test]
    fn error_feedback_preserves_information() {
        // With a heavily-compressing operator, EF-SGD still converges on a
        // quadratic while the no-EF variant stalls at a worse point.
        let run = |ef: bool| {
            let mut rng = Rng::new(202);
            let mut x = vec![Tensor::full(&[10, 10], 1.0)];
            let comp = RandomK::new(1, 11);
            let mut opt = EfSgd::new(Box::new(comp), const_schedule(0.08), 0.0);
            if !ef {
                opt = opt.without_error_feedback();
            }
            let mut log = CommLog::default();
            for step in 0..400 {
                let grads = quad_grads(&x, 2, 0.0, &mut rng);
                let delta = opt.step(&grads, step, &mut log);
                for (xi, di) in x.iter_mut().zip(delta.iter()) {
                    xi.axpy(-1.0, di);
                }
            }
            x[0].norm()
        };
        let with_ef = run(true);
        let without = run(false);
        assert!(
            with_ef < without * 0.5,
            "EF {with_ef} should beat no-EF {without}"
        );
    }

    #[test]
    fn sgd_momentum_converges() {
        let mut rng = Rng::new(203);
        let mut x = vec![Tensor::full(&[5, 5], 2.0)];
        let mut opt = Sgd::new(const_schedule(0.05), 0.9);
        let mut log = CommLog::default();
        for step in 0..200 {
            let grads = quad_grads(&x, 2, 0.0, &mut rng);
            let delta = opt.step(&grads, step, &mut log);
            x[0].axpy(-1.0, &delta[0]);
        }
        assert!(x[0].norm() < 1e-2, "{}", x[0].norm());
    }

    #[test]
    fn signum_moves_toward_optimum() {
        let mut rng = Rng::new(204);
        let mut x = vec![Tensor::full(&[6, 6], 1.0)];
        let mut opt = SignumOpt::new(const_schedule(0.01), 0.9);
        let mut log = CommLog::default();
        let start = x[0].norm();
        for step in 0..200 {
            let grads = quad_grads(&x, 3, 0.01, &mut rng);
            let delta = opt.step(&grads, step, &mut log);
            x[0].axpy(-1.0, &delta[0]);
        }
        // Signum oscillates at ±lr scale but must reduce the norm a lot.
        assert!(x[0].norm() < start * 0.2, "{} -> {}", start, x[0].norm());
    }

    #[test]
    fn delayed_aggregation_converges_on_quadratic() {
        let mut rng = Rng::new(206);
        let mut x = vec![Tensor::full(&[8, 6], 1.0), Tensor::full(&[4], -2.0)];
        let mut opt = EfSgd::new(Box::new(PowerSgd::new(2, 7)), const_schedule(0.05), 0.9)
            .with_delayed_aggregate();
        let mut log = CommLog::default();
        for step in 0..300 {
            let grads = quad_grads(&x, 4, 0.01, &mut rng);
            let delta = opt.step(&grads, step, &mut log);
            for (xi, di) in x.iter_mut().zip(delta.iter()) {
                xi.axpy(-1.0, di);
            }
        }
        let norm: f64 = x.iter().map(|t| t.norm()).sum();
        assert!(norm < 0.3, "delayed EF-SGD failed to converge: |x| = {norm}");
    }

    /// On a fixed gradient sequence (identical compression inputs) with
    /// a constant learning rate, the delayed trajectory is exactly the
    /// synchronous one shifted by one step: delta'₀ = 0 and
    /// delta'ₜ ≡ deltaₜ₋₁ bit for bit — the precise meaning of
    /// "one step of staleness".
    #[test]
    fn delayed_is_the_synchronous_trajectory_shifted_one_step() {
        let make = || EfSgd::new(Box::new(PowerSgd::new(2, 7)), const_schedule(0.05), 0.9);
        let mut sync = make();
        let mut delayed = make().with_delayed_aggregate();
        let mut rng = Rng::new(207);
        let mut sync_deltas = Vec::new();
        let mut delayed_deltas = Vec::new();
        for step in 0..5 {
            // Gradients independent of the trajectory, so both runs
            // compress identical inputs.
            let grads: Vec<Vec<Tensor>> = (0..3)
                .map(|_| {
                    [&[6, 5][..], &[3][..]]
                        .iter()
                        .map(|s| {
                            let mut t = Tensor::zeros(s);
                            rng.fill_normal(t.data_mut(), 1.0);
                            t
                        })
                        .collect()
                })
                .collect();
            sync_deltas.push(sync.step(&grads, step, &mut CommLog::default()));
            delayed_deltas.push(delayed.step(&grads, step, &mut CommLog::default()));
        }
        for t in &delayed_deltas[0] {
            assert_eq!(t.norm(), 0.0, "step 0 must apply nothing");
        }
        for s in 1..5 {
            for (a, b) in delayed_deltas[s].iter().zip(sync_deltas[s - 1].iter()) {
                assert_eq!(a.data(), b.data(), "delayed[{s}] != sync[{}]", s - 1);
            }
        }
    }

    #[test]
    fn efsgd_with_identity_compressor_has_zero_error() {
        let mut rng = Rng::new(205);
        let x = vec![Tensor::full(&[4, 4], 1.0)];
        let mut opt = EfSgd::new(Box::new(NoCompression::new()), const_schedule(0.1), 0.9);
        let mut log = CommLog::default();
        let grads = quad_grads(&x, 3, 0.1, &mut rng);
        opt.step(&grads, 0, &mut log);
        for we in &opt.errors {
            for e in we {
                assert!(e.norm() < 1e-6);
            }
        }
    }
}
