//! Cache-blocked, register-tiled GEMM kernels for the compression hot
//! path.
//!
//! PowerSGD's GEMMs are *skinny*: `A[n×m] · B[m×r]` and `Aᵀ[m×n] · P[n×r]`
//! with r ∈ 1..32 but n·m up to ~19M elements (the LSTM encoder layer).
//! Every kernel is a single-pass stream over the big operand (the
//! bandwidth roofline), organized so the hot working set is packed,
//! contiguous, and small enough to live in registers and L1:
//!
//! - `matmul` packs the skinny B into a transposed panel once
//!   (m·r ≤ a few hundred KB, reused per-thread scratch), then emits
//!   each output row as register-tiled groups of up to 4 column dots
//!   ([`dot8_cols`]): the A row chunk is loaded once per group instead
//!   of once per column, and each column keeps its own 8-lane
//!   [`F32x8`] accumulator that LLVM lowers to one vector FMA per
//!   chunk.
//! - `matmul_tn` accumulates into an r×jb transposed tile sized to
//!   stay L1-resident ([`tn_tile_cols`] picks jb per shape), the inner
//!   loop a contiguous vectorized axpy, then transposes each tile back
//!   once.
//! - `matmul_nt` packs Qᵀ once and emits each output row in 8-wide
//!   register chunks, accumulating all r terms in lanes before a
//!   single store — one pass over the n×m output instead of r
//!   read-modify-write passes.
//!
//! All three `_into` kernels run on the kernel pool
//! ([`crate::runtime::pool`], DESIGN.md §11) when `--threads` /
//! `POWERSGD_THREADS` asks for more than one thread:
//!
//! - `matmul_into` / `matmul_nt_into` shard over **output rows**;
//!   `matmul_tn_into` shards over the **m dimension** (each task owns
//!   a disjoint range of accumulator columns). Every output element is
//!   produced by exactly one task with a partition-independent
//!   operation order, so results are bitwise identical at every thread
//!   count.
//!
//! Blocked-vs-[`reference`](super::reference) equivalence is decided
//! and documented per kernel (DESIGN.md §11): `tn`/`nt` keep the
//! reference per-element accumulation chain exactly; `nn` splits the k
//! dimension over 8 lanes — a documented, harness-pinned numerics
//! change. `POWERSGD_KERNEL_BACKEND=reference` (or
//! [`set_kernel_backend`](crate::runtime::pool::set_kernel_backend))
//! reroutes every call here to the naive kernels.
//!
//! The packed panels and accumulator tiles live in per-thread pool
//! scratch ([`with_panel`] / [`with_tile`]) that grows once and is
//! reused by every later call on that thread — the steady-state step
//! allocates nothing here (`tests/integration_kernels.rs` and
//! `tests/proptest_invariants.rs` pin both properties).
//!
//! Perf history: multi-accumulator + layout change ≈ 2–3× over the
//! first blocked loop; register-tiled column groups + packed panels
//! added the next ≥2× single-thread step over the naive reference
//! (`benches/kernel_hotpath.rs` records GFLOP/s for both backends).

use super::{reference, Tensor};
use crate::obs::{span, Phase};
use crate::runtime::pool::{
    kernel_backend, parallel_ranges, with_panel, with_tile, DisjointSlice, KernelBackend,
};

/// Minimum per-range elements touched before a kernel fans out; tiny
/// layers stay on the calling thread (the partition never changes
/// results, only who computes them).
const MIN_PAR_ELEMS: usize = 16 * 1024;

/// 8-lane f32 accumulator. The alignment matches a 256-bit vector
/// register so LLVM keeps the whole array in one YMM/equivalent and
/// lowers the lane loop to a single vector FMA — portable SIMD with no
/// nightly intrinsics.
#[repr(align(32))]
#[derive(Clone, Copy)]
struct F32x8([f32; 8]);

impl F32x8 {
    const ZERO: F32x8 = F32x8([0.0; 8]);

    /// acc[l] += a[l] * b[l] over 8-element windows.
    #[inline(always)]
    fn fma(&mut self, a: &[f32], b: &[f32]) {
        for (acc, (&x, &y)) in self.0.iter_mut().zip(a.iter().zip(b.iter())) {
            *acc += x * y;
        }
    }

    /// Left-to-right lane sum — the fixed combine order of the
    /// determinism contract.
    #[inline(always)]
    fn hsum(self) -> f32 {
        self.0.iter().sum()
    }
}

/// Register-tiled micro-kernel: `NC` simultaneous column dots against
/// one A row. Each column keeps the exact documented accumulation
/// order — 8 lanes striding the k dimension (element k lands in lane
/// k mod 8), lanes summed left-to-right, serial tail appended — while
/// the A row chunk is loaded once per group of NC columns instead of
/// once per column. NC ≤ 4 keeps NC+1 vector registers live, well
/// under the 16 available on AVX2-class hardware.
//
// NOTE (perf pass): a fused two-column dot with 4-wide accumulators
// was tried and REVERTED — it broke 8-lane (AVX2) auto-vectorization
// and ran 2x slower than one 8-wide accumulator per column. The
// column-group tiling here keeps the 8-wide per-column accumulators
// and only shares the A load.
#[inline]
fn dot8_cols<const NC: usize>(arow: &[f32], bt: &[f32], m: usize, c0: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), NC);
    let chunks = m / 8;
    let mut acc = [F32x8::ZERO; NC];
    for k in 0..chunks {
        let a8 = &arow[k * 8..k * 8 + 8];
        for (j, accj) in acc.iter_mut().enumerate() {
            let base = (c0 + j) * m + k * 8;
            accj.fma(a8, &bt[base..base + 8]);
        }
    }
    for (j, accj) in acc.into_iter().enumerate() {
        let bcol = &bt[(c0 + j) * m..(c0 + j + 1) * m];
        let mut tail = 0.0f32;
        for k in chunks * 8..m {
            tail += arow[k] * bcol[k];
        }
        out[j] = accj.hsum() + tail;
    }
}

/// out[j] += s * a[j] over a contiguous slice (vectorizable fused axpy).
#[inline]
fn axpy_slice(out: &mut [f32], s: f32, a: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    for (o, &v) in out.iter_mut().zip(a.iter()) {
        *o += s * v;
    }
}

/// out[n×r] = A[n×m] · B[m×r], allocating the output.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[a.rows(), b.cols()]);
    matmul_into(a, b, &mut out);
    out
}

/// out[n×r] = A[n×m] · B[m×r]; `out` is overwritten. B is packed into
/// a transposed per-thread panel once, then output rows are emitted as
/// register-tiled column groups ([`dot8_cols`]). Sharded over output
/// rows on the kernel pool — bitwise identical at every thread count.
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let _span = span(Phase::MatmulNn);
    match kernel_backend() {
        KernelBackend::Reference => reference::matmul_into(a, b, out),
        KernelBackend::Blocked => blocked_matmul_into(a, b, out),
    }
}

fn blocked_matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (n, m) = (a.rows(), a.cols());
    let (mb, r) = (b.rows(), b.cols());
    assert_eq!(m, mb, "matmul inner-dim mismatch: {m} vs {mb}");
    assert_eq!(out.shape(), &[n, r], "matmul output shape");
    let ad = a.data();
    let bd = b.data();
    // Pack skinny B once: column c becomes a contiguous panel row.
    with_panel(m * r, |bt| {
        for k in 0..m {
            for c in 0..r {
                bt[c * m + k] = bd[k * r + c];
            }
        }
        let bt: &[f32] = bt;
        let od = DisjointSlice::new(out.data_mut());
        let min_rows = (MIN_PAR_ELEMS / m.max(1)).max(1);
        parallel_ranges(n, min_rows, move |i0, i1| {
            // SAFETY: row bands are disjoint across tasks.
            let band = unsafe { od.range_mut(i0 * r, i1 * r) };
            for i in i0..i1 {
                let arow = &ad[i * m..(i + 1) * m];
                let orow = &mut band[(i - i0) * r..(i - i0 + 1) * r];
                let mut c = 0;
                while c + 4 <= r {
                    dot8_cols::<4>(arow, bt, m, c, &mut orow[c..c + 4]);
                    c += 4;
                }
                if c + 2 <= r {
                    dot8_cols::<2>(arow, bt, m, c, &mut orow[c..c + 2]);
                    c += 2;
                }
                if c < r {
                    dot8_cols::<1>(arow, bt, m, c, &mut orow[c..c + 1]);
                }
            }
        });
    });
}

/// Blocking parameter for [`matmul_tn_into`], chosen per shape: the
/// widest accumulator tile of r panel rows that stays within ~32 KB of
/// L1 alongside the streamed A-row chunk. Floor of 8 keeps the axpy
/// wide enough to vectorize; cap of 2048 bounds the transpose-back
/// working set for rank-1 layers.
fn tn_tile_cols(r: usize) -> usize {
    (8 * 1024 / r.max(1)).clamp(8, 2048)
}

/// out[m×r] = Aᵀ[m×n] · P[n×r] without materializing Aᵀ.
///
/// This is the second GEMM of the PowerSGD step (`Q = Mᵀ·P̂`). We
/// stream rows of A once per tile and accumulate into an r×jb
/// transposed tile sized by [`tn_tile_cols`] to stay L1-resident, so
/// every inner loop is a contiguous vectorized axpy over an A-row
/// chunk that's hot in cache. Parallelism shards the **m dimension**:
/// each task owns a range of accumulator columns, walks it tile by
/// tile, and transposes each tile into `out`. Every accumulator
/// element keeps the serial i-ordered summation chain, so results are
/// bitwise identical at every thread count *and* equal (`==`) to the
/// reference kernel on finite data.
pub fn matmul_tn_into(a: &Tensor, p: &Tensor, out: &mut Tensor) {
    let _span = span(Phase::MatmulTn);
    match kernel_backend() {
        KernelBackend::Reference => reference::matmul_tn_into(a, p, out),
        KernelBackend::Blocked => blocked_matmul_tn_into(a, p, out),
    }
}

fn blocked_matmul_tn_into(a: &Tensor, p: &Tensor, out: &mut Tensor) {
    let (n, m) = (a.rows(), a.cols());
    let (np, r) = (p.rows(), p.cols());
    assert_eq!(n, np, "matmul_tn inner-dim mismatch: {n} vs {np}");
    assert_eq!(out.shape(), &[m, r], "matmul_tn output shape");
    let ad = a.data();
    let pd = p.data();
    let od = DisjointSlice::new(out.data_mut());
    let jb = tn_tile_cols(r);
    let min_cols = (MIN_PAR_ELEMS / n.max(1)).max(1);
    parallel_ranges(m, min_cols, move |j0, j1| {
        let mut jlo = j0;
        while jlo < j1 {
            let jhi = (jlo + jb).min(j1);
            let w = jhi - jlo;
            with_tile(r * w, |tile| {
                tile.fill(0.0);
                for i in 0..n {
                    let arow = &ad[i * m + jlo..i * m + jhi];
                    let prow = &pd[i * r..(i + 1) * r];
                    for (c, &s) in prow.iter().enumerate() {
                        // Skipping an exact-zero scale adds no term
                        // the reference's `acc += 0·a` would change
                        // (finite data; DESIGN.md §11).
                        if s != 0.0 {
                            axpy_slice(&mut tile[c * w..(c + 1) * w], s, arow);
                        }
                    }
                }
                // SAFETY: column bands are disjoint across tasks, and
                // tiles partition this task's band.
                let band = unsafe { od.range_mut(jlo * r, jhi * r) };
                for j in 0..w {
                    for c in 0..r {
                        band[j * r + c] = tile[c * w + j];
                    }
                }
            });
            jlo = jhi;
        }
    });
}

/// One reconstruction output row: out[j] = Σ_c ps[c]·qt[c·m+j], first
/// term overwriting. Per element this is the same c-ordered chain as
/// the reference kernel, but each 8-wide output chunk accumulates all
/// r terms in lane registers and stores once.
#[inline]
fn nt_row(orow: &mut [f32], ps: &[f32], qt: &[f32], m: usize) {
    let r = ps.len();
    if r == 0 {
        orow.fill(0.0);
        return;
    }
    let chunks = m / 8;
    for kc in 0..chunks {
        let j = kc * 8;
        let mut acc = F32x8::ZERO;
        for (accl, &v) in acc.0.iter_mut().zip(qt[j..j + 8].iter()) {
            *accl = ps[0] * v;
        }
        for (c, &s) in ps.iter().enumerate().skip(1) {
            let base = c * m + j;
            for (accl, &v) in acc.0.iter_mut().zip(qt[base..base + 8].iter()) {
                *accl += s * v;
            }
        }
        orow[j..j + 8].copy_from_slice(&acc.0);
    }
    for j in chunks * 8..m {
        let mut o = ps[0] * qt[j];
        for (c, &s) in ps.iter().enumerate().skip(1) {
            o += s * qt[c * m + j];
        }
        orow[j] = o;
    }
}

/// out[n×m] = P[n×r] · Qᵀ where Q is m×r — the PowerSGD
/// *reconstruction* (decompress) kernel. The inner dimension is tiny
/// (r), so the skinny `matmul` path would pay its per-output-dot
/// overhead on n·m outputs; here we pack Qᵀ once per call and emit
/// each output row in 8-wide register chunks ([`nt_row`]) — one store
/// per output element instead of r read-modify-write passes (perf
/// pass: 4.4 ms → 1.0 ms per 512×4608 layer before the register
/// chunking; `benches/kernel_hotpath.rs` tracks both backends now).
/// Sharded over output rows like `matmul_into` — bitwise identical at
/// every thread count, and `==`-equal to the reference kernel.
pub fn matmul_nt_into(p: &Tensor, q: &Tensor, out: &mut Tensor) {
    let _span = span(Phase::MatmulNt);
    match kernel_backend() {
        KernelBackend::Reference => reference::matmul_nt_into(p, q, out),
        KernelBackend::Blocked => blocked_matmul_nt_into(p, q, out),
    }
}

fn blocked_matmul_nt_into(p: &Tensor, q: &Tensor, out: &mut Tensor) {
    let (n, r) = (p.rows(), p.cols());
    let (m, rq) = (q.rows(), q.cols());
    assert_eq!(r, rq, "matmul_nt rank mismatch: {r} vs {rq}");
    assert_eq!(out.shape(), &[n, m], "matmul_nt output shape");
    let pd = p.data();
    let qd = q.data();
    // Pack Qᵀ: column c contiguous.
    with_panel(r * m, |qt| {
        for j in 0..m {
            for c in 0..r {
                qt[c * m + j] = qd[j * r + c];
            }
        }
        let qt: &[f32] = qt;
        let od = DisjointSlice::new(out.data_mut());
        let min_rows = (MIN_PAR_ELEMS / m.max(1)).max(1);
        parallel_ranges(n, min_rows, move |i0, i1| {
            // SAFETY: row bands are disjoint across tasks.
            let band = unsafe { od.range_mut(i0 * m, i1 * m) };
            for i in i0..i1 {
                let orow = &mut band[(i - i0) * m..(i - i0 + 1) * m];
                nt_row(orow, &pd[i * r..(i + 1) * r], qt, m);
            }
        });
    });
}

/// Allocating wrapper for [`matmul_nt_into`].
pub fn matmul_nt(p: &Tensor, q: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[p.rows(), q.rows()]);
    matmul_nt_into(p, q, &mut out);
    out
}

/// Convenience: Aᵀ·B allocating the output.
pub fn matmul_at_b(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[a.cols(), b.cols()]);
    matmul_tn_into(a, b, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pool::{set_threads, test_guard};
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (n, m, r) = (a.rows(), a.cols(), b.cols());
        let mut out = Tensor::zeros(&[n, r]);
        for i in 0..n {
            for j in 0..r {
                let mut acc = 0.0f64;
                for k in 0..m {
                    acc += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                out.set(i, j, acc as f32);
            }
        }
        out
    }

    fn random(shape: &[usize], rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn matches_naive_over_shapes_and_ranks() {
        let mut rng = Rng::new(11);
        for &(n, m) in &[(1, 1), (3, 5), (17, 64), (40, 300), (257, 31)] {
            // r sweep covers every column-tile remainder (r mod 4).
            for &r in &[1usize, 2, 3, 4, 5, 6, 7, 16] {
                let a = random(&[n, m], &mut rng);
                let b = random(&[m, r], &mut rng);
                let got = matmul(&a, &b);
                let want = naive(&a, &b);
                assert!(
                    got.allclose(&want, 1e-4, 1e-4),
                    "mismatch n={n} m={m} r={r}, max diff {}",
                    got.max_abs_diff(&want)
                );
            }
        }
    }

    #[test]
    fn transpose_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(12);
        for &(n, m, r) in &[(5, 3, 1), (64, 48, 2), (123, 77, 4), (30, 200, 9)] {
            let a = random(&[n, m], &mut rng);
            let p = random(&[n, r], &mut rng);
            let got = matmul_at_b(&a, &p);
            let want = matmul(&a.transpose(), &p);
            assert!(
                got.allclose(&want, 1e-4, 1e-4),
                "mismatch n={n} m={m} r={r}"
            );
        }
    }

    #[test]
    fn nt_matches_explicit_transpose() {
        let mut rng = Rng::new(14);
        for &(n, m, r) in &[(5, 3, 1), (64, 48, 2), (123, 77, 4), (30, 200, 7)] {
            let p = random(&[n, r], &mut rng);
            let q = random(&[m, r], &mut rng);
            let mut got = Tensor::zeros(&[n, m]);
            matmul_nt_into(&p, &q, &mut got);
            let want = matmul(&p, &q.transpose());
            assert!(got.allclose(&want, 1e-4, 1e-4), "n={n} m={m} r={r}");
        }
    }

    #[test]
    fn identity_matmul() {
        let mut rng = Rng::new(13);
        let a = random(&[6, 6], &mut rng);
        let mut eye = Tensor::zeros(&[6, 6]);
        for i in 0..6 {
            eye.set(i, i, 1.0);
        }
        assert!(matmul(&a, &eye).allclose(&a, 1e-6, 1e-6));
    }

    /// The determinism invariant at unit scale: every GEMM kernel is
    /// bitwise identical to its serial (1-thread) run at 2/4/8 threads.
    /// The full property suite over the paper's layer shapes lives in
    /// `tests/integration_kernels.rs`.
    #[test]
    fn parallel_kernels_bitwise_match_serial() {
        let _g = test_guard();
        let mut rng = Rng::new(15);
        for &(n, m, r) in &[(1, 1, 1), (257, 129, 2), (640, 384, 4)] {
            let a = random(&[n, m], &mut rng);
            let b = random(&[m, r], &mut rng);
            let p = random(&[n, r], &mut rng);
            let q = random(&[m, r], &mut rng);
            set_threads(1);
            let mut ab = Tensor::zeros(&[n, r]);
            matmul_into(&a, &b, &mut ab);
            let mut atp = Tensor::zeros(&[m, r]);
            matmul_tn_into(&a, &p, &mut atp);
            let mut pqt = Tensor::zeros(&[n, m]);
            matmul_nt_into(&p, &q, &mut pqt);
            for t in [2usize, 4, 8] {
                set_threads(t);
                let mut got = Tensor::zeros(&[n, r]);
                matmul_into(&a, &b, &mut got);
                assert_eq!(got.data(), ab.data(), "matmul n={n} m={m} r={r} t={t}");
                let mut got = Tensor::zeros(&[m, r]);
                matmul_tn_into(&a, &p, &mut got);
                assert_eq!(got.data(), atp.data(), "matmul_tn n={n} m={m} r={r} t={t}");
                let mut got = Tensor::zeros(&[n, m]);
                matmul_nt_into(&p, &q, &mut got);
                assert_eq!(got.data(), pqt.data(), "matmul_nt n={n} m={m} r={r} t={t}");
            }
        }
    }

    /// The per-kernel equivalence contract at unit scale (DESIGN.md
    /// §11): tn and nt keep the reference accumulation chain exactly,
    /// so blocked output equals reference output on every element.
    /// Both implementations are invoked directly — flipping the
    /// process backend here would race other tests in this binary; the
    /// dispatch path itself is covered by the differential harness.
    #[test]
    fn blocked_tn_nt_equal_reference_exactly() {
        let mut rng = Rng::new(16);
        for &(n, m, r) in &[(1, 1, 1), (63, 40, 3), (300, 170, 5), (41, 513, 8)] {
            let a = random(&[n, m], &mut rng);
            let p = random(&[n, r], &mut rng);
            let q = random(&[m, r], &mut rng);
            let mut blocked = Tensor::zeros(&[m, r]);
            blocked_matmul_tn_into(&a, &p, &mut blocked);
            let mut refr = Tensor::zeros(&[m, r]);
            super::reference::matmul_tn_into(&a, &p, &mut refr);
            assert_eq!(blocked.data(), refr.data(), "tn n={n} m={m} r={r}");
            let mut blocked = Tensor::zeros(&[n, m]);
            blocked_matmul_nt_into(&p, &q, &mut blocked);
            let mut refr = Tensor::zeros(&[n, m]);
            super::reference::matmul_nt_into(&p, &q, &mut refr);
            assert_eq!(blocked.data(), refr.data(), "nt n={n} m={m} r={r}");
        }
    }

    /// Executable pin of the nn kernel's documented accumulation
    /// order: element k lands in lane k mod 8, lanes sum left to
    /// right, the serial tail is appended. This *is* the snapshot for
    /// the one documented blocked-vs-reference numerics change — a
    /// spec you can run, rather than opaque stored bits.
    #[test]
    fn nn_matches_lane_order_spec_bitwise() {
        fn lane_order_dot(a: &[f32], b: &[f32]) -> f32 {
            let mut acc = [0.0f32; 8];
            let split = a.len() / 8 * 8;
            for k in 0..split {
                acc[k % 8] += a[k] * b[k];
            }
            let mut tail = 0.0f32;
            for k in split..a.len() {
                tail += a[k] * b[k];
            }
            acc.iter().sum::<f32>() + tail
        }
        let mut rng = Rng::new(17);
        for &(n, m, r) in &[(7, 5, 1), (33, 64, 4), (50, 301, 6)] {
            let a = random(&[n, m], &mut rng);
            let b = random(&[m, r], &mut rng);
            let mut got = Tensor::zeros(&[n, r]);
            blocked_matmul_into(&a, &b, &mut got);
            for i in 0..n {
                let arow = &a.data()[i * m..(i + 1) * m];
                for c in 0..r {
                    let bcol: Vec<f32> = (0..m).map(|k| b.at(k, c)).collect();
                    let want = lane_order_dot(arow, &bcol);
                    assert_eq!(
                        got.at(i, c).to_bits(),
                        want.to_bits(),
                        "n={n} m={m} r={r} i={i} c={c}"
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn dimension_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        matmul(&a, &b);
    }
}
