//! Blocked matrix multiplication for the compression hot path.
//!
//! PowerSGD's GEMMs are *skinny*: `A[n×m] · B[m×r]` and `Aᵀ[m×n] · P[n×r]`
//! with r ∈ 1..32 but n·m up to ~19M elements (the LSTM encoder layer).
//! Both kernels are single-pass streams over A (the bandwidth roofline):
//!
//! - `matmul` transposes the skinny B once (m·r ≤ a few hundred KB) so
//!   every output element is a contiguous dot product, computed with an
//!   8-way multi-accumulator that LLVM auto-vectorizes; the A row is hot
//!   in L1 across the r dots.
//! - `matmul_tn` accumulates into an r×m transposed scratch so the inner
//!   loop is a contiguous axpy, then transposes back once.
//!
//! All three `_into` kernels run on the kernel pool
//! ([`crate::runtime::pool`], DESIGN.md §11) when `--threads` /
//! `POWERSGD_THREADS` asks for more than one thread:
//!
//! - `matmul_into` / `matmul_nt_into` shard over **output rows**; every
//!   output element keeps the serial kernel's exact operation order, so
//!   results are bitwise identical at every thread count.
//! - `matmul_tn_into` shards over the **m dimension** of its r×m
//!   accumulator: each task owns a column band of the accumulator and
//!   streams all rows of A through it in the serial order, so every
//!   accumulator element again sums in the serial order.
//!
//! The per-call transpose/accumulator scratch (`bt`/`qt`/the tn band)
//! lives in per-thread buffers that grow once and are reused by every
//! later call on that thread — the steady-state step allocates nothing
//! here (`tests/integration_kernels.rs` pins both properties).
//!
//! Perf history: multi-accumulator + layout change ≈ 2–3× over the
//! naive blocked loop (`benches/kernel_hotpath.rs` tracks the numbers).

use super::Tensor;
use crate::obs::{span, Phase};
use crate::runtime::pool::{parallel_ranges, DisjointSlice};
use std::cell::RefCell;

/// Minimum per-range elements touched before a kernel fans out; tiny
/// layers stay on the calling thread (the partition never changes
/// results, only who computes them).
const MIN_PAR_ELEMS: usize = 16 * 1024;

thread_local! {
    /// Per-thread kernel scratch (`bt`/`qt` transposes, the tn
    /// accumulator band): grows to the step maximum once, then every
    /// later call on this thread reuses it — the zero-alloc steady
    /// state. Worker threads of the kernel pool persist for the
    /// process lifetime, so their buffers amortize the same way.
    static SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Borrow this thread's kernel scratch at `len` elements (contents are
/// stale; callers overwrite). Never nested — each kernel either uses
/// the scratch on the calling thread *or* inside its chunk tasks.
fn with_scratch<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// Contiguous dot product with 8 independent accumulators (ILP + SIMD).
#[inline]
fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for k in 0..chunks {
        let a8 = &a[k * 8..k * 8 + 8];
        let b8 = &b[k * 8..k * 8 + 8];
        for l in 0..8 {
            acc[l] += a8[l] * b8[l];
        }
    }
    let mut tail = 0.0f32;
    for k in chunks * 8..a.len() {
        tail += a[k] * b[k];
    }
    acc.iter().sum::<f32>() + tail
}

/// out[j] += s * a[j] over a contiguous slice (vectorizable fused axpy).
#[inline]
fn axpy_slice(out: &mut [f32], s: f32, a: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    for (o, &v) in out.iter_mut().zip(a.iter()) {
        *o += s * v;
    }
}

/// out[n×r] = A[n×m] · B[m×r], allocating the output.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[a.rows(), b.cols()]);
    matmul_into(a, b, &mut out);
    out
}

/// out[n×r] = A[n×m] · B[m×r]; `out` is overwritten. Sharded over
/// output rows on the kernel pool — bitwise identical to the serial
/// kernel at every thread count.
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let _span = span(Phase::MatmulNn);
    let (n, m) = (a.rows(), a.cols());
    let (mb, r) = (b.rows(), b.cols());
    assert_eq!(m, mb, "matmul inner-dim mismatch: {m} vs {mb}");
    assert_eq!(out.shape(), &[n, r], "matmul output shape");
    let ad = a.data();
    let bd = b.data();
    // Transpose skinny B once: column c becomes a contiguous row.
    with_scratch(m * r, |bt| {
        for k in 0..m {
            for c in 0..r {
                bt[c * m + k] = bd[k * r + c];
            }
        }
        let bt: &[f32] = bt;
        let od = DisjointSlice::new(out.data_mut());
        let min_rows = (MIN_PAR_ELEMS / m.max(1)).max(1);
        parallel_ranges(n, min_rows, move |i0, i1| {
            // SAFETY: row bands are disjoint across tasks.
            let band = unsafe { od.range_mut(i0 * r, i1 * r) };
            for i in i0..i1 {
                let arow = &ad[i * m..(i + 1) * m];
                let orow = &mut band[(i - i0) * r..(i - i0 + 1) * r];
                for (c, o) in orow.iter_mut().enumerate() {
                    *o = dot8(arow, &bt[c * m..(c + 1) * m]);
                }
            }
        });
    });
}
// NOTE (perf pass): a fused two-column dot with
// 4-wide accumulators was tried and REVERTED — it broke 8-lane (AVX2)
// auto-vectorization and ran 2x slower than one 8-wide dot per column.

/// out[m×r] = Aᵀ[m×n] · P[n×r] without materializing Aᵀ.
///
/// This is the second GEMM of the PowerSGD step (`Q = Mᵀ·P̂`). We stream
/// rows of A once and accumulate into an r×m transposed scratch so every
/// inner loop is a contiguous axpy over the A row. Parallelism shards
/// the **m dimension** of the accumulator: each task owns a column band
/// `[j0, j1)`, streams all n rows through its band in row order, and
/// transposes its band into `out` — every accumulator element keeps the
/// serial summation order, so results are bitwise identical at every
/// thread count.
pub fn matmul_tn_into(a: &Tensor, p: &Tensor, out: &mut Tensor) {
    let _span = span(Phase::MatmulTn);
    let (n, m) = (a.rows(), a.cols());
    let (np, r) = (p.rows(), p.cols());
    assert_eq!(n, np, "matmul_tn inner-dim mismatch: {n} vs {np}");
    assert_eq!(out.shape(), &[m, r], "matmul_tn output shape");
    let ad = a.data();
    let pd = p.data();
    let od = DisjointSlice::new(out.data_mut());
    let min_cols = (MIN_PAR_ELEMS / n.max(1)).max(1);
    parallel_ranges(m, min_cols, move |j0, j1| {
        let width = j1 - j0;
        with_scratch(r * width, |scratch| {
            scratch.fill(0.0);
            for i in 0..n {
                let arow = &ad[i * m + j0..i * m + j1];
                let prow = &pd[i * r..(i + 1) * r];
                for (c, &s) in prow.iter().enumerate() {
                    if s != 0.0 {
                        axpy_slice(&mut scratch[c * width..(c + 1) * width], s, arow);
                    }
                }
            }
            // SAFETY: column bands are disjoint across tasks.
            let band = unsafe { od.range_mut(j0 * r, j1 * r) };
            for j in 0..width {
                for c in 0..r {
                    band[j * r + c] = scratch[c * width + j];
                }
            }
        });
    });
}

/// out[n×m] = P[n×r] · Qᵀ where Q is m×r — the PowerSGD *reconstruction*
/// (decompress) kernel. The inner dimension is tiny (r), so the skinny
/// `matmul` path would pay its per-output-dot overhead on n·m outputs;
/// here we instead transpose Q once and emit each output row as r
/// contiguous scaled-accumulate passes (perf pass: 4.4 ms → 1.0 ms per
/// 512×4608 layer, tracked by `benches/kernel_hotpath.rs`). Sharded
/// over output rows like `matmul_into` — bitwise identical at every
/// thread count.
pub fn matmul_nt_into(p: &Tensor, q: &Tensor, out: &mut Tensor) {
    let _span = span(Phase::MatmulNt);
    let (n, r) = (p.rows(), p.cols());
    let (m, rq) = (q.rows(), q.cols());
    assert_eq!(r, rq, "matmul_nt rank mismatch: {r} vs {rq}");
    assert_eq!(out.shape(), &[n, m], "matmul_nt output shape");
    let pd = p.data();
    let qd = q.data();
    // Qᵀ: column c contiguous.
    with_scratch(r * m, |qt| {
        for j in 0..m {
            for c in 0..r {
                qt[c * m + j] = qd[j * r + c];
            }
        }
        let qt: &[f32] = qt;
        let od = DisjointSlice::new(out.data_mut());
        let min_rows = (MIN_PAR_ELEMS / m.max(1)).max(1);
        parallel_ranges(n, min_rows, move |i0, i1| {
            // SAFETY: row bands are disjoint across tasks.
            let band = unsafe { od.range_mut(i0 * m, i1 * m) };
            for i in i0..i1 {
                let orow = &mut band[(i - i0) * m..(i - i0 + 1) * m];
                // first term overwrites, the rest accumulate
                let s0 = pd[i * r];
                let q0 = &qt[..m];
                for (o, &v) in orow.iter_mut().zip(q0.iter()) {
                    *o = s0 * v;
                }
                for c in 1..r {
                    axpy_slice(orow, pd[i * r + c], &qt[c * m..(c + 1) * m]);
                }
            }
        });
    });
}

/// Allocating wrapper for [`matmul_nt_into`].
pub fn matmul_nt(p: &Tensor, q: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[p.rows(), q.rows()]);
    matmul_nt_into(p, q, &mut out);
    out
}

/// Convenience: Aᵀ·B allocating the output.
pub fn matmul_at_b(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[a.cols(), b.cols()]);
    matmul_tn_into(a, b, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pool::{set_threads, test_guard};
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (n, m, r) = (a.rows(), a.cols(), b.cols());
        let mut out = Tensor::zeros(&[n, r]);
        for i in 0..n {
            for j in 0..r {
                let mut acc = 0.0f64;
                for k in 0..m {
                    acc += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                out.set(i, j, acc as f32);
            }
        }
        out
    }

    fn random(shape: &[usize], rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn matches_naive_over_shapes_and_ranks() {
        let mut rng = Rng::new(11);
        for &(n, m) in &[(1, 1), (3, 5), (17, 64), (40, 300), (257, 31)] {
            for &r in &[1usize, 2, 3, 4, 7, 16] {
                let a = random(&[n, m], &mut rng);
                let b = random(&[m, r], &mut rng);
                let got = matmul(&a, &b);
                let want = naive(&a, &b);
                assert!(
                    got.allclose(&want, 1e-4, 1e-4),
                    "mismatch n={n} m={m} r={r}, max diff {}",
                    got.max_abs_diff(&want)
                );
            }
        }
    }

    #[test]
    fn transpose_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(12);
        for &(n, m, r) in &[(5, 3, 1), (64, 48, 2), (123, 77, 4), (30, 200, 9)] {
            let a = random(&[n, m], &mut rng);
            let p = random(&[n, r], &mut rng);
            let got = matmul_at_b(&a, &p);
            let want = matmul(&a.transpose(), &p);
            assert!(
                got.allclose(&want, 1e-4, 1e-4),
                "mismatch n={n} m={m} r={r}"
            );
        }
    }

    #[test]
    fn nt_matches_explicit_transpose() {
        let mut rng = Rng::new(14);
        for &(n, m, r) in &[(5, 3, 1), (64, 48, 2), (123, 77, 4), (30, 200, 7)] {
            let p = random(&[n, r], &mut rng);
            let q = random(&[m, r], &mut rng);
            let mut got = Tensor::zeros(&[n, m]);
            matmul_nt_into(&p, &q, &mut got);
            let want = matmul(&p, &q.transpose());
            assert!(got.allclose(&want, 1e-4, 1e-4), "n={n} m={m} r={r}");
        }
    }

    #[test]
    fn identity_matmul() {
        let mut rng = Rng::new(13);
        let a = random(&[6, 6], &mut rng);
        let mut eye = Tensor::zeros(&[6, 6]);
        for i in 0..6 {
            eye.set(i, i, 1.0);
        }
        assert!(matmul(&a, &eye).allclose(&a, 1e-6, 1e-6));
    }

    /// The determinism invariant at unit scale: every GEMM kernel is
    /// bitwise identical to its serial (1-thread) run at 2/4/8 threads.
    /// The full property suite over the paper's layer shapes lives in
    /// `tests/integration_kernels.rs`.
    #[test]
    fn parallel_kernels_bitwise_match_serial() {
        let _g = test_guard();
        let mut rng = Rng::new(15);
        for &(n, m, r) in &[(1, 1, 1), (257, 129, 2), (640, 384, 4)] {
            let a = random(&[n, m], &mut rng);
            let b = random(&[m, r], &mut rng);
            let p = random(&[n, r], &mut rng);
            let q = random(&[m, r], &mut rng);
            set_threads(1);
            let mut ab = Tensor::zeros(&[n, r]);
            matmul_into(&a, &b, &mut ab);
            let mut atp = Tensor::zeros(&[m, r]);
            matmul_tn_into(&a, &p, &mut atp);
            let mut pqt = Tensor::zeros(&[n, m]);
            matmul_nt_into(&p, &q, &mut pqt);
            for t in [2usize, 4, 8] {
                set_threads(t);
                let mut got = Tensor::zeros(&[n, r]);
                matmul_into(&a, &b, &mut got);
                assert_eq!(got.data(), ab.data(), "matmul n={n} m={m} r={r} t={t}");
                let mut got = Tensor::zeros(&[m, r]);
                matmul_tn_into(&a, &p, &mut got);
                assert_eq!(got.data(), atp.data(), "matmul_tn n={n} m={m} r={r} t={t}");
                let mut got = Tensor::zeros(&[n, m]);
                matmul_nt_into(&p, &q, &mut got);
                assert_eq!(got.data(), pqt.data(), "matmul_nt n={n} m={m} r={r} t={t}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn dimension_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        matmul(&a, &b);
    }
}
