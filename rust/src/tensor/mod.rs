//! Dense f32 tensor substrate.
//!
//! The coordinator's native hot path (PowerSGD compression, error
//! feedback, optimizer updates) runs on these tensors. The heavy model
//! fwd/bwd FLOPs run inside XLA via the PJRT runtime; here we only need
//! skinny GEMMs (`n×m · m×r`, r ≤ 32), elementwise kernels, and packing.
//!
//! Layout is always contiguous row-major. Shapes are `Vec<usize>`;
//! matrices are rank-2 views over the flat buffer.

mod matmul;
pub mod reference;
pub use matmul::{matmul, matmul_at_b, matmul_into, matmul_nt, matmul_nt_into, matmul_tn_into};

/// Contiguous row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor from existing data; `data.len()` must equal the shape volume.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(data.len(), n, "data length {} != shape volume {}", data.len(), n);
        Tensor { shape: shape.to_vec(), data }
    }

    /// Filled with a constant.
    pub fn full(shape: &[usize], value: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![value; n] }
    }

    /// The tensor's shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat row-major view of the elements.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat row-major view of the elements.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor, returning its flat buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows when viewed as a matrix (rank-2 only).
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on rank-{} tensor", self.shape.len());
        self.shape[0]
    }

    /// Number of columns when viewed as a matrix (rank-2 only).
    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on rank-{} tensor", self.shape.len());
        self.shape[1]
    }

    /// Matrix element access (rank-2 only, debug-friendly; hot loops index
    /// `data()` directly).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    /// Matrix element write (rank-2 only, debug-friendly).
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        let cols = self.shape[1];
        self.data[i * cols + j] = v;
    }

    /// Reinterpret with a new shape of the same volume (no copy).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape volume mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// Transposed copy (rank-2).
    pub fn transpose(&self) -> Tensor {
        let (n, m) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..n {
            for j in 0..m {
                out.data[j * n + i] = self.data[i * m + j];
            }
        }
        out
    }

    // ---- elementwise / BLAS-1 ----

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// self *= alpha
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Elementwise difference `self - other` as a new tensor.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape, "sub shape mismatch");
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a - b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Elementwise sum `self + other` as a new tensor.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape, "add shape mismatch");
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a + b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Dot product over flattened contents.
    pub fn dot(&self, other: &Tensor) -> f64 {
        assert_eq!(self.len(), other.len(), "dot length mismatch");
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum()
    }

    /// Frobenius / L2 norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }

    /// L1 norm.
    pub fn norm_l1(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64).abs()).sum()
    }

    /// Sum of elements.
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|x| *x as f64).sum()
    }

    /// Max |relative or absolute| difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// True iff elementwise |a-b| <= atol + rtol*|b|.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(other.data.iter())
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at(1, 2), 6.0);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_volume() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.at(2, 1), 6.0);
        assert_eq!(tt.transpose(), t);
    }

    #[test]
    fn axpy_scale_sub() {
        let mut a = Tensor::from_vec(&[3], vec![1., 2., 3.]);
        let b = Tensor::from_vec(&[3], vec![10., 20., 30.]);
        a.axpy(0.1, &b);
        assert_eq!(a.data(), &[2., 4., 6.]);
        a.scale(0.5);
        assert_eq!(a.data(), &[1., 2., 3.]);
        let d = b.sub(&a);
        assert_eq!(d.data(), &[9., 18., 27.]);
    }

    #[test]
    fn norms_and_dot() {
        let a = Tensor::from_vec(&[2, 2], vec![3., 0., 0., 4.]);
        assert!((a.norm() - 5.0).abs() < 1e-9);
        assert!((a.norm_l1() - 7.0).abs() < 1e-9);
        let b = Tensor::full(&[2, 2], 1.0);
        assert!((a.dot(&b) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]).reshape(&[2, 2]);
        assert_eq!(t.at(1, 0), 3.0);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.0 + 1e-6, 2.0 - 1e-6]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
        assert!(!a.allclose(&b, 0.0, 1e-8));
    }
}
