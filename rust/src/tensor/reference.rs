//! Naive reference GEMM kernels — the executable specification behind
//! [`KernelBackend::Reference`](crate::runtime::pool::KernelBackend).
//!
//! Each kernel here is the textbook triple loop: one serial f32
//! accumulation chain per output element, no packed panels, no lane
//! splitting, no skipped terms. Slow on purpose — these exist so that
//!
//! - the differential harness (`tests/integration_kernel_equiv.rs`)
//!   has an obviously-correct implementation to compare the blocked
//!   kernels against, and
//! - `benches/kernel_hotpath.rs` can report an honest blocked-vs-naive
//!   GFLOP/s speedup.
//!
//! They still run on the kernel pool (sharded over *disjoint outputs*,
//! never over accumulation), so each reference kernel is itself
//! bitwise-identical at every thread count — the harness sweeps
//! threads on both backends.
//!
//! Equivalence to the blocked kernels, per kernel (DESIGN.md §11):
//!
//! - [`matmul_tn_into`] and [`matmul_nt_into`]: the blocked kernels
//!   keep the exact per-element accumulation chain, so outputs are
//!   equal on finite data (`==` on every element; the blocked nt
//!   kernel's overwrite-first-term start can flip the sign of an exact
//!   zero, which `==` treats as equal).
//! - [`matmul_into`]: the blocked kernel splits the k dimension over
//!   8 lanes; a documented one-time numerics change, ULP-bounded and
//!   pinned by the harness.

use super::Tensor;
use crate::runtime::pool::{parallel_ranges, DisjointSlice};

/// Matches the blocked kernels' fan-out threshold so both backends
/// shard identically-shaped problems at the same sizes.
const MIN_PAR_ELEMS: usize = 16 * 1024;

/// out[n×r] = A[n×m] · B[m×r]: serial k-ordered f32 dot per output.
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (n, m) = (a.rows(), a.cols());
    let (mb, r) = (b.rows(), b.cols());
    assert_eq!(m, mb, "matmul inner-dim mismatch: {m} vs {mb}");
    assert_eq!(out.shape(), &[n, r], "matmul output shape");
    let ad = a.data();
    let bd = b.data();
    let od = DisjointSlice::new(out.data_mut());
    let min_rows = (MIN_PAR_ELEMS / m.max(1)).max(1);
    parallel_ranges(n, min_rows, move |i0, i1| {
        // SAFETY: row bands are disjoint across tasks.
        let band = unsafe { od.range_mut(i0 * r, i1 * r) };
        for i in i0..i1 {
            for c in 0..r {
                let mut acc = 0.0f32;
                for k in 0..m {
                    acc += ad[i * m + k] * bd[k * r + c];
                }
                band[(i - i0) * r + c] = acc;
            }
        }
    });
}

/// out[m×r] = Aᵀ[m×n] · P[n×r]: serial i-ordered f32 accumulation per
/// output, reading A column-wise (no transposed scratch).
pub fn matmul_tn_into(a: &Tensor, p: &Tensor, out: &mut Tensor) {
    let (n, m) = (a.rows(), a.cols());
    let (np, r) = (p.rows(), p.cols());
    assert_eq!(n, np, "matmul_tn inner-dim mismatch: {n} vs {np}");
    assert_eq!(out.shape(), &[m, r], "matmul_tn output shape");
    let ad = a.data();
    let pd = p.data();
    let od = DisjointSlice::new(out.data_mut());
    let min_cols = (MIN_PAR_ELEMS / n.max(1)).max(1);
    parallel_ranges(m, min_cols, move |j0, j1| {
        // SAFETY: column bands are disjoint across tasks.
        let band = unsafe { od.range_mut(j0 * r, j1 * r) };
        for j in j0..j1 {
            for c in 0..r {
                let mut acc = 0.0f32;
                for i in 0..n {
                    acc += ad[i * m + j] * pd[i * r + c];
                }
                band[(j - j0) * r + c] = acc;
            }
        }
    });
}

/// out[n×m] = P[n×r] · Qᵀ (Q is m×r): serial c-ordered f32 dot per
/// output element.
pub fn matmul_nt_into(p: &Tensor, q: &Tensor, out: &mut Tensor) {
    let (n, r) = (p.rows(), p.cols());
    let (m, rq) = (q.rows(), q.cols());
    assert_eq!(r, rq, "matmul_nt rank mismatch: {r} vs {rq}");
    assert_eq!(out.shape(), &[n, m], "matmul_nt output shape");
    let pd = p.data();
    let qd = q.data();
    let od = DisjointSlice::new(out.data_mut());
    let min_rows = (MIN_PAR_ELEMS / m.max(1)).max(1);
    parallel_ranges(n, min_rows, move |i0, i1| {
        // SAFETY: row bands are disjoint across tasks.
        let band = unsafe { od.range_mut(i0 * m, i1 * m) };
        for i in i0..i1 {
            for j in 0..m {
                let mut acc = 0.0f32;
                for c in 0..r {
                    acc += pd[i * r + c] * qd[j * r + c];
                }
                band[(i - i0) * m + j] = acc;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pool::{set_threads, test_guard};
    use crate::util::Rng;

    fn random(shape: &[usize], rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    /// The reference kernels are themselves thread-count invariant —
    /// otherwise the differential harness couldn't sweep threads on
    /// both backends.
    #[test]
    fn reference_kernels_bitwise_match_serial() {
        let _g = test_guard();
        let mut rng = Rng::new(23);
        let (n, m, r) = (300, 170, 3);
        let a = random(&[n, m], &mut rng);
        let b = random(&[m, r], &mut rng);
        let p = random(&[n, r], &mut rng);
        let q = random(&[m, r], &mut rng);
        set_threads(1);
        let mut ab = Tensor::zeros(&[n, r]);
        matmul_into(&a, &b, &mut ab);
        let mut atp = Tensor::zeros(&[m, r]);
        matmul_tn_into(&a, &p, &mut atp);
        let mut pqt = Tensor::zeros(&[n, m]);
        matmul_nt_into(&p, &q, &mut pqt);
        for t in [2usize, 4, 8] {
            set_threads(t);
            let mut got = Tensor::zeros(&[n, r]);
            matmul_into(&a, &b, &mut got);
            assert_eq!(got.data(), ab.data(), "reference nn t={t}");
            let mut got = Tensor::zeros(&[m, r]);
            matmul_tn_into(&a, &p, &mut got);
            assert_eq!(got.data(), atp.data(), "reference tn t={t}");
            let mut got = Tensor::zeros(&[n, m]);
            matmul_nt_into(&p, &q, &mut got);
            assert_eq!(got.data(), pqt.data(), "reference nt t={t}");
        }
    }

    /// Against an f64 oracle: the reference kernels are the textbook
    /// computation, merely rounded per-step to f32.
    #[test]
    fn reference_matches_f64_oracle() {
        let mut rng = Rng::new(24);
        let (n, m, r) = (37, 53, 4);
        let a = random(&[n, m], &mut rng);
        let b = random(&[m, r], &mut rng);
        let mut oracle = Tensor::zeros(&[n, r]);
        for i in 0..n {
            for c in 0..r {
                let mut acc = 0.0f64;
                for k in 0..m {
                    acc += a.at(i, k) as f64 * b.at(k, c) as f64;
                }
                oracle.set(i, c, acc as f32);
            }
        }
        let mut got = Tensor::zeros(&[n, r]);
        matmul_into(&a, &b, &mut got);
        assert!(got.allclose(&oracle, 1e-4, 1e-4));
    }
}
