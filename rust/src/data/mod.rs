//! Synthetic dataset substrates (DESIGN.md §6).
//!
//! The offline environment has no CIFAR10 / WikiText; these generators
//! produce deterministic workloads that exercise the same optimization
//! dynamics:
//!
//! - [`Classification`] — K-class Gaussian-mixture images. Each class
//!   owns a few random prototypes; samples are prototype + noise. The
//!   classes overlap, so models must actually learn boundaries and
//!   compressor quality separates test accuracy (Tables 1/2/4/6).
//! - [`LmCorpus`] — Zipf-distributed tokens with Markov bigram structure,
//!   a proxy for WikiText: perplexity is meaningful and embedding-heavy
//!   models stress the communication path (Table 7 / Appendix D).
//!
//! Sharding: worker `w` of `W` draws disjoint sample streams (split RNG),
//! matching the paper's i.i.d. data-parallel setting.

use crate::runtime::Value;
use crate::tensor::Tensor;
use crate::util::Rng;

/// A per-worker batch supplier.
pub trait DataSource: Send {
    /// Next training batch for `worker` (advances that worker's stream).
    fn next_batch(&mut self, worker: usize) -> Vec<Value>;
    /// A fixed held-out evaluation batch (same for all callers).
    fn eval_batch(&mut self) -> Vec<Value>;
}

/// K-class Gaussian-mixture classification task.
pub struct Classification {
    /// Input dimensionality (flattened "image" size).
    pub dim: usize,
    /// Number of classes K.
    pub classes: usize,
    /// Samples per worker per batch.
    pub batch_per_worker: usize,
    prototypes: Vec<Vec<f32>>, // classes × protos_per_class flattened
    protos_per_class: usize,
    noise: f32,
    worker_rngs: Vec<Rng>,
    eval_rng: Rng,
    eval_cache: Option<Vec<Value>>,
    eval_batch_size: usize,
}

impl Classification {
    /// Deterministic task: `classes` Gaussian clusters in `dim`
    /// dimensions, sharded over `workers` disjoint streams from `seed`.
    pub fn new(
        dim: usize,
        classes: usize,
        batch_per_worker: usize,
        workers: usize,
        seed: u64,
    ) -> Classification {
        let mut root = Rng::new(seed);
        let protos_per_class = 3;
        // Prototypes drawn on a sphere of radius ~1.4 so classes overlap
        // under unit noise but are separable by a trained model.
        let mut prototypes = Vec::with_capacity(classes * protos_per_class);
        for _ in 0..classes * protos_per_class {
            let mut p = vec![0.0f32; dim];
            root.fill_normal(&mut p, 1.0);
            // Cyclic box blur: gives prototypes the low-frequency spatial
            // structure natural images have, so convolutional models can
            // average noise over neighbourhoods (white noise stays white).
            let blur = 9usize.min(dim);
            let mut smooth = vec![0.0f32; dim];
            for i in 0..dim {
                let mut acc = 0.0;
                for k in 0..blur {
                    acc += p[(i + k) % dim];
                }
                smooth[i] = acc / blur as f32;
            }
            let mut p = smooth;
            let norm = p.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
            // Per-coordinate prototype scale ~ 0.55 (vs noise sigma 0.9):
            // overlapping but separable clusters.
            let scale = 0.55 * (dim as f32).sqrt() / norm;
            for v in p.iter_mut() {
                *v *= scale;
            }
            prototypes.push(p);
        }
        let worker_rngs = (0..workers).map(|w| root.split(w as u64 + 1)).collect();
        let eval_rng = root.split(0xEEE);
        Classification {
            dim,
            classes,
            batch_per_worker,
            prototypes,
            protos_per_class,
            noise: 0.9,
            worker_rngs,
            eval_rng,
            eval_cache: None,
            eval_batch_size: 256,
        }
    }

    fn sample_into(&self, rng: &mut Rng, x: &mut [f32], y: &mut [i32], n: usize, dim: usize) {
        for i in 0..n {
            let class = rng.below(self.classes as u64) as usize;
            let proto_ix =
                class * self.protos_per_class + rng.below(self.protos_per_class as u64) as usize;
            let proto = &self.prototypes[proto_ix];
            for d in 0..dim {
                x[i * dim + d] = proto[d] + rng.normal() as f32 * self.noise;
            }
            y[i] = class as i32;
        }
    }
}

impl DataSource for Classification {
    fn next_batch(&mut self, worker: usize) -> Vec<Value> {
        let (n, dim) = (self.batch_per_worker, self.dim);
        let mut x = vec![0.0f32; n * dim];
        let mut y = vec![0i32; n];
        let mut rng = self.worker_rngs[worker].clone();
        self.sample_into(&mut rng, &mut x, &mut y, n, dim);
        self.worker_rngs[worker] = rng;
        vec![
            Value::F32(Tensor::from_vec(&[n, dim], x)),
            Value::I32(vec![n], y),
        ]
    }

    fn eval_batch(&mut self) -> Vec<Value> {
        if self.eval_cache.is_none() {
            let (n, dim) = (self.eval_batch_size, self.dim);
            let mut x = vec![0.0f32; n * dim];
            let mut y = vec![0i32; n];
            let mut rng = self.eval_rng.clone();
            self.sample_into(&mut rng, &mut x, &mut y, n, dim);
            self.eval_cache = Some(vec![
                Value::F32(Tensor::from_vec(&[n, dim], x)),
                Value::I32(vec![n], y),
            ]);
        }
        self.eval_cache.clone().unwrap()
    }
}

/// Zipf + Markov-bigram synthetic language corpus.
pub struct LmCorpus {
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequences per worker per batch.
    pub batch_per_worker: usize,
    /// Tokens per sequence.
    pub seq_len: usize,
    /// Per-token successor tables: `succ[t]` lists plausible next tokens.
    succ: Vec<Vec<u32>>,
    /// Zipf sampling table (token ids, heavy head).
    zipf_weights: Vec<f64>,
    worker_rngs: Vec<Rng>,
    eval_rng: Rng,
    eval_cache: Option<Vec<Value>>,
    eval_batch_size: usize,
}

impl LmCorpus {
    /// Deterministic corpus: Zipf(1.1) unigrams with bigram successor
    /// structure, sharded over `workers` disjoint streams from `seed`.
    pub fn new(
        vocab: usize,
        batch_per_worker: usize,
        seq_len: usize,
        workers: usize,
        seed: u64,
    ) -> LmCorpus {
        let mut root = Rng::new(seed ^ 0x11A0);
        // Zipf(1.1) unigram weights.
        let zipf_weights: Vec<f64> = (1..=vocab).map(|r| 1.0 / (r as f64).powf(1.1)).collect();
        // Bigram structure: each token has 8 preferred successors; with
        // prob 0.75 the next token comes from the successor table, else
        // from the unigram Zipf. Gives the corpus learnable structure
        // (perplexity well below vocab size for a trained model).
        let branch = 8;
        let succ: Vec<Vec<u32>> = (0..vocab)
            .map(|_| {
                (0..branch)
                    .map(|_| root.weighted_index(&zipf_weights) as u32)
                    .collect()
            })
            .collect();
        let worker_rngs = (0..workers).map(|w| root.split(w as u64 + 101)).collect();
        let eval_rng = root.split(0xFFF);
        LmCorpus {
            vocab,
            batch_per_worker,
            seq_len,
            succ,
            zipf_weights,
            worker_rngs,
            eval_rng,
            eval_cache: None,
            eval_batch_size: 16,
        }
    }

    fn gen_tokens(&self, rng: &mut Rng, count: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(count);
        let mut cur = rng.weighted_index(&self.zipf_weights) as u32;
        out.push(cur as i32);
        for _ in 1..count {
            cur = if rng.uniform() < 0.75 {
                let s = &self.succ[cur as usize];
                s[rng.below(s.len() as u64) as usize]
            } else {
                rng.weighted_index(&self.zipf_weights) as u32
            };
            out.push(cur as i32);
        }
        out
    }

    fn make_batch(&self, rng: &mut Rng, batch: usize) -> Vec<Value> {
        let t = self.seq_len;
        let mut inputs = Vec::with_capacity(batch * t);
        let mut targets = Vec::with_capacity(batch * t);
        for _ in 0..batch {
            let toks = self.gen_tokens(rng, t + 1);
            inputs.extend_from_slice(&toks[..t]);
            targets.extend_from_slice(&toks[1..]);
        }
        vec![
            Value::I32(vec![batch, t], inputs),
            Value::I32(vec![batch, t], targets),
        ]
    }
}

impl DataSource for LmCorpus {
    fn next_batch(&mut self, worker: usize) -> Vec<Value> {
        let mut rng = self.worker_rngs[worker].clone();
        let b = self.make_batch(&mut rng, self.batch_per_worker);
        self.worker_rngs[worker] = rng;
        b
    }

    fn eval_batch(&mut self) -> Vec<Value> {
        if self.eval_cache.is_none() {
            let mut rng = self.eval_rng.clone();
            self.eval_cache = Some(self.make_batch(&mut rng, self.eval_batch_size));
        }
        self.eval_cache.clone().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_shapes_and_labels() {
        let mut d = Classification::new(16, 4, 8, 2, 1);
        let b = d.next_batch(0);
        assert_eq!(b[0].shape(), &[8, 16]);
        assert_eq!(b[1].shape(), &[8]);
        if let Value::I32(_, y) = &b[1] {
            assert!(y.iter().all(|&c| (0..4).contains(&c)));
        } else {
            panic!("labels must be i32");
        }
    }

    #[test]
    fn workers_get_different_streams() {
        let mut d = Classification::new(8, 3, 4, 2, 2);
        let b0 = d.next_batch(0);
        let b1 = d.next_batch(1);
        if let (Value::F32(x0), Value::F32(x1)) = (&b0[0], &b1[0]) {
            assert!(x0.max_abs_diff(x1) > 1e-3);
        } else {
            panic!();
        }
    }

    #[test]
    fn streams_are_deterministic_per_seed() {
        let batch = |seed| {
            let mut d = Classification::new(8, 3, 4, 1, seed);
            match &d.next_batch(0)[0] {
                Value::F32(t) => t.clone(),
                _ => panic!(),
            }
        };
        assert_eq!(batch(7), batch(7));
        assert!(batch(7).max_abs_diff(&batch(8)) > 1e-3);
    }

    #[test]
    fn eval_batch_is_fixed() {
        let mut d = Classification::new(8, 3, 4, 1, 3);
        let a = d.eval_batch();
        let b = d.eval_batch();
        if let (Value::F32(x), Value::F32(y)) = (&a[0], &b[0]) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn lm_targets_are_shifted_inputs() {
        let mut d = LmCorpus::new(100, 2, 12, 1, 4);
        let b = d.next_batch(0);
        if let (Value::I32(_, x), Value::I32(_, y)) = (&b[0], &b[1]) {
            // rows of length 12: y[i] == x[i+1] within a row
            for row in 0..2 {
                for i in 0..11 {
                    assert_eq!(y[row * 12 + i], x[row * 12 + i + 1]);
                }
            }
            assert!(x.iter().all(|&t| (0..100).contains(&t)));
        } else {
            panic!();
        }
    }

    #[test]
    fn lm_zipf_head_is_heavy() {
        let mut d = LmCorpus::new(500, 4, 64, 1, 5);
        let mut counts = vec![0usize; 500];
        for _ in 0..30 {
            let b = d.next_batch(0);
            if let Value::I32(_, x) = &b[0] {
                for &t in x {
                    counts[t as usize] += 1;
                }
            }
        }
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[400..].iter().sum();
        assert!(head > 10 * tail.max(1), "head {head} tail {tail}");
    }
}
