//! Compile-time stub of the `xla` PJRT bindings (CI only).
//!
//! Mirrors exactly the API surface `powersgd::runtime` uses. Every
//! operation that would need native XLA returns an error at run time;
//! nothing in CI reaches them because all PJRT-dependent tests skip
//! when `artifacts/` is absent.

use std::fmt;

/// Error produced by every unavailable operation.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!("{what} unavailable (stub build without native XLA)")))
}

/// Host-side literal value.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle. Construction succeeds (so callers can probe for
/// artifacts before touching the device); compilation does not.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
